"""Train step construction: loss, grad, AdamW update — distribution-aware.

``make_train_step`` returns a pure function (state, batch) → (state, metrics)
suitable for jit with in/out shardings derived from the ShardingPlan;
GSPMD turns the data-parallel gradient sum into reduce-scatter/all-gather
pairs when FSDP sharding is active (ZeRO), or all-reduce otherwise.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.layers.common import activate_rules, lconstraint
from repro.models import lm
from repro.optim.adamw import AdamWConfig, adamw_update

PyTree = Any


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  ignore_id: int = -1) -> jax.Array:
    """Mean token NLL in f32.  logits: [B,S,V]; labels: [B,S]."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    take = jnp.take_along_axis(
        logp, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    mask = (labels != ignore_id).astype(jnp.float32)
    return -jnp.sum(take * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _loss_fn(params, batch, cfg: ArchConfig):
    logits, aux = lm.forward_train(params, batch, cfg)
    labels = batch["labels"]
    # (VLM logits already cover only the text suffix — see forward_train)
    loss = cross_entropy(logits, labels)
    return loss + aux, (loss, aux)


def make_train_step(cfg: ArchConfig, hp: AdamWConfig,
                    act_rules: Optional[Dict] = None,
                    accum_steps: int = 1):
    """Returns train_step(state, batch) → (state, metrics).

    state = {"params", "opt": {"m","v"}, "step"}.

    accum_steps > 1 runs gradient accumulation over microbatches (a scan):
    live activation memory scales with B/accum_steps — required to fit the
    train_4k cells on 16 GB v5e HBM (see EXPERIMENTS.md §Dry-run).
    """

    def _constrain_batch(b):
        return jax.tree.map(
            lambda t: lconstraint(t, ("batch",) + (None,) * (t.ndim - 1)), b)

    def train_step(state, batch):
        with activate_rules(act_rules):
            grad_fn = jax.value_and_grad(_loss_fn, has_aux=True)
            if accum_steps == 1:
                (total, (loss, aux)), grads = grad_fn(state["params"], batch,
                                                      cfg)
            else:
                mb = jax.tree.map(
                    lambda t: t.reshape(accum_steps, t.shape[0] // accum_steps,
                                        *t.shape[1:]), batch)

                def mb_step(acc, mbatch):
                    mbatch = _constrain_batch(mbatch)
                    (tt, (ll, aa)), g = grad_fn(state["params"], mbatch, cfg)
                    g_acc, t_acc, l_acc, a_acc = acc
                    g_acc = jax.tree.map(
                        lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                    return (g_acc, t_acc + tt, l_acc + ll, a_acc + aa), None

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32),
                    state["params"])
                init = (zeros, jnp.zeros((), jnp.float32),
                        jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
                (grads, total, loss, aux), _ = jax.lax.scan(
                    mb_step, init, mb)
                scale = 1.0 / accum_steps
                grads = jax.tree.map(lambda g: g * scale, grads)
                total, loss, aux = total * scale, loss * scale, aux * scale
            new_params, new_opt, om = adamw_update(
                state["params"], grads, state["opt"], state["step"], hp)
        metrics = {"loss": loss, "aux_loss": aux, "total_loss": total, **om}
        return ({"params": new_params, "opt": new_opt,
                 "step": state["step"] + 1}, metrics)

    return train_step


def make_eval_step(cfg: ArchConfig, act_rules: Optional[Dict] = None):
    def eval_step(params, batch):
        with activate_rules(act_rules):
            _, (loss, aux) = _loss_fn(params, batch, cfg)
        return {"loss": loss, "aux_loss": aux}
    return eval_step


def init_state_specs(cfg: ArchConfig):
    """ParamSpec pytree for the full train state (params + AdamW moments)."""
    from repro.optim.adamw import opt_state_specs
    pspecs = lm.param_specs(cfg)
    return {"params": pspecs, "opt": opt_state_specs(pspecs)}
