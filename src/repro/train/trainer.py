"""Training loop with production concerns:

* checkpoint/restart — periodic async checkpoints; on (injected or real)
  step failure the trainer restores the latest checkpoint, rewinds the
  data cursor (the pipeline is seekable), and continues — the resumed loss
  trajectory is bit-identical to an uninterrupted run (tested);
* straggler monitor — per-step wall-time EMA; steps slower than
  ``k × EMA`` fire a configurable action (on real multi-host deployments
  this hooks the coordinator to re-shard or evict; here it logs and
  counts — the decision logic is what we can test without a fleet);
* optional gradient compression (int8 + error feedback) before the update;
* elastic restart — checkpoints restore onto any mesh (see checkpoint.py).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro import obs
from repro.checkpoint.checkpoint import Checkpointer

PyTree = Any


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    async_checkpoint: bool = True
    log_every: int = 10
    # straggler detection
    straggler_factor: float = 3.0
    straggler_warmup: int = 5
    straggler_action: str = "log"      # log | checkpoint
    # failure injection (testing fault tolerance)
    fail_at_steps: tuple = ()
    max_restarts: int = 10


class StragglerMonitor:
    def __init__(self, factor: float, warmup: int):
        self.factor = factor
        self.warmup = warmup
        self.ema: Optional[float] = None
        self.events: List[Dict] = []
        self._n = 0

    def observe(self, step: int, dt: float) -> bool:
        self._n += 1
        if self.ema is None:
            self.ema = dt
            return False
        is_straggler = (self._n > self.warmup
                        and dt > self.factor * self.ema)
        if is_straggler:
            self.events.append({"step": step, "dt": dt, "ema": self.ema})
        # EMA updated with clipped dt so one outlier doesn't poison the basis
        self.ema = 0.9 * self.ema + 0.1 * min(dt, 2 * self.ema)
        return is_straggler


class InjectedFailure(RuntimeError):
    pass


class Trainer:
    def __init__(self, cfg: TrainerConfig, train_step: Callable,
                 pipeline, init_state: PyTree,
                 state_shardings: Optional[PyTree] = None,
                 to_device: Optional[Callable] = None):
        self.cfg = cfg
        self.train_step = train_step
        self.pipeline = pipeline
        self.state = init_state
        self.state_shardings = state_shardings
        self.to_device = to_device or (lambda b: jax.tree.map(
            jax.numpy.asarray, b))
        self.ckpt = Checkpointer(cfg.checkpoint_dir,
                                 keep=cfg.keep_checkpoints)
        self.monitor = StragglerMonitor(cfg.straggler_factor,
                                        cfg.straggler_warmup)
        self.history: List[Dict] = []
        self.restarts = 0

    # ------------------------------------------------------------------
    def _current_step(self) -> int:
        return int(jax.device_get(self.state["step"]))

    def _maybe_fail(self, step: int, already_failed: set):
        if step in self.cfg.fail_at_steps and step not in already_failed:
            already_failed.add(step)
            raise InjectedFailure(f"injected failure at step {step}")

    def _restore_latest(self):
        latest = self.ckpt.latest_step()
        if latest is None:
            raise RuntimeError("failure before first checkpoint — "
                               "cannot recover")
        self.state, extra = self.ckpt.restore(
            self.state, step=latest, shardings=self.state_shardings)
        return latest

    # ------------------------------------------------------------------
    def run(self) -> List[Dict]:
        cfg = self.cfg
        failed: set = set()
        # step 0 checkpoint so any early failure is recoverable
        self.ckpt.save(self._current_step(), self.state, blocking=True)
        while self._current_step() < cfg.total_steps:
            step = self._current_step()
            try:
                self._maybe_fail(step, failed)
                batch = self.to_device(self.pipeline.batch_at(step))
                # perf_counter, not time.time(): wall clock is not
                # monotonic — an NTP step mid-step would corrupt the
                # timing, poison the straggler EMA, and skew the
                # histogram
                with obs.span("trainer.step", step=step):
                    t0 = time.perf_counter()
                    self.state, metrics = self.train_step(self.state, batch)
                    metrics = {k: float(jax.device_get(v))
                               for k, v in metrics.items()}
                    dt = time.perf_counter() - t0
                obs.metrics.histogram("trainer.step_us").observe(dt * 1e6)
                if self.monitor.observe(step, dt):
                    metrics["straggler"] = 1.0
                    if cfg.straggler_action == "checkpoint":
                        self.ckpt.save(self._current_step(), self.state,
                                       blocking=False)
                metrics.update({"step": step, "dt": dt})
                self.history.append(metrics)
                if cfg.log_every and step % cfg.log_every == 0:
                    print(f"step {step:6d} loss {metrics.get('loss', 0):.4f} "
                          f"({dt*1e3:.0f} ms)")
                nxt = self._current_step()
                if nxt % cfg.checkpoint_every == 0:
                    self.ckpt.save(nxt, self.state,
                                   blocking=not cfg.async_checkpoint)
            except InjectedFailure as e:
                self.restarts += 1
                if self.restarts > cfg.max_restarts:
                    raise
                self.ckpt.wait()
                restored = self._restore_latest()
                print(f"[trainer] {e}; restored step {restored}, resuming")
        self.ckpt.wait()
        self.ckpt.save(self._current_step(), self.state, blocking=True)
        return self.history
