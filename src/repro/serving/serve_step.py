"""Serving steps: prefill (context ingest → cache) and decode (one token).

These are the functions the decode_* / long_* dry-run cells lower: decode is
a single new-token step against a seq_len-sized cache (ring-buffered for
sliding-window blocks, O(1) recurrent state for SSM/hybrid blocks)."""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.layers.common import activate_rules
from repro.models import lm

PyTree = Any


def make_prefill_step(cfg: ArchConfig, act_rules: Optional[Dict] = None):
    def prefill_step(params, batch):
        with activate_rules(act_rules):
            last_logits, cache = lm.prefill(params, batch, cfg)
        return last_logits, cache
    return prefill_step


def make_decode_step(cfg: ArchConfig, act_rules: Optional[Dict] = None):
    """decode_step(params, cache, token [B], pos [B]) → (logits, cache).

    The cache argument is donatable (same sharding in/out) — serving engines
    run it in a double-buffer-free loop."""
    def decode_step(params, cache, token, pos):
        with activate_rules(act_rules):
            logits, new_cache = lm.decode_step(params, cfg, token=token,
                                               pos=pos, cache=cache)
        return logits, new_cache
    return decode_step


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample(logits: jax.Array, rng: jax.Array, temperature: float = 1.0):
    if temperature == 0.0:
        return greedy_sample(logits)
    return jax.random.categorical(
        rng, logits.astype(jnp.float32) / temperature, axis=-1).astype(jnp.int32)
