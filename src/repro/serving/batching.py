"""Continuous batching for conv-net serving: async request queue,
deadline-driven batch formation, multi-model LRU program cache.

The paper's full-board mode (§5.2: ~20 replicated IP cores, 4.48 GOPS) is
a *serving* configuration — the fabric earns its throughput only if the
host keeps its lanes full.  The submit-and-wait engine this module
replaces did not: every caller blocked on its own microbatch, partial
batches burned padded lanes, and each network needed its own engine and
compiled program.  The FPGA-CNN acceleration surveys (Guo et al. 2017,
Jiang et al. 2025 — PAPERS.md) both name batch scheduling and on-chip
resource reuse, not raw MACs, as what decides deployed throughput; this
is the host half of that argument.

Three pieces, composable and individually testable:

* :class:`RequestQueue` — thread-safe admission into two priority lanes
  (``interactive`` / ``bulk``).  **Batch formation is deadline-driven**:
  a batch launches when some model has a full batch, when the oldest
  queued request hits the configured latency deadline, or when a
  synchronous caller is draining — never by waiting for stragglers.
  Bulk requests **age into the interactive lane** after
  ``bulk_aging_ms`` (ordered by original enqueue time), so interactive
  traffic preempts bulk without ever starving it.  Formation is a pure
  function of (queue contents, clock) so tests drive every reason —
  ``full`` / ``deadline`` / ``drain`` — with a fake clock and no
  threads.

* :class:`ProgramCache` — a bounded LRU of compiled
  ``(network, backend)`` programs.  One engine serves the whole zoo off
  one backend/scheduler; eviction and recompile are *measured* (hit /
  miss / eviction counters, ``engine.compile`` spans), bounded
  (``capacity``), and observable (``cache.size`` gauge).

* :class:`ContinuousBatchingEngine` — the serving loop.  ``submit_async``
  returns a :class:`concurrent.futures.Future` per request; a single
  worker thread forms batches, pads them onto the fixed ``[batch,H,W,C]``
  program shape, and dispatches through ``MultiCoreScheduler``.  Dispatch
  uses JAX **async dispatch**: up to ``max_inflight`` batches are in
  flight with unmaterialized device results while the next batch forms
  and launches (slot reuse across in-flight batches), and results
  materialize (``np.asarray``) only at retirement.  With ``route=True``
  and a per-model ``NetworkTunePlan``, each formed batch is routed
  through the ``MultiCoreScheduler`` mode the calibrated perf model
  predicts fastest for that *(network, formed-batch-size)* pair
  (``core/autotune.route_batch``) — small deadline-launched batches take
  the single-image kout/spatial modes, full batches take batch sharding.

Telemetry (all through the PR 9 obs layer, in the engine's own
``MetricsRegistry``): ``queue.depth`` / ``queue.depth.peak`` gauges,
``queue_wait_us`` + ``batch_device_us`` + honest enqueue→result
``request_latency_us`` histograms, ``batch_formed.{full,deadline,drain}``
and ``cache.{hits,misses,evictions}`` counters, ``batch_fill``, and
``route.<mode>`` counters when routing is live.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict, deque
from concurrent.futures import Future
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs

PRIORITIES = ("interactive", "bulk")
FORMATION_REASONS = ("full", "deadline", "drain")

# a synchronous caller waiting on its own requests must fail loudly, not
# hang CI, if the worker dies — generous because interpret-mode compiles
# of large plans take minutes on CPU
SUBMIT_TIMEOUT_S = 600.0


@dataclasses.dataclass
class ServeRequest:
    """One admitted single-image request (engine-internal)."""
    uid: int
    model: str
    image: np.ndarray                    # [H, W, C] float32
    priority: str
    enqueue_ns: int
    deadline_ns: int
    future: Future


@dataclasses.dataclass
class FormedBatch:
    """A launched batch: which model, which requests, and why it left
    the queue (``full`` / ``deadline`` / ``drain``)."""
    model: str
    requests: List[ServeRequest]
    reason: str


class RequestQueue:
    """Two-lane priority queue with deadline-driven batch formation.

    Admission (``push_many``) is thread-safe and atomic: a caller's
    requests become visible to the batch former all at once, so a
    synchronous ``submit`` of R images can never have its first
    ``batch`` images split by a racing deadline.  ``form`` decides, for
    a given clock reading, whether a batch should launch and why:

    * ``full`` — some model has at least ``batch`` queued requests; the
      winning model is the one owning the oldest request in formation
      order (interactive + aged bulk by enqueue time, then fresh bulk);
    * ``deadline`` — the oldest queued request (either lane) is past
      ``deadline_ms``; its model launches with whatever it has;
    * ``drain`` — a synchronous caller is waiting; partial batches
      launch rather than idling until the deadline.

    Bulk requests older than ``bulk_aging_ms`` are *promoted*: they
    merge into the interactive ordering by original enqueue time, so a
    saturating interactive load cannot starve them (they out-age it).

    ``clock`` is injectable (perf_counter_ns by default) so formation
    semantics are unit-testable without threads or sleeps."""

    def __init__(self, registry: obs.MetricsRegistry, *,
                 deadline_ms: float = 5.0, bulk_aging_ms: float = 50.0,
                 clock: Callable[[], int] = time.perf_counter_ns):
        if deadline_ms <= 0:
            raise ValueError(f"deadline_ms must be > 0, got {deadline_ms}")
        self.cond = threading.Condition()
        self.deadline_ns = int(deadline_ms * 1e6)
        self.aging_ns = int(bulk_aging_ms * 1e6)
        self.clock = clock
        self._lanes: Dict[str, deque] = {p: deque() for p in PRIORITIES}
        self._depth = registry.gauge("queue.depth")
        self._peak = registry.gauge("queue.depth.peak")
        self._depth.set(0)
        self._peak.set(0)

    # -- admission -----------------------------------------------------------

    def push_many(self, reqs: Sequence[ServeRequest]) -> None:
        with self.cond:
            for r in reqs:
                if r.priority not in self._lanes:
                    raise ValueError(f"unknown priority {r.priority!r}; "
                                     f"have {PRIORITIES}")
                self._lanes[r.priority].append(r)
            d = self._len_locked()
            self._depth.set(d)
            if d > (self._peak.value or 0):
                self._peak.set(d)
            self.cond.notify_all()

    def _len_locked(self) -> int:
        return sum(len(q) for q in self._lanes.values())

    def __len__(self) -> int:
        with self.cond:
            return self._len_locked()

    # -- formation -----------------------------------------------------------

    def next_deadline_ns(self) -> Optional[int]:
        """Earliest queued deadline (caller must hold ``cond``)."""
        heads = [q[0].deadline_ns for q in self._lanes.values() if q]
        return min(heads) if heads else None

    def form(self, batch: int, *, drain: bool = False,
             now_ns: Optional[int] = None) -> Optional[FormedBatch]:
        with self.cond:
            return self.form_locked(batch, drain=drain, now_ns=now_ns)

    def form_locked(self, batch: int, *, drain: bool = False,
                    now_ns: Optional[int] = None) -> Optional[FormedBatch]:
        """Formation decision for one clock reading (hold ``cond``)."""
        now = self.clock() if now_ns is None else now_ns
        inter, bulk = self._lanes["interactive"], self._lanes["bulk"]
        if not inter and not bulk:
            return None
        promoted = [r for r in bulk if now - r.enqueue_ns >= self.aging_ns]
        fresh = [r for r in bulk if now - r.enqueue_ns < self.aging_ns]
        # formation order: interactive + aged bulk by original enqueue
        # time (aged bulk is older than the interactive flood that would
        # otherwise starve it), then fresh bulk FIFO
        urgent = sorted([*inter, *promoted], key=lambda r: r.enqueue_ns)
        ordered = urgent + fresh
        counts: Dict[str, int] = {}
        for r in ordered:
            counts[r.model] = counts.get(r.model, 0) + 1
        model = reason = None
        for r in ordered:                    # oldest full model wins
            if counts[r.model] >= batch:
                model, reason = r.model, "full"
                break
        if reason is None:
            oldest = min((q[0] for q in self._lanes.values() if q),
                         key=lambda r: r.enqueue_ns)
            if now >= oldest.deadline_ns:
                model, reason = oldest.model, "deadline"
            elif drain:
                model, reason = ordered[0].model, "drain"
            else:
                return None
        take = [r for r in ordered if r.model == model][:batch]
        taken = set(id(r) for r in take)
        for lane in self._lanes.values():
            kept = [r for r in lane if id(r) not in taken]
            lane.clear()
            lane.extend(kept)
        self._depth.set(self._len_locked())
        return FormedBatch(model=model, requests=take, reason=reason)


class ProgramCache:
    """Bounded LRU of compiled programs, keyed by ``(network, backend)``.

    ``get`` is get-or-build: a hit refreshes recency, a miss runs
    ``build()`` (the caller wraps it in an ``engine.compile`` span) and
    evicts the least-recently-used entries past ``capacity``.  Hit /
    miss / eviction counters and a ``cache.size`` gauge live in the
    engine registry, so eviction + recompile is measured and bounded —
    the multi-model serving contract."""

    def __init__(self, capacity: int, registry: obs.MetricsRegistry):
        if capacity < 1:
            raise ValueError(f"cache capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._lock = threading.Lock()
        self._entries: "OrderedDict[Any, Any]" = OrderedDict()
        self._hits = registry.counter("cache.hits")
        self._misses = registry.counter("cache.misses")
        self._evictions = registry.counter("cache.evictions")
        self._size = registry.gauge("cache.size")
        self._size.set(0)

    def get(self, key, build: Callable[[], Any]):
        with self._lock:
            if key in self._entries:
                self._hits.inc()
                self._entries.move_to_end(key)
                return self._entries[key]
            self._misses.inc()
            value = build()
            self._entries[key] = value
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self._evictions.inc()
            self._size.set(len(self._entries))
            return value

    def __contains__(self, key) -> bool:
        with self._lock:
            return key in self._entries

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def keys(self) -> List[Any]:
        with self._lock:
            return list(self._entries)


@dataclasses.dataclass
class _Model:
    """One registered network: quantized weights, admission shape, the
    static scheduler verdict, and (when routing) the per-formed-size
    route table."""
    name: str
    qnet: Any
    input_shape: Tuple[int, int, int]
    classes: int
    tune: Any
    backend_name: str
    sched: Any
    routes: Dict[int, Tuple[str, Any, str]] = \
        dataclasses.field(default_factory=dict)


class ContinuousBatchingEngine:
    """Multi-model continuous-batching engine over compiled int8
    NetworkPlan programs.

    ``add_model`` registers a quantized network (admission keyed by its
    input shape) and eagerly compiles its default program into the LRU
    cache.  ``submit_async`` enqueues single-image requests and returns
    futures; ``submit`` is the synchronous convenience (enqueue, drain,
    stack).  One worker thread forms batches (full / deadline / drain),
    dispatches them through the scheduler with JAX async dispatch, and
    keeps up to ``max_inflight`` device results unmaterialized while the
    next batch launches.

    Per-request latency (``request_latency_us`` → ``latency_
    percentiles()``) is **enqueue→result** — it includes queue wait,
    unlike the old submit-and-wait accounting, which survives as
    ``batch_device_us`` (dispatch→materialized batch wall).

    ``route=True`` + a per-model ``tune`` (NetworkTunePlan) routes each
    formed batch through the scheduler mode ``autotune.route_batch``
    predicts fastest for its size; the routed kout/spatial programs are
    distinct cache entries (they compile against sharded backends)."""

    def __init__(self, *, batch: int = 8, n_cores: int = 1,
                 backend: str = "pallas", deadline_ms: float = 5.0,
                 bulk_aging_ms: float = 50.0, cache_capacity: int = 4,
                 max_inflight: int = 2, calib=None, drift_band=None,
                 route: bool = False,
                 clock: Callable[[], int] = time.perf_counter_ns):
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        if max_inflight < 1:
            raise ValueError(
                f"max_inflight must be >= 1, got {max_inflight}")
        self.batch = batch
        self.n_cores = n_cores
        self.backend = backend
        self.calib = calib
        self.route = route
        self.clock = clock
        self.metrics = obs.MetricsRegistry()
        self.queue = RequestQueue(self.metrics, deadline_ms=deadline_ms,
                                  bulk_aging_ms=bulk_aging_ms, clock=clock)
        self.cache = ProgramCache(cache_capacity, self.metrics)
        self._requests = self.metrics.counter("requests")
        self._batches = self.metrics.counter("batches")
        self._padded = self.metrics.counter("padded")
        self._formed = {r: self.metrics.counter(f"batch_formed.{r}")
                        for r in FORMATION_REASONS}
        self._latency = self.metrics.histogram("request_latency_us")
        self._queue_wait = self.metrics.histogram("queue_wait_us")
        self._device = self.metrics.histogram("batch_device_us")
        self._fill = self.metrics.histogram(
            "batch_fill", bounds=[i / 16 for i in range(1, 17)])
        self._models: Dict[str, _Model] = {}
        self._inflight: deque = deque()
        self._uid_lock = threading.Lock()
        self._uid = 0
        self._drain_waiters = 0
        self._worker: Optional[threading.Thread] = None
        self._worker_lock = threading.Lock()
        self._stopping = False
        self.max_inflight = max_inflight
        self.layer_profile = None          # first obs'd batch, any model
        self.drift_events: tuple = ()
        self._drift_band = drift_band

    # -- model registry ------------------------------------------------------

    def add_model(self, qnet, *, name: Optional[str] = None,
                  tune=None) -> str:
        """Register a quantized network and eagerly compile its default
        program (an ``engine.compile`` span + a cache miss).  Returns
        the model name used for admission."""
        from repro.core.scheduler import MultiCoreScheduler, SchedulerConfig
        name = name or qnet.plan.name
        if name in self._models:
            raise ValueError(f"model {name!r} already registered")
        if tune is not None and tune.network != qnet.plan.name:
            raise ValueError(
                f"tune plan is for network {tune.network!r}, "
                f"engine serves {qnet.plan.name!r}")
        if tune is not None:
            sched = MultiCoreScheduler.from_tune(tune)
            backend_name = self._shard_backend_name(sched)
        else:
            sched = MultiCoreScheduler(
                SchedulerConfig(n_cores=self.n_cores))
            backend_name = self.backend
        entry = _Model(
            name=name, qnet=qnet,
            input_shape=tuple(qnet.plan.input_shape),
            classes=qnet.plan.activation_shapes()[-1][-1],
            tune=tune, backend_name=backend_name, sched=sched)
        self._models[name] = entry
        self._compiled(entry, backend_name)     # eager default program
        return name

    def _shard_backend_name(self, sched) -> str:
        """kout/spatial verdicts put the cores INSIDE the program as a
        sharded backend; batch verdicts shard around it."""
        from repro.core.convcore import register_backend
        if sched.config.mode in ("kout", "spatial"):
            sb = sched.shard_backend(self.backend)
            register_backend(sb)
            return sb.name
        return self.backend

    def models(self) -> List[str]:
        return sorted(self._models)

    def _resolve(self, model: Optional[str],
                 shape: Tuple[int, ...]) -> _Model:
        """Admission: by name (shape-checked) or, with ``model=None``,
        by unique input-shape match across the registered zoo."""
        if not self._models:
            raise ValueError("no models registered (add_model first)")
        if model is not None:
            entry = self._models.get(model)
            if entry is None:
                raise ValueError(f"unknown model {model!r}; "
                                 f"have {self.models()}")
            if tuple(shape) != entry.input_shape:
                raise ValueError(
                    f"model {model!r} wants input shape "
                    f"{entry.input_shape}, got {tuple(shape)}")
            return entry
        matches = [e for e in self._models.values()
                   if e.input_shape == tuple(shape)]
        if len(matches) != 1:
            raise ValueError(
                f"input shape {tuple(shape)} matches "
                f"{[e.name for e in matches] or 'no'} models — pass "
                f"model= (have {self.models()})")
        return matches[0]

    # -- compilation ---------------------------------------------------------

    def _compiled(self, entry: _Model, backend_name: str):
        """(program, tile_plans, core_config) for one (model, backend)
        point, through the LRU cache."""
        from repro.core.convcore import ConvCoreConfig
        from repro.core.network import make_int8_program, program_tile_plans

        def build():
            cfg = ConvCoreConfig(backend=backend_name, int8=True,
                                 calib=self.calib)
            with obs.span("engine.compile", network=entry.qnet.plan.name,
                          model=entry.name, backend=backend_name,
                          batch=self.batch):
                if entry.tune is not None:
                    tile_plans = entry.tune.tile_plans
                else:
                    tile_plans = program_tile_plans(entry.qnet.plan, cfg)
                program = make_int8_program(entry.qnet, cfg,
                                            tile_plans=tile_plans)
            return program, tile_plans, cfg

        return self.cache.get((entry.name, backend_name), build)

    # -- admission / submission ----------------------------------------------

    def _next_uids(self, n: int) -> range:
        with self._uid_lock:
            lo = self._uid
            self._uid += n
        return range(lo, lo + n)

    def submit_async(self, images, *, model: Optional[str] = None,
                     priority: str = "interactive"):
        """Enqueue requests; returns one Future per image (a bare Future
        for a single [H,W,C] image, a list for a [R,H,W,C] stack).  Each
        future resolves to that request's [classes] float32 logits."""
        if priority not in PRIORITIES:
            raise ValueError(f"unknown priority {priority!r}; "
                             f"have {PRIORITIES}")
        imgs = np.asarray(images, np.float32)
        single = imgs.ndim == 3
        if single:
            imgs = imgs[None]
        entry = self._resolve(model, imgs.shape[1:])
        now = self.clock()
        reqs = [ServeRequest(uid=u, model=entry.name, image=imgs[i],
                             priority=priority, enqueue_ns=now,
                             deadline_ns=now + self.queue.deadline_ns,
                             future=Future())
                for i, u in enumerate(self._next_uids(imgs.shape[0]))]
        self._requests.inc(len(reqs))
        self._ensure_worker()
        self.queue.push_many(reqs)
        futures = [r.future for r in reqs]
        return futures[0] if single else futures

    def submit(self, images, *, model: Optional[str] = None,
               priority: str = "interactive") -> np.ndarray:
        """Synchronous convenience: enqueue, drain, stack.  [R,H,W,C]
        (or one [H,W,C]) → [R, classes] logits in request order.  While
        a synchronous caller waits, the queue drains — partial batches
        launch immediately instead of idling until the deadline."""
        imgs = np.asarray(images, np.float32)
        if imgs.ndim == 3:
            imgs = imgs[None]
        if imgs.shape[0] == 0:
            entry = self._resolve(model, imgs.shape[1:]) \
                if model or self._models else None
            k = entry.classes if entry is not None else 0
            return np.zeros((0, k), np.float32)
        with self.queue.cond:
            self._drain_waiters += 1
        try:
            futures = self.submit_async(imgs, model=model,
                                        priority=priority)
            out = [f.result(timeout=SUBMIT_TIMEOUT_S) for f in futures]
        finally:
            with self.queue.cond:
                self._drain_waiters -= 1
        return np.stack(out)

    # -- the serving loop ----------------------------------------------------

    def _ensure_worker(self) -> None:
        with self._worker_lock:
            if self._stopping:
                raise RuntimeError("engine is closed")
            if self._worker is None or not self._worker.is_alive():
                self._worker = threading.Thread(
                    target=self._serve_loop, daemon=True,
                    name="conv-serve-worker")
                self._worker.start()

    def _serve_loop(self) -> None:
        while True:
            fb = None
            retire_idle = False
            with self.queue.cond:
                while not self._stopping:
                    fb = self.queue.form_locked(
                        self.batch, drain=self._drain_waiters > 0)
                    if fb is not None:
                        break
                    if self._inflight:
                        retire_idle = True    # use idle time to retire
                        break
                    nxt = self.queue.next_deadline_ns()
                    timeout = None if nxt is None else \
                        max((nxt - self.clock()) / 1e9, 0.0)
                    self.queue.cond.wait(timeout=timeout)
                if self._stopping and fb is None and not retire_idle:
                    break
            try:
                if fb is not None:
                    self._dispatch(fb)
                    while len(self._inflight) > self.max_inflight:
                        self._retire_one()
                elif self._inflight:
                    self._retire_one()
            except BaseException as e:        # never strand submitters
                if fb is not None:
                    for r in fb.requests:
                        if not r.future.done():
                            r.future.set_exception(e)
        # stop: drain whatever is queued, then materialize everything
        while True:
            fb = self.queue.form(self.batch, drain=True)
            if fb is None:
                break
            try:
                self._dispatch(fb)
            except BaseException as e:
                for r in fb.requests:
                    if not r.future.done():
                        r.future.set_exception(e)
        while self._inflight:
            self._retire_one()

    def _maybe_profile(self, entry: _Model, chunk: np.ndarray,
                       tile_plans, cfg) -> None:
        """One-off layer-at-a-time profile of the first observed batch
        (obs enabled only) — the per-layer breakdown + live drift check
        a running server can't get from offline benches."""
        import jax.numpy as jnp

        from repro.obs.profile import DriftDetector, profile_network
        drift = None
        if self.calib is not None:
            drift = DriftDetector(self._drift_band) if self._drift_band \
                else DriftDetector()
        self.layer_profile = profile_network(
            entry.qnet, jnp.asarray(chunk), core_config=cfg,
            tile_plans=tile_plans, calib=self.calib, drift=drift)
        self.drift_events = self.layer_profile.drift

    def _route_for(self, entry: _Model,
                   n_real: int) -> Tuple[str, Any, Optional[str]]:
        """(backend_name, scheduler, routed-mode) for one formed batch.
        Static verdict unless routing is on AND the model carries a
        tune plan (the route table needs its per-layer costs)."""
        if not self.route or entry.tune is None:
            return entry.backend_name, entry.sched, None
        cached = entry.routes.get(n_real)
        if cached is None:
            from repro.core.autotune import route_batch
            from repro.core.scheduler import (MultiCoreScheduler,
                                              SchedulerConfig)
            budget = self.n_cores if self.n_cores > 1 \
                else max(entry.tune.n_cores, 1)
            mode, cores, _ = route_batch(entry.tune.layers, n_real,
                                         budget, calib=self.calib)
            sched = MultiCoreScheduler(
                SchedulerConfig(n_cores=cores, mode=mode))
            bname = self._shard_backend_name(sched)
            cached = entry.routes[n_real] = (bname, sched, mode)
        self.metrics.counter(f"route.{cached[2]}").inc()
        return cached

    def _dispatch(self, fb: FormedBatch) -> None:
        import jax.numpy as jnp
        entry = self._models[fb.model]
        n_real = len(fb.requests)
        pad = self.batch - n_real
        now = self.clock()
        for r in fb.requests:
            self._queue_wait.observe((now - r.enqueue_ns) / 1e3)
        self._formed[fb.reason].inc()
        self._fill.observe(n_real / self.batch)
        if pad:
            self._padded.inc(pad)
        chunk = np.stack([r.image for r in fb.requests])
        if pad:
            chunk = np.concatenate(
                [chunk, np.zeros((pad, *entry.input_shape), np.float32)])
        backend_name, sched, routed = self._route_for(entry, n_real)
        program, tile_plans, cfg = self._compiled(entry, backend_name)
        if obs.enabled() and self.layer_profile is None:
            self._maybe_profile(entry, chunk, tile_plans, cfg)
        t0 = self.clock()
        with obs.span("engine.batch", network=entry.qnet.plan.name,
                      model=entry.name, fill=n_real / self.batch,
                      padded=pad, reason=fb.reason,
                      **({"routed": routed} if routed else {})):
            dev = sched.run(program, jnp.asarray(chunk))
        # async dispatch: the device result stays unmaterialized; the
        # next batch forms and launches while this one computes
        self._inflight.append((dev, fb, t0))

    def _retire_one(self) -> None:
        dev, fb, t0 = self._inflight.popleft()
        try:
            logits = np.asarray(dev)          # blocks on the device
        except BaseException as e:
            for r in fb.requests:
                if not r.future.done():
                    r.future.set_exception(e)
            return
        now = self.clock()
        # dispatch→materialized wall: equals device time when the queue
        # drains faster than the device, an upper bound when batches
        # stack up behind max_inflight
        self._device.observe((now - t0) / 1e3)
        self._batches.inc()
        for i, r in enumerate(fb.requests):
            self._latency.observe((now - r.enqueue_ns) / 1e3)
            r.future.set_result(logits[i])

    # -- stats / lifecycle ---------------------------------------------------

    @property
    def stats(self) -> Dict[str, int]:
        """The classic counter triple (requests / batches / padded)."""
        return {"requests": self._requests.value,
                "batches": self._batches.value,
                "padded": self._padded.value}

    def formation_counts(self) -> Dict[str, int]:
        return {r: c.value for r, c in self._formed.items()}

    def cache_stats(self) -> Dict[str, int]:
        return {"hits": self.metrics.counter("cache.hits").value,
                "misses": self.metrics.counter("cache.misses").value,
                "evictions":
                    self.metrics.counter("cache.evictions").value,
                "size": len(self.cache),
                "capacity": self.cache.capacity}

    def latency_percentiles(self) -> Dict[str, float]:
        """p50/p90/p99 (+count/mean) of honest enqueue→result latency in
        µs (queue wait INCLUDED — the old batch-wall-only number lives
        on as ``batch_device_us``)."""
        return self._latency.summary()

    def close(self, timeout: float = SUBMIT_TIMEOUT_S) -> None:
        """Stop the worker after draining queued work (idempotent)."""
        with self._worker_lock:
            worker = self._worker
            self._stopping = True
        with self.queue.cond:
            self.queue.cond.notify_all()
        if worker is not None and worker.is_alive():
            worker.join(timeout=timeout)

    def __enter__(self) -> "ContinuousBatchingEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
