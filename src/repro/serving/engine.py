"""Batched serving engines.

LM path (``ServingEngine``): continuous-batching-lite over fixed slots.
A fixed pool of B slots runs lockstep decode steps (one jit'd program, the
same one the decode dry-run cells lower).  Requests are admitted into free
slots between steps: a slot prefill writes its KV into the batch cache at
the slot index.  Finished slots (EOS or max_tokens) free immediately —
admission latency is one decode step, the practical property continuous
batching provides.

For simplicity the reference engine prefilires per-request with batch-1
programs and scatters into the pool cache; a production engine would batch
prefills — the scatter/cache layout already supports it.

Conv-net path (``ConvNetEngine``): the image-classification analogue over
the network executor (core/network.py).  Single-image requests are
microbatched into one fixed-shape jitted int8 NetworkPlan program (partial
batches zero-pad — one compiled program serves all), and the batch spreads
over replicated IP cores via core/scheduler.py, the paper's full-board
serving mode.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.layers.common import materialize, shape_structs
from repro.models import lm
from repro.serving.serve_step import greedy_sample

PyTree = Any


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray             # [S_prompt] int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params: PyTree, *, slots: int = 4,
                 max_seq: int = 256):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        cspecs = lm.cache_specs(cfg, slots, max_seq)
        self.cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)), cspecs,
            is_leaf=lambda x: hasattr(x, "axes"))
        self.pos = np.zeros((slots,), np.int32)
        self.active: List[Optional[Request]] = [None] * slots
        self.last_token = np.zeros((slots,), np.int32)

        self._decode = jax.jit(
            lambda p, c, t, po: lm.decode_step(p, cfg, token=t, pos=po,
                                               cache=c))
        self._prefill_one = jax.jit(
            lambda p, b: lm.prefill(p, b, cfg, cache_len=max_seq))

    # ------------------------------------------------------------------
    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.active) if r is None]

    def admit(self, req: Request) -> bool:
        free = self._free_slots()
        if not free:
            return False
        slot = free[0]
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]
        logits, cache1 = self._prefill_one(self.params, {"tokens": prompt})
        # scatter the request's prefill cache into the pool at `slot`
        self.cache = jax.tree.map(
            lambda pool, one: _scatter_slot(pool, one, slot),
            self.cache, cache1)
        tok = int(greedy_sample(logits)[0])
        req.output.append(tok)
        self.active[slot] = req
        self.pos[slot] = len(req.prompt)
        self.last_token[slot] = tok
        return True

    def step(self):
        """One lockstep decode step over the whole pool."""
        if all(r is None for r in self.active):
            return
        tokens = jnp.asarray(self.last_token, jnp.int32)
        pos = jnp.asarray(self.pos, jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache,
                                          tokens, pos)
        nxt = np.asarray(greedy_sample(logits))
        for i, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[i] += 1
            tok = int(nxt[i])
            req.output.append(tok)
            self.last_token[i] = tok
            hit_eos = req.eos_id is not None and tok == req.eos_id
            if hit_eos or len(req.output) >= req.max_new_tokens \
                    or self.pos[i] >= self.max_seq - 1:
                req.done = True
                self.active[i] = None

    def run(self, requests: List[Request]) -> List[Request]:
        pending = list(requests)
        done: List[Request] = []
        while pending or any(r is not None for r in self.active):
            while pending and self._free_slots():
                if not self.admit(pending[0]):
                    break
                pending.pop(0)
            self.step()
            done.extend(r for r in requests if r.done)
            requests = [r for r in requests if not r.done]
        return done


class ConvNetEngine:
    """Image serving over a compiled NetworkPlan int8 program.

    One fixed [batch, H, W, C] jitted program (zero-padded partial
    batches), optionally batch-sharded over ``n_cores`` replicated IP
    cores (core/scheduler.py — the scheduler pads ragged batches itself,
    so ``batch`` need not divide by the core count).  ``submit`` is
    synchronous microbatching — the conv analogue of the LM engine's
    lockstep step.

    ``tune`` (a core/autotune.NetworkTunePlan) deploys an autotuned
    recipe end-to-end: its per-layer ``tile_plans`` thread into the
    compiled program, and its winning (scheduler mode × core count)
    verdict replaces ``n_cores`` — kout/spatial verdicts compile the
    program against the matching sharded backend, batch verdicts shard
    ``submit``'s microbatches.  Without ``tune`` the engine runs the
    greedy plans on ``n_cores`` batch cores, exactly as before.

    Telemetry: the engine's counters (requests / batches / padded) live
    in a per-engine ``obs.metrics.MetricsRegistry`` (the
    backward-compatible ``.stats`` property reads them), and every
    ``submit`` observes per-request latency and batch fill ratio into
    histograms regardless of the obs flag (an observation is
    nanoseconds).  With obs ENABLED (``obs.enable()`` / ``REPRO_OBS=1``)
    each microbatch additionally gets an ``engine.batch`` trace span,
    and the first batch triggers a one-off layer-at-a-time profile
    (``obs.profile.profile_network`` — cached at ``.layer_profile``)
    whose layer set matches the plan topology; pass ``calib`` (a fitted
    CalibrationTable) to price the profile's predicted column on the
    measured model and run live drift detection against ``drift_band``
    (flagged layers land in ``.drift_events`` and in the trace)."""

    def __init__(self, qnet, *, batch: int = 8, n_cores: int = 1,
                 backend: str = "pallas", tune=None, calib=None,
                 drift_band=None):
        from repro import obs
        from repro.core.convcore import ConvCoreConfig, register_backend
        from repro.core.network import make_int8_program
        from repro.core.scheduler import MultiCoreScheduler, SchedulerConfig

        self.qnet = qnet
        self.batch = batch
        self.input_shape = qnet.plan.input_shape
        self.tune = tune
        self.calib = calib
        tile_plans = None
        if tune is not None:
            if tune.network != qnet.plan.name:
                raise ValueError(
                    f"tune plan is for network {tune.network!r}, "
                    f"engine serves {qnet.plan.name!r}")
            tile_plans = tune.tile_plans
            self._sched = MultiCoreScheduler.from_tune(tune)
            if self._sched.config.mode in ("kout", "spatial"):
                # single-image latency modes: the cores live INSIDE the
                # program as a sharded backend, not around the batch
                sb = self._sched.shard_backend(backend)
                register_backend(sb)
                backend = sb.name
        else:
            self._sched = MultiCoreScheduler(SchedulerConfig(n_cores=n_cores))
        self._core_config = ConvCoreConfig(backend=backend, int8=True,
                                           calib=calib)
        with obs.span("engine.compile", network=qnet.plan.name,
                      backend=backend, batch=batch):
            self._program = make_int8_program(qnet, self._core_config,
                                              tile_plans=tile_plans)
        self._tile_plans = tile_plans
        # per-engine registry: .stats must count THIS engine's traffic,
        # not the process's (tests construct several engines)
        self.metrics = obs.MetricsRegistry()
        self._requests = self.metrics.counter("requests")
        self._batches = self.metrics.counter("batches")
        self._padded = self.metrics.counter("padded")
        self._latency = self.metrics.histogram("request_latency_us")
        self._fill = self.metrics.histogram(
            "batch_fill", bounds=[i / 16 for i in range(1, 17)])
        self.layer_profile = None         # set by the first obs'd submit
        self.drift_events = ()
        self._drift_band = drift_band

    @property
    def stats(self) -> Dict[str, int]:
        """Backward-compatible counter view (the old ad-hoc dict)."""
        return {"requests": self._requests.value,
                "batches": self._batches.value,
                "padded": self._padded.value}

    def latency_percentiles(self) -> Dict[str, float]:
        """p50/p90/p99 (+count/mean) of per-request latency in µs."""
        return self._latency.summary()

    def _maybe_profile(self, chunk: np.ndarray):
        """One-off layer-at-a-time profile on the first observed batch
        (obs enabled only): the per-layer breakdown + live drift check
        the offline measured_vs_predicted section cannot give a running
        server."""
        from repro.obs.profile import DriftDetector, profile_network
        drift = None
        if self.calib is not None:
            drift = DriftDetector(self._drift_band) if self._drift_band \
                else DriftDetector()
        self.layer_profile = profile_network(
            self.qnet, jnp.asarray(chunk), core_config=self._core_config,
            tile_plans=self._tile_plans, calib=self.calib, drift=drift)
        self.drift_events = self.layer_profile.drift

    def submit(self, images) -> np.ndarray:
        """images: [R, H, W, C] array or list of [H,W,C] → logits [R, K]."""
        import time as _time

        from repro import obs
        imgs = np.asarray(images, np.float32)
        if imgs.ndim == 3:
            imgs = imgs[None]
        r = imgs.shape[0]
        assert imgs.shape[1:] == self.input_shape, (
            imgs.shape, self.input_shape)
        outs = []
        for lo in range(0, r, self.batch):
            chunk = imgs[lo:lo + self.batch]
            n_real = chunk.shape[0]
            pad = self.batch - n_real
            if pad:
                chunk = np.concatenate(
                    [chunk, np.zeros((pad, *self.input_shape), np.float32)])
                self._padded.inc(pad)
            if obs.enabled() and self.layer_profile is None:
                self._maybe_profile(chunk)
            with obs.span("engine.batch", network=self.qnet.plan.name,
                          fill=n_real / self.batch, padded=pad):
                t0 = _time.perf_counter_ns()
                logits = self._sched.run(self._program, jnp.asarray(chunk))
                logits = np.asarray(logits)       # blocks on the result
                batch_us = (_time.perf_counter_ns() - t0) / 1e3
            outs.append(logits[:self.batch - pad])
            self._batches.inc()
            self._fill.observe(n_real / self.batch)
            # synchronous microbatching: every request in the chunk
            # experienced the batch's wall time
            for _ in range(n_real):
                self._latency.observe(batch_us)
        self._requests.inc(r)
        if not outs:
            k = self.qnet.plan.activation_shapes()[-1][-1]
            return np.zeros((0, k), np.float32)
        return np.concatenate(outs)


def _scatter_slot(pool, one, slot: int):
    """Insert a batch-1 cache leaf into the pool cache at slot index.

    The batch axis is the first axis where the request leaf has size 1 and
    the pool leaf doesn't (cache leaves are [B,...] or stacked [G,B,...]).
    Sequence axes may be shorter on the request side (prompt < pool ring);
    fresh prompts align at offset 0 with the pool's ring indexing (engine
    admits prompts ≤ window for sliding-window models)."""
    batch_axis = None
    for i in range(pool.ndim):
        if one.shape[i] == 1 and pool.shape[i] != 1:
            batch_axis = i
            break
    if batch_axis is None:
        return pool                      # replicated / batch-free leaf
    dst = tuple(slice(slot, slot + 1) if ax == batch_axis
                else slice(0, min(pool.shape[ax], one.shape[ax]))
                for ax in range(pool.ndim))
    src = tuple(slice(0, 1) if ax == batch_axis
                else slice(0, min(pool.shape[ax], one.shape[ax]))
                for ax in range(pool.ndim))
    return pool.at[dst].set(one[src].astype(pool.dtype))
