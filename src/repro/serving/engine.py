"""Batched serving engines.

LM path (``ServingEngine``): continuous-batching-lite over fixed slots.
A fixed pool of B slots runs lockstep decode steps (one jit'd program, the
same one the decode dry-run cells lower).  Requests are admitted into free
slots between steps: a slot prefill writes its KV into the batch cache at
the slot index.  Finished slots (EOS or max_tokens) free immediately —
admission latency is one decode step, the practical property continuous
batching provides.

For simplicity the reference engine prefills per-request with batch-1
programs and scatters into the pool cache; a production engine would batch
prefills — the scatter/cache layout already supports it.

Conv-net path (``ConvNetEngine``): the image-classification analogue over
the network executor (core/network.py).  Since PR 10 it is a facade over
``serving/batching.py``'s :class:`ContinuousBatchingEngine`: requests are
admitted into an async priority queue, batches form dynamically (full /
deadline / drain), dispatch pipelines up to ``max_inflight`` batches via
JAX async dispatch, and the batch spreads over replicated IP cores via
core/scheduler.py, the paper's full-board serving mode.  ``submit`` keeps
the original synchronous contract; ``submit_async`` exposes the futures.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.layers.common import materialize, shape_structs
from repro.models import lm
from repro.serving.serve_step import greedy_sample

PyTree = Any


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray             # [S_prompt] int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params: PyTree, *, slots: int = 4,
                 max_seq: int = 256):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        cspecs = lm.cache_specs(cfg, slots, max_seq)
        self.cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)), cspecs,
            is_leaf=lambda x: hasattr(x, "axes"))
        self.pos = np.zeros((slots,), np.int32)
        self.active: List[Optional[Request]] = [None] * slots
        self.last_token = np.zeros((slots,), np.int32)

        self._decode = jax.jit(
            lambda p, c, t, po: lm.decode_step(p, cfg, token=t, pos=po,
                                               cache=c))
        self._prefill_one = jax.jit(
            lambda p, b: lm.prefill(p, b, cfg, cache_len=max_seq))

    # ------------------------------------------------------------------
    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.active) if r is None]

    def admit(self, req: Request) -> bool:
        free = self._free_slots()
        if not free:
            return False
        slot = free[0]
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]
        logits, cache1 = self._prefill_one(self.params, {"tokens": prompt})
        # scatter the request's prefill cache into the pool at `slot`
        self.cache = jax.tree.map(
            lambda pool, one: _scatter_slot(pool, one, slot),
            self.cache, cache1)
        tok = int(greedy_sample(logits)[0])
        req.output.append(tok)
        self.active[slot] = req
        self.pos[slot] = len(req.prompt)
        self.last_token[slot] = tok
        return True

    def step(self) -> List[Request]:
        """One lockstep decode step over the whole pool.  Returns the
        requests that finished on this step (their slots are freed)."""
        finished: List[Request] = []
        if all(r is None for r in self.active):
            return finished
        tokens = jnp.asarray(self.last_token, jnp.int32)
        pos = jnp.asarray(self.pos, jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache,
                                          tokens, pos)
        nxt = np.asarray(greedy_sample(logits))
        for i, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[i] += 1
            tok = int(nxt[i])
            req.output.append(tok)
            self.last_token[i] = tok
            hit_eos = req.eos_id is not None and tok == req.eos_id
            if hit_eos or len(req.output) >= req.max_new_tokens \
                    or self.pos[i] >= self.max_seq - 1:
                req.done = True
                self.active[i] = None
                finished.append(req)
        return finished

    def run(self, requests: List[Request]) -> List[Request]:
        # O(1) bookkeeping per step: popleft admission and finished
        # requests moved out by step() exactly once — no per-step rescan
        # of the full request list
        pending = deque(requests)
        done: List[Request] = []
        while pending or any(r is not None for r in self.active):
            while pending and self._free_slots():
                if not self.admit(pending[0]):
                    break
                pending.popleft()
            done.extend(self.step())
        return done


class ConvNetEngine:
    """Image serving over compiled NetworkPlan int8 programs.

    A single-model facade over ``serving/batching.py``'s
    :class:`ContinuousBatchingEngine` (which also serves multi-model —
    use it directly for that).  Requests land in an async priority
    queue; batches form when full, when the oldest request hits
    ``deadline_ms``, or when a synchronous caller drains; dispatch keeps
    up to ``max_inflight`` batches in flight on the device via JAX async
    dispatch; partial batches zero-pad onto the one fixed
    [batch, H, W, C] jitted program, batch-sharded over ``n_cores``
    replicated IP cores (core/scheduler.py), the paper's full-board
    serving mode.

    ``tune`` (a core/autotune.NetworkTunePlan) deploys an autotuned
    recipe end-to-end: its per-layer ``tile_plans`` thread into the
    compiled program, and its winning (scheduler mode × core count)
    verdict replaces ``n_cores`` — kout/spatial verdicts compile the
    program against the matching sharded backend, batch verdicts shard
    the formed batches.  ``route=True`` additionally re-routes each
    *formed* batch through the scheduler mode the calibrated perf model
    predicts fastest for its actual size (``autotune.route_batch``).

    Telemetry: counters (requests / batches / padded), the honest
    enqueue→result ``request_latency_us`` histogram (queue wait
    INCLUDED — the pre-queue batch-wall-only number lives on as
    ``batch_device_us``), ``queue_wait_us``, ``batch_fill``, queue-depth
    gauges, formation-reason and program-cache counters — all in the
    per-engine ``.metrics`` registry.  With obs ENABLED
    (``obs.enable()`` / ``REPRO_OBS=1``) compiles and batches get trace
    spans and the first batch triggers a one-off layer-at-a-time profile
    (``.layer_profile``; ``calib`` + ``drift_band`` arm the live drift
    check whose hits land in ``.drift_events``)."""

    def __init__(self, qnet, *, batch: int = 8, n_cores: int = 1,
                 backend: str = "pallas", tune=None, calib=None,
                 drift_band=None, deadline_ms: float = 5.0,
                 bulk_aging_ms: float = 50.0, max_inflight: int = 2,
                 route: bool = False):
        from repro.serving.batching import ContinuousBatchingEngine
        self.qnet = qnet
        self.batch = batch
        self.input_shape = qnet.plan.input_shape
        self.tune = tune
        self.calib = calib
        self.engine = ContinuousBatchingEngine(
            batch=batch, n_cores=n_cores, backend=backend,
            deadline_ms=deadline_ms, bulk_aging_ms=bulk_aging_ms,
            cache_capacity=4, max_inflight=max_inflight, calib=calib,
            drift_band=drift_band, route=route)
        self.model = self.engine.add_model(qnet, tune=tune)

    @property
    def metrics(self):
        return self.engine.metrics

    @property
    def stats(self) -> Dict[str, int]:
        """Backward-compatible counter view (the old ad-hoc dict)."""
        return self.engine.stats

    @property
    def layer_profile(self):
        return self.engine.layer_profile

    @property
    def drift_events(self):
        return self.engine.drift_events

    def latency_percentiles(self) -> Dict[str, float]:
        """p50/p90/p99 (+count/mean) of per-request enqueue→result
        latency in µs (queue wait included)."""
        return self.engine.latency_percentiles()

    def submit(self, images, *, priority: str = "interactive") -> np.ndarray:
        """images: [R, H, W, C] array or list of [H,W,C] → logits [R, K].

        Synchronous: enqueues all R requests atomically, drains the
        queue, and returns logits in request order."""
        return self.engine.submit(images, model=self.model,
                                  priority=priority)

    def submit_async(self, images, *, priority: str = "interactive"):
        """Async admission — returns a Future per image (see
        ``ContinuousBatchingEngine.submit_async``)."""
        return self.engine.submit_async(images, model=self.model,
                                        priority=priority)

    def close(self) -> None:
        self.engine.close()


def _scatter_slot(pool, one, slot: int):
    """Insert a batch-1 cache leaf into the pool cache at slot index.

    The batch axis is the first axis where the request leaf has size 1 and
    the pool leaf doesn't (cache leaves are [B,...] or stacked [G,B,...]).
    Sequence axes may be shorter on the request side (prompt < pool ring);
    fresh prompts align at offset 0 with the pool's ring indexing (engine
    admits prompts ≤ window for sliding-window models)."""
    batch_axis = None
    for i in range(pool.ndim):
        if one.shape[i] == 1 and pool.shape[i] != 1:
            batch_axis = i
            break
    if batch_axis is None:
        return pool                      # replicated / batch-free leaf
    dst = tuple(slice(slot, slot + 1) if ax == batch_axis
                else slice(0, min(pool.shape[ax], one.shape[ax]))
                for ax in range(pool.ndim))
    src = tuple(slice(0, 1) if ax == batch_axis
                else slice(0, min(pool.shape[ax], one.shape[ax]))
                for ax in range(pool.ndim))
    return pool.at[dst].set(one[src].astype(pool.dtype))
