"""RecurrentGemma 9B (Griffin) — RG-LRU recurrent blocks + local attention,
pattern (recurrent, recurrent, attention), MQA, window 2048.
[arXiv:2402.19427]

This is the one assigned architecture with a *real in-model convolution*: the
temporal conv1d (width 4) inside every recurrent block — implemented with the
paper's ConvCore dataflow (see DESIGN.md §6).
"""
from repro.configs.base import ArchConfig, BLOCK_RGLRU, BLOCK_LOCAL

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    kind="decoder",
    num_layers=38,                       # 12 × (R,R,A) + (R,R) tail
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,                      # MQA
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    layer_pattern=(BLOCK_RGLRU, BLOCK_RGLRU, BLOCK_LOCAL),
    attention_window=2048,
    rope_theta=10_000.0,
    mlp_act="gelu",                      # GeGLU
    norm="rmsnorm",
    rmsnorm_unit_offset=True,
    embed_scale=True,
    tie_embeddings=True,
    rnn_width=4096,
    conv1d_width=4,
)
