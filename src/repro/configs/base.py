"""Architecture / shape configuration system.

Every assigned architecture is expressed as an :class:`ArchConfig`; the four
assigned input shapes are :class:`ShapeConfig` instances.  Configs are frozen
dataclasses so they can be hashed into jit caches and serialized into
checkpoint manifests.

The reduced (smoke-test) variant of every architecture is derived
programmatically by :func:`reduce_config` so smoke tests always exercise the
same code path / layer pattern as the full model.
"""

from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts feed-forward configuration (GShard-style capacity)."""

    num_experts: int
    top_k: int
    expert_ff: int
    num_shared: int = 0          # shared (always-on) experts, DeepSeekMoE style
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # number of dispatch groups; capacity is enforced per group.  0 means
    # "use the batch dimension" which keeps the dispatch cumsum local to a
    # data shard (no cross-device cumsum).
    num_groups: int = 0
    aux_loss_weight: float = 0.01


# ---------------------------------------------------------------------------
# Architecture
# ---------------------------------------------------------------------------

# Block kinds a decoder stack can be built from.
BLOCK_ATTN = "attn"          # global self attention
BLOCK_LOCAL = "local_attn"   # sliding-window self attention
BLOCK_RGLRU = "rglru"        # RecurrentGemma recurrent block (conv1d + RG-LRU)
BLOCK_RWKV6 = "rwkv6"        # RWKV-v6 time-mix block (attention free)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | vlm | audio
    kind: str                    # decoder | encdec | vlm

    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention / mixer details -------------------------------------
    # repeating unit of block kinds; tiles over num_layers, remainder layers
    # take the pattern prefix (e.g. 38 layers of (R,R,A) = 12 groups + R,R).
    layer_pattern: Tuple[str, ...] = (BLOCK_ATTN,)
    attention_window: int = 0            # for local_attn blocks
    rope_theta: float = 500_000.0
    use_rope: bool = True
    qk_norm: bool = False                # qwen3 style
    logit_softcap: float = 0.0           # gemma style final-logit softcap

    # --- ffn ------------------------------------------------------------
    mlp_act: str = "silu"                # silu (SwiGLU) | gelu (GeGLU)
    moe: Optional[MoEConfig] = None

    # --- norms / embeddings ----------------------------------------------
    norm: str = "rmsnorm"                # rmsnorm | layernorm
    rmsnorm_unit_offset: bool = False    # gemma: weight = 1 + w
    embed_scale: bool = False            # gemma: x *= sqrt(d_model)
    tie_embeddings: bool = False

    # --- rglru (hybrid) ---------------------------------------------------
    rnn_width: int = 0
    conv1d_width: int = 4

    # --- rwkv -------------------------------------------------------------
    rwkv_head_size: int = 64
    rwkv_lora_rank: int = 64             # data-dependent decay LoRA rank

    # --- enc-dec / multimodal frontends ------------------------------------
    encoder_layers: int = 0
    frontend: Optional[str] = None       # None | "audio" | "vision" (STUB)
    frontend_tokens: int = 0             # patches / frames occupying the prefix
    frontend_dim: int = 0                # raw embedding dim provided by stub

    # --- numerics / backend -----------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # int8 KV cache (paper 8-bit datapath applied to serving state; decode
    # reads the cache through true s8 dots — §Perf iteration C2).  Scale is
    # a fixed calibration constant (symmetric per-tensor).  "auto" follows
    # compute_dtype.
    kv_cache_dtype: str = "auto"
    kv_cache_scale: float = 0.05

    @property
    def resolved_kv_dtype(self) -> str:
        return (self.compute_dtype if self.kv_cache_dtype == "auto"
                else self.kv_cache_dtype)
    remat_policy: str = "minimal"        # none | minimal | full
    # which GEMM implementation linear layers use:
    #   "xla"       — jnp.einsum (used for the 512-device dry run: the CPU
    #                 host platform cannot lower Mosaic kernels)
    #   "pallas_ws" — the paper-dataflow weight-stationary Pallas kernel
    gemm_backend: str = "xla"
    # attention implementation: "chunked" (flash-style lax.scan, O(S*blk)
    # memory), "flash" (the Pallas kernel — TPU target; falls back to
    # chunked for windowed/cross attention), or "dense" (materialized
    # scores; small models / tests only).
    attn_impl: str = "chunked"
    attn_chunk: int = 512

    # ----------------------------------------------------------------- utils
    @property
    def sub_quadratic(self) -> bool:
        """True if no block attends to unbounded history (long_500k eligible)."""
        return all(b in (BLOCK_RGLRU, BLOCK_RWKV6, BLOCK_LOCAL)
                   for b in self.layer_pattern)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def num_groups_scan(self) -> int:
        return self.num_layers // len(self.layer_pattern)

    @property
    def tail_blocks(self) -> Tuple[str, ...]:
        """Remainder layers that do not fill a whole pattern group."""
        rem = self.num_layers % len(self.layer_pattern)
        return self.layer_pattern[:rem]

    def block_kinds(self) -> Tuple[str, ...]:
        """The full, ordered list of block kinds (length == num_layers)."""
        reps = self.num_layers // len(self.layer_pattern)
        return self.layer_pattern * reps + self.tail_blocks

    def validate(self) -> None:
        assert self.num_heads % self.num_kv_heads == 0, self.name
        if self.moe is not None:
            assert self.moe.num_experts % 4 == 0, "paper banking divisibility"
        if BLOCK_RGLRU in self.layer_pattern:
            assert self.rnn_width > 0
        if self.kind == "encdec":
            assert self.encoder_layers > 0


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell is runnable; returns (ok, reason)."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, ("pure full-attention architecture: 500k-token decode is "
                       "architecturally quadratic-history; skipped per DESIGN.md")
    return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_NAMES = (
    "llama3_8b",
    "llama3p2_3b",
    "yi_34b",
    "gemma_7b",
    "internvl2_26b",
    "recurrentgemma_9b",
    "deepseek_moe_16b",
    "qwen3_moe_30b_a3b",
    "seamless_m4t_medium",
    "rwkv6_1p6b",
)

# CLI aliases (assignment ids → module names)
ALIASES = {
    "llama3-8b": "llama3_8b",
    "llama3.2-3b": "llama3p2_3b",
    "yi-34b": "yi_34b",
    "gemma-7b": "gemma_7b",
    "internvl2-26b": "internvl2_26b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "rwkv6-1.6b": "rwkv6_1p6b",
}


def get_config(name: str) -> ArchConfig:
    mod_name = ALIASES.get(name, name)
    if mod_name not in ARCH_NAMES:
        raise KeyError(f"unknown architecture {name!r}; have {sorted(ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg: ArchConfig = mod.CONFIG
    cfg.validate()
    return cfg


def all_configs():
    return {n: get_config(n) for n in ARCH_NAMES}


# ---------------------------------------------------------------------------
# Reduced (smoke) configs
# ---------------------------------------------------------------------------


def reduce_config(cfg: ArchConfig) -> ArchConfig:
    """Shrink a full architecture to a CPU-smoke size, preserving the family
    structure (layer pattern, GQA ratio, MoE routing, frontends)."""
    group = len(cfg.layer_pattern)
    # keep one full pattern group plus the tail structure if there is one
    layers = group + (1 if cfg.tail_blocks else 0) * len(cfg.tail_blocks)
    kv = max(1, min(cfg.num_kv_heads, 2))
    ratio = cfg.num_heads // cfg.num_kv_heads
    heads = kv * ratio
    moe = None
    if cfg.moe is not None:
        moe = replace(cfg.moe, num_experts=8,
                      top_k=min(cfg.moe.top_k, 2),
                      num_shared=min(cfg.moe.num_shared, 1),
                      expert_ff=64)
    return replace(
        cfg,
        num_layers=layers,
        d_model=64,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        moe=moe,
        rnn_width=64 if cfg.rnn_width else 0,
        rwkv_lora_rank=8,
        attention_window=min(cfg.attention_window, 64) if cfg.attention_window else 0,
        encoder_layers=min(cfg.encoder_layers, 2),
        frontend_tokens=min(cfg.frontend_tokens, 8),
        frontend_dim=min(cfg.frontend_dim, 32) if cfg.frontend_dim else 0,
        attn_chunk=32,
        remat_policy="none",
        param_dtype="float32",
        compute_dtype="float32",
    )


def config_summary(cfg: ArchConfig) -> str:
    n = param_count(cfg)
    return (f"{cfg.name}: {cfg.num_layers}L d={cfg.d_model} H={cfg.num_heads} "
            f"kv={cfg.num_kv_heads} dh={cfg.head_dim} ff={cfg.d_ff} "
            f"V={cfg.vocab_size} params={n/1e9:.2f}B")


# ---------------------------------------------------------------------------
# Analytic parameter / FLOP accounting (used by roofline cross-checks)
# ---------------------------------------------------------------------------


def _per_block_params(cfg: ArchConfig, kind: str) -> int:
    d = cfg.d_model
    attn = (d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d)
    if kind in (BLOCK_ATTN, BLOCK_LOCAL):
        mix = attn
    elif kind == BLOCK_RGLRU:
        w = cfg.rnn_width
        # in/gate linear, out linear, conv1d, RG-LRU gates
        mix = d * w * 2 + w * d + cfg.conv1d_width * w + 2 * w * w // 1 + w
    elif kind == BLOCK_RWKV6:
        # r,k,v,w,g projections + output + ddlerp loras
        mix = 5 * d * d + d * d + 5 * cfg.rwkv_lora_rank * 2 * d
    else:
        raise ValueError(kind)
    if cfg.moe is not None and kind != BLOCK_RWKV6:
        m = cfg.moe
        ffn = (m.num_experts + m.num_shared) * 3 * d * m.expert_ff + d * m.num_experts
    elif kind == BLOCK_RWKV6:
        ffn = 2 * d * cfg.d_ff  # rwkv channel mix: two mats
    else:
        mult = 3  # gated mlps: up, gate, down
        ffn = mult * d * cfg.d_ff
    return mix + ffn + 2 * d  # two norms


def param_count(cfg: ArchConfig) -> int:
    total = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    for kind in cfg.block_kinds():
        total += _per_block_params(cfg, kind)
    if cfg.kind == "encdec":
        # encoder self-attn blocks + decoder cross-attn additions
        d = cfg.d_model
        enc = cfg.encoder_layers * _per_block_params(cfg, BLOCK_ATTN)
        cross = cfg.num_layers * (d * cfg.q_dim + 2 * d * cfg.kv_dim
                                  + cfg.q_dim * d + d)
        total += enc + cross
    if cfg.frontend is not None and cfg.frontend_dim:
        total += cfg.frontend_dim * cfg.d_model
    total += cfg.d_model  # final norm
    return total


def active_param_count(cfg: ArchConfig) -> int:
    """Parameters touched per token (MoE: only routed top-k + shared)."""
    if cfg.moe is None:
        return param_count(cfg)
    m = cfg.moe
    dense_like = param_count(cfg)
    per_layer_all = (m.num_experts + m.num_shared) * 3 * cfg.d_model * m.expert_ff
    per_layer_act = (m.top_k + m.num_shared) * 3 * cfg.d_model * m.expert_ff
    n_moe_layers = sum(1 for k in cfg.block_kinds() if k != BLOCK_RWKV6)
    return dense_like - n_moe_layers * (per_layer_all - per_layer_act)
