"""Yi-34B — llama-architecture GQA, 64k vocab. [arXiv:2403.04652]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="yi-34b",
    family="dense",
    kind="decoder",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    rope_theta=5_000_000.0,
    mlp_act="silu",
    norm="rmsnorm",
    tie_embeddings=False,
)
