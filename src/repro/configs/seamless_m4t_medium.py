"""SeamlessM4T-medium — encoder-decoder, multimodal (speech/text); the audio
conformer frontend is a STUB per assignment (``input_specs()`` provides
precomputed frame embeddings). [arXiv:2308.11596]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    kind="encdec",
    num_layers=12,            # decoder layers
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    use_rope=False,           # learned/sinusoidal positions in m4t; we use sinusoidal
    mlp_act="gelu",
    norm="layernorm",
    tie_embeddings=True,
    frontend="audio",
    frontend_dim=1024,        # post-subsampler frame embedding width
)
