"""Llama-3.2 3B — small llama3, tied embeddings. [hf:meta-llama/Llama-3.2-3B]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3.2-3b",
    family="dense",
    kind="decoder",
    num_layers=28,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=500_000.0,
    mlp_act="silu",
    norm="rmsnorm",
    tie_embeddings=True,
)
