"""Gemma 7B — GeGLU MLP, head_dim 256, scaled embeddings, 256k vocab.
[arXiv:2403.08295]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b",
    family="dense",
    kind="decoder",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,   # 7b is MHA (the 2b variant is MQA)
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    rope_theta=10_000.0,
    mlp_act="gelu",    # GeGLU
    norm="rmsnorm",
    rmsnorm_unit_offset=True,
    embed_scale=True,
    tie_embeddings=True,
)
