"""Qwen3-30B-A3B — 128 experts top-8, QK-norm, GQA kv=4.
[hf:Qwen/Qwen3-30B-A3B]"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    kind="decoder",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,            # per-expert ff (assignment)
    vocab_size=151936,
    rope_theta=1_000_000.0,
    qk_norm=True,
    mlp_act="silu",
    norm="rmsnorm",
    tie_embeddings=False,
    moe=MoEConfig(
        num_experts=128,
        top_k=8,
        num_shared=0,
        expert_ff=768,
        capacity_factor=1.25,
    ),
)
