"""DeepSeekMoE 16B — fine-grained experts: 64 routed top-6 + 2 shared,
expert_ff 1408. [arXiv:2401.06066]"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    kind="decoder",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,     # MHA
    head_dim=128,
    d_ff=1408,           # per-expert ff (assignment)
    vocab_size=102400,
    rope_theta=10_000.0,
    mlp_act="silu",
    norm="rmsnorm",
    tie_embeddings=False,
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        num_shared=2,
        expert_ff=1408,
        capacity_factor=1.25,
    ),
)
