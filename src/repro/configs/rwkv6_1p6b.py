"""RWKV-6 (Finch) 1.6B — attention-free, data-dependent decay linear
recurrence; head size 64. [arXiv:2404.05892]"""
from repro.configs.base import ArchConfig, BLOCK_RWKV6

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    kind="decoder",
    num_layers=24,
    d_model=2048,
    num_heads=32,             # d_model / rwkv_head_size
    num_kv_heads=32,
    head_dim=64,
    d_ff=7168,                # channel-mix width (3.5x)
    vocab_size=65536,
    layer_pattern=(BLOCK_RWKV6,),
    use_rope=False,
    norm="layernorm",
    tie_embeddings=False,
    rwkv_head_size=64,
    rwkv_lora_rank=64,
)
