"""InternVL2-26B — InternViT-6B vision frontend (STUB per assignment) +
InternLM2-20B language backbone. [arXiv:2404.16821]

The assignment specifies the transformer BACKBONE only; ``input_specs()``
provides precomputed patch embeddings (the InternViT + MLP projector output)
as a ``frontend_tokens``-long prefix in ``frontend_dim`` = ViT output width.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    kind="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    rope_theta=1_000_000.0,
    mlp_act="silu",
    norm="rmsnorm",
    tie_embeddings=False,
    frontend="vision",
    frontend_tokens=1024,   # (448/14)^2 patches with pixel-unshuffle x4 = 256/img, 4 tiles
    frontend_dim=3200,      # InternViT-6B width (projector input)
)
