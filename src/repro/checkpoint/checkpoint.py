"""Sharded, resumable, elastic checkpoints (no external deps).

Layout:  <dir>/step_<N>/
           manifest.json            — step, pytree structure, shapes, dtypes,
                                      data cursor, mesh shape (provenance)
           shard_<p>.npz            — this process's arrays (host-local data)

Properties:
* **Elastic restore** — arrays are saved as full (global) host arrays and
  restored onto *any* mesh/sharding: restart with a different device count
  or sharding plan re-shards transparently (tested).
* **Async save** — a background thread serializes while training continues;
  ``wait()`` joins before the next save (double-buffered host copy).
* **Atomic** — writes go to a tmp dir renamed into place, so a crash during
  save never corrupts the latest checkpoint.
* **Resume equality** — together with the seekable data pipeline, restoring
  step N reproduces the uninterrupted run bit-for-bit (tested).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

PyTree = Any
_SEP = "/"


def _flatten_with_paths(tree: PyTree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        out[key] = leaf
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save
    def save(self, step: int, state: PyTree, extra: Optional[Dict] = None,
             blocking: bool = True):
        """Snapshot to host memory synchronously; write to disk (optionally)
        in the background."""
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        if blocking:
            self._write(step, host, extra or {})
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, extra or {}),
                daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: PyTree, extra: Dict):
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten_with_paths(host)
        manifest = {
            "step": step,
            "keys": sorted(flat),
            "shapes": {k: list(np.shape(v)) for k, v in flat.items()},
            "dtypes": {k: str(np.asarray(v).dtype) for k, v in flat.items()},
            "extra": extra,
            "process_count": jax.process_count(),
        }
        np.savez(os.path.join(tmp, f"shard_{jax.process_index()}.npz"),
                 **{k: np.asarray(v) for k, v in flat.items()})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target: PyTree, step: Optional[int] = None,
                shardings: Optional[PyTree] = None):
        """Restore into the structure of ``target`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: optional NamedSharding tree —
        this is the elastic path (any mesh, any plan).
        Returns (state, extra)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.directory}")
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, f"shard_{jax.process_index()}.npz"))

        flat_target = _flatten_with_paths(target)
        missing = [k for k in flat_target if k not in data.files]
        if missing:
            raise KeyError(f"checkpoint {d} missing keys {missing[:5]}...")
        flat_shard = _flatten_with_paths(shardings) if shardings else {}

        def build(key, like):
            arr = data[key]
            want_shape = tuple(np.shape(like))
            if tuple(arr.shape) != want_shape:
                raise ValueError(
                    f"{key}: checkpoint shape {arr.shape} != {want_shape}")
            sh = flat_shard.get(key)
            if sh is not None:
                return jax.device_put(arr, sh)
            dtype = like.dtype if hasattr(like, "dtype") else arr.dtype
            return jax.device_put(arr.astype(dtype))

        restored_flat = {k: build(k, v) for k, v in flat_target.items()}
        leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(target)
        ordered = [restored_flat[_SEP.join(_path_str(p) for p in path)]
                   for path, _ in leaves_paths]
        state = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(target), ordered)
        return state, manifest.get("extra", {})
