"""Multi-core network scheduler — the paper's replicated-IP-core mode.

§5.2: one IP core reaches 0.224 GOPS; "when the board is fully utilized"
~20 replicated cores reach 4.48 GOPS.  Replication on the FPGA takes two
forms, and both have exact TPU analogues:

* **batch sharding** ("each IP core processes its own image"): the input
  batch is split across cores.  On a multi-device TPU slice this is data
  parallelism — one device per IP core via a NamedSharding over the batch
  axis, GSPMD partitions the jitted program.  On one device the cores are
  *virtual*: a vmap over batch shards (the compiler interleaves them the
  way the fabric interleaves replicated cores).

* **kout sharding** ("the kernel sets are divided among the cores", the
  single-image latency mode): every layer's K output channels are split
  across cores, each core convolves the SAME feature map with its kernel
  slice, and the slices concatenate into the next layer's input — the
  inter-layer concat is the fabric's output-BRAM crossbar (on a real mesh,
  an all-gather).  Implemented as a ``Backend`` decorator so any network
  program compiles against it unchanged.

``perfmodel.network_report`` prices both: cycles scale ~1/n_cores until a
layer's psum count no longer fills all cores.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp

from repro.core.banking import divisor_banks
from repro.core.convcore import Backend, get_backend


@dataclass(frozen=True)
class SchedulerConfig:
    n_cores: int = 1
    mode: str = "batch"                 # "batch" | "kout"


class KoutShardedBackend:
    """Backend decorator: split every conv/matmul's output channels across
    ``n_cores`` virtual IP cores and concatenate (paper kernel-set
    division).  Each shard sees the full input map — weight-stationary per
    core, exactly the replicated-core dataflow."""

    def __init__(self, inner: Backend, n_cores: int):
        self.inner = inner
        self.n_cores = n_cores
        self.name = f"{inner.name}@kout{n_cores}"

    def _shards(self, k: int) -> int:
        n = min(self.n_cores, k)
        while k % n:
            n -= 1
        return n

    def conv(self, x, w, bias=None, *, out_scale=None, plan=None, **kw):
        k = w.shape[-1]
        n = self._shards(k)
        if n == 1:
            return self.inner.conv(x, w, bias, out_scale=out_scale,
                                   plan=plan, **kw)
        if plan is not None:
            # re-bank for the per-core kernel slice (K/n output channels)
            plan = replace(plan, kout_banks=divisor_banks(
                k // n, plan.kout_banks))
        outs = []
        for i in range(n):                 # one iteration per fabric core
            sl = slice(i * (k // n), (i + 1) * (k // n))
            outs.append(self.inner.conv(
                x, w[..., sl], None if bias is None else bias[sl],
                out_scale=(out_scale if out_scale is None
                           or jnp.ndim(out_scale) == 0 else out_scale[sl]),
                plan=plan, **kw))
        return jnp.concatenate(outs, axis=-1)

    def matmul(self, x, w, bias=None):
        k = w.shape[-1]
        n = self._shards(k)
        if n == 1:
            return self.inner.matmul(x, w, bias)
        outs = [self.inner.matmul(
            x, w[:, i * (k // n):(i + 1) * (k // n)],
            None if bias is None else bias[i * (k // n):(i + 1) * (k // n)])
            for i in range(n)]
        return jnp.concatenate(outs, axis=-1)


class MultiCoreScheduler:
    """Run a compiled network program as if on ``n_cores`` replicated IP
    cores."""

    def __init__(self, config: SchedulerConfig = SchedulerConfig()):
        assert config.mode in ("batch", "kout"), config.mode
        self.config = config

    def shard_backend(self, backend_name: str) -> Backend:
        """kout mode: a Backend whose every layer is kernel-set-sharded."""
        return KoutShardedBackend(get_backend(backend_name),
                                  self.config.n_cores)

    def run(self, program, x: jax.Array) -> jax.Array:
        """batch mode: split the batch over cores.  kout mode: pass
        through — the cores divide kernels inside the program (compile it
        against ``shard_backend``), not the batch.

        With enough local devices, one device per IP core (NamedSharding +
        GSPMD); otherwise vmapped virtual cores on one device."""
        cores = self.config.n_cores
        n = x.shape[0]
        if cores == 1 or self.config.mode == "kout":
            return program(x)
        assert n % cores == 0, (n, cores)
        if jax.device_count() >= cores:
            from jax.sharding import NamedSharding, PartitionSpec as P
            mesh = jax.make_mesh((cores,), ("cores",),
                                 devices=jax.devices()[:cores])
            x = jax.device_put(x, NamedSharding(mesh, P("cores")))
            return program(x)
        xs = x.reshape(cores, n // cores, *x.shape[1:])
        ys = jax.vmap(program)(xs)
        return ys.reshape(n, *ys.shape[2:])
