"""Multi-core network scheduler — the paper's replicated-IP-core mode.

§5.2: one IP core reaches 0.224 GOPS; "when the board is fully utilized"
~20 replicated cores reach 4.48 GOPS.  Replication on the FPGA takes two
forms, and both have exact TPU analogues:

* **batch sharding** ("each IP core processes its own image"): the input
  batch is split across cores.  On a multi-device TPU slice this is data
  parallelism — one device per IP core via a NamedSharding over the batch
  axis, GSPMD partitions the jitted program.  On one device the cores are
  *virtual*: a vmap over batch shards (the compiler interleaves them the
  way the fabric interleaves replicated cores).

* **kout sharding** ("the kernel sets are divided among the cores", the
  single-image latency mode): every layer's K output channels are split
  across cores, each core convolves the SAME feature map with its kernel
  slice, and the slices concatenate into the next layer's input — the
  inter-layer concat is the fabric's output-BRAM crossbar (on a real mesh,
  an all-gather).  Implemented as a ``Backend`` decorator so any network
  program compiles against it unchanged.

* **spatial sharding** (this PR's third axis): every conv layer's output
  ROWS are split across cores; each core receives a halo'd horizontal
  band of the input map (halo = kernel extent − stride, the same overlap
  math as the tiled kernel's BlockSpecs) and convolves it with the FULL
  kernel set — the paper's fixed-size image BRAMs replicated across the
  fabric, each holding one band of a map too large for any single core.
  Bands are pool-aligned so the fused 2×2 epilogue never straddles a
  band edge; single-image latency mode, like kout.

``perfmodel.network_report`` prices them: cycles scale ~1/n_cores until a
layer's psum count no longer fills all cores, and tile/halo re-reads are
charged against the DMA interface.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.banking import divisor_banks
from repro.core.convcore import Backend, get_backend
from repro.kernels.ref import conv_out_shape, halo_window, normalize_padding


@dataclass(frozen=True)
class SchedulerConfig:
    n_cores: int = 1
    mode: str = "batch"                 # "batch" | "kout" | "spatial"

    @classmethod
    def for_tune(cls, tune) -> "SchedulerConfig":
        """Config matching an autotuned plan's (mode × cores) verdict —
        accepts anything with ``scheduler_mode`` / ``n_cores`` attributes
        (core/autotune.NetworkTunePlan), so autotune stays an optional
        upper layer this module never imports."""
        return cls(n_cores=int(tune.n_cores), mode=str(tune.scheduler_mode))


class KoutShardedBackend:
    """Backend decorator: split every conv/matmul's output channels across
    ``n_cores`` virtual IP cores and concatenate (paper kernel-set
    division).  Each shard sees the full input map — weight-stationary per
    core, exactly the replicated-core dataflow.

    Grouped convs shard along GROUP boundaries: a core's contiguous
    kernel-set slice must either tile one group (a dense conv over that
    group's cin slice) or cover whole groups (a narrower grouped conv
    over their cin slices) — each core then DMAs only the input channels
    its kernel sets actually read, the grouped reading of "each core
    convolves the same feature map with its kernel slice".  A core count
    that would cut through a group mid-slice raises a ``ValueError`` with
    the offending shapes instead of silently degrading the core count
    the way dense convs do (``_shards``): silently running a depthwise
    layer on fewer cores than configured would misreport the fabric."""

    def __init__(self, inner: Backend, n_cores: int):
        self.inner = inner
        self.n_cores = n_cores
        self.name = f"{inner.name}@kout{n_cores}"

    def _shards(self, k: int) -> int:
        n = min(self.n_cores, k)
        while k % n:
            n -= 1
        return n

    def conv(self, x, w, bias=None, *, groups=1, out_scale=None, plan=None,
             **kw):
        return self._sharded(self.inner.conv, x, w, bias, groups=groups,
                             out_scale=out_scale, plan=plan, **kw)

    def conv_transpose(self, x, w, bias=None, *, groups=1, out_scale=None,
                       plan=None, **kw):
        """Kernel-set division of a TRANSPOSED conv: identical sharding
        law — the transpose's output channels are its K kernel sets, each
        core upsamples the same input map with its slice, and the slices
        concatenate on the channel axis (the output-BRAM crossbar)."""
        return self._sharded(self.inner.conv_transpose, x, w, bias,
                             groups=groups, out_scale=out_scale, plan=plan,
                             **kw)

    def _sharded(self, op, x, w, bias, *, groups, out_scale, plan, **kw):
        k = w.shape[-1]
        if groups > 1:
            return self._conv_grouped(op, x, w, bias, groups=groups,
                                      out_scale=out_scale, plan=plan, **kw)
        n = self._shards(k)
        if n == 1:
            return op(x, w, bias, out_scale=out_scale, plan=plan, **kw)
        if plan is not None:
            # re-bank for the per-core kernel slice (K/n output channels)
            plan = replace(plan, kout_banks=divisor_banks(
                k // n, plan.kout_banks))
        outs = []
        for i in range(n):                 # one iteration per fabric core
            sl = slice(i * (k // n), (i + 1) * (k // n))
            outs.append(op(
                x, w[..., sl], None if bias is None else bias[sl],
                out_scale=(out_scale if out_scale is None
                           or jnp.ndim(out_scale) == 0 else out_scale[sl]),
                plan=plan, **kw))
        return jnp.concatenate(outs, axis=-1)

    def _conv_grouped(self, op, x, w, bias, *, groups, out_scale, plan,
                      **kw):
        """Kernel-set division of a grouped conv: each core's contiguous
        K/n slice stays group-aligned (tiles one group, or covers whole
        groups) and reads only the matching cin slice."""
        k = w.shape[-1]
        kg = k // groups                     # kernels per group
        cgrp = x.shape[-1] // groups         # cin channels per group
        n = min(self.n_cores, k)
        if n == 1:
            return op(x, w, bias, groups=groups,
                      out_scale=out_scale, plan=plan, **kw)
        s = k // n                           # kernel sets per core
        if k % n or (kg % s and s % kg):
            raise ValueError(
                f"kout sharding cannot split K={k} kernels "
                f"(groups={groups}, {kg} kernels/group) across "
                f"{self.n_cores} cores: each core's slice of {k}/{n} "
                f"kernel sets must tile a group or cover whole groups")
        outs = []
        for i in range(n):                   # one iteration per fabric core
            sl = slice(i * s, (i + 1) * s)
            gi0, gi1 = (i * s) // kg, ((i + 1) * s - 1) // kg + 1
            g_s = gi1 - gi0 if s >= kg else 1    # shard's group count
            shard_plan = plan
            if plan is not None:
                if s >= kg:                  # whole groups: keep banks/group
                    kb_n = g_s * max(1, plan.kout_banks // groups)
                else:                        # within one group: dense shard
                    kb_n = divisor_banks(s, plan.kout_banks)
                shard_plan = replace(plan, kout_banks=kb_n, groups=g_s)
            outs.append(op(
                x[..., gi0 * cgrp:gi1 * cgrp], w[..., sl],
                None if bias is None else bias[sl], groups=g_s,
                out_scale=(out_scale if out_scale is None
                           or jnp.ndim(out_scale) == 0 else out_scale[sl]),
                plan=shard_plan, **kw))
        return jnp.concatenate(outs, axis=-1)

    def matmul(self, x, w, bias=None):
        k = w.shape[-1]
        n = self._shards(k)
        if n == 1:
            return self.inner.matmul(x, w, bias)
        outs = [self.inner.matmul(
            x, w[:, i * (k // n):(i + 1) * (k // n)],
            None if bias is None else bias[i * (k // n):(i + 1) * (k // n)])
            for i in range(n)]
        return jnp.concatenate(outs, axis=-1)


class SpatialShardedBackend:
    """Backend decorator: split every conv's output rows into ``n_cores``
    halo'd horizontal bands, one per virtual IP core, and concatenate.

    Band i computing conv-output rows [oy0, oy1) reads padded-input rows
    [oy0·s, (oy1−1)·s + kh) — adjacent bands overlap by the same
    ``kh − s`` halo the tiled kernel's BlockSpecs re-read.  The overlap
    is materialized by slicing the unpadded map and converting the
    residual margins to per-band explicit padding, so each band is an
    ordinary conv the inner backend (and its own TilePlan) handles.
    Bands are pool-aligned: with the fused 2×2 epilogue, band boundaries
    sit on even output rows so no pool window straddles cores."""

    def __init__(self, inner: Backend, n_cores: int):
        self.inner = inner
        self.n_cores = n_cores
        self.name = f"{inner.name}@spatial{n_cores}"

    def conv(self, x, w, bias=None, *, stride=1, padding="VALID",
             dilation=1, pool=False, plan=None, **kw):
        n, h, w_dim, c = x.shape
        kh, kw_ = w.shape[:2]
        (pt, pb), (pl_, pr) = normalize_padding(padding, kh, kw_, stride,
                                                h, w_dim, dilation)
        oh, _ = conv_out_shape(h, w_dim, kh, kw_, stride, padding, dilation)
        if pool:
            oh = (oh // 2) * 2           # floor semantics, like the kernel
        unit = 2 if pool else 1          # pool-aligned band boundaries
        rows = oh // unit
        shards = min(self.n_cores, rows)
        if shards <= 1:
            return self.inner.conv(x, w, bias, stride=stride,
                                   padding=padding, dilation=dilation,
                                   pool=pool, plan=plan, **kw)
        # balanced unit split: the first (rows % shards) bands get one more
        base, rem = divmod(rows, shards)
        outs, oy0 = [], 0
        for i in range(shards):
            oy1 = oy0 + (base + (1 if i < rem else 0)) * unit
            a = oy0 * stride - pt        # input rows, unpadded coordinates
            # the band halo is the DILATED kernel extent minus stride —
            # dilation widens every band's overlap exactly like the tiled
            # kernel's BlockSpecs
            b_ = a + halo_window(oy1 - oy0, stride, kh, dilation)
            lo, hi = max(a, 0), min(b_, h)
            outs.append(self.inner.conv(
                x[:, lo:hi], w, bias, stride=stride,
                padding=((lo - a, b_ - hi), (pl_, pr)), dilation=dilation,
                pool=pool, plan=plan, **kw))
            oy0 = oy1
        return jnp.concatenate(outs, axis=1)

    def conv_transpose(self, x, w, bias=None, *, stride=1, padding="VALID",
                       dilation=1, **kw):
        """Row-band a TRANSPOSED conv by lowering it to its equivalent
        stride-1 conv first (kernels/conv2d_ws_trans.transpose_eq_conv_
        inputs: zero-inserted map + flipped kernel + "full" padding) and
        banding THAT through ``self.conv`` — each core sweeps a halo'd
        band of the upsampled map, which is exactly what replicated
        fixed-size image BRAMs holding one band each would do.  Bit-exact
        with the unsharded kernel because the lowering is the SAME one
        conv2d_ws_transpose performs before launching."""
        from repro.kernels.conv2d_ws_trans import transpose_eq_conv_inputs
        xd, eq_pads = transpose_eq_conv_inputs(
            x, w.shape[0], w.shape[1], stride=stride, padding=padding,
            dilation=dilation)
        return self.conv(xd, jnp.flip(w, (0, 1)), bias, stride=1,
                         padding=eq_pads, dilation=dilation, **kw)

    def matmul(self, x, w, bias=None):
        return self.inner.matmul(x, w, bias)


class MultiCoreScheduler:
    """Run a compiled network program as if on ``n_cores`` replicated IP
    cores."""

    def __init__(self, config: SchedulerConfig = SchedulerConfig()):
        assert config.mode in ("batch", "kout", "spatial"), config.mode
        self.config = config

    @classmethod
    def from_tune(cls, tune) -> "MultiCoreScheduler":
        """Scheduler for an autotuned network plan: the (scheduler mode ×
        core count) the search priced cheapest under the calibrated
        model (see core/autotune.autotune_network)."""
        return cls(SchedulerConfig.for_tune(tune))

    def shard_backend(self, backend_name: str) -> Backend:
        """kout / spatial modes: a Backend whose every conv layer is
        kernel-set- or row-band-sharded across the virtual cores."""
        inner = get_backend(backend_name)
        if self.config.mode == "spatial":
            return SpatialShardedBackend(inner, self.config.n_cores)
        return KoutShardedBackend(inner, self.config.n_cores)

    def run(self, program, x: jax.Array) -> jax.Array:
        """batch mode: split the batch over cores.  kout / spatial modes:
        pass through — the cores divide kernels or row bands inside the
        program (compile it against ``shard_backend``), not the batch.

        Ragged batches (n not a multiple of the core count) zero-pad up to
        the next multiple and slice the padding back off — some cores
        process a blank image on the last step instead of the host
        crashing (the fabric doesn't care what's in an idle core's BRAMs).

        With enough local devices, one device per IP core (NamedSharding +
        GSPMD); otherwise vmapped virtual cores on one device.

        Each run is an ``sched.run`` trace span (mode, cores, batch,
        virtual-vs-device) when obs is enabled — the per-core/mode
        breakdown the full-board utilization story needs."""
        cores = self.config.n_cores
        n = x.shape[0]
        if cores == 1 or self.config.mode in ("kout", "spatial"):
            # kout/spatial: the cores live INSIDE the program (sharded
            # backend); the span still attributes the pass to the mode
            with obs.span("sched.run", mode=self.config.mode, cores=cores,
                          batch=n):
                return program(x)
        pad = -n % cores
        if pad:
            x = jnp.concatenate(
                [x, jnp.zeros((pad, *x.shape[1:]), x.dtype)])
        if jax.device_count() >= cores:
            from jax.sharding import NamedSharding, PartitionSpec as P
            with obs.span("sched.run", mode="batch", cores=cores, batch=n,
                          padded=pad, virtual=False):
                mesh = jax.make_mesh((cores,), ("cores",),
                                     devices=jax.devices()[:cores])
                x = jax.device_put(x, NamedSharding(mesh, P("cores")))
                return program(x)[:n]
        with obs.span("sched.run", mode="batch", cores=cores, batch=n,
                      padded=pad, virtual=True):
            xs = x.reshape(cores, (n + pad) // cores, *x.shape[1:])
            ys = jax.vmap(program)(xs)
            return ys.reshape(n + pad, *ys.shape[2:])[:n]
