"""Analytic cycle/throughput model of the paper's IP core (§5.2).

Reproduces the paper's own numbers exactly:

* [224×224×8] ⊛ [8×3×3×8] → 3,154,176 psums (= 222·222·8·8),
* the 4-core system computes 16 psums / 8 cycles,
* at 112 MHz (Pynq Z2 synthesis, Table 1) → 0.01408 s,
* paper-GOPS (= psums/second): 0.224; 20 replicated IP cores: 4.48.

The paper counts one psum (a 3×3×1 weighted sum) as one "operation"; we
also report standard MAC-ops (1 psum = KH·KW MACs = 2·KH·KW flops) so the
numbers are comparable with TPU rooflines (DESIGN.md §3).

Calibration layer (core/calibration.py): every cost entry point below
takes an optional ``calib=`` — a ``CalibrationTable`` of fitted
correction factors (compute-overhead factor, effective DMA bytes/cycle,
per-slab pipeline overhead) from measured microbenchmarks
(benchmarks/calibrate.py).  The contract is strict separation: with
``calib=None`` (the default) every function below is bit-identical to
the uncalibrated analytic model, so the paper anchors (0.224 / 4.48
GOPS) stay exact — CI asserts this with a fitted table loaded.  The
table is duck-typed here (attributes, not an import) so perfmodel never
depends on the calibration layer it feeds.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import List, Optional, Sequence, Tuple

from repro.kernels.ref import conv_out_shape, conv_transpose_out_shape


@dataclass(frozen=True)
class IPCoreConfig:
    clock_hz: float = 112e6        # Pynq Z2 synthesis (Table 1)
    computing_cores: int = 4       # channel-parallel cores (M1)
    pcores_per_core: int = 4       # kernels in flight per core (M2)
    cycles_per_batch: int = 8      # "four psum values for each eight cycles"
    ip_cores: int = 1              # replicated IP cores on the fabric
    dma_bytes_per_cycle: float = 8.0   # 64-bit DDR/AXI interface (shared)


def psum_count(h: int, w: int, c: int, k: int, kh: int = 3, kw: int = 3,
               stride: int = 1, padding="VALID", groups: int = 1,
               dilation: int = 1) -> int:
    """One psum per (output pixel × kernel × input channel); stride/padding
    change only the output pixel count.  ``groups > 1`` contracts only the
    C/groups channels of each kernel's group — a depthwise layer
    (groups == C) computes a factor-C fewer psums than its dense
    counterpart while moving the SAME feature maps, which is exactly why
    its cycles floor at the shared DMA interface, not at compute
    (``network_report`` flags this per layer).  ``dilation`` spreads the
    taps without multiplying them — it changes the psum count only
    through the output pixel count."""
    oh, ow = conv_out_shape(h, w, kh, kw, stride, padding, dilation)
    return oh * ow * k * (c // groups)


def conv_transpose_psum_count(h: int, w: int, c: int, k: int, kh: int = 3,
                              kw: int = 3, stride: int = 1,
                              padding="VALID", groups: int = 1,
                              dilation: int = 1, skip_zeros: bool = True
                              ) -> int:
    """Psum count of a TRANSPOSED conv layer (lhs zero-insertion by
    ``stride``, then a stride-1 conv — kernels/conv2d_ws_trans.py).

    Two prices, both honest about different hardware:

    * **naive** (``skip_zeros=False``): the equivalent stride-1 conv
      sweeps the zero-inserted map as-is — one psum per (output pixel ×
      kernel × group channel), ``oh·ow·k·c/groups``.  This is what the
      unmodified IP core pays: its MAC array cannot tell an inserted
      zero from data.
    * **skip** (``skip_zeros=True``, the default): every psum whose
      image window lands entirely on inserted zeros is free, and only
      ~1/stride² of each window's taps carry data — the input-pixel
      accounting ``h·w·k·c/groups``: one psum per (INPUT pixel × kernel
      × group channel), since each real input pixel is touched by
      exactly KH·KW output taps.  A zero-skipping MAC controller (the
      standard deconv-accelerator trick the FPGA survey literature
      describes) achieves this bound.

    The ratio naive/skip ≈ stride² is the upsampling waste a
    zero-skipping datapath recovers; ``network_report`` rows for
    transposed layers are priced on the skip count with the naive count
    recorded alongside."""
    if skip_zeros:
        return h * w * k * (c // groups)
    oh, ow = conv_transpose_out_shape(h, w, kh, kw, stride, padding,
                                      dilation)
    return oh * ow * k * (c // groups)


def cycles(n_psums: int, cfg: IPCoreConfig = IPCoreConfig()) -> int:
    per_batch = cfg.computing_cores * cfg.pcores_per_core  # 16 psums
    batches = -(-n_psums // (per_batch * cfg.ip_cores))
    return batches * cfg.cycles_per_batch


def seconds(n_psums: int, cfg: IPCoreConfig = IPCoreConfig()) -> float:
    return cycles(n_psums, cfg) / cfg.clock_hz


def gops_paper(n_psums: int, cfg: IPCoreConfig = IPCoreConfig()) -> float:
    """The paper's accounting: psums per second / 1e9."""
    return n_psums / seconds(n_psums, cfg) / 1e9


def gops_macs(n_psums: int, kh: int = 3, kw: int = 3,
              cfg: IPCoreConfig = IPCoreConfig()) -> float:
    """Standard accounting: 1 psum = KH·KW MACs = 2·KH·KW ops."""
    return n_psums * 2 * kh * kw / seconds(n_psums, cfg) / 1e9


def paper_reference_numbers():
    """The exact §5.2 workload; asserted in tests/test_perfmodel.py."""
    n = psum_count(224, 224, 8, 8)
    one = IPCoreConfig()
    twenty = IPCoreConfig(ip_cores=20)
    return {
        "psums": n,
        "seconds_1core": seconds(n, one),
        "gops_1core": gops_paper(n, one),
        "gops_20cores": gops_paper(n, twenty),
        "gops_macs_1core": gops_macs(n, cfg=one),
    }


def network_cycles(layer_psums: Sequence[int],
                   cfg: IPCoreConfig = IPCoreConfig()) -> int:
    """Whole-network cycle estimate: the IP core processes one layer at a
    time (§4.2), so the network cost is the sum of per-layer passes (each
    layer rounds up to full psum batches separately — the pipeline drains
    between layer configurations).  This holds for DAG plans too: parallel
    branches of a residual graph still serialize on the single core, so a
    topological schedule's length is exactly this sum; merge nodes (add /
    concat) contribute zero psums — the output-BRAM crossbar absorbs
    them."""
    return sum(cycles(p, cfg) for p in layer_psums if p)


def tile_traffic(plan) -> dict:
    """DMA traffic of one layer pass under a ``banking.TilePlan``.

    Every kout bank revisits every spatial tile (the weight-stationary
    sweep re-DMAs the halo'd input window per kernel set), so

        input bytes  = n_tiles · cin_banks · image_block · kout_banks
        weight bytes = n_tiles · cin_banks · kout_banks · weight_block
        output bytes = n_tiles · kout_banks · output_block

    The halo_read_factor isolates the pure halo/zero-extension overhead
    vs a single whole-map read."""
    in_b = plan.n_tiles * plan.cin_banks * plan.image_block_bytes \
        * plan.kout_banks
    w_b = plan.n_tiles * plan.cin_banks * plan.kout_banks \
        * plan.weight_block_bytes
    out_b = plan.n_tiles * plan.kout_banks * plan.output_block_bytes
    return {"input_bytes": in_b, "weight_bytes": w_b,
            "output_bytes": out_b, "total_bytes": in_b + w_b + out_b,
            "halo_read_factor": plan.halo_read_factor,
            "kout_revisits": plan.kout_banks}


def dma_cycles(total_bytes: int, cfg: IPCoreConfig = IPCoreConfig(),
               calib=None) -> int:
    """DMA cycles for ``total_bytes`` on the shared interface.  A
    ``calib`` table with a fitted ``dma_bytes_per_cycle`` overrides the
    config's analytic bandwidth (None keeps it — and ``calib=None`` is
    bit-identical to the uncalibrated model)."""
    bpc = cfg.dma_bytes_per_cycle
    if calib is not None and getattr(calib, "dma_bytes_per_cycle", None):
        bpc = calib.dma_bytes_per_cycle
    return math.ceil(total_bytes / max(bpc, 1e-9))


# Per-slab cost of the explicit ping-pong protocol (descriptor setup,
# semaphore wait, buffer swap) — the reason tiny layers stay sequential:
# when the overlappable work per slab is smaller than the per-slab
# bookkeeping, the steady-state overlap never amortizes it.  This module
# constant is the NO-TABLE default: a fitted ``CalibrationTable`` carries
# its own ``pipeline_overhead_cycles`` (measured, not assumed), and the
# crossover predictor uses that value whenever a table is passed.
PIPELINE_OVERHEAD_CYCLES = 16


def pipeline_overhead_cycles(calib=None) -> float:
    """The per-slab protocol cost the crossover predictor charges: the
    fitted table's value when one is loaded, the 16-cycle analytic
    constant otherwise (CI pins the constant)."""
    if calib is None:
        return PIPELINE_OVERHEAD_CYCLES
    return float(getattr(calib, "pipeline_overhead_cycles",
                         PIPELINE_OVERHEAD_CYCLES))


def calibrated_cycles(n_psums: int, cfg: IPCoreConfig = IPCoreConfig(),
                      calib=None) -> int:
    """Compute cycles with the fitted compute-overhead factor applied
    (the exemplar's measured ``overhead_factor`` idiom).  ``calib=None``
    returns ``cycles`` unchanged — bit-identical, not approximately."""
    base = cycles(n_psums, cfg)
    if calib is None:
        return base
    return math.ceil(base * float(getattr(calib, "compute_factor", 1.0)))


def pipeline_slabs(plan) -> int:
    """Number of (spatial tile × kout bank × cin bank) slabs one layer
    pass streams through the ping-pong buffers — the weight-stationary
    sweep order of both conv kernels."""
    return plan.n_tiles * plan.kout_banks * plan.cin_banks


def pipeline_estimate(plan, psums: int,
                      cfg: IPCoreConfig = IPCoreConfig(),
                      calib=None) -> dict:
    """Sequential-vs-pipelined cost of one layer pass under ``plan``.

    * sequential (``conv2d_ws`` without overlap credit):
      every slab pays its DMA then its compute →  Σ(dma + compute) = D + C;
    * pipelined (``conv2d_ws_pipe`` ping-pong): the first slab's load
      fills the pipe, steady state hides the cheaper phase behind the
      costlier one, the last slab's compute drains →
      fill + (n−1)·max(d, c) + drain, plus per-slab protocol overhead,

    with d = ⌈D/n⌉, c = ⌈C/n⌉ the per-slab shares.  Priced entirely on
    the §5.2 cycle model (``cycles``) and the ``tile_traffic`` /
    ``dma_cycles`` machinery — the paper anchors are untouched.  The
    ``profitable`` verdict is what ``banking.plan_tiles(kernel="auto")``
    uses to set ``TilePlan.pipelined`` per layer.

    ``calib`` applies the fitted corrections (compute-overhead factor,
    effective DMA bandwidth, measured per-slab overhead) to every term —
    the crossover can flip when measurement disagrees with the analytic
    assumptions; ``calib=None`` is bit-identical to the uncalibrated
    estimate."""
    n = max(pipeline_slabs(plan), 1)
    dma = dma_cycles(tile_traffic(plan)["total_bytes"], cfg, calib)
    compute = calibrated_cycles(psums, cfg, calib) if psums else 0
    # fitted fixed per-layer-pass cost (kernel dispatch): identical for
    # both variants and every candidate plan of a layer, so it keeps
    # totals honest without ever changing a verdict; 0 with no table
    base = 0 if calib is None else math.ceil(
        float(getattr(calib, "per_call_overhead_cycles", 0.0)))
    d, c = -(-dma // n), -(-compute // n)
    sequential = dma + compute + base
    pipelined = d + (n - 1) * max(d, c) + c + base \
        + math.ceil(n * pipeline_overhead_cycles(calib))
    return {
        "n_slabs": n,
        "dma_cycles": dma,
        "compute_cycles": compute,
        "sequential_cycles": sequential,
        "pipelined_cycles": pipelined,
        "speedup": sequential / pipelined if pipelined else 1.0,
        "profitable": pipelined < sequential,
    }


def network_report(layers: Sequence[Tuple[str, int]],
                   cfg: IPCoreConfig = IPCoreConfig(),
                   full_board_cores: int = 20,
                   tile_plans: Optional[Sequence] = None,
                   calib=None) -> dict:
    """Per-layer + total cycles/seconds/GOPS for a layer list
    [(name, psums_per_image), ...], for ``cfg`` and for the paper's
    full-board configuration (ip_cores=20, batch-sharded replication).

    ``tile_plans`` (one ``banking.TilePlan`` or None per layer, e.g. from
    ``NetworkPlan.tile_plans``) adds the spatial-tiling DMA cost: each
    layer is priced by ``pipeline_estimate`` for the kernel variant its
    plan carries (``TilePlan.pipelined``) — sequential pays DMA + compute
    per slab, pipelined overlaps them through the ping-pong buffers —
    with tile revisits and halo re-reads priced by ``tile_traffic``.
    Priced rows carry both variants (``cycles_sequential`` /
    ``cycles_pipelined`` / ``pipeline_speedup``) so the crossover is
    auditable per layer.  The DMA interface is SHARED across
    replicated IP cores, so full-board cycles floor at the same DMA time:
    that is what keeps the 20-core GOPS honest on large maps.  Each
    priced row carries ``dma_bound`` / ``dma_bound_board`` flags — on
    depthwise/grouped layers the psum count collapses by the group factor
    while the feature-map traffic stays put, so the shared-DMA floor, not
    compute, is what binds (visibly so on the full board, where compute
    divides by the core count and the DMA interface does not).

    ``calib`` prices every row under the fitted corrections
    (core/calibration.py); ``calib=None`` keeps the analytic model
    bit-identical."""
    board = replace(cfg, ip_cores=full_board_cores)
    if tile_plans is None:
        tile_plans = [None] * len(layers)
    per_layer: List[dict] = []
    total = total_board = 0
    for (name, p), tp in zip(layers, tile_plans):
        compute = calibrated_cycles(p, cfg, calib) if p else 0
        compute_board = calibrated_cycles(p, board, calib) if p else 0
        row = {"name": name, "psums": p, "cycles": compute}
        if tp is not None:
            traffic = tile_traffic(tp)
            dma = dma_cycles(traffic["total_bytes"], cfg, calib)
            pipelined = bool(getattr(tp, "pipelined", False))
            est = pipeline_estimate(tp, p, cfg, calib)
            est_board = pipeline_estimate(tp, p, board, calib)
            chosen = est["pipelined_cycles" if pipelined
                         else "sequential_cycles"]
            chosen_board = est_board["pipelined_cycles" if pipelined
                                     else "sequential_cycles"]
            row.update(dma_bytes=traffic["total_bytes"], dma_cycles=dma,
                       halo_read_factor=traffic["halo_read_factor"],
                       n_tiles=tp.n_tiles,
                       cycles=chosen if p else dma,
                       pipelined=pipelined,
                       cycles_sequential=est["sequential_cycles"],
                       cycles_pipelined=est["pipelined_cycles"],
                       pipeline_speedup=est["speedup"],
                       dma_bound=dma >= compute,
                       dma_bound_board=dma >= compute_board)
            total += row["cycles"]
            total_board += chosen_board if p else dma
        else:
            total += compute
            total_board += compute_board
        per_layer.append(row)
    total_psums = sum(p for _, p in layers)
    return {
        "layers": per_layer,
        # how many priced layers the SHARED DMA interface binds on the
        # full board — the depthwise/grouped arithmetic-intensity story
        "dma_bound_board_layers": sum(
            1 for r in per_layer if r.get("dma_bound_board")),
        # how many priced layers the planner routed to conv2d_ws_pipe
        "pipelined_layers": sum(
            1 for r in per_layer if r.get("pipelined")),
        "psums": total_psums,
        "cycles": total,
        "seconds": total / cfg.clock_hz,
        "gops_paper": total_psums / (total / cfg.clock_hz) / 1e9 if total
        else 0.0,
        "full_board": {
            "ip_cores": full_board_cores,
            "cycles": total_board,
            "seconds": total_board / board.clock_hz,
            "gops_paper": total_psums / (total_board / board.clock_hz) / 1e9
            if total_board else 0.0,
        },
    }


def train_report(layers: Sequence[Tuple[str, int]],
                 cfg: IPCoreConfig = IPCoreConfig(),
                 weight_bytes: Optional[Sequence[int]] = None,
                 full_board_cores: int = 20,
                 tile_plans: Optional[Sequence] = None,
                 calib=None) -> dict:
    """§5.2 cycle model of one TRAINING step over a layer list
    [(name, forward_psums_per_image), ...].

    Backward accounting on the weight-stationary dataflow
    (kernels/conv2d_ws_bwd.py):

    * the input gradient is a transposed conv with the SAME psum count as
      the forward pass — every forward psum has exactly one transposed
      counterpart (one cotangent pixel × kernel tap × channel);
    * the weight gradient is a batched correlation contracting the same
      (output pixel × kernel × channel) index set — again one psum per
      forward psum;

    so the backward pass costs ≈2× the forward psums, and a full step
    (forward + backward) ≈3× — the classic conv-training rule of thumb,
    here exact in the paper's psum accounting.  ``weight_bytes`` (per
    layer, e.g. 4·|W| for f32 gradients; None entries for parameter-free
    nodes) adds the weight-GRADIENT writeback traffic on the shared DMA
    interface — unlike inference, every layer pass must ship dW back to
    the host optimizer, and for fat dense layers that traffic, not
    compute, bounds the backward pass.  Per-layer backward cycles are
    max(compute, dW DMA), the M4 overlap argument applied to the
    gradient stream.

    ``tile_plans`` prices the forward exactly like ``network_report``
    (tile revisits + halo re-reads); the backward input/weight streams
    revisit the same tiles, which the 2× psum accounting already covers
    at compute level."""
    fwd = network_report(layers, cfg, full_board_cores=full_board_cores,
                         tile_plans=tile_plans, calib=calib)
    board = replace(cfg, ip_cores=full_board_cores)
    if weight_bytes is None:
        weight_bytes = [None] * len(layers)
    bwd_rows: List[dict] = []
    bwd_total = bwd_board = 0
    for (name, p), wb in zip(layers, weight_bytes):
        compute = calibrated_cycles(2 * p, cfg, calib) if p else 0
        compute_board = calibrated_cycles(2 * p, board, calib) if p else 0
        row = {"name": name, "psums_bwd": 2 * p, "cycles": compute}
        if wb:
            dma = dma_cycles(wb, cfg, calib)
            row.update(dw_bytes=wb, dw_dma_cycles=dma,
                       cycles=max(compute, dma))
            bwd_total += row["cycles"]
            bwd_board += max(compute_board, dma)   # shared DMA interface
        else:
            bwd_total += compute
            bwd_board += compute_board
        bwd_rows.append(row)
    total = fwd["cycles"] + bwd_total
    total_board = fwd["full_board"]["cycles"] + bwd_board
    step_psums = 3 * fwd["psums"]
    return {
        "forward": fwd,
        "backward": {"layers": bwd_rows, "psums": 2 * fwd["psums"],
                     "cycles": bwd_total,
                     "seconds": bwd_total / cfg.clock_hz},
        "psums": step_psums,
        "cycles": total,
        "seconds": total / cfg.clock_hz,
        "gops_paper": step_psums / (total / cfg.clock_hz) / 1e9 if total
        else 0.0,
        "full_board": {
            "ip_cores": full_board_cores,
            "cycles": total_board,
            "seconds": total_board / board.clock_hz,
            "gops_paper": step_psums / (total_board / board.clock_hz) / 1e9
            if total_board else 0.0,
        },
    }


def tpu_conv_roofline(h: int, w: int, c: int, k: int, kh: int = 3,
                      kw: int = 3, in_bytes: int = 1,
                      peak_flops: float = 197e12 / 2,  # int8 ≈ bf16 on v5e MXU
                      hbm_bw: float = 819e9):
    """Roofline terms for the same layer on one v5e core (conv2d_ws kernel):
    used for the paper-vs-TPU comparison table in benchmarks."""
    oh, ow = h - kh + 1, w - kw + 1
    flops = 2.0 * oh * ow * k * c * kh * kw
    bytes_moved = (h * w * c + kh * kw * c * k) * in_bytes + oh * ow * k * 4
    t = max(flops / peak_flops, bytes_moved / hbm_bw)
    return {"flops": flops, "bytes": bytes_moved,
            "t_compute": flops / peak_flops, "t_memory": bytes_moved / hbm_bw,
            "seconds": t, "gops_macs": flops / t / 1e9,
            "gops_paper": (oh * ow * k * c) / t / 1e9}
