"""Measurement-calibrated corrections to the §5.2 analytic cycle model.

The analytic model in ``core/perfmodel.py`` is first-principles: 16 psums
per 8 cycles, 8 DMA bytes per cycle, a hardcoded 16-cycle per-slab
pipeline protocol cost.  Every plan decision in the stack — tile shapes
in ``banking.plan_tiles``, the sequential/pipelined kernel choice, the
``MultiCoreScheduler`` mode — descends against that model, so a
systematic error in any term silently picks the wrong plan for every
layer.  The survey literature's answer (and the exemplar repo's whole
method — a measured ``overhead_factor = 3.89`` on top of pure-FMACS
cycles) is to *fit* correction factors from microbenchmarks instead of
trusting the datasheet.

This module is that fit, as a SEPARATE layer:

* :class:`CalibrationTable` — the fitted per-term corrections
  (compute-overhead factor, effective DMA bytes/cycle, per-slab pipeline
  overhead), JSON round-trippable and provenance-stamped like
  ``BENCH_network.json``.  ``perfmodel`` consumes it through an optional
  ``calib=`` argument; with no table loaded every perfmodel output is
  bit-identical to the uncalibrated model and the §5.2 paper anchors
  (0.224 / 4.48 GOPS) stay exact — that invariant is CI-asserted.
* :func:`fit_calibration` — least-squares fit of the three correction
  terms onto measured (kernel, tile shape, banks, groups, epilogue,
  pipelined) microbenchmark samples (``benchmarks/calibrate.py`` runs
  the sweep), with IQR-based rejection of noisy samples.

The fitted table expresses measured wall time in *model cycles at
``clock_hz``*: on an FPGA/TPU host the factors calibrate the real
datapath; on the CPU interpret-mode host they calibrate the emulation —
either way the calibrated model and the measurement live on the same
scale, which is what makes ``measured_vs_predicted`` error a
regression-tested number instead of an assumption.
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field, replace
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core import perfmodel

# fraction of the median that the inter-quartile range may span before a
# sample is considered too noisy to constrain the fit
NOISE_IQR_FRACTION = 0.5


@dataclass(frozen=True)
class CalibrationSample:
    """One microbenchmark observation: the analytic model's terms for the
    measured configuration, plus the measurement itself.

    ``compute_cycles`` / ``dma_bytes`` / ``n_slabs`` come straight from
    ``perfmodel.cycles`` / ``perfmodel.tile_traffic`` /
    ``perfmodel.pipeline_slabs`` for the benchmarked plan;
    ``measured_us`` is the median wall time and ``iqr_us`` the
    inter-quartile range of the sample list (``bench_util.time_fn``'s
    stats record) — the fit rejects samples whose IQR says the median is
    not trustworthy."""
    name: str
    compute_cycles: int
    dma_bytes: int
    n_slabs: int
    pipelined: bool
    measured_us: float
    iqr_us: float = 0.0
    meta: Mapping[str, Any] = field(default_factory=dict)

    @property
    def noisy(self) -> bool:
        return self.measured_us > 0 and \
            self.iqr_us > NOISE_IQR_FRACTION * self.measured_us


@dataclass(frozen=True)
class CalibrationTable:
    """Fitted per-term corrections onto the §5.2 analytic model.

    * ``compute_factor`` — measured cycles per analytic compute cycle
      (the exemplar's ``overhead_factor``; 1.0 = the paper's datasheet
      rate is exact);
    * ``dma_bytes_per_cycle`` — EFFECTIVE DMA bandwidth (replaces
      ``IPCoreConfig.dma_bytes_per_cycle``; ``None`` keeps the config's
      analytic value);
    * ``pipeline_overhead_cycles`` — the fitted per-slab ping-pong
      protocol cost (descriptor setup, semaphore wait, buffer swap).
      Defaults to ``perfmodel.PIPELINE_OVERHEAD_CYCLES`` (16) — the
      module constant is the no-table value and stays CI-pinned, so the
      pipelined/sequential crossover only moves when a fitted table says
      it should;
    * ``per_call_overhead_cycles`` — fixed per-layer-pass launch cost
      (kernel dispatch, tracing, descriptor setup) in model cycles.
      Constant across every candidate plan of a layer, so it never
      changes which plan the tuner picks — but without it the other
      terms get silently biased to absorb it (on the interpret-mode
      host it dominates small layers), so it is fitted and reported;
    * ``clock_hz`` — the clock the fit expressed measured seconds
      against (model cycles = seconds × clock_hz), so calibrated
      predictions and measurements share a scale.

    ``fit`` carries the fit diagnostics (sample counts, mean |error| %),
    ``provenance`` pins the run to its toolchain (jax version, device
    kind, git sha) in the same style as ``BENCH_network.json``."""
    compute_factor: float = 1.0
    dma_bytes_per_cycle: Optional[float] = None
    pipeline_overhead_cycles: float = float(
        perfmodel.PIPELINE_OVERHEAD_CYCLES)
    per_call_overhead_cycles: float = 0.0
    clock_hz: float = 112e6
    fit: Mapping[str, Any] = field(default_factory=dict)
    provenance: Mapping[str, Any] = field(default_factory=dict)

    # -- JSON round-trip ----------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        d = asdict(self)
        d["fit"] = dict(self.fit)
        d["provenance"] = dict(self.provenance)
        return d

    def to_json(self, **kw) -> str:
        kw.setdefault("indent", 2)
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "CalibrationTable":
        known = {f for f in cls.__dataclass_fields__}
        return cls(**{k: v for k, v in d.items() if k in known})

    @classmethod
    def from_json(cls, s: str) -> "CalibrationTable":
        return cls.from_dict(json.loads(s))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path: str) -> "CalibrationTable":
        with open(path) as f:
            return cls.from_json(f.read())

    # -- prediction ---------------------------------------------------------

    def predicted_cycles(self, compute_cycles: int, dma_bytes: int,
                         n_slabs: int = 1, pipelined: bool = False,
                         cfg: perfmodel.IPCoreConfig =
                         perfmodel.IPCoreConfig()) -> float:
        """The calibrated model's cycle count for one observation — the
        same three-term expression :func:`fit_calibration` fits, used for
        fit diagnostics and measured-vs-predicted reporting."""
        bpc = self.dma_bytes_per_cycle or cfg.dma_bytes_per_cycle
        cyc = (self.compute_factor * compute_cycles
               + dma_bytes / max(bpc, 1e-9)
               + self.per_call_overhead_cycles)
        if pipelined:
            cyc += self.pipeline_overhead_cycles * n_slabs
        return cyc

    def predicted_us(self, compute_cycles: int, dma_bytes: int,
                     n_slabs: int = 1, pipelined: bool = False) -> float:
        return self.predicted_cycles(
            compute_cycles, dma_bytes, n_slabs, pipelined) \
            / self.clock_hz * 1e6


def load_table(path: Optional[str]) -> Optional[CalibrationTable]:
    """``CalibrationTable.load`` that maps a missing/None path to None —
    the "no table loaded → analytic model bit-exact" convention callers
    (benchmarks, CI) share."""
    if not path:
        return None
    try:
        return CalibrationTable.load(path)
    except FileNotFoundError:
        return None


def _nnls(a: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Non-negative least squares: scipy's reference implementation when
    available, otherwise an active-set fallback (solve unconstrained,
    drop negative-coefficient columns, repeat) — the fitted terms are
    physical rates and must be ≥ 0, and plain clamping after ``lstsq``
    lets one term's violation silently distort the others."""
    try:
        from scipy.optimize import nnls
        return nnls(a, y)[0]
    except ImportError:
        idx = list(range(a.shape[1]))
        while idx:
            sol, *_ = np.linalg.lstsq(a[:, idx], y, rcond=None)
            if np.all(sol >= 0):
                out = np.zeros(a.shape[1])
                out[idx] = sol
                return out
            idx = [j for j, v in zip(idx, sol) if v >= 0]
        return np.zeros(a.shape[1])


def fit_calibration(samples: Sequence[CalibrationSample],
                    cfg: perfmodel.IPCoreConfig = perfmodel.IPCoreConfig(),
                    clock_hz: Optional[float] = None,
                    provenance: Optional[Mapping[str, Any]] = None,
                    reject_noisy: bool = True) -> CalibrationTable:
    """Fit (compute_factor, effective DMA bytes/cycle, per-slab pipeline
    overhead, per-call fixed overhead) by non-negative least squares:

        measured_us · 1e-6 · clock_hz ≈
            compute_factor · compute_cycles
          + (1 / dma_bytes_per_cycle) · dma_bytes
          + pipeline_overhead_cycles · n_slabs·[pipelined]
          + per_call_overhead_cycles · 1

    The intercept column absorbs the fixed per-layer-pass launch cost
    (huge on the interpret-mode host) so it cannot silently bias the
    three physical rates — without it the fit attributes dispatch time
    to whichever term correlates best and the planner optimizes noise.

    Rows are weighted by 1/measured so the fit minimizes RELATIVE error
    — the same mean |error| % the diagnostics report and
    ``measured_vs_predicted`` regression-tests.  Unweighted least
    squares lets the few largest layers dominate and, on a sweep whose
    compute and DMA columns are highly correlated, collapses every term
    but one to zero.

    Samples whose IQR exceeds ``NOISE_IQR_FRACTION`` of their median are
    rejected before fitting (the stats record ``bench_util.time_fn``
    returns exists exactly for this).  Terms the sample set cannot
    constrain keep their analytic defaults: no pipelined samples → the
    16-cycle constant; a degenerate DMA column → the config bandwidth."""
    clock = cfg.clock_hz if clock_hz is None else clock_hz
    kept = [s for s in samples if not (reject_noisy and s.noisy)]
    rejected = len(samples) - len(kept)
    if not kept:
        raise ValueError("fit_calibration: no usable samples "
                         f"({rejected} rejected as noisy)")
    a = np.array([[s.compute_cycles, s.dma_bytes,
                   s.n_slabs if s.pipelined else 0.0, 1.0]
                  for s in kept], dtype=np.float64)
    y = np.array([s.measured_us * 1e-6 * clock for s in kept],
                 dtype=np.float64)
    # columns with no variation cannot be fit — freeze them at the
    # analytic default and solve only for the constrained terms
    active = [j for j in range(4) if np.any(a[:, j] > 0)]
    coef = np.array([1.0, 1.0 / cfg.dma_bytes_per_cycle,
                     float(perfmodel.PIPELINE_OVERHEAD_CYCLES), 0.0])
    if active:
        # weight rows by 1/measured (relative error), then precondition
        # to unit-norm columns so the per-slab overhead term (a few
        # cycles × tens of slabs) isn't drowned by the megacycle
        # compute/DMA columns
        w = 1.0 / np.maximum(y, 1e-12)
        sub = a[:, active] * w[:, None]
        norms = np.linalg.norm(sub, axis=0)
        norms[norms == 0] = 1.0
        sol = _nnls(sub / norms, y * w) / norms
        for j, v in zip(active, sol):
            coef[j] = float(v)
    # a DMA coefficient driven to ~0 means the sample set could not
    # constrain the bandwidth — keep the analytic value rather than
    # reporting infinite bytes/cycle
    dma_bpc = (1.0 / coef[1]) if 1 in active and coef[1] > 1e-15 else None
    table = CalibrationTable(
        compute_factor=coef[0],
        dma_bytes_per_cycle=dma_bpc,
        pipeline_overhead_cycles=coef[2],
        per_call_overhead_cycles=coef[3],
        clock_hz=clock,
        provenance=dict(provenance or {}))
    pred = np.array([table.predicted_cycles(
        s.compute_cycles, s.dma_bytes, s.n_slabs, s.pipelined, cfg)
        for s in kept])
    err = np.abs(pred - y) / np.maximum(np.abs(y), 1e-12)
    return replace(table, fit={
        "n_samples": len(samples),
        "n_rejected_noisy": rejected,
        "n_fit": len(kept),
        "mean_abs_error_pct": float(np.mean(err) * 100.0),
        "max_abs_error_pct": float(np.max(err) * 100.0),
        "terms_fit": [("compute_factor", "dma_bytes_per_cycle",
                       "pipeline_overhead_cycles",
                       "per_call_overhead_cycles")[j] for j in active],
    })


def sample_from_plan(name: str, plan, psums: int, measured_us: float,
                     iqr_us: float = 0.0, pipelined: Optional[bool] = None,
                     cfg: perfmodel.IPCoreConfig = perfmodel.IPCoreConfig(),
                     **meta) -> CalibrationSample:
    """Build a :class:`CalibrationSample` from a ``banking.TilePlan`` —
    the analytic terms come from the same perfmodel machinery the
    calibrated model corrects, so fit and prediction can never disagree
    about what "compute cycles" means."""
    return CalibrationSample(
        name=name,
        compute_cycles=perfmodel.cycles(psums, cfg) if psums else 0,
        dma_bytes=perfmodel.tile_traffic(plan)["total_bytes"],
        n_slabs=perfmodel.pipeline_slabs(plan),
        pipelined=plan.pipelined if pipelined is None else pipelined,
        measured_us=float(measured_us), iqr_us=float(iqr_us),
        meta=dict(meta))
