"""The paper's primary contribution: the convolution IP-core architecture
(channel banking × multi-kernel weight-stationary dataflow × load/compute
pipelining × bias preload × 8-bit datapath), adapted to TPU and scaled
from one layer to whole networks.

* ConvCore / ConvCoreConfig   — the layer-at-a-time IP core (paper §3–4);
                                Backend protocol + registry for dispatch
* network                     — LayerSpec/NetworkPlan graphs compiled into
                                jitted multi-layer int8 programs
* scheduler                   — the replicated-IP-core mode (batch / kout /
                                spatial sharding over devices or virtual
                                cores)
* perfmodel                   — the paper's §5.2 cycle/GOPS model, exact,
                                extended to whole-network estimates with
                                tile-revisit / halo-re-read DMA pricing
* banking                     — BRAM↔VMEM bank + spatial-tile planning
                                (§4.1 → TilePlan), stride/padding-aware
* quantize                    — the 8-bit datapath as reusable substrate,
                                incl. the QAT fake-quantize STE
* training                    — float-shadow / QAT trainer over NetworkPlan
                                DAGs through the WS kernels' custom VJPs
"""

from repro.core.convcore import (Backend, ConvCore, ConvCoreConfig,
                                 get_backend, paper_workload,
                                 register_backend, unregister_backend)
from repro.core import (banking, network, perfmodel, quantize, scheduler,
                        training)

__all__ = ["Backend", "ConvCore", "ConvCoreConfig", "get_backend",
           "paper_workload", "register_backend", "unregister_backend",
           "banking", "network", "perfmodel", "quantize", "scheduler",
           "training"]
