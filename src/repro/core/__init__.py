"""The paper's primary contribution: the convolution IP-core architecture
(channel banking × multi-kernel weight-stationary dataflow × load/compute
pipelining × bias preload × 8-bit datapath), adapted to TPU.

* ConvCore / ConvCoreConfig   — the layer-at-a-time IP core (paper §3–4)
* perfmodel                   — the paper's §5.2 cycle/GOPS model, exact
* banking                     — BRAM↔VMEM bank planning (§4.1)
* quantize                    — the 8-bit datapath as reusable substrate
"""

from repro.core.convcore import ConvCore, ConvCoreConfig, paper_workload
from repro.core import banking, perfmodel, quantize

__all__ = ["ConvCore", "ConvCoreConfig", "paper_workload", "banking",
           "perfmodel", "quantize"]
