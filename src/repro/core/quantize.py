"""int8 quantization — the paper's 8-bit datapath, as a reusable substrate.

Used three ways in this framework (DESIGN.md §3):
1. the ConvCore int8 inference path (quantize activations/weights → int8
   kernel → requantize), matching the paper's 8-bit features/weights;
2. w8a8 serving for the LM stack (per-channel weight scales);
3. gradient all-reduce compression with error feedback (the beyond-paper
   application of the same idea to the DP collective — see
   distributed/compression.py).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Quantized(NamedTuple):
    values: jax.Array              # int8
    scale: jax.Array               # f32; per-tensor [] or per-channel [...,1]

    def dequantize(self) -> jax.Array:
        return self.values.astype(jnp.float32) * self.scale


def quantize_symmetric(x: jax.Array, axis: Optional[int] = None) -> Quantized:
    """Symmetric int8: scale = max|x| / 127 (per tensor or per channel)."""
    xf = x.astype(jnp.float32)
    if axis is None:
        amax = jnp.max(jnp.abs(xf))
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(xf / scale), -128, 127).astype(jnp.int8)
        return Quantized(q, scale)
    amax = jnp.max(jnp.abs(xf), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -128, 127).astype(jnp.int8)
    return Quantized(q, scale)


def requant_scale(in_scale, w_scale, out_scale) -> jax.Array:
    """Per-layer int8 chaining scale: an int32 accumulator holds values in
    units of ``in_scale·w_scale``; multiplying by ``in_scale·w_scale /
    out_scale`` re-expresses them on the next layer's int8 grid, so
    quantized layers chain without dequantizing (the FPGA requantization
    stage between layer passes)."""
    return jnp.asarray(in_scale * w_scale / out_scale, jnp.float32)


def branch_requant_scale(s_branch, s_out) -> jax.Array:
    """Merge-node branch scale: int8 values living on grid ``s_branch``
    re-express on the merge node's shared output grid ``s_out`` via
    ``round(q · s_branch/s_out)`` — the per-branch requantize that makes a
    residual add a pure saturating int8 op (kernels/ref.add_requant_ref),
    the FPGA output-BRAM-crossbar alignment between a conv path and its
    skip path."""
    return jnp.asarray(s_branch / s_out, jnp.float32)


def act_scale_from_calibration(x_f32: jax.Array) -> jax.Array:
    """Activation scale from a calibration batch: max|x|/127 (symmetric)."""
    amax = jnp.max(jnp.abs(x_f32.astype(jnp.float32)))
    return jnp.maximum(amax, 1e-12) / 127.0


# ---------------------------------------------------------------------------
# Quantization-aware training (straight-through fake quantization)
# ---------------------------------------------------------------------------


def fake_quantize(x: jax.Array, scale: jax.Array) -> jax.Array:
    """Quantize-dequantize onto the symmetric int8 grid with a
    straight-through estimator: the forward value is the exact int8
    round-trip (round, saturate to ±127, rescale) — what the deployed
    8-bit datapath will compute — while the backward pass treats the
    rounding as identity (the STE), so gradients flow to the float master
    weights.  ``scale`` is stop-gradiented: QAT learns values ON a grid,
    not the grid itself (the deployment scale is recalibrated by
    ``quantize_network``)."""
    s = jax.lax.stop_gradient(jnp.asarray(scale, jnp.float32))
    q = jnp.clip(jnp.round(x / s), -127, 127) * s
    return x + jax.lax.stop_gradient(q - x)


def fake_quant_weight(w: jax.Array, per_channel: bool = False) -> jax.Array:
    """Fake-quantize a weight tensor exactly the way ``quantize_network``
    will lower it: symmetric max|w|/127 scale, per tensor or per output
    channel (the last axis — conv [KH,KW,C,K] and dense [C,K] alike), so
    the QAT forward sees the deployment grid."""
    wf = w.astype(jnp.float32)
    if per_channel:
        amax = jnp.max(jnp.abs(wf), axis=tuple(range(w.ndim - 1)),
                       keepdims=True)
    else:
        amax = jnp.max(jnp.abs(wf))
    scale = jnp.maximum(jax.lax.stop_gradient(amax), 1e-12) / 127.0
    return fake_quantize(wf, scale).astype(w.dtype)


def fake_quant_act(x: jax.Array) -> jax.Array:
    """Fake-quantize an activation on its per-batch symmetric scale
    (``act_scale_from_calibration`` of the current batch, stop-gradiented)
    — the QAT stand-in for the calibrated activation grids the int8
    program chains through its fused requantize epilogues."""
    scale = act_scale_from_calibration(jax.lax.stop_gradient(x))
    return fake_quantize(x, scale)


def quantized_matmul(x: jax.Array, wq: Quantized,
                     use_kernel: bool = True) -> jax.Array:
    """w8a8 GEMM: quantize activations per-tensor, int8×int8→int32 through
    the paper-dataflow kernel, rescale to f32."""
    xq = quantize_symmetric(x.reshape(-1, x.shape[-1]))
    if use_kernel:
        from repro.kernels import ops
        acc = ops.matmul_ws(xq.values, wq.values)
    else:
        from repro.kernels.ref import matmul_ref_int8
        acc = matmul_ref_int8(xq.values, wq.values)
    out = acc.astype(jnp.float32) * xq.scale * wq.scale.reshape(1, -1)
    return out.reshape(*x.shape[:-1], wq.values.shape[-1])


def quantize_params_for_serving(params, axis: int = 0):
    """Per-output-channel int8 quantization of every 2-D weight matrix."""
    def q(p):
        if p.ndim == 2:
            return quantize_symmetric(p, axis=axis)
        return p
    return jax.tree.map(q, params)


# ---------------------------------------------------------------------------
# w8a8 serving (paper 8-bit datapath → LM weights; §Perf iteration C1)
# ---------------------------------------------------------------------------


def quantize_weight_specs(pspecs, exclude: tuple = ("embedding",)):
    """ParamSpec tree → w8 spec tree: every ≥2-D weight becomes
    {"q": int8 spec, "s": per-last-dim f32 scale spec}.

    The scale varies only along the LAST dimension, which by this repo's
    spec conventions is never contracted in the consuming einsum — so
    rescaling after the int8 dot is exact.  1-D tensors (norm scales,
    biases) stay f32; embedding tables stay f32 (the tied-logits einsum
    contracts their last dim).  Sharding axes carry over unchanged."""
    from repro.layers.common import ParamSpec, spec_map

    def f(s):
        eff_ndim = len(s.shape) - (1 if s.axes and s.axes[0] == "stack" else 0)
        if eff_ndim < 2 or s.dtype != "float32":
            return s
        # scanned params keep their stack dim in the scale (per-layer scales)
        lead = s.shape[0] if s.axes and s.axes[0] == "stack" else 1
        lead_ax = s.axes[0] if lead != 1 else None
        scale_shape = (lead,) + (1,) * (len(s.shape) - 2) + (s.shape[-1],)
        scale_axes = (lead_ax,) + (None,) * (len(s.shape) - 2) + (s.axes[-1],)
        return {"q": ParamSpec(s.shape, s.axes, dtype="int8"),
                "s": ParamSpec(scale_shape, scale_axes, dtype="float32")}

    return {k: (v if k in exclude else spec_map(f, v))
            for k, v in pspecs.items()}


def quantize_weights(params, pspecs=None, exclude: tuple = ("embedding",)):
    """Materialized f32 params → the w8 tree (serving deployment path).

    pspecs: the (unquantized) ParamSpec tree; used to skip stacked 1-D
    tensors (norm scales carry a leading scan dim).  Without it, plain
    ndim≥2 float tensors are quantized."""
    from repro.layers.common import is_spec

    def decide(p, s):
        if not hasattr(p, "ndim") or p.dtype not in (jnp.float32,
                                                     jnp.bfloat16):
            return p
        eff = p.ndim - (1 if s is not None and s.axes
                        and s.axes[0] == "stack" else 0)
        if eff < 2:
            return p
        q = quantize_symmetric(p, axis=tuple(range(p.ndim - 1)))
        return {"q": q.values, "s": q.scale.astype(jnp.float32)}

    out = {}
    for k, v in params.items():
        if k in exclude:
            out[k] = v
        elif pspecs is not None:
            out[k] = jax.tree.map(decide, v, jax.tree.map(
                lambda s: s, pspecs[k], is_leaf=is_spec),
                is_leaf=lambda x: hasattr(x, "ndim"))
        else:
            out[k] = jax.tree.map(lambda p: decide(p, None), v)
    return out


def w8_einsum(subscripts: str, x: jax.Array, w_q: jax.Array,
              w_s: jax.Array, compute_dtype=jnp.bfloat16) -> jax.Array:
    """True int8×int8 GEMM (the paper's datapath): dynamic per-tensor
    activation quantization, s8 dot with int32 accumulation, rescale.
    The HLO dot reads int8 operands — HBM traffic genuinely halves vs bf16
    (this is what the decode roofline measures)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    sx = jnp.maximum(amax, 1e-12) / 127.0
    xq = jnp.clip(jnp.round(xf / sx), -128, 127).astype(jnp.int8)
    acc = jnp.einsum(subscripts, xq, w_q,
                     preferred_element_type=jnp.int32)
    out = acc.astype(jnp.float32) * sx * w_s.reshape(-1).astype(jnp.float32)
    return out.astype(compute_dtype)


# ---------------------------------------------------------------------------
# Error-feedback compressor (for gradient all-reduce compression)
# ---------------------------------------------------------------------------


class EFState(NamedTuple):
    residual: jax.Array            # f32 carry of quantization error


def ef_compress(g: jax.Array, state: Optional[EFState]) -> Tuple[Quantized, EFState]:
    """int8-compress a gradient with error feedback: the quantization error
    is carried into the next step so compression noise is unbiased over
    time (Seide et al. 1-bit SGD lineage)."""
    gf = g.astype(jnp.float32)
    if state is not None:
        gf = gf + state.residual
    q = quantize_symmetric(gf)
    err = gf - q.dequantize()
    return q, EFState(residual=err)


def ef_decompress(q: Quantized) -> jax.Array:
    return q.dequantize()
