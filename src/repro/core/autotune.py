"""Plan autotuner: search (TilePlan × kernel variant × scheduler mode ×
core count) against the measurement-calibrated cost model.

``banking.plan_tiles`` is a greedy descent: from the paper's 4×4 banking
it applies whichever single move shrinks the working set most until the
plan fits VMEM.  That finds *a* legal plan, not the cheapest one — the
descent stops at the first fit, never revisits bank counts that trade
VMEM headroom for DMA traffic (input bytes scale with ``kout_banks``
revisits!), and its pipelined/sequential verdict trusts the analytic
crossover.  The FPGA-mapper literature is unanimous that accelerator
CNN planners win by design-space exploration against a measured cost
model; this module is that exploration:

* :func:`autotune_layer` — enumerate the LEGAL candidate space for one
  conv layer (pool-aligned tile halving chains × divisor bank sets,
  pruned by ``fits_vmem`` and group alignment), price every candidate
  under BOTH kernel variants with ``perfmodel.pipeline_estimate(...,
  calib=...)``, and return the cheapest (deterministic tie-break).  The
  greedy ``plan_tiles`` plan is always seeded into the candidate set, so
  the tuned plan is never worse than the fallback *by construction*.
* :func:`autotune_network` — run the layer search over a
  ``NetworkPlan`` and then search (scheduler mode × core count) for the
  whole network, returning a :class:`NetworkTunePlan` whose
  ``tile_plans`` list threads through ``NetworkPlan.tile_plans`` /
  ``make_int8_program`` / ``MultiCoreScheduler`` unchanged at the call
  sites.

With ``calib=None`` the search prices candidates on the analytic §5.2
model (still a strict improvement over greedy descent — same model,
bigger search space); with a fitted ``CalibrationTable`` the search
optimizes what was *measured*, which is the point.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import List, Optional, Sequence, Tuple

from repro import obs
from repro.core import banking, perfmodel
from repro.core.banking import TilePlan
from repro.kernels.ref import check_groups, conv_out_shape, grouped_banks

SCHEDULER_MODES = ("batch", "kout", "spatial")
CORE_COUNTS = (1, 2, 4, 8, 16, 20)


# ---------------------------------------------------------------------------
# Per-layer candidate enumeration
# ---------------------------------------------------------------------------


def _tile_chain(full: int, pool: bool) -> List[int]:
    """The pool-aligned halving chain ``plan_tiles`` descends — full map
    first, then successive (aligned) halvings down to the minimum tile.
    Enumerating exactly this chain keeps every candidate a tile extent
    the kernels' BlockSpecs already handle and makes the greedy plan a
    guaranteed member of the search space."""
    vals, v = [], max(full, 2 if pool else 1)
    while True:
        vals.append(v)
        nv = banking._align_tile(-(-v // 2), pool)
        if nv >= v or v <= (2 if pool else 1):
            return vals
        v = nv


def _divisors(n: int) -> List[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


def candidate_states(oh: int, ow: int, cgrp: int, k: int, groups: int,
                     pool: bool) -> List[Tuple[int, int, int, int]]:
    """All legal (h_tile, w_tile, cin_banks, kout_banks) states for one
    layer: tile extents from the pool-aligned halving chains, cin banks
    any divisor of the per-group channel slice, kout banks any
    group-aligned divisor of K (``kout_banks = groups · m`` with ``m``
    dividing ``K/groups`` — a bank never straddles a group boundary)."""
    kouts = [groups * m for m in _divisors(k // groups)]
    cins = _divisors(cgrp)
    return [(th, tw, cb, kb)
            for th in _tile_chain(oh, pool)
            for tw in _tile_chain(ow, pool)
            for cb in cins
            for kb in kouts]


@dataclass(frozen=True)
class LayerTune:
    """The tuner's verdict for one node: the chosen plan, its calibrated
    chosen-variant cycle count, and the greedy fallback it beat (or
    matched).  ``source`` is "autotuned" when the chosen plan differs
    from the greedy ``plan_tiles(kernel="auto")`` plan, "greedy" when
    the search confirmed the fallback was already optimal."""
    name: str
    plan: Optional[TilePlan]
    cycles: int
    greedy_plan: Optional[TilePlan] = None
    greedy_cycles: int = 0
    psums: int = 0
    k: int = 0                       # conv layers: kernel count (for the
    groups: int = 1                  # kout-shard legality rule)

    @property
    def source(self) -> str:
        if self.plan is None:
            return "greedy"
        return "greedy" if self.plan == self.greedy_plan else "autotuned"


def _variant_cost(plan: TilePlan, psums: int, cfg, calib) -> Tuple[int, int]:
    est = perfmodel.pipeline_estimate(plan, psums, cfg, calib)
    return est["sequential_cycles"], est["pipelined_cycles"]


def plan_cost(plan: TilePlan, psums: int,
              cfg: perfmodel.IPCoreConfig = perfmodel.IPCoreConfig(),
              calib=None) -> int:
    """Calibrated cycle count of one layer pass under ``plan``, priced
    for the kernel variant the plan carries (``TilePlan.pipelined``) —
    the single cost definition the tuner, its tests, and the benchmark
    reports share."""
    seq, pipe = _variant_cost(plan, psums, cfg, calib)
    return pipe if plan.pipelined else seq


def autotune_layer(h: int, w: int, c: int, k: int, kh: int = 3, kw: int = 3,
                   *, stride: int = 1, padding="VALID", pool: bool = False,
                   groups: int = 1, dilation: int = 1, in_bytes: int = 1,
                   acc_bytes: int = 4,
                   out_bytes: Optional[int] = None,
                   cin_banks: int = 4, kout_banks: int = 4,
                   vmem_budget: Optional[int] = banking.VMEM_BYTES,
                   cfg: perfmodel.IPCoreConfig = perfmodel.IPCoreConfig(),
                   calib=None, name: str = "conv",
                   psums: Optional[int] = None) -> LayerTune:
    """Exhaustive (TilePlan × kernel variant) search for one conv layer.

    Every candidate is built through ``banking.plan_tiles``'s own
    ``build`` geometry (same halo math, same byte accounting), pruned by
    ``fits_vmem``, and priced by ``perfmodel.pipeline_estimate`` under
    ``calib`` for BOTH kernel variants; the cheapest (cost, then a fixed
    structural tie-break) wins, so the result is deterministic given a
    fixed CalibrationTable.  The greedy ``plan_tiles(kernel="auto")``
    plan for the same arguments is seeded into the candidate set: the
    tuned plan can only ever match or beat it under the same model.

    ``psums`` overrides the compute price (transposed layers pass their
    zero-skipping count — the eq stride-1 conv geometry this function
    sees would otherwise price the ~stride²× naive sweep)."""
    check_groups(c, k, groups)
    cgrp = c // groups
    out_bytes_eff = acc_bytes if out_bytes is None else out_bytes
    if psums is None:
        psums = perfmodel.psum_count(h, w, c, k, kh, kw, stride=stride,
                                     padding=padding, groups=groups,
                                     dilation=dilation)
    greedy = banking.plan_tiles(
        h, w, c, k, kh, kw, stride=stride, padding=padding, pool=pool,
        groups=groups, dilation=dilation, in_bytes=in_bytes,
        acc_bytes=acc_bytes,
        out_bytes=out_bytes, cin_banks=cin_banks, kout_banks=kout_banks,
        vmem_budget=vmem_budget, kernel="auto", calib=calib)
    greedy_cost = plan_cost(greedy, psums, cfg, calib)

    oh, ow = conv_out_shape(h, w, kh, kw, stride, padding, dilation)
    if pool:
        oh, ow = (oh // 2) * 2, (ow // 2) * 2
    budget = banking.VMEM_BYTES if vmem_budget is None else vmem_budget

    def build(th: int, tw: int, cbn: int, kbn: int) -> TilePlan:
        cb, kb = cgrp // cbn, k // kbn
        in_th = banking.halo_window(th, stride, kh, dilation)
        in_tw = banking.halo_window(tw, stride, kw, dilation)
        pth, ptw = (th // 2, tw // 2) if pool else (th, tw)
        return TilePlan(
            cin_banks=cbn, kout_banks=kbn, h_tile=th, w_tile=tw,
            n_h_tiles=-(-oh // th), n_w_tiles=-(-ow // tw),
            in_h_tile=in_th, in_w_tile=in_tw,
            image_block_bytes=in_th * in_tw * cb * in_bytes,
            weight_block_bytes=kh * kw * cb * kb * in_bytes,
            acc_block_bytes=th * tw * kb * acc_bytes,
            output_block_bytes=pth * ptw * kb * out_bytes_eff,
            stride=stride, out_h=oh, out_w=ow, pool=pool,
            in_bytes=in_bytes, budget=budget, groups=groups)

    # (cost, structural tie-break, plan): the tie-break prefers fewer
    # tiles, coarser banking, then the sequential kernel — a fixed total
    # order, so equal-cost candidate sets always resolve the same way
    def key(plan: TilePlan, cost: int):
        return (cost, plan.n_tiles, plan.kout_banks, plan.cin_banks,
                plan.pipelined, plan.h_tile, plan.w_tile)

    best_plan, best_key = greedy, key(greedy, greedy_cost)
    n_cands = 0
    with obs.span("autotune.layer", layer=name, psums=psums):
        for th, tw, cbn, kbn in candidate_states(oh, ow, cgrp, k, groups,
                                                 pool):
            # per-candidate evaluation span: pruned candidates never get
            # one (they were never priced) — gated so the disabled path
            # costs one branch per candidate
            cand = build(th, tw, cbn, kbn)
            if vmem_budget is not None and not cand.fits_vmem:
                continue
            n_cands += 1
            with obs.span("autotune.candidate", layer=name, h_tile=th,
                          w_tile=tw, cin_banks=cbn, kout_banks=kbn):
                seq, pipe = _variant_cost(cand, psums, cfg, calib)
                for pipelined, cost in ((False, seq), (True, pipe)):
                    p = replace(cand, pipelined=pipelined)
                    k_ = key(p, cost)
                    if k_ < best_key:
                        best_plan, best_key = p, k_
    obs.metrics.counter("autotune.candidates").inc(n_cands)
    return LayerTune(name=name, plan=best_plan, cycles=best_key[0],
                     greedy_plan=greedy, greedy_cycles=greedy_cost,
                     psums=psums, k=k, groups=groups)


# ---------------------------------------------------------------------------
# Whole-network tuning: layers, then (scheduler mode × core count)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class NetworkTunePlan:
    """A tuned execution recipe for one network: per-layer plans (the
    ``tile_plans`` property is a drop-in for ``NetworkPlan.tile_plans``
    output — pass it to ``make_int8_program(..., tile_plans=...)``), the
    winning scheduler (mode, core count), and the calibrated totals for
    both the tuned and the greedy-fallback plan sets."""
    network: str
    layers: Tuple[LayerTune, ...]
    scheduler_mode: str = "batch"
    n_cores: int = 1
    cycles: int = 0                 # tuned total, 1 core
    greedy_cycles: int = 0          # greedy-fallback total, 1 core
    schedule_cycles_: int = 0       # tuned total at (mode, n_cores)
    calibrated: bool = False        # a CalibrationTable priced the search

    @property
    def tile_plans(self) -> List[Optional[TilePlan]]:
        return [lt.plan for lt in self.layers]

    @property
    def greedy_tile_plans(self) -> List[Optional[TilePlan]]:
        return [lt.greedy_plan for lt in self.layers]

    @property
    def layers_differ(self) -> int:
        """How many conv layers the search moved off the greedy plan."""
        return sum(1 for lt in self.layers if lt.source == "autotuned")

    @property
    def speedup(self) -> float:
        return self.greedy_cycles / self.cycles if self.cycles else 1.0

    def scheduler_config(self):
        """The winning mode/cores as a ``SchedulerConfig`` — feed it to
        ``MultiCoreScheduler`` unchanged."""
        from repro.core.scheduler import SchedulerConfig
        return SchedulerConfig(n_cores=self.n_cores,
                               mode=self.scheduler_mode)

    def layer_rows(self) -> List[dict]:
        """Per-layer report rows (plan_source + both cycle counts) for
        the benchmark JSON."""
        return [{"name": lt.name, "plan_source": lt.source,
                 "cycles_autotuned": lt.cycles,
                 "cycles_greedy": lt.greedy_cycles,
                 "pipelined": bool(lt.plan.pipelined) if lt.plan else None}
                for lt in self.layers]


def _kout_shards(k: int, groups: int, cores: int) -> int:
    """Largest core count ≤ ``cores`` whose contiguous K/n kernel-set
    slices stay group-aligned — the same legality rule
    ``scheduler.KoutShardedBackend`` enforces at run time."""
    kg = k // groups
    for n in range(min(cores, k), 0, -1):
        if k % n:
            continue
        s = k // n
        if s % kg == 0 or kg % s == 0:
            return n
    return 1


def _spatial_shards(tp: TilePlan, cores: int) -> int:
    unit = 2 if tp.pool else 1
    return max(1, min(cores, tp.out_h // unit))


def _spatial_halo_plan(tp: TilePlan, bands: int) -> TilePlan:
    """Charge the spatial mode's halo re-read: each extra band re-reads
    ``kh − stride`` input rows, exactly the overlap
    ``SpatialShardedBackend`` materializes.  Expressed as an inflated
    per-step image block so ``pipeline_estimate`` prices it unchanged."""
    if bands <= 1:
        return tp
    kh = tp.in_h_tile - (tp.h_tile - 1) * tp.stride
    in_h = banking.halo_window(tp.out_h, tp.stride, kh)
    factor = 1.0 + (bands - 1) * max(kh - tp.stride, 0) / max(in_h, 1)
    return replace(tp,
                   image_block_bytes=math.ceil(tp.image_block_bytes * factor))


def schedule_cycles(layers: Sequence[LayerTune], mode: str, cores: int,
                    cfg: perfmodel.IPCoreConfig = perfmodel.IPCoreConfig(),
                    calib=None) -> int:
    """Calibrated whole-network cycles under one (scheduler mode, core
    count) point:

    * batch — throughput pricing: compute divides by the core count,
      the SHARED DMA interface does not (the ``network_report``
      full-board rule);
    * kout — per-layer compute divides by the largest group-aligned
      kernel-set split ≤ cores; the input map is broadcast over the
      fabric crossbar, so DMA traffic is unchanged;
    * spatial — per-layer compute divides by the row-band count and the
      bands' ``kh − stride`` halo re-reads are charged to DMA.

    Layers without a plan (dense GEMMs, merge nodes) price on calibrated
    compute cycles with the same per-mode division."""
    total = 0
    for lt in layers:
        tp, p = lt.plan, lt.psums
        if tp is None:
            if not p:
                continue
            eff = cores if mode in ("batch", "kout") else 1
            total += perfmodel.calibrated_cycles(
                p, replace(cfg, ip_cores=eff), calib)
            continue
        if mode == "batch":
            eff, priced = cores, tp
        elif mode == "kout":
            eff = _kout_shards(lt.k, lt.groups, cores)
            priced = tp
        else:
            eff = _spatial_shards(tp, cores)
            priced = _spatial_halo_plan(tp, eff)
        est = perfmodel.pipeline_estimate(
            priced, p, replace(cfg, ip_cores=eff), calib)
        total += est["pipelined_cycles" if tp.pipelined
                     else "sequential_cycles"]
    return total


def route_batch(layers: Sequence[LayerTune], batch: int, n_cores: int,
                cfg: perfmodel.IPCoreConfig = perfmodel.IPCoreConfig(),
                calib=None, modes: Sequence[str] = SCHEDULER_MODES
                ) -> Tuple[str, int, int]:
    """Pick the scheduler mode the calibrated model predicts fastest for
    ONE formed batch of ``batch`` images on an ``n_cores`` budget.
    Returns ``(mode, cores, predicted_cycles)``.

    The autotuner's ``schedule_cycles`` prices steady-state throughput
    for a fixed batch size; a continuous-batching engine instead sees
    whatever size the deadline handed it, and the best verdict flips
    with that size: a deadline-launched single image wants the cores
    INSIDE the program (kout/spatial sharded backends — batch sharding
    can't split one image), while a full batch usually wants batch
    sharding (compute divides by every core with no halo/broadcast tax).
    Pricing: batch mode processes the formed batch across
    ``min(batch, n_cores)`` cores; kout/spatial run the sharded program
    once per image on all ``n_cores``.  First mode in ``modes`` wins
    ties (strict improvement to switch), matching ``autotune_network``'s
    never-worse-than-greedy convention."""
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    if n_cores < 1:
        raise ValueError(f"n_cores must be >= 1, got {n_cores}")
    best = None
    for mode in modes:
        cores = min(batch, n_cores) if mode == "batch" else n_cores
        cycles = batch * schedule_cycles(layers, mode, cores, cfg, calib)
        if best is None or cycles < best[2]:
            best = (mode, cores, cycles)
    return best


def autotune_network(plan, cin_banks: int = 4, kout_banks: int = 4,
                     in_bytes: int = 1,
                     vmem_budget: Optional[int] = banking.VMEM_BYTES,
                     cfg: perfmodel.IPCoreConfig = perfmodel.IPCoreConfig(),
                     calib=None,
                     modes: Sequence[str] = SCHEDULER_MODES,
                     core_counts: Sequence[int] = CORE_COUNTS
                     ) -> NetworkTunePlan:
    """Tune every conv layer of a ``NetworkPlan`` (same walk and bank
    legalization as ``NetworkPlan.tile_plans``, so the tuned list is a
    drop-in replacement), then search (scheduler mode × core count) for
    the whole network under the calibrated model.  Deterministic: modes
    are scanned in the given order and core counts ascending, with
    strict improvement required to move — ties resolve to the earliest
    (fewest-cores) point."""
    from repro.core.network import PARAM_KINDS, conv_geometry
    from repro.kernels.conv2d_ws_trans import transpose_eq_conv_geometry
    last_param = max((i for i, sp in enumerate(plan.layers)
                      if sp.kind in PARAM_KINDS), default=-1)
    names = plan.node_names()
    ins = plan.resolved_inputs()
    acts = plan.activation_shapes()
    psum_rows = dict(plan.psum_table())
    tunes: List[LayerTune] = []
    for i, sp in enumerate(plan.layers):
        if sp.kind not in ("conv", "conv_transpose"):
            p = psum_rows[names[i]]
            cyc = perfmodel.calibrated_cycles(p, cfg, calib) if p else 0
            tunes.append(LayerTune(name=names[i], plan=None, cycles=cyc,
                                   greedy_cycles=cyc, psums=p))
            continue
        h, w, c = plan.input_shape if ins[i][0] < 0 else acts[ins[i][0]]
        kh, kw = sp.kernel
        k_, g_ = conv_geometry(sp, c)
        cb_n, kb_n = grouped_banks(c, k_, g_, want_cin=cin_banks,
                                   want_kout=kout_banks)
        stride, pad = sp.stride, sp.padding
        if sp.kind == "conv_transpose":
            # tune on the eq stride-1 conv geometry (what the kernel
            # lowering launches) but price compute on the zero-skipping
            # psum count the psum_table carries
            h, w, pad = transpose_eq_conv_geometry(
                h, w, kh, kw, sp.stride, sp.padding, sp.dilation)
            stride = 1
        tunes.append(autotune_layer(
            h, w, c, k_, kh, kw, stride=stride, padding=pad,
            pool=sp.pool, groups=g_, dilation=sp.dilation,
            in_bytes=in_bytes,
            out_bytes=4 if i == last_param else in_bytes,
            cin_banks=cb_n, kout_banks=kb_n, vmem_budget=vmem_budget,
            cfg=cfg, calib=calib, name=names[i],
            psums=psum_rows[names[i]]))
    total = sum(lt.cycles for lt in tunes)
    greedy_total = sum(lt.greedy_cycles for lt in tunes)
    best = ("batch", 1, schedule_cycles(tunes, "batch", 1, cfg, calib))
    with obs.span("autotune.schedule_sweep", network=plan.name):
        for mode in modes:
            for cores in sorted(core_counts):
                with obs.span("autotune.schedule", mode=mode, cores=cores):
                    cyc = schedule_cycles(tunes, mode, cores, cfg, calib)
                if cyc < best[2]:
                    best = (mode, cores, cyc)
    return NetworkTunePlan(
        network=plan.name, layers=tuple(tunes),
        scheduler_mode=best[0], n_cores=best[1],
        cycles=total, greedy_cycles=greedy_total,
        schedule_cycles_=best[2], calibrated=calib is not None)
