"""ConvCore — the paper's IP core as a composable JAX module.

Semantics follow the paper exactly (§3–4): the core processes **one
convolutional layer at a time**; it accepts a C-channel feature-map stack and
K C-channel kernels, and produces a K-channel feature map.  Bias is
*preloaded* into the output accumulator (M5).  C and K must satisfy the
divisible-by-4 banking invariant (§4.1) for the faithful (4,4)
configuration; bank counts are parameterizable for TPU block-size tuning
(banking.py picks VMEM-fitting counts).

Backends:
* "pallas"  — kernels/conv2d_ws.py, the TPU-native dataflow (interpret mode
  on CPU);
* "ref"     — pure-jnp oracle (lax.conv), used for training graphs/vjp.

The int8 path mirrors the paper's 8-bit datapath: int8 features/weights →
int32 psum accumulation → requantize (or wrap8 for waveform fidelity).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import banking
from repro.core.quantize import Quantized, quantize_symmetric
from repro.kernels import ops, ref


@dataclass(frozen=True)
class ConvCoreConfig:
    cin_banks: int = 4            # paper: 4 image BMGs / computing cores (M1)
    kout_banks: int = 4           # paper: 4 PCOREs per core (M2)
    backend: str = "pallas"       # pallas | ref
    int8: bool = False            # the paper's 8-bit datapath
    wrap8: bool = False           # bit-faithful 8-bit psum wrap (Fig. 6)
    auto_bank: bool = False       # let banking.py grow banks to fit VMEM


class ConvCore:
    """One paper IP core.  Use ``apply_layer`` per convolutional layer."""

    def __init__(self, config: ConvCoreConfig = ConvCoreConfig()):
        self.config = config

    def plan(self, x_shape, w_shape) -> banking.BankPlan:
        n, h, w_, c = x_shape
        kh, kw, _, k = w_shape
        cfg = self.config
        in_bytes = 1 if cfg.int8 else 4
        if cfg.auto_bank:
            return banking.plan_banks(h, w_, c, k, kh, kw, in_bytes=in_bytes,
                                      cin_banks=cfg.cin_banks,
                                      kout_banks=cfg.kout_banks)
        cb, kb = c // cfg.cin_banks, k // cfg.kout_banks
        oh, ow = h - kh + 1, w_ - kw + 1
        return banking.BankPlan(cfg.cin_banks, cfg.kout_banks,
                                h * w_ * cb * in_bytes,
                                kh * kw * cb * kb * in_bytes,
                                oh * ow * kb * 4)

    def apply_layer(self, x: jax.Array, w: jax.Array,
                    bias: Optional[jax.Array] = None,
                    out_scale: Optional[jax.Array] = None) -> jax.Array:
        """x: [N,H,W,C] ⊛ w: [KH,KW,C,K] (+bias [K]) → [N,OH,OW,K]."""
        cfg = self.config
        plan = self.plan(x.shape, w.shape)
        if cfg.int8:
            assert x.dtype == jnp.int8 and w.dtype == jnp.int8
        if cfg.backend == "ref":
            if cfg.int8:
                out = ref.conv2d_ref_int8(x, w, bias)
                if cfg.wrap8:
                    return out.astype(jnp.int8)
                if out_scale is not None:
                    return jnp.clip(jnp.round(
                        out.astype(jnp.float32) * out_scale),
                        -128, 127).astype(jnp.int8)
                return out
            return ref.conv2d_ref(x, w, bias)
        return ops.conv2d(x, w, bias, cin_banks=plan.cin_banks,
                          kout_banks=plan.kout_banks,
                          wrap8=cfg.wrap8, out_scale=out_scale)

    def apply_quantized_layer(self, x_f32: jax.Array, w_f32: jax.Array,
                              bias_f32: Optional[jax.Array] = None):
        """Float-in/float-out convenience: symmetric int8 quantization of
        activations + weights, int32 accumulate, dequantize (the edge-AI
        deployment path the paper targets)."""
        xq = quantize_symmetric(x_f32)
        wq = quantize_symmetric(w_f32)
        bias_i32 = None
        if bias_f32 is not None:
            bias_i32 = jnp.round(
                bias_f32.astype(jnp.float32) / (xq.scale * wq.scale)
            ).astype(jnp.int32)
        core = ConvCore(ConvCoreConfig(
            cin_banks=self.config.cin_banks,
            kout_banks=self.config.kout_banks,
            backend=self.config.backend, int8=True))
        acc = core.apply_layer(xq.values, wq.values, bias_i32)
        return acc.astype(jnp.float32) * (xq.scale * wq.scale)


def paper_workload():
    """The exact §5.2 simulation workload shapes."""
    return {"x": (1, 224, 224, 8), "w": (3, 3, 8, 8), "bias": (8,)}
