"""ConvCore — the paper's IP core as a composable JAX module.

Semantics follow the paper exactly (§3–4): the core processes **one
convolutional layer at a time**; it accepts a C-channel feature-map stack and
K C-channel kernels, and produces a K-channel feature map.  Bias is
*preloaded* into the output accumulator (M5).  Generalized beyond the
paper's stride-1 VALID demo: any stride, SAME/VALID/explicit padding, and
the fused post-processing epilogue (ReLU → 2×2 max-pool → requantize)
executed before writeback.  Bank counts degrade gracefully for channel
counts that break the divisible-by-4 invariant (a C=1 grayscale input
layer runs on one image BMG), and ``ConvCore.plan`` returns a joint
``banking.TilePlan`` — feature maps whose whole-map working set exceeds
the VMEM budget stream through halo'd spatial tiles.

Backends implement the ``Backend`` protocol and live in a registry, so
``apply_layer`` is a pure dispatch (no per-dtype if/else ladder):

* "pallas"  — kernels/conv2d_ws.py, the TPU-native dataflow (interpret mode
  on CPU);
* "ref"     — pure-jnp oracle (lax.conv), used for training graphs/vjp.

The int8 path mirrors the paper's 8-bit datapath: int8 features/weights →
int32 psum accumulation → requantize (or wrap8 for waveform fidelity).
Layer-at-a-time networks are built on top by core/network.py; replicated
IP cores map to core/scheduler.py.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Protocol

import jax
import jax.numpy as jnp

from repro.core import banking
from repro.core.quantize import Quantized, quantize_symmetric
from repro.kernels import ops, ref


class Backend(Protocol):
    """One implementation of the IP-core ops (conv + transposed conv +
    the dense GEMM).

    ``plan`` is a banking.TilePlan: the joint spatial-tile × channel-bank
    decomposition the conv should run under (None → whole map, paper 4×4
    banking).  ``conv_transpose`` is the dense-prediction upsampling
    layer — its plan is sized on the EQUIVALENT stride-1 conv geometry
    (the zero-inserted map the kernel actually sweeps)."""

    name: str

    def conv(self, x: jax.Array, w: jax.Array,
             bias: Optional[jax.Array] = None, *, stride: int = 1,
             padding="VALID", groups: int = 1, dilation: int = 1,
             relu: bool = False, pool: bool = False, out_scale=None,
             wrap8: bool = False,
             plan: Optional[banking.TilePlan] = None) -> jax.Array:
        ...

    def conv_transpose(self, x: jax.Array, w: jax.Array,
                       bias: Optional[jax.Array] = None, *,
                       stride: int = 1, padding="VALID", groups: int = 1,
                       dilation: int = 1, relu: bool = False,
                       pool: bool = False, out_scale=None,
                       plan: Optional[banking.TilePlan] = None
                       ) -> jax.Array:
        ...

    def matmul(self, x: jax.Array, w: jax.Array,
               bias: Optional[jax.Array] = None) -> jax.Array:
        ...


class RefBackend:
    """Pure-jnp oracle (lax.conv) — differentiable, the correctness
    contract for the Pallas dataflow."""

    name = "ref"

    def conv(self, x, w, bias=None, *, stride=1, padding="VALID",
             groups=1, dilation=1, relu=False, pool=False, out_scale=None,
             wrap8=False, plan=None):
        if wrap8:
            # epilogue runs on the int32 accumulator, THEN the result wraps
            # to 8 bits — matching the Pallas path (epilogue in the kernel,
            # wrap in ops.conv2d); like ops.conv2d, wrap8 + out_scale is a
            # contract violation, not a silent drop
            if out_scale is not None:
                raise ValueError("wrap8 and out_scale are mutually "
                                 "exclusive: the Fig. 6 wrap path has no "
                                 "requantize stage")
            assert x.dtype == jnp.int8
            acc = ref.conv2d_epilogue_ref(x, w, bias, stride=stride,
                                          padding=padding, relu=relu,
                                          pool=pool, groups=groups,
                                          dilation=dilation)
            return acc.astype(jnp.int8)
        return ref.conv2d_epilogue_ref(x, w, bias, stride=stride,
                                       padding=padding, relu=relu,
                                       pool=pool, out_scale=out_scale,
                                       groups=groups, dilation=dilation)

    def conv_transpose(self, x, w, bias=None, *, stride=1, padding="VALID",
                       groups=1, dilation=1, relu=False, pool=False,
                       out_scale=None, plan=None):
        return ref.conv2d_transpose_epilogue_ref(
            x, w, bias, stride=stride, padding=padding, relu=relu,
            pool=pool, out_scale=out_scale, groups=groups,
            dilation=dilation)

    def matmul(self, x, w, bias=None):
        if x.dtype == jnp.int8:
            return ref.matmul_ref_int8(x, w, bias)
        return ref.matmul_ref(x, w, bias)


class PallasBackend:
    """The TPU-native weight-stationary dataflow (kernels/conv2d_ws.py)."""

    name = "pallas"

    def conv(self, x, w, bias=None, *, stride=1, padding="VALID",
             groups=1, dilation=1, relu=False, pool=False, out_scale=None,
             wrap8=False, plan=None):
        if plan is not None:
            cin_banks, kout_banks = plan.cin_banks, plan.kout_banks
        else:
            # no plan → whole map under the paper's 4×4 banking, degraded
            # to the largest legal divisors (narrow kernel-set shards and
            # grouped layers would otherwise trip the divisibility assert)
            cin_banks, kout_banks = ref.grouped_banks(
                x.shape[-1], w.shape[-1], groups)
        # tile extents are conv-output pixels; the kernel clamps them to
        # the actual map (shard slices may be smaller than the plan's map)
        h_tile = plan.h_tile if plan else 0
        w_tile = plan.w_tile if plan else 0
        return ops.conv2d(x, w, bias, stride=stride, padding=padding,
                          groups=groups, cin_banks=cin_banks,
                          kout_banks=kout_banks, h_tile=h_tile,
                          w_tile=w_tile, relu=relu, pool=pool, wrap8=wrap8,
                          out_scale=out_scale, dilation=dilation,
                          pipelined=plan.pipelined if plan else False)

    def conv_transpose(self, x, w, bias=None, *, stride=1, padding="VALID",
                       groups=1, dilation=1, relu=False, pool=False,
                       out_scale=None, plan=None):
        if plan is not None:
            cin_banks, kout_banks = plan.cin_banks, plan.kout_banks
        else:
            cin_banks, kout_banks = ref.grouped_banks(
                x.shape[-1], w.shape[-1], groups)
        h_tile = plan.h_tile if plan else 0
        w_tile = plan.w_tile if plan else 0
        return ops.conv2d_transpose(
            x, w, bias, stride=stride, padding=padding, groups=groups,
            cin_banks=cin_banks, kout_banks=kout_banks, h_tile=h_tile,
            w_tile=w_tile, relu=relu, pool=pool, out_scale=out_scale,
            dilation=dilation,
            pipelined=plan.pipelined if plan else False)

    def matmul(self, x, w, bias=None):
        return ops.matmul_ws(x, w, bias)


BACKENDS: Dict[str, Backend] = {"ref": RefBackend(), "pallas": PallasBackend()}


def get_backend(name: str) -> Backend:
    try:
        return BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; have {sorted(BACKENDS)}") from None


def register_backend(backend: Backend) -> None:
    BACKENDS[backend.name] = backend


def unregister_backend(name: str) -> None:
    """Remove a registered backend (no-op if absent).  Tests that register
    sharded backends must clean up so the global registry doesn't leak
    across tests — tests/conftest.py snapshots/restores it as well."""
    BACKENDS.pop(name, None)


@dataclass(frozen=True)
class ConvCoreConfig:
    cin_banks: int = 4            # paper: 4 image BMGs / computing cores (M1)
    kout_banks: int = 4           # paper: 4 PCOREs per core (M2)
    backend: str = "pallas"       # a BACKENDS registry key
    int8: bool = False            # the paper's 8-bit datapath
    wrap8: bool = False           # bit-faithful 8-bit psum wrap (Fig. 6)
    auto_bank: bool = True        # fit spatial tiles + banks to VMEM
    vmem_budget: int = banking.VMEM_BYTES   # per-core VMEM target
    kernel: str = "auto"          # conv variant per layer: "auto" lets the
                                  # perfmodel crossover predictor choose
                                  # conv2d_ws_pipe vs conv2d_ws;
                                  # "pipelined"/"sequential" force one
    calib: Optional[object] = None  # core.calibration.CalibrationTable:
                                  # measured model terms for the planner's
                                  # crossover; None → analytic §5.2 model


class ConvCore:
    """One paper IP core.  Use ``apply_layer`` per convolutional layer."""

    def __init__(self, config: ConvCoreConfig = ConvCoreConfig()):
        self.config = config

    def plan(self, x_shape, w_shape, stride: int = 1, padding="VALID",
             *, pool: bool = False, groups: int = 1,
             out_bytes: Optional[int] = None) -> banking.TilePlan:
        """Joint spatial-tile × channel-bank plan for one layer.  With
        ``auto_bank`` the planner shrinks tiles / grows banks until the
        working set fits ``vmem_budget``; otherwise the whole map runs as
        one tile under the configured banking (the seed dataflow).
        ``groups`` plans the grouped/depthwise working set (per-group
        channel slices, kout banks on group boundaries)."""
        n, h, w_, c = x_shape
        kh, kw, _, k = w_shape
        cfg = self.config
        in_bytes = 1 if cfg.int8 else 4
        # degrade bank counts to the largest legal divisors (C=1 input
        # layers, per-group slices, group-aligned kout banks)
        cb_n, kb_n = banking.grouped_banks(
            c, k, groups, want_cin=cfg.cin_banks, want_kout=cfg.kout_banks)
        return banking.plan_tiles(
            h, w_, c, k, kh, kw, stride=stride, padding=padding, pool=pool,
            groups=groups, in_bytes=in_bytes, acc_bytes=4,
            out_bytes=out_bytes, cin_banks=cb_n, kout_banks=kb_n,
            vmem_budget=cfg.vmem_budget if cfg.auto_bank else None,
            kernel=cfg.kernel, calib=cfg.calib)

    def apply_layer(self, x: jax.Array, w: jax.Array,
                    bias: Optional[jax.Array] = None,
                    out_scale: Optional[jax.Array] = None, *,
                    stride: int = 1, padding="VALID", groups: int = 1,
                    relu: bool = False, pool: bool = False) -> jax.Array:
        """x: [N,H,W,C] ⊛ w: [KH,KW,C/groups,K] (+bias [K]) → [N,OH,OW,K].

        Fused epilogue order: ReLU → 2×2 max-pool → requantize(out_scale).
        """
        cfg = self.config
        plan = self.plan(x.shape, w.shape, stride, padding, pool=pool,
                         groups=groups,
                         out_bytes=1 if out_scale is not None else None)
        if cfg.int8:
            assert x.dtype == jnp.int8 and w.dtype == jnp.int8
        backend = get_backend(cfg.backend)
        return backend.conv(x, w, bias, stride=stride, padding=padding,
                            groups=groups, relu=relu, pool=pool,
                            out_scale=out_scale, wrap8=cfg.wrap8, plan=plan)

    def apply_quantized_layer(self, x_f32: jax.Array, w_f32: jax.Array,
                              bias_f32: Optional[jax.Array] = None, *,
                              stride: int = 1, padding="VALID",
                              relu: bool = False, pool: bool = False):
        """Float-in/float-out convenience: symmetric int8 quantization of
        activations + weights, int32 accumulate, dequantize (the edge-AI
        deployment path the paper targets)."""
        xq = quantize_symmetric(x_f32)
        wq = quantize_symmetric(w_f32)
        bias_i32 = None
        if bias_f32 is not None:
            bias_i32 = jnp.round(
                bias_f32.astype(jnp.float32) / (xq.scale * wq.scale)
            ).astype(jnp.int32)
        core = ConvCore(ConvCoreConfig(
            cin_banks=self.config.cin_banks,
            kout_banks=self.config.kout_banks,
            backend=self.config.backend, int8=True))
        acc = core.apply_layer(xq.values, wq.values, bias_i32,
                               stride=stride, padding=padding, relu=relu,
                               pool=pool)
        return acc.astype(jnp.float32) * (xq.scale * wq.scale)


def paper_workload():
    """The exact §5.2 simulation workload shapes."""
    return {"x": (1, 224, 224, 8), "w": (3, 3, 8, 8), "bias": (8,)}
