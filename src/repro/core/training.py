"""Training subsystem: the float shadow of a ``NetworkPlan``, trained
through the paper-dataflow kernels, with optional quantization-aware
training that drops straight into the int8 deployment pipeline.

The paper's IP core is inference-only, but the int8 weights it consumes
have to come from somewhere: the standard route (Jiang et al. 2025; Guo
et al. 2017 — PAPERS.md) is to train a float "shadow" of the deployed
network, fake-quantizing weights and activations during training so the
float model learns values that survive the 8-bit datapath, then lower the
trained weights with the existing calibration pipeline.  This module is
that route, end-to-end through this repo's stack:

* ``float_forward`` — a differentiable forward of ANY NetworkPlan DAG
  (skip adds, concats, projection shortcuts included) that runs convs and
  GEMMs through the weight-stationary kernels (``ops.conv2d`` /
  ``ops.matmul_ws``), so the backward pass executes the transposed-conv /
  batched-correlation WS kernels of kernels/conv2d_ws_bwd.py — training
  exercises the same dataflow the deployment runs;
* QAT mode — straight-through fake quantization (quantize.fake_quantize)
  of weights (per-tensor or per-output-channel, matching what
  ``quantize_network`` will emit) and of every activation grid point the
  int8 program has (the network input, every non-final conv/dense output
  AFTER its fused epilogue, every merge node);
* ``make_train_step`` — one jitted AdamW step (optim/adamw.py) over the
  plan's parameter list; ``fit`` the minibatch loop on top;
* the round trip: a QAT-trained ``state.params`` feeds directly into
  ``quantize_network`` → ``make_int8_program`` — the acceptance contract
  is that the deployed int8 accuracy stays within a couple points of the
  float shadow.

core/perfmodel.train_report prices the backward pass of all of this on
the §5.2 cycle model (≈2× the forward psums + weight-gradient traffic).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import banking
from repro.core.network import PARAM_KINDS, NetworkPlan
from repro.core.quantize import fake_quant_act, fake_quant_weight
from repro.kernels import ops, ref
from repro.optim.adamw import AdamWConfig, adamw_update


@dataclass(frozen=True)
class TrainConfig:
    """Hyperparameters of one training run.

    ``qat=True`` turns on straight-through fake quantization of weights
    and activations (the deployment-grid shadow); ``per_channel`` must
    match the ``quantize_network(per_channel=...)`` call that will lower
    the trained weights, so training and deployment see the same weight
    grids."""
    adamw: AdamWConfig = field(default_factory=lambda: AdamWConfig(
        peak_lr=3e-3, warmup_steps=20, total_steps=400, weight_decay=1e-4,
        grad_clip_norm=1.0))
    qat: bool = False
    per_channel: bool = False


class TrainState(NamedTuple):
    params: List[Optional[dict]]       # plan.init_params layout
    opt_state: Dict[str, List]         # AdamW m/v mirroring params
    step: jax.Array                    # int32 scalar


def init_train_state(plan: NetworkPlan,
                     rng: np.random.Generator) -> TrainState:
    """He-initialized float parameters + zeroed AdamW moments (m/v mirror
    the parameter tree, ZeRO-style — here simply zeros_like)."""
    params = plan.init_params(rng)
    zeros = lambda: jax.tree.map(jnp.zeros_like, params)  # noqa: E731
    return TrainState(params=params,
                      opt_state={"m": zeros(), "v": zeros()},
                      step=jnp.zeros((), jnp.int32))


def float_forward(plan: NetworkPlan, params: Sequence[Optional[dict]],
                  x: jax.Array, *, qat: bool = False,
                  per_channel: bool = False) -> jax.Array:
    """Differentiable forward of the plan's float shadow through the
    weight-stationary kernels.

    Node semantics mirror ``NetworkPlan.forward_activations`` (the float
    oracle) exactly; the difference is the execution substrate — convs and
    dense GEMMs run ``ops.conv2d`` / ``ops.matmul_ws``, whose custom VJPs
    execute the backward through the same WS dataflow.  In QAT mode every
    int8 grid point of the deployed program is shadowed with a
    straight-through fake-quantize: the input, each non-final parametric
    output (after its fused ReLU/pool epilogue — where the deployed
    requantize sits), and each merge node's shared grid.  The final
    parametric layer stays unquantized on its output, like the deployed
    program's dequantized logits."""
    ins = plan.resolved_inputs()
    geoms = plan.conv_geometries()
    last_param = max((i for i, sp in enumerate(plan.layers)
                      if sp.kind in PARAM_KINDS), default=-1)
    x0 = fake_quant_act(x) if qat else x
    acts: List[jax.Array] = []
    for i, sp in enumerate(plan.layers):
        p = params[i]
        src = [x0 if j < 0 else acts[j] for j in ins[i]]
        h = src[0]
        if sp.kind in ("conv", "conv_transpose"):
            k_, g_ = geoms[i]
            w = fake_quant_weight(p["w"], per_channel) if qat else p["w"]
            cb_n, kb_n = banking.grouped_banks(h.shape[-1], k_, g_)
            op = (ops.conv2d_transpose if sp.kind == "conv_transpose"
                  else ops.conv2d)
            h = op(
                h, w, p["b"], stride=sp.stride, padding=sp.padding,
                groups=g_, dilation=sp.dilation, cin_banks=cb_n,
                kout_banks=kb_n, relu=sp.relu, pool=sp.pool)
            if qat and i != last_param:
                h = fake_quant_act(h)
        elif sp.kind == "pool":
            h = ref.maxpool2d_ref(h, sp.size)
        elif sp.kind == "avgpool":
            h = ref.avgpool2d_ref(h, sp.size)
        elif sp.kind == "globalpool":
            h = ref.global_avgpool_ref(h)
        elif sp.kind == "flatten":
            h = h.reshape(h.shape[0], -1)
        elif sp.kind == "dense":
            w = fake_quant_weight(p["w"], per_channel) if qat else p["w"]
            h = ops.matmul_ws(h, w, p["b"])
            if sp.relu:
                h = jnp.maximum(h, 0)
            if qat and i != last_param:
                h = fake_quant_act(h)
        elif sp.kind == "add":
            h = src[0] + src[1]
            if sp.relu:
                h = jnp.maximum(h, 0)
            if qat:                       # the merge node's shared grid
                h = fake_quant_act(h)
        elif sp.kind == "concat":
            h = jnp.concatenate(src, axis=-1)
            if qat:
                h = fake_quant_act(h)
        else:
            raise ValueError(f"unknown layer kind {sp.kind!r}")
        acts.append(h)
    return acts[-1]


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean cross-entropy of integer labels over the LAST (class) axis,
    computed in f32.  Leading dims are arbitrary: classifier heads pass
    [N, classes] + [N] labels, dense-prediction heads pass per-pixel
    [N, H, W, classes] + [N, H, W] label maps — every pixel is one term
    of the mean."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(
        jnp.take_along_axis(logp, labels[..., None].astype(jnp.int32),
                            axis=-1))


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Fraction of correct argmax predictions over the last axis —
    per-sample for classifiers, per-pixel for segmentation maps."""
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels)
                    .astype(jnp.float32))


def make_train_step(plan: NetworkPlan, cfg: TrainConfig = TrainConfig()):
    """One jitted training step: float-shadow forward (QAT-aware) →
    backward through the WS kernels' custom VJPs → AdamW update.

    Returns ``step(state, x, y) -> (state, metrics)`` with metrics
    {loss, accuracy, lr, grad_norm}."""

    def loss_fn(params, x, y):
        logits = float_forward(plan, params, x, qat=cfg.qat,
                               per_channel=cfg.per_channel)
        return softmax_cross_entropy(logits, y), accuracy(logits, y)

    @jax.jit
    def step(state: TrainState, x: jax.Array, y: jax.Array):
        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, x, y)
        params, opt_state, metrics = adamw_update(
            state.params, grads, state.opt_state, state.step, cfg.adamw)
        new_state = TrainState(params=params, opt_state=opt_state,
                               step=state.step + 1)
        return new_state, {"loss": loss, "accuracy": acc, **metrics}

    return step


def make_eval_step(plan: NetworkPlan, cfg: TrainConfig = TrainConfig()):
    """Jitted (loss, accuracy) of the float shadow (QAT-aware, so eval
    sees the same fake-quantized forward training optimizes)."""

    @jax.jit
    def evaluate(params, x, y):
        logits = float_forward(plan, params, x, qat=cfg.qat,
                               per_channel=cfg.per_channel)
        return softmax_cross_entropy(logits, y), accuracy(logits, y)

    return evaluate


def fit(plan: NetworkPlan, x: jax.Array, y: jax.Array, *, steps: int,
        batch: int = 32, cfg: TrainConfig = TrainConfig(), seed: int = 0,
        state: Optional[TrainState] = None
        ) -> Tuple[TrainState, List[dict]]:
    """Minibatch training loop (uniform sampling with replacement).
    Returns the final state and the per-step metric history."""
    import time

    from repro import obs
    rng = np.random.default_rng(seed)
    if state is None:
        state = init_train_state(plan, rng)
    step_fn = make_train_step(plan, cfg)
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    history: List[dict] = []
    n = x.shape[0]
    # telemetry: monotonic per-step wall time into the shared histogram
    # type (p50/p90/p99), images/sec as a gauge — observation is cheap
    # enough to keep on unconditionally; spans only when obs is enabled
    step_us = obs.metrics.histogram(f"train.step_us.{plan.name}")
    ips = obs.metrics.gauge(f"train.images_per_s.{plan.name}")
    with obs.span("train.fit", network=plan.name, steps=steps, batch=batch,
                  qat=cfg.qat):
        for i in range(steps):
            idx = rng.integers(0, n, size=batch)
            with obs.span("train.step", step=i):
                t0 = time.perf_counter()
                state, metrics = step_fn(state, x[idx], y[idx])
                metrics = {k: float(v) for k, v in metrics.items()}
                dt = time.perf_counter() - t0
            step_us.observe(dt * 1e6)
            if dt > 0:
                ips.set(batch / dt)
            history.append(metrics)
    return state, history


def synthetic_digits(rng: np.random.Generator, n: int,
                     input_shape: Tuple[int, int, int] = (12, 12, 1),
                     classes: int = 10, noise: float = 0.35,
                     template_seed: int = 0
                     ) -> Tuple[jax.Array, jax.Array]:
    """A synthetic "digits" classification set: one low-frequency template
    per class (a coarse random pattern upsampled 3×) plus per-sample
    noise.  Linearly separable but not trivially so — a tiny LeNet fits
    it in a few dozen steps, which is exactly what the training smoke
    tests and the QAT round-trip acceptance need.

    The class templates come from ``template_seed`` (NOT from ``rng``), so
    successive calls with the same seed draw train/eval sets from the
    same task; ``rng`` drives only the labels and the per-sample noise."""
    h, w, c = input_shape
    trng = np.random.default_rng(template_seed)
    base = trng.normal(size=(classes, max(1, -(-h // 3)),
                             max(1, -(-w // 3)), c))
    templates = np.repeat(np.repeat(base, 3, axis=1), 3, axis=2)[:, :h, :w]
    y = rng.integers(0, classes, size=n)
    x = templates[y] + noise * rng.normal(size=(n, h, w, c))
    return (jnp.asarray(x, jnp.float32),
            jnp.asarray(y, jnp.int32))


def synthetic_segmentation(rng: np.random.Generator, n: int,
                           input_shape: Tuple[int, int, int] = (16, 16, 4),
                           classes: int = 3, noise: float = 0.3,
                           template_seed: int = 0
                           ) -> Tuple[jax.Array, jax.Array]:
    """A synthetic dense-prediction set: each image is a per-pixel class
    map (coarse random label blobs upsampled 4×, so regions are several
    pixels wide) rendered through one channel signature per class, plus
    noise.  The label is the [H, W] class map itself — what the
    ``unet_small`` / ``dilated_context`` heads must reproduce per pixel.
    A few conv layers separate it easily, which is what the segmentation
    training smokes and the QAT round-trip acceptance need.

    Like :func:`synthetic_digits`, the class signatures and blob layout
    statistics come from ``template_seed`` so train/eval calls draw from
    the same task; ``rng`` drives the per-sample blobs and noise."""
    h, w, c = input_shape
    trng = np.random.default_rng(template_seed)
    sig = trng.normal(size=(classes, c))              # channel signature
    coarse = rng.integers(0, classes,
                          size=(n, max(1, -(-h // 4)), max(1, -(-w // 4))))
    y = np.repeat(np.repeat(coarse, 4, axis=1), 4, axis=2)[:, :h, :w]
    x = sig[y] + noise * rng.normal(size=(n, h, w, c))
    return (jnp.asarray(x, jnp.float32),
            jnp.asarray(y, jnp.int32))
