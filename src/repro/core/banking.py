"""BRAM-bank ↔ VMEM-block mapping math (paper §4.1 → TPU v5e).

The paper stores one quarter of the channels per BRAM (4 image BMGs) and a
4×4 grid of kernel BMGs.  On TPU the analogous resource is VMEM: a grid
step's working set is (padded image block + weight block + accumulator +
epilogue output block) × pipeline double-buffering; this module sizes bank
counts so the working set fits the per-core VMEM budget, and enforces the
paper's divisible-by-4 invariant.

Stride / padding awareness: the image block is the *padded* map (the FPGA
writes zero margins into the image BRAMs) and the accumulator block is the
*strided* conv output, so plans stay correct for SAME / stride-2 / pooled
layers.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.kernels.ref import conv_out_shape, normalize_padding

VMEM_BYTES_V5E = 128 * 1024 * 1024   # ~128 MiB per TensorCore


@dataclass(frozen=True)
class BankPlan:
    cin_banks: int
    kout_banks: int
    image_block_bytes: int
    weight_block_bytes: int
    output_block_bytes: int
    stride: int = 1
    out_h: int = 0                    # conv output (pre-pool) spatial shape
    out_w: int = 0

    @property
    def working_set_bytes(self) -> int:
        # ×2: Pallas double-buffers input blocks (load/compute pipeline, M4)
        return (2 * (self.image_block_bytes + self.weight_block_bytes)
                + self.output_block_bytes)

    @property
    def fits_vmem(self) -> bool:
        return self.working_set_bytes <= VMEM_BYTES_V5E


def plan_banks(h: int, w: int, c: int, k: int, kh: int = 3, kw: int = 3,
               in_bytes: int = 1, acc_bytes: int = 4,
               cin_banks: int = 4, kout_banks: int = 4,
               stride: int = 1, padding="VALID",
               vmem_budget: int = VMEM_BYTES_V5E) -> BankPlan:
    """Start from the paper's 4×4 banking; double bank counts until the
    working set fits VMEM (each doubling halves the per-bank block)."""
    assert c % cin_banks == 0 and k % kout_banks == 0, (
        "divisible-by-4 invariant (paper §4.1)")
    (pt, pb), (pl_, pr) = normalize_padding(padding, kh, kw, stride, h, w)
    hp, wp = h + pt + pb, w + pl_ + pr
    oh, ow = conv_out_shape(h, w, kh, kw, stride, padding)
    while True:
        cb, kb = c // cin_banks, k // kout_banks
        plan = BankPlan(
            cin_banks=cin_banks, kout_banks=kout_banks,
            image_block_bytes=hp * wp * cb * in_bytes,
            weight_block_bytes=kh * kw * cb * kb * in_bytes,
            output_block_bytes=oh * ow * kb * acc_bytes,
            stride=stride, out_h=oh, out_w=ow,
        )
        if plan.fits_vmem or (cb == 1 and kb == 1):
            return plan
        if plan.image_block_bytes >= plan.output_block_bytes and cb > 1 \
                and c % (cin_banks * 2) == 0:
            cin_banks *= 2
        elif kb > 1 and k % (kout_banks * 2) == 0:
            kout_banks *= 2
        elif cb > 1 and c % (cin_banks * 2) == 0:
            cin_banks *= 2
        else:
            return plan


def divisor_banks(dim: int, want: int) -> int:
    """Largest bank count ≤ ``want`` that divides ``dim`` — how the paper's
    divisible-by-4 invariant degrades for awkward channel counts (e.g. the
    C=1 input layer of a grayscale network runs on a single image BMG)."""
    b = max(1, min(want, dim))
    while dim % b:
        b -= 1
    return b
