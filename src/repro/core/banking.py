"""BRAM-bank ↔ VMEM-block mapping math (paper §4.1 → TPU v5e), promoted to
a full spatial-tile planner.

The paper stores one quarter of the channels per BRAM (4 image BMGs) and a
4×4 grid of kernel BMGs; crucially its image BRAMs are *fixed-size* — maps
stream through a bounded window, they are never required to fit whole.  On
TPU the analogous resource is VMEM: a grid step's working set is

    2 × (halo'd image block + weight block + epilogue output block)
      + accumulator scratch

— the ×2 is Pallas's load/compute pipeline double-buffering (M4) of the
DMA'd blocks; the accumulator scratch is a single persistent VMEM buffer
revisited across the cin sweep, so it is *not* double-buffered, and the
epilogue output block is the post-pool block in the output dtype (int8
when the epilogue requantizes) — counting those two separately is what
keeps ``fits_vmem`` truthful.

``plan_tiles`` jointly chooses (h_tile, w_tile, cin_banks, kout_banks):
starting from the paper's 4×4 banking and the whole map as one tile, it
greedily applies whichever legal move (halve a spatial tile dimension,
double a bank count) shrinks the working set most, until the plan fits
the VMEM budget or nothing can shrink further.  Tile-size halving keeps
tiles pool-aligned (even extents when the 2×2 epilogue pool is fused) so
pool windows never straddle tile edges.

Halo math: an ``h_tile × w_tile`` conv-output tile at stride s consumes a
``((h_tile−1)·s + kh) × ((w_tile−1)·s + kw)`` halo'd input window;
adjacent windows overlap by ``k − s`` rows/columns, which are re-read
from HBM per tile (the FPGA re-DMAs its BRAM window boundaries the same
way).  core/perfmodel.tile_traffic prices that re-read.

Stride / padding awareness: the image window lives in the *padded* map
(the FPGA writes zero margins into the image BRAMs) and the accumulator
block is the *strided* conv output, so plans stay correct for SAME /
stride-2 / pooled layers.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

from repro.kernels.ref import (check_groups, conv_out_shape, dilated_extent,
                               grouped_banks, halo_window, normalize_padding)
from repro.kernels.ref import divisor_banks as _ref_divisor_banks

VMEM_BYTES = 16 * 1024 * 1024        # realistic per-core VMEM (~16 MiB)
VMEM_BYTES_V5E = 128 * 1024 * 1024   # legacy generous budget (BankPlan)


@dataclass(frozen=True)
class BankPlan:
    cin_banks: int
    kout_banks: int
    image_block_bytes: int
    weight_block_bytes: int
    output_block_bytes: int           # epilogue output block (out dtype)
    stride: int = 1
    out_h: int = 0                    # conv output (pre-pool) spatial shape
    out_w: int = 0
    acc_block_bytes: int = 0          # accumulator scratch (acc dtype)
    budget: int = VMEM_BYTES_V5E      # the budget the plan was sized for

    @property
    def working_set_bytes(self) -> int:
        # ×2: Pallas double-buffers the DMA'd blocks (load/compute
        # pipeline, M4); the accumulator scratch is a single persistent
        # buffer — counted once, separately from the epilogue output.
        return (2 * (self.image_block_bytes + self.weight_block_bytes
                     + self.output_block_bytes) + self.acc_block_bytes)

    @property
    def fits_vmem(self) -> bool:
        return self.working_set_bytes <= self.budget


def plan_banks(h: int, w: int, c: int, k: int, kh: int = 3, kw: int = 3,
               in_bytes: int = 1, acc_bytes: int = 4,
               out_bytes: Optional[int] = None,
               cin_banks: int = 4, kout_banks: int = 4,
               stride: int = 1, padding="VALID",
               vmem_budget: int = VMEM_BYTES_V5E) -> BankPlan:
    """Channel-bank-only legacy planner: start from the paper's 4×4
    banking; double bank counts until the working set fits VMEM (each
    doubling halves the per-bank block).  ``plan_tiles`` supersedes this
    with joint spatial/channel planning."""
    assert c % cin_banks == 0 and k % kout_banks == 0, (
        "divisible-by-4 invariant (paper §4.1)")
    out_bytes = acc_bytes if out_bytes is None else out_bytes
    (pt, pb), (pl_, pr) = normalize_padding(padding, kh, kw, stride, h, w)
    hp, wp = h + pt + pb, w + pl_ + pr
    oh, ow = conv_out_shape(h, w, kh, kw, stride, padding)
    while True:
        cb, kb = c // cin_banks, k // kout_banks
        plan = BankPlan(
            cin_banks=cin_banks, kout_banks=kout_banks,
            image_block_bytes=hp * wp * cb * in_bytes,
            weight_block_bytes=kh * kw * cb * kb * in_bytes,
            output_block_bytes=oh * ow * kb * out_bytes,
            stride=stride, out_h=oh, out_w=ow,
            acc_block_bytes=oh * ow * kb * acc_bytes,
            budget=vmem_budget,
        )
        if plan.fits_vmem or (cb == 1 and kb == 1):
            return plan
        if plan.image_block_bytes >= plan.acc_block_bytes and cb > 1 \
                and c % (cin_banks * 2) == 0:
            cin_banks *= 2
        elif kb > 1 and k % (kout_banks * 2) == 0:
            kout_banks *= 2
        elif cb > 1 and c % (cin_banks * 2) == 0:
            cin_banks *= 2
        else:
            return plan


@dataclass(frozen=True)
class TilePlan:
    """A joint (spatial tile × channel bank) decomposition of one conv
    layer for the tiled conv2d_ws kernel.

    ``h_tile``/``w_tile`` are conv-output tile extents (pre-pool pixels);
    ``in_h_tile``/``in_w_tile`` the halo'd input windows they consume.
    Byte fields are per-grid-step VMEM blocks; see the module docstring
    for the working-set accounting."""
    cin_banks: int
    kout_banks: int
    h_tile: int
    w_tile: int
    n_h_tiles: int
    n_w_tiles: int
    in_h_tile: int                    # (h_tile-1)·stride + dilation·(kh-1)+1
    in_w_tile: int
    image_block_bytes: int            # halo'd input window × cb × in_bytes
    weight_block_bytes: int
    acc_block_bytes: int              # accumulator scratch (acc dtype)
    output_block_bytes: int           # epilogue output block (out dtype)
    stride: int = 1
    out_h: int = 0                    # whole-map conv output (pool-floored)
    out_w: int = 0
    pool: bool = False
    in_bytes: int = 1
    budget: int = VMEM_BYTES
    groups: int = 1                   # grouped conv: kout banks stay inside
                                      # group boundaries; image blocks are
                                      # the per-group C/groups slice
    pipelined: bool = False           # run this layer on conv2d_ws_pipe
                                      # (explicit ping-pong DMA) instead of
                                      # the implicitly pipelined conv2d_ws

    @property
    def working_set_bytes(self) -> int:
        # The ×2 below IS the ping-pong pair: Pallas's implicit pipeline
        # double-buffers the DMA'd blocks, and conv2d_ws_pipe materializes
        # the same two slots as explicit VMEM scratch — so the working set
        # is identical for both kernel variants and ``pipelined`` never
        # changes whether a plan fits.
        return (2 * (self.image_block_bytes + self.weight_block_bytes
                     + self.output_block_bytes) + self.acc_block_bytes)

    @property
    def fits_vmem(self) -> bool:
        return self.working_set_bytes <= self.budget

    @property
    def n_tiles(self) -> int:
        return self.n_h_tiles * self.n_w_tiles

    @property
    def tiled(self) -> bool:
        return self.n_tiles > 1

    @property
    def halo_read_factor(self) -> float:
        """Input bytes DMA'd with tiling ÷ the whole-map input bytes for
        one full kout sweep — ≥ 1; the excess is halo re-reads (plus the
        zero-extension of the trailing partial tiles)."""
        kh = self.in_h_tile - (self.h_tile - 1) * self.stride
        kw = self.in_w_tile - (self.w_tile - 1) * self.stride
        whole = (halo_window(self.out_h, self.stride, kh)
                 * halo_window(self.out_w, self.stride, kw))
        tiled = self.n_tiles * self.in_h_tile * self.in_w_tile
        return tiled / whole if whole else 1.0


def _align_tile(v: int, pool: bool) -> int:
    if pool:
        return max(2, -(-v // 2) * 2)
    return max(1, v)


def plan_tiles(h: int, w: int, c: int, k: int, kh: int = 3, kw: int = 3, *,
               stride: int = 1, padding="VALID", pool: bool = False,
               groups: int = 1, dilation: int = 1, in_bytes: int = 1,
               acc_bytes: int = 4, out_bytes: Optional[int] = None,
               cin_banks: int = 4, kout_banks: int = 4,
               vmem_budget: Optional[int] = VMEM_BYTES,
               kernel: str = "auto", calib=None) -> TilePlan:
    """Jointly choose (h_tile, w_tile, cin_banks, kout_banks) so the true
    per-grid-step working set fits ``vmem_budget``.

    Greedy descent from (whole map, requested banks): each step applies
    the legal move — halve h_tile, halve w_tile (kept pool-aligned),
    double cin_banks, double kout_banks — that shrinks the working set
    most; stops when the plan fits or no move shrinks it.  With
    ``vmem_budget=None`` no fitting is attempted (whole-map single tile —
    the seed dataflow).

    ``groups`` plans the grouped/depthwise working set: image and weight
    blocks carry the per-group C/groups channel slice (a kout bank only
    ever DMAs its own group's channels), cin-bank doubling is bounded by
    that slice, and kout-bank doubling stays on group boundaries.
    Depthwise layers therefore bottom out at one-channel blocks whose
    working set is pure DMA — the planner's view of why their arithmetic
    intensity sits on the DMA roofline (perfmodel prices it).

    ``dilation`` widens the halo'd input windows to the dilated kernel
    extent ``dilation·(k−1)+1`` (weight blocks are unchanged — the taps
    spread, they do not multiply); a layer whose dilated extent exceeds
    the padded input raises the same shaped ``ValueError`` as the kernel
    itself, at plan time.

    ``out_bytes`` is the epilogue output element size (1 when the fused
    requantize writes int8; defaults to ``acc_bytes``).

    ``kernel`` selects the conv kernel variant the plan will run on:
    ``"sequential"`` (conv2d_ws), ``"pipelined"`` (conv2d_ws_pipe, the
    explicit ping-pong DMA kernel), or ``"auto"`` — consult
    ``perfmodel.pipeline_estimate`` and set ``TilePlan.pipelined`` only
    where the overlap model says it wins (tiny layers lose to the
    per-slab protocol overhead and stay sequential).  The choice never
    affects VMEM fitting: both variants hold the same two buffered
    copies of each block (see ``working_set_bytes``).

    ``calib`` (a ``core.calibration.CalibrationTable``) makes the
    ``kernel="auto"`` crossover consult the MEASUREMENT-calibrated model
    instead of the analytic one; the tile/bank descent itself is VMEM
    geometry and does not depend on it.  ``core/autotune.py`` supersedes
    this greedy descent with a full search of the candidate space — this
    function remains the fallback when no tuner/table is present."""
    if kernel not in ("auto", "pipelined", "sequential"):
        raise ValueError(f"kernel must be auto|pipelined|sequential, "
                         f"got {kernel!r}")
    check_groups(c, k, groups)
    cgrp = c // groups
    assert cgrp % cin_banks == 0 and k % kout_banks == 0 \
        and kout_banks % groups == 0, (
        "banking invariant: C/groups and K divisible by the bank counts, "
        "kout banks on group boundaries", c, k, groups, cin_banks,
        kout_banks)
    out_bytes = acc_bytes if out_bytes is None else out_bytes
    (pt, pb), (pl_, pr) = normalize_padding(padding, kh, kw, stride, h, w,
                                            dilation)
    if (dilated_extent(kh, dilation) > h + pt + pb
            or dilated_extent(kw, dilation) > w + pl_ + pr):
        # same error (and wording) as conv2d_ws.setup_conv — an
        # over-dilated layer must fail at PLAN time with the geometry
        # spelled out, not produce an out-of-range halo'd BlockSpec
        raise ValueError(
            f"dilated kernel extent "
            f"{dilated_extent(kh, dilation)}×{dilated_extent(kw, dilation)} "
            f"(kernel {kh}×{kw}, dilation={dilation}) exceeds the padded "
            f"input {h + pt + pb}×{w + pl_ + pr}")
    oh, ow = conv_out_shape(h, w, kh, kw, stride, padding, dilation)
    if pool:
        # agree with the kernel: conv2d_ws rejects fused pooling of conv
        # outputs smaller than the 2×2 window, so the planner must not
        # invent a 2×2 map (and its phantom tile traffic) for such layers
        if oh < 2 or ow < 2:
            raise ValueError(
                f"2×2 pool needs a ≥2×2 conv output, got {oh}×{ow}")
        oh, ow = (oh // 2) * 2, (ow // 2) * 2
    budget = VMEM_BYTES if vmem_budget is None else vmem_budget

    def build(th: int, tw: int, cbn: int, kbn: int) -> TilePlan:
        cb, kb = cgrp // cbn, k // kbn
        in_th = halo_window(th, stride, kh, dilation)
        in_tw = halo_window(tw, stride, kw, dilation)
        pth, ptw = (th // 2, tw // 2) if pool else (th, tw)
        return TilePlan(
            cin_banks=cbn, kout_banks=kbn, h_tile=th, w_tile=tw,
            n_h_tiles=-(-oh // th), n_w_tiles=-(-ow // tw),
            in_h_tile=in_th, in_w_tile=in_tw,
            image_block_bytes=in_th * in_tw * cb * in_bytes,
            weight_block_bytes=kh * kw * cb * kb * in_bytes,
            acc_block_bytes=th * tw * kb * acc_bytes,
            output_block_bytes=pth * ptw * kb * out_bytes,
            stride=stride, out_h=oh, out_w=ow, pool=pool,
            in_bytes=in_bytes, budget=budget, groups=groups)

    def choose_kernel(plan: TilePlan) -> TilePlan:
        if kernel == "sequential":
            return plan
        if kernel == "pipelined":
            return replace(plan, pipelined=True)
        from repro.core import perfmodel
        psums = perfmodel.psum_count(h, w, c, k, kh, kw, stride=stride,
                                     padding=padding, groups=groups,
                                     dilation=dilation)
        est = perfmodel.pipeline_estimate(plan, psums, calib=calib)
        return replace(plan, pipelined=est["profitable"])

    state = (oh, ow, cin_banks, kout_banks)
    plan = build(*state)
    if vmem_budget is None:
        return choose_kernel(plan)
    min_tile = 2 if pool else 1
    while not plan.fits_vmem:
        th, tw, cbn, kbn = state
        moves = []
        if _align_tile(-(-th // 2), pool) < th and th > min_tile:
            moves.append((_align_tile(-(-th // 2), pool), tw, cbn, kbn))
        if _align_tile(-(-tw // 2), pool) < tw and tw > min_tile:
            moves.append((th, _align_tile(-(-tw // 2), pool), cbn, kbn))
        if cgrp // cbn > 1 and cgrp % (cbn * 2) == 0:
            moves.append((th, tw, cbn * 2, kbn))
        # kout doubling keeps banks on group boundaries automatically
        # (2·(m·groups) is still a multiple of groups)
        if k // kbn > 1 and k % (kbn * 2) == 0:
            moves.append((th, tw, cbn, kbn * 2))
        candidates = [(build(*m), m) for m in moves]
        candidates = [(p, m) for p, m in candidates
                      if p.working_set_bytes < plan.working_set_bytes]
        if not candidates:
            # nothing shrinks further: best effort
            return choose_kernel(plan)
        plan, state = min(candidates,
                          key=lambda pm: pm[0].working_set_bytes)
    return choose_kernel(plan)


def divisor_banks(dim: int, want: int) -> int:
    """Largest bank count ≤ ``want`` that divides ``dim`` — how the paper's
    divisible-by-4 invariant degrades for awkward channel counts (e.g. the
    C=1 input layer of a grayscale network runs on a single image BMG).
    Delegates to the shared definition in kernels/ref.py; ``grouped_banks``
    (re-exported here) is its grouped-conv generalization."""
    return _ref_divisor_banks(dim, want)
