"""BRAM-bank ↔ VMEM-block mapping math (paper §4.1 → TPU v5e).

The paper stores one quarter of the channels per BRAM (4 image BMGs) and a
4×4 grid of kernel BMGs.  On TPU the analogous resource is VMEM: a grid
step's working set is (image block + weight block + output block) × 2 for
the double-buffered pipeline; this module sizes bank counts so the working
set fits the per-core VMEM budget, and enforces the paper's
divisible-by-4 invariant.
"""

from __future__ import annotations

from dataclasses import dataclass

VMEM_BYTES_V5E = 128 * 1024 * 1024   # ~128 MiB per TensorCore


@dataclass(frozen=True)
class BankPlan:
    cin_banks: int
    kout_banks: int
    image_block_bytes: int
    weight_block_bytes: int
    output_block_bytes: int

    @property
    def working_set_bytes(self) -> int:
        # ×2: Pallas double-buffers input blocks (load/compute pipeline, M4)
        return (2 * (self.image_block_bytes + self.weight_block_bytes)
                + self.output_block_bytes)

    @property
    def fits_vmem(self) -> bool:
        return self.working_set_bytes <= VMEM_BYTES_V5E


def plan_banks(h: int, w: int, c: int, k: int, kh: int = 3, kw: int = 3,
               in_bytes: int = 1, acc_bytes: int = 4,
               cin_banks: int = 4, kout_banks: int = 4,
               vmem_budget: int = VMEM_BYTES_V5E) -> BankPlan:
    """Start from the paper's 4×4 banking; double bank counts until the
    working set fits VMEM (each doubling halves the per-bank block)."""
    assert c % cin_banks == 0 and k % kout_banks == 0, (
        "divisible-by-4 invariant (paper §4.1)")
    oh, ow = h - kh + 1, w - kw + 1
    while True:
        cb, kb = c // cin_banks, k // kout_banks
        plan = BankPlan(
            cin_banks=cin_banks, kout_banks=kout_banks,
            image_block_bytes=h * w * cb * in_bytes,
            weight_block_bytes=kh * kw * cb * kb * in_bytes,
            output_block_bytes=oh * ow * kb * acc_bytes,
        )
        if plan.fits_vmem or (cb == 1 and kb == 1):
            return plan
        if plan.image_block_bytes >= plan.output_block_bytes and cb > 1 \
                and c % (cin_banks * 2) == 0:
            cin_banks *= 2
        elif kb > 1 and k % (kout_banks * 2) == 0:
            kout_banks *= 2
        elif cb > 1 and c % (cin_banks * 2) == 0:
            cin_banks *= 2
        else:
            return plan
