"""Network-level executor: whole CNNs through the layer-at-a-time IP core.

The paper's IP core "can process a convolutional layer at a time" (§4.2);
running a network on the FPGA means the host sequences layer passes, with
the output BRAMs of one pass becoming the image BRAMs of the next.  This
module is that sequencer as a compiler: a ``NetworkPlan`` (a straight-line
graph of conv / pool / flatten / dense ``LayerSpec``s) is turned into one
jitted multi-layer program over a ``Backend`` (core/convcore.py).

Layer-to-layer int8 chaining (the production path): ``quantize_network``
calibrates per-layer activation scales from a float forward pass, quantizes
weights/biases (per-tensor or per-output-channel — ``per_channel=True``
yields [K] scale vectors the fused epilogue broadcasts), and computes the
*requantization scale* of each layer (``s_in·s_w / s_out`` —
core/quantize.requant_scale).  The compiled int8 program then keeps every
inter-layer feature map in int8: the fused kernel epilogue (ReLU → pool →
requantize) writes the next layer's int8 input directly, so nothing
round-trips HBM in int32 — the FPGA post-processing idiom at network scale.

Spatial tiling: ``make_int8_program`` computes a per-layer
``banking.TilePlan`` (``NetworkPlan.tile_plans``), so conv layers whose
whole-map working set exceeds the VMEM budget stream through halo'd H/W
tiles — VGG-small at 64×64+, the ImageNet-scale ``vgg_imagenet`` demo,
and the segmentation-scale ``large_map`` plan all compile unchanged.

Paper → TPU mapping of the replicated-IP-core mode (full-board 4.48 GOPS):
core/scheduler.py shards a compiled program across devices (one IP core ↔
one device) or vmapped virtual cores; core/perfmodel.network_report sums
the §5.2 cycle model over the plan's layers, including the 20-core
configuration.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import banking, perfmodel
from repro.core.convcore import ConvCoreConfig, get_backend
from repro.core.quantize import (act_scale_from_calibration, quantize_symmetric,
                                 requant_scale)
from repro.kernels import ref

# ---------------------------------------------------------------------------
# Layer graph
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LayerSpec:
    """One layer of a straight-line CNN.

    kind: "conv" | "pool" | "avgpool" | "globalpool" | "flatten" |
    "dense".  ``pool=True`` on a conv layer fuses the 2×2/2 max-pool into
    the kernel epilogue (one HBM round-trip); standalone "pool" /
    "avgpool" layers are the unfused fallbacks, and "globalpool" is the
    global average pool ([N,H,W,C] → [N,C]) that lets classifier heads
    skip the flatten + giant-dense pattern."""
    kind: str
    features: int = 0                      # conv: K; dense: output dim
    kernel: Tuple[int, int] = (3, 3)
    stride: int = 1
    padding: ref.Padding = "SAME"
    relu: bool = False
    pool: bool = False                     # conv only: fused 2×2 max-pool
    size: int = 2                          # "pool"/"avgpool": window/stride


def conv(features: int, kernel: int = 3, stride: int = 1,
         padding: ref.Padding = "SAME", relu: bool = True,
         pool: bool = False) -> LayerSpec:
    return LayerSpec("conv", features=features, kernel=(kernel, kernel),
                     stride=stride, padding=padding, relu=relu, pool=pool)


def maxpool(size: int = 2) -> LayerSpec:
    return LayerSpec("pool", size=size)


def avgpool(size: int = 2) -> LayerSpec:
    return LayerSpec("avgpool", size=size)


def global_pool() -> LayerSpec:
    return LayerSpec("globalpool")


def flatten() -> LayerSpec:
    return LayerSpec("flatten")


def dense(features: int, relu: bool = False) -> LayerSpec:
    return LayerSpec("dense", features=features, relu=relu)


@dataclass(frozen=True)
class NetworkPlan:
    """A straight-line CNN over [H, W, C] inputs."""
    name: str
    input_shape: Tuple[int, int, int]          # (H, W, C)
    layers: Tuple[LayerSpec, ...]

    def activation_shapes(self) -> List[Tuple[int, ...]]:
        """Per-layer output shapes (without the batch dim)."""
        h, w, c = self.input_shape
        flat: Optional[int] = None
        out: List[Tuple[int, ...]] = []
        for sp in self.layers:
            if sp.kind == "conv":
                assert flat is None, "conv after flatten"
                kh, kw = sp.kernel
                h, w = ref.conv_out_shape(h, w, kh, kw, sp.stride,
                                          sp.padding)
                if sp.pool:
                    h, w = h // 2, w // 2
                c = sp.features
                out.append((h, w, c))
            elif sp.kind in ("pool", "avgpool"):
                h, w = (h - sp.size) // sp.size + 1, \
                       (w - sp.size) // sp.size + 1
                out.append((h, w, c))
            elif sp.kind == "globalpool":
                flat = c
                out.append((flat,))
            elif sp.kind == "flatten":
                flat = h * w * c
                out.append((flat,))
            elif sp.kind == "dense":
                assert flat is not None, "dense before flatten/globalpool"
                flat = sp.features
                out.append((flat,))
            else:
                raise ValueError(f"unknown layer kind {sp.kind!r}")
        return out

    def param_shapes(self) -> List[Optional[dict]]:
        """Per-layer {"w": ..., "b": ...} shapes (None for pool/flatten)."""
        h, w, c = self.input_shape
        shapes: List[Optional[dict]] = []
        in_c: int = c
        in_flat: Optional[int] = None
        for sp, out in zip(self.layers, self.activation_shapes()):
            if sp.kind == "conv":
                kh, kw = sp.kernel
                shapes.append({"w": (kh, kw, in_c, sp.features),
                               "b": (sp.features,)})
                in_c = sp.features
            elif sp.kind == "dense":
                shapes.append({"w": (in_flat, sp.features),
                               "b": (sp.features,)})
            else:
                shapes.append(None)
            in_flat = out[0] if len(out) == 1 else None
        return shapes

    def init_params(self, rng: np.random.Generator) -> List[Optional[dict]]:
        """He-initialized float32 parameters."""
        params: List[Optional[dict]] = []
        for shp in self.param_shapes():
            if shp is None:
                params.append(None)
                continue
            fan_in = int(np.prod(shp["w"][:-1]))
            std = math.sqrt(2.0 / fan_in)
            params.append({
                "w": jnp.asarray(rng.normal(size=shp["w"]) * std,
                                 jnp.float32),
                "b": jnp.asarray(rng.normal(size=shp["b"]) * 0.05,
                                 jnp.float32)})
        return params

    def psum_table(self) -> List[Tuple[str, int]]:
        """Per-layer psum counts in the paper's accounting (conv: output
        pixels × kernels × input channels; dense: a 1×1-conv GEMM, in×out;
        pool/flatten: free — the fused epilogue absorbs post-processing)."""
        h, w, c = self.input_shape
        flat: Optional[int] = None
        rows: List[Tuple[str, int]] = []
        for i, sp in enumerate(self.layers):
            if sp.kind == "conv":
                kh, kw = sp.kernel
                rows.append((f"conv{i}", perfmodel.psum_count(
                    h, w, c, sp.features, kh, kw, sp.stride, sp.padding)))
                h, w = ref.conv_out_shape(h, w, kh, kw, sp.stride,
                                          sp.padding)
                if sp.pool:
                    h, w = h // 2, w // 2
                c = sp.features
            elif sp.kind in ("pool", "avgpool"):
                h, w = (h - sp.size) // sp.size + 1, \
                       (w - sp.size) // sp.size + 1
                rows.append((f"{sp.kind}{i}", 0))
            elif sp.kind == "globalpool":
                flat = c
                rows.append((f"globalpool{i}", 0))
            elif sp.kind == "flatten":
                flat = h * w * c
                rows.append((f"flatten{i}", 0))
            elif sp.kind == "dense":
                rows.append((f"dense{i}", flat * sp.features))
                flat = sp.features
        return rows

    def tile_plans(self, cin_banks: int = 4, kout_banks: int = 4,
                   in_bytes: int = 1,
                   vmem_budget: Optional[int] = banking.VMEM_BYTES
                   ) -> List[Optional[banking.TilePlan]]:
        """Per-layer spatial-tile × channel-bank plans (None for layers
        without a conv).  int8-datapath sizes by default; the final
        parametric layer (no fused requantize) keeps a 4-byte epilogue
        output, every other conv writes int8.  ``vmem_budget=None``
        disables fitting (whole-map tiles — the seed dataflow)."""
        param_kinds = ("conv", "dense")
        last_param = max((i for i, sp in enumerate(self.layers)
                          if sp.kind in param_kinds), default=-1)
        h, w, c = self.input_shape
        plans: List[Optional[banking.TilePlan]] = []
        for i, (sp, out) in enumerate(zip(self.layers,
                                          self.activation_shapes())):
            if sp.kind == "conv":
                kh, kw = sp.kernel
                plans.append(banking.plan_tiles(
                    h, w, c, sp.features, kh, kw, stride=sp.stride,
                    padding=sp.padding, pool=sp.pool, in_bytes=in_bytes,
                    out_bytes=4 if i == last_param else in_bytes,
                    cin_banks=banking.divisor_banks(c, cin_banks),
                    kout_banks=banking.divisor_banks(sp.features,
                                                     kout_banks),
                    vmem_budget=vmem_budget))
            else:
                plans.append(None)
            if len(out) == 3:
                h, w, c = out
        return plans

    def perf_report(self, cfg: perfmodel.IPCoreConfig =
                    perfmodel.IPCoreConfig(),
                    tile_plans: Optional[Sequence] = None) -> dict:
        """The §5.2 cycle model summed over the network, including the
        20-core full-board configuration (perfmodel.network_report).
        With ``tile_plans`` (e.g. from :meth:`tile_plans`) the model also
        prices tile revisits and halo re-reads against the DMA interface,
        keeping large-map GOPS honest."""
        return perfmodel.network_report(self.psum_table(), cfg,
                                        tile_plans=tile_plans)

    def forward_activations(self, params: Sequence[Optional[dict]],
                            x: jax.Array):
        """Yield (index, spec, layer_params, activation-after-layer)
        through the float oracle — the single definition of layer
        semantics, shared by ``apply_ref`` and ``quantize_network``."""
        for i, (sp, p) in enumerate(zip(self.layers, params)):
            if sp.kind == "conv":
                x = ref.conv2d_epilogue_ref(
                    x, p["w"], p["b"], stride=sp.stride, padding=sp.padding,
                    relu=sp.relu, pool=sp.pool)
            elif sp.kind == "pool":
                x = ref.maxpool2d_ref(x, sp.size)
            elif sp.kind == "avgpool":
                x = ref.avgpool2d_ref(x, sp.size)
            elif sp.kind == "globalpool":
                x = ref.global_avgpool_ref(x)
            elif sp.kind == "flatten":
                x = x.reshape(x.shape[0], -1)
            elif sp.kind == "dense":
                x = ref.matmul_ref(x, p["w"], p["b"])
                if sp.relu:
                    x = jnp.maximum(x, 0)
            else:
                raise ValueError(f"unknown layer kind {sp.kind!r}")
            yield i, sp, p, x

    def apply_ref(self, params: Sequence[Optional[dict]], x: jax.Array
                  ) -> jax.Array:
        """Float oracle forward pass (lax.conv; differentiable)."""
        for _, _, _, x in self.forward_activations(params, x):
            pass
        return x


# ---------------------------------------------------------------------------
# int8 network quantization + compilation
# ---------------------------------------------------------------------------


def program_tile_plans(plan: NetworkPlan, core_config) -> List:
    """The per-layer TilePlans a ``make_int8_program`` compile would run
    under ``core_config`` — the single derivation shared by the compiler
    and by benchmark/perf reporting, so reported tiling stats always
    describe the plans that actually executed."""
    return plan.tile_plans(
        cin_banks=core_config.cin_banks,
        kout_banks=core_config.kout_banks, in_bytes=1,
        vmem_budget=(core_config.vmem_budget if core_config.auto_bank
                     else None))


@dataclass(frozen=True)
class QuantizedNetwork:
    """A NetworkPlan lowered to the 8-bit datapath.

    Per parametric layer i: int8 weights, int32 bias (at scale
    ``s_in·s_w``), and the requantization scale putting the int32
    accumulator on the NEXT layer's int8 grid.  With per-channel (kout)
    weight scales the bias, requant, and dequant entries are [K] vectors —
    the kernel epilogue broadcasts them over the last axis.  The final
    parametric layer keeps ``requant=None`` and the program dequantizes
    its accumulator with ``out_dequant`` (logits want full precision)."""
    plan: NetworkPlan
    weights: Tuple[Optional[jax.Array], ...]       # int8
    biases: Tuple[Optional[jax.Array], ...]        # int32
    requants: Tuple[Optional[jax.Array], ...]      # f32 scalar or [K]
    in_scale: jax.Array                            # input activation scale
    out_dequant: jax.Array                         # final accumulator scale
    per_channel: bool = False                      # kout-bank weight scales


def quantize_network(plan: NetworkPlan, params: Sequence[Optional[dict]],
                     calib_x: jax.Array,
                     per_channel: bool = False) -> QuantizedNetwork:
    """Calibrate activation scales with a float forward pass and lower every
    parametric layer to int8 (symmetric weights).

    ``per_channel=True`` calibrates one weight scale per output channel
    (kout bank) instead of per tensor: conv kernels reduce over
    (KH, KW, C), dense weights over the contraction dim, yielding [K]
    scale vectors that ride the fused requantize epilogue end-to-end —
    the per-channel refinement the paper's per-kernel-set BRAM layout
    makes natural."""
    last_param = max(i for i, sp in enumerate(plan.layers)
                     if sp.kind in ("conv", "dense"))
    s_act = act_scale_from_calibration(calib_x)
    in_scale = s_act
    weights: List[Optional[jax.Array]] = []
    biases: List[Optional[jax.Array]] = []
    requants: List[Optional[jax.Array]] = []
    out_dequant = jnp.float32(1.0)
    for i, sp, p, x in plan.forward_activations(params, calib_x):
        if sp.kind not in ("conv", "dense"):
            # pooling/flatten are monotone/shape-only: the int8 scale
            # carries (avg-pool stays on the same grid — the mean of
            # same-scale values rounds back onto it)
            weights.append(None); biases.append(None); requants.append(None)
            continue
        if per_channel:
            # reduce over everything but the output-channel axis → [K]
            wq = quantize_symmetric(p["w"],
                                    axis=tuple(range(p["w"].ndim - 1)))
            w_scale = wq.scale.reshape(-1)
        else:
            wq = quantize_symmetric(p["w"])
            w_scale = wq.scale
        acc_scale = s_act * w_scale                   # int32 psum units
        weights.append(wq.values)
        biases.append(jnp.round(p["b"] / acc_scale).astype(jnp.int32))
        if i == last_param:
            requants.append(None)
            out_dequant = acc_scale
        else:
            s_next = act_scale_from_calibration(x)
            requants.append(requant_scale(s_act, w_scale, s_next))
            s_act = s_next
    return QuantizedNetwork(plan, tuple(weights), tuple(biases),
                            tuple(requants), in_scale, out_dequant,
                            per_channel=per_channel)


def make_int8_program(qnet: QuantizedNetwork,
                      core_config: ConvCoreConfig = ConvCoreConfig(int8=True),
                      tile_plans: Optional[Sequence] = None):
    """Compile the quantized network into one jitted program
    x_f32 [N,H,W,C] → logits_f32 [N,classes].

    Conv layers run through the backend with the FULL fused epilogue
    (ReLU → pool → requantize in-VMEM) under a per-layer TilePlan — maps
    larger than the VMEM budget stream through halo'd spatial tiles, so
    VGG-small at 64×64+ inputs and ImageNet-scale plans compile; every
    inter-layer tensor is int8.  Dense accumulators requantize inline
    (the GEMM epilogue is a cheap elementwise op XLA fuses into the
    kernel's consumer).

    ``tile_plans`` overrides the per-layer plans (one entry per layer,
    None for non-conv) — pass ``program_tile_plans(qnet.plan,
    core_config)`` to share the exact plans with reporting code."""
    backend = get_backend(core_config.backend)
    plan = qnet.plan
    if tile_plans is None:
        tile_plans = program_tile_plans(plan, core_config)

    def program(x: jax.Array) -> jax.Array:
        h = jnp.clip(jnp.round(x.astype(jnp.float32) / qnet.in_scale),
                     -128, 127).astype(jnp.int8)
        for sp, w, b, rq, tp in zip(plan.layers, qnet.weights, qnet.biases,
                                    qnet.requants, tile_plans):
            if sp.kind == "conv":
                h = backend.conv(h, w, b, stride=sp.stride,
                                 padding=sp.padding, relu=sp.relu,
                                 pool=sp.pool, out_scale=rq, plan=tp)
                if rq is None:                       # final conv: dequantize
                    h = h.astype(jnp.float32) * qnet.out_dequant
            elif sp.kind == "pool":
                # max-pool commutes with the monotone int8 mapping
                h = ref.maxpool2d_ref(h, sp.size)
            elif sp.kind == "avgpool":
                # window mean rounds back onto the same int8 grid
                h = ref.avgpool2d_ref(h, sp.size)
            elif sp.kind == "globalpool":
                h = ref.global_avgpool_ref(h)
            elif sp.kind == "flatten":
                h = h.reshape(h.shape[0], -1)
            elif sp.kind == "dense":
                acc = backend.matmul(h, w, b)        # int32
                if sp.relu:
                    acc = jnp.maximum(acc, 0)
                if rq is None:
                    h = acc.astype(jnp.float32) * qnet.out_dequant
                else:
                    h = ref.requantize_ref(acc, rq)
        return h

    return jax.jit(program)


# ---------------------------------------------------------------------------
# Reference network zoo
# ---------------------------------------------------------------------------


def lenet(input_shape: Tuple[int, int, int] = (28, 28, 1),
          classes: int = 10) -> NetworkPlan:
    """LeNet-style grayscale classifier exercising the full feature matrix:
    SAME padding, fused conv+pool epilogues, a stride-2 conv, and int8
    dense layers."""
    return NetworkPlan(
        name="lenet", input_shape=input_shape,
        layers=(
            conv(8, kernel=3, padding="SAME", relu=True, pool=True),
            conv(16, kernel=3, padding="SAME", relu=True, pool=True),
            conv(32, kernel=3, stride=2, padding="SAME", relu=True),
            flatten(),
            dense(64, relu=True),
            dense(classes),
        ))


def vgg_small(input_shape: Tuple[int, int, int] = (32, 32, 4),
              classes: int = 10) -> NetworkPlan:
    """VGG-style stacked 3×3 blocks (conv-conv-pool), the shape class the
    paper's full-board replication mode targets.  With 64×64+ inputs the
    per-layer TilePlans stream the early maps through spatial tiles."""
    return NetworkPlan(
        name="vgg_small", input_shape=input_shape,
        layers=(
            conv(16, relu=True), conv(16, relu=True, pool=True),
            conv(32, relu=True), conv(32, relu=True, pool=True),
            conv(64, relu=True, pool=True),
            flatten(),
            dense(128, relu=True),
            dense(classes),
        ))


def vgg_imagenet(input_shape: Tuple[int, int, int] = (224, 224, 4),
                 classes: int = 1000) -> NetworkPlan:
    """ImageNet-scale demo: a VGG-style pyramid over 224×224 inputs whose
    classifier head is a global average pool + one dense layer (no
    flatten + giant GEMM).  Early layers exceed the whole-map VMEM budget
    and compile onto halo'd spatial tiles."""
    return NetworkPlan(
        name="vgg_imagenet", input_shape=input_shape,
        layers=(
            conv(32, relu=True), conv(32, relu=True, pool=True),   # 112
            conv(64, relu=True, pool=True),                        # 56
            conv(128, relu=True, pool=True),                       # 28
            conv(256, relu=True, pool=True),                       # 14
            conv(256, relu=True),
            global_pool(),
            dense(classes),
        ))


def large_map(input_shape: Tuple[int, int, int] = (512, 512, 16),
              classes: int = 4) -> NetworkPlan:
    """Segmentation-scale feature maps: the 512×512×16 first layer's
    whole-map working set exceeds the VMEM budget, so this plan only runs
    through the spatially-tiled kernel — the workload class the seed
    dataflow could not express."""
    return NetworkPlan(
        name="large_map", input_shape=input_shape,
        layers=(
            conv(64, relu=True, pool=True),                        # 256
            conv(32, stride=2, relu=True, pool=True),              # 64
            conv(32, stride=2, relu=True),                         # 32
            avgpool(2),                                            # 16
            global_pool(),
            dense(classes),
        ))
