"""Network-level executor: whole CNNs through the layer-at-a-time IP core.

The paper's IP core "can process a convolutional layer at a time" (§4.2);
running a network on the FPGA means the host sequences layer passes, with
the output BRAMs of one pass becoming the image BRAMs of the next.  This
module is that sequencer as a compiler: a ``NetworkPlan`` — a **DAG** of
conv / pool / flatten / dense ``LayerSpec`` nodes plus ``add``/``concat``
merge nodes — is turned into one jitted multi-layer program over a
``Backend`` (core/convcore.py).

Graph topology: every node may name its producer(s) (``inputs``; empty
means "the previous layer", the straight-line default), so ResNet-class
skip connections and branch-merge topologies express directly.  The
``layers`` tuple must already be topologically ordered (inputs precede
consumers) — one left-to-right sweep IS a topological schedule, which is
also the hardware truth: the single layer-at-a-time core runs parallel
branches serially, the host just sequences the passes.

Layer-to-layer int8 chaining (the production path): ``quantize_network``
calibrates per-layer activation scales from a float forward pass, quantizes
weights/biases (per-tensor or per-output-channel — ``per_channel=True``
yields [K] scale vectors the fused epilogue broadcasts), and computes the
*requantization scale* of each layer (``s_in·s_w / s_out`` —
core/quantize.requant_scale).  The compiled int8 program then keeps every
inter-layer feature map in int8: the fused kernel epilogue (ReLU → pool →
requantize) writes the next layer's int8 input directly, so nothing
round-trips HBM in int32 — the FPGA post-processing idiom at network scale.

Residual adds stay on that int8 story: a skip add is only exact when both
branches land on the same int8 grid, so ``quantize_network`` calibrates a
shared output scale per merge node and emits per-branch requant scales
(``s_branch / s_out`` — quantize.branch_requant_scale) that align the skip
path and the conv path onto the shared grid.  The merge itself is then a
pure saturating int8 add (kernels/ref.add_requant_ref) — the FPGA
output-BRAM-crossbar idiom, no int32 round-trip.

Spatial tiling: ``make_int8_program`` computes a per-layer
``banking.TilePlan`` (``NetworkPlan.tile_plans``), so conv layers whose
whole-map working set exceeds the VMEM budget stream through halo'd H/W
tiles — VGG-small at 64×64+, the ImageNet-scale ``vgg_imagenet`` demo,
and the segmentation-scale ``large_map`` plan all compile unchanged.

Paper → TPU mapping of the replicated-IP-core mode (full-board 4.48 GOPS):
core/scheduler.py shards a compiled program across devices (one IP core ↔
one device) or vmapped virtual cores; core/perfmodel.network_report sums
the §5.2 cycle model over the plan's nodes, including the 20-core
configuration (branches serialize on the single core, so the DAG's cost
is still the sum of its nodes).

Training: core/training.py trains the float shadow of any plan through
the WS kernels' custom VJPs (QAT-aware), and the trained parameters feed
straight back into ``quantize_network`` → ``make_int8_program``;
:meth:`NetworkPlan.train_report` prices a train step on the §5.2 model.
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import banking, perfmodel
from repro.core.convcore import ConvCoreConfig, get_backend
from repro.core.quantize import (act_scale_from_calibration,
                                 branch_requant_scale, quantize_symmetric,
                                 requant_scale)
from repro.kernels import ref

# ---------------------------------------------------------------------------
# Layer graph
# ---------------------------------------------------------------------------

INPUT = "input"          # reserved node name: the network input
DEPTHWISE = -1           # LayerSpec.groups sentinel: groups = cin
PARAM_KINDS = ("conv", "conv_transpose", "dense")   # nodes that own weights


@dataclass(frozen=True)
class LayerSpec:
    """One node of a CNN graph.

    kind: "conv" | "conv_transpose" | "pool" | "avgpool" | "globalpool" |
    "flatten" | "dense" | "add" | "concat".  ``pool=True`` on a conv
    layer fuses the 2×2/2 max-pool into the kernel epilogue (one HBM
    round-trip); standalone "pool" / "avgpool" layers are the unfused
    fallbacks, and "globalpool" is the global average pool
    ([N,H,W,C] → [N,C]) that lets classifier heads skip the flatten +
    giant-dense pattern.

    ``dilation`` (conv kinds) spaces the kernel taps by inserting
    ``dilation−1`` zeros between them (rhs dilation — the dilated-context
    trick that widens receptive fields without shrinking the map).
    "conv_transpose" is the learned-upsampling node (lhs zero-insertion:
    output grows ~stride×); its weights share the forward conv layout
    [KH,KW,C/groups,K] and it lowers onto the SAME weight-stationary
    kernels via the stride-1 equivalent conv
    (kernels/conv2d_ws_trans.py), so the int8 epilogue contract
    (ReLU → pool → requantize) carries over unchanged.

    ``groups`` (conv only) selects grouped channel contraction: 1 = dense,
    ``DEPTHWISE`` (−1) resolves to the node's input channel count at walk
    time — the MobileNet depthwise case, where ``features`` may stay 0 to
    default to "same width as the input".  ``conv_geometry`` is the single
    resolver every shape/cost/compile walk shares.

    ``name`` labels the node so later layers can reference it (default
    ``f"{kind}{index}"``); ``inputs`` names the producer node(s) — empty
    means "the previous layer" (the straight-line default) and the
    reserved name "input" is the network input.  "add" is the residual
    merge (exactly two branches of identical shape, optional fused ReLU);
    "concat" stacks ≥2 branches along the channel axis."""
    kind: str
    features: int = 0                      # conv: K; dense: output dim
    kernel: Tuple[int, int] = (3, 3)
    stride: int = 1
    padding: ref.Padding = "SAME"
    relu: bool = False
    pool: bool = False                     # conv only: fused 2×2 max-pool
    size: int = 2                          # "pool"/"avgpool": window/stride
    groups: int = 1                        # conv only: 1=dense, −1=depthwise
    dilation: int = 1                      # conv kinds: kernel-tap spacing
    name: Optional[str] = None             # node label for skip references
    inputs: Tuple[str, ...] = ()           # () → previous layer


def conv_geometry(sp: LayerSpec, cin: int,
                  name: str = "?") -> Tuple[int, int]:
    """Resolve a conv node's (features, groups) given its input channel
    count — the ONE place the DEPTHWISE sentinel and the grouped
    divisibility contract are interpreted, shared by every walk (shapes,
    params, psums, tile plans, the float oracle, the int8 compiler, the
    trainer) so they can never disagree."""
    groups = cin if sp.groups == DEPTHWISE else sp.groups
    features = sp.features if sp.features else (
        cin if sp.groups == DEPTHWISE else 0)
    if features <= 0:
        raise ValueError(f"node {name!r}: conv needs features > 0")
    if groups < 1 or cin % groups or features % groups:
        raise ValueError(
            f"node {name!r}: groups={groups} must divide both the input "
            f"channels C={cin} and the kernels K={features} "
            f"(groups == C is depthwise)")
    return features, groups


def _single(input: Optional[str]) -> Tuple[str, ...]:
    return () if input is None else (input,)


def conv(features: int, kernel: int = 3, stride: int = 1,
         padding: ref.Padding = "SAME", relu: bool = True,
         pool: bool = False, groups: int = 1, dilation: int = 1,
         name: Optional[str] = None,
         input: Optional[str] = None) -> LayerSpec:
    return LayerSpec("conv", features=features, kernel=(kernel, kernel),
                     stride=stride, padding=padding, relu=relu, pool=pool,
                     groups=groups, dilation=dilation, name=name,
                     inputs=_single(input))


def conv_transpose(features: int, kernel: int = 2, stride: int = 2,
                   padding: ref.Padding = "VALID", relu: bool = True,
                   pool: bool = False, groups: int = 1, dilation: int = 1,
                   name: Optional[str] = None,
                   input: Optional[str] = None) -> LayerSpec:
    """Transposed-conv (learned upsampling) node: output spatial size is
    ``(h−1)·stride + dilated_extent`` under VALID padding and ``h·stride``
    under SAME — the 2×2/stride-2 default exactly doubles the map, the
    U-Net decoder idiom.  Weights are forward-conv layout
    [KH,KW,C/groups,K]."""
    return LayerSpec("conv_transpose", features=features,
                     kernel=(kernel, kernel), stride=stride, padding=padding,
                     relu=relu, pool=pool, groups=groups, dilation=dilation,
                     name=name, inputs=_single(input))


def depthwise(kernel: int = 3, stride: int = 1,
              padding: ref.Padding = "SAME", relu: bool = True,
              pool: bool = False, features: int = 0,
              name: Optional[str] = None,
              input: Optional[str] = None) -> LayerSpec:
    """Depthwise conv node (groups == input channels): each channel is
    filtered by its own spatial kernel — the MobileNet workload family's
    per-channel half of a depthwise-separable block.  ``features``
    defaults to the input width (multiplier 1); a multiple of it selects
    a channel multiplier."""
    return LayerSpec("conv", features=features, kernel=(kernel, kernel),
                     stride=stride, padding=padding, relu=relu, pool=pool,
                     groups=DEPTHWISE, name=name, inputs=_single(input))


def maxpool(size: int = 2, name: Optional[str] = None,
            input: Optional[str] = None) -> LayerSpec:
    return LayerSpec("pool", size=size, name=name, inputs=_single(input))


def avgpool(size: int = 2, name: Optional[str] = None,
            input: Optional[str] = None) -> LayerSpec:
    return LayerSpec("avgpool", size=size, name=name, inputs=_single(input))


def global_pool(name: Optional[str] = None,
                input: Optional[str] = None) -> LayerSpec:
    return LayerSpec("globalpool", name=name, inputs=_single(input))


def flatten(name: Optional[str] = None,
            input: Optional[str] = None) -> LayerSpec:
    return LayerSpec("flatten", name=name, inputs=_single(input))


def dense(features: int, relu: bool = False, name: Optional[str] = None,
          input: Optional[str] = None) -> LayerSpec:
    return LayerSpec("dense", features=features, relu=relu, name=name,
                     inputs=_single(input))


def add(a: str, b: str, relu: bool = False,
        name: Optional[str] = None) -> LayerSpec:
    """Residual merge: elementwise add of two same-shape branches (int8
    path: per-branch requantize onto a shared grid, then a saturating
    int8 add — ref.add_requant_ref)."""
    return LayerSpec("add", relu=relu, name=name, inputs=(a, b))


def concat(*inputs: str, name: Optional[str] = None) -> LayerSpec:
    """Branch merge: concatenate ≥2 branches along the channel axis (int8
    path: each branch requantizes onto the merge node's shared grid)."""
    return LayerSpec("concat", name=name, inputs=tuple(inputs))


@dataclass(frozen=True)
class NetworkPlan:
    """A CNN graph over [H, W, C] inputs.

    ``layers`` is a topologically-ordered node tuple: every node's inputs
    must be earlier nodes (or the network input).  Straight-line plans
    (no ``inputs`` anywhere) behave exactly as before."""
    name: str
    input_shape: Tuple[int, int, int]          # (H, W, C)
    layers: Tuple[LayerSpec, ...]

    # -- graph resolution ---------------------------------------------------

    @functools.cached_property
    def _graph(self) -> Tuple[Tuple[str, ...], Tuple[Tuple[int, ...], ...]]:
        """(node names, resolved input indices), computed and VALIDATED
        once per (frozen) plan instance — every shape/cost/execution walk
        shares this resolution instead of re-deriving it."""
        explicit = {sp.name for sp in self.layers if sp.name}
        names: List[str] = []
        for i, sp in enumerate(self.layers):
            if sp.name:
                if sp.name == INPUT or sp.name in names:
                    raise ValueError(
                        f"duplicate or reserved node name {sp.name!r}")
                names.append(sp.name)
                continue
            nm = f"{sp.kind}{i}"
            while nm == INPUT or nm in explicit:
                nm += "_"
            names.append(nm)
        index = {nm: i for i, nm in enumerate(names)}
        out: List[Tuple[int, ...]] = []
        for i, sp in enumerate(self.layers):
            if sp.inputs:
                idxs = []
                for nm in sp.inputs:
                    if nm == INPUT:
                        idxs.append(-1)
                        continue
                    j = index.get(nm)
                    if j is None:
                        raise ValueError(
                            f"node {names[i]!r}: unknown input {nm!r}")
                    if j >= i:
                        raise ValueError(
                            f"node {names[i]!r}: input {nm!r} does not "
                            "precede it — layers must be topologically "
                            "ordered")
                    idxs.append(j)
                resolved = tuple(idxs)
            else:
                resolved = (i - 1,)
            if sp.kind == "add" and len(resolved) != 2:
                raise ValueError(f"node {names[i]!r}: add takes exactly two "
                                 f"inputs, got {len(resolved)}")
            if sp.kind == "concat" and len(resolved) < 2:
                raise ValueError(f"node {names[i]!r}: concat needs ≥2 inputs")
            if sp.kind not in ("add", "concat") and len(resolved) != 1:
                raise ValueError(f"node {names[i]!r}: {sp.kind} takes one "
                                 f"input, got {len(resolved)}")
            out.append(resolved)
        return tuple(names), tuple(out)

    def node_names(self) -> List[str]:
        """Per-node names (``sp.name`` or ``f"{kind}{i}"``); unique, never
        the reserved input name.  Explicit names own the namespace: an
        auto-generated default that would collide with one (e.g. a user
        node named "conv1" before an unnamed conv at index 1) steps aside
        instead of rejecting the plan."""
        return list(self._graph[0])

    def resolved_inputs(self) -> List[Tuple[int, ...]]:
        """Per-node input indices (−1 = the network input).  Validates the
        graph: referenced nodes must exist and *precede* their consumer
        (the layer tuple is a topological order) and merge arities hold."""
        return list(self._graph[1])

    # -- static shape / cost walks -----------------------------------------

    def activation_shapes(self) -> List[Tuple[int, ...]]:
        """Per-node output shapes (without the batch dim)."""
        names = self.node_names()
        ins = self.resolved_inputs()
        shapes: List[Tuple[int, ...]] = []

        def src(j: int) -> Tuple[int, ...]:
            return self.input_shape if j < 0 else shapes[j]

        for i, sp in enumerate(self.layers):
            s0 = src(ins[i][0])
            if sp.kind in ("conv", "conv_transpose"):
                if len(s0) != 3:
                    raise ValueError(f"node {names[i]!r}: conv after flatten")
                kh, kw = sp.kernel
                k_, _ = conv_geometry(sp, s0[2], names[i])
                if sp.kind == "conv_transpose":
                    h, w = ref.conv_transpose_out_shape(
                        s0[0], s0[1], kh, kw, sp.stride, sp.padding,
                        sp.dilation)
                else:
                    h, w = ref.conv_out_shape(s0[0], s0[1], kh, kw,
                                              sp.stride, sp.padding,
                                              sp.dilation)
                if sp.pool:
                    if h < 2 or w < 2:
                        # same error as plan_tiles / conv2d_ws — the shape
                        # walk must not report a map the kernel rejects
                        raise ValueError(
                            f"node {names[i]!r}: 2×2 pool needs a ≥2×2 "
                            f"conv output, got {h}×{w}")
                    h, w = h // 2, w // 2
                shapes.append((h, w, k_))
            elif sp.kind in ("pool", "avgpool", "globalpool", "flatten"):
                if len(s0) != 3:
                    raise ValueError(f"node {names[i]!r}: {sp.kind} needs "
                                     f"an [H,W,C] input, got shape {s0}")
                h, w, c = s0
                if sp.kind == "globalpool":
                    shapes.append((c,))
                elif sp.kind == "flatten":
                    shapes.append((h * w * c,))
                else:
                    shapes.append(((h - sp.size) // sp.size + 1,
                                   (w - sp.size) // sp.size + 1, c))
            elif sp.kind == "dense":
                if len(s0) != 1:
                    raise ValueError(f"node {names[i]!r}: dense before "
                                     "flatten/globalpool")
                shapes.append((sp.features,))
            elif sp.kind == "add":
                branches = [src(j) for j in ins[i]]
                if len(set(branches)) != 1:
                    raise ValueError(f"node {names[i]!r}: add branches "
                                     f"disagree on shape: {branches}")
                shapes.append(branches[0])
            elif sp.kind == "concat":
                branches = [src(j) for j in ins[i]]
                if any(len(b) != 3 for b in branches) or \
                        len({b[:2] for b in branches}) != 1:
                    raise ValueError(f"node {names[i]!r}: concat branches "
                                     f"must share H×W: {branches}")
                shapes.append((*branches[0][:2],
                               sum(b[2] for b in branches)))
            else:
                raise ValueError(f"unknown layer kind {sp.kind!r}")
        return shapes

    def param_shapes(self) -> List[Optional[dict]]:
        """Per-node {"w": ..., "b": ...} shapes (None for parameter-free
        nodes).  Grouped convs carry the per-group channel slice
        ([KH,KW,C/groups,K] — depthwise weights are [KH,KW,1,C])."""
        ins = self.resolved_inputs()
        acts = self.activation_shapes()
        shapes: List[Optional[dict]] = []
        for i, sp in enumerate(self.layers):
            s0 = self.input_shape if ins[i][0] < 0 else acts[ins[i][0]]
            if sp.kind in ("conv", "conv_transpose"):
                kh, kw = sp.kernel
                k_, g_ = conv_geometry(sp, s0[2])
                shapes.append({"w": (kh, kw, s0[2] // g_, k_),
                               "b": (k_,)})
            elif sp.kind == "dense":
                shapes.append({"w": (s0[0], sp.features),
                               "b": (sp.features,)})
            else:
                shapes.append(None)
        return shapes

    def init_params(self, rng: np.random.Generator) -> List[Optional[dict]]:
        """He-initialized float32 parameters."""
        params: List[Optional[dict]] = []
        for shp in self.param_shapes():
            if shp is None:
                params.append(None)
                continue
            fan_in = int(np.prod(shp["w"][:-1]))
            std = math.sqrt(2.0 / fan_in)
            params.append({
                "w": jnp.asarray(rng.normal(size=shp["w"]) * std,
                                 jnp.float32),
                "b": jnp.asarray(rng.normal(size=shp["b"]) * 0.05,
                                 jnp.float32)})
        return params

    def psum_table(self) -> List[Tuple[str, int]]:
        """Per-node psum counts in the paper's accounting (conv: output
        pixels × kernels × input channels; dense: a 1×1-conv GEMM, in×out;
        pool/flatten/merge: free — the fused epilogue absorbs
        post-processing and the output-BRAM crossbar absorbs residual
        adds/concats).  Parallel branches of a DAG cost their SUM: the
        single layer-at-a-time core serializes them (§4.2).

        Transposed convs are priced on the zero-skipping bound
        (``perfmodel.conv_transpose_psum_count(skip_zeros=True)``: one
        psum per INPUT pixel × tap — the MAC controller skips the
        inserted zeros); the ~stride²× naive count is available from
        perfmodel for what an unmodified IP core would burn."""
        names = self.node_names()
        ins = self.resolved_inputs()
        acts = self.activation_shapes()
        rows: List[Tuple[str, int]] = []
        for i, sp in enumerate(self.layers):
            s0 = self.input_shape if ins[i][0] < 0 else acts[ins[i][0]]
            if sp.kind == "conv":
                kh, kw = sp.kernel
                k_, g_ = conv_geometry(sp, s0[2], names[i])
                rows.append((names[i], perfmodel.psum_count(
                    s0[0], s0[1], s0[2], k_, kh, kw, sp.stride,
                    sp.padding, groups=g_, dilation=sp.dilation)))
            elif sp.kind == "conv_transpose":
                kh, kw = sp.kernel
                k_, g_ = conv_geometry(sp, s0[2], names[i])
                rows.append((names[i], perfmodel.conv_transpose_psum_count(
                    s0[0], s0[1], s0[2], k_, kh, kw, sp.stride,
                    sp.padding, groups=g_, dilation=sp.dilation)))
            elif sp.kind == "dense":
                rows.append((names[i], s0[0] * sp.features))
            else:
                rows.append((names[i], 0))
        return rows

    def tile_plans(self, cin_banks: int = 4, kout_banks: int = 4,
                   in_bytes: int = 1,
                   vmem_budget: Optional[int] = banking.VMEM_BYTES,
                   kernel: str = "auto", calib=None
                   ) -> List[Optional[banking.TilePlan]]:
        """Per-node spatial-tile × channel-bank plans (None for nodes
        without a conv).  int8-datapath sizes by default; the final
        parametric layer (no fused requantize) keeps a 4-byte epilogue
        output, every other conv writes int8.  ``vmem_budget=None``
        disables fitting (whole-map tiles — the seed dataflow).
        ``kernel`` picks the conv variant per layer ("auto" → the
        perfmodel crossover predictor sets ``TilePlan.pipelined`` where
        the explicit DMA pipeline wins; see banking.plan_tiles).
        ``calib`` (a core.calibration.CalibrationTable) prices the
        crossover under measured terms instead of the analytic defaults;
        core/autotune.py searches the full plan space against it.

        Transposed convs are planned on their stride-1 EQUIVALENT conv
        (the zero-inserted map + clipped equivalence pads —
        conv2d_ws_trans.transpose_eq_conv_geometry), which is the
        geometry the kernel lowering actually launches, so VMEM fitting
        and halo math describe the real working set."""
        from repro.kernels.conv2d_ws_trans import transpose_eq_conv_geometry
        last_param = max((i for i, sp in enumerate(self.layers)
                          if sp.kind in PARAM_KINDS), default=-1)
        ins = self.resolved_inputs()
        acts = self.activation_shapes()
        plans: List[Optional[banking.TilePlan]] = []
        for i, sp in enumerate(self.layers):
            if sp.kind not in ("conv", "conv_transpose"):
                plans.append(None)
                continue
            h, w, c = self.input_shape if ins[i][0] < 0 else acts[ins[i][0]]
            kh, kw = sp.kernel
            k_, g_ = conv_geometry(sp, c)
            cb_n, kb_n = banking.grouped_banks(
                c, k_, g_, want_cin=cin_banks, want_kout=kout_banks)
            stride, pad = sp.stride, sp.padding
            if sp.kind == "conv_transpose":
                h, w, pad = transpose_eq_conv_geometry(
                    h, w, kh, kw, sp.stride, sp.padding, sp.dilation)
                stride = 1
            plans.append(banking.plan_tiles(
                h, w, c, k_, kh, kw, stride=stride,
                padding=pad, pool=sp.pool, groups=g_,
                dilation=sp.dilation, in_bytes=in_bytes,
                out_bytes=4 if i == last_param else in_bytes,
                cin_banks=cb_n, kout_banks=kb_n,
                vmem_budget=vmem_budget, kernel=kernel, calib=calib))
        return plans

    def conv_geometries(self) -> List[Optional[Tuple[int, int]]]:
        """Per-node resolved (features, groups) for conv nodes (None for
        everything else) — the DEPTHWISE sentinel resolved against each
        node's actual input width, for consumers that need the group
        structure without re-deriving shapes (the int8 compiler, the
        trainer's float shadow)."""
        names = self.node_names()
        ins = self.resolved_inputs()
        acts = self.activation_shapes()
        out: List[Optional[Tuple[int, int]]] = []
        for i, sp in enumerate(self.layers):
            if sp.kind not in ("conv", "conv_transpose"):
                out.append(None)
                continue
            s0 = self.input_shape if ins[i][0] < 0 else acts[ins[i][0]]
            out.append(conv_geometry(sp, s0[2], names[i]))
        return out

    def grouped_layer_count(self) -> int:
        """Number of conv nodes with grouped (groups > 1) contraction —
        the benchmark/report shorthand for "how much of this plan is the
        depthwise workload class"."""
        return sum(1 for g in self.conv_geometries()
                   if g is not None and g[1] > 1)

    def perf_report(self, cfg: perfmodel.IPCoreConfig =
                    perfmodel.IPCoreConfig(),
                    tile_plans: Optional[Sequence] = None,
                    calib=None) -> dict:
        """The §5.2 cycle model summed over the network, including the
        20-core full-board configuration (perfmodel.network_report).
        With ``tile_plans`` (e.g. from :meth:`tile_plans`) the model also
        prices tile revisits and halo re-reads against the DMA interface,
        keeping large-map GOPS honest.  DAG branches serialize on the
        single core, so the sum over nodes is the schedule length.
        ``calib`` applies a measured CalibrationTable to every term;
        omitted, the report is bit-identical to the analytic model."""
        return perfmodel.network_report(self.psum_table(), cfg,
                                        tile_plans=tile_plans, calib=calib)

    def train_report(self, cfg: perfmodel.IPCoreConfig =
                     perfmodel.IPCoreConfig(),
                     tile_plans: Optional[Sequence] = None,
                     calib=None) -> dict:
        """The §5.2 cycle model of one TRAINING step over this plan:
        forward + backward ≈ 3× the forward psums (input-gradient
        transposed conv + weight-gradient correlation each match the
        forward count — perfmodel.train_report), with the f32
        weight-gradient writeback traffic of every parametric node priced
        against the shared DMA interface."""
        wbytes = [None if shp is None else
                  4 * (int(np.prod(shp["w"])) + int(np.prod(shp["b"])))
                  for shp in self.param_shapes()]
        return perfmodel.train_report(self.psum_table(), cfg,
                                      weight_bytes=wbytes,
                                      tile_plans=tile_plans, calib=calib)

    # -- execution ----------------------------------------------------------

    def forward_activations(self, params: Sequence[Optional[dict]],
                            x: jax.Array):
        """Yield (index, spec, layer_params, activation-after-node) through
        the float oracle in graph (tuple) order — the single definition of
        node semantics, shared by ``apply_ref`` and ``quantize_network``.
        Skip/branch inputs are looked up from the per-node activation
        list, so DAG plans walk exactly like straight-line ones.  This
        loop runs EAGERLY (apply_ref / calibration), so each activation is
        released after its last consumer — peak memory stays
        O(live activations), not O(all activations)."""
        ins = self.resolved_inputs()
        last_use = {}
        for i, idxs in enumerate(ins):
            for j in idxs:
                if j >= 0:
                    last_use[j] = i
        # tests/test_network.py asserts the liveness property through this
        # local's name (acts)
        acts: List[Optional[jax.Array]] = []
        for i, (sp, p) in enumerate(zip(self.layers, params)):
            src = [x if j < 0 else acts[j] for j in ins[i]]
            h = src[0]
            if sp.kind == "conv":
                _, g_ = conv_geometry(sp, h.shape[-1])
                h = ref.conv2d_epilogue_ref(
                    h, p["w"], p["b"], stride=sp.stride, padding=sp.padding,
                    relu=sp.relu, pool=sp.pool, groups=g_,
                    dilation=sp.dilation)
            elif sp.kind == "conv_transpose":
                _, g_ = conv_geometry(sp, h.shape[-1])
                h = ref.conv2d_transpose_epilogue_ref(
                    h, p["w"], p["b"], stride=sp.stride, padding=sp.padding,
                    relu=sp.relu, pool=sp.pool, groups=g_,
                    dilation=sp.dilation)
            elif sp.kind == "pool":
                h = ref.maxpool2d_ref(h, sp.size)
            elif sp.kind == "avgpool":
                h = ref.avgpool2d_ref(h, sp.size)
            elif sp.kind == "globalpool":
                h = ref.global_avgpool_ref(h)
            elif sp.kind == "flatten":
                h = h.reshape(h.shape[0], -1)
            elif sp.kind == "dense":
                h = ref.matmul_ref(h, p["w"], p["b"])
                if sp.relu:
                    h = jnp.maximum(h, 0)
            elif sp.kind == "add":
                h = src[0] + src[1]
                if sp.relu:
                    h = jnp.maximum(h, 0)
            elif sp.kind == "concat":
                h = jnp.concatenate(src, axis=-1)
            else:
                raise ValueError(f"unknown layer kind {sp.kind!r}")
            acts.append(h)
            for j in ins[i]:
                if j >= 0 and last_use[j] == i:
                    acts[j] = None               # last consumer passed
            yield i, sp, p, h

    def apply_ref(self, params: Sequence[Optional[dict]], x: jax.Array
                  ) -> jax.Array:
        """Float oracle forward pass (lax.conv; differentiable)."""
        for _, _, _, x in self.forward_activations(params, x):
            pass
        return x


# ---------------------------------------------------------------------------
# int8 network quantization + compilation
# ---------------------------------------------------------------------------


def program_tile_plans(plan: NetworkPlan, core_config) -> List:
    """The per-layer TilePlans a ``make_int8_program`` compile would run
    under ``core_config`` — the single derivation shared by the compiler
    and by benchmark/perf reporting, so reported tiling stats always
    describe the plans that actually executed."""
    return plan.tile_plans(
        cin_banks=core_config.cin_banks,
        kout_banks=core_config.kout_banks, in_bytes=1,
        vmem_budget=(core_config.vmem_budget if core_config.auto_bank
                     else None),
        kernel=getattr(core_config, "kernel", "auto"),
        calib=getattr(core_config, "calib", None))


@dataclass(frozen=True)
class QuantizedNetwork:
    """A NetworkPlan lowered to the 8-bit datapath.

    Per parametric layer i: int8 weights, int32 bias (at scale
    ``s_in·s_w``), and the requantization scale putting the int32
    accumulator on the NEXT layer's int8 grid.  With per-channel (kout)
    weight scales the bias, requant, and dequant entries are [K] vectors —
    the kernel epilogue broadcasts them over the last axis.  The final
    parametric layer keeps ``requant=None`` and the program dequantizes
    its accumulator with ``out_dequant`` (logits want full precision).

    Per merge node i (``add``/``concat``), ``merge_scales[i]`` holds the
    per-branch requant scales (``s_branch / s_out``) aligning each int8
    branch onto the node's shared output grid — the int32-free residual
    add contract (ref.add_requant_ref)."""
    plan: NetworkPlan
    weights: Tuple[Optional[jax.Array], ...]       # int8
    biases: Tuple[Optional[jax.Array], ...]        # int32
    requants: Tuple[Optional[jax.Array], ...]      # f32 scalar or [K]
    in_scale: jax.Array                            # input activation scale
    out_dequant: jax.Array                         # final accumulator scale
    per_channel: bool = False                      # kout-bank weight scales
    merge_scales: Tuple[Optional[Tuple[jax.Array, ...]], ...] = ()


def quantize_network(plan: NetworkPlan, params: Sequence[Optional[dict]],
                     calib_x: jax.Array,
                     per_channel: bool = False) -> QuantizedNetwork:
    """Calibrate activation scales with a float forward pass and lower every
    parametric layer to int8 (symmetric weights).

    ``per_channel=True`` calibrates one weight scale per output channel
    (kout bank) instead of per tensor: conv kernels reduce over
    (KH, KW, C), dense weights over the contraction dim, yielding [K]
    scale vectors that ride the fused requantize epilogue end-to-end —
    the per-channel refinement the paper's per-kernel-set BRAM layout
    makes natural.

    Merge nodes calibrate a SHARED output scale from the float merge
    activation and carry per-branch requant scales (s_branch / s_out):
    each int8 branch re-expresses on the shared grid, so the residual add
    is a pure saturating int8 op — both branches land on the same grid,
    which is the only way the skip add is exact (ref.add_requant_ref is
    the correctness contract)."""
    last_param = max(i for i, sp in enumerate(plan.layers)
                     if sp.kind in PARAM_KINDS)
    ins = plan.resolved_inputs()
    in_scale = act_scale_from_calibration(calib_x)
    node_scale: List[Optional[jax.Array]] = []  # per-node int8 output scale

    def scale_of(j: int) -> jax.Array:
        s = in_scale if j < 0 else node_scale[j]
        if s is None:
            raise ValueError("graph consumes the dequantized float output "
                             "of the final parametric layer")
        return s

    weights: List[Optional[jax.Array]] = []
    biases: List[Optional[jax.Array]] = []
    requants: List[Optional[jax.Array]] = []
    merges: List[Optional[Tuple[jax.Array, ...]]] = []
    out_dequant = jnp.float32(1.0)
    for i, sp, p, x in plan.forward_activations(params, calib_x):
        w_ = b_ = rq = ms = None
        if sp.kind in PARAM_KINDS:
            s_act = scale_of(ins[i][0])
            if per_channel:
                # reduce over everything but the output-channel axis → [K]
                wq = quantize_symmetric(p["w"],
                                        axis=tuple(range(p["w"].ndim - 1)))
                w_scale = wq.scale.reshape(-1)
            else:
                wq = quantize_symmetric(p["w"])
                w_scale = wq.scale
            acc_scale = s_act * w_scale               # int32 psum units
            w_ = wq.values
            b_ = jnp.round(p["b"] / acc_scale).astype(jnp.int32)
            if i == last_param:
                out_dequant = acc_scale
                node_scale.append(None)
            else:
                s_next = act_scale_from_calibration(x)
                rq = requant_scale(s_act, w_scale, s_next)
                node_scale.append(s_next)
        elif sp.kind in ("add", "concat"):
            # shared merge grid: calibrate from the float merge activation,
            # align every branch onto it with a per-branch requant scale
            s_out = act_scale_from_calibration(x)
            ms = tuple(branch_requant_scale(scale_of(j), s_out)
                       for j in ins[i])
            node_scale.append(s_out)
        else:
            # pooling/flatten are monotone/shape-only: the int8 scale
            # carries (avg-pool stays on the same grid — the mean of
            # same-scale values rounds back onto it).  A None scale (the
            # dequantized float tail after the final parametric layer)
            # propagates: these ops run fine on the float output, only
            # parametric/merge consumers need an int8 grid.
            node_scale.append(in_scale if ins[i][0] < 0
                              else node_scale[ins[i][0]])
        weights.append(w_)
        biases.append(b_)
        requants.append(rq)
        merges.append(ms)
    return QuantizedNetwork(plan, tuple(weights), tuple(biases),
                            tuple(requants), in_scale, out_dequant,
                            per_channel=per_channel,
                            merge_scales=tuple(merges))


def int8_forward(qnet: QuantizedNetwork, x: jax.Array, *, backend,
                 tile_plans: Sequence, node_hook=None) -> jax.Array:
    """The int8 forward walk of ``make_int8_program`` as a plain
    function: quantize the input onto the calibrated grid, execute every
    node in topological order through ``backend``, return the final
    activation.  This is the SINGLE definition of int8 node semantics —
    ``make_int8_program`` jits it, and the per-layer profiler
    (obs/profile.py) calls it EAGERLY with a ``node_hook`` so each
    node's output can be block_until_ready'd and wall-clocked
    individually (the layer-at-a-time walk the paper's single IP core
    performs is exactly this loop).

    ``node_hook(i, name, spec, activation)`` is called after each node
    computes; under ``jax.jit`` the hook only fires at trace time, so
    the compiled path must pass None (the compiler enforces nothing —
    profiling a jitted program through the hook is simply meaningless,
    not unsafe)."""
    plan = qnet.plan
    ins = plan.resolved_inputs()
    geoms = plan.conv_geometries()     # resolved (features, groups)
    merges = qnet.merge_scales or (None,) * len(plan.layers)
    names = plan.node_names() if node_hook is not None else None
    qin = jnp.clip(jnp.round(x.astype(jnp.float32) / qnet.in_scale),
                   -128, 127).astype(jnp.int8)
    acts: List[jax.Array] = []
    for i, (sp, w, b, rq, ms, tp) in enumerate(zip(
            plan.layers, qnet.weights, qnet.biases, qnet.requants,
            merges, tile_plans)):
        src = [qin if j < 0 else acts[j] for j in ins[i]]
        h = src[0]
        if sp.kind in ("conv", "conv_transpose"):
            op = (backend.conv_transpose if sp.kind == "conv_transpose"
                  else backend.conv)
            h = op(h, w, b, stride=sp.stride,
                   padding=sp.padding, groups=geoms[i][1],
                   dilation=sp.dilation,
                   relu=sp.relu, pool=sp.pool, out_scale=rq,
                   plan=tp)
            if rq is None:                       # final conv: dequantize
                h = h.astype(jnp.float32) * qnet.out_dequant
        elif sp.kind == "pool":
            # max-pool commutes with the monotone int8 mapping
            h = ref.maxpool2d_ref(h, sp.size)
        elif sp.kind == "avgpool":
            # window mean rounds back onto the same int8 grid
            h = ref.avgpool2d_ref(h, sp.size)
        elif sp.kind == "globalpool":
            h = ref.global_avgpool_ref(h)
        elif sp.kind == "flatten":
            h = h.reshape(h.shape[0], -1)
        elif sp.kind == "dense":
            acc = backend.matmul(h, w, b)        # int32
            if sp.relu:
                acc = jnp.maximum(acc, 0)
            if rq is None:
                h = acc.astype(jnp.float32) * qnet.out_dequant
            else:
                h = ref.requantize_ref(acc, rq)
        elif sp.kind == "add":
            # int32-free residual add: both branches requantize onto
            # the merge node's shared int8 grid, then saturating add
            h = ref.add_requant_ref(src[0], src[1], ms[0], ms[1],
                                    relu=sp.relu)
        elif sp.kind == "concat":
            h = jnp.concatenate(
                [ref.requantize_ref(s, m) for s, m in zip(src, ms)],
                axis=-1)
        acts.append(h)
        if node_hook is not None:
            node_hook(i, names[i], sp, h)
    return acts[-1]


def make_int8_program(qnet: QuantizedNetwork,
                      core_config: ConvCoreConfig = ConvCoreConfig(int8=True),
                      tile_plans: Optional[Sequence] = None):
    """Compile the quantized network into one jitted program
    x_f32 [N,H,W,C] → logits_f32 [N,classes].

    Conv layers run through the backend with the FULL fused epilogue
    (ReLU → pool → requantize in-VMEM) under a per-layer TilePlan — maps
    larger than the VMEM budget stream through halo'd spatial tiles, so
    VGG-small at 64×64+ inputs and ImageNet-scale plans compile; every
    inter-layer tensor is int8.  Dense accumulators requantize inline
    (the GEMM epilogue is a cheap elementwise op XLA fuses into the
    kernel's consumer).

    Nodes compile in the tuple's topological order; skip/branch operands
    are looked up from the per-node output list, and merge nodes execute
    the int8 residual-add / concat contract (per-branch requantize onto
    the shared grid — ref.add_requant_ref).  Because merges consume full
    feature maps AFTER each sharded conv has concatenated its shards,
    kout/spatial-sharded backends see consistent operands by
    construction.

    ``tile_plans`` overrides the per-layer plans (one entry per layer,
    None for non-conv) — pass ``program_tile_plans(qnet.plan,
    core_config)`` to share the exact plans with reporting code."""
    backend = get_backend(core_config.backend)
    plan = qnet.plan
    merges = qnet.merge_scales or (None,) * len(plan.layers)
    if tile_plans is None:
        tile_plans = program_tile_plans(plan, core_config)
    # a short override list would make the compile zip stop early and
    # silently return an intermediate activation as the "logits"
    if len(tile_plans) != len(plan.layers):
        raise ValueError(f"tile_plans needs one entry per node "
                         f"({len(plan.layers)}), got {len(tile_plans)}")
    if len(merges) != len(plan.layers):
        raise ValueError(f"merge_scales needs one entry per node "
                         f"({len(plan.layers)}), got {len(merges)}")

    def program(x: jax.Array) -> jax.Array:
        return int8_forward(qnet, x, backend=backend, tile_plans=tile_plans)

    return jax.jit(program)


# ---------------------------------------------------------------------------
# Reference network zoo
# ---------------------------------------------------------------------------


def lenet(input_shape: Tuple[int, int, int] = (28, 28, 1),
          classes: int = 10) -> NetworkPlan:
    """LeNet-style grayscale classifier exercising the full feature matrix:
    SAME padding, fused conv+pool epilogues, a stride-2 conv, and int8
    dense layers."""
    return NetworkPlan(
        name="lenet", input_shape=input_shape,
        layers=(
            conv(8, kernel=3, padding="SAME", relu=True, pool=True),
            conv(16, kernel=3, padding="SAME", relu=True, pool=True),
            conv(32, kernel=3, stride=2, padding="SAME", relu=True),
            flatten(),
            dense(64, relu=True),
            dense(classes),
        ))


def vgg_small(input_shape: Tuple[int, int, int] = (32, 32, 4),
              classes: int = 10) -> NetworkPlan:
    """VGG-style stacked 3×3 blocks (conv-conv-pool), the shape class the
    paper's full-board replication mode targets.  With 64×64+ inputs the
    per-layer TilePlans stream the early maps through spatial tiles."""
    return NetworkPlan(
        name="vgg_small", input_shape=input_shape,
        layers=(
            conv(16, relu=True), conv(16, relu=True, pool=True),
            conv(32, relu=True), conv(32, relu=True, pool=True),
            conv(64, relu=True, pool=True),
            flatten(),
            dense(128, relu=True),
            dense(classes),
        ))


def vgg_imagenet(input_shape: Tuple[int, int, int] = (224, 224, 4),
                 classes: int = 1000) -> NetworkPlan:
    """ImageNet-scale demo: a VGG-style pyramid over 224×224 inputs whose
    classifier head is a global average pool + one dense layer (no
    flatten + giant GEMM).  Early layers exceed the whole-map VMEM budget
    and compile onto halo'd spatial tiles."""
    return NetworkPlan(
        name="vgg_imagenet", input_shape=input_shape,
        layers=(
            conv(32, relu=True), conv(32, relu=True, pool=True),   # 112
            conv(64, relu=True, pool=True),                        # 56
            conv(128, relu=True, pool=True),                       # 28
            conv(256, relu=True, pool=True),                       # 14
            conv(256, relu=True),
            global_pool(),
            dense(classes),
        ))


def large_map(input_shape: Tuple[int, int, int] = (512, 512, 16),
              classes: int = 4) -> NetworkPlan:
    """Segmentation-scale feature maps: the 512×512×16 first layer's
    whole-map working set exceeds the VMEM budget, so this plan only runs
    through the spatially-tiled kernel — the workload class the seed
    dataflow could not express."""
    return NetworkPlan(
        name="large_map", input_shape=input_shape,
        layers=(
            conv(64, relu=True, pool=True),                        # 256
            conv(32, stride=2, relu=True, pool=True),              # 64
            conv(32, stride=2, relu=True),                         # 32
            avgpool(2),                                            # 16
            global_pool(),
            dense(classes),
        ))


def _basic_block(i: int, src: str, k: int, stride: int,
                 project: Optional[bool] = None) -> List[LayerSpec]:
    """A ResNet basic block: conv-conv plus a skip — identity by default
    for stride 1, a 1×1 stride-s projection otherwise (the He et al.
    option-B shortcut).  A stride-1 block that CHANGES width must pass
    ``project=True`` (the identity skip can't change channel count; the
    shape walk rejects the mismatch otherwise)."""
    if project is None:
        project = stride != 1
    blk = [
        conv(k, stride=stride, relu=True, name=f"b{i}c1", input=src),
        conv(k, relu=False, name=f"b{i}c2"),
    ]
    skip = src
    if project:
        blk.append(conv(k, kernel=1, stride=stride, relu=False,
                        name=f"b{i}p", input=src))
        skip = f"b{i}p"
    blk.append(add(skip, f"b{i}c2", relu=True, name=f"b{i}"))
    return blk


def resnet_small(input_shape: Tuple[int, int, int] = (32, 32, 4),
                 classes: int = 10) -> NetworkPlan:
    """ResNet-style residual classifier: a stem conv, three basic blocks
    (identity skip, then two stride-2 projection-shortcut blocks), global
    average pool, dense head — the skip-connection workload class
    (ResNet/MobileNet families) the straight-line executor could not
    express.  All merges run the int8 shared-grid residual add."""
    layers: List[LayerSpec] = [conv(16, relu=True, name="stem")]
    layers += _basic_block(1, "stem", 16, 1)
    layers += _basic_block(2, "b1", 32, 2)                      # 16×16
    layers += _basic_block(3, "b2", 64, 2)                      # 8×8
    layers += [global_pool(), dense(classes)]
    return NetworkPlan(name="resnet_small", input_shape=input_shape,
                       layers=tuple(layers))


def _ds_block(i: int, k: int, stride: int = 1) -> List[LayerSpec]:
    """A MobileNet-v1 depthwise-separable block: 3×3 depthwise (spatial
    filtering, one kernel per channel) followed by a 1×1 pointwise conv
    (the channel mix) — the factorization that trades the dense conv's
    C·K channel contraction for C + C·K."""
    return [
        depthwise(stride=stride, relu=True, name=f"d{i}"),
        conv(k, kernel=1, relu=True, name=f"p{i}"),
    ]


def mobilenet_small(input_shape: Tuple[int, int, int] = (16, 16, 4),
                    classes: int = 10) -> NetworkPlan:
    """MobileNet-v1-style depthwise-separable classifier: a dense stem,
    then depthwise + pointwise pairs with stride-2 downsampling, global
    average pool, dense head — the edge-CNN workload family the grouped
    conv contract opens up.  Depthwise layers run the degenerate
    one-cin-bank sweep (one kernel set per channel group), so their
    perfmodel rows sit on the shared-DMA floor, not on compute."""
    layers: List[LayerSpec] = [conv(8, relu=True, name="stem")]
    layers += _ds_block(1, 16)
    layers += _ds_block(2, 32, stride=2)                        # 8×8
    layers += _ds_block(3, 32)
    layers += [global_pool(), dense(classes)]
    return NetworkPlan(name="mobilenet_small", input_shape=input_shape,
                       layers=tuple(layers))


def _inverted_residual(i: int, src: str, cin: int, out: int, stride: int,
                       expand: int = 2) -> List[LayerSpec]:
    """A MobileNet-v2 inverted-residual block: 1×1 expand (×``expand``) →
    3×3 depthwise → linear 1×1 project, with an identity skip add (the
    PR-3 DAG merge) when the block keeps shape.  The projection conv is
    deliberately relu=False — v2's linear bottleneck."""
    blk = [
        conv(cin * expand, kernel=1, relu=True, name=f"m{i}e", input=src),
        depthwise(stride=stride, relu=True, name=f"m{i}d"),
        conv(out, kernel=1, relu=False, name=f"m{i}p"),
    ]
    if stride == 1 and cin == out:
        blk.append(add(src, f"m{i}p", name=f"m{i}"))
    return blk


def mobilenet_v2ish(input_shape: Tuple[int, int, int] = (16, 16, 4),
                    classes: int = 10) -> NetworkPlan:
    """MobileNet-v2-style inverted-residual classifier: expand → depthwise
    → linear-project blocks whose identity skips reuse the residual-graph
    int8 merge (shared-grid saturating add), stacking grouped convs onto
    the DAG story — the second half of the edge workload family."""
    layers: List[LayerSpec] = [conv(8, relu=True, name="stem")]
    layers += _inverted_residual(1, "stem", 8, 8, 1)            # skip add
    layers += _inverted_residual(2, "m1", 8, 16, 2)             # 8×8
    layers += _inverted_residual(3, "m2p", 16, 16, 1)           # skip add
    layers += [global_pool(), dense(classes)]
    return NetworkPlan(name="mobilenet_v2ish", input_shape=input_shape,
                       layers=tuple(layers))


def resnet_bottleneck(input_shape: Tuple[int, int, int] = (32, 32, 8),
                      classes: int = 10) -> NetworkPlan:
    """Bottleneck-residual variant (the ResNet-50 block family): 1×1
    reduce → 3×3 → 1×1 expand with projection shortcuts, exercising 1×1
    convs and width changes through the merge-node int8 story."""
    def bottleneck(i: int, src: str, mid: int, out: int,
                   stride: int) -> List[LayerSpec]:
        return [
            conv(mid, kernel=1, stride=stride, relu=True, name=f"b{i}r",
                 input=src),
            conv(mid, relu=True, name=f"b{i}c"),
            conv(out, kernel=1, relu=False, name=f"b{i}e"),
            conv(out, kernel=1, stride=stride, relu=False, name=f"b{i}p",
                 input=src),
            add(f"b{i}p", f"b{i}e", relu=True, name=f"b{i}"),
        ]

    layers: List[LayerSpec] = [conv(16, relu=True, name="stem")]
    layers += bottleneck(1, "stem", 8, 32, 1)
    layers += bottleneck(2, "b1", 16, 64, 2)                    # 16×16
    layers += [global_pool(), dense(classes)]
    return NetworkPlan(name="resnet_bottleneck", input_shape=input_shape,
                       layers=tuple(layers))


def unet_small(input_shape: Tuple[int, int, int] = (16, 16, 4),
               classes: int = 3) -> NetworkPlan:
    """U-Net-style encoder–decoder segmenter: two stride-2 downsampling
    stages, a bottleneck, then two 2×2/stride-2 ``conv_transpose``
    upsampling stages each concat-merged with its same-resolution encoder
    skip (the U-Net long skip, riding the shared-grid int8 concat), and a
    1×1 per-pixel classifier head — the dense-prediction workload class
    ROADMAP item 5(b) names.  The output is a full-resolution
    [H, W, classes] logit map, not a vector."""
    return NetworkPlan(
        name="unet_small", input_shape=input_shape,
        layers=(
            conv(8, relu=True, name="enc1"),                       # 16×16
            conv(16, stride=2, relu=True, name="down1"),           # 8×8
            conv(16, relu=True, name="enc2"),
            conv(32, stride=2, relu=True, name="down2"),           # 4×4
            conv(32, relu=True, name="bott"),
            conv_transpose(16, kernel=2, stride=2, relu=True,
                           name="up1"),                            # 8×8
            concat("up1", "enc2", name="cat1"),
            conv(16, relu=True, name="dec1"),
            conv_transpose(8, kernel=2, stride=2, relu=True,
                           name="up2"),                            # 16×16
            concat("up2", "enc1", name="cat2"),
            conv(8, relu=True, name="dec2"),
            conv(classes, kernel=1, relu=False, name="head"),
        ))


def dilated_context(input_shape: Tuple[int, int, int] = (16, 16, 4),
                    classes: int = 3) -> NetworkPlan:
    """Dilated-context segmenter (the DeepLab/context-module idiom): a
    stem plus SAME-padded 3×3 convs at dilation 1 → 2 → 4 keep the map at
    full resolution while the receptive field grows exponentially
    (15×15 after the d=4 layer) — dense prediction WITHOUT any
    down/upsampling, the workload dilation exists for.  A 1×1 head emits
    the per-pixel logit map."""
    return NetworkPlan(
        name="dilated_context", input_shape=input_shape,
        layers=(
            conv(8, relu=True, name="stem"),
            conv(8, relu=True, dilation=2, name="ctx2"),
            conv(16, relu=True, dilation=4, name="ctx4"),
            conv(16, relu=True, name="fuse"),
            conv(classes, kernel=1, relu=False, name="head"),
        ))
