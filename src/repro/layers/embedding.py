"""Token embeddings / unembedding (tied optional)."""

from __future__ import annotations

import math

import jax.numpy as jnp

from repro.layers.common import ParamSpec, cast, lconstraint


def embedding_specs(cfg):
    specs = {"embed": ParamSpec((cfg.vocab_size, cfg.d_model),
                                ("vocab", "embed"), init="normal", scale=0.02)}
    if not cfg.tie_embeddings:
        specs["unembed"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                     ("embed", "vocab"), init="fan_in")
    return specs


def embed_tokens(params, tokens, cfg):
    x = jnp.take(params["embed"], tokens, axis=0)
    x = cast(x, cfg.compute_dtype)
    if cfg.embed_scale:
        x = x * math.sqrt(cfg.d_model)
    return lconstraint(x, ("batch", "seq_r", "embed"))


def logits(params, x, cfg):
    """Final projection; always f32 for a stable softmax/loss."""
    x = cast(x, cfg.compute_dtype)
    if cfg.tie_embeddings:
        w = cast(params["embed"], cfg.compute_dtype)
        out = jnp.einsum("bsd,vd->bsv", x, w,
                         preferred_element_type=jnp.float32)
    elif isinstance(params["unembed"], dict):   # w8 serving
        from repro.core.quantize import w8_einsum
        out = w8_einsum("bsd,dv->bsv", x, params["unembed"]["q"],
                        params["unembed"]["s"], compute_dtype=jnp.float32)
    else:
        w = cast(params["unembed"], cfg.compute_dtype)
        out = jnp.einsum("bsd,dv->bsv", x, w,
                         preferred_element_type=jnp.float32)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        out = c * jnp.tanh(out / c)
    return lconstraint(out, ("batch", "seq", "vocab"))
