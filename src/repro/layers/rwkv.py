"""RWKV-6 ("Finch") — attention-free time-mix with data-dependent per-channel
decay, plus the RWKV channel-mix FFN.

Two execution forms, validated against each other in tests:

* ``wkv6_recurrent`` — the O(S) sequential oracle / decode step
  (state [B,H,N,N]).
* ``wkv6_chunked``  — chunk-parallel form used for train/prefill.  All decay
  exponentials appear as exp(logP_i - logP_j) with i ≥ j, which is always
  ≤ 0 because log-decays are negative — numerically exact, no clamping.
  Per-chunk intra work is an [L,L]-pairwise per-channel contraction
  (the linear-attention analogue of a flash block).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.layers.common import ParamSpec, cast, dense, lconstraint
from repro.layers.norms import groupnorm_heads

MIX_NAMES = ("w", "k", "v", "r", "g")


class RWKVState(NamedTuple):
    S: jax.Array        # [B, H, N, N] wkv state (f32)
    x_att: jax.Array    # [B, D] last input to time-mix (token shift)
    x_ffn: jax.Array    # [B, D] last input to channel-mix

    @staticmethod
    def init_specs(cfg, batch: int):
        H = cfg.d_model // cfg.rwkv_head_size
        N = cfg.rwkv_head_size
        return RWKVState(
            S=ParamSpec((batch, H, N, N), ("batch", "heads", None, None),
                        dtype="float32", init="zeros"),
            x_att=ParamSpec((batch, cfg.d_model), ("batch", "embed"),
                            dtype=cfg.compute_dtype, init="zeros"),
            x_ffn=ParamSpec((batch, cfg.d_model), ("batch", "embed"),
                            dtype=cfg.compute_dtype, init="zeros"),
        )


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def timemix_specs(cfg):
    d = cfg.d_model
    r = cfg.rwkv_lora_rank
    H = d // cfg.rwkv_head_size
    N = cfg.rwkv_head_size
    return {
        "mu_base": ParamSpec((d,), ("embed",), init="zeros"),
        "mu": ParamSpec((5, d), (None, "embed"), init="zeros"),
        "ddlerp_a": ParamSpec((d, 5, r), ("embed", None, None), init="fan_in"),
        "ddlerp_b": ParamSpec((5, r, d), (None, None, "embed"), init="zeros"),
        "w0": ParamSpec((d,), ("embed",), init="constant", scale=-2.0),
        "w_lora_a": ParamSpec((d, r), ("embed", None), init="fan_in"),
        "w_lora_b": ParamSpec((r, d), (None, "embed"), init="zeros"),
        "wr": ParamSpec((d, d), ("embed", "heads")),
        "wk": ParamSpec((d, d), ("embed", "heads")),
        "wv": ParamSpec((d, d), ("embed", "heads")),
        "wg": ParamSpec((d, d), ("embed", "heads")),
        "wo": ParamSpec((d, d), ("heads", "embed")),
        "u": ParamSpec((H, N), ("heads", None), init="normal", scale=0.5),
        "gn_scale": ParamSpec((H, N), ("heads", None), init="ones"),
        "gn_bias": ParamSpec((H, N), ("heads", None), init="zeros"),
    }


def channelmix_specs(cfg):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu_k": ParamSpec((d,), ("embed",), init="zeros"),
        "mu_r": ParamSpec((d,), ("embed",), init="zeros"),
        "wk": ParamSpec((d, f), ("embed", "mlp")),
        "wv": ParamSpec((f, d), ("mlp", "embed")),
        "wr": ParamSpec((d, d), ("embed", "heads")),
    }


# ---------------------------------------------------------------------------
# wkv6 cores
# ---------------------------------------------------------------------------


def wkv6_recurrent(r, k, v, logw, u, S0=None):
    """Sequential oracle.  r,k,v,logw: [B,S,H,N] f32; u: [H,N].
    Returns (o [B,S,H,N], S_final [B,H,N,N])."""
    B, S, H, N = r.shape
    if S0 is None:
        S0 = jnp.zeros((B, H, N, N), jnp.float32)

    def step(Sc, inp):
        rt, kt, vt, lwt = inp                    # [B,H,N]
        bonus = jnp.einsum("bhn,bhn->bh", rt, u[None] * kt)
        o = jnp.einsum("bhn,bhnm->bhm", rt, Sc) + bonus[..., None] * vt
        Sn = jnp.exp(lwt)[..., None] * Sc + kt[..., None] * vt[..., None, :]
        return Sn, o

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (r, k, v, logw))
    S_fin, o = jax.lax.scan(step, S0, xs)
    return o.transpose(1, 0, 2, 3), S_fin


def wkv6_chunked(r, k, v, logw, u, S0=None, chunk: int = 32):
    """Chunk-parallel wkv6 (see module docstring).  Same signature/returns
    as :func:`wkv6_recurrent`."""
    B, S, H, N = r.shape
    L = min(chunk, S)
    while S % L:
        L //= 2
    nc = S // L
    if S0 is None:
        S0 = jnp.zeros((B, H, N, N), jnp.float32)

    def reshape(t):
        return t.reshape(B, nc, L, H, N).transpose(1, 0, 2, 3, 4)

    rs, ks, vs, lws = map(reshape, (r, k, v, logw))     # [nc,B,L,H,N]
    tri_strict = jnp.tril(jnp.ones((L, L), bool), k=-1)

    def chunk_step(Sc, inp):
        rc, kc, vc, lwc = inp                           # [B,L,H,N]
        lp = jnp.cumsum(lwc, axis=1)                    # inclusive logP_i
        lp_prev = lp - lwc                              # exclusive logP_{i-1}
        lp_last = lp[:, -1]                             # [B,H,N]
        # intra-chunk pairwise decays: D[b,i,j,h,n] = exp(lp_prev_i - lp_j),
        # exponent <= 0 for j <= i-1 (cumsum of negatives) — always finite.
        expo = lp_prev[:, :, None] - lp[:, None]        # [B,L,L,H,N]
        D = jnp.exp(jnp.where(tri_strict[None, :, :, None, None], expo, -jnp.inf))
        A = jnp.einsum("blhn,bmhn,blmhn->bhlm", rc, kc, D)
        bonus = jnp.einsum("blhn,blhn->blh", rc, u[None, None] * kc)
        o_intra = jnp.einsum("bhlm,bmhn->blhn", A, vc)
        o_intra += bonus[..., None] * vc
        o_inter = jnp.einsum("blhn,bhnm->blhm", rc * jnp.exp(lp_prev), Sc)
        # state to end of chunk: decay S0 fully; each k_j decayed to chunk end
        k_dec = kc * jnp.exp(lp_last[:, None] - lp)
        Sn = (jnp.exp(lp_last)[..., None] * Sc
              + jnp.einsum("blhn,blhm->bhnm", k_dec, vc))
        return Sn, o_intra + o_inter

    S_fin, o = jax.lax.scan(chunk_step, S0, (rs, ks, vs, lws))
    o = o.transpose(1, 0, 2, 3, 4).reshape(B, S, H, N)
    return o, S_fin


# ---------------------------------------------------------------------------
# Layer assembly
# ---------------------------------------------------------------------------


def _token_shift(x, x_prev_last=None):
    """x_{t-1} with zero (or carried) state at t=0.  x: [B,S,D]."""
    if x_prev_last is None:
        pad = jnp.zeros_like(x[:, :1])
    else:
        pad = cast(x_prev_last[:, None], x.dtype)
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def apply_timemix(params, x, cfg, state: RWKVState | None = None,
                  chunked: bool = True):
    """RWKV6 time mix.  x: [B,S,D] → (y, (S_fin, x_last))."""
    B, S, D = x.shape
    H = D // cfg.rwkv_head_size
    N = cfg.rwkv_head_size

    xf = cast(x, jnp.float32)
    xprev = cast(_token_shift(
        x, state.x_att if state is not None else None), jnp.float32)
    sx = xprev - xf

    # data-dependent lerp (ddlerp): 5 mixed inputs for w,k,v,r,g
    z = xf + sx * params["mu_base"].astype(jnp.float32)
    tan = jnp.tanh(jnp.einsum("bsd,dpr->bspr", z,
                              cast(params["ddlerp_a"], jnp.float32)))
    dyn = jnp.einsum("bspr,prd->bspd", tan,
                     cast(params["ddlerp_b"], jnp.float32))     # [B,S,5,D]
    mixed = xf[:, :, None] + sx[:, :, None] * (
        params["mu"].astype(jnp.float32)[None, None] + dyn)     # [B,S,5,D]
    xw, xk, xv, xr, xg = [mixed[:, :, i] for i in range(5)]

    # decay (per-channel, data-dependent): logw = -exp(w0 + lora_w(xw))
    wlo = jnp.tanh(xw @ cast(params["w_lora_a"], jnp.float32)) \
        @ cast(params["w_lora_b"], jnp.float32)
    logw = -jnp.exp(jnp.clip(params["w0"].astype(jnp.float32) + wlo,
                             -20.0, 8.0))                        # [B,S,D] <0

    cd = cfg.compute_dtype
    rr = dense(params["wr"], cast(xr, cd), "bsd,de->bse", compute_dtype=cd)
    kk = dense(params["wk"], cast(xk, cd), "bsd,de->bse", compute_dtype=cd)
    vv = dense(params["wv"], cast(xv, cd), "bsd,de->bse", compute_dtype=cd)
    gg = dense(params["wg"], cast(xg, cd), "bsd,de->bse", compute_dtype=cd)

    def heads(t):
        return cast(t, jnp.float32).reshape(B, S, H, N)

    S0 = state.S if state is not None else None
    core = wkv6_chunked if (chunked and S > 1) else wkv6_recurrent
    o, S_fin = core(heads(rr), heads(kk), heads(vv),
                    logw.reshape(B, S, H, N),
                    params["u"].astype(jnp.float32), S0=S0)

    o = groupnorm_heads(o, params["gn_scale"], params["gn_bias"])
    o = o.reshape(B, S, D)
    y = cast(o, cd) * jax.nn.silu(gg)
    y = dense(params["wo"], y, "bse,ed->bsd", compute_dtype=cd)
    return lconstraint(y, ("batch", "seq_r", "embed")), (S_fin, x[:, -1])


def apply_channelmix(params, x, cfg, state_x_last=None):
    """RWKV channel mix.  Returns (y, x_last)."""
    cd = cfg.compute_dtype
    xf = cast(x, jnp.float32)
    sx = cast(_token_shift(x, state_x_last), jnp.float32) - xf
    xk = cast(xf + sx * params["mu_k"].astype(jnp.float32), cd)
    xr = cast(xf + sx * params["mu_r"].astype(jnp.float32), cd)
    kk = dense(params["wk"], xk, "bsd,df->bsf", compute_dtype=cd)
    kk = jnp.square(jax.nn.relu(kk))
    kk = lconstraint(kk, ("batch", "seq", "mlp"))
    vv = dense(params["wv"], kk, "bsf,fd->bsd", compute_dtype=cd)
    rr = jax.nn.sigmoid(dense(params["wr"], xr, "bsd,de->bse",
                              compute_dtype=cd))
    return lconstraint(rr * vv, ("batch", "seq_r", "embed")), x[:, -1]
