"""Gated feed-forward (SwiGLU / GeGLU)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.layers.common import ParamSpec, dense, lconstraint


def mlp_specs(cfg, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "wi_gate": ParamSpec((d, f), ("embed", "mlp")),
        "wi_up": ParamSpec((d, f), ("embed", "mlp")),
        "wo": ParamSpec((f, d), ("mlp", "embed")),
    }


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def apply_mlp(params, x, cfg):
    g = dense(params["wi_gate"], x, "bsd,df->bsf", backend=cfg.gemm_backend,
              compute_dtype=cfg.compute_dtype)
    u = dense(params["wi_up"], x, "bsd,df->bsf", backend=cfg.gemm_backend,
              compute_dtype=cfg.compute_dtype)
    h = _act(cfg.mlp_act)(g) * u
    h = lconstraint(h, ("batch", "seq", "mlp"))
    y = dense(params["wo"], h, "bsf,fd->bsd", backend=cfg.gemm_backend,
              compute_dtype=cfg.compute_dtype)
    return lconstraint(y, ("batch", "seq_r", "embed"))
