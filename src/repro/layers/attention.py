"""Self/cross attention: GQA/MQA, RoPE, chunked-flash (O(S·chunk) memory),
sliding-window, and single-token decode against a KV cache.

Memory design (why chunked): a 32k-token prefill with materialized scores
would need B·H·S² f32 — hundreds of GB per device.  ``chunked_attention``
runs a flash-style two-level scan: outer over query chunks, inner over KV
chunks, with ``lax.cond`` skipping fully-masked (future / out-of-window)
KV chunks so causal compute is ~half of dense and sliding-window compute is
O(S·window).

Sharding: q/k/v projections are head-sharded where the head count divides the
model axis; KV tensors with few heads shard head_dim instead (see DESIGN.md).
KV is *broadcast* to full heads only in the chunked prefill path (small
relative cost); decode uses grouped einsums against the un-broadcast cache.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.layers.common import ParamSpec, cast, dense, lconstraint
from repro.layers.norms import rmsnorm_specs
from repro.layers.rope import apply_rope

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def attention_specs(cfg, cross: bool = False):
    d, h, kv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    specs = {
        "wq": ParamSpec((d, h, dh), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, kv, dh), ("embed", "kv_heads", "qkv")),
        "wv": ParamSpec((d, kv, dh), ("embed", "kv_heads", "qkv")),
        "wo": ParamSpec((h, dh, d), ("heads", "head_dim", "embed"),
                        fan_in_axes=(0, 1)),
    }
    if cfg.qk_norm and not cross:
        specs["q_norm"] = rmsnorm_specs(dh)
        specs["k_norm"] = rmsnorm_specs(dh)
    return specs


# ---------------------------------------------------------------------------
# Flash-style chunked attention
# ---------------------------------------------------------------------------


def _flash_update(carry, scores, v_j):
    """One online-softmax update.  scores: [B,H,cq,ck] f32, v_j: [B,ck,H,D]."""
    m, l, acc = carry
    m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
    alpha = jnp.exp(m - m_new)
    p = jnp.exp(scores - m_new[..., None])
    l_new = alpha * l + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bhqk,bkhd->bhqd", p, cast(v_j, jnp.float32))
    acc_new = alpha[..., None] * acc + pv
    return m_new, l_new, acc_new


def chunked_attention(q, k, v, *, causal: bool, window: int = 0,
                      chunk: int = 512, q_offset: int = 0,
                      softcap: float = 0.0):
    """q: [B,Sq,H,D]; k/v: [B,Sk,H,D] (already broadcast to H heads).

    Returns [B,Sq,H,D].  ``window`` > 0 restricts each query to the last
    ``window`` keys (inclusive of itself).  ``q_offset`` is the absolute
    position of q[0] relative to k[0] (cross/cache cases).
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    c = min(chunk, Sq, Sk)
    while Sq % c or Sk % c:            # shapes in this repo are powers of two
        c //= 2
    assert c >= 1
    nq, nk = Sq // c, Sk // c
    scale = 1.0 / math.sqrt(D)

    qc = q.reshape(B, nq, c, H, D)
    kc = k.reshape(B, nk, c, H, D)
    vc = v.reshape(B, nk, c, H, D)

    def q_step(_, i):
        q_i = cast(qc[:, i], jnp.float32) * scale          # [B,c,H,D]
        qpos = q_offset + i * c + jnp.arange(c)

        def kv_step(carry, j):
            # lax.cond skips fully-masked chunks at *runtime*: causal compute
            # is ~S²/2 and sliding-window compute is O(S·window).
            kpos = j * c + jnp.arange(c)
            pred_causal = jnp.logical_or(
                jnp.asarray(not causal), kpos[0] <= qpos[-1])
            pred_window = (kpos[-1] >= qpos[0] - (window - 1)
                           if window > 0 else jnp.asarray(True))
            pred = jnp.logical_and(pred_causal, pred_window)

            def compute(carry):
                k_j, v_j = kc[:, j], vc[:, j]
                scores = jnp.einsum("bqhd,bkhd->bhqk", q_i,
                                    cast(k_j, jnp.float32))
                if softcap:
                    scores = softcap * jnp.tanh(scores / softcap)
                mask = jnp.ones((c, c), bool)
                if causal:
                    mask &= qpos[:, None] >= kpos[None, :]
                if window > 0:
                    mask &= kpos[None, :] > qpos[:, None] - window
                scores = jnp.where(mask, scores, NEG_INF)
                return _flash_update(carry, scores, v_j)

            new = jax.lax.cond(pred, compute, lambda cry: cry, carry)
            return new, None

        init = (jnp.full((B, H, c), NEG_INF, jnp.float32),
                jnp.zeros((B, H, c), jnp.float32),
                jnp.zeros((B, H, c, D), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        out_i = acc / jnp.maximum(l, 1e-30)[..., None]       # [B,H,c,D]
        return None, out_i.transpose(0, 2, 1, 3)             # [B,c,H,D]

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))     # [nq,B,c,H,D]
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, D)
    return cast(out, q.dtype)


def dense_attention(q, k, v, *, causal: bool, window: int = 0,
                    q_offset: int = 0, softcap: float = 0.0):
    """Materialized-scores oracle (tests / tiny shapes only)."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    scale = 1.0 / math.sqrt(D)
    scores = jnp.einsum("bqhd,bkhd->bhqk", cast(q, jnp.float32) * scale,
                        cast(k, jnp.float32))
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, cast(v, jnp.float32))
    return cast(out, q.dtype)


# ---------------------------------------------------------------------------
# Full layer
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    """Decode-time cache for one attention layer.  For sliding-window blocks
    the cache is a ring buffer of size ``window`` (sub-quadratic memory —
    this is what makes recurrentgemma long_500k feasible)."""
    k: jax.Array          # [B, S_cache, KV, D]
    v: jax.Array          # [B, S_cache, KV, D]

    @staticmethod
    def init_specs(cfg, batch: int, seq_len: int, window: int = 0):
        size = min(seq_len, window) if window > 0 else seq_len
        shp = (batch, size, cfg.num_kv_heads, cfg.head_dim)
        axes = ("batch", "cache_seq", "kv_heads", "qkv")
        dt = cfg.resolved_kv_dtype
        return KVCache(
            k=ParamSpec(shp, axes, dtype=dt, init="zeros"),
            v=ParamSpec(shp, axes, dtype=dt, init="zeros"),
        )


def _project_qkv(params, x, cfg, positions):
    b = cfg.gemm_backend
    q = dense(params["wq"], x, "bsd,dhe->bshe", backend="xla",
              compute_dtype=cfg.compute_dtype)
    k = dense(params["wk"], x, "bsd,dke->bske", backend="xla",
              compute_dtype=cfg.compute_dtype)
    v = dense(params["wv"], x, "bsd,dke->bske", backend="xla",
              compute_dtype=cfg.compute_dtype)
    q = lconstraint(q, ("batch", "seq", "heads", "head_dim"))
    k = lconstraint(k, ("batch", "seq", "kv_heads", "qkv"))
    v = lconstraint(v, ("batch", "seq", "kv_heads", "qkv"))
    if cfg.qk_norm:
        from repro.layers.norms import apply_norm
        q = apply_norm(params["q_norm"], q, cfg)
        k = apply_norm(params["k_norm"], k, cfg)
    if cfg.use_rope and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _broadcast_kv(t, num_heads):
    """[B,S,KV,D] → [B,S,H,D] by repeating each KV head H/KV times."""
    B, S, KV, D = t.shape
    g = num_heads // KV
    t = jnp.broadcast_to(t[:, :, :, None, :], (B, S, KV, g, D))
    t = t.reshape(B, S, KV * g, D)
    return lconstraint(t, ("batch", "seq", "heads", "head_dim"))


def attention_layer(params, x, cfg, *, positions, causal=True, window=0,
                    kv=None):
    """Full attention over a sequence (train / prefill / encoder).

    kv: optional (k_src, v_src) for cross attention (already projected
    source sequence is NOT expected here; pass source hidden states).
    Returns (out, (k, v)) — projected k/v for cache priming.
    """
    if kv is None:
        q, k, v = _project_qkv(params, x, cfg, positions)
        q_offset = 0
    else:
        q = dense(params["wq"], x, "bsd,dhe->bshe",
                  compute_dtype=cfg.compute_dtype)
        if cfg.use_rope and positions is not None:
            q = apply_rope(q, positions, cfg.rope_theta)
        src = kv
        k = dense(params["wk"], src, "bsd,dke->bske",
                  compute_dtype=cfg.compute_dtype)
        v = dense(params["wv"], src, "bsd,dke->bske",
                  compute_dtype=cfg.compute_dtype)
        q_offset = 0
        causal = False

    kf = _broadcast_kv(k, cfg.num_heads)
    vf = _broadcast_kv(v, cfg.num_heads)
    if cfg.attn_impl == "dense":
        out = dense_attention(q, kf, vf, causal=causal, window=window,
                              q_offset=q_offset)
    elif cfg.attn_impl == "flash" and window == 0 and q_offset == 0:
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, kf, vf, causal=causal,
                                   block_q=cfg.attn_chunk,
                                   block_k=cfg.attn_chunk)
    else:
        out = chunked_attention(q, kf, vf, causal=causal, window=window,
                                chunk=cfg.attn_chunk, q_offset=q_offset)
    out = lconstraint(out, ("batch", "seq", "heads", "head_dim"))
    y = dense(params["wo"], out, "bshe,hed->bsd",
              compute_dtype=cfg.compute_dtype)
    return lconstraint(y, ("batch", "seq_r", "embed")), (k, v)


def decode_attention_layer(params, x, cfg, *, cache: KVCache, pos,
                           window=0, cross_kv=None):
    """One-token decode.  x: [B,1,D]; pos: [B] absolute positions.

    Grouped-einsum attention against the (possibly ring-buffered) cache —
    the KV tensors are never broadcast to full heads, so per-step HBM
    traffic is exactly one cache read (the decode roofline term).
    Returns (out [B,1,D], new_cache).
    """
    B = x.shape[0]
    KV, D = cfg.num_kv_heads, cfg.head_dim
    G = cfg.num_heads // KV

    if cross_kv is not None:
        q = dense(params["wq"], x, "bsd,dhe->bshe",
                  compute_dtype=cfg.compute_dtype)
        if cfg.use_rope:
            q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k_all, v_all = cross_kv                    # precomputed, static
        qg = q.reshape(B, KV, G, D)
        scores = jnp.einsum("bkgd,bskd->bkgs", cast(qg, jnp.float32),
                            cast(k_all, jnp.float32)) / math.sqrt(D)
        p = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgs,bskd->bkgd", p, cast(v_all, jnp.float32))
        out = cast(out, cfg.compute_dtype).reshape(B, 1, cfg.num_heads, D)
        y = dense(params["wo"], out, "bshe,hed->bsd",
                  compute_dtype=cfg.compute_dtype)
        return y, cache

    q, k_new, v_new = _project_qkv(params, x, cfg, pos[:, None])
    S_cache = cache.k.shape[1]
    int8_cache = cache.k.dtype == jnp.int8
    kv_scale = cfg.kv_cache_scale

    def to_cache(t):
        if int8_cache:
            return jnp.clip(jnp.round(t.astype(jnp.float32) / kv_scale),
                            -128, 127).astype(jnp.int8)
        return cast(t, cache.k.dtype)

    # ring-buffer slot (== pos when the cache is not a ring)
    slot = pos % S_cache                                          # [B]
    bidx = jnp.arange(B)
    k_cache = cache.k.at[bidx, slot].set(to_cache(k_new[:, 0]))
    v_cache = cache.v.at[bidx, slot].set(to_cache(v_new[:, 0]))
    k_cache = lconstraint(k_cache, ("batch", "cache_seq", "kv_heads", "qkv"))
    v_cache = lconstraint(v_cache, ("batch", "cache_seq", "kv_heads", "qkv"))

    qg = q.reshape(B, KV, G, D)
    if int8_cache:
        # paper 8-bit datapath on the cache read: quantize q per-tensor and
        # contract in s8 with int32 accumulation (§Perf C2)
        qf = qg.astype(jnp.float32)
        sq = jnp.maximum(jnp.max(jnp.abs(qf)), 1e-12) / 127.0
        qq = jnp.clip(jnp.round(qf / sq), -128, 127).astype(jnp.int8)
        acc = jnp.einsum("bkgd,bskd->bkgs", qq, k_cache,
                         preferred_element_type=jnp.int32)
        scores = acc.astype(jnp.float32) * (sq * kv_scale) / math.sqrt(D)
    else:
        scores = jnp.einsum("bkgd,bskd->bkgs", cast(qg, jnp.float32),
                            cast(k_cache, jnp.float32)) / math.sqrt(D)
    # validity: a slot s holds absolute position p(s); valid if p(s) <= pos
    # and (window) p(s) > pos - window.  For a ring of size S_cache filled
    # past capacity every slot is valid.
    slots = jnp.arange(S_cache)
    # absolute position currently stored in each slot
    wraps = (pos[:, None] - slots[None, :] + S_cache) // S_cache
    abs_pos = pos[:, None] - ((pos[:, None] - slots[None, :]) % S_cache)
    valid = (abs_pos >= 0) & (abs_pos <= pos[:, None])
    if window > 0:
        valid &= abs_pos > pos[:, None] - window
    del wraps
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    if int8_cache:
        # probabilities ∈ [0,1]: quantize p at 1/127 resolution, s8 dot
        pq = jnp.clip(jnp.round(p * 127.0), 0, 127).astype(jnp.int8)
        acc = jnp.einsum("bkgs,bskd->bkgd", pq, v_cache,
                         preferred_element_type=jnp.int32)
        out = acc.astype(jnp.float32) * (kv_scale / 127.0)
    else:
        out = jnp.einsum("bkgs,bskd->bkgd", p, cast(v_cache, jnp.float32))
    out = cast(out, cfg.compute_dtype).reshape(B, 1, cfg.num_heads, D)
    y = dense(params["wo"], out, "bshe,hed->bsd",
              compute_dtype=cfg.compute_dtype)
    return y, KVCache(k=k_cache, v=v_cache)
