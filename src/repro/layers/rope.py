"""Rotary position embeddings + sinusoidal absolute positions."""

from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: [B, S, H, D]; positions: [B, S] (int)."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                    # [half]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B,S,half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions, d_model: int):
    """Classic transformer sinusoidal table, computed on the fly.
    positions: [B, S] → [B, S, d_model]."""
    half = d_model // 2
    freqs = 1.0 / (10_000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
