"""RecurrentGemma / Griffin recurrent block: temporal conv1d (width 4) +
RG-LRU gated linear recurrence.

The temporal conv1d is this repo's *in-model* convolution site: it runs
through the paper's ConvCore dataflow on the TPU target
(``cfg.gemm_backend == "pallas_ws"`` routes it to the depthwise conv1d
kernel); the default path is the shift-based jnp form (dry-run / CPU).

Train/prefill uses ``lax.associative_scan`` (log-depth, avoids the O(S)
sequential chain); decode keeps an O(1) recurrent state.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.layers.common import ParamSpec, cast, dense, lconstraint

_C = 8.0  # RG-LRU sharpness constant (Griffin §2.4)


class RGLRUState(NamedTuple):
    conv: jax.Array    # [B, conv_width-1, W] — last inputs for the conv1d
    h: jax.Array       # [B, W] — recurrence carry

    @staticmethod
    def init_specs(cfg, batch: int):
        w = cfg.rnn_width
        return RGLRUState(
            conv=ParamSpec((batch, cfg.conv1d_width - 1, w),
                           ("batch", None, "rnn"),
                           dtype=cfg.compute_dtype, init="zeros"),
            h=ParamSpec((batch, w), ("batch", "rnn"),
                        dtype="float32", init="zeros"),
        )


def rglru_specs(cfg):
    d, w = cfg.d_model, cfg.rnn_width
    return {
        "w_gate": ParamSpec((d, w), ("embed", "rnn")),
        "w_rnn_in": ParamSpec((d, w), ("embed", "rnn")),
        "conv_w": ParamSpec((cfg.conv1d_width, w), (None, "rnn"),
                            init="fan_in", fan_in_axes=(0,)),
        "conv_b": ParamSpec((w,), ("rnn",), init="zeros"),
        "w_a": ParamSpec((w, w), ("rnn", "rnn")),       # recurrence gate
        "b_a": ParamSpec((w,), ("rnn",), init="zeros"),
        "w_x": ParamSpec((w, w), ("rnn", "rnn")),       # input gate
        "b_x": ParamSpec((w,), ("rnn",), init="zeros"),
        "lam": ParamSpec((w,), ("rnn",), init="constant", scale=0.7),
        "w_out": ParamSpec((w, d), ("rnn", "embed")),
    }


def causal_conv1d(u, conv_w, conv_b, prefix=None):
    """Depthwise causal temporal conv.  u: [B,S,W]; conv_w: [K,W].

    prefix: [B,K-1,W] carried state (decode / chunked prefill); zeros
    otherwise.  TPU target: this maps onto the ConvCore weight-stationary
    dataflow (kernels/conv1d section of DESIGN.md)."""
    K = conv_w.shape[0]
    if prefix is None:
        prefix = jnp.zeros((u.shape[0], K - 1, u.shape[2]), u.dtype)
    xp = jnp.concatenate([cast(prefix, u.dtype), u], axis=1)   # [B,S+K-1,W]
    S = u.shape[1]
    y = conv_b.astype(u.dtype)[None, None]
    for j in range(K):   # K is 4: unrolled shifted MACs == the 9-MAC analogue
        y = y + xp[:, j:j + S] * conv_w[j][None, None]
    return y


def _gates(params, u):
    """RG-LRU gate computation in f32.  u: [B,S,W] → (log_a, b_input)."""
    uf = cast(u, jnp.float32)
    r = jax.nn.sigmoid(uf @ cast(params["w_a"], jnp.float32)
                       + params["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf @ cast(params["w_x"], jnp.float32)
                       + params["b_x"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    gated = i * uf
    # multiplier sqrt(1 - a^2) = sqrt(1 - exp(2 log_a)), computed stably
    mult = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    return log_a, mult * gated


def rglru_scan(params, u, h0=None):
    """Associative linear recurrence h_t = a_t h_{t-1} + b_t over axis 1."""
    log_a, b = _gates(params, u)
    a = jnp.exp(log_a)
    if h0 is not None:
        # fold the carried state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h  # f32 [B,S,W]


def apply_rglru(params, x, cfg, state: RGLRUState | None = None):
    """Full recurrent block.  x: [B,S,D] → (y, new_state or None)."""
    gate = jax.nn.gelu(dense(params["w_gate"], x, "bsd,dw->bsw",
                             compute_dtype=cfg.compute_dtype))
    u_raw = dense(params["w_rnn_in"], x, "bsd,dw->bsw",
                  compute_dtype=cfg.compute_dtype)
    u_raw = lconstraint(u_raw, ("batch", "seq", "rnn"))
    prefix = state.conv if state is not None else None
    u = causal_conv1d(u_raw, cast(params["conv_w"], u_raw.dtype),
                      params["conv_b"], prefix=prefix)
    h0 = state.h if state is not None else None
    h = rglru_scan(params, u, h0=h0)
    y = cast(h, cfg.compute_dtype) * gate
    y = dense(params["w_out"], y, "bsw,wd->bsd",
              compute_dtype=cfg.compute_dtype)
    y = lconstraint(y, ("batch", "seq_r", "embed"))
    if state is None:
        return y, None
    K = cfg.conv1d_width
    # carry the last K-1 conv inputs and the last recurrence state
    xp = jnp.concatenate([cast(state.conv, u_raw.dtype), u_raw], axis=1)
    new_state = RGLRUState(conv=xp[:, -(K - 1):], h=h[:, -1])
    return y, new_state


def decode_rglru(params, x, cfg, state: RGLRUState):
    """Single-token step.  x: [B,1,D]."""
    y, new_state = apply_rglru(params, x, cfg, state=state)
    return y, new_state
