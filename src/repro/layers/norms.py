"""RMSNorm / LayerNorm, computed in f32 and cast back."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.layers.common import ParamSpec


def rmsnorm_specs(d: int, unit_offset: bool = False):
    init = "zeros" if unit_offset else "ones"
    return {"scale": ParamSpec((d,), ("embed",), init=init)}


def layernorm_specs(d: int):
    return {"scale": ParamSpec((d,), ("embed",), init="ones"),
            "bias": ParamSpec((d,), ("embed",), init="zeros")}


def norm_specs(cfg, d: int | None = None):
    d = d or cfg.d_model
    if cfg.norm == "rmsnorm":
        return rmsnorm_specs(d, cfg.rmsnorm_unit_offset)
    return layernorm_specs(d)


def apply_norm(params, x, cfg, eps: float = 1e-6):
    orig = x.dtype
    x = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        x = x * jax.lax.rsqrt(var + eps)
        scale = params["scale"].astype(jnp.float32)
        if cfg.rmsnorm_unit_offset:
            scale = 1.0 + scale
        return (x * scale).astype(orig)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mean) * jax.lax.rsqrt(var + eps)
    out = x * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(orig)


def groupnorm_heads(x, scale, bias, eps: float = 64e-5):
    """Per-head group norm (RWKV6 wkv output).  x: [..., H, N]."""
    orig = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mean) * jax.lax.rsqrt(var + eps)
    out = x * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(orig)
