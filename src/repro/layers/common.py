"""Parameter-spec machinery shared by all layers.

A model is described once as a pytree of :class:`ParamSpec`.  From that single
source of truth we derive

* materialized parameters  (``materialize`` — smoke tests / real training),
* ``jax.ShapeDtypeStruct`` stand-ins  (``shape_structs`` — the dry run),
* ``NamedSharding``s via logical-axis rules (``repro.distributed.sharding``).

Logical axis names used throughout (mapped to mesh axes by the sharding
rules):

``embed``      residual stream width            (FSDP-shardable)
``heads``      query heads                      → model
``kv_heads``   kv heads (may be < model axis)   → replicated
``qkv``        head_dim of kv projections       → model (see DESIGN.md)
``mlp``        feed-forward hidden              → model
``vocab``      vocabulary                       → model
``experts``    MoE expert dimension             → model (EP)
``rnn``        RG-LRU / conv1d channel width    → model
``stack``      scanned layer-group dimension    → never sharded
``null``       never sharded
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: str = "float32"
    init: str = "fan_in"        # fan_in | normal | zeros | ones | constant
    scale: float = 1.0
    fan_in_axes: Tuple[int, ...] = (0,)   # which dims form fan-in for scaling

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def spec_map(fn: Callable[[ParamSpec], Any], tree: PyTree) -> PyTree:
    return jax.tree.map(fn, tree, is_leaf=is_spec)


def shape_structs(tree: PyTree, dtype_override: Optional[str] = None) -> PyTree:
    """ShapeDtypeStruct stand-ins (no allocation) — dry-run inputs."""
    def f(s: ParamSpec):
        dt = dtype_override or s.dtype
        return jax.ShapeDtypeStruct(s.shape, jnp.dtype(dt))
    return spec_map(f, tree)


def axes_tree(tree: PyTree) -> PyTree:
    return spec_map(lambda s: s.axes, tree)


def materialize(tree: PyTree, key: jax.Array,
                dtype_override: Optional[str] = None) -> PyTree:
    """Materialize real parameters (smoke tests / examples / training)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, max(len(leaves), 1))

    def one(s: ParamSpec, k):
        dt = jnp.dtype(dtype_override or s.dtype)
        if s.init == "zeros":
            return jnp.zeros(s.shape, dt)
        if s.init == "ones":
            return jnp.ones(s.shape, dt)
        if s.init == "constant":
            return jnp.full(s.shape, s.scale, dt)
        if s.init == "normal":
            return (jax.random.normal(k, s.shape, jnp.float32) * s.scale).astype(dt)
        if s.init == "fan_in":
            fan = max(int(np.prod([s.shape[a] for a in s.fan_in_axes])), 1)
            std = s.scale / math.sqrt(fan)
            return (jax.random.normal(k, s.shape, jnp.float32) * std).astype(dt)
        raise ValueError(s.init)

    return jax.tree.unflatten(treedef, [one(s, k) for s, k in zip(leaves, keys)])


def param_bytes(tree: PyTree) -> int:
    return sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
               for s in jax.tree.leaves(tree, is_leaf=is_spec))


def param_count_tree(tree: PyTree) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(tree, is_leaf=is_spec))


def stack_specs(tree: PyTree, n: int) -> PyTree:
    """Prepend a scanned ``stack`` dimension of size n to every spec."""
    def f(s: ParamSpec):
        return ParamSpec((n,) + s.shape, ("stack",) + s.axes, s.dtype,
                         s.init, s.scale,
                         tuple(a + 1 for a in s.fan_in_axes))
    return spec_map(f, tree)


# ---------------------------------------------------------------------------
# Logical sharding constraints (no-op outside an active rule context)
# ---------------------------------------------------------------------------

_ACTIVE_RULES: Optional[dict] = None


class activate_rules:
    """Context manager installing logical-axis → mesh-axis rules; while
    active, :func:`lconstraint` emits with_sharding_constraint."""

    def __init__(self, rules: Optional[dict]):
        self.rules = rules

    def __enter__(self):
        global _ACTIVE_RULES
        self._prev = _ACTIVE_RULES
        _ACTIVE_RULES = self.rules
        return self

    def __exit__(self, *exc):
        global _ACTIVE_RULES
        _ACTIVE_RULES = self._prev
        return False


def resolve_pspec(axes: Tuple[Optional[str], ...], rules: dict):
    """Logical axes → PartitionSpec with first-come-first-served mesh-axis
    conflict resolution (a mesh axis may shard at most one dimension)."""
    from jax.sharding import PartitionSpec as P
    used: set = set()
    out = []
    for name in axes:
        mesh_axis = rules.get(name) if name else None
        if mesh_axis is None:
            out.append(None)
            continue
        flat = (mesh_axis,) if isinstance(mesh_axis, str) else tuple(mesh_axis)
        if not flat or any(a in used for a in flat):
            out.append(None)
            continue
        used.update(flat)
        out.append(mesh_axis if isinstance(mesh_axis, str) else tuple(flat))
    return P(*out)


def lconstraint(x: jax.Array, axes: Tuple[Optional[str], ...]) -> jax.Array:
    """Constrain activation sharding by logical axis names (no-op when no
    rules are active, e.g. in single-device smoke tests)."""
    if _ACTIVE_RULES is None:
        return x
    return jax.lax.with_sharding_constraint(x, resolve_pspec(axes, _ACTIVE_RULES))


def cast(x, dtype):
    dt = jnp.dtype(dtype)
    return x.astype(dt) if x.dtype != dt else x


# ---------------------------------------------------------------------------
# GEMM backend dispatch: "xla" einsum vs the paper-dataflow Pallas kernel
# ---------------------------------------------------------------------------


def dense(w: jax.Array, x: jax.Array, subscripts: str, *,
          backend: str = "xla", bias: Optional[jax.Array] = None,
          compute_dtype=jnp.bfloat16) -> jax.Array:
    """Linear layer core.  ``subscripts`` is the einsum string x,w->y.

    backend "pallas_ws" routes 2-D GEMMs through the weight-stationary
    kernel implementing the paper's dataflow (see repro.kernels.matmul_ws);
    everything else (and all CPU dry-run paths) uses XLA einsum.

    w8a8 serving: a dict weight {"q": int8, "s": scale} runs the paper's
    8-bit datapath (true s8 dot — §Perf iteration C1)."""
    if isinstance(w, dict) and "q" in w:
        from repro.core.quantize import w8_einsum
        y = w8_einsum(subscripts, x, w["q"], w["s"],
                      compute_dtype=compute_dtype)
        if bias is not None:
            y = y + bias
        return y
    x = cast(x, compute_dtype)
    w = cast(w, compute_dtype)
    if backend == "pallas_ws" and w.ndim == 2:
        from repro.kernels import ops as kops
        lead = x.shape[:-1]
        y = kops.matmul_ws(x.reshape(-1, x.shape[-1]), w, bias=bias)
        return y.reshape(*lead, w.shape[-1])
    # preferred_element_type pins the dot output to the compute dtype, so
    # model-parallel partial sums are all-reduced in bf16, not the f32
    # accumulator dtype — halves TP collective wire (EXPERIMENTS.md §Perf,
    # iteration A1).  JAX propagates this to the AD transpose dots, so
    # weight-gradient reductions get the same halving.
    y = jnp.einsum(subscripts, x, w,
                   preferred_element_type=jnp.dtype(compute_dtype))
    if bias is not None:
        y = y + bias
    return y
