"""Mixture-of-Experts feed-forward (GShard-style capacity, scatter dispatch).

Dataflow (expert-parallel friendly — see DESIGN.md §Distribution):

1. router logits → top-k experts per token (f32 softmax),
2. position-in-expert via a per-*group* cumulative count (groups = batch
   rows by default, so the cumsum never crosses a data shard),
3. scatter tokens into a capacity-bounded buffer [groups, E, C, D]
   (overflow tokens are dropped — capacity_factor bounds the blow-up),
4. per-expert GEMMs: einsum over the E-sharded buffer — compute is local
   to the expert's device(s) (this is EP),
5. gather back + combine weighted by gate probabilities.

The buffer einsums carry ~top_k·capacity_factor× the token activations —
the inherent cost of top-k routing, equal to what an all-to-all dispatch
would move.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.layers.common import ParamSpec, cast, dense, lconstraint
from repro.layers.mlp import mlp_specs, apply_mlp, _act


def moe_specs(cfg):
    m = cfg.moe
    d, f, e = cfg.d_model, m.expert_ff, m.num_experts
    specs = {
        "router": ParamSpec((d, e), ("embed", "experts"), init="fan_in"),
        "wi_gate": ParamSpec((e, d, f), ("experts", "embed", "mlp"),
                             fan_in_axes=(1,)),
        "wi_up": ParamSpec((e, d, f), ("experts", "embed", "mlp"),
                           fan_in_axes=(1,)),
        "wo": ParamSpec((e, f, d), ("experts", "mlp", "embed"),
                        fan_in_axes=(1,)),
    }
    if m.num_shared:
        # DeepSeekMoE: shared experts form one dense gated MLP
        specs["shared"] = mlp_specs(cfg, d_ff=m.num_shared * f)
    return specs


def _capacity(tokens_per_group: int, m) -> int:
    c = int(tokens_per_group * m.top_k * m.capacity_factor / m.num_experts)
    return max(8, -(-c // 8) * 8)   # round up to 8 for clean tiling


def apply_moe(params, x, cfg, *, train: bool = False,
              rng=None) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, D] → (out [B, S, D], aux_loss scalar)."""
    m = cfg.moe
    B, S, D = x.shape
    G = m.num_groups or B
    Tg = (B * S) // G
    E, K = m.num_experts, m.top_k
    C = _capacity(Tg, m)

    xg = x.reshape(G, Tg, D)
    xg = lconstraint(xg, ("batch", None, "embed"))

    # ---- router (f32 for a stable softmax) -----------------------------
    logits = jnp.einsum("gtd,de->gte", cast(xg, jnp.float32),
                        cast(params["router"], jnp.float32))
    if train and m.router_jitter and rng is not None:
        logits += m.router_jitter * jax.random.normal(rng, logits.shape)
    probs = jax.nn.softmax(logits, axis=-1)                    # [G,Tg,E]
    gate_vals, gate_idx = jax.lax.top_k(probs, K)              # [G,Tg,K]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)           # renormalize

    # ---- load-balancing auxiliary loss (Switch/GShard form) ------------
    me = jnp.mean(probs, axis=1)                               # [G,E]
    ce = jnp.mean(
        jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32), axis=1)
    aux = jnp.mean(jnp.sum(me * ce, axis=-1)) * E * m.aux_loss_weight

    # ---- position-in-expert --------------------------------------------
    flat_idx = gate_idx.reshape(G, Tg * K)                     # [G,TK]
    onehot = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)      # [G,TK,E]
    pos_all = jnp.cumsum(onehot, axis=1) - onehot              # count before me
    pos = jnp.take_along_axis(
        pos_all, flat_idx[..., None], axis=-1)[..., 0]         # [G,TK]
    keep = pos < C
    slot = flat_idx * C + jnp.where(keep, pos, 0)              # [G,TK]

    # ---- dispatch --------------------------------------------------------
    # Only a small int32 index map is ever *scattered*; the activations move
    # through gathers along G-sharded axes (local per data shard) and one
    # contiguous buffer reshard G↔E (the EP all-to-all).  Scattering the
    # [G,TK,D] activations directly makes GSPMD replicate+all-reduce the
    # 10+ GB buffer every layer (§Perf iteration 1).
    TK = Tg * K
    gidx = jnp.broadcast_to(jnp.arange(G)[:, None], slot.shape)
    sentinel = TK                                  # → pad row (zeros)
    rows = jnp.where(keep, jnp.arange(TK)[None, :], sentinel)
    slot_to_row = jnp.full((G, E * C), sentinel, jnp.int32)
    slot_to_row = slot_to_row.at[gidx, slot].min(rows, mode="drop")
    token_of_slot = jnp.where(slot_to_row < sentinel,
                              slot_to_row // K, Tg)            # [G,EC]
    xpad = jnp.concatenate(
        [cast(xg, cfg.compute_dtype),
         jnp.zeros((G, 1, D), jnp.dtype(cfg.compute_dtype))], axis=1)
    buf = jnp.take_along_axis(xpad, token_of_slot[..., None], axis=1)
    buf = lconstraint(buf, ("batch", None, "embed"))           # G-local gather
    buf = buf.reshape(G, E, C, D)
    buf = lconstraint(buf, ("batch", "experts", None, "embed"))  # EP reshard

    # ---- expert GEMMs (E-sharded: expert parallel) ----------------------
    wg = cast(params["wi_gate"], cfg.compute_dtype)
    wu = cast(params["wi_up"], cfg.compute_dtype)
    wo = cast(params["wo"], cfg.compute_dtype)
    h = _act(cfg.mlp_act)(jnp.einsum("gecd,edf->gecf", buf, wg))
    h = h * jnp.einsum("gecd,edf->gecf", buf, wu)
    h = lconstraint(h, ("batch", "experts", None, "mlp"))
    yb = jnp.einsum("gecf,efd->gecd", h, wo)                   # [G,E,C,D]
    yb = lconstraint(yb, ("batch", "experts", None, "embed"))

    # ---- combine: gather back + gate-weighted sum over K ----------------
    # Reshard the expert outputs from E-sharded (EP) back to group-sharded
    # BEFORE the gather: one explicit all-to-all-sized move instead of the
    # replicate-the-buffer fallback GSPMD picks for a gather from a sharded
    # axis (§Perf iteration 1 — 394s → see EXPERIMENTS.md).
    yfl = lconstraint(yb.reshape(G, E * C, D), ("batch", None, "embed"))
    got = jnp.take_along_axis(yfl, slot[..., None], axis=1)    # [G,TK,D]
    got = jnp.where(keep[..., None], got, 0)
    got = got.reshape(G, Tg, K, D)
    y = jnp.einsum("gtkd,gtk->gtd", cast(got, jnp.float32),
                   cast(gate_vals, jnp.float32))
    y = cast(y, cfg.compute_dtype).reshape(B, S, D)

    # ---- shared experts (always-on) --------------------------------------
    if m.num_shared:
        y = y + apply_mlp(params["shared"], x, cfg)
    return lconstraint(y, ("batch", "seq_r", "embed")), aux
