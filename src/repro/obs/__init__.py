"""obs — the telemetry subsystem: spans, metrics, per-layer profiles,
drift detection.

One import surface for everything observable in the runtime:

* ``obs.span("compile")`` / ``obs.span("layer:conv1", psums=...)`` —
  nestable trace spans (obs/trace.py) exported as Chrome
  ``chrome://tracing`` JSON that Perfetto loads directly;
* ``obs.metrics`` — the process-global :class:`MetricsRegistry`
  (obs/metrics.py): counters, gauges, p50/p90/p99 histograms, JSONL
  export, ``reset()`` for tests;
* ``obs.profile.profile_network`` — per-layer wall time / psums /
  achieved GOPS / calibrated-model prediction over any compiled
  ``NetworkPlan`` program, plus the live drift detector
  (obs/profile.py).

**Disabled by default, zero overhead when disabled.**  ``obs.span``
checks one module flag and returns a shared no-op context manager; the
tier-1 numerical tests and the §5.2 anchors run with the subsystem off
and cannot observe it.  Enable with ``obs.enable()`` or by exporting
``REPRO_OBS=1`` before import.  ``obs.metrics`` is live regardless of
the flag — incrementing a counter is nanoseconds and serving code
(``ConvNetEngine.stats``) depends on its counts — but nothing *records
spans* or *profiles layers* unless enabled.

``obs.dump(dir)`` writes the trace (``obs_trace.json``) and the metrics
(``obs_metrics.jsonl``) — the CI ``obs-smoke`` lane uploads both.

Dependency-free (stdlib only): importable before jax, usable in every
process the runtime runs in.
"""

from __future__ import annotations

import os
from typing import Any, Optional

from repro.obs.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                               MetricsRegistry, default_buckets)
from repro.obs.trace import NOOP_SPAN, Span, Tracer  # noqa: F401

# -- global state -----------------------------------------------------------

_enabled = False
tracer = Tracer()
metrics = MetricsRegistry()


def enable() -> None:
    """Turn span recording / profiling on (idempotent)."""
    global _enabled
    _enabled = True


def disable() -> None:
    """Back to the zero-overhead no-op sink (idempotent).  Collected
    events/metrics stay until ``reset()``."""
    global _enabled
    _enabled = False


def enabled() -> bool:
    return _enabled


def reset() -> None:
    """Clear the trace buffer and zero every metric — the test contract:
    enable → exercise → assert → reset leaves nothing behind."""
    tracer.reset()
    metrics.reset()


def span(name: str, **args: Any):
    """A trace span when enabled, the shared no-op otherwise.  The
    disabled path is one global load + one branch — no allocation, no
    clock read."""
    if not _enabled:
        return NOOP_SPAN
    return tracer.span(name, **args)


def instant(name: str, **args: Any) -> None:
    """A zero-duration trace mark (drift warnings etc.); no-op when
    disabled."""
    if _enabled:
        tracer.instant(name, **args)


def dump(out_dir: str = ".", prefix: str = "obs") -> Optional[dict]:
    """Export the Chrome trace + metrics JSONL into ``out_dir``;
    returns the written paths (None when disabled — nothing was
    collected)."""
    if not _enabled:
        return None
    os.makedirs(out_dir, exist_ok=True)
    return {
        "trace": tracer.export(
            os.path.join(out_dir, f"{prefix}_trace.json")),
        "metrics": metrics.export_jsonl(
            os.path.join(out_dir, f"{prefix}_metrics.jsonl")),
    }


# REPRO_OBS=1 (or any non-empty value except "0") enables at import — the
# env-var path CI's obs-smoke lane and ad-hoc benchmark runs use.
if os.environ.get("REPRO_OBS", "0") not in ("", "0"):
    enable()
