"""Per-layer profiler + live model-drift detection.

PR 7 made the cost model *calibrated* (benchmarks/calibrate.py fits a
``CalibrationTable`` onto the §5.2 terms) but only compared it against
reality inside offline benchmark scripts (``network_bench``'s
``measured_vs_predicted`` section).  This module makes that comparison a
*runtime* capability:

* :func:`profile_network` runs a quantized ``NetworkPlan`` program
  layer-at-a-time through the SAME int8 node semantics the compiled
  program executes (``network.int8_forward`` with a node hook — the
  paper's single IP core processes "a convolutional layer at a time"
  (§4.2), so the walk is the hardware schedule, not an approximation),
  wall-clocking each node with monotonic clocks and emitting one
  :class:`LayerProfile` per node: wall µs, psums, achieved GOPS (the
  paper's psums/second accounting), and the cost model's predicted µs —
  calibrated when a table is passed, analytic otherwise.

* :class:`DriftDetector` flags layers whose measured/predicted ratio
  leaves a configurable band — the live version of the offline
  ``measured_vs_predicted`` check.  A drifting layer means the
  calibration no longer describes the machine (thermal throttling, a
  toolchain change, a mis-fitted table) and the autotuner's verdicts
  are stale: re-run benchmarks/calibrate.py.  Events also land in
  ``obs.metrics`` (counter ``obs.drift.events``) and as instant marks
  in the trace, so a Perfetto view shows *where* the model lost the
  machine.

Profiling imports jax lazily and is only ever called explicitly (or by
the engine when obs is enabled) — the obs package itself stays
dependency-free.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro import obs

# measured/predicted inside [lo, hi] is "calibration holds"; outside is
# drift.  The default band is generous (2× each way) because even a
# fitted table carries per-layer error — the offline fit reports mean
# |error|, not worst-case.
DEFAULT_DRIFT_BAND = (0.5, 2.0)


@dataclass(frozen=True)
class LayerProfile:
    """One node's profile record: measurement, workload, prediction."""
    index: int
    name: str
    kind: str
    wall_us: float
    psums: int                         # per image (the paper accounting)
    batch: int
    gops: float                        # achieved, psums·batch / wall / 1e9
    predicted_us: Optional[float]      # None: the model prices it free
    pipelined: Optional[bool]          # conv nodes: kernel variant
    calibrated: bool

    @property
    def ratio(self) -> Optional[float]:
        """measured / predicted — the drift signal (None when the model
        prices the node free: merges, pools, flatten)."""
        if not self.predicted_us:
            return None
        return self.wall_us / self.predicted_us

    def to_dict(self) -> Dict[str, Any]:
        return {"index": self.index, "name": self.name, "kind": self.kind,
                "wall_us": self.wall_us, "psums": self.psums,
                "batch": self.batch, "gops": self.gops,
                "predicted_us": self.predicted_us, "ratio": self.ratio,
                "pipelined": self.pipelined, "calibrated": self.calibrated}


@dataclass(frozen=True)
class DriftEvent:
    """One flagged layer: its measured/predicted ratio left the band."""
    name: str
    wall_us: float
    predicted_us: float
    ratio: float
    band: Tuple[float, float]

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "wall_us": self.wall_us,
                "predicted_us": self.predicted_us, "ratio": self.ratio,
                "band": list(self.band)}


@dataclass(frozen=True)
class NetworkProfile:
    """The per-layer profile of one forward pass."""
    network: str
    batch: int
    records: Tuple[LayerProfile, ...]
    calibrated: bool
    drift: Tuple[DriftEvent, ...] = ()

    @property
    def layer_names(self) -> List[str]:
        return [r.name for r in self.records]

    @property
    def total_wall_us(self) -> float:
        return sum(r.wall_us for r in self.records)

    def to_dict(self) -> Dict[str, Any]:
        return {"network": self.network, "batch": self.batch,
                "calibrated": self.calibrated,
                "total_wall_us": self.total_wall_us,
                "layers": [r.to_dict() for r in self.records],
                "drift": [d.to_dict() for d in self.drift]}


class DriftDetector:
    """Flag layers whose measured/predicted wall-time ratio leaves
    ``band`` — live model-drift detection over profile records.

    ``min_wall_us`` suppresses noise-floor layers: a 2 µs pool node
    doubling its time is clock jitter, not drift.  Each flagged layer
    increments ``obs.metrics`` counter ``obs.drift.events`` and drops an
    instant mark into the trace (when obs is enabled), so drift is
    visible both in aggregate and on the timeline."""

    def __init__(self, band: Tuple[float, float] = DEFAULT_DRIFT_BAND,
                 min_wall_us: float = 0.0):
        lo, hi = band
        if not (0.0 < lo < hi):
            raise ValueError(f"drift band wants 0 < lo < hi, got {band}")
        self.band = (float(lo), float(hi))
        self.min_wall_us = float(min_wall_us)

    def check(self, records: Sequence[LayerProfile]) -> List[DriftEvent]:
        lo, hi = self.band
        events: List[DriftEvent] = []
        for r in records:
            ratio = r.ratio
            if ratio is None or r.wall_us < self.min_wall_us:
                continue
            if lo <= ratio <= hi:
                continue
            ev = DriftEvent(name=r.name, wall_us=r.wall_us,
                            predicted_us=float(r.predicted_us),
                            ratio=ratio, band=self.band)
            events.append(ev)
            obs.metrics.counter("obs.drift.events").inc()
            obs.instant("drift", layer=r.name, ratio=round(ratio, 3),
                        band=list(self.band))
        return events


def _predicted_us(sp_kind: str, psums: int, tile_plan, calib,
                  cfg) -> Optional[float]:
    """The cost model's wall-time prediction for one node, priced exactly
    the way the planner/autotuner price it (perfmodel.pipeline_estimate
    for planned convs, calibrated compute cycles for GEMMs); None for
    nodes the model prices free (merges, pools, flatten — the fused
    epilogue / output-BRAM crossbar absorb them)."""
    from repro.core import perfmodel
    clock = float(getattr(calib, "clock_hz", None) or cfg.clock_hz)
    if tile_plan is not None:
        est = perfmodel.pipeline_estimate(tile_plan, psums, cfg, calib)
        cyc = est["pipelined_cycles" if tile_plan.pipelined
                  else "sequential_cycles"]
        return cyc / clock * 1e6
    if not psums:
        return None
    cyc = perfmodel.calibrated_cycles(psums, cfg, calib)
    if calib is not None:
        cyc += float(getattr(calib, "per_call_overhead_cycles", 0.0))
    return cyc / clock * 1e6


def profile_network(qnet, x, *, core_config=None,
                    tile_plans: Optional[Sequence] = None,
                    calib=None, warmup: int = 1,
                    drift: Optional[DriftDetector] = None,
                    perf_cfg=None) -> NetworkProfile:
    """Profile one int8 forward pass layer-at-a-time.

    Runs ``network.int8_forward`` EAGERLY (no jit) with a node hook that
    blocks on each node's output and wall-clocks it — the per-node walk
    is the same topological schedule the single layer-at-a-time IP core
    executes, so the layer set matches ``NetworkPlan`` topology exactly
    (one record per node, asserted in tests).  Each node gets a
    ``layer:<name>`` span in the trace when obs is enabled.

    ``calib`` (a core.calibration.CalibrationTable) prices the predicted
    column under the fitted terms — measured and predicted then share a
    scale through the fitted ``clock_hz`` and the measured/predicted
    ratio is meaningful; without a table the predicted column is the
    analytic §5.2 FPGA time (a cross-platform reference, NOT comparable
    to interpret-mode wall time — pass a ``drift`` detector only with a
    table).  ``warmup`` extra passes absorb first-call compilation.

    Eager per-node dispatch is slower than the fused jitted program —
    profiling is a diagnostic mode, never the serving path."""
    import jax

    from repro.core import network, perfmodel
    from repro.core.convcore import ConvCoreConfig, get_backend

    if core_config is None:
        core_config = ConvCoreConfig(int8=True)
    plan = qnet.plan
    if tile_plans is None:
        tile_plans = network.program_tile_plans(plan, core_config)
    cfg = perf_cfg if perf_cfg is not None else perfmodel.IPCoreConfig()
    backend = get_backend(core_config.backend)
    batch = int(x.shape[0]) if getattr(x, "ndim", 4) == 4 else 1
    psum_rows = dict(plan.psum_table())
    names = plan.node_names()

    for _ in range(max(warmup, 0)):
        jax.block_until_ready(network.int8_forward(
            qnet, x, backend=backend, tile_plans=tile_plans))

    intervals: List[Tuple[int, int]] = []    # per-node (t0_ns, t1_ns)
    t_prev = [time.perf_counter_ns()]

    def hook(i, name, sp, h):
        jax.block_until_ready(h)
        t1 = time.perf_counter_ns()
        intervals.append((t_prev[0], t1))
        t_prev[0] = time.perf_counter_ns()   # exclude the hook's own cost

    with obs.span("profile", network=plan.name, batch=batch):
        t_prev[0] = time.perf_counter_ns()
        out = network.int8_forward(qnet, x, backend=backend,
                                   tile_plans=tile_plans, node_hook=hook)
        jax.block_until_ready(out)

    records: List[LayerProfile] = []
    hist = obs.metrics.histogram(f"profile.layer_us.{plan.name}")
    for i, sp in enumerate(plan.layers):
        psums = psum_rows[names[i]]
        t0, t1 = intervals[i]
        wall = (t1 - t0) / 1e3
        pred = _predicted_us(sp.kind, psums, tile_plans[i], calib, cfg)
        rec = LayerProfile(
            index=i, name=names[i], kind=sp.kind, wall_us=wall,
            psums=psums, batch=batch,
            gops=(psums * batch) / (wall * 1e-6) / 1e9 if wall > 0 else 0.0,
            predicted_us=pred,
            pipelined=(bool(tile_plans[i].pipelined)
                       if tile_plans[i] is not None else None),
            calibrated=calib is not None)
        records.append(rec)
        if obs.enabled():
            # the measured walk as trace events with their REAL intervals
            # (timing happened inside the hook, so the spans are emitted
            # retroactively — ts/dur are what Perfetto nests on)
            obs.tracer._record(
                f"layer:{names[i]}", t0, t1,
                {"kind": sp.kind, "psums": psums,
                 "predicted_us": None if pred is None else round(pred, 2)})
        hist.observe(wall)

    events: Tuple[DriftEvent, ...] = ()
    if drift is not None:
        events = tuple(drift.check(records))
    return NetworkProfile(network=plan.name, batch=batch,
                          records=tuple(records), calibrated=calib is not None,
                          drift=events)
