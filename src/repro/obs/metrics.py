"""Process-local metrics: counters, gauges, fixed-bucket histograms.

The serving/training hot paths need numbers that survive aggregation —
"how many requests", "what is the p99 request latency", "how full are
the batches" — without dragging in a metrics daemon.  This module is a
dependency-free registry of three primitives:

* :class:`Counter` — monotonically increasing int (requests, batches,
  padded images, drift events);
* :class:`Gauge` — last-write-wins float (images/sec, batch fill ratio);
* :class:`Histogram` — FIXED log-spaced buckets with p50/p90/p99
  summaries.  Fixed buckets are the deliberate choice over reservoir
  sampling: observation is O(log buckets) with bounded memory forever
  (a "millions of users" serving path cannot keep raw samples), and two
  histograms merge by adding counts.  Percentiles interpolate inside
  the bucket, so their error is bounded by the bucket ratio (~12% with
  the default 20-buckets-per-decade layout); exact min/max/sum/count
  ride along and clamp the estimates.

Everything supports ``reset()`` — the test contract: a test may enable
obs, exercise a path, assert on the registry, and reset without leaking
state into the next test.  ``export_jsonl`` writes one JSON object per
metric (the CI artifact format).

Thread-safe: each instrument takes a lock per observation; the registry
locks around instrument creation.  No numpy, no jax — the obs subsystem
must be importable (and no-op) everywhere, including before jax init.
"""

from __future__ import annotations

import bisect
import json
import math
import threading
import time
from typing import Any, Dict, Iterable, List, Optional, Sequence


def default_buckets(lo: float = 1.0, hi: float = 1e8,
                    per_decade: int = 20) -> List[float]:
    """Log-spaced bucket upper bounds covering [lo, hi] — the default is
    1 µs … 100 s at ~12% resolution, which brackets everything from one
    int8 GEMM dispatch to an interpret-mode large-map pass."""
    n = int(math.ceil(per_decade * math.log10(hi / lo)))
    return [lo * 10 ** (i / per_decade) for i in range(n + 1)]


class Counter:
    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        with self._lock:
            return self._value

    def reset(self) -> None:
        with self._lock:
            self._value = 0

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "type": "counter", "value": self.value}


class Gauge:
    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value: Optional[float] = None

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    @property
    def value(self) -> Optional[float]:
        with self._lock:
            return self._value

    def reset(self) -> None:
        with self._lock:
            self._value = None

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "type": "gauge", "value": self.value}


class Histogram:
    """Fixed-bucket histogram with interpolated percentiles.

    ``bounds`` are ascending bucket UPPER bounds; an observation lands in
    the first bucket whose bound is ≥ the value, values beyond the last
    bound land in an overflow bucket.  ``percentile(p)`` walks the
    cumulative counts to the target rank and interpolates linearly
    inside the bucket (clamped to the exact observed min/max), so the
    estimate is within one bucket ratio of the true order statistic —
    the property tests/test_obs.py checks against numpy."""

    __slots__ = ("name", "bounds", "_lock", "_counts", "_overflow",
                 "_count", "_sum", "_min", "_max")

    def __init__(self, name: str, bounds: Optional[Sequence[float]] = None):
        self.name = name
        self.bounds = list(bounds) if bounds is not None \
            else default_buckets()
        if any(b <= a for a, b in zip(self.bounds, self.bounds[1:])):
            raise ValueError(f"histogram {name!r}: bucket bounds must be "
                             "strictly ascending")
        self._lock = threading.Lock()
        self._counts = [0] * len(self.bounds)
        self._overflow = 0
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        i = bisect.bisect_left(self.bounds, v)
        with self._lock:
            if i < len(self.bounds):
                self._counts[i] += 1
            else:
                self._overflow += 1
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    def observe_many(self, vs: Iterable[float]) -> None:
        for v in vs:
            self.observe(v)

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    @property
    def mean(self) -> float:
        with self._lock:
            return self._sum / self._count if self._count else 0.0

    def percentile(self, p: float) -> float:
        """Interpolated percentile estimate, p in [0, 100]."""
        if not 0.0 <= p <= 100.0:
            raise ValueError(f"percentile wants p in [0, 100], got {p}")
        with self._lock:
            n = self._count
            if n == 0:
                return 0.0
            # nearest-rank target (1-indexed), then interpolate in-bucket
            rank = max(1, math.ceil(p / 100.0 * n))
            cum = 0
            for i, c in enumerate(self._counts):
                if c == 0:
                    continue
                if cum + c >= rank:
                    lo = self.bounds[i - 1] if i > 0 else min(
                        self._min, self.bounds[0])
                    hi = self.bounds[i]
                    frac = (rank - cum) / c
                    est = lo + (hi - lo) * frac
                    return min(max(est, self._min), self._max)
                cum += c
            return self._max            # rank fell in the overflow bucket

    def summary(self) -> Dict[str, float]:
        with self._lock:
            n, s = self._count, self._sum
            mn = self._min if n else 0.0
            mx = self._max if n else 0.0
        return {"count": n, "sum": s, "min": mn, "max": mx,
                "mean": s / n if n else 0.0,
                "p50": self.percentile(50.0),
                "p90": self.percentile(90.0),
                "p99": self.percentile(99.0)}

    def reset(self) -> None:
        with self._lock:
            self._counts = [0] * len(self.bounds)
            self._overflow = 0
            self._count = 0
            self._sum = 0.0
            self._min = math.inf
            self._max = -math.inf

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "type": "histogram", **self.summary()}


class MetricsRegistry:
    """A named collection of instruments.  ``counter``/``gauge``/
    ``histogram`` get-or-create (idempotent, type-checked), ``reset()``
    zeroes every instrument (the test contract), ``export_jsonl`` writes
    one JSON line per instrument."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, Any] = {}

    def _get(self, name: str, cls, *args):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, *args)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  bounds: Optional[Sequence[float]] = None) -> Histogram:
        if bounds is None:
            return self._get(name, Histogram)
        return self._get(name, Histogram, bounds)

    def get(self, name: str):
        with self._lock:
            return self._metrics.get(name)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def reset(self) -> None:
        with self._lock:
            metrics = list(self._metrics.values())
        for m in metrics:
            m.reset()

    def clear(self) -> None:
        """Drop every instrument (reset() keeps them registered at
        zero)."""
        with self._lock:
            self._metrics.clear()

    def to_dicts(self) -> List[Dict[str, Any]]:
        with self._lock:
            metrics = [self._metrics[k] for k in sorted(self._metrics)]
        return [m.to_dict() for m in metrics]

    def export_jsonl(self, path: str) -> str:
        """One JSON object per line per instrument, stamped with export
        wall time (the only place wall time belongs: provenance, not
        measurement)."""
        ts = time.time()
        with open(path, "w") as f:
            for d in self.to_dicts():
                d["exported_at"] = ts
                f.write(json.dumps(d) + "\n")
        return path
