"""Nestable tracing spans with a Chrome ``chrome://tracing`` exporter.

The paper's value proposition is a *measured* number (0.224 GOPS per IP
core, §5.2), and an accelerator runtime you cannot observe is one you
cannot tune: per-layer latency breakdowns are what the FPGA-accelerator
survey literature (Guo et al. 2017, Jiang et al. 2025 — PAPERS.md) names
as the prerequisite for design-space exploration.  This module is the
span half of the obs subsystem: ``span("compile")`` /
``span("layer:conv1")`` context managers that nest, survive exceptions,
and serialize to the Chrome trace-event JSON format that Perfetto /
``chrome://tracing`` load directly.

Design constraints (the reason this is not a logging veneer):

* **monotonic clocks** — timestamps come from ``time.perf_counter_ns``
  (never ``time.time``: NTP steps corrupt wall-clock deltas), expressed
  in microseconds relative to the tracer's origin;
* **thread-safe context stack** — each thread keeps its own span stack
  (``threading.local``) so concurrent engine/scheduler threads nest
  independently, and the shared event buffer appends under a lock;
* **zero overhead when disabled** — the module-level :func:`span`
  checks one global flag and returns a singleton no-op context manager;
  no allocation, no clock read, no lock.  Tier-1 numerics and the §5.2
  anchor assertions run with tracing disabled and must not be able to
  tell it exists.

Dependency-free by construction: stdlib only.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional

# Chrome trace-event "complete" phase: one event carries both ts and dur.
_PHASE_COMPLETE = "X"


class _NoopSpan:
    """The disabled-path singleton: enter/exit do nothing, attribute
    writes are swallowed.  Identity-stable so tests can assert the
    disabled path allocates nothing per call."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **args: Any) -> "_NoopSpan":
        return self


NOOP_SPAN = _NoopSpan()


class Span:
    """One live span: a context manager that records a complete trace
    event on exit — including when the body raises (the event is
    recorded with an ``error`` arg and the exception propagates)."""

    __slots__ = ("tracer", "name", "args", "_t0", "_parent")

    def __init__(self, tracer: "Tracer", name: str, args: Dict[str, Any]):
        self.tracer = tracer
        self.name = name
        self.args = args
        self._t0 = 0
        self._parent: Optional[str] = None

    def set(self, **args: Any) -> "Span":
        """Attach/override args on the live span (e.g. results computed
        inside the body)."""
        self.args.update(args)
        return self

    def __enter__(self) -> "Span":
        stack = self.tracer._stack()
        if stack:
            self._parent = stack[-1].name
        stack.append(self)
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        t1 = time.perf_counter_ns()
        stack = self.tracer._stack()
        # exception safety: pop THIS span even if an inner span leaked
        while stack and stack[-1] is not self:
            stack.pop()
        if stack:
            stack.pop()
        if exc_type is not None:
            self.args["error"] = exc_type.__name__
        if self._parent is not None:
            self.args.setdefault("parent", self._parent)
        self.tracer._record(self.name, self._t0, t1, self.args)
        return False                      # never swallow the exception


class Tracer:
    """A thread-safe trace-event collector.

    Spans append Chrome trace-event dicts to a shared buffer; the
    per-thread nesting stack lives in ``threading.local`` so spans on
    different threads never interleave their parentage.  ``export``
    writes the ``{"traceEvents": [...]}`` JSON object Perfetto and
    ``chrome://tracing`` load as-is."""

    def __init__(self):
        self._lock = threading.Lock()
        self._events: List[Dict[str, Any]] = []
        self._local = threading.local()
        self._origin_ns = time.perf_counter_ns()
        self._pid = os.getpid()

    # -- span plumbing ------------------------------------------------------

    def _stack(self) -> List[Span]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def span(self, name: str, **args: Any) -> Span:
        return Span(self, name, dict(args))

    def current_span(self) -> Optional[Span]:
        st = self._stack()
        return st[-1] if st else None

    def _record(self, name: str, t0_ns: int, t1_ns: int,
                args: Dict[str, Any]) -> None:
        ev = {
            "name": name,
            "ph": _PHASE_COMPLETE,
            "ts": (t0_ns - self._origin_ns) / 1e3,       # µs
            "dur": (t1_ns - t0_ns) / 1e3,                # µs
            "pid": self._pid,
            "tid": threading.get_ident(),
        }
        if args:
            ev["args"] = args
        with self._lock:
            self._events.append(ev)

    # -- instant events (marks) ---------------------------------------------

    def instant(self, name: str, **args: Any) -> None:
        """A zero-duration mark (Chrome phase "i") — drift warnings and
        other point-in-time annotations."""
        ev = {
            "name": name,
            "ph": "i",
            "s": "t",                                    # thread-scoped
            "ts": (time.perf_counter_ns() - self._origin_ns) / 1e3,
            "pid": self._pid,
            "tid": threading.get_ident(),
        }
        if args:
            ev["args"] = args
        with self._lock:
            self._events.append(ev)

    # -- inspection / export -------------------------------------------------

    def events(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._events)

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def reset(self) -> None:
        with self._lock:
            self._events.clear()
        self._origin_ns = time.perf_counter_ns()

    def to_chrome_trace(self) -> Dict[str, Any]:
        return {"traceEvents": self.events(), "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        """Write the Chrome trace JSON; returns the path (handy for CI
        artifact steps)."""
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f, indent=1)
        return path
