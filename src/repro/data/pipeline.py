"""Deterministic, seekable data pipeline.

Fault-tolerance contract: the pipeline state is a single integer cursor
(the global step); ``batch_at(step)`` is a pure function, so restoring a
checkpoint and replaying from its step yields bit-identical batches —
tested in tests/test_trainer_fault.py.

Sources:
* SyntheticLM  — counting-friendly synthetic token streams with a learnable
  structure (a fixed Markov-ish mixing so training loss actually drops);
* TextFile     — byte-level tokenization of a local file, packed into
  fixed-length sequences (used by examples/train_llama_tiny.py).

Per-host sharding: each process materializes only its slice
(process_index/process_count), so the pipeline scales to multi-host pods.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Optional

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "synthetic"        # synthetic | textfile
    path: Optional[str] = None     # for textfile


class SyntheticLM:
    """Deterministic synthetic LM data with learnable structure: token t+1
    depends on token t through a fixed permutation + noise, so models fit it
    quickly (loss decreases) yet batches are a pure function of step."""

    def __init__(self, cfg: DataConfig, process_index: int = 0,
                 process_count: int = 1):
        self.cfg = cfg
        self.process_index = process_index
        self.process_count = process_count
        assert cfg.global_batch % process_count == 0
        self.local_batch = cfg.global_batch // process_count
        rng = np.random.default_rng(cfg.seed)
        self.perm = rng.permutation(cfg.vocab_size)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        ss = np.random.SeedSequence(
            entropy=c.seed,
            spawn_key=(step, self.process_index))
        rng = np.random.default_rng(ss)
        first = rng.integers(0, c.vocab_size, size=(self.local_batch, 1))
        noise = rng.random((self.local_batch, c.seq_len)) < 0.1
        toks = np.empty((self.local_batch, c.seq_len + 1), np.int64)
        toks[:, :1] = first
        for t in range(1, c.seq_len + 1):
            nxt = self.perm[toks[:, t - 1]]
            rnd = rng.integers(0, c.vocab_size, size=self.local_batch)
            toks[:, t] = np.where(noise[:, t - 1], rnd, nxt)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


class TextFile:
    """Byte-level LM over a local file, deterministic packing by step."""

    def __init__(self, cfg: DataConfig, process_index: int = 0,
                 process_count: int = 1):
        assert cfg.path is not None
        self.cfg = cfg
        with open(cfg.path, "rb") as f:
            data = np.frombuffer(f.read(), np.uint8)
        if len(data) < cfg.seq_len + 1:
            reps = (cfg.seq_len + 1) // max(len(data), 1) + 1
            data = np.tile(data, reps)
        self.data = data.astype(np.int32) % cfg.vocab_size
        self.process_index = process_index
        self.process_count = process_count
        self.local_batch = cfg.global_batch // process_count

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        n = len(self.data) - c.seq_len - 1
        rng = np.random.default_rng(
            np.random.SeedSequence(entropy=c.seed,
                                   spawn_key=(step, self.process_index)))
        starts = rng.integers(0, n, size=self.local_batch)
        toks = np.stack([self.data[s:s + c.seq_len + 1] for s in starts])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_pipeline(cfg: DataConfig, process_index: int = 0,
                  process_count: int = 1):
    if cfg.kind == "synthetic":
        return SyntheticLM(cfg, process_index, process_count)
    if cfg.kind == "textfile":
        return TextFile(cfg, process_index, process_count)
    raise ValueError(cfg.kind)


def fingerprint(batch: Dict[str, np.ndarray]) -> str:
    """Stable digest of a batch (used by resume-equality tests)."""
    h = hashlib.sha256()
    for k in sorted(batch):
        h.update(k.encode())
        h.update(np.ascontiguousarray(batch[k]).tobytes())
    return h.hexdigest()[:16]
