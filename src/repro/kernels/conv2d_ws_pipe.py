"""Manual-DMA double-buffered variant of the weight-stationary conv kernel:
the paper's two-stage load/compute pipeline (M4) made EXPLICIT.

``conv2d_ws`` leans on Pallas's implicit software pipeline: BlockSpecs
describe the blocks, Pallas double-buffers the HBM→VMEM DMAs behind the
MXU.  That is the right default, but BENCH_network.json shows where it is
not enough — depthwise/grouped layers whose arithmetic intensity collapses
onto the shared-DMA roofline (``dma_bound_board`` rows).  This kernel is
the canonical FPGA answer (ping-pong BRAM buffers overlapping
load/compute/store) written out by hand:

* inputs stay in HBM (``memory_space=ANY``); the kernel owns the motion;
* **ping-pong VMEM buffers** (2× halo'd input window, 2× weight bank):
  while slab ``g`` (one (tile, kout bank, cin bank) step) is computing on
  buffer ``g % 2``, the DMAs for slab ``g+1`` stream into buffer
  ``(g+1) % 2`` — ``pltpu.make_async_copy`` + per-slot DMA semaphores;
* the prefetch chain crosses grid steps: the LAST cin slab of one
  (tile, ko) grid step starts the FIRST slab of the next, so the pipe
  never drains between kernel sets or spatial tiles (scratch buffers and
  semaphores persist across the sequential TPU grid);
* the fused epilogue (ReLU → 2×2 max-pool → requantize) writes into a
  ping-pong OUTPUT buffer whose VMEM→HBM store overlaps the next tile's
  compute; the store from slot ``s`` is only waited two grid steps later,
  when the slot is about to be reused (and drained at the final step).

Logical iteration space is IDENTICAL to ``conv2d_ws`` — the
(N, h_tiles, w_tiles, kout, cin) sweep with co innermost — except the cin
sweep runs as an in-kernel ``fori_loop`` instead of a grid dimension (the
accumulator lives in the same VMEM scratch either way).  The compute body
performs the same KH·KW shifted MXU matmuls on the same operand blocks in
the same order, so results are **bit-exact** against ``conv2d_ws`` on both
the int32 and the f32 accumulator paths (asserted across the full
stride × padding × epilogue × groups × tiling space in
tests/test_pipeline_kernel.py).

VMEM working set: 2·input + 2·weight + 2·output ping-pong blocks plus the
accumulator scratch — exactly the bytes ``banking.TilePlan.
working_set_bytes`` already budgets (the implicit pipeline double-buffers
the same blocks), so any plan that fits the sequential kernel fits this
one.  ``banking.plan_tiles(kernel="auto")`` consults
``perfmodel.pipeline_estimate`` to choose per layer; the backend
dispatches on ``TilePlan.pipelined``.

Interpret-mode note: ``make_async_copy`` executes eagerly under
``interpret=True`` (the DMA completes at ``start()``), so CPU validation
checks the full descriptor/semaphore protocol but not the overlap itself;
on TPU the same code compiles to real async DMAs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.conv2d_ws import setup_conv


def _pipe_kernel(x_hbm, w_hbm, b_ref, s_ref, o_hbm, xb, wb, ob, acc_ref,
                 in_sem, w_sem, out_sem, *, kh: int, kw: int, stride: int,
                 cin_banks: int, kout_banks: int, th: int, tw: int,
                 pth: int, ptw: int, cb: int, kb: int, cgrp: int, bpg: int,
                 relu: bool, pool: bool, requant: bool, acc_dtype,
                 dilation: int = 1):
    b, ty, tx, ko = (pl.program_id(i) for i in range(4))
    n_th, n_tw = pl.num_programs(1), pl.num_programs(2)
    n_steps = pl.num_programs(0) * n_th * n_tw * kout_banks
    # linear grid-step index (row-major, matching TPU's sequential grid)
    step = ((b * n_th + ty) * n_tw + tx) * kout_banks + ko
    total_slabs = n_steps * cin_banks

    def coords(s):
        """Decompose a linear step index back into (b, ty, tx, ko)."""
        sko = jax.lax.rem(s, kout_banks)
        s = jax.lax.div(s, kout_banks)
        stx = jax.lax.rem(s, n_tw)
        s = jax.lax.div(s, n_tw)
        return jax.lax.div(s, n_th), jax.lax.rem(s, n_th), stx, sko

    def slab_copies(sb, sty, stx, sko, sco, slot):
        """The two DMAs of one slab: the halo'd input window and the
        weight bank of (tile, kout bank, cin bank) — element offsets
        carry the group's channel base, exactly like the sequential
        kernel's BlockSpec index maps."""
        coff = (sko // bpg) * cgrp + sco * cb
        in_dma = pltpu.make_async_copy(
            x_hbm.at[sb, pl.ds(sty * th * stride, xb.shape[1]),
                     pl.ds(stx * tw * stride, xb.shape[2]),
                     pl.ds(coff, cb)],
            xb.at[slot], in_sem.at[slot])
        w_dma = pltpu.make_async_copy(
            w_hbm.at[:, :, pl.ds(sco * cb, cb), pl.ds(sko * kb, kb)],
            wb.at[slot], w_sem.at[slot])
        return in_dma, w_dma

    def out_copy(s):
        """The epilogue store of grid step ``s``: output ping-pong slot
        ``s % 2`` → that step's (tile, kout bank) HBM region."""
        sb, sty, stx, sko = coords(s)
        slot = jax.lax.rem(s, 2)
        return pltpu.make_async_copy(
            ob.at[slot],
            o_hbm.at[sb, pl.ds(sty * pth, pth), pl.ds(stx * ptw, ptw),
                     pl.ds(sko * kb, kb)],
            out_sem.at[slot])

    # Warm-up: the very first grid step primes the pipe with slab 0;
    # every later slab is prefetched by its predecessor.
    @pl.when(step == 0)
    def _prime():
        for dma in slab_copies(b, ty, tx, ko, 0, 0):
            dma.start()

    # M5: bias preload — the accumulator starts as the bias, exactly like
    # preloading the output BRAMs (same init as conv2d_ws at co == 0).
    acc_ref[...] = jnp.broadcast_to(
        b_ref[...].astype(acc_dtype), acc_ref.shape)

    def cin_step(co, _):
        g = step * cin_banks + co                   # global slab index
        slot = jax.lax.rem(g, 2)
        # the DMAs for THIS slab were started by the previous slab (or the
        # warm-up); wait for them, then immediately stream the next slab
        # into the other buffer while the MXU works on this one
        for dma in slab_copies(b, ty, tx, ko, co, slot):
            dma.wait()

        @pl.when(g + 1 < total_slabs)
        def _prefetch():
            last_co = co + 1 == cin_banks
            ns = jnp.where(last_co, step + 1, step)
            nco = jnp.where(last_co, 0, co + 1)
            nb, nty, ntx, nko = coords(ns)
            for dma in slab_copies(nb, nty, ntx, nko, nco, 1 - slot):
                dma.start()

        acc = acc_ref[...]                          # [TH, TW, KB]
        x = xb[slot]                                # [in_th, in_tw, CB]
        # KH×KW shifted matmuls — identical operand blocks, identical
        # order to conv2d_ws's grid step, hence bit-exact accumulation
        # (dilated taps sit dilation pixels apart, exactly as there)
        for dy in range(kh):
            for dx in range(kw):
                xs = jax.lax.slice(
                    x, (dy * dilation, dx * dilation, 0),
                    (dy * dilation + (th - 1) * stride + 1,
                     dx * dilation + (tw - 1) * stride + 1, cb),
                    (stride, stride, 1)).reshape(th * tw, cb)
                wk = wb[slot, dy, dx]               # [CB, KB]
                acc = acc + jnp.dot(
                    xs, wk, preferred_element_type=acc_dtype
                ).reshape(th, tw, kb)
        acc_ref[...] = acc
        return 0

    jax.lax.fori_loop(0, cin_banks, cin_step, 0)

    # Fused epilogue, then the overlapped store: the VMEM→HBM copy of this
    # tile drains while the NEXT grid step computes — its slot is only
    # waited on two steps later, right before reuse.
    y = acc_ref[...]
    if relu:
        y = jnp.maximum(y, 0)
    if pool:
        y = jnp.max(y.reshape(th // 2, 2, tw // 2, 2, kb), axis=(1, 3))
    if requant:
        y = jnp.clip(jnp.round(y.astype(jnp.float32) * s_ref[...]),
                     -128, 127)

    @pl.when(step >= 2)
    def _reclaim():                                 # slot reused: drain it
        out_copy(step - 2).wait()

    oslot = jax.lax.rem(step, 2)
    ob[oslot] = y.astype(ob.dtype)
    out_copy(step).start()

    @pl.when(step == n_steps - 1)
    def _drain():                                   # kernel end: all stores
        out_copy(step).wait()

        @pl.when(step >= 1)
        def _():
            out_copy(step - 1).wait()


@functools.partial(jax.jit, static_argnames=(
    "stride", "padding", "groups", "cin_banks", "kout_banks", "h_tile",
    "w_tile", "relu", "pool", "dilation", "interpret"))
def conv2d_ws_pipe(x, w, bias=None, out_scale=None, *, stride: int = 1,
                   padding="VALID", groups: int = 1, cin_banks: int = 4,
                   kout_banks: int = 4, h_tile: int = 0, w_tile: int = 0,
                   relu: bool = False, pool: bool = False,
                   dilation: int = 1, interpret: bool = False):
    """Drop-in replacement for ``conv2d_ws`` with explicit double-buffered
    DMA (see the module docstring).  Same signature, same contracts, same
    results bit-for-bit; ``banking.plan_tiles`` decides per layer which
    variant a compiled network runs (``TilePlan.pipelined``)."""
    x, g = setup_conv(x, w, stride=stride, padding=padding, groups=groups,
                      cin_banks=cin_banks, kout_banks=kout_banks,
                      h_tile=h_tile, w_tile=w_tile, pool=pool,
                      requant=out_scale is not None, dilation=dilation)
    acc_dtype = jnp.int32 if g.int_path else jnp.float32
    if bias is None:
        bias = jnp.zeros((g.k,), acc_dtype)
    bias = bias.astype(acc_dtype)
    out_dtype = jnp.int8 if g.requant else acc_dtype
    scale = jnp.broadcast_to(
        jnp.asarray(1.0 if out_scale is None else out_scale, jnp.float32),
        (g.k,))

    kernel = functools.partial(
        _pipe_kernel, kh=g.kh, kw=g.kw, stride=g.stride,
        cin_banks=g.cin_banks, kout_banks=g.kout_banks, th=g.th, tw=g.tw,
        pth=g.pth, ptw=g.ptw, cb=g.cb, kb=g.kb, cgrp=g.cgrp, bpg=g.bpg,
        relu=relu, pool=pool, requant=g.requant, acc_dtype=acc_dtype,
        dilation=g.dilation)
    out = pl.pallas_call(
        kernel,
        grid=(g.n, g.n_th, g.n_tw, g.kout_banks),
        in_specs=[
            # feature map + weights stay in HBM: the kernel moves them
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            # bias/scale per-bank blocks are tiny: implicit pipeline
            pl.BlockSpec((g.kb,), lambda b, ty, tx, ko: (ko,)),
            pl.BlockSpec((g.kb,), lambda b, ty, tx, ko: (ko,)),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        out_shape=jax.ShapeDtypeStruct(
            (g.n, g.n_th * g.pth, g.n_tw * g.ptw, g.k), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((2, g.in_th, g.in_tw, g.cb), x.dtype),   # ping-pong in
            pltpu.VMEM((2, g.kh, g.kw, g.cb, g.kb), w.dtype),   # ping-pong w
            pltpu.VMEM((2, g.pth, g.ptw, g.kb), out_dtype),     # ping-pong out
            pltpu.VMEM((g.th, g.tw, g.kb), acc_dtype),          # accumulator
            pltpu.SemaphoreType.DMA((2,)),                      # input slabs
            pltpu.SemaphoreType.DMA((2,)),                      # weight slabs
            pltpu.SemaphoreType.DMA((2,)),                      # output stores
        ],
        interpret=interpret,
    )(x, w, bias, scale)
    if (g.n_th * g.pth, g.n_tw * g.ptw) != (g.poh, g.pow_):
        out = out[:, :g.poh, :g.pow_]
    return out
