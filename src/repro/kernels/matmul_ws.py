"""Weight-stationary blocked GEMM — the paper's dataflow generalized to the
matmuls that dominate transformers (a 1×1 convolution *is* a GEMM; this is
the TPU-native statement of the IP-core architecture — DESIGN.md §4).

Same four mechanisms as conv2d_ws:
* grid = (N-blocks, K-blocks, M-blocks), m innermost → the weight block
  w[kb, nb] stays VMEM-resident across the whole M (token) stream
  (weight-stationary: the Weight Loader);
* contraction (K) banking with output-block revisiting & accumulation
  (channel banks → PSUM accumulation into the output BRAM);
* bias preload at the first contraction bank (M5);
* Pallas double-buffered block DMA = the load/compute pipeline (M4).

int8×int8→int32 supported (the 8-bit datapath).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mm_kernel(x_ref, w_ref, b_ref, o_ref, *, acc_dtype):
    ko = pl.program_id(1)

    @pl.when(ko == 0)
    def _init():
        o_ref[...] = jnp.broadcast_to(
            b_ref[...].astype(acc_dtype), o_ref.shape)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                          preferred_element_type=acc_dtype)


def _pick(total: int, target: int) -> int:
    """Largest divisor of ``total`` that is ≤ target (tile-friendly)."""
    t = min(target, total)
    while total % t:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def matmul_ws(x, w, bias=None, *, bm: int = 256, bk: int = 512, bn: int = 256,
              interpret: bool = False):
    """x: [M,K] @ w: [K,N] (+bias [N]) → [M,N] (f32, or int32 for int8 in).

    Default blocks: bm×bk×bn = 256×512×256 → VMEM working set
    (x 256×512 + w 512×256 + out 256×256) ≈ 0.9 MiB in bf16/f32 with double
    buffering — far under the ~128 MiB v5e budget, MXU-aligned (×128).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    bm, bk, bn = _pick(m, bm), _pick(k, bk), _pick(n, bn)

    int_path = x.dtype == jnp.int8
    acc_dtype = jnp.int32 if int_path else jnp.float32
    if bias is None:
        bias = jnp.zeros((n,), acc_dtype)
    bias = bias.astype(acc_dtype)

    out = pl.pallas_call(
        functools.partial(_mm_kernel, acc_dtype=acc_dtype),
        grid=(n // bn, k // bk, m // bm),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda no, ko, mo: (mo, ko)),
            pl.BlockSpec((bk, bn), lambda no, ko, mo: (ko, no)),
            pl.BlockSpec((bn,), lambda no, ko, mo: (no,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda no, ko, mo: (mo, no)),
        out_shape=jax.ShapeDtypeStruct((m, n), acc_dtype),
        interpret=interpret,
    )(x, w, bias)
    return out
