"""Transposed convolution on the SAME weight-stationary dataflow — the
dense-prediction upsampling layer (ROADMAP item 5(b)), promoted from the
backward-pass machinery of kernels/conv2d_ws_bwd.py to a first-class
forward contract.

A transposed conv IS an ordinary stride-1 conv on a lowered input: the
lhs is zero-insertion-dilated by the (output-growth) stride, the kernel
is flipped spatially, and the "full" padding of the equivalence
(``ref.conv_transpose_eq_params``) frames the dilated map.  No new
device code exists here — the lowered problem streams through
``conv2d_ws`` or the double-buffered ``conv2d_ws_pipe`` with their whole
contract intact (halo'd spatial tiling, grouped banking, fused
ReLU→pool→requantize epilogue, int8 datapath), which is exactly how the
FPGA would run it: write the sparse upsampled map into the image BRAMs
and let the unchanged IP core sweep it.

Negative equivalence pads (forward padding beyond the kernel extent)
become slices of the dilated map before the kernel launch, because the
image-BRAM zero margins can only add pixels, never remove them.

The backward input-gradient kernel (conv2d_ws_bwd.conv2d_ws_input_grad)
is now the thinnest special case of this path: a transposed conv of the
cotangent with channel-swapped weights, pinned to the forward input's
spatial shape.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.conv2d_ws import conv2d_ws
from repro.kernels.conv2d_ws_pipe import conv2d_ws_pipe
from repro.kernels.ref import (check_groups, conv_transpose_eq_params,
                               grouped_banks)


def transpose_eq_conv_geometry(h: int, w: int, kh: int, kw: int,
                               stride: int = 1, padding="VALID",
                               dilation: int = 1, out_spatial=None):
    """Shape-only companion of :func:`transpose_eq_conv_inputs`: the
    (h_eq, w_eq, eq_pads) of the equivalent stride-1 conv — the dilated
    map after negative-pad cropping plus the clipped (all-≥0) explicit
    pads.  Tile/bank planners (banking.plan_tiles via
    NetworkPlan.tile_plans) price a transposed layer on exactly this
    geometry, so plans and the kernel lowering can never disagree."""
    _, eq_pads = conv_transpose_eq_params(h, w, kh, kw, stride, padding,
                                          dilation, out_spatial)
    hd = (h - 1) * stride + 1 if stride > 1 else h
    wd = (w - 1) * stride + 1 if stride > 1 else w
    pads = [eq_pads[0][0], eq_pads[0][1], eq_pads[1][0], eq_pads[1][1]]
    hd -= max(0, -pads[0]) + max(0, -pads[1])
    wd -= max(0, -pads[2]) + max(0, -pads[3])
    pads = [max(0, p) for p in pads]
    return hd, wd, ((pads[0], pads[1]), (pads[2], pads[3]))


def transpose_eq_conv_inputs(x, kh: int, kw: int, *, stride: int = 1,
                             padding="VALID", dilation: int = 1,
                             out_spatial=None):
    """Lower a transposed conv's input to its equivalent stride-1 conv:
    zero-insert ``x`` by ``stride`` (the lhs dilation, materialized the
    way the FPGA writes a sparse map into its image BRAMs) and resolve
    the equivalence's explicit padding, folding any negative pad into a
    slice of the dilated map.

    Returns ``(x_eq, eq_pads)`` with ``eq_pads = ((t,b),(l,r))`` all
    ≥ 0, ready for ``conv2d_ws(x_eq, flip(w), stride=1,
    padding=eq_pads, dilation=dilation)``.
    """
    n, h, w_dim, c = x.shape
    _, eq_pads = conv_transpose_eq_params(h, w_dim, kh, kw, stride,
                                          padding, dilation, out_spatial)
    if stride > 1:
        xd = jnp.zeros((n, (h - 1) * stride + 1, (w_dim - 1) * stride + 1,
                        c), x.dtype)
        xd = xd.at[:, ::stride, ::stride, :].set(x)
    else:
        xd = x
    pads = [eq_pads[0][0], eq_pads[0][1], eq_pads[1][0], eq_pads[1][1]]
    if min(pads) < 0:
        top, bot, left, right = (max(0, -p) for p in pads)
        xd = xd[:, top:xd.shape[1] - bot, left:xd.shape[2] - right, :]
        pads = [max(0, p) for p in pads]
    return xd, ((pads[0], pads[1]), (pads[2], pads[3]))


def conv2d_ws_transpose(x, w, bias=None, out_scale=None, *, stride: int = 1,
                        padding="VALID", groups: int = 1,
                        cin_banks: int = 4, kout_banks: int = 4,
                        h_tile: int = 0, w_tile: int = 0,
                        relu: bool = False, pool: bool = False,
                        dilation: int = 1, out_spatial=None,
                        pipelined: bool = False, interpret: bool = False):
    """Transposed convolution through the weight-stationary dataflow.

    x: [N,H,W,C]; w: [KH,KW,C/groups,K] (forward layout — the spatial
    flip is internal); bias: [K] or None → [N,OH,OW,K] with
    ``ref.conv_transpose_out_shape`` semantics: VALID grows to
    ``(H−1)·s + ek``, SAME to exactly ``H·s``, explicit pads crop the
    VALID extent, and ``out_spatial`` pins the output shape (the
    gradient-duality form — the stride remainder that a forward conv's
    floor division discarded).

    stride is the OUTPUT growth factor (the lhs zero-insertion rate);
    ``dilation`` dilates the kernel taps of the equivalent conv.  The
    epilogue contract (relu / 2×2 pool / requantize), grouped banking,
    spatial tiling (``h_tile``/``w_tile`` tile the transpose OUTPUT), the
    int8 datapath, and ``pipelined=`` kernel choice are all inherited
    unchanged from conv2d_ws / conv2d_ws_pipe.
    """
    check_groups(x.shape[3], w.shape[3], groups)
    kh, kw = w.shape[0], w.shape[1]
    xd, eq_pads = transpose_eq_conv_inputs(
        x, kh, kw, stride=stride, padding=padding, dilation=dilation,
        out_spatial=out_spatial)
    wt = jnp.flip(w, (0, 1))
    cb, kb = grouped_banks(x.shape[3], w.shape[3], groups,
                           want_cin=cin_banks, want_kout=kout_banks)
    kern = conv2d_ws_pipe if pipelined else conv2d_ws
    return kern(xd, wt, bias, out_scale, stride=1, padding=eq_pads,
                groups=groups, cin_banks=cb, kout_banks=kb,
                h_tile=h_tile, w_tile=w_tile, relu=relu, pool=pool,
                dilation=dilation, interpret=interpret)
