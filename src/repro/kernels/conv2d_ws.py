"""The paper's IP core as a Pallas TPU kernel: weight-stationary, channel-
banked, bias-preloaded blocked convolution with a fused post-processing
epilogue.

Mapping of the FPGA architecture (DESIGN.md §3):

* grid = (N, kout_banks, cin_banks) — co innermost: "PSUM values of each
  core get accumulated continually into the output BRAMs until the
  processing depth is finished" (§4.2), then the next kernel set (ko).
* the weight block (the Weight Loader contents) is VMEM-resident for the
  whole spatial sweep of a grid step — weight-stationary;
* the accumulator is a VMEM scratch block (the output BRAMs), revisited
  across the cin sweep and *initialized with the bias at cin step 0* —
  the paper's bias-preload trick (M5), so bias costs zero extra passes;
* the KH×KW window is computed as KH·KW shifted (HW×Cb)@(Cb×Kb) MXU
  matmuls — the systolic-array form of "9 MACs + adder tree" per PCORE;
  stride-s convolution reads the shifted slices with stride s;
* on the LAST cin step the fused epilogue runs in VMEM before writeback —
  ReLU → 2×2 max-pool → requantize(int8) — the FPGA "post-process in the
  output BRAMs before DMA-out" idiom, so a conv+relu+pool layer costs one
  HBM round-trip instead of three;
* Pallas's software pipeline double-buffers the HBM→VMEM block DMA against
  MXU compute across grid steps — the paper's two-stage load/compute
  pipeline (M4).

Padding is materialized by zero-padding the feature map before the kernel
(the FPGA writes zero margins into the image BRAMs); zero padding is exact
for the symmetric zero-point-0 int8 scheme.

int8 mode: int8×int8 → int32 accumulation (the production reading of the
paper's 8-bit datapath).  With ``out_scale`` the epilogue requantizes to
int8 in-kernel, so chained layers never round-trip int32 through HBM.  The
bit-exact wrap-around-in-8-bit mode of the Fig. 6 waveform lives in
ops.conv2d (wrap8=True) on top of the int32 result.

Spatial extent is kept whole per block (edge-size feature maps fit VMEM
comfortably: 224×224×Cb int8 ≈ 0.4 MiB/bank); banking.py checks the VMEM
budget and picks bank counts for larger maps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import conv_out_shape, normalize_padding


def _conv_kernel(x_ref, w_ref, b_ref, s_ref, o_ref, acc_ref, *, kh: int,
                 kw: int, stride: int, cin_banks: int, relu: bool,
                 pool: bool, requant: bool, acc_dtype):
    co = pl.program_id(2)

    oh, ow, kb = acc_ref.shape
    cb = x_ref.shape[3]

    # M5: bias preload — initialize the accumulator with the bias on the
    # first channel bank, exactly like preloading the output BRAMs.
    @pl.when(co == 0)
    def _init():
        acc_ref[...] = jnp.broadcast_to(
            b_ref[...].astype(acc_dtype), acc_ref.shape)

    acc = acc_ref[...]                                 # [OH, OW, KB]
    x = x_ref[0]                                       # [Hp, Wp, CB]
    # KH×KW shifted matmuls — the 9-MAC adder tree on the MXU; stride-s
    # output pixels read every s-th input row/column of the shifted slab
    for dy in range(kh):
        for dx in range(kw):
            xs = jax.lax.slice(
                x, (dy, dx, 0),
                (dy + (oh - 1) * stride + 1, dx + (ow - 1) * stride + 1, cb),
                (stride, stride, 1)).reshape(oh * ow, cb)
            wk = w_ref[dy, dx]                         # [CB, KB]
            acc = acc + jnp.dot(
                xs, wk, preferred_element_type=acc_dtype
            ).reshape(oh, ow, kb)
    acc_ref[...] = acc

    # Fused epilogue on the last cin step: the FPGA post-processes the
    # output BRAMs (activation, pooling, requantization) before writeback.
    @pl.when(co == cin_banks - 1)
    def _epilogue():
        y = acc_ref[...]
        if relu:
            y = jnp.maximum(y, 0)
        if pool:
            y = jnp.max(y.reshape(oh // 2, 2, ow // 2, 2, kb), axis=(1, 3))
        if requant:
            y = jnp.clip(jnp.round(y.astype(jnp.float32) * s_ref[...]),
                         -128, 127)
        o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "stride", "padding", "cin_banks", "kout_banks", "relu", "pool",
    "interpret"))
def conv2d_ws(x, w, bias=None, out_scale=None, *, stride: int = 1,
              padding="VALID", cin_banks: int = 4, kout_banks: int = 4,
              relu: bool = False, pool: bool = False,
              interpret: bool = False):
    """Generalized paper-dataflow convolution with fused epilogue.

    x: [N,H,W,C]; w: [KH,KW,C,K]; bias: [K] or None → [N,OH,OW,K]
    (f32 accumulate for float inputs, int32 for int8 inputs).

    stride / padding: any stride ≥ 1; "SAME" | "VALID" | int |
    ((top,bottom),(left,right)).  Epilogue (applied in-VMEM on the last
    cin step, in this order): ``relu``, ``pool`` (2×2/2 max-pool, floor
    semantics), ``out_scale`` (requantize to int8; scalar or per-channel
    [K]).

    cin_banks/kout_banks default to the paper's 4×4 banking; C and K must
    divide by them (the paper's divisible-by-4 invariant, §4.1).
    """
    n, h, w_dim, c = x.shape
    kh, kw, c2, k = w.shape
    assert c == c2, (c, c2)
    assert c % cin_banks == 0 and k % kout_banks == 0, (
        "paper banking invariant: C and K divisible by the bank counts")
    (pt, pb), (pl_, pr) = normalize_padding(padding, kh, kw, stride,
                                            h, w_dim)
    if pt or pb or pl_ or pr:
        # zero margins written into the image BRAMs (exact for zero-point-0)
        x = jnp.pad(x, ((0, 0), (pt, pb), (pl_, pr), (0, 0)))
    hp, wp = h + pt + pb, w_dim + pl_ + pr
    oh, ow = conv_out_shape(h, w_dim, kh, kw, stride, padding)
    if pool:
        assert oh >= 2 and ow >= 2, "2×2 pool needs a ≥2×2 conv output"
        oh, ow = (oh // 2) * 2, (ow // 2) * 2     # floor semantics
        poh, pow_ = oh // 2, ow // 2
    else:
        poh, pow_ = oh, ow
    cb, kb = c // cin_banks, k // kout_banks

    int_path = x.dtype == jnp.int8
    acc_dtype = jnp.int32 if int_path else jnp.float32
    if bias is None:
        bias = jnp.zeros((k,), acc_dtype)
    bias = bias.astype(acc_dtype)
    requant = out_scale is not None
    out_dtype = jnp.int8 if requant else acc_dtype
    # scale broadcast to per-kout-bank blocks ([K] covers scalar + per-chan)
    scale = jnp.broadcast_to(
        jnp.asarray(1.0 if out_scale is None else out_scale, jnp.float32),
        (k,))

    kernel = functools.partial(
        _conv_kernel, kh=kh, kw=kw, stride=stride, cin_banks=cin_banks,
        relu=relu, pool=pool, requant=requant, acc_dtype=acc_dtype)
    out = pl.pallas_call(
        kernel,
        grid=(n, kout_banks, cin_banks),
        in_specs=[
            pl.BlockSpec((1, hp, wp, cb), lambda b, ko, co: (b, 0, 0, co)),
            pl.BlockSpec((kh, kw, cb, kb), lambda b, ko, co: (0, 0, co, ko)),
            pl.BlockSpec((kb,), lambda b, ko, co: (ko,)),
            pl.BlockSpec((kb,), lambda b, ko, co: (ko,)),
        ],
        out_specs=pl.BlockSpec((1, poh, pow_, kb),
                               lambda b, ko, co: (b, 0, 0, ko)),
        out_shape=jax.ShapeDtypeStruct((n, poh, pow_, k), out_dtype),
        scratch_shapes=[pltpu.VMEM((oh, ow, kb), acc_dtype)],
        interpret=interpret,
    )(x, w, bias, scale)
    return out
