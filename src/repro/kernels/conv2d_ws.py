"""The paper's IP core as a Pallas TPU kernel: weight-stationary, channel-
banked, bias-preloaded blocked convolution with a fused post-processing
epilogue — and spatially tiled, so feature maps larger than VMEM stream
through halo'd H/W blocks.

Mapping of the FPGA architecture (DESIGN.md §3):

* grid = (N, h_tiles, w_tiles, kout_banks, cin_banks) — co innermost:
  "PSUM values of each core get accumulated continually into the output
  BRAMs until the processing depth is finished" (§4.2), then the next
  kernel set (ko), then the next spatial tile.  Spatial tiles are the
  paper's fixed-size image BRAMs generalized: the FPGA streams a bounded
  window of the map through BRAM; here each grid step DMAs one halo'd
  window of the padded map into VMEM;
* the weight block (the Weight Loader contents) is VMEM-resident for the
  whole spatial sweep of a grid step — weight-stationary;
* the accumulator is a VMEM scratch block (the output BRAMs), revisited
  across the cin sweep and *initialized with the bias at cin step 0* —
  the paper's bias-preload trick (M5), so bias costs zero extra passes;
* the KH×KW window is computed as KH·KW shifted (HW×Cb)@(Cb×Kb) MXU
  matmuls — the systolic-array form of "9 MACs + adder tree" per PCORE;
  stride-s convolution reads the shifted slices with stride s;
* on the LAST cin step the fused epilogue runs in VMEM before writeback —
  ReLU → 2×2 max-pool → requantize(int8) — the FPGA "post-process in the
  output BRAMs before DMA-out" idiom, so a conv+relu+pool layer costs one
  HBM round-trip instead of three;
* Pallas's software pipeline double-buffers the HBM→VMEM block DMA against
  MXU compute across grid steps — the paper's two-stage load/compute
  pipeline (M4).

Tiling dataflow and halo math
-----------------------------
An output tile of ``h_tile × w_tile`` conv-output pixels at tile index
(ty, tx) consumes the padded-input window starting at element
``(ty·h_tile·s, tx·w_tile·s)`` with extent

    in_tile = (tile − 1)·s + k        (per spatial dim, s = stride)

so adjacent input windows overlap by a halo of ``k − s`` rows/columns
(k − 1 for the stride-1 case) — re-read from HBM per tile, exactly like
the FPGA re-DMAs the boundary rows of its image BRAM window.  The input
BlockSpec uses element-granularity (Unblocked) indexing because halo'd
windows overlap: block strides (h_tile·s) differ from block extents
(in_tile).  The padded map is extended with extra zero rows/columns on
the bottom/right so the LAST tile's window is always in bounds; the
correspondingly padded output rows are sliced off after the call.

The fused epilogue is tile-local: with ``pool=True`` tile sizes must be
even (pool-aligned) so no 2×2 pool window straddles a tile edge — tile
boundaries then land on pool-window boundaries and tiled pooling equals
whole-map pooling.  core/banking.plan_tiles chooses (h_tile, w_tile,
cin_banks, kout_banks) jointly so the true VMEM working set (halo'd
input block + weight block + accumulator scratch + epilogue output
block, with pipeline double-buffering) fits the budget.

Padding is materialized by zero-padding the feature map before the kernel
(the FPGA writes zero margins into the image BRAMs); zero padding is exact
for the symmetric zero-point-0 int8 scheme.

int8 mode: int8×int8 → int32 accumulation (the production reading of the
paper's 8-bit datapath).  With ``out_scale`` the epilogue requantizes to
int8 in-kernel, so chained layers never round-trip int32 through HBM.  The
bit-exact wrap-around-in-8-bit mode of the Fig. 6 waveform lives in
ops.conv2d (wrap8=True) on top of the int32 result.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import (check_groups, conv_out_shape, dilated_extent,
                               halo_window, normalize_padding)


class ConvGeom(NamedTuple):
    """Resolved static geometry of one conv layer pass — the single
    host-side derivation (banking legality, halo math, tile extents,
    zero-extension, epilogue dtypes) shared by the implicitly-pipelined
    kernel (``conv2d_ws``) and the manual-DMA double-buffered variant
    (``conv2d_ws_pipe``), so the two dataflows can never disagree on
    shapes — the precondition for their bit-exactness contract."""
    n: int
    kh: int
    kw: int
    k: int
    stride: int
    cin_banks: int
    kout_banks: int
    cb: int                   # channels per cin bank (within one group)
    kb: int                   # kernels per kout bank
    cgrp: int                 # channels per group (C // groups)
    bpg: int                  # kout banks per group
    th: int                   # conv-output tile extents (pre-pool)
    tw: int
    n_th: int
    n_tw: int
    in_th: int                # halo'd input window extents
    in_tw: int
    hp: int                   # padded (+zero-extended) map extents
    wp: int
    pth: int                  # epilogue output tile extents (post-pool)
    ptw: int
    poh: int                  # whole-map epilogue output extents
    pow_: int
    tiled: bool
    int_path: bool
    requant: bool
    dilation: int = 1


def setup_conv(x, w, *, stride: int = 1, padding="VALID", groups: int = 1,
               cin_banks: int = 4, kout_banks: int = 4, h_tile: int = 0,
               w_tile: int = 0, pool: bool = False, requant: bool = False,
               dilation: int = 1):
    """Validate one conv layer pass and materialize its padded input.

    Returns ``(x_padded, geom)`` where ``x_padded`` carries the zero
    margins (padding + trailing-tile zero-extension — exact for the
    symmetric zero-point-0 int8 scheme) and ``geom`` is the resolved
    :class:`ConvGeom`.  Raises exactly the errors the kernels contract
    with the planner (banking invariant, group boundaries, sub-2×2
    pooled outputs, pool-aligned tiles)."""
    n, h, w_dim, c = x.shape
    kh, kw, c2, k = w.shape
    check_groups(c, k, groups)
    cgrp = c // groups
    assert cgrp == c2, ("weights carry the per-group channel slice: "
                        "w.shape[2] must be C/groups", c, groups, c2)
    if groups > 1 and kout_banks % groups:
        raise ValueError(
            f"grouped conv needs kout banks that split along group "
            f"boundaries: kout_banks={kout_banks} is not a multiple "
            f"of groups={groups} (C={c}, K={k})")
    if cgrp % cin_banks or k % kout_banks:
        raise ValueError(
            f"paper banking invariant (§4.1): C/groups={cgrp} and K={k} "
            f"must divide by the bank counts ({cin_banks}, {kout_banks})")
    (pt, pb), (pl_, pr) = normalize_padding(padding, kh, kw, stride,
                                            h, w_dim, dilation)
    oh, ow = conv_out_shape(h, w_dim, kh, kw, stride, padding, dilation)
    if oh < 1 or ow < 1:
        # same error as banking.plan_tiles — planner and kernel agree
        raise ValueError(
            f"dilated kernel extent "
            f"{dilated_extent(kh, dilation)}×{dilated_extent(kw, dilation)} "
            f"(kernel {kh}×{kw}, dilation={dilation}) exceeds the padded "
            f"input {h + pt + pb}×{w_dim + pl_ + pr}")
    if pool:
        if oh < 2 or ow < 2:
            # same error as banking.plan_tiles — planner and kernel agree
            raise ValueError(
                f"2×2 pool needs a ≥2×2 conv output, got {oh}×{ow}")
        oh, ow = (oh // 2) * 2, (ow // 2) * 2     # floor semantics
    th = oh if h_tile in (0, None) else min(h_tile, oh)
    tw = ow if w_tile in (0, None) else min(w_tile, ow)
    if pool:
        assert th % 2 == 0 and tw % 2 == 0, (
            "pool-aligned tiles required: 2×2 windows must not straddle "
            "tile edges", th, tw)
    n_th, n_tw = -(-oh // th), -(-ow // tw)
    tiled = (th, tw) != (oh, ow)
    # halo'd input window per tile: (tile-1)·s + d·(k-1)+1, overlapping by
    # the dilated kernel extent minus the stride
    in_th = halo_window(th, stride, kh, dilation)
    in_tw = halo_window(tw, stride, kw, dilation)
    hp, wp = h + pt + pb, w_dim + pl_ + pr
    # extend the padded map so the LAST tile's window is in bounds; the
    # matching garbage output rows/cols are sliced off after the kernel
    extra_h = max(0, (n_th - 1) * th * stride + in_th - hp)
    extra_w = max(0, (n_tw - 1) * tw * stride + in_tw - wp)
    if pt or pb or pl_ or pr or extra_h or extra_w:
        # zero margins written into the image BRAMs (exact for zero-point-0)
        x = jnp.pad(x, ((0, 0), (pt, pb + extra_h), (pl_, pr + extra_w),
                        (0, 0)))
    hp, wp = hp + extra_h, wp + extra_w
    if pool:
        pth, ptw = th // 2, tw // 2
        poh, pow_ = oh // 2, ow // 2
    else:
        pth, ptw = th, tw
        poh, pow_ = oh, ow
    # per-bank blocks live inside ONE group: the cin sweep covers only the
    # C/groups channels a kout bank's kernel set reads (dense: the whole C)
    geom = ConvGeom(
        n=n, kh=kh, kw=kw, k=k, stride=stride,
        cin_banks=cin_banks, kout_banks=kout_banks,
        cb=cgrp // cin_banks, kb=k // kout_banks, cgrp=cgrp,
        bpg=kout_banks // groups,
        th=th, tw=tw, n_th=n_th, n_tw=n_tw, in_th=in_th, in_tw=in_tw,
        hp=hp, wp=wp, pth=pth, ptw=ptw, poh=poh, pow_=pow_,
        tiled=tiled, int_path=x.dtype == jnp.int8, requant=requant,
        dilation=dilation)
    return x, geom


def _conv_kernel(x_ref, w_ref, b_ref, s_ref, o_ref, acc_ref, *, kh: int,
                 kw: int, stride: int, cin_banks: int, relu: bool,
                 pool: bool, requant: bool, acc_dtype, dilation: int = 1):
    co = pl.program_id(4)

    th, tw, kb = acc_ref.shape
    cb = x_ref.shape[3]

    # M5: bias preload — initialize the accumulator with the bias on the
    # first channel bank, exactly like preloading the output BRAMs.
    @pl.when(co == 0)
    def _init():
        acc_ref[...] = jnp.broadcast_to(
            b_ref[...].astype(acc_dtype), acc_ref.shape)

    acc = acc_ref[...]                                 # [TH, TW, KB]
    x = x_ref[0]                                       # [in_th, in_tw, CB]
    # KH×KW shifted matmuls — the 9-MAC adder tree on the MXU; stride-s
    # output pixels read every s-th input row/column of the shifted slab;
    # a dilated kernel's taps sit dilation pixels apart
    for dy in range(kh):
        for dx in range(kw):
            xs = jax.lax.slice(
                x, (dy * dilation, dx * dilation, 0),
                (dy * dilation + (th - 1) * stride + 1,
                 dx * dilation + (tw - 1) * stride + 1, cb),
                (stride, stride, 1)).reshape(th * tw, cb)
            wk = w_ref[dy, dx]                         # [CB, KB]
            acc = acc + jnp.dot(
                xs, wk, preferred_element_type=acc_dtype
            ).reshape(th, tw, kb)
    acc_ref[...] = acc

    # Fused epilogue on the last cin step: the FPGA post-processes the
    # output BRAMs (activation, pooling, requantization) before writeback.
    # Tile-local: pool-aligned tiles guarantee no 2×2 window straddles a
    # tile edge, so per-tile pooling == whole-map pooling.
    @pl.when(co == cin_banks - 1)
    def _epilogue():
        y = acc_ref[...]
        if relu:
            y = jnp.maximum(y, 0)
        if pool:
            y = jnp.max(y.reshape(th // 2, 2, tw // 2, 2, kb), axis=(1, 3))
        if requant:
            y = jnp.clip(jnp.round(y.astype(jnp.float32) * s_ref[...]),
                         -128, 127)
        o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "stride", "padding", "groups", "cin_banks", "kout_banks", "h_tile",
    "w_tile", "relu", "pool", "dilation", "interpret"))
def conv2d_ws(x, w, bias=None, out_scale=None, *, stride: int = 1,
              padding="VALID", groups: int = 1, cin_banks: int = 4,
              kout_banks: int = 4, h_tile: int = 0, w_tile: int = 0,
              relu: bool = False, pool: bool = False, dilation: int = 1,
              interpret: bool = False):
    """Generalized paper-dataflow convolution with fused epilogue and
    halo-aware spatial tiling.

    x: [N,H,W,C]; w: [KH,KW,C/groups,K]; bias: [K] or None → [N,OH,OW,K]
    (f32 accumulate for float inputs, int32 for int8 inputs).

    stride / padding: any stride ≥ 1; "SAME" | "VALID" | int |
    ((top,bottom),(left,right)).  Epilogue (applied in-VMEM on the last
    cin step, in this order): ``relu``, ``pool`` (2×2/2 max-pool, floor
    semantics), ``out_scale`` (requantize to int8; scalar or per-channel
    [K]).

    groups: grouped channel contraction (1 = dense, ``groups == C`` =
    depthwise).  The grid shape is unchanged — kout banks are constrained
    to group boundaries (``kout_banks % groups == 0``, so every kout
    bank's kernel set lives inside ONE group) and the input BlockSpec's
    channel index gains the group offset: the cin sweep of kout bank
    ``ko`` walks only its group's C/groups-channel slice.  The per-bank
    weight block, the accumulator revisit pattern, and the halo'd H/W
    tiling are identical to the dense dataflow — a depthwise layer is
    simply the degenerate one-cin-bank sweep per kernel set, which is why
    its arithmetic intensity collapses onto the DMA roofline
    (core/perfmodel prices this).

    h_tile / w_tile: conv-output tile extents (pre-pool pixels).  0 means
    "whole map" (one spatial tile — the seed dataflow).  Tiles need not
    divide the output: the trailing tile is computed on zero-extended
    input and sliced off.  With ``pool=True`` tile sizes must be even so
    pool windows never straddle tile edges.  core/banking.plan_tiles
    picks sizes that fit the VMEM budget.

    cin_banks/kout_banks default to the paper's 4×4 banking; C/groups and
    K must divide by them (the paper's divisible-by-4 invariant, §4.1 —
    ``ref.grouped_banks`` degrades the defaults legally for grouped
    layers).
    """
    x, g = setup_conv(x, w, stride=stride, padding=padding, groups=groups,
                      cin_banks=cin_banks, kout_banks=kout_banks,
                      h_tile=h_tile, w_tile=w_tile, pool=pool,
                      requant=out_scale is not None, dilation=dilation)
    n, kh, kw, k = g.n, g.kh, g.kw, g.k
    th, tw, n_th, n_tw = g.th, g.tw, g.n_th, g.n_tw
    in_th, in_tw, hp, wp = g.in_th, g.in_tw, g.hp, g.wp
    pth, ptw, poh, pow_ = g.pth, g.ptw, g.poh, g.pow_
    cb, kb, cgrp, bpg, tiled = g.cb, g.kb, g.cgrp, g.bpg, g.tiled

    acc_dtype = jnp.int32 if g.int_path else jnp.float32
    if bias is None:
        bias = jnp.zeros((k,), acc_dtype)
    bias = bias.astype(acc_dtype)
    requant = out_scale is not None
    out_dtype = jnp.int8 if requant else acc_dtype
    # scale broadcast to per-kout-bank blocks ([K] covers scalar + per-chan)
    scale = jnp.broadcast_to(
        jnp.asarray(1.0 if out_scale is None else out_scale, jnp.float32),
        (k,))

    # the channel index of the input block carries the GROUP offset: kout
    # bank ko belongs to group ko // bpg, whose cin slice starts at
    # (ko // bpg) · C/groups — the cin sweep (co) walks only that slice.
    # Dense convs have bpg == kout_banks, so the offset is always 0.
    if tiled:
        # overlapping halo'd windows: element-granularity indexing (block
        # stride th·s ≠ block extent in_th)
        x_spec = pl.BlockSpec(
            (1, in_th, in_tw, cb),
            lambda b, ty, tx, ko, co: (b, ty * th * stride,
                                       tx * tw * stride,
                                       (ko // bpg) * cgrp + co * cb),
            indexing_mode=pl.unblocked)
    else:
        x_spec = pl.BlockSpec(
            (1, hp, wp, cb),
            lambda b, ty, tx, ko, co: (b, 0, 0,
                                       (ko // bpg) * cin_banks + co))

    kernel = functools.partial(
        _conv_kernel, kh=kh, kw=kw, stride=stride, cin_banks=cin_banks,
        relu=relu, pool=pool, requant=requant, acc_dtype=acc_dtype,
        dilation=dilation)
    out = pl.pallas_call(
        kernel,
        grid=(n, n_th, n_tw, kout_banks, cin_banks),
        in_specs=[
            x_spec,
            pl.BlockSpec((kh, kw, cb, kb),
                         lambda b, ty, tx, ko, co: (0, 0, co, ko)),
            pl.BlockSpec((kb,), lambda b, ty, tx, ko, co: (ko,)),
            pl.BlockSpec((kb,), lambda b, ty, tx, ko, co: (ko,)),
        ],
        out_specs=pl.BlockSpec((1, pth, ptw, kb),
                               lambda b, ty, tx, ko, co: (b, ty, tx, ko)),
        out_shape=jax.ShapeDtypeStruct(
            (n, n_th * pth, n_tw * ptw, k), out_dtype),
        scratch_shapes=[pltpu.VMEM((th, tw, kb), acc_dtype)],
        interpret=interpret,
    )(x, w, bias, scale)
    if (n_th * pth, n_tw * ptw) != (poh, pow_):
        out = out[:, :poh, :pow_]
    return out
