"""The paper's IP core as a Pallas TPU kernel: weight-stationary, channel-
banked, bias-preloaded blocked convolution.

Mapping of the FPGA architecture (DESIGN.md §3):

* grid = (N, kout_banks, cin_banks) — co innermost: "PSUM values of each
  core get accumulated continually into the output BRAMs until the
  processing depth is finished" (§4.2), then the next kernel set (ko).
* the weight block (the Weight Loader contents) is VMEM-resident for the
  whole spatial sweep of a grid step — weight-stationary;
* the output block is revisited across the cin sweep and *initialized with
  the bias at cin step 0* — the paper's bias-preload trick (M5), so bias
  costs zero extra passes;
* the 3×3 window is computed as KH·KW shifted (HW×Cb)@(Cb×Kb) MXU matmuls
  — the systolic-array form of "9 MACs + adder tree" per PCORE;
* Pallas's software pipeline double-buffers the HBM→VMEM block DMA against
  MXU compute across grid steps — the paper's two-stage load/compute
  pipeline (M4).

int8 mode: int8×int8 → int32 accumulation (the production reading of the
paper's 8-bit datapath).  The bit-exact wrap-around-in-8-bit mode of the
Fig. 6 waveform lives in ops.conv2d (wrap8=True) on top of the int32 result.

Spatial extent is kept whole per block (edge-size feature maps fit VMEM
comfortably: 224×224×Cb int8 ≈ 0.4 MiB/bank); banking.py checks the VMEM
budget and picks bank counts for larger maps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_kernel(x_ref, w_ref, b_ref, o_ref, *, kh: int, kw: int, acc_dtype):
    co = pl.program_id(2)

    oh, ow, kb = o_ref.shape[1], o_ref.shape[2], o_ref.shape[3]
    cb = x_ref.shape[3]

    # M5: bias preload — initialize the output accumulator with the bias on
    # the first channel bank, exactly like preloading the output BRAMs.
    @pl.when(co == 0)
    def _init():
        o_ref[...] = jnp.broadcast_to(
            b_ref[...].astype(acc_dtype), o_ref.shape)

    acc = o_ref[0]                                     # [OH, OW, KB]
    x = x_ref[0]                                       # [H, W, CB]
    # KH×KW shifted matmuls — the 9-MAC adder tree on the MXU
    for dy in range(kh):
        for dx in range(kw):
            xs = jax.lax.dynamic_slice(
                x, (dy, dx, 0), (oh, ow, cb)).reshape(oh * ow, cb)
            wk = w_ref[dy, dx]                         # [CB, KB]
            acc = acc + jnp.dot(
                xs, wk, preferred_element_type=acc_dtype
            ).reshape(oh, ow, kb)
    o_ref[0] = acc


@functools.partial(jax.jit, static_argnames=("cin_banks", "kout_banks",
                                             "interpret"))
def conv2d_ws(x, w, bias=None, *, cin_banks: int = 4, kout_banks: int = 4,
              interpret: bool = False):
    """VALID stride-1 conv, paper dataflow.

    x: [N,H,W,C]; w: [KH,KW,C,K]; bias: [K] or None → [N,OH,OW,K]
    (f32 accumulate for float inputs, int32 for int8 inputs).

    cin_banks/kout_banks default to the paper's 4×4 banking; C and K must
    divide by them (the paper's divisible-by-4 invariant, §4.1).
    """
    n, h, w_dim, c = x.shape
    kh, kw, c2, k = w.shape
    assert c == c2, (c, c2)
    assert c % cin_banks == 0 and k % kout_banks == 0, (
        "paper banking invariant: C and K divisible by the bank counts")
    oh, ow = h - kh + 1, w_dim - kw + 1
    cb, kb = c // cin_banks, k // kout_banks

    int_path = x.dtype == jnp.int8
    acc_dtype = jnp.int32 if int_path else jnp.float32
    if bias is None:
        bias = jnp.zeros((k,), acc_dtype)
    bias = bias.astype(acc_dtype)

    kernel = functools.partial(_conv_kernel, kh=kh, kw=kw, acc_dtype=acc_dtype)
    out = pl.pallas_call(
        kernel,
        grid=(n, kout_banks, cin_banks),
        in_specs=[
            pl.BlockSpec((1, h, w_dim, cb), lambda b, ko, co: (b, 0, 0, co)),
            pl.BlockSpec((kh, kw, cb, kb), lambda b, ko, co: (0, 0, co, ko)),
            pl.BlockSpec((kb,), lambda b, ko, co: (ko,)),
        ],
        out_specs=pl.BlockSpec((1, oh, ow, kb), lambda b, ko, co: (b, 0, 0, ko)),
        out_shape=jax.ShapeDtypeStruct((n, oh, ow, k), acc_dtype),
        interpret=interpret,
    )(x, w, bias)
    return out
