"""Public jit'd kernel wrappers.

* auto-select interpret mode on CPU (the host platform cannot lower Mosaic;
  interpret=True executes the kernel body in Python — the validation mode
  this container uses; on TPU the same call compiles natively);
* ``matmul_ws`` carries a custom VJP so the paper-dataflow GEMM is usable
  inside training graphs (backward = two more WS-GEMMs);
* ``conv2d`` adds the requantization / wrap8 modes of the 8-bit datapath.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import conv2d_ws as _conv_mod
from repro.kernels import matmul_ws as _mm_mod


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# GEMM with custom VJP
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def matmul_ws(x, w, bias=None):
    return _matmul_fwd_impl(x, w, bias)


def _matmul_fwd_impl(x, w, bias):
    out = _mm_mod.matmul_ws(x, w, bias, interpret=_interpret())
    if x.dtype == jnp.int8:
        return out
    return out.astype(x.dtype)


def _matmul_fwd(x, w, bias):
    return _matmul_fwd_impl(x, w, bias), (x, w, bias is not None)


def _matmul_bwd(res, g):
    x, w, has_bias = res
    if not (jnp.issubdtype(x.dtype, jnp.floating)
            and jnp.issubdtype(w.dtype, jnp.floating)):
        raise TypeError(
            "matmul_ws VJP requires float operands: an int8 forward has no "
            "meaningful int8 gradient (casting the cotangent to int8 would "
            "silently truncate it) — differentiate the float path instead")
    # promote the cotangent to the accumulator dtype; the backward GEMMs run
    # in f32 and only the results cast back to the operand dtypes
    gf = g.astype(jnp.float32)
    dx = _mm_mod.matmul_ws(gf, w.T.astype(jnp.float32),
                           interpret=_interpret()).astype(x.dtype)
    dw = _mm_mod.matmul_ws(x.T.astype(jnp.float32), gf,
                           interpret=_interpret()).astype(w.dtype)
    db = jnp.sum(g, axis=0) if has_bias else None
    return dx, dw, db


matmul_ws.defvjp(_matmul_fwd, _matmul_bwd)


# ---------------------------------------------------------------------------
# Convolution (the IP core entry point)
# ---------------------------------------------------------------------------


def conv2d(x, w, bias=None, *, stride: int = 1, padding="VALID",
           cin_banks: int = 4, kout_banks: int = 4, h_tile: int = 0,
           w_tile: int = 0, relu: bool = False, pool: bool = False,
           wrap8: bool = False, out_scale=None):
    """Paper-dataflow convolution (arbitrary stride / SAME|VALID|explicit
    padding, fused ReLU → 2×2 max-pool → requantize epilogue, halo-aware
    spatial tiling via h_tile/w_tile — 0 = whole map).

    float in → f32 out; int8 in → int32 out.  ``out_scale`` requantizes
    in-kernel (acc × scale → int8) on EITHER accumulator path — int32 for
    int8 inputs (the production chained-layer path) and f32 for float
    inputs (matching RefBackend's epilogue contract) — so the output dtype
    is int8 whenever a scale is given.  ``wrap8=True`` (int8 inputs only)
    instead wraps the accumulator to int8, bit-matching the paper's Fig. 6
    waveform — the wrap path has no requantize stage, so combining it with
    ``out_scale`` is an error rather than a silent drop.
    """
    if wrap8 and out_scale is not None:
        raise ValueError("wrap8 and out_scale are mutually exclusive: the "
                         "Fig. 6 wrap path has no requantize stage")
    fused_scale = out_scale
    out = _conv_mod.conv2d_ws(x, w, bias, fused_scale, stride=stride,
                              padding=padding, cin_banks=cin_banks,
                              kout_banks=kout_banks, h_tile=h_tile,
                              w_tile=w_tile, relu=relu, pool=pool,
                              interpret=_interpret())
    if x.dtype == jnp.int8 and wrap8:
        return out.astype(jnp.int8)
    return out


def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int = 512, block_k: int = 512):
    """Pallas flash attention (beyond-paper kernel; see
    kernels/flash_attention.py).  On TPU this replaces the pure-JAX
    chunked attention for prefill/train (cfg.attn_impl == "flash");
    interpret mode validates it on CPU."""
    from repro.kernels.flash_attention import flash_attention as _fa
    return _fa(q, k, v, causal=causal, block_q=block_q, block_k=block_k,
               interpret=_interpret())


def conv1d_depthwise(x, w, bias=None):
    """Causal depthwise temporal conv via the WS-GEMM dataflow.

    x: [B,S,W], w: [K,W].  Depthwise conv = K shifted elementwise MACs —
    on TPU these fuse into the surrounding ops; routed through the ref
    implementation (the conv2d kernel targets the paper's dense conv)."""
    from repro.kernels.ref import conv1d_depthwise_ref
    return conv1d_depthwise_ref(x, w, bias)
