"""Public jit'd kernel wrappers.

* auto-select interpret mode on CPU (the host platform cannot lower Mosaic;
  interpret=True executes the kernel body in Python — the validation mode
  this container uses; on TPU the same call compiles natively);
* ``matmul_ws`` carries a custom VJP so the paper-dataflow GEMM is usable
  inside training graphs (backward = two more WS-GEMMs);
* ``conv2d`` adds the requantization / wrap8 modes of the 8-bit datapath,
  and carries a custom VJP on the float accumulator path: the backward
  kernels (kernels/conv2d_ws_bwd.py) run the same weight-stationary
  dataflow, and the residuals store the fused-epilogue MASKS (ReLU sign
  bits, 2×2-pool argmax indices) instead of a second copy of the
  accumulator, so stride/padding/epilogue configs differentiate
  bit-consistently with the ref oracle.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import conv2d_ws as _conv_mod
from repro.kernels import conv2d_ws_bwd as _bwd_mod
from repro.kernels import conv2d_ws_pipe as _pipe_mod
from repro.kernels import conv2d_ws_trans as _trans_mod
from repro.kernels import matmul_ws as _mm_mod
from repro.kernels import ref as _ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# GEMM with custom VJP
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def matmul_ws(x, w, bias=None):
    return _matmul_fwd_impl(x, w, bias)


def _matmul_fwd_impl(x, w, bias):
    out = _mm_mod.matmul_ws(x, w, bias, interpret=_interpret())
    if x.dtype == jnp.int8:
        return out
    return out.astype(x.dtype)


def _matmul_fwd(x, w, bias):
    return _matmul_fwd_impl(x, w, bias), (x, w, bias)


def _matmul_bwd(res, g):
    x, w, bias = res
    if not (jnp.issubdtype(x.dtype, jnp.floating)
            and jnp.issubdtype(w.dtype, jnp.floating)):
        raise TypeError(
            "matmul_ws VJP requires float operands: an int8 forward has no "
            "meaningful int8 gradient (casting the cotangent to int8 would "
            "silently truncate it) — differentiate the float path instead")
    # promote the cotangent to the accumulator dtype; the backward GEMMs run
    # in f32 and only the results cast back to the operand dtypes
    gf = g.astype(jnp.float32)
    dx = _mm_mod.matmul_ws(gf, w.T.astype(jnp.float32),
                           interpret=_interpret()).astype(x.dtype)
    dw = _mm_mod.matmul_ws(x.T.astype(jnp.float32), gf,
                           interpret=_interpret()).astype(w.dtype)
    # bias grad reduces in f32 and only the RESULT casts to the bias dtype:
    # summing the raw cotangent rounds every partial sum to the cotangent
    # dtype, and an f32 master bias fed bf16 cotangents would silently get
    # a bf16-rounded gradient
    db = (jnp.sum(gf, axis=0).astype(bias.dtype)
          if bias is not None else None)
    return dx, dw, db


matmul_ws.defvjp(_matmul_fwd, _matmul_bwd)


# ---------------------------------------------------------------------------
# Convolution (the IP core entry point)
# ---------------------------------------------------------------------------


class _ConvCfg(NamedTuple):
    """Hashable static config of one conv layer pass (the nondiff argument
    of the custom VJP; padding is pre-resolved to explicit form so SAME
    needs no shape context in the backward rules)."""
    stride: int
    padding: Tuple[Tuple[int, int], Tuple[int, int]]
    groups: int
    cin_banks: int
    kout_banks: int
    h_tile: int
    w_tile: int
    relu: bool
    pool: bool
    dilation: int = 1
    pipelined: bool = False


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _conv2d_float(cfg: _ConvCfg, x, w, bias):
    """Float-accumulator conv with the fused ReLU → 2×2-max-pool epilogue
    and a paper-dataflow backward (see _conv2d_float_bwd).  The primal
    honors ``cfg.pipelined`` (both kernel variants are bit-exact, so the
    VJP rules below may keep the sequential kernel for the residual
    recompute without any value drift)."""
    fwd = (_pipe_mod.conv2d_ws_pipe if cfg.pipelined
           else _conv_mod.conv2d_ws)
    return fwd(x, w, bias, None, stride=cfg.stride,
               padding=cfg.padding, groups=cfg.groups,
               cin_banks=cfg.cin_banks,
               kout_banks=cfg.kout_banks, h_tile=cfg.h_tile,
               w_tile=cfg.w_tile, relu=cfg.relu,
               pool=cfg.pool, dilation=cfg.dilation, interpret=_interpret())


def _conv2d_float_fwd(cfg: _ConvCfg, x, w, bias):
    """Run the kernel WITHOUT the epilogue to expose the f32 accumulator,
    then apply ReLU/pool at the jnp level — bit-identical to the fused
    epilogue (same maximum ops on the same accumulator values) — and keep
    only the epilogue MASKS as residuals: the ReLU sign bits and the pool
    argmax indices, 1 byte each per accumulator cell instead of 4."""
    acc = _conv_mod.conv2d_ws(x, w, bias, None, stride=cfg.stride,
                              padding=cfg.padding, groups=cfg.groups,
                              cin_banks=cfg.cin_banks,
                              kout_banks=cfg.kout_banks, h_tile=cfg.h_tile,
                              w_tile=cfg.w_tile, dilation=cfg.dilation,
                              interpret=_interpret())
    relu_mask = pool_idx = None
    y = acc
    if cfg.relu:
        relu_mask = _ref.relu_mask_ref(acc)
        y = jnp.maximum(y, 0)
    if cfg.pool:
        oh, ow = acc.shape[1], acc.shape[2]
        if oh < 2 or ow < 2:
            # the epilogue-disabled kernel call above skipped conv2d_ws's
            # own check — differentiation must fail exactly like the
            # primal, not train on an empty pooled map
            raise ValueError(
                f"2×2 pool needs a ≥2×2 conv output, got {oh}×{ow}")
        pool_idx = _ref.maxpool2x2_argmax_ref(y)
        y = _ref.maxpool2d_ref(y, 2)
    return y, (x, w, bias, relu_mask, pool_idx, acc.shape)


def _conv2d_float_bwd(cfg: _ConvCfg, res, g):
    x, w, bias, relu_mask, pool_idx, acc_shape = res
    # walk the epilogue backwards: pool argmax routing → ReLU mask → the
    # accumulator cotangent the WS backward kernels consume
    dacc = g.astype(jnp.float32)
    if cfg.pool:
        dacc = _ref.maxpool2x2_bwd_ref(pool_idx, dacc, acc_shape)
    if cfg.relu:
        dacc = dacc * relu_mask
    dx = _bwd_mod.conv2d_ws_input_grad(
        dacc, w, x.shape, stride=cfg.stride, padding=cfg.padding,
        groups=cfg.groups, cin_banks=cfg.cin_banks,
        kout_banks=cfg.kout_banks, h_tile=cfg.h_tile, w_tile=cfg.w_tile,
        dilation=cfg.dilation, interpret=_interpret()).astype(x.dtype)
    dw = _bwd_mod.conv2d_ws_weight_grad(
        x, dacc, w.shape[0], w.shape[1], stride=cfg.stride,
        padding=cfg.padding, groups=cfg.groups, dilation=cfg.dilation,
        interpret=_interpret()).astype(w.dtype)
    # like _matmul_bwd: reduce in f32, cast only the result to the bias dtype
    db = (jnp.sum(dacc, axis=(0, 1, 2)).astype(bias.dtype)
          if bias is not None else None)
    return dx, dw, db


_conv2d_float.defvjp(_conv2d_float_fwd, _conv2d_float_bwd)


def conv2d(x, w, bias=None, *, stride: int = 1, padding="VALID",
           groups: int = 1, cin_banks: int = 4, kout_banks: int = 4,
           h_tile: int = 0, w_tile: int = 0, relu: bool = False,
           pool: bool = False, wrap8: bool = False, out_scale=None,
           dilation: int = 1, pipelined: bool = False):
    """Paper-dataflow convolution (arbitrary stride / SAME|VALID|explicit
    padding, fused ReLU → 2×2 max-pool → requantize epilogue, halo-aware
    spatial tiling via h_tile/w_tile — 0 = whole map).

    ``groups`` selects grouped channel contraction (w: [KH,KW,C/groups,K];
    1 = dense, ``groups == C`` = depthwise — the MobileNet workload
    family).  For grouped layers the requested bank counts re-legalize
    through ``ref.grouped_banks`` (cin banks must divide the per-group
    slice, kout banks split along group boundaries); dense layers keep
    the strict paper invariant.

    float in → f32 out; int8 in → int32 out.  ``out_scale`` requantizes
    in-kernel (acc × scale → int8) on EITHER accumulator path — int32 for
    int8 inputs (the production chained-layer path) and f32 for float
    inputs (matching RefBackend's epilogue contract) — so the output dtype
    is int8 whenever a scale is given.  ``wrap8=True`` (int8 inputs only)
    instead wraps the accumulator to int8, bit-matching the paper's Fig. 6
    waveform — the wrap path has no requantize stage, so combining it with
    ``out_scale`` is an error rather than a silent drop.

    The float accumulator path (float inputs, no out_scale/wrap8) is
    differentiable: a custom VJP runs the backward through the same
    weight-stationary dataflow (kernels/conv2d_ws_bwd.py), with residuals
    carrying the fused-epilogue masks — so any stride/padding/epilogue
    config used in a training graph differentiates consistently with the
    ref oracle.  The int8 and requantized paths stay non-differentiable
    (an int8 forward has no meaningful int8 gradient; QAT trains the
    float shadow with straight-through fake quantization instead —
    core/training.py).

    ``dilation`` dilates the kernel taps (effective extent
    ``dilation·(k−1)+1``) — the dense-prediction context-aggregation
    knob; it threads through padding/halo geometry, both kernel
    variants, and the custom VJP unchanged.

    ``pipelined=True`` routes the layer through ``conv2d_ws_pipe`` (the
    explicit double-buffered manual-DMA kernel) instead of ``conv2d_ws``
    — bit-exact on every path, so this is purely a performance choice;
    ``banking.plan_tiles(kernel="auto")`` makes it per layer and the
    backends forward ``TilePlan.pipelined`` here.
    """
    if wrap8 and out_scale is not None:
        raise ValueError("wrap8 and out_scale are mutually exclusive: the "
                         "Fig. 6 wrap path has no requantize stage")
    if groups > 1:
        # re-legalize the requested banking for the group structure (the
        # kernel rejects banks that straddle group boundaries)
        cin_banks, kout_banks = _ref.grouped_banks(
            x.shape[3], w.shape[3], groups, want_cin=cin_banks,
            want_kout=kout_banks)
    if (out_scale is None and not wrap8
            and jnp.issubdtype(jnp.result_type(x), jnp.floating)):
        pad = _ref.normalize_padding(padding, w.shape[0], w.shape[1],
                                     stride, x.shape[1], x.shape[2],
                                     dilation)
        cfg = _ConvCfg(stride=stride, padding=pad, groups=groups,
                       cin_banks=cin_banks, kout_banks=kout_banks,
                       h_tile=h_tile, w_tile=w_tile, relu=relu, pool=pool,
                       dilation=dilation, pipelined=pipelined)
        return _conv2d_float(cfg, x, w, bias)
    fwd = (_pipe_mod.conv2d_ws_pipe if pipelined else _conv_mod.conv2d_ws)
    out = fwd(x, w, bias, out_scale, stride=stride,
              padding=padding, groups=groups,
              cin_banks=cin_banks, kout_banks=kout_banks,
              h_tile=h_tile, w_tile=w_tile, relu=relu,
              pool=pool, dilation=dilation, interpret=_interpret())
    if x.dtype == jnp.int8 and wrap8:
        return out.astype(jnp.int8)
    return out


# ---------------------------------------------------------------------------
# Transposed convolution (the dense-prediction upsampling entry point)
# ---------------------------------------------------------------------------


class _ConvTransCfg(NamedTuple):
    """Hashable static config of one transposed-conv pass.  ``padding`` is
    pre-resolved to explicit form normalized against the OUTPUT spatial
    shape ``(out_h, out_w)`` — the forward-conv frame of the transpose
    duality — so the backward rules need no shape context."""
    stride: int
    padding: Tuple[Tuple[int, int], Tuple[int, int]]
    groups: int
    cin_banks: int
    kout_banks: int
    h_tile: int
    w_tile: int
    relu: bool
    pool: bool
    dilation: int
    out_h: int
    out_w: int
    pipelined: bool = False


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _conv2d_transpose_float(cfg: _ConvTransCfg, x, w, bias):
    return _trans_mod.conv2d_ws_transpose(
        x, w, bias, None, stride=cfg.stride, padding=cfg.padding,
        groups=cfg.groups, cin_banks=cfg.cin_banks,
        kout_banks=cfg.kout_banks, h_tile=cfg.h_tile, w_tile=cfg.w_tile,
        relu=cfg.relu, pool=cfg.pool, dilation=cfg.dilation,
        out_spatial=(cfg.out_h, cfg.out_w), pipelined=cfg.pipelined,
        interpret=_interpret())


def _conv2d_transpose_float_fwd(cfg: _ConvTransCfg, x, w, bias):
    """Epilogue-free transpose exposes the f32 accumulator; ReLU/pool at
    the jnp level are bit-identical to the fused epilogue and leave only
    their MASKS as residuals (same scheme as _conv2d_float_fwd)."""
    acc = _trans_mod.conv2d_ws_transpose(
        x, w, bias, None, stride=cfg.stride, padding=cfg.padding,
        groups=cfg.groups, cin_banks=cfg.cin_banks,
        kout_banks=cfg.kout_banks, h_tile=cfg.h_tile, w_tile=cfg.w_tile,
        dilation=cfg.dilation, out_spatial=(cfg.out_h, cfg.out_w),
        interpret=_interpret())
    relu_mask = pool_idx = None
    y = acc
    if cfg.relu:
        relu_mask = _ref.relu_mask_ref(acc)
        y = jnp.maximum(y, 0)
    if cfg.pool:
        oh, ow = acc.shape[1], acc.shape[2]
        if oh < 2 or ow < 2:
            raise ValueError(
                f"2×2 pool needs a ≥2×2 transpose output, got {oh}×{ow}")
        pool_idx = _ref.maxpool2x2_argmax_ref(y)
        y = _ref.maxpool2d_ref(y, 2)
    return y, (x, w, bias, relu_mask, pool_idx, acc.shape)


def _conv2d_transpose_float_bwd(cfg: _ConvTransCfg, res, g):
    """The transpose duality run in reverse — NO new kernel code:

    * dX = the ORDINARY strided forward conv of the cotangent with the
      channel-swapped weights (the transpose op is the adjoint of exactly
      that conv, so its VJP wrt the input is the conv itself);
    * dW = the channel-swap of the forward weight-grad GEMMs with the
      cotangent playing the conv INPUT and the primal input playing the
      conv cotangent (⟨g, Tᵂ x⟩ = ⟨F_w g, x⟩ differentiated in w);
    * db = the cotangent summed over (N, OH, OW).
    """
    x, w, bias, relu_mask, pool_idx, acc_shape = res
    dacc = g.astype(jnp.float32)
    if cfg.pool:
        dacc = _ref.maxpool2x2_bwd_ref(pool_idx, dacc, acc_shape)
    if cfg.relu:
        dacc = dacc * relu_mask
    wf = _ref.grouped_swap_weights(w, cfg.groups).astype(jnp.float32)
    # the dual conv contracts the transpose's K channels back to C, so
    # the bank requests re-legalize against (K, C)
    cb_n, kb_n = _ref.grouped_banks(
        w.shape[3], x.shape[3], cfg.groups, want_cin=cfg.cin_banks,
        want_kout=max(cfg.kout_banks, cfg.groups))
    dx = _conv_mod.conv2d_ws(
        dacc, wf, None, stride=cfg.stride, padding=cfg.padding,
        groups=cfg.groups, cin_banks=cb_n, kout_banks=kb_n,
        h_tile=cfg.h_tile, w_tile=cfg.w_tile, dilation=cfg.dilation,
        interpret=_interpret()).astype(x.dtype)
    dwf = _bwd_mod.conv2d_ws_weight_grad(
        dacc, x.astype(jnp.float32), w.shape[0], w.shape[1],
        stride=cfg.stride, padding=cfg.padding, groups=cfg.groups,
        dilation=cfg.dilation, interpret=_interpret())
    dw = _ref.grouped_swap_weights(dwf, cfg.groups).astype(w.dtype)
    db = (jnp.sum(dacc, axis=(0, 1, 2)).astype(bias.dtype)
          if bias is not None else None)
    return dx, dw, db


_conv2d_transpose_float.defvjp(_conv2d_transpose_float_fwd,
                               _conv2d_transpose_float_bwd)


def conv2d_transpose(x, w, bias=None, *, stride: int = 1, padding="VALID",
                     groups: int = 1, cin_banks: int = 4,
                     kout_banks: int = 4, h_tile: int = 0, w_tile: int = 0,
                     relu: bool = False, pool: bool = False, out_scale=None,
                     dilation: int = 1, out_spatial=None,
                     pipelined: bool = False):
    """Transposed convolution through the weight-stationary dataflow
    (kernels/conv2d_ws_trans.py): lhs zero-insertion by ``stride``,
    kernel flip, and the stride-1 forward kernel under the "full"-padding
    equivalence.  x: [N,H,W,C]; w: [KH,KW,C/groups,K] (forward layout) →
    [N,OH,OW,K] with SAME growing to exactly ``H·stride``, VALID to
    ``(H−1)·stride + dilation·(k−1)+1``, and ``out_spatial`` pinning the
    output shape (the gradient-duality form).

    The epilogue contract (``relu`` / 2×2 ``pool`` / ``out_scale``
    requantize — int8 chained-layer deployment), grouped banking,
    spatial tiling, and ``pipelined=`` kernel choice all match
    ``conv2d``.  The float path (no out_scale) is differentiable: the
    custom VJP is the transpose duality run in reverse — dX is an
    ordinary strided conv, dW the channel-swapped weight-grad GEMMs — so
    upsampling layers train through the same paper dataflow.
    """
    if groups > 1:
        cin_banks, kout_banks = _ref.grouped_banks(
            x.shape[3], w.shape[3], groups, want_cin=cin_banks,
            want_kout=kout_banks)
    kh, kw = w.shape[0], w.shape[1]
    (oh, ow), _ = _ref.conv_transpose_eq_params(
        x.shape[1], x.shape[2], kh, kw, stride, padding, dilation,
        out_spatial)
    pad = _ref.normalize_padding(padding, kh, kw, stride, oh, ow, dilation)
    if (out_scale is None
            and jnp.issubdtype(jnp.result_type(x), jnp.floating)):
        cfg = _ConvTransCfg(stride=stride, padding=pad, groups=groups,
                            cin_banks=cin_banks, kout_banks=kout_banks,
                            h_tile=h_tile, w_tile=w_tile, relu=relu,
                            pool=pool, dilation=dilation, out_h=oh,
                            out_w=ow, pipelined=pipelined)
        return _conv2d_transpose_float(cfg, x, w, bias)
    return _trans_mod.conv2d_ws_transpose(
        x, w, bias, out_scale, stride=stride, padding=pad, groups=groups,
        cin_banks=cin_banks, kout_banks=kout_banks, h_tile=h_tile,
        w_tile=w_tile, relu=relu, pool=pool, dilation=dilation,
        out_spatial=(oh, ow), pipelined=pipelined, interpret=_interpret())


def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int = 512, block_k: int = 512):
    """Pallas flash attention (beyond-paper kernel; see
    kernels/flash_attention.py).  On TPU this replaces the pure-JAX
    chunked attention for prefill/train (cfg.attn_impl == "flash");
    interpret mode validates it on CPU."""
    from repro.kernels.flash_attention import flash_attention as _fa
    return _fa(q, k, v, causal=causal, block_q=block_q, block_k=block_k,
               interpret=_interpret())


def conv1d_depthwise(x, w, bias=None):
    """Causal depthwise temporal conv through the grouped WS conv kernel.

    x: [B,S,W], w: [K,W] → [B,S,W] (in x's dtype).  The temporal conv is
    a width-grouped 1×K conv2d over a height-1 map: the sequence plays
    the spatial W axis, causality is left-padding of K−1, and
    ``groups == W`` makes every lane its own group — the depthwise case
    of the paper dataflow (one image BMG per lane, kernel-set banks on
    group boundaries).  Going through ``conv2d`` keeps the grouped
    custom VJP, so the temporal conv stays differentiable inside
    training graphs.  The old pass-through to the ref oracle is gone;
    ``ref.conv1d_depthwise_ref`` remains the correctness contract."""
    k, width = w.shape
    acc = conv2d(x[:, None], w[None, :, None, :], bias, stride=1,
                 padding=((0, 0), (k - 1, 0)), groups=width,
                 cin_banks=1, kout_banks=width)[:, 0]
    return acc.astype(x.dtype)
