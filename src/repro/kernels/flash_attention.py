"""Flash attention as a Pallas TPU kernel (beyond-paper optimization).

The paper's load/compute pipelining + VMEM banking ideas, applied to the
*other* hot spot of the LM stack: causal attention.  One (batch, head,
q-block) grid cell streams KV blocks through VMEM with online-softmax
accumulation — the KV stream is the paper's "image loader", the q block is
weight-stationary in VMEM for the whole sweep.

Grid: (B·H, nq, nk) with nk innermost; the causal upper triangle is skipped
with @pl.when (the kernel-level analogue of the cond-skip in
layers/attention.chunked_attention).  Accumulators (m, l, acc) live in VMEM
scratch across the nk sweep.

Used on TPU via ops.flash_attention; validated in interpret mode against
layers.attention.dense_attention (tests/test_flash_kernel.py).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  nk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    i = pl.program_id(1)
    q_lo = i * block_q
    k_lo = j * block_k

    # causal: skip blocks entirely above the diagonal
    needed = (not causal) or (k_lo <= q_lo + block_q - 1)

    @pl.when(jnp.asarray(needed) if isinstance(needed, bool) else needed)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale          # [bq, d]
        k = k_ref[0].astype(jnp.float32)                  # [bk, d]
        v = v_ref[0].astype(jnp.float32)                  # [bk, d]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        if causal:
            qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
            kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1)
        acc_ref[...] = (alpha[:, None] * acc_ref[...]
                        + jnp.dot(p, v, preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "block_q", "block_k",
                                    "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 512,
                    block_k: int = 512, interpret: bool = False):
    """q,k,v: [B, S, H, D] → [B, S, H, D] (flash, O(S·block) memory).

    Block defaults are MXU/VMEM-tuned for v5e: a (512×D + 2·512×D) f32
    working set plus [512,512] scores ≈ 2.6 MiB at D=128 — comfortably
    double-bufferable in ~128 MiB VMEM.
    """
    B, S, H, D = q.shape
    bq = min(block_q, S)
    bk = min(block_k, S)
    while S % bq:
        bq //= 2
    while S % bk:
        bk //= 2
    nq, nk = S // bq, S // bk
    scale = 1.0 / math.sqrt(D)

    def reorg(t):
        return t.transpose(0, 2, 1, 3).reshape(B * H, S, t.shape[-1])

    qf, kf, vf = reorg(q), reorg(k), reorg(v)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               block_q=bq, block_k=bk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, i, j: (bh, j, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, i, j: (bh, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)
