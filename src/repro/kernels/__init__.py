"""Pallas TPU kernels (validated in interpret mode on CPU; see ops.py for
the public jit'd wrappers and ref.py for the pure-jnp oracles).

* conv2d_ws        — the paper's IP core: channel-banked, weight-stationary,
                     bias-preloaded blocked convolution (+int8/wrap8 modes)
* conv2d_ws_bwd    — the conv backward pass on the same dataflow: input
                     grads as a dilated transposed conv through conv2d_ws,
                     weight grads as batched-correlation WS GEMMs (wired
                     into ops.conv2d's custom VJP for training)
* matmul_ws        — the same dataflow generalized to transformer GEMMs
                     (custom VJP for training use)
* flash_attention  — beyond-paper: flash attention with the paper's
                     load/compute pipelining on the KV stream
"""
