"""Pallas TPU kernels (validated in interpret mode on CPU; see ops.py for
the public jit'd wrappers and ref.py for the pure-jnp oracles).

* conv2d_ws        — the paper's IP core: channel-banked, weight-stationary,
                     bias-preloaded blocked convolution (+int8/wrap8 modes)
* matmul_ws        — the same dataflow generalized to transformer GEMMs
                     (custom VJP for training use)
* flash_attention  — beyond-paper: flash attention with the paper's
                     load/compute pipelining on the KV stream
"""
