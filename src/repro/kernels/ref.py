"""Pure-jnp oracles for every kernel (the correctness contract).

Includes the paper-faithful int8 datapath variants:
* int8 inputs with int32 accumulation (production),
* ``wrap8``: 8-bit wrap-around psum accumulation, bit-matching the Fig.6
  simulation waveform (psums stored in 8-bit BRAM slots).
"""

from __future__ import annotations

from typing import Tuple, Union

import jax
import jax.numpy as jnp

Padding = Union[str, int, Tuple[Tuple[int, int], Tuple[int, int]]]


def normalize_padding(padding: Padding, kh: int, kw: int,
                      stride: int = 1, h: int = 0, w: int = 0
                      ) -> Tuple[Tuple[int, int], Tuple[int, int]]:
    """Resolve SAME/VALID/int/explicit padding to ((top,bottom),(left,right)).

    SAME follows the TF/XLA convention: output = ceil(in/stride), with the
    extra pixel (odd total pad) on the bottom/right."""
    if isinstance(padding, int):
        return ((padding, padding), (padding, padding))
    if isinstance(padding, (tuple, list)):
        (a, b), (c, d) = padding
        return ((int(a), int(b)), (int(c), int(d)))
    if padding == "VALID":
        return ((0, 0), (0, 0))
    if padding == "SAME":
        def same(dim, k):
            out = -(-dim // stride)
            total = max((out - 1) * stride + k - dim, 0)
            return (total // 2, total - total // 2)
        return (same(h, kh), same(w, kw))
    raise ValueError(f"unknown padding {padding!r}")


def conv_out_shape(h: int, w: int, kh: int, kw: int, stride: int = 1,
                   padding: Padding = "VALID") -> Tuple[int, int]:
    """Spatial output shape of a conv layer (shared by kernel/banking/perf)."""
    (pt, pb), (pl_, pr) = normalize_padding(padding, kh, kw, stride, h, w)
    return ((h + pt + pb - kh) // stride + 1,
            (w + pl_ + pr - kw) // stride + 1)


def halo_window(tile: int, stride: int, k: int) -> int:
    """Input extent consumed by ``tile`` contiguous conv outputs: adjacent
    windows overlap by ``k − stride`` (the halo).  The single definition
    shared by the tiled kernel's BlockSpecs, the TilePlan planner, and the
    spatial-shard band math — they must never disagree on this."""
    return (tile - 1) * stride + k


def divisor_banks(dim: int, want: int) -> int:
    """Largest bank count ≤ ``want`` that divides ``dim`` — how the paper's
    divisible-by-4 invariant degrades for awkward channel counts (e.g. the
    C=1 input layer of a grayscale network runs on a single image BMG).
    Lives here (with the other shared shape math) so kernels and the core
    planner agree without a layering inversion."""
    b = max(1, min(want, dim))
    while dim % b:
        b -= 1
    return b


def grouped_banks(c: int, k: int, groups: int = 1, want_cin: int = 4,
                  want_kout: int = 4) -> Tuple[int, int]:
    """Legal (cin_banks, kout_banks) for a grouped conv, degraded from the
    requested paper banking: cin banks must divide the per-group channel
    slice C/g (the only channels a kernel set reads), and kout banks must
    split along group boundaries — ``kout_banks % groups == 0`` with the
    banks-per-group count dividing K/g — so every kout bank's weight block
    stays inside one group's cin slice.  Depthwise (g == C) degenerates to
    one cin bank and one kout bank per channel."""
    check_groups(c, k, groups)
    cg, kg = c // groups, k // groups
    cin = divisor_banks(cg, want_cin)
    bpg = divisor_banks(kg, max(1, want_kout // groups))
    return cin, groups * bpg


def check_groups(c: int, k: int, groups: int) -> None:
    """The grouped-conv divisibility contract, shared by oracle / kernel /
    planner / compiler so they all reject the same shapes the same way:
    ``groups`` must divide both the input and output channel counts
    (``groups == c`` is the depthwise case)."""
    if groups < 1 or c % groups or k % groups:
        raise ValueError(
            f"groups={groups} must divide both C={c} and K={k} "
            f"(groups == C is depthwise)")


def conv2d_ref(x, w, bias=None, *, stride: int = 1,
               padding: Padding = "VALID", groups: int = 1,
               accum_dtype=jnp.float32):
    """General convolution oracle.  x: [N,H,W,C]; w: [KH,KW,C/groups,K] →
    [N,OH,OW,K].

    The paper's Eq. (2): F(i,j) = Σ_d Σ_m Σ_n I(i·s+m, j·s+n, d) · K(m,n,d),
    extended with stride s, zero padding, and grouped channel contraction
    (``groups > 1``): output kernel k only reads the C/groups input
    channels of its group — ``groups == C`` is the depthwise conv of the
    MobileNet workload family."""
    check_groups(x.shape[3], w.shape[3], groups)
    pad = normalize_padding(padding, w.shape[0], w.shape[1], stride,
                            x.shape[1], x.shape[2])
    out = jax.lax.conv_general_dilated(
        x.astype(accum_dtype), w.astype(accum_dtype),
        window_strides=(stride, stride), padding=pad,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
        preferred_element_type=accum_dtype)
    if bias is not None:
        out = out + bias.astype(accum_dtype)
    return out


def conv2d_ref_int8(x, w, bias=None, *, stride: int = 1,
                    padding: Padding = "VALID", groups: int = 1):
    """int8 × int8 → int32 accumulation (production 8-bit datapath).

    Zero padding is exact for the symmetric (zero-point-0) int8 scheme."""
    assert x.dtype == jnp.int8 and w.dtype == jnp.int8
    check_groups(x.shape[3], w.shape[3], groups)
    pad = normalize_padding(padding, w.shape[0], w.shape[1], stride,
                            x.shape[1], x.shape[2])
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.int32), w.astype(jnp.int32),
        window_strides=(stride, stride), padding=pad,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)
    if bias is not None:
        out = out + bias.astype(jnp.int32)
    return out


def maxpool2d_ref(x, size: int = 2, stride: int = None):
    """Max pool over [N,H,W,C]; trailing rows/cols that don't fill a window
    are dropped (floor semantics, matching the fused kernel epilogue)."""
    stride = size if stride is None else stride
    init = jnp.iinfo(x.dtype).min if jnp.issubdtype(x.dtype, jnp.integer) \
        else -jnp.inf
    return jax.lax.reduce_window(
        x, jnp.asarray(init, x.dtype), jax.lax.max,
        (1, size, size, 1), (1, stride, stride, 1), "VALID")


def avgpool2d_ref(x, size: int = 2, stride: int = None):
    """Average pool over [N,H,W,C] (floor semantics, like maxpool2d_ref).

    Integer inputs accumulate the window sum in int32 and round the mean
    back to the input dtype — the int8 feature-map grid is preserved
    (mean of same-scale values stays on the same scale), so the unfused
    int8 avg-pool layer needs no requantization."""
    stride = size if stride is None else stride
    if jnp.issubdtype(x.dtype, jnp.integer):
        s = jax.lax.reduce_window(
            x.astype(jnp.int32), jnp.int32(0), jax.lax.add,
            (1, size, size, 1), (1, stride, stride, 1), "VALID")
        mean = jnp.round(s.astype(jnp.float32) / (size * size))
        info = jnp.iinfo(x.dtype)
        return jnp.clip(mean, info.min, info.max).astype(x.dtype)
    s = jax.lax.reduce_window(
        x.astype(jnp.float32), jnp.float32(0), jax.lax.add,
        (1, size, size, 1), (1, stride, stride, 1), "VALID")
    return (s / (size * size)).astype(x.dtype)


def global_avgpool_ref(x):
    """Global average pool [N,H,W,C] → [N,C] (the classifier-head reduce).

    Integer inputs round the mean back onto the input dtype's grid, like
    ``avgpool2d_ref``."""
    if jnp.issubdtype(x.dtype, jnp.integer):
        s = jnp.sum(x.astype(jnp.int32), axis=(1, 2))
        mean = jnp.round(s.astype(jnp.float32) / (x.shape[1] * x.shape[2]))
        info = jnp.iinfo(x.dtype)
        return jnp.clip(mean, info.min, info.max).astype(x.dtype)
    return jnp.mean(x.astype(jnp.float32), axis=(1, 2)).astype(x.dtype)


def requantize_ref(acc, out_scale):
    """int32/f32 accumulator × scale → int8 (round-to-nearest, saturating).
    out_scale: scalar or per-channel [K] (broadcast over the last axis)."""
    scaled = jnp.round(acc.astype(jnp.float32) * out_scale)
    return jnp.clip(scaled, -128, 127).astype(jnp.int8)


def add_requant_ref(a, b, scale_a, scale_b, *, relu: bool = False):
    """Residual (skip-connection) merge on a shared int8 grid — the oracle
    for the network executor's ``add`` node.

    Each int8 operand re-expresses on the merge node's output grid through
    its branch requant scale (``s_branch / s_out``, round-to-nearest), the
    aligned values add, optional ReLU, saturate to int8.  When both
    branches already sit on the shared grid (branch scales == 1) the merge
    is exact int8 arithmetic — the FPGA output-BRAM-crossbar idiom: the
    skip path adds into the conv path's output BRAMs without ever leaving
    8 bits, no int32 accumulator round-trip."""
    assert a.dtype == jnp.int8 and b.dtype == jnp.int8, (a.dtype, b.dtype)
    ya = jnp.round(a.astype(jnp.float32) * jnp.asarray(scale_a, jnp.float32))
    yb = jnp.round(b.astype(jnp.float32) * jnp.asarray(scale_b, jnp.float32))
    y = ya + yb
    if relu:
        y = jnp.maximum(y, 0)
    return jnp.clip(y, -128, 127).astype(jnp.int8)


def conv2d_epilogue_ref(x, w, bias=None, *, stride: int = 1,
                        padding: Padding = "VALID", relu: bool = False,
                        pool: bool = False, out_scale=None,
                        groups: int = 1):
    """Conv + the fused FPGA post-processing chain: ReLU → 2×2 max-pool →
    requantize, in accumulator precision (the oracle for the fused kernel
    epilogue).  ``groups`` selects grouped/depthwise channel contraction
    like ``conv2d_ref``."""
    if x.dtype == jnp.int8:
        acc = conv2d_ref_int8(x, w, bias, stride=stride, padding=padding,
                              groups=groups)
    else:
        acc = conv2d_ref(x, w, bias, stride=stride, padding=padding,
                         groups=groups)
    if relu:
        acc = jnp.maximum(acc, 0)
    if pool:
        acc = maxpool2d_ref(acc)
    if out_scale is not None:
        return requantize_ref(acc, out_scale)
    return acc


def conv2d_ref_wrap8(x, w, bias=None):
    """Paper-waveform mode: every accumulation wraps in 8 bits.

    Because int8 wrap-around addition is associative and the products enter
    mod-256 arithmetic independently, this equals the int32 result mod 256."""
    out = conv2d_ref_int8(x, w, bias)
    return out.astype(jnp.int8)


# ---------------------------------------------------------------------------
# Backward-pass oracles (the training contract)
# ---------------------------------------------------------------------------


def grouped_transpose_weights(w, groups: int = 1):
    """Forward weights [KH,KW,C/groups,K] → transposed-conv weights
    [KH,KW,K/groups,C]: spatial flip + per-group channel-axis swap, groups
    reassembled along the new output axis.  The single definition shared
    by the input-gradient oracle and the WS backward kernel — in the
    transposed conv the cotangent's K channels play the input role (K/g
    per group) and the forward input's C channels the output role."""
    kh, kw, cg, k = w.shape
    kg = k // groups
    wt = jnp.flip(w, (0, 1))
    if groups == 1:
        return wt.swapaxes(2, 3)
    return (wt.reshape(kh, kw, cg, groups, kg)
            .transpose(0, 1, 4, 3, 2).reshape(kh, kw, kg, groups * cg))


def conv2d_input_grad_ref(g, w, x_shape, *, stride: int = 1,
                          padding: Padding = "VALID", groups: int = 1):
    """dL/dx of ``conv2d_ref``: the transposed convolution, stated directly
    as zero-insertion dilation + kernel flip (NOT via jax.vjp, so it is an
    independent contract for the WS backward kernel).

    The cotangent ``g`` [N,OH,OW,K] dilates by the forward stride
    (zero-insertion), the kernel flips spatially and swaps its channel
    axes per group ([KH,KW,C/g,K] → [KH,KW,K/g,C] —
    ``grouped_transpose_weights``), and a stride-1 grouped correlation
    with "full" padding (kh−1−pt on top, h+pt−(oh−1)·s−1 on the bottom —
    rows the strided forward never reached get negative padding) recovers
    [N,H,W,C]."""
    n, h, w_dim, c = x_shape
    kh, kw, cg, k = w.shape
    assert c == cg * groups, (c, cg, groups)
    (pt, _), (pl_, _) = normalize_padding(padding, kh, kw, stride, h, w_dim)
    oh, ow = g.shape[1], g.shape[2]
    wt = grouped_transpose_weights(w, groups)
    return jax.lax.conv_general_dilated(
        g.astype(jnp.float32), wt.astype(jnp.float32), (1, 1),
        ((kh - 1 - pt, h + pt - (oh - 1) * stride - 1),
         (kw - 1 - pl_, w_dim + pl_ - (ow - 1) * stride - 1)),
        lhs_dilation=(stride, stride),
        feature_group_count=groups,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def conv2d_weight_grad_ref(x, g, kh: int, kw: int, *, stride: int = 1,
                           padding: Padding = "VALID", groups: int = 1):
    """dL/dw of ``conv2d_ref``: a batched correlation — tap (dy,dx) of the
    weight gradient contracts the stride-strided input window starting at
    (dy,dx) with the cotangent over (N,OH,OW):

        dW[dy,dx,c,k] = Σ_{n,i,j} x_pad[n, i·s+dy, j·s+dx, c] · g[n,i,j,k]

    With ``groups > 1`` the contraction stays within each group: output
    kernel k in group i only ever saw that group's C/g input channels, so
    the tap einsum carries a group axis and dW keeps the forward's
    [KH,KW,C/g,K] layout."""
    n, h, w_dim, c = x.shape
    oh, ow, k = g.shape[1], g.shape[2], g.shape[3]
    check_groups(c, k, groups)
    cg, kg = c // groups, k // groups
    (pt, pb), (pl_, pr) = normalize_padding(padding, kh, kw, stride, h,
                                            w_dim)
    xp = jnp.pad(x.astype(jnp.float32),
                 ((0, 0), (pt, pb), (pl_, pr), (0, 0)))
    gf = g.astype(jnp.float32)
    taps = []
    for dy in range(kh):
        for dx in range(kw):
            xs = jax.lax.slice(
                xp, (0, dy, dx, 0),
                (n, dy + (oh - 1) * stride + 1, dx + (ow - 1) * stride + 1,
                 c), (1, stride, stride, 1))
            if groups == 1:
                taps.append(jnp.einsum("nijc,nijk->ck", xs, gf))
            else:
                tap = jnp.einsum(
                    "nijgc,nijgk->gck",
                    xs.reshape(n, oh, ow, groups, cg),
                    gf.reshape(n, oh, ow, groups, kg))
                taps.append(tap.transpose(1, 0, 2).reshape(cg, k))
    return jnp.stack(taps).reshape(kh, kw, cg, k)


def conv2d_bias_grad_ref(g):
    """dL/db of ``conv2d_ref``: the cotangent summed over (N,OH,OW), in
    f32 (low-precision cotangents must not round per-partial-sum)."""
    return jnp.sum(g.astype(jnp.float32), axis=(0, 1, 2))


def relu_mask_ref(acc):
    """The fused-epilogue ReLU backward mask: 1 where the accumulator was
    strictly positive (the subgradient-at-0 convention jax.grad uses)."""
    return acc > 0


def maxpool2x2_argmax_ref(y):
    """Per-window argmax of the 2×2/2 max-pool (row-major within the
    window, first max wins — jnp.argmax semantics).  Trailing odd rows /
    columns are dropped, matching the fused epilogue's floor semantics.
    Returns int8 [N, H//2, W//2, C] with values in 0..3 — the pool mask
    the training residuals carry."""
    n, h, w, c = y.shape
    h2, w2 = h // 2, w // 2
    win = y[:, :h2 * 2, :w2 * 2].reshape(n, h2, 2, w2, 2, c)
    win = win.transpose(0, 1, 3, 5, 2, 4).reshape(n, h2, w2, c, 4)
    return jnp.argmax(win, axis=-1).astype(jnp.int8)


def maxpool2x2_bwd_ref(idx, g, out_shape):
    """Backward of the 2×2/2 max-pool given its argmax mask: each window's
    cotangent routes to the position ``idx`` selected in the forward pass;
    dropped trailing odd rows/columns get zero.  ``out_shape`` is the
    pre-pool [N,H,W,C] shape."""
    n, h, w, c = out_shape
    h2, w2 = h // 2, w // 2
    onehot = jax.nn.one_hot(idx.astype(jnp.int32), 4,
                            dtype=jnp.float32)            # [N,H2,W2,C,4]
    dwin = g.astype(jnp.float32)[..., None] * onehot
    dy = dwin.reshape(n, h2, w2, c, 2, 2).transpose(0, 1, 4, 2, 5, 3)
    dy = dy.reshape(n, h2 * 2, w2 * 2, c)
    return jnp.pad(dy, ((0, 0), (0, h - h2 * 2), (0, w - w2 * 2), (0, 0)))


def matmul_ref(x, w, bias=None, *, accum_dtype=jnp.float32):
    """x: [M,K] @ w: [K,N] + bias."""
    out = jnp.dot(x.astype(accum_dtype), w.astype(accum_dtype),
                  preferred_element_type=accum_dtype)
    if bias is not None:
        out = out + bias.astype(accum_dtype)
    return out


def matmul_ref_int8(x, w, bias=None):
    assert x.dtype == jnp.int8 and w.dtype == jnp.int8
    out = jnp.dot(x.astype(jnp.int32), w.astype(jnp.int32))
    if bias is not None:
        out = out + bias.astype(jnp.int32)
    return out


def conv1d_depthwise_ref(x, w, bias=None):
    """Causal depthwise temporal conv (RecurrentGemma site).
    x: [B,S,W]; w: [K,W] → [B,S,W]."""
    K = w.shape[0]
    pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    S = x.shape[1]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for j in range(K):
        out = out + xp[:, j:j + S].astype(jnp.float32) * w[j].astype(jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(x.dtype)
