"""Pure-jnp oracles for every kernel (the correctness contract).

Includes the paper-faithful int8 datapath variants:
* int8 inputs with int32 accumulation (production),
* ``wrap8``: 8-bit wrap-around psum accumulation, bit-matching the Fig.6
  simulation waveform (psums stored in 8-bit BRAM slots).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def conv2d_ref(x, w, bias=None, *, accum_dtype=jnp.float32):
    """VALID, stride-1 convolution.  x: [N,H,W,C]; w: [KH,KW,C,K] → [N,OH,OW,K].

    The paper's Eq. (2): F(i,j) = Σ_d Σ_m Σ_n I(i+m, j+n, d) · K(m,n,d)."""
    out = jax.lax.conv_general_dilated(
        x.astype(accum_dtype), w.astype(accum_dtype),
        window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=accum_dtype)
    if bias is not None:
        out = out + bias.astype(accum_dtype)
    return out


def conv2d_ref_int8(x, w, bias=None):
    """int8 × int8 → int32 accumulation (production 8-bit datapath)."""
    assert x.dtype == jnp.int8 and w.dtype == jnp.int8
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.int32), w.astype(jnp.int32),
        window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if bias is not None:
        out = out + bias.astype(jnp.int32)
    return out


def conv2d_ref_wrap8(x, w, bias=None):
    """Paper-waveform mode: every accumulation wraps in 8 bits.

    Because int8 wrap-around addition is associative and the products enter
    mod-256 arithmetic independently, this equals the int32 result mod 256."""
    out = conv2d_ref_int8(x, w, bias)
    return out.astype(jnp.int8)


def matmul_ref(x, w, bias=None, *, accum_dtype=jnp.float32):
    """x: [M,K] @ w: [K,N] + bias."""
    out = jnp.dot(x.astype(accum_dtype), w.astype(accum_dtype),
                  preferred_element_type=accum_dtype)
    if bias is not None:
        out = out + bias.astype(accum_dtype)
    return out


def matmul_ref_int8(x, w, bias=None):
    assert x.dtype == jnp.int8 and w.dtype == jnp.int8
    out = jnp.dot(x.astype(jnp.int32), w.astype(jnp.int32))
    if bias is not None:
        out = out + bias.astype(jnp.int32)
    return out


def conv1d_depthwise_ref(x, w, bias=None):
    """Causal depthwise temporal conv (RecurrentGemma site).
    x: [B,S,W]; w: [K,W] → [B,S,W]."""
    K = w.shape[0]
    pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    S = x.shape[1]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for j in range(K):
        out = out + xp[:, j:j + S].astype(jnp.float32) * w[j].astype(jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(x.dtype)
