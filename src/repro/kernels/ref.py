"""Pure-jnp oracles for every kernel (the correctness contract).

Includes the paper-faithful int8 datapath variants:
* int8 inputs with int32 accumulation (production),
* ``wrap8``: 8-bit wrap-around psum accumulation, bit-matching the Fig.6
  simulation waveform (psums stored in 8-bit BRAM slots).
"""

from __future__ import annotations

from typing import Tuple, Union

import jax
import jax.numpy as jnp

Padding = Union[str, int, Tuple[Tuple[int, int], Tuple[int, int]]]


def dilated_extent(k: int, dilation: int = 1) -> int:
    """Spatial extent of a dilated kernel: ``dilation·(k−1)+1`` taps apart.
    Every piece of halo/padding/output-shape math sees the dilated kernel
    only through this extent, so it is THE shared definition."""
    return dilation * (k - 1) + 1


def normalize_padding(padding: Padding, kh: int, kw: int,
                      stride: int = 1, h: int = 0, w: int = 0,
                      dilation: int = 1
                      ) -> Tuple[Tuple[int, int], Tuple[int, int]]:
    """Resolve SAME/VALID/int/explicit padding to ((top,bottom),(left,right)).

    SAME follows the TF/XLA convention: output = ceil(in/stride), with the
    extra pixel (odd total pad) on the bottom/right; a dilated kernel pads
    for its effective extent ``dilation·(k−1)+1``."""
    if isinstance(padding, int):
        return ((padding, padding), (padding, padding))
    if isinstance(padding, (tuple, list)):
        (a, b), (c, d) = padding
        return ((int(a), int(b)), (int(c), int(d)))
    if padding == "VALID":
        return ((0, 0), (0, 0))
    if padding == "SAME":
        def same(dim, k):
            out = -(-dim // stride)
            total = max((out - 1) * stride + dilated_extent(k, dilation)
                        - dim, 0)
            return (total // 2, total - total // 2)
        return (same(h, kh), same(w, kw))
    raise ValueError(f"unknown padding {padding!r}")


def conv_out_shape(h: int, w: int, kh: int, kw: int, stride: int = 1,
                   padding: Padding = "VALID",
                   dilation: int = 1) -> Tuple[int, int]:
    """Spatial output shape of a conv layer (shared by kernel/banking/perf)."""
    (pt, pb), (pl_, pr) = normalize_padding(padding, kh, kw, stride, h, w,
                                            dilation)
    return ((h + pt + pb - dilated_extent(kh, dilation)) // stride + 1,
            (w + pl_ + pr - dilated_extent(kw, dilation)) // stride + 1)


def halo_window(tile: int, stride: int, k: int, dilation: int = 1) -> int:
    """Input extent consumed by ``tile`` contiguous conv outputs: adjacent
    windows overlap by ``dilation·(k−1)+1 − stride`` (the halo).  The single
    definition shared by the tiled kernel's BlockSpecs, the TilePlan
    planner, and the spatial-shard band math — they must never disagree on
    this."""
    return (tile - 1) * stride + dilated_extent(k, dilation)


def divisor_banks(dim: int, want: int) -> int:
    """Largest bank count ≤ ``want`` that divides ``dim`` — how the paper's
    divisible-by-4 invariant degrades for awkward channel counts (e.g. the
    C=1 input layer of a grayscale network runs on a single image BMG).
    Lives here (with the other shared shape math) so kernels and the core
    planner agree without a layering inversion."""
    b = max(1, min(want, dim))
    while dim % b:
        b -= 1
    return b


def grouped_banks(c: int, k: int, groups: int = 1, want_cin: int = 4,
                  want_kout: int = 4) -> Tuple[int, int]:
    """Legal (cin_banks, kout_banks) for a grouped conv, degraded from the
    requested paper banking: cin banks must divide the per-group channel
    slice C/g (the only channels a kernel set reads), and kout banks must
    split along group boundaries — ``kout_banks % groups == 0`` with the
    banks-per-group count dividing K/g — so every kout bank's weight block
    stays inside one group's cin slice.  Depthwise (g == C) degenerates to
    one cin bank and one kout bank per channel."""
    check_groups(c, k, groups)
    cg, kg = c // groups, k // groups
    cin = divisor_banks(cg, want_cin)
    bpg = divisor_banks(kg, max(1, want_kout // groups))
    return cin, groups * bpg


def check_groups(c: int, k: int, groups: int) -> None:
    """The grouped-conv divisibility contract, shared by oracle / kernel /
    planner / compiler so they all reject the same shapes the same way:
    ``groups`` must divide both the input and output channel counts
    (``groups == c`` is the depthwise case)."""
    if groups < 1 or c % groups or k % groups:
        raise ValueError(
            f"groups={groups} must divide both C={c} and K={k} "
            f"(groups == C is depthwise)")


def conv2d_ref(x, w, bias=None, *, stride: int = 1,
               padding: Padding = "VALID", groups: int = 1,
               dilation: int = 1, accum_dtype=jnp.float32):
    """General convolution oracle.  x: [N,H,W,C]; w: [KH,KW,C/groups,K] →
    [N,OH,OW,K].

    The paper's Eq. (2): F(i,j) = Σ_d Σ_m Σ_n I(i·s+m, j·s+n, d) · K(m,n,d),
    extended with stride s, zero padding, grouped channel contraction
    (``groups > 1``): output kernel k only reads the C/groups input
    channels of its group — ``groups == C`` is the depthwise conv of the
    MobileNet workload family — and rhs/kernel dilation (``dilation > 1``
    spreads the taps ``dilation`` pixels apart, the atrous conv of
    dense-prediction context modules)."""
    check_groups(x.shape[3], w.shape[3], groups)
    pad = normalize_padding(padding, w.shape[0], w.shape[1], stride,
                            x.shape[1], x.shape[2], dilation)
    out = jax.lax.conv_general_dilated(
        x.astype(accum_dtype), w.astype(accum_dtype),
        window_strides=(stride, stride), padding=pad,
        rhs_dilation=(dilation, dilation),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
        preferred_element_type=accum_dtype)
    if bias is not None:
        out = out + bias.astype(accum_dtype)
    return out


def conv2d_ref_int8(x, w, bias=None, *, stride: int = 1,
                    padding: Padding = "VALID", groups: int = 1,
                    dilation: int = 1):
    """int8 × int8 → int32 accumulation (production 8-bit datapath).

    Zero padding is exact for the symmetric (zero-point-0) int8 scheme."""
    assert x.dtype == jnp.int8 and w.dtype == jnp.int8
    check_groups(x.shape[3], w.shape[3], groups)
    pad = normalize_padding(padding, w.shape[0], w.shape[1], stride,
                            x.shape[1], x.shape[2], dilation)
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.int32), w.astype(jnp.int32),
        window_strides=(stride, stride), padding=pad,
        rhs_dilation=(dilation, dilation),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)
    if bias is not None:
        out = out + bias.astype(jnp.int32)
    return out


def maxpool2d_ref(x, size: int = 2, stride: int = None):
    """Max pool over [N,H,W,C]; trailing rows/cols that don't fill a window
    are dropped (floor semantics, matching the fused kernel epilogue)."""
    stride = size if stride is None else stride
    init = jnp.iinfo(x.dtype).min if jnp.issubdtype(x.dtype, jnp.integer) \
        else -jnp.inf
    return jax.lax.reduce_window(
        x, jnp.asarray(init, x.dtype), jax.lax.max,
        (1, size, size, 1), (1, stride, stride, 1), "VALID")


def avgpool2d_ref(x, size: int = 2, stride: int = None):
    """Average pool over [N,H,W,C] (floor semantics, like maxpool2d_ref).

    Integer inputs accumulate the window sum in int32 and round the mean
    back to the input dtype — the int8 feature-map grid is preserved
    (mean of same-scale values stays on the same scale), so the unfused
    int8 avg-pool layer needs no requantization."""
    stride = size if stride is None else stride
    if jnp.issubdtype(x.dtype, jnp.integer):
        s = jax.lax.reduce_window(
            x.astype(jnp.int32), jnp.int32(0), jax.lax.add,
            (1, size, size, 1), (1, stride, stride, 1), "VALID")
        mean = jnp.round(s.astype(jnp.float32) / (size * size))
        info = jnp.iinfo(x.dtype)
        return jnp.clip(mean, info.min, info.max).astype(x.dtype)
    s = jax.lax.reduce_window(
        x.astype(jnp.float32), jnp.float32(0), jax.lax.add,
        (1, size, size, 1), (1, stride, stride, 1), "VALID")
    return (s / (size * size)).astype(x.dtype)


def global_avgpool_ref(x):
    """Global average pool [N,H,W,C] → [N,C] (the classifier-head reduce).

    Integer inputs round the mean back onto the input dtype's grid, like
    ``avgpool2d_ref``."""
    if jnp.issubdtype(x.dtype, jnp.integer):
        s = jnp.sum(x.astype(jnp.int32), axis=(1, 2))
        mean = jnp.round(s.astype(jnp.float32) / (x.shape[1] * x.shape[2]))
        info = jnp.iinfo(x.dtype)
        return jnp.clip(mean, info.min, info.max).astype(x.dtype)
    return jnp.mean(x.astype(jnp.float32), axis=(1, 2)).astype(x.dtype)


def requantize_ref(acc, out_scale):
    """int32/f32 accumulator × scale → int8 (round-to-nearest, saturating).
    out_scale: scalar or per-channel [K] (broadcast over the last axis)."""
    scaled = jnp.round(acc.astype(jnp.float32) * out_scale)
    return jnp.clip(scaled, -128, 127).astype(jnp.int8)


def add_requant_ref(a, b, scale_a, scale_b, *, relu: bool = False):
    """Residual (skip-connection) merge on a shared int8 grid — the oracle
    for the network executor's ``add`` node.

    Each int8 operand re-expresses on the merge node's output grid through
    its branch requant scale (``s_branch / s_out``, round-to-nearest), the
    aligned values add, optional ReLU, saturate to int8.  When both
    branches already sit on the shared grid (branch scales == 1) the merge
    is exact int8 arithmetic — the FPGA output-BRAM-crossbar idiom: the
    skip path adds into the conv path's output BRAMs without ever leaving
    8 bits, no int32 accumulator round-trip."""
    assert a.dtype == jnp.int8 and b.dtype == jnp.int8, (a.dtype, b.dtype)
    ya = jnp.round(a.astype(jnp.float32) * jnp.asarray(scale_a, jnp.float32))
    yb = jnp.round(b.astype(jnp.float32) * jnp.asarray(scale_b, jnp.float32))
    y = ya + yb
    if relu:
        y = jnp.maximum(y, 0)
    return jnp.clip(y, -128, 127).astype(jnp.int8)


def conv2d_epilogue_ref(x, w, bias=None, *, stride: int = 1,
                        padding: Padding = "VALID", relu: bool = False,
                        pool: bool = False, out_scale=None,
                        groups: int = 1, dilation: int = 1):
    """Conv + the fused FPGA post-processing chain: ReLU → 2×2 max-pool →
    requantize, in accumulator precision (the oracle for the fused kernel
    epilogue).  ``groups``/``dilation`` select grouped/depthwise channel
    contraction and kernel dilation like ``conv2d_ref``."""
    if x.dtype == jnp.int8:
        acc = conv2d_ref_int8(x, w, bias, stride=stride, padding=padding,
                              groups=groups, dilation=dilation)
    else:
        acc = conv2d_ref(x, w, bias, stride=stride, padding=padding,
                         groups=groups, dilation=dilation)
    if relu:
        acc = jnp.maximum(acc, 0)
    if pool:
        acc = maxpool2d_ref(acc)
    if out_scale is not None:
        return requantize_ref(acc, out_scale)
    return acc


def conv2d_ref_wrap8(x, w, bias=None):
    """Paper-waveform mode: every accumulation wraps in 8 bits.

    Because int8 wrap-around addition is associative and the products enter
    mod-256 arithmetic independently, this equals the int32 result mod 256."""
    out = conv2d_ref_int8(x, w, bias)
    return out.astype(jnp.int8)


# ---------------------------------------------------------------------------
# Transposed-convolution oracles (the dense-prediction contract)
# ---------------------------------------------------------------------------


def grouped_swap_weights(w, groups: int = 1):
    """Per-group channel-axis swap [KH,KW,C/groups,K] → [KH,KW,K/groups,C]
    with the groups reassembled along the new output axis — NO spatial
    flip.  An involution (applying it twice is the identity), and the
    algebraic half of ``grouped_transpose_weights = flip ∘ swap``: it maps
    the weights of a ``conv2d_transpose`` to the weights of the ordinary
    strided conv that is its adjoint (and vice versa), which is how the
    transpose op's own VJP reuses the forward kernels."""
    kh, kw, cg, k = w.shape
    kg = k // groups
    if groups == 1:
        return w.swapaxes(2, 3)
    return (w.reshape(kh, kw, cg, groups, kg)
            .transpose(0, 1, 4, 3, 2).reshape(kh, kw, kg, groups * cg))


def conv_transpose_out_shape(h: int, w: int, kh: int, kw: int,
                             stride: int = 1, padding: Padding = "VALID",
                             dilation: int = 1) -> Tuple[int, int]:
    """Spatial output shape of ``conv2d_transpose_ref``: the padding names
    the FORWARD conv being inverted, so the output extent is the input
    extent that forward conv would have consumed — VALID grows to
    ``(h−1)·s + ek`` (ek the dilated kernel extent), SAME to exactly
    ``h·s``, explicit ((pt,pb),(pl,pr)) to ``(h−1)·s + ek − pt − pb``."""
    (oh, ow), _ = conv_transpose_eq_params(h, w, kh, kw, stride, padding,
                                           dilation)
    return oh, ow


def conv_transpose_eq_params(h: int, w: int, kh: int, kw: int,
                             stride: int = 1, padding: Padding = "VALID",
                             dilation: int = 1, out_spatial=None):
    """The shared geometry of a transposed conv as its equivalent stride-1
    conv: resolve the output extent (OH, OW) and the "full" padding the
    zero-inserted input needs — ``ek−1−pt`` on top, ``OH+pt−(h−1)·s−1`` on
    the bottom (negative when the forward padding exceeded the kernel
    extent: those rows must be sliced away, not padded).  One definition
    consumed by the oracle, the WS kernel path, and the planner, so they
    can never disagree on transpose geometry.

    ``out_spatial`` pins (OH, OW) directly — the input-gradient use, where
    the forward input extent is known and the stride remainder rows
    (``r = OH+pt+pb−ek−(h−1)·s ∈ [0, s)``) must be recovered exactly."""
    ekh, ekw = dilated_extent(kh, dilation), dilated_extent(kw, dilation)
    if out_spatial is not None:
        oh, ow = out_spatial
        (pt, pb), (pl_, pr) = normalize_padding(padding, kh, kw, stride,
                                                oh, ow, dilation)
    elif isinstance(padding, (int, tuple, list)):
        (pt, pb), (pl_, pr) = normalize_padding(padding, kh, kw, stride)
        oh = (h - 1) * stride + ekh - pt - pb
        ow = (w - 1) * stride + ekw - pl_ - pr
    elif padding == "VALID":
        (pt, pb), (pl_, pr) = (0, 0), (0, 0)
        oh, ow = (h - 1) * stride + ekh, (w - 1) * stride + ekw
    elif padding == "SAME":
        oh, ow = h * stride, w * stride
        (pt, pb), (pl_, pr) = normalize_padding(padding, kh, kw, stride,
                                                oh, ow, dilation)
    else:
        raise ValueError(f"unknown padding {padding!r}")
    for dim, o, p0, p1, ek in ((h, oh, pt, pb, ekh), (w, ow, pl_, pr, ekw)):
        r = o + p0 + p1 - ek - (dim - 1) * stride
        if o < 1 or not 0 <= r < max(stride, 1):
            raise ValueError(
                f"conv_transpose geometry is not invertible: input {dim} "
                f"with stride={stride}, kernel extent {ek}, padding "
                f"({p0},{p1}) cannot produce output extent {o}")
    eq_pads = ((ekh - 1 - pt, oh + pt - (h - 1) * stride - 1),
               (ekw - 1 - pl_, ow + pl_ - (w - 1) * stride - 1))
    return (oh, ow), eq_pads


def conv2d_transpose_ref(x, w, bias=None, *, stride: int = 1,
                         padding: Padding = "VALID", groups: int = 1,
                         dilation: int = 1, out_spatial=None,
                         accum_dtype=jnp.float32):
    """Transposed (fractionally-strided / upsampling) convolution oracle.
    x: [N,H,W,C]; w: [KH,KW,C/groups,K] → [N,OH,OW,K] — the FORWARD weight
    layout, so an encoder conv and its decoder transpose read the same
    shaped parameter.

    Stated directly as zero-insertion dilation + kernel flip (NOT via
    jax.vjp, so it is an independent contract for the WS kernel path): the
    input dilates by ``stride`` (lhs zero-insertion), the kernel flips
    spatially, and a stride-1 grouped correlation with the "full" padding
    of ``conv_transpose_eq_params`` produces the upsampled map.  Duality:
    ``conv2d_input_grad_ref`` is exactly this op applied to the cotangent
    with per-group channel-swapped weights (``grouped_swap_weights``)."""
    check_groups(x.shape[3], w.shape[3], groups)
    kh, kw = w.shape[0], w.shape[1]
    _, eq_pads = conv_transpose_eq_params(
        x.shape[1], x.shape[2], kh, kw, stride, padding, dilation,
        out_spatial)
    out = jax.lax.conv_general_dilated(
        x.astype(accum_dtype), jnp.flip(w, (0, 1)).astype(accum_dtype),
        (1, 1), eq_pads, lhs_dilation=(stride, stride),
        rhs_dilation=(dilation, dilation),
        feature_group_count=groups,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=accum_dtype)
    if bias is not None:
        out = out + bias.astype(accum_dtype)
    return out


def conv2d_transpose_ref_int8(x, w, bias=None, *, stride: int = 1,
                              padding: Padding = "VALID", groups: int = 1,
                              dilation: int = 1, out_spatial=None):
    """int8 × int8 → int32 transposed conv (production 8-bit datapath).
    Zero insertion is exact for the symmetric (zero-point-0) scheme — the
    inserted zeros ARE the quantized zero."""
    assert x.dtype == jnp.int8 and w.dtype == jnp.int8
    return conv2d_transpose_ref(x, w, bias, stride=stride, padding=padding,
                                groups=groups, dilation=dilation,
                                out_spatial=out_spatial,
                                accum_dtype=jnp.int32)


def conv2d_transpose_epilogue_ref(x, w, bias=None, *, stride: int = 1,
                                  padding: Padding = "VALID",
                                  relu: bool = False, pool: bool = False,
                                  out_scale=None, groups: int = 1,
                                  dilation: int = 1):
    """Transposed conv + the same fused post-processing chain as
    ``conv2d_epilogue_ref`` (ReLU → 2×2 max-pool → requantize) — the
    oracle for a first-class ``conv_transpose`` network layer."""
    if x.dtype == jnp.int8:
        acc = conv2d_transpose_ref_int8(x, w, bias, stride=stride,
                                        padding=padding, groups=groups,
                                        dilation=dilation)
    else:
        acc = conv2d_transpose_ref(x, w, bias, stride=stride,
                                   padding=padding, groups=groups,
                                   dilation=dilation)
    if relu:
        acc = jnp.maximum(acc, 0)
    if pool:
        acc = maxpool2d_ref(acc)
    if out_scale is not None:
        return requantize_ref(acc, out_scale)
    return acc


# ---------------------------------------------------------------------------
# Backward-pass oracles (the training contract)
# ---------------------------------------------------------------------------


def grouped_transpose_weights(w, groups: int = 1):
    """Forward weights [KH,KW,C/groups,K] → transposed-conv weights
    [KH,KW,K/groups,C]: spatial flip + per-group channel-axis swap
    (``grouped_swap_weights``), groups reassembled along the new output
    axis.  The single definition shared by the input-gradient oracle and
    the WS backward kernel — in the transposed conv the cotangent's K
    channels play the input role (K/g per group) and the forward input's
    C channels the output role."""
    return grouped_swap_weights(jnp.flip(w, (0, 1)), groups)


def conv2d_input_grad_ref(g, w, x_shape, *, stride: int = 1,
                          padding: Padding = "VALID", groups: int = 1,
                          dilation: int = 1):
    """dL/dx of ``conv2d_ref``: a special case of the first-class
    transposed conv — ``conv2d_transpose_ref`` applied to the cotangent
    with per-group channel-swapped weights ([KH,KW,C/g,K] → [KH,KW,K/g,C],
    ``grouped_swap_weights``; the transpose op supplies the spatial flip),
    with ``out_spatial`` pinned to the forward input extent so the stride
    remainder rows the strided forward never reached are recovered."""
    n, h, w_dim, c = x_shape
    kh, kw, cg, k = w.shape
    assert c == cg * groups, (c, cg, groups)
    return conv2d_transpose_ref(
        g.astype(jnp.float32),
        grouped_swap_weights(w, groups).astype(jnp.float32),
        stride=stride, padding=padding, groups=groups, dilation=dilation,
        out_spatial=(h, w_dim))


def conv2d_weight_grad_ref(x, g, kh: int, kw: int, *, stride: int = 1,
                           padding: Padding = "VALID", groups: int = 1,
                           dilation: int = 1):
    """dL/dw of ``conv2d_ref``: a batched correlation — tap (dy,dx) of the
    weight gradient contracts the stride-strided input window starting at
    (dy·dilation, dx·dilation) with the cotangent over (N,OH,OW):

        dW[dy,dx,c,k] = Σ_{n,i,j} x_pad[n, i·s+dy·d, j·s+dx·d, c] · g[n,i,j,k]

    With ``groups > 1`` the contraction stays within each group: output
    kernel k in group i only ever saw that group's C/g input channels, so
    the tap einsum carries a group axis and dW keeps the forward's
    [KH,KW,C/g,K] layout."""
    n, h, w_dim, c = x.shape
    oh, ow, k = g.shape[1], g.shape[2], g.shape[3]
    check_groups(c, k, groups)
    cg, kg = c // groups, k // groups
    (pt, pb), (pl_, pr) = normalize_padding(padding, kh, kw, stride, h,
                                            w_dim, dilation)
    xp = jnp.pad(x.astype(jnp.float32),
                 ((0, 0), (pt, pb), (pl_, pr), (0, 0)))
    gf = g.astype(jnp.float32)
    taps = []
    for dy in range(kh):
        for dx in range(kw):
            xs = jax.lax.slice(
                xp, (0, dy * dilation, dx * dilation, 0),
                (n, dy * dilation + (oh - 1) * stride + 1,
                 dx * dilation + (ow - 1) * stride + 1,
                 c), (1, stride, stride, 1))
            if groups == 1:
                taps.append(jnp.einsum("nijc,nijk->ck", xs, gf))
            else:
                tap = jnp.einsum(
                    "nijgc,nijgk->gck",
                    xs.reshape(n, oh, ow, groups, cg),
                    gf.reshape(n, oh, ow, groups, kg))
                taps.append(tap.transpose(1, 0, 2).reshape(cg, k))
    return jnp.stack(taps).reshape(kh, kw, cg, k)


def conv2d_bias_grad_ref(g):
    """dL/db of ``conv2d_ref``: the cotangent summed over (N,OH,OW), in
    f32 (low-precision cotangents must not round per-partial-sum)."""
    return jnp.sum(g.astype(jnp.float32), axis=(0, 1, 2))


def relu_mask_ref(acc):
    """The fused-epilogue ReLU backward mask: 1 where the accumulator was
    strictly positive (the subgradient-at-0 convention jax.grad uses)."""
    return acc > 0


def maxpool2x2_argmax_ref(y):
    """Per-window argmax of the 2×2/2 max-pool (row-major within the
    window, first max wins — jnp.argmax semantics).  Trailing odd rows /
    columns are dropped, matching the fused epilogue's floor semantics.
    Returns int8 [N, H//2, W//2, C] with values in 0..3 — the pool mask
    the training residuals carry."""
    n, h, w, c = y.shape
    h2, w2 = h // 2, w // 2
    win = y[:, :h2 * 2, :w2 * 2].reshape(n, h2, 2, w2, 2, c)
    win = win.transpose(0, 1, 3, 5, 2, 4).reshape(n, h2, w2, c, 4)
    return jnp.argmax(win, axis=-1).astype(jnp.int8)


def maxpool2x2_bwd_ref(idx, g, out_shape):
    """Backward of the 2×2/2 max-pool given its argmax mask: each window's
    cotangent routes to the position ``idx`` selected in the forward pass;
    dropped trailing odd rows/columns get zero.  ``out_shape`` is the
    pre-pool [N,H,W,C] shape."""
    n, h, w, c = out_shape
    h2, w2 = h // 2, w // 2
    onehot = jax.nn.one_hot(idx.astype(jnp.int32), 4,
                            dtype=jnp.float32)            # [N,H2,W2,C,4]
    dwin = g.astype(jnp.float32)[..., None] * onehot
    dy = dwin.reshape(n, h2, w2, c, 2, 2).transpose(0, 1, 4, 2, 5, 3)
    dy = dy.reshape(n, h2 * 2, w2 * 2, c)
    return jnp.pad(dy, ((0, 0), (0, h - h2 * 2), (0, w - w2 * 2), (0, 0)))


def matmul_ref(x, w, bias=None, *, accum_dtype=jnp.float32):
    """x: [M,K] @ w: [K,N] + bias."""
    out = jnp.dot(x.astype(accum_dtype), w.astype(accum_dtype),
                  preferred_element_type=accum_dtype)
    if bias is not None:
        out = out + bias.astype(accum_dtype)
    return out


def matmul_ref_int8(x, w, bias=None):
    assert x.dtype == jnp.int8 and w.dtype == jnp.int8
    out = jnp.dot(x.astype(jnp.int32), w.astype(jnp.int32))
    if bias is not None:
        out = out + bias.astype(jnp.int32)
    return out


def conv1d_depthwise_ref(x, w, bias=None):
    """Causal depthwise temporal conv (RecurrentGemma site).
    x: [B,S,W]; w: [K,W] → [B,S,W]."""
    K = w.shape[0]
    pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    S = x.shape[1]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for j in range(K):
        out = out + xp[:, j:j + S].astype(jnp.float32) * w[j].astype(jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(x.dtype)
