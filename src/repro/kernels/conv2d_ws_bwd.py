"""Backward pass of the paper's IP core through the SAME weight-stationary
dataflow — the conv gradients an FPGA-trained deployment would compute
on-accelerator (DESIGN.md §3; ROADMAP "conv backward pass").

Two kernels, both re-statements of the forward architecture rather than
new dataflows:

* **input gradient** = a transposed convolution, executed as
  zero-insertion dilation of the cotangent + spatial kernel flip +
  channel-axis swap, then the ORDINARY stride-1 forward kernel
  (``conv2d_ws``) with "full" padding.  This literally reuses the halo'd
  spatial-tile grid machinery: the dilated cotangent streams through the
  same (N, h_tiles, w_tiles, kout, cin) grid, with the cotangent's K
  channels playing the cin-bank role and the input's C channels the
  kout-bank role.  Rows the strided forward never reached appear as
  negative "full" padding — folded into a slice of the dilated map
  because the image-BRAM zero margins can only add, never remove.

* **weight gradient** = a batched correlation: tap (dy,dx) of dW is the
  GEMM  x_window(dy,dx)ᵀ @ g  contracting over N·OH·OW, so the whole
  weight gradient is KH·KW weight-stationary GEMMs (``matmul_ws`` — the
  same MXU dataflow the forward's "9 MACs per PCORE" decomposition uses,
  with the roles of weights and activations exchanged: now the cotangent
  block stays VMEM-resident while the image stream flows past it).

The fused-epilogue backward (ReLU mask, 2×2 max-pool argmax routing)
lives in kernels/ref.py (`relu_mask_ref` / `maxpool2x2_bwd_ref`); ops.py
wires all three into ``conv2d``'s custom VJP with residuals that carry
the epilogue masks instead of the full accumulator.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.conv2d_ws import conv2d_ws
from repro.kernels.matmul_ws import matmul_ws
from repro.kernels.ref import (check_groups, grouped_banks,
                               grouped_transpose_weights, normalize_padding)


def conv2d_ws_input_grad(g, w, x_shape, *, stride: int = 1,
                         padding="VALID", groups: int = 1,
                         cin_banks: int = 4, kout_banks: int = 4,
                         h_tile: int = 0, w_tile: int = 0,
                         interpret: bool = False):
    """dL/dx [N,H,W,C] from cotangent ``g`` [N,OH,OW,K] and weights ``w``
    [KH,KW,C/groups,K], through the forward WS kernel:

    1. zero-insertion-dilate ``g`` by the forward stride (the transposed
       conv's lhs dilation, materialized the way the FPGA would write a
       sparse map into its image BRAMs);
    2. flip the kernel spatially and swap its channel axes per group →
       [KH,KW,K/groups,C] (ref.grouped_transpose_weights);
    3. run ``conv2d_ws`` at stride 1 under "full" padding
       (kh−1−pt …), slicing the dilated map first wherever the full
       padding is negative (forward padding larger than the kernel).

    The transposed conv inherits the forward's group structure: the
    cotangent's K channels play the cin role (K/groups per group) and the
    forward input's C channels the kout role, so a depthwise forward has
    a depthwise backward — the same degenerate one-cin-bank sweep.

    ``h_tile``/``w_tile`` tile the OUTPUT map (= the forward input), so
    gradient maps larger than VMEM stream through the same halo'd blocks
    as the forward pass.
    """
    n, h, w_dim, c = x_shape
    kh, kw, cg, k = w.shape
    assert c == cg * groups, (c, cg, groups)
    assert g.shape[0] == n and g.shape[3] == k, (g.shape, x_shape, w.shape)
    (pt, _), (pl_, _) = normalize_padding(padding, kh, kw, stride, h, w_dim)
    oh, ow = g.shape[1], g.shape[2]

    gf = g.astype(jnp.float32)
    if stride > 1:
        gd = jnp.zeros((n, (oh - 1) * stride + 1, (ow - 1) * stride + 1, k),
                       jnp.float32)
        gd = gd.at[:, ::stride, ::stride, :].set(gf)
    else:
        gd = gf
    # full padding of the transposed conv; negative entries (forward pad
    # beyond the kernel extent) become slices of the dilated map
    pads = [kh - 1 - pt, h + pt - (oh - 1) * stride - 1,
            kw - 1 - pl_, w_dim + pl_ - (ow - 1) * stride - 1]
    if min(pads) < 0:
        top, bot, left, right = (max(0, -p) for p in pads)
        gd = gd[:, top:gd.shape[1] - bot, left:gd.shape[2] - right, :]
        pads = [max(0, p) for p in pads]
    wt = grouped_transpose_weights(w, groups).astype(jnp.float32)

    # channel roles swap in the transposed conv (K plays cin, C plays
    # kout), so the bank requests re-legalize against (K, C)
    cb_n, kb_n = grouped_banks(k, c, groups, want_cin=cin_banks,
                               want_kout=max(kout_banks, groups))
    return conv2d_ws(
        gd, wt, None, stride=1,
        padding=((pads[0], pads[1]), (pads[2], pads[3])),
        groups=groups, cin_banks=cb_n, kout_banks=kb_n,
        h_tile=h_tile, w_tile=w_tile, interpret=interpret)


def conv2d_ws_weight_grad(x, g, kh: int, kw: int, *, stride: int = 1,
                          padding="VALID", groups: int = 1,
                          interpret: bool = False):
    """dL/dw [KH,KW,C/groups,K] from input ``x`` [N,H,W,C] and cotangent
    ``g`` [N,OH,OW,K], as KH·KW weight-stationary GEMMs: tap (dy,dx)
    contracts the strided input window starting at (dy,dx) with the
    cotangent over the N·OH·OW stream —

        dW[dy,dx] = x_window(dy,dx)ᵀ [C, N·OH·OW] @ g [N·OH·OW, K]

    the batched-correlation form of the weight gradient, on the same MXU
    dataflow as the forward's shifted-matmul decomposition (the cotangent
    block is the stationary operand of each GEMM).  With ``groups > 1``
    each tap runs one GEMM per group — kernel set k only ever saw its
    group's C/groups input channels, so the per-group GEMMs reassemble
    into the forward's [KH,KW,C/groups,K] weight layout."""
    n, h, w_dim, c = x.shape
    assert g.shape[0] == n, (x.shape, g.shape)
    oh, ow, k = g.shape[1], g.shape[2], g.shape[3]
    check_groups(c, k, groups)
    cg, kg = c // groups, k // groups
    (pt, pb), (pl_, pr) = normalize_padding(padding, kh, kw, stride, h,
                                            w_dim)
    xp = jnp.pad(x.astype(jnp.float32),
                 ((0, 0), (pt, pb), (pl_, pr), (0, 0)))
    gm = g.astype(jnp.float32).reshape(n * oh * ow, k)
    taps = []
    for dy in range(kh):
        for dx in range(kw):
            xs = jax.lax.slice(
                xp, (0, dy, dx, 0),
                (n, dy + (oh - 1) * stride + 1, dx + (ow - 1) * stride + 1,
                 c), (1, stride, stride, 1))
            xm = xs.reshape(n * oh * ow, c)
            if groups == 1:
                taps.append(matmul_ws(xm.T, gm, interpret=interpret))
            else:
                taps.append(jnp.concatenate(
                    [matmul_ws(xm[:, i * cg:(i + 1) * cg].T,
                               gm[:, i * kg:(i + 1) * kg],
                               interpret=interpret)
                     for i in range(groups)], axis=1))
    return jnp.stack(taps).reshape(kh, kw, cg, k)
