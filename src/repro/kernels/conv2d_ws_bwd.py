"""Backward pass of the paper's IP core through the SAME weight-stationary
dataflow — the conv gradients an FPGA-trained deployment would compute
on-accelerator (DESIGN.md §3; ROADMAP "conv backward pass").

Two kernels, both re-statements of the forward architecture rather than
new dataflows:

* **input gradient** = a transposed convolution of the cotangent with
  channel-swapped weights, pinned to the forward input's spatial shape.
  Since PR 8 the zero-insertion / kernel-flip / "full"-padding lowering
  lives in the FIRST-CLASS transpose path
  (kernels/conv2d_ws_trans.conv2d_ws_transpose) — this module only adds
  the gradient-duality framing: the cotangent's K channels play the
  cin-bank role and the input's C channels the kout-bank role, and
  ``out_spatial`` restores the stride remainder the forward's floor
  division discarded.

* **weight gradient** = a batched correlation: tap (dy,dx) of dW is the
  GEMM  x_window(dy,dx)ᵀ @ g  contracting over N·OH·OW, so the whole
  weight gradient is KH·KW weight-stationary GEMMs (``matmul_ws`` — the
  same MXU dataflow the forward's "9 MACs per PCORE" decomposition uses,
  with the roles of weights and activations exchanged: now the cotangent
  block stays VMEM-resident while the image stream flows past it).

The fused-epilogue backward (ReLU mask, 2×2 max-pool argmax routing)
lives in kernels/ref.py (`relu_mask_ref` / `maxpool2x2_bwd_ref`); ops.py
wires all three into ``conv2d``'s custom VJP with residuals that carry
the epilogue masks instead of the full accumulator.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.conv2d_ws_trans import conv2d_ws_transpose
from repro.kernels.matmul_ws import matmul_ws
from repro.kernels.ref import (check_groups, grouped_banks,
                               grouped_swap_weights, normalize_padding)


def conv2d_ws_input_grad(g, w, x_shape, *, stride: int = 1,
                         padding="VALID", groups: int = 1,
                         cin_banks: int = 4, kout_banks: int = 4,
                         h_tile: int = 0, w_tile: int = 0,
                         dilation: int = 1, interpret: bool = False):
    """dL/dx [N,H,W,C] from cotangent ``g`` [N,OH,OW,K] and weights ``w``
    [KH,KW,C/groups,K]: the transposed conv of the cotangent with
    channel-swapped weights, via the shared first-class lowering
    (kernels/conv2d_ws_trans) — zero-insertion of the cotangent, kernel
    flip, stride-1 forward WS kernel under the "full"-padding
    equivalence, with ``out_spatial=(H,W)`` restoring the rows a strided
    forward's floor division never reached.

    The transposed conv inherits the forward's group structure: the
    cotangent's K channels play the cin role (K/groups per group) and the
    forward input's C channels the kout role, so a depthwise forward has
    a depthwise backward — the same degenerate one-cin-bank sweep.

    ``h_tile``/``w_tile`` tile the OUTPUT map (= the forward input), so
    gradient maps larger than VMEM stream through the same halo'd blocks
    as the forward pass.
    """
    n, h, w_dim, c = x_shape
    kh, kw, cg, k = w.shape
    assert c == cg * groups, (c, cg, groups)
    assert g.shape[0] == n and g.shape[3] == k, (g.shape, x_shape, w.shape)
    # channel roles swap in the transposed conv (K plays cin, C plays
    # kout), so the bank requests re-legalize against (K, C)
    cb_n, kb_n = grouped_banks(k, c, groups, want_cin=cin_banks,
                               want_kout=max(kout_banks, groups))
    return conv2d_ws_transpose(
        g.astype(jnp.float32),
        grouped_swap_weights(w, groups).astype(jnp.float32),
        stride=stride, padding=padding, groups=groups, dilation=dilation,
        cin_banks=cb_n, kout_banks=kb_n, h_tile=h_tile, w_tile=w_tile,
        out_spatial=(h, w_dim), interpret=interpret)


def conv2d_ws_weight_grad(x, g, kh: int, kw: int, *, stride: int = 1,
                          padding="VALID", groups: int = 1,
                          dilation: int = 1, interpret: bool = False):
    """dL/dw [KH,KW,C/groups,K] from input ``x`` [N,H,W,C] and cotangent
    ``g`` [N,OH,OW,K], as KH·KW weight-stationary GEMMs: tap (dy,dx)
    contracts the strided input window starting at (dy,dx) with the
    cotangent over the N·OH·OW stream —

        dW[dy,dx] = x_window(dy,dx)ᵀ [C, N·OH·OW] @ g [N·OH·OW, K]

    the batched-correlation form of the weight gradient, on the same MXU
    dataflow as the forward's shifted-matmul decomposition (the cotangent
    block is the stationary operand of each GEMM).  With ``groups > 1``
    each tap runs one GEMM per group — kernel set k only ever saw its
    group's C/groups input channels, so the per-group GEMMs reassemble
    into the forward's [KH,KW,C/groups,K] weight layout."""
    n, h, w_dim, c = x.shape
    assert g.shape[0] == n, (x.shape, g.shape)
    oh, ow, k = g.shape[1], g.shape[2], g.shape[3]
    check_groups(c, k, groups)
    cg, kg = c // groups, k // groups
    (pt, pb), (pl_, pr) = normalize_padding(padding, kh, kw, stride, h,
                                            w_dim, dilation)
    xp = jnp.pad(x.astype(jnp.float32),
                 ((0, 0), (pt, pb), (pl_, pr), (0, 0)))
    gm = g.astype(jnp.float32).reshape(n * oh * ow, k)
    taps = []
    for dy in range(kh):
        for dx in range(kw):
            xs = jax.lax.slice(
                xp, (0, dy * dilation, dx * dilation, 0),
                (n, dy * dilation + (oh - 1) * stride + 1,
                 dx * dilation + (ow - 1) * stride + 1,
                 c), (1, stride, stride, 1))
            xm = xs.reshape(n * oh * ow, c)
            if groups == 1:
                taps.append(matmul_ws(xm.T, gm, interpret=interpret))
            else:
                taps.append(jnp.concatenate(
                    [matmul_ws(xm[:, i * cg:(i + 1) * cg].T,
                               gm[:, i * kg:(i + 1) * kg],
                               interpret=interpret)
                     for i in range(groups)], axis=1))
    return jnp.stack(taps).reshape(kh, kw, cg, k)
