"""Residual blocks: spec construction + apply, per block kind.

A "block" is one pre-norm residual pair: x += mixer(norm(x)); x += ffn(norm(x)).
Block kinds: attn | local_attn | rglru | rwkv6 (configs.base.BLOCK_*).
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.ad_checkpoint import checkpoint_name
import jax.numpy as jnp

from repro.configs.base import (BLOCK_ATTN, BLOCK_LOCAL, BLOCK_RGLRU,
                                BLOCK_RWKV6)
from repro.layers import attention as attn_lib
from repro.layers import rglru as rglru_lib
from repro.layers import rwkv as rwkv_lib
from repro.layers.attention import KVCache
from repro.layers.common import cast
from repro.layers.mlp import apply_mlp, mlp_specs
from repro.layers.moe import apply_moe, moe_specs
from repro.layers.norms import apply_norm, norm_specs
from repro.layers.rglru import RGLRUState
from repro.layers.rwkv import RWKVState


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


def block_specs(cfg, kind: str, cross: bool = False):
    specs = {"norm1": norm_specs(cfg), "norm2": norm_specs(cfg)}
    if kind in (BLOCK_ATTN, BLOCK_LOCAL):
        specs["attn"] = attn_lib.attention_specs(cfg)
    elif kind == BLOCK_RGLRU:
        specs["rglru"] = rglru_lib.rglru_specs(cfg)
    elif kind == BLOCK_RWKV6:
        specs["timemix"] = rwkv_lib.timemix_specs(cfg)
    else:
        raise ValueError(kind)

    if kind == BLOCK_RWKV6:
        specs["channelmix"] = rwkv_lib.channelmix_specs(cfg)
    elif cfg.moe is not None:
        specs["moe"] = moe_specs(cfg)
    else:
        specs["mlp"] = mlp_specs(cfg)

    if cross:  # enc-dec decoder blocks get cross attention
        specs["cross_norm"] = norm_specs(cfg)
        specs["cross_attn"] = attn_lib.attention_specs(cfg, cross=True)
    return specs


def block_cache_specs(cfg, kind: str, batch: int, seq_len: int,
                      cross_len: int = 0):
    """Decode-time cache spec for one block."""
    cache: dict[str, Any] = {}
    if kind == BLOCK_ATTN:
        cache["kv"] = KVCache.init_specs(cfg, batch, seq_len)
    elif kind == BLOCK_LOCAL:
        cache["kv"] = KVCache.init_specs(cfg, batch, seq_len,
                                         window=cfg.attention_window)
    elif kind == BLOCK_RGLRU:
        cache["rglru"] = RGLRUState.init_specs(cfg, batch)
    elif kind == BLOCK_RWKV6:
        cache["rwkv"] = RWKVState.init_specs(cfg, batch)
    if cross_len:
        from repro.layers.common import ParamSpec
        kv = cfg.num_kv_heads
        shp = (batch, cross_len, kv, cfg.head_dim)
        axes = ("batch", "cache_seq", "kv_heads", "qkv")
        cache["cross_k"] = ParamSpec(shp, axes, dtype=cfg.compute_dtype,
                                     init="zeros")
        cache["cross_v"] = ParamSpec(shp, axes, dtype=cfg.compute_dtype,
                                     init="zeros")
    return cache


# ---------------------------------------------------------------------------
# Apply — full-sequence (train / prefill / encoder)
# ---------------------------------------------------------------------------


def _prime_cache(t, seq_len: int, window: int, cache_len: Optional[int]):
    """Lay out prefill K/V into decode-cache slots.

    Full attention: positions 0..S-1 land at slots 0..S-1; the cache is
    right-padded to ``cache_len`` so decode appends without wrapping.
    Sliding window: the cache is a ring of size min(cache_len, window);
    kept position p must land at slot p %% ring — a roll by S when the
    prompt exceeds the ring (decode's ``slot = pos %% ring`` contract)."""
    cache_len = cache_len or seq_len
    if window:
        ring = min(cache_len, window)
        kept = t[:, -min(seq_len, ring):]
        if kept.shape[1] < ring:
            pad = jnp.zeros((t.shape[0], ring - kept.shape[1],
                             *t.shape[2:]), t.dtype)
            kept = jnp.concatenate([kept, pad], axis=1)
        if seq_len > ring:
            kept = jnp.roll(kept, seq_len % ring, axis=1)
        return kept
    if cache_len > seq_len:
        pad = jnp.zeros((t.shape[0], cache_len - seq_len, *t.shape[2:]),
                        t.dtype)
        return jnp.concatenate([t, pad], axis=1)
    return t


def apply_block_seq(params, x, cfg, kind: str, *, positions,
                    causal: bool = True, enc_out=None,
                    cache_in=None, want_cache: bool = False,
                    cache_len: Optional[int] = None):
    """Returns (x, aux_loss, new_cache_or_None).

    want_cache=True (prefill) also produces the block's decode cache,
    sized ``cache_len`` (≥ prompt length) so decode can append.
    cache_in is only consulted for recurrent kinds during chunked prefill.
    """
    aux = jnp.zeros((), jnp.float32)
    new_cache: Optional[dict] = None
    h = apply_norm(params["norm1"], x, cfg)

    if kind in (BLOCK_ATTN, BLOCK_LOCAL):
        window = cfg.attention_window if kind == BLOCK_LOCAL else 0
        y, (k, v) = attn_lib.attention_layer(
            params["attn"], h, cfg, positions=positions, causal=causal,
            window=window)
        if want_cache:
            S = x.shape[1]

            def to_cache(t):
                if cfg.kv_cache_dtype == "int8":
                    return jnp.clip(
                        jnp.round(t.astype(jnp.float32) / cfg.kv_cache_scale),
                        -128, 127).astype(jnp.int8)
                return cast(t, cfg.resolved_kv_dtype)

            new_cache = {"kv": KVCache(
                k=_prime_cache(to_cache(k), S, window, cache_len),
                v=_prime_cache(to_cache(v), S, window, cache_len))}
    elif kind == BLOCK_RGLRU:
        state = cache_in["rglru"] if cache_in is not None else None
        if want_cache and state is None:
            state = _zero_rglru_state(cfg, x.shape[0], x.dtype)
        y, st = rglru_lib.apply_rglru(params["rglru"], h, cfg, state=state)
        if want_cache:
            new_cache = {"rglru": st}
    elif kind == BLOCK_RWKV6:
        state = cache_in["rwkv"] if cache_in is not None else None
        y, (S_fin, x_last) = rwkv_lib.apply_timemix(
            params["timemix"], h, cfg,
            state=state, chunked=True)
        if want_cache:
            new_cache = {"rwkv": RWKVState(S=S_fin, x_att=x_last,
                                           x_ffn=jnp.zeros_like(x_last))}
    else:
        raise ValueError(kind)
    y = checkpoint_name(y, "attn_out")
    x = x + y

    if enc_out is not None:   # cross attention (enc-dec decoder)
        h = apply_norm(params["cross_norm"], x, cfg)
        y, (ck, cv) = attn_lib.attention_layer(
            params["cross_attn"], h, cfg, positions=None, kv=enc_out)
        x = x + y
        if want_cache and new_cache is not None:
            new_cache["cross_k"] = cast(ck, cfg.compute_dtype)
            new_cache["cross_v"] = cast(cv, cfg.compute_dtype)

    h = apply_norm(params["norm2"], x, cfg)
    if kind == BLOCK_RWKV6:
        y, xl = rwkv_lib.apply_channelmix(
            params["channelmix"], h, cfg,
            state_x_last=(cache_in["rwkv"].x_ffn if cache_in is not None
                          else None))
        if want_cache and new_cache is not None:
            new_cache["rwkv"] = new_cache["rwkv"]._replace(x_ffn=cast(
                xl, cfg.compute_dtype))
    elif cfg.moe is not None:
        y, aux = apply_moe(params["moe"], h, cfg)
    else:
        y = apply_mlp(params["mlp"], h, cfg)
    y = checkpoint_name(y, "ffn_out")
    x = x + y
    return x, aux, new_cache


def _zero_rglru_state(cfg, batch, dtype):
    return RGLRUState(
        conv=jnp.zeros((batch, cfg.conv1d_width - 1, cfg.rnn_width),
                       jnp.dtype(cfg.compute_dtype)),
        h=jnp.zeros((batch, cfg.rnn_width), jnp.float32))


# ---------------------------------------------------------------------------
# Apply — single-token decode
# ---------------------------------------------------------------------------


def apply_block_decode(params, x, cfg, kind: str, *, pos, cache):
    """x: [B,1,D]; pos: [B].  Returns (x, new_cache)."""
    new_cache = dict(cache)
    h = apply_norm(params["norm1"], x, cfg)

    if kind in (BLOCK_ATTN, BLOCK_LOCAL):
        window = cfg.attention_window if kind == BLOCK_LOCAL else 0
        y, kv = attn_lib.decode_attention_layer(
            params["attn"], h, cfg, cache=cache["kv"], pos=pos, window=window)
        new_cache["kv"] = kv
    elif kind == BLOCK_RGLRU:
        y, st = rglru_lib.decode_rglru(params["rglru"], h, cfg,
                                       state=cache["rglru"])
        new_cache["rglru"] = st
    elif kind == BLOCK_RWKV6:
        y, (S_fin, x_last) = rwkv_lib.apply_timemix(
            params["timemix"], h, cfg, state=cache["rwkv"], chunked=False)
        new_cache["rwkv"] = cache["rwkv"]._replace(
            S=S_fin, x_att=cast(x_last, cfg.compute_dtype))
    else:
        raise ValueError(kind)
    x = x + y

    if "cross_k" in cache:
        h = apply_norm(params["cross_norm"], x, cfg)
        y, _ = attn_lib.decode_attention_layer(
            params["cross_attn"], h, cfg, cache=None, pos=pos,
            cross_kv=(cache["cross_k"], cache["cross_v"]))
        x = x + y

    h = apply_norm(params["norm2"], x, cfg)
    if kind == BLOCK_RWKV6:
        y, xl = rwkv_lib.apply_channelmix(
            params["channelmix"], h, cfg, state_x_last=cache["rwkv"].x_ffn)
        new_cache["rwkv"] = new_cache["rwkv"]._replace(
            x_ffn=cast(xl, cfg.compute_dtype))
    elif cfg.moe is not None:
        y, _ = apply_moe(params["moe"], h, cfg)
    else:
        y = apply_mlp(params["mlp"], h, cfg)
    return x + y, new_cache
