"""Model assembly: decoder-only LM (dense / MoE / hybrid / SSM via the
config's layer pattern), VLM (stub vision frontend), and encoder-decoder
(stub audio frontend).

Layers are scanned over *pattern groups* (jax.lax.scan over stacked params)
so the HLO size is depth-independent — essential for fast 512-device
compiles and for per-layer roofline extraction.  Remainder layers that do
not fill a whole group ("tail") are unrolled.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, BLOCK_ATTN, ShapeConfig
from repro.layers.common import (ParamSpec, cast, lconstraint, stack_specs)
from repro.layers.embedding import embed_tokens, embedding_specs, logits
from repro.layers.norms import apply_norm, norm_specs
from repro.layers.rope import sinusoidal_positions
from repro.models.blocks import (apply_block_decode, apply_block_seq,
                                 block_cache_specs, block_specs)

PyTree = Any


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def param_specs(cfg: ArchConfig) -> PyTree:
    cross = cfg.kind == "encdec"
    specs: Dict[str, Any] = {
        "embedding": embedding_specs(cfg),
        "final_norm": norm_specs(cfg),
    }
    group = {f"b{i}": block_specs(cfg, k, cross=cross)
             for i, k in enumerate(cfg.layer_pattern)}
    specs["blocks"] = stack_specs(group, cfg.num_groups_scan)
    if cfg.tail_blocks:
        specs["tail"] = {f"b{i}": block_specs(cfg, k, cross=cross)
                         for i, k in enumerate(cfg.tail_blocks)}
    if cfg.kind == "encdec":
        enc_group = {"b0": block_specs(cfg, BLOCK_ATTN)}
        specs["encoder"] = {
            "blocks": stack_specs(enc_group, cfg.encoder_layers),
            "final_norm": norm_specs(cfg),
        }
    if cfg.frontend is not None and cfg.frontend_dim:
        specs["frontend_proj"] = ParamSpec(
            (cfg.frontend_dim, cfg.d_model), (None, "embed"))
    return specs


def cache_specs(cfg: ArchConfig, batch: int, seq_len: int) -> PyTree:
    """Decode cache pytree (ParamSpecs) matching the scan structure."""
    cross_len = seq_len if cfg.kind == "encdec" else 0
    group = {f"b{i}": block_cache_specs(cfg, k, batch, seq_len, cross_len)
             for i, k in enumerate(cfg.layer_pattern)}
    out = {"blocks": stack_specs(group, cfg.num_groups_scan)}
    if cfg.tail_blocks:
        out["tail"] = {f"b{i}": block_cache_specs(cfg, k, batch, seq_len,
                                                  cross_len)
                       for i, k in enumerate(cfg.tail_blocks)}
    return out


def _remat(fn, cfg):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "minimal":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    elif cfg.remat_policy == "save_block_outputs":
        # §Perf A4: save exactly the per-layer psum outputs.  Under the
        # sequence-sharded residual (A2) these are S/model-axis-sized, so
        # the memory cost is ~1 GB/device while the backward pass skips
        # recomputing the forward TP collectives.
        policy = jax.checkpoint_policies.save_only_these_names(
            "attn_out", "ffn_out")
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


# ---------------------------------------------------------------------------
# Sequence forward (train / prefill)
# ---------------------------------------------------------------------------


def _encoder_forward(params, frames, cfg):
    """Stub-frontend encoder: frames [B,S,frontend_dim] → [B,S,D]."""
    x = jnp.einsum("bsf,fd->bsd", cast(frames, cfg.compute_dtype),
                   cast(params["frontend_proj"], cfg.compute_dtype))
    pos = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    x = x + cast(sinusoidal_positions(pos, cfg.d_model), x.dtype)
    x = lconstraint(x, ("batch", "seq_r", "embed"))

    def body(carry, gparams):
        h, _, _ = apply_block_seq(gparams["b0"], carry, cfg, BLOCK_ATTN,
                                  positions=pos, causal=False)
        return h, None

    x, _ = jax.lax.scan(_remat(body, cfg), x, params["encoder"]["blocks"])
    return apply_norm(params["encoder"]["final_norm"], x, cfg)


def forward_seq(params, cfg: ArchConfig, *, tokens, patches=None,
                frames=None, want_cache: bool = False,
                cache_len: int | None = None):
    """Full-sequence forward.

    tokens: [B, S_text].  VLM: patches [B,P,frontend_dim] prepended.
    encdec: frames [B,S_enc,frontend_dim] through the encoder + cross attn.
    Returns (hidden [B,S,D], aux_loss, cache_or_None).
    """
    x = embed_tokens(params["embedding"], tokens, cfg)
    if cfg.kind == "vlm" and patches is not None:
        pe = jnp.einsum("bpf,fd->bpd", cast(patches, cfg.compute_dtype),
                        cast(params["frontend_proj"], cfg.compute_dtype))
        x = jnp.concatenate([pe, x], axis=1)
        x = lconstraint(x, ("batch", "seq_r", "embed"))
    enc_out = None
    if cfg.kind == "encdec":
        enc_out = _encoder_forward(params, frames, cfg)

    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    if cfg.kind == "encdec":   # seamless: sinusoidal absolute positions
        x = x + cast(sinusoidal_positions(positions, cfg.d_model), x.dtype)

    def group_fn(carry, gparams):
        h, aux = carry
        caches = {}
        for i, kind in enumerate(cfg.layer_pattern):
            h, a, nc = apply_block_seq(
                gparams[f"b{i}"], h, cfg, kind, positions=positions,
                causal=True, enc_out=enc_out, want_cache=want_cache,
                cache_len=cache_len)
            aux = aux + a
            caches[f"b{i}"] = nc
        return (h, aux), caches

    carry = (x, jnp.zeros((), jnp.float32))
    carry, scan_caches = jax.lax.scan(_remat(group_fn, cfg), carry,
                                      params["blocks"])
    x, aux = carry

    tail_caches = {}
    for i, kind in enumerate(cfg.tail_blocks):
        x, a, nc = apply_block_seq(
            params["tail"][f"b{i}"], x, cfg, kind, positions=positions,
            causal=True, enc_out=enc_out, want_cache=want_cache,
            cache_len=cache_len)
        aux = aux + a
        tail_caches[f"b{i}"] = nc

    x = apply_norm(params["final_norm"], x, cfg)
    cache = None
    if want_cache:
        cache = {"blocks": scan_caches}
        if cfg.tail_blocks:
            cache["tail"] = tail_caches
    return x, aux, cache


def forward_train(params, batch, cfg: ArchConfig):
    """batch → (logits [B,S_text,V] f32, aux_loss).

    VLM: the patch prefix carries no loss, so hidden states are sliced to
    the text suffix BEFORE the vocab projection — saves the (huge) logits
    matmul + its collectives over patch positions."""
    x, aux, _ = forward_seq(params, cfg, tokens=batch["tokens"],
                            patches=batch.get("patches"),
                            frames=batch.get("frames"))
    if cfg.kind == "vlm" and batch.get("patches") is not None:
        x = x[:, batch["patches"].shape[1]:]
    return logits(params["embedding"], x, cfg), aux


def prefill(params, batch, cfg: ArchConfig, cache_len: int | None = None):
    """Prefill: returns (last-token logits [B,V], cache).

    cache_len (≥ prompt length) sizes the decode cache so generation can
    append; defaults to the prompt length (the dry-run decode cells build
    their seq_len-sized caches directly from cache_specs)."""
    x, _, cache = forward_seq(params, cfg, tokens=batch["tokens"],
                              patches=batch.get("patches"),
                              frames=batch.get("frames"), want_cache=True,
                              cache_len=cache_len)
    lg = logits(params["embedding"], x[:, -1:], cfg)
    return lg[:, 0], cache


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def decode_step(params, cfg: ArchConfig, *, token, pos, cache):
    """One serving step.  token: [B] int32, pos: [B] int32 (absolute).
    Returns (logits [B,V] f32, new_cache)."""
    x = embed_tokens(params["embedding"], token[:, None], cfg)
    if cfg.kind == "encdec":
        x = x + cast(sinusoidal_positions(pos[:, None], cfg.d_model), x.dtype)

    def group_fn(carry, xs):
        h = carry
        gparams, gcache = xs
        new_caches = {}
        for i, kind in enumerate(cfg.layer_pattern):
            h, nc = apply_block_decode(gparams[f"b{i}"], h, cfg, kind,
                                       pos=pos, cache=gcache[f"b{i}"])
            new_caches[f"b{i}"] = nc
        return h, new_caches

    x, new_scan_cache = jax.lax.scan(
        group_fn, x, (params["blocks"], cache["blocks"]))

    new_cache = {"blocks": new_scan_cache}
    if cfg.tail_blocks:
        new_tail = {}
        for i, kind in enumerate(cfg.tail_blocks):
            x, nc = apply_block_decode(params["tail"][f"b{i}"], x, cfg, kind,
                                       pos=pos, cache=cache["tail"][f"b{i}"])
            new_tail[f"b{i}"] = nc
        new_cache["tail"] = new_tail

    x = apply_norm(params["final_norm"], x, cfg)
    lg = logits(params["embedding"], x, cfg)
    return lg[:, 0], new_cache


# ---------------------------------------------------------------------------
# Input specs per (arch × shape) — the dry run contract
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train:   {"tokens", "labels" [, "patches"/"frames"]}
    prefill: {"tokens" [, "patches"/"frames"]}
    decode:  {"token", "pos"}   (cache comes from cache_specs)
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    cdt = jnp.dtype(cfg.compute_dtype)
    sd = jax.ShapeDtypeStruct

    if shape.kind == "decode":
        return {"token": sd((B,), i32), "pos": sd((B,), i32)}

    specs: Dict[str, Any] = {}
    if cfg.kind == "vlm":
        P = min(cfg.frontend_tokens, S // 4)
        specs["patches"] = sd((B, P, cfg.frontend_dim), cdt)
        specs["tokens"] = sd((B, S - P), i32)
        if shape.kind == "train":
            specs["labels"] = sd((B, S - P), i32)
    elif cfg.kind == "encdec":
        specs["frames"] = sd((B, S, cfg.frontend_dim), cdt)
        specs["tokens"] = sd((B, S), i32)
        if shape.kind == "train":
            specs["labels"] = sd((B, S), i32)
    else:
        specs["tokens"] = sd((B, S), i32)
        if shape.kind == "train":
            specs["labels"] = sd((B, S), i32)
    return specs
