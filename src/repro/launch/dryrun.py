"""Multi-pod dry run: lower + compile every (architecture × shape × mesh)
cell from ShapeDtypeStructs only (no allocation), and extract the roofline
terms from the compiled artifact.

MUST set the fake-device flag before any other import — jax locks the
device count on first init.
"""

import os
import tempfile

# Dump the module right after SPMD partitioning: that HLO carries the TRUE
# tensor dtypes (bf16 collectives) and per-device shapes.  The final CPU
# executable is float-normalized (bf16→f32 everywhere), which would double
# the roofline's collective/memory byte counts vs. a real TPU lowering.
_DUMP_DIR = tempfile.mkdtemp(prefix="repro_spmd_dump_")
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    f"--xla_dump_to={_DUMP_DIR} "
    "--xla_dump_hlo_pass_re=spmd-partitioning "
    + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402
import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import (ALIASES, ARCH_NAMES, SHAPES, get_config,
                                shape_applicable)
from repro.distributed import sharding
from repro.distributed.sharding import ShardingPlan
from repro.launch.mesh import make_production_mesh
from repro.layers.common import ParamSpec, shape_structs
from repro.models import lm
from repro.optim.adamw import AdamWConfig, opt_state_specs
from repro.roofline import hlo as hlo_lib
from repro.roofline.analysis import build_report
from repro.serving.serve_step import make_decode_step, make_prefill_step
from repro.train.train_step import make_train_step


def _state_specs(cfg):
    pspecs = lm.param_specs(cfg)
    return {
        "params": pspecs,
        "opt": opt_state_specs(pspecs),
        "step": ParamSpec((), (), dtype="int32", init="zeros"),
    }


def _mem_analysis_dict(compiled):
    out = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                out[k] = int(v)
        out["total_hbm_bytes"] = (out.get("argument_size_in_bytes", 0)
                                  + out.get("output_size_in_bytes", 0)
                                  + out.get("temp_size_in_bytes", 0)
                                  - out.get("alias_size_in_bytes", 0))
    except Exception as e:  # pragma: no cover - backend specific
        out["error"] = str(e)
    return out


DEFAULT_ACCUM = 4   # microbatches for train cells (memory fit — DESIGN.md)

# Per-arch microbatch tuning (§Perf A3/A5): under SP + selective remat the
# smaller dense models fit at accum 2, and fewer microbatch loops measurably
# reduces collective wire (remat × accum interact — see EXPERIMENTS.md).
ACCUM_BY_ARCH = {
    "llama3_8b": 2,
    "llama3p2_3b": 2,
    "gemma_7b": 2,
    "seamless_m4t_medium": 2,
    "deepseek_moe_16b": 2,
    # qwen3-moe and rwkv6 measured better at accum 2 (MFU 2×) but exceed
    # the 16 GB budget there (16.6 / 17.5 GB) — kept at 4; see EXPERIMENTS.
}


def lower_cell(arch: str, shape_name: str, mesh_kind: str,
               serve_dtype: str = "bfloat16", accum_steps: int = None,
               overrides: dict = None):
    """Builds and lowers one cell; returns (lowered, cfg, shape, mesh, plan)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise SystemExit(f"SKIP {arch}×{shape_name}: {why}")
    if accum_steps is None:
        default = ACCUM_BY_ARCH.get(ALIASES.get(arch, arch), DEFAULT_ACCUM)
        accum_steps = int(os.environ.get("REPRO_ACCUM", default)) \
            if shape.kind == "train" else 1

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    mode = shape.kind if shape.kind != "train" else "train"
    # residual-stream sequence sharding (§Perf A2): valid only when no block
    # mixes along time sequentially (recurrent archs keep seq local)
    from repro.configs.base import BLOCK_ATTN, BLOCK_LOCAL
    seq_shard = (os.environ.get("REPRO_SEQ_SHARD", "1") == "1" and shape.kind == "train"
                 and all(b in (BLOCK_ATTN, BLOCK_LOCAL)
                         for b in cfg.layer_pattern))
    plan = ShardingPlan(mesh=mesh, fsdp=(shape.kind == "train"), mode=mode,
                        seq_shard=seq_shard)

    if shape.kind == "train":
        # save_block_outputs is cheap only under SP (S/16-sized saves);
        # recurrent archs (no SP) use full recompute to fit HBM
        default_remat = "save_block_outputs" if seq_shard else "full"
        cfg = dataclasses.replace(
            cfg, remat_policy=os.environ.get("REPRO_REMAT", default_remat))
    else:
        cfg = dataclasses.replace(cfg, param_dtype=serve_dtype,
                                  remat_policy="none")
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    with sharding.use_mesh(mesh):
        if shape.kind == "train":
            sspecs = _state_specs(cfg)
            state = shape_structs(sspecs)
            state_sh = plan.param_shardings(sspecs)
            batch = lm.input_specs(cfg, shape)
            batch_sh = plan.input_shardings(batch)
            step_fn = make_train_step(cfg, AdamWConfig(), act_rules=plan.acts,
                                      accum_steps=accum_steps)
            lowered = jax.jit(step_fn,
                              in_shardings=(state_sh, batch_sh),
                              out_shardings=(state_sh, None),
                              donate_argnums=(0,)).lower(state, batch)
        elif shape.kind == "prefill":
            pspecs = lm.param_specs(cfg)
            params = shape_structs(pspecs, dtype_override=serve_dtype)
            params_sh = plan.param_shardings(pspecs)
            batch = lm.input_specs(cfg, shape)
            batch_sh = plan.input_shardings(batch)
            step_fn = make_prefill_step(cfg, act_rules=plan.acts)
            lowered = jax.jit(step_fn,
                              in_shardings=(params_sh, batch_sh)
                              ).lower(params, batch)
        else:  # decode
            # §Perf C: the paper's 8-bit datapath applied to serving —
            # w8 weights (REPRO_W8=1) and int8 KV cache (REPRO_KV8=1)
            w8 = (os.environ.get("REPRO_W8") == "1" and cfg.moe is None
                  and all(b in (BLOCK_ATTN, BLOCK_LOCAL)
                          for b in cfg.layer_pattern))
            if os.environ.get("REPRO_KV8") == "1":   # int8 cache: any arch
                cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
            pspecs = lm.param_specs(cfg)
            if w8:
                from repro.core.quantize import quantize_weight_specs
                pspecs = quantize_weight_specs(pspecs)
                params = shape_structs(pspecs)
            else:
                params = shape_structs(pspecs, dtype_override=serve_dtype)
            params_sh = plan.param_shardings(pspecs)
            cspecs = lm.cache_specs(cfg, shape.global_batch, shape.seq_len)
            cache = shape_structs(cspecs)
            cache_sh = plan.cache_shardings(cspecs)
            inp = lm.input_specs(cfg, shape)
            inp_sh = plan.input_shardings(inp)
            step_fn = make_decode_step(cfg, act_rules=plan.acts)
            lowered = jax.jit(step_fn,
                              in_shardings=(params_sh, cache_sh,
                                            inp_sh["token"], inp_sh["pos"]),
                              donate_argnums=(1,)
                              ).lower(params, cache, inp["token"], inp["pos"])
    return lowered, cfg, shape, mesh, plan


def _spmd_dump_text() -> str:
    """Newest/largest post-SPMD-partitioning dump (dtype-exact HLO)."""
    best, size = None, -1
    for name in os.listdir(_DUMP_DIR):
        if "after_spmd-partitioning" in name and name.endswith(".txt"):
            p = os.path.join(_DUMP_DIR, name)
            s = os.path.getsize(p)
            if s > size:
                best, size = p, s
    if best is None:
        return ""
    with open(best) as f:
        return f.read()


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             keep_hlo: bool = False) -> dict:
    t0 = time.time()
    lowered, cfg, shape, mesh, plan = lower_cell(arch, shape_name, mesh_kind)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    chips = mesh.devices.size
    dump = _spmd_dump_text()
    hlo_source = "spmd_dump" if dump else "final_executable"
    txt = dump or compiled.as_text()
    costs = hlo_lib.analyze(txt)
    ca = compiled.cost_analysis() or {}
    report = build_report(arch, shape_name, mesh_kind, chips, costs,
                          cfg, shape, xla_flops=float(ca.get("flops", 0.0)))

    cell = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "chips": chips, "fsdp": plan.fsdp, "mode": plan.mode,
        "hlo_source": hlo_source,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": _mem_analysis_dict(compiled),
        "cost_analysis": {k: float(v) for k, v in ca.items()
                          if not k.startswith("utilization")},
        "collectives": {k: float(v) for k, v in costs.coll_bytes.items()},
        "collective_counts": {k: int(v) for k, v in costs.coll_counts.items()},
        "roofline": report.as_dict(),
    }
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_kind}.json")
    with open(path, "w") as f:
        json.dump(cell, f, indent=1)
    if keep_hlo:
        with open(path.replace(".json", ".hlo.txt"), "w") as f:
            f.write(txt)
    print(f"OK {arch} × {shape_name} × {mesh_kind}: "
          f"compile {t_compile:.1f}s  "
          f"bottleneck={report.bottleneck}  "
          f"terms(c/m/x)=({report.t_compute:.4f}/{report.t_memory:.4f}/"
          f"{report.t_collective:.4f})s  "
          f"mfu@roofline={report.mfu_at_roofline:.3f}")
    return cell


def iter_cells(meshes=("single", "multi")):
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for shape_name, shape in SHAPES.items():
            ok, why = shape_applicable(cfg, shape)
            for mesh_kind in meshes:
                yield arch, shape_name, mesh_kind, ok, why


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", help="architecture id (see configs)", default=None)
    p.add_argument("--shape", choices=sorted(SHAPES), default=None)
    p.add_argument("--mesh", choices=("single", "multi"), default="single")
    p.add_argument("--out", default="experiments/dryrun")
    p.add_argument("--all", action="store_true",
                   help="run every runnable cell (subprocess per cell, "
                        "resumable via existing JSONs)")
    p.add_argument("--keep-hlo", action="store_true")
    p.add_argument("--force", action="store_true")
    args = p.parse_args()

    if args.all:
        failures = []
        skips = []
        for arch, shape_name, mesh_kind, ok, why in iter_cells():
            path = os.path.join(args.out,
                                f"{arch}__{shape_name}__{mesh_kind}.json")
            if not ok:
                skips.append((arch, shape_name, mesh_kind, why))
                continue
            if os.path.exists(path) and not args.force:
                print(f"cached {path}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape_name,
                   "--mesh", mesh_kind, "--out", args.out]
            if args.keep_hlo:
                cmd.append("--keep-hlo")
            r = subprocess.run(cmd)
            if r.returncode != 0:
                failures.append((arch, shape_name, mesh_kind))
        # record skips for the roofline table
        with open(os.path.join(args.out, "skips.json"), "w") as f:
            json.dump([{"arch": a, "shape": s, "mesh": m, "reason": w}
                       for a, s, m, w in skips], f, indent=1)
        print(f"done; {len(failures)} failures, {len(skips)} skips")
        if failures:
            for f_ in failures:
                print("FAILED:", f_)
            sys.exit(1)
        return

    arch = ALIASES.get(args.arch, args.arch)
    try:
        run_cell(arch, args.shape, args.mesh, args.out,
                 keep_hlo=args.keep_hlo)
    except SystemExit:
        raise
    except Exception:
        traceback.print_exc()
        sys.exit(1)


if __name__ == "__main__":
    main()
