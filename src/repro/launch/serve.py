"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Brings up the batched engine on a (reduced) architecture and serves a
synthetic request stream; ``--w8`` switches to the paper's 8-bit datapath
(w8 weights + int8 KV cache — §Perf iteration C)."""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.base import ALIASES, get_config, reduce_config
from repro.core.quantize import quantize_weights
from repro.layers.common import materialize
from repro.models import lm
from repro.serving.engine import Request, ServingEngine


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--max-seq", type=int, default=128)
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--w8", action="store_true")
    args = p.parse_args()

    cfg = reduce_config(get_config(ALIASES.get(args.arch, args.arch)))
    params = materialize(lm.param_specs(cfg), jax.random.PRNGKey(0))
    if args.w8:
        params = quantize_weights(params, lm.param_specs(cfg))
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8",
                                  kv_cache_scale=0.25)

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(
        0, cfg.vocab_size, size=int(rng.integers(4, 16))).astype(np.int32),
        max_new_tokens=args.max_new) for i in range(args.requests)]
    engine = ServingEngine(cfg, params, slots=args.slots,
                           max_seq=args.max_seq)
    t0 = time.time()
    done = engine.run(list(reqs))
    toks = sum(len(r.output) for r in done)
    print(f"{len(done)} requests, {toks} tokens, {time.time()-t0:.2f}s")


if __name__ == "__main__":
    main()
