"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Mesh shapes:

* single-pod: (data=16, model=16)       — 256 chips (one v5e pod)
* multi-pod:  (pod=2, data=16, model=16) — 512 chips

The "pod" axis carries data parallelism across pods (gradient reduction
crosses DCN); "model" carries TP/EP inside a pod.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — the dry "
            "run must set XLA_FLAGS=--xla_force_host_platform_device_count "
            "before any jax import (see launch/dryrun.py)")
    return jax.make_mesh(shape, axes, devices=devices)


def make_debug_mesh(data: int = 2, model: int = 4):
    """Small mesh for sharding tests (8 host devices)."""
    n = data * model
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[:n])
