"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On a real pod this runs under the cluster launcher with one process per
host (jax.distributed.initialize); flags select the assigned architecture,
the mesh, and the production loop's fault-tolerance knobs.  On CPU it runs
the reduced config so the full path is exercisable anywhere.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ALIASES, SHAPES, get_config, reduce_config
from repro.data.pipeline import DataConfig, make_pipeline
from repro.distributed.sharding import ShardingPlan
from repro.layers.common import materialize
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import init_state_specs, make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True)
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-3)
    p.add_argument("--accum", type=int, default=1)
    p.add_argument("--reduced", action="store_true", default=True,
                   help="reduced config (full configs need a TPU pod)")
    p.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    p.add_argument("--resume", action="store_true")
    args = p.parse_args()

    cfg = get_config(ALIASES.get(args.arch, args.arch))
    if args.reduced:
        cfg = reduce_config(cfg)

    sspecs = init_state_specs(cfg)
    state = {
        "params": materialize(sspecs["params"], jax.random.PRNGKey(0)),
        "opt": materialize(sspecs["opt"], jax.random.PRNGKey(1)),
        "step": jnp.zeros((), jnp.int32),
    }
    pipe = make_pipeline(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                   global_batch=args.batch, seed=0),
        process_index=jax.process_index(),
        process_count=jax.process_count())
    hp = AdamWConfig(peak_lr=args.lr, total_steps=args.steps,
                     warmup_steps=max(args.steps // 20, 2))
    step_fn = jax.jit(make_train_step(cfg, hp, accum_steps=args.accum))

    trainer = Trainer(
        TrainerConfig(total_steps=args.steps, checkpoint_dir=args.ckpt_dir,
                      checkpoint_every=max(args.steps // 5, 10)),
        step_fn, pipe, state)
    if args.resume and trainer.ckpt.latest_step() is not None:
        trainer.state, _ = trainer.ckpt.restore(trainer.state)
        print(f"resumed from step {trainer.ckpt.latest_step()}")
    trainer.run()


if __name__ == "__main__":
    main()
