"""GPipe-style pipeline parallelism over a mesh axis (shard_map + ppermute).

Completes the parallelism matrix (DP/FSDP/TP/EP/SP + **PP**): the layer
stack is split into ``n_stages`` groups laid out along a mesh axis (on the
production mesh this is the "pod" axis — cross-pod DCN carries only the
[microbatch, S, D] activation handoff per tick, the communication pattern
that makes pipelining attractive across pods).

Schedule: classic GPipe.  ``n_micro`` microbatches flow through
``n_stages + n_micro - 1`` ticks; at tick t, stage s computes microbatch
``t - s`` if it is in range, then ppermutes its activation to stage s+1.
Bubble fraction = (n_stages-1)/(n_stages+n_micro-1), reported by
``bubble_fraction`` so launchers can budget microbatches.

The schedule runs inside shard_map over the stage axis with a lax.scan of
ticks; everything is differentiable (ppermute/scan transpose cleanly), and
``tests/test_pipeline.py`` checks pipeline == sequential to float
tolerance, forward and backward, on a debug mesh.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_stages + n_micro - 1)


def _shard_map(f, mesh: Mesh, in_specs, out_specs):
    """Version-compatible shard_map: ``jax.shard_map`` (jax ≥ 0.6,
    check_vma=) or ``jax.experimental.shard_map`` (0.4.x, check_rep=)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


def pipeline_apply(stage_fn: Callable, stage_params, x, *, mesh: Mesh,
                   axis: str, n_micro: int):
    """Run ``stage_fn`` as a pipeline over ``axis``.

    stage_fn(params_one_stage, x_mb) → y_mb  (same shape as x_mb)
    stage_params: pytree with a leading stage axis == mesh.shape[axis]
    x: [B, ...] with B divisible by n_micro.
    Returns y: [B, ...] (the last stage's outputs, gathered).
    """
    n_stages = mesh.shape[axis]
    B = x.shape[0]
    assert B % n_micro == 0, (B, n_micro)
    mb = B // n_micro
    x_mb = x.reshape(n_micro, mb, *x.shape[1:])

    def local(params_loc, x_loc):
        # params_loc: [1, ...] this stage's params; x_loc: the full
        # microbatched input (replicated — only stage 0 consumes it)
        params_one = jax.tree.map(lambda t: t[0], params_loc)
        s = jax.lax.axis_index(axis)
        ticks = n_micro + n_stages - 1
        fwd_perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def tick(carry, t):
            buf, outs = carry          # buf: activation arriving this tick
            mb_idx = t - s
            active = jnp.logical_and(mb_idx >= 0, mb_idx < n_micro)
            # stage 0 reads from the input stream; others from the wire
            inp0 = jax.lax.dynamic_index_in_dim(
                x_loc, jnp.clip(t, 0, n_micro - 1), axis=0, keepdims=False)
            inp = jnp.where(s == 0, inp0, buf)
            y = stage_fn(params_one, inp)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # last stage collects; everyone else forwards
            outs = jax.lax.cond(
                jnp.logical_and(s == n_stages - 1, active),
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(mb_idx, 0, n_micro - 1), axis=0),
                lambda o: o, outs)
            buf = jax.lax.ppermute(y, axis, fwd_perm)
            return (buf, outs), None

        buf0 = jnp.zeros_like(x_loc[0])
        outs0 = jnp.zeros_like(x_loc)
        (buf, outs), _ = jax.lax.scan(tick, (buf0, outs0),
                                      jnp.arange(ticks))
        # outputs live on the last stage; psum broadcasts them (others hold 0)
        return jax.lax.psum(outs, axis)

    fn = _shard_map(local, mesh,
                    in_specs=(P(axis), P()),
                    out_specs=P())
    y_mb = fn(stage_params, x_mb)
    return y_mb.reshape(B, *x.shape[1:])
