"""Logical-axis sharding rules → PartitionSpecs / NamedShardings.

Axis semantics (see layers/common.py for the logical-name glossary):

* Parameters: TP axes ("heads", "mlp", "vocab", "experts", "rnn", "qkv")
  map to the "model" mesh axis; with FSDP on, the "embed" axis is
  additionally sharded over the FSDP axes (ZeRO-style — parameters,
  gradients and optimizer state all follow the same spec, so XLA emits
  reduce-scatter + all-gather instead of all-reduce in the backward pass).
* Activations: "batch" maps to the DP axes (("pod","data") on the
  multi-pod mesh); "cache_seq" maps to "model" in *decode* mode only —
  a sequence-sharded KV cache makes the per-step cache read perfectly
  parallel and keeps softmax collectives at [B, heads]-scalar size
  (DESIGN.md §Distribution).

Conflicts (a tensor whose logical axes map to the same mesh axis twice,
e.g. MoE weights [experts, embed, mlp] with experts→model and mlp→model)
are resolved first-come-first-served along dimensions, matching MaxText.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.layers.common import ParamSpec, is_spec, resolve_pspec, spec_map


def use_mesh(mesh: Mesh):
    """Version-compatible mesh context manager.

    ``jax.set_mesh`` (jax ≥ 0.6) → ``jax.sharding.use_mesh`` (0.5.x) →
    the ``Mesh`` object itself (0.4.x, where Mesh is a context manager).
    All three scope the mesh for jit/shard_map resolution."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh


def _dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def param_rules(mesh: Mesh, fsdp: bool) -> Dict[str, Any]:
    rules = {
        "heads": "model",
        "qkv": "model",
        "mlp": "model",
        "vocab": "model",
        "experts": "model",
        "rnn": "model",
        "kv_heads": None,
        "head_dim": None,
        "stack": None,
        "embed": _dp_axes(mesh) if fsdp else None,
    }
    return rules


def act_rules(mesh: Mesh, mode: str, seq_shard: bool = False) -> Dict[str, Any]:
    """mode: train | prefill | decode.

    seq_shard: Megatron-SP-style residual-stream sequence sharding ("seq_r"
    is the residual sequence axis, used only on between-block constraints).
    Forward wire is AG+RS ≈ the AR it replaces, but every backward dgrad
    psum becomes the *transpose of an all-gather* — a reduce-scatter at half
    the wire (§Perf iteration A2).  Only valid when no block mixes along
    time sequentially (recurrent archs keep seq local)."""
    rules = {
        "batch": _dp_axes(mesh),
        "seq": None,
        "seq_r": "model" if seq_shard else None,
        "embed": None,
        "heads": "model",
        "kv_heads": None,
        "qkv": "model",
        "head_dim": None,
        "mlp": "model",
        "vocab": "model",
        "experts": "model",
        "rnn": "model",
        "cache_seq": "model" if mode == "decode" else None,
    }
    return rules


def axes_to_pspec(axes: Tuple[Optional[str], ...], rules: Dict[str, Any]) -> P:
    """Map logical axes to a PartitionSpec, dropping mesh-axis reuse."""
    return resolve_pspec(axes, rules)


def _fits(shape, spec: P, mesh: Mesh) -> P:
    """Drop sharding on dims not divisible by their mesh-axis size."""
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(entry if dim % size == 0 else None)
    return P(*out)


# when a logical axis cannot take its mesh axis (divisibility), try moving
# the mesh axis to one of these sibling dims instead (yi-34b: 56 heads don't
# divide model=16, so q/o projections shard head_dim — without this they
# would silently replicate, +12 GB/device)
_FALLBACKS = {"heads": ("head_dim",)}


def spec_shardings(spec_tree, mesh: Mesh, rules: Dict[str, Any]):
    """ParamSpec pytree → NamedSharding pytree (divisibility-safe, with
    per-axis fallbacks)."""
    def f(s: ParamSpec):
        raw = axes_to_pspec(s.axes, rules)
        pspec = _fits(s.shape, raw, mesh)
        # re-place dropped mesh axes on fallback dims
        entries = list(tuple(pspec) + (None,) * (len(s.shape) - len(pspec)))
        raw_entries = tuple(raw) + (None,) * (len(s.shape) - len(raw))
        for i, (want, got) in enumerate(zip(raw_entries, entries)):
            if want is None or got is not None:
                continue
            name = s.axes[i]
            for fb in _FALLBACKS.get(name, ()):
                for j, ax_name in enumerate(s.axes):
                    if ax_name != fb or entries[j] is not None:
                        continue
                    size = mesh.shape[want] if isinstance(want, str) else 0
                    if size and s.shape[j] % size == 0:
                        entries[j] = want
                        break
                else:
                    continue
                break
        return NamedSharding(mesh, P(*entries))
    return spec_map(f, spec_tree)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def batch_sharding(mesh: Mesh, ndim: int = 2):
    """Inputs: [B, ...] sharded over the DP axes."""
    return NamedSharding(mesh, P(_dp_axes(mesh), *([None] * (ndim - 1))))


def input_shardings(input_tree, mesh: Mesh):
    """ShapeDtypeStruct tree → batch-sharded NamedShardings (dim 0 = batch),
    dropping the constraint when the batch dim does not divide."""
    def f(s):
        dp = _dp_axes(mesh)
        size = 1
        for a in dp:
            size *= mesh.shape[a]
        if s.shape and s.shape[0] % size == 0:
            return NamedSharding(mesh, P(dp, *([None] * (len(s.shape) - 1))))
        return NamedSharding(mesh, P())
    return jax.tree.map(f, input_tree)


@dataclass
class ShardingPlan:
    """Everything a step builder needs to place one (arch × shape) cell."""
    mesh: Mesh
    fsdp: bool
    mode: str                       # train | prefill | decode
    seq_shard: bool = False         # residual-stream SP (see act_rules)

    @property
    def params(self) -> Dict[str, Any]:
        return param_rules(self.mesh, self.fsdp)

    @property
    def acts(self) -> Dict[str, Any]:
        return act_rules(self.mesh, self.mode, self.seq_shard)

    def param_shardings(self, spec_tree):
        return spec_shardings(spec_tree, self.mesh, self.params)

    def cache_shardings(self, cache_spec_tree):
        # caches are activations: batch + cache_seq rules apply
        return spec_shardings(cache_spec_tree, self.mesh, self.acts)

    def input_shardings(self, input_tree):
        return input_shardings(input_tree, self.mesh)
