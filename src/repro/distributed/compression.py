"""Gradient all-reduce compression (int8 + error feedback).

The paper's 8-bit datapath, applied to the distributed-optimization layer:
cross-pod (DCN) gradient reduction is bandwidth-starved relative to ICI, so
we int8-compress gradients before the pod-axis reduction and carry the
quantization error into the next step (error feedback keeps the noise
unbiased over time).

Two integration modes:
* value-level (default here, CPU-testable): compress→decompress around the
  optimizer — numerically identical to compressing the wire payload when
  the reduction is a mean of identically-scaled shards;
* wire-level (real pods): wrap the DP all-reduce in shard_map and move the
  int8 payload + per-tensor scale through jax.lax.psum — same math, the
  hook is ``compressed_psum`` below.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quantize import EFState, ef_compress

PyTree = Any


def init_ef_state(params: PyTree) -> PyTree:
    return jax.tree.map(
        lambda p: EFState(residual=jnp.zeros(p.shape, jnp.float32)), params,
        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, EFState))


def compress_grads(grads: PyTree, ef: Optional[PyTree]) -> Tuple[PyTree, PyTree]:
    """int8-round-trip every gradient leaf with error feedback.
    Returns (decompressed_grads, new_ef_state)."""
    leaves, treedef = jax.tree.flatten(grads)
    ef_leaves = (jax.tree.leaves(ef, is_leaf=lambda x: isinstance(x, EFState))
                 if ef is not None else [None] * len(leaves))
    outs, states = [], []
    for g, s in zip(leaves, ef_leaves):
        q, ns = ef_compress(g, s)
        outs.append(q.dequantize().astype(g.dtype))
        states.append(ns)
    return (jax.tree.unflatten(treedef, outs),
            jax.tree.unflatten(treedef, states))


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """Wire-level hook (use inside shard_map): quantize to int8, psum the
    int8 payload and the scales, dequantize.  Sum of int8 shards fits int32;
    scale averaging keeps the estimate unbiased for similar shard scales."""
    from repro.core.quantize import quantize_symmetric
    q = quantize_symmetric(x)
    acc = jax.lax.psum(q.values.astype(jnp.int32), axis_name)
    # max-scale upper bound keeps the reconstruction conservative
    scale = jax.lax.pmax(q.scale, axis_name)
    return acc.astype(jnp.float32) * scale
