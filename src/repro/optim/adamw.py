"""AdamW in raw JAX, spec-driven so optimizer state inherits parameter
sharding (FSDP shards m/v exactly like the weights — ZeRO)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.layers.common import ParamSpec, is_spec, spec_map

PyTree = Any


@dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "warmup_cosine"


def opt_state_specs(param_specs: PyTree) -> Dict[str, PyTree]:
    """m/v mirror the parameter specs (same logical axes → same sharding)."""
    def f32(s: ParamSpec):
        return ParamSpec(s.shape, s.axes, dtype="float32", init="zeros")
    return {"m": spec_map(f32, param_specs), "v": spec_map(f32, param_specs)}


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree: PyTree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, tree), norm


def adamw_update(params: PyTree, grads: PyTree, opt_state: Dict[str, PyTree],
                 step: jax.Array, hp: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    from repro.optim.schedule import SCHEDULES
    lr = SCHEDULES[hp.schedule](step, peak_lr=hp.peak_lr,
                                warmup_steps=hp.warmup_steps,
                                total_steps=hp.total_steps)
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if hp.grad_clip_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, hp.grad_clip_norm)
    else:
        gnorm = global_norm(grads)

    t = step.astype(jnp.float32) + 1.0
    c1 = 1.0 - hp.b1 ** t
    c2 = 1.0 - hp.b2 ** t

    def upd(p, g, m, v):
        m_new = hp.b1 * m + (1 - hp.b1) * g
        v_new = hp.b2 * v + (1 - hp.b2) * jnp.square(g)
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + hp.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (delta + hp.weight_decay * p32)
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, {"m": new_m, "v": new_v}, metrics
