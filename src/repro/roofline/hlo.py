"""Post-SPMD HLO text analysis: exact FLOP / collective / traffic accounting
with while-loop trip-count multipliers.

Why: ``compiled.cost_analysis()`` counts each ``while`` body ONCE, so any
scan-over-layers program is undercounted by ~num_layers×.  The optimized HLO
carries ``backend_config={"known_trip_count":{"n":...}}`` on every while op;
we parse the module into computations, build the call graph (while bodies,
fusions, conditionals, calls), and accumulate counts with exact multipliers.

All shapes in post-SPMD HLO are PER-DEVICE, so every number returned here is
per-device.
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*")
_KIND_RE = re.compile(r"\s*([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")


def _split_def(line: str):
    """'%name = TYPE kind(rest' → (name, type_str, kind, rest) or None.

    TYPE may be a tuple like '(s32[], /*index=5*/f32[...])' containing '='
    inside comments, so we scan balanced parens instead of regexing."""
    m = _DEF_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    i = m.end()
    if i < len(line) and line[i] == "(":
        depth = 0
        j = i
        while j < len(line):
            if line[j] == "(":
                depth += 1
            elif line[j] == ")":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        type_str = line[i:j + 1]
        rest_start = j + 1
    else:
        j = line.find(" ", i)
        if j < 0:
            return None
        type_str = line[i:j]
        rest_start = j
    mk = _KIND_RE.match(line, rest_start)
    if not mk:
        return None
    return name, type_str, mk.group(1), line[mk.end():]


def _parse_shape(s: str) -> Optional[Tuple[str, Tuple[int, ...]]]:
    m = _SHAPE_RE.match(s)
    if not m:
        return None
    dt, dims = m.group(1), m.group(2)
    if dt not in DTYPE_BYTES:
        return None
    shape = tuple(int(d) for d in dims.split(",")) if dims else ()
    return dt, shape


def _nbytes(dt_shape) -> int:
    dt, shape = dt_shape
    n = DTYPE_BYTES[dt]
    for d in shape:
        n *= d
    return n


def _numel(dt_shape) -> int:
    n = 1
    for d in dt_shape[1]:
        n *= d
    return n


@dataclass
class OpInfo:
    name: str
    kind: str
    out: Optional[Tuple[str, Tuple[int, ...]]]
    line: str
    operands: Tuple[str, ...] = ()


@dataclass
class Computation:
    name: str
    ops: List[OpInfo] = field(default_factory=list)
    symbols: Dict[str, Tuple[str, Tuple[int, ...]]] = field(default_factory=dict)
    # (callee, multiplier) edges; while bodies with unknown trip counts are
    # stored as (body, cond) in while_edges and resolved in analyze()
    calls: List[Tuple[str, float]] = field(default_factory=list)
    while_edges: List[Tuple[str, str]] = field(default_factory=list)
    int_consts: Dict[str, int] = field(default_factory=dict)
    root_compare_const: Optional[str] = None
    flops: float = 0.0
    transcendentals: float = 0.0
    traffic: float = 0.0                      # approx HBM bytes (see below)
    coll_bytes: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    coll_wire: float = 0.0                    # modeled wire bytes
    # collectives deferred for user analysis: (name, kind, size, group)
    pending_coll: List[Tuple[str, str, float, int]] = field(default_factory=list)


def parse_module(hlo_text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    entry_name = None
    for raw in hlo_text.splitlines():
        if raw and not raw[0].isspace() and "->" in raw and "{" in raw:
            m = _COMP_RE.match(raw)
            if m:
                current = Computation(m.group(1))
                comps[current.name] = current
                if raw.startswith("ENTRY"):
                    entry_name = current.name
                continue
        if current is None:
            continue
        parsed = _split_def(raw)
        if parsed is None:
            continue
        name, out_type, kind, rest = parsed
        out = _parse_shape(out_type) if not out_type.startswith("(") else None
        op = OpInfo(name, kind, out, raw, tuple(_operands(rest)))
        current.ops.append(op)
        if out is not None:
            current.symbols[name] = out
        _account(current, op, rest, raw, out_type)
    for comp in comps.values():
        _finalize_comp(comp)
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


def _finalize_comp(comp: Computation) -> None:
    """Resolve deferred collective costs with user analysis (AR→DS = RS)."""
    if not comp.pending_coll:
        return
    users: Dict[str, List[OpInfo]] = defaultdict(list)
    for op in comp.ops:
        for o in op.operands:
            users[o].append(op)
    for name, kind, size, gsize in comp.pending_coll:
        eff_kind = kind
        if kind == "all-reduce":
            u = users.get(name, [])
            if u and all(x.kind == "dynamic-slice" for x in u):
                eff_kind = "reduce-scatter-folded"
        if eff_kind == "reduce-scatter-folded":
            # input (= the AR tensor) is size; RS wire = size·(g-1)/g
            wire = size * (gsize - 1) / gsize
            comp.coll_bytes["reduce-scatter"] += size
        else:
            wire = _wire_bytes(kind, size, gsize)
            comp.coll_bytes[kind] += size
        comp.coll_wire += wire


def _operands(rest: str) -> List[str]:
    """Names of top-level operands in 'a, %b, ...), attrs'.

    Newer HLO dumps type each operand ('f32[64,128]{1,0} %Arg_0.1'); the
    name is always the last whitespace-separated token."""
    depth = 0
    out = []
    token = ""
    for ch in rest:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            if ch == ")" and depth == 0:
                out.append(token)
                break
            depth -= 1
        elif ch == "," and depth == 0:
            out.append(token)
            token = ""
            continue
        token += ch
    return [t.strip().split()[-1].lstrip("%") for t in out if t.strip()]


_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLEE_RE = re.compile(r"(?:body|to_apply|calls)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _account(comp: Computation, op: OpInfo, rest: str, raw: str,
             out_type: str = "") -> None:
    kind = op.kind
    if kind == "constant":
        m = re.match(r"\s*(\d+)\)", rest) if op.out and op.out[0].startswith(
            ("s", "u")) else None
        if m:
            comp.int_consts[op.name] = int(m.group(1))
        return
    if kind == "compare" and "ROOT" in raw and "direction=LT" in raw:
        ops_ = _operands(rest)
        if len(ops_) == 2:
            comp.root_compare_const = ops_[1]
        return
    if kind == "while":
        mb = _CALLEE_RE.search(raw)
        mc = _COND_RE.search(raw)
        m = _TRIP_RE.search(raw)
        if m and mb:
            trip = float(m.group(1))
            comp.calls.append((mb.group(1), trip))
            if mc:
                comp.calls.append((mc.group(1), trip))
        elif mb and mc:
            # pre-optimization dumps carry no known_trip_count; recover the
            # bound from the scan condition (induction < constant, step 1)
            comp.while_edges.append((mb.group(1), mc.group(1)))
        return
    if kind == "conditional":
        mb = _BRANCHES_RE.search(raw)
        if mb:
            # count every branch once: for our cond-skip attention this is the
            # upper bound (the compute branch) plus a trivial identity branch.
            for callee in mb.group(1).split(","):
                comp.calls.append((callee.strip().lstrip("%"), 1.0))
        return
    if kind in ("fusion", "call", "map", "reduce", "reduce-window", "sort",
                "scatter", "select-and-scatter"):
        for m in _CALLEE_RE.finditer(raw):
            comp.calls.append((m.group(1), 1.0))
        # fall through: scatter/reduce also contribute traffic below
    if kind == "dot":
        ops_ = _operands(rest)
        lhs = comp.symbols.get(ops_[0]) if ops_ else None
        contract = 1
        mc = _CONTRACT_RE.search(raw)
        if lhs is not None and mc is not None and mc.group(1):
            for idx in mc.group(1).split(","):
                contract *= lhs[1][int(idx)]
        if op.out is not None:
            op_flops = 2.0 * _numel(op.out) * contract
            comp.flops += op_flops
            comp.traffic += _nbytes(op.out)
            for o in ops_[:2]:
                s = comp.symbols.get(o)
                if s is not None:
                    comp.traffic += _nbytes(s)
        return
    if kind in ("exponential", "tanh", "log", "rsqrt", "sqrt", "power",
                "logistic", "sine", "cosine", "exponential-minus-one"):
        if op.out is not None:
            comp.transcendentals += _numel(op.out)
        return
    for c in COLLECTIVES:
        if kind == c:
            size = _nbytes(op.out) if op.out is not None else 0
            # tuple-shaped collectives: sum listed array shapes
            if op.out is None:
                size = sum(_nbytes(s) for s in
                           (_parse_shape(t.strip()) for t in
                            re.findall(r"\w+\[[\d,]*\]", out_type))
                           if s is not None)
            groups = _GROUPS_RE.search(raw)
            gsize = int(groups.group(2)) if groups else 2
            # wire accounting deferred to _finalize_comp: an all-reduce whose
            # only consumer is a dynamic-slice is a reduce-scatter in
            # disguise (the TPU pipeline's reduce-scatter-creator rewrites
            # it; the CPU pipeline never does) — cost it as RS.
            comp.pending_coll.append((op.name, c, float(size), gsize))
            comp.traffic += size
            return


def _wire_bytes(kind: str, out_bytes: float, group: int) -> float:
    """Ring-model bytes per device through its ICI links."""
    if kind == "all-reduce":
        return 2.0 * out_bytes * (group - 1) / group
    if kind == "all-gather":
        return out_bytes * (group - 1) / group
    if kind == "reduce-scatter":
        return out_bytes * (group - 1)        # input = out × group
    if kind == "all-to-all":
        return out_bytes * (group - 1) / group
    if kind == "collective-permute":
        return out_bytes
    return out_bytes


@dataclass
class ModuleCosts:
    flops: float = 0.0
    transcendentals: float = 0.0
    traffic: float = 0.0
    coll_wire: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    coll_counts: Dict[str, int] = field(default_factory=lambda: defaultdict(int))


def _resolve_trip(comps: Dict[str, Computation], cond_name: str) -> float:
    cond = comps.get(cond_name)
    if cond is None:
        return 1.0
    if cond.root_compare_const is not None:
        v = cond.int_consts.get(cond.root_compare_const)
        if v is not None:
            return float(v)
    if len(cond.int_consts) == 1:     # single integer constant → the bound
        return float(next(iter(cond.int_consts.values())))
    return 1.0


def analyze(hlo_text: str) -> ModuleCosts:
    """Walk the call graph from ENTRY with trip-count multipliers."""
    comps = parse_module(hlo_text)
    entry = comps.get("__entry__")
    total = ModuleCosts()
    if entry is None:
        return total

    def walk(comp: Computation, mult: float, seen: tuple):
        if comp.name in seen:       # defensive: HLO call graphs are acyclic
            return
        total.flops += mult * comp.flops
        total.transcendentals += mult * comp.transcendentals
        total.traffic += mult * comp.traffic
        total.coll_wire += mult * comp.coll_wire
        for k, v in comp.coll_bytes.items():
            total.coll_bytes[k] += mult * v
            total.coll_counts[k] += int(mult)
        for callee, m in comp.calls:
            c = comps.get(callee)
            if c is not None:
                walk(c, mult * m, seen + (comp.name,))
        for body, cond in comp.while_edges:
            trip = _resolve_trip(comps, cond)
            for name in (body, cond):
                c = comps.get(name)
                if c is not None:
                    walk(c, mult * trip, seen + (comp.name,))

    walk(entry, 1.0, ())
    return total
