"""Three-term roofline vs TPU v5e, from the dry-run's compiled artifact.

Terms (seconds, per step, per device — post-SPMD HLO shapes are per-device):

    compute    = HLO_dot_FLOPs / peak_FLOPs            (197 TFLOP/s bf16)
    memory     = HLO_traffic_bytes / HBM_bw            (819 GB/s)
    collective = wire_bytes / ICI_link_bw              (50 GB/s/link)

HLO_dot_FLOPs / traffic / wire come from roofline.hlo.analyze (exact
while-trip-count multipliers).  ``traffic`` counts operands+outputs of
dots, collectives and scatter/gather ops — an HBM-traffic *model* (fusion
can only reduce it), recorded as such in EXPERIMENTS.md.

MODEL_FLOPS is the analytic useful-work count (6·N_active·D etc.); the
ratio MODEL_FLOPS/HLO_FLOPs exposes remat/capacity/cond waste.
"""

from __future__ import annotations

from dataclasses import dataclass, asdict
from typing import Dict

from repro.configs.base import (ArchConfig, ShapeConfig, BLOCK_ATTN,
                                BLOCK_LOCAL, BLOCK_RGLRU, BLOCK_RWKV6,
                                active_param_count)

V5E = {
    "peak_flops": 197e12,      # bf16 per chip
    "hbm_bw": 819e9,           # bytes/s per chip
    "ici_bw": 50e9,            # bytes/s per link direction (~1 axis)
}


# ---------------------------------------------------------------------------
# Analytic useful-FLOPs model (global, whole step)
# ---------------------------------------------------------------------------


def _attn_flops_fwd(cfg: ArchConfig, B: int, S: int, kind: str) -> float:
    """score+av matmuls for one layer, forward, causal-halved."""
    H, dh = cfg.num_heads, cfg.head_dim
    if kind == BLOCK_LOCAL and cfg.attention_window:
        eff = min(cfg.attention_window, S)
        pairs = S * eff - eff * (eff - 1) / 2 if S >= eff else S * (S + 1) / 2
    else:
        pairs = S * (S + 1) / 2
    return 4.0 * B * H * dh * pairs


def _mixer_state_flops_fwd(cfg: ArchConfig, B: int, S: int, kind: str) -> float:
    if kind == BLOCK_RWKV6:
        H = cfg.d_model // cfg.rwkv_head_size
        N = cfg.rwkv_head_size
        return 6.0 * B * S * H * N * N
    if kind == BLOCK_RGLRU:
        return 8.0 * B * S * cfg.rnn_width * cfg.conv1d_width
    return 0.0


def _n_matmul(cfg: ArchConfig) -> float:
    """Active parameters participating in GEMMs (gathers excluded)."""
    n = float(active_param_count(cfg))
    if not cfg.tie_embeddings:
        n -= cfg.vocab_size * cfg.d_model   # input embedding gather is free
    return n


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    B, S = shape.global_batch, shape.seq_len
    kinds = cfg.block_kinds()
    if shape.kind == "decode":
        f = 2.0 * _n_matmul(cfg) * B
        for k in kinds:
            if k in (BLOCK_ATTN, BLOCK_LOCAL):
                eff = min(cfg.attention_window, S) if k == BLOCK_LOCAL else S
                f += 4.0 * B * cfg.num_heads * cfg.head_dim * eff
            elif k == BLOCK_RWKV6:
                H = cfg.d_model // cfg.rwkv_head_size
                f += 6.0 * B * H * cfg.rwkv_head_size ** 2
        if cfg.kind == "encdec":
            f += 4.0 * B * cfg.num_heads * cfg.head_dim * S * len(kinds)
        return f

    factor = 6.0 if shape.kind == "train" else 2.0
    att_factor = 3.0 if shape.kind == "train" else 1.0
    f = factor * _n_matmul(cfg) * B * S
    for k in kinds:
        f += att_factor * _attn_flops_fwd(cfg, B, S, k)
        f += att_factor * _mixer_state_flops_fwd(cfg, B, S, k)
    if cfg.kind == "encdec":
        # encoder blocks (non-causal ⇒ full pairs ≈ 2× causal) + cross attn
        f += att_factor * cfg.encoder_layers * 2 * _attn_flops_fwd(
            cfg, B, S, BLOCK_ATTN)
        f += att_factor * len(kinds) * 2 * _attn_flops_fwd(cfg, B, S, BLOCK_ATTN)
    return f


def model_bytes_decode(cfg: ArchConfig, shape: ShapeConfig,
                       param_bytes_total: float, cache_bytes: float) -> float:
    """Useful HBM traffic for one decode step (global): read every live
    parameter once + the whole KV/recurrent cache once."""
    return param_bytes_total + cache_bytes


# ---------------------------------------------------------------------------
# Report
# ---------------------------------------------------------------------------


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device HLO-derived
    hlo_flops_dev: float
    hlo_traffic_dev: float
    wire_bytes_dev: float
    # terms (seconds)
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    # usefulness
    model_flops_global: float
    useful_ratio: float          # MODEL / (HLO × chips)
    mfu_at_roofline: float       # MODEL/(chips·peak) ÷ max(term)
    # raw cost_analysis cross-check (body-once counting)
    xla_flops_dev: float = 0.0

    def as_dict(self) -> Dict:
        return asdict(self)


def build_report(arch: str, shape_name: str, mesh_name: str, chips: int,
                 costs, cfg: ArchConfig, shape: ShapeConfig,
                 xla_flops: float = 0.0) -> RooflineReport:
    t_c = costs.flops / V5E["peak_flops"]
    t_m = costs.traffic / V5E["hbm_bw"]
    t_x = costs.coll_wire / V5E["ici_bw"]
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_global = costs.flops * chips
    t_bound = max(terms.values())
    ideal = mf / (chips * V5E["peak_flops"])
    return RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops_dev=costs.flops, hlo_traffic_dev=costs.traffic,
        wire_bytes_dev=costs.coll_wire,
        t_compute=t_c, t_memory=t_m, t_collective=t_x,
        bottleneck=bottleneck,
        model_flops_global=mf,
        useful_ratio=mf / hlo_global if hlo_global else 0.0,
        mfu_at_roofline=ideal / t_bound if t_bound else 0.0,
        xla_flops_dev=xla_flops,
    )
