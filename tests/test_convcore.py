"""ConvCore (the paper IP abstraction): layer-at-a-time semantics, banking
plans, int8 datapath, quantized float convenience path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ConvCore, ConvCoreConfig, paper_workload
from repro.core.banking import plan_banks
from repro.kernels import ref

RNG = np.random.default_rng(17)


def test_paper_workload_shapes():
    wl = paper_workload()
    core = ConvCore(ConvCoreConfig(backend="ref"))
    x = jnp.asarray(RNG.normal(size=wl["x"]), jnp.float32)
    w = jnp.asarray(RNG.normal(size=wl["w"]), jnp.float32)
    b = jnp.asarray(RNG.normal(size=wl["bias"]), jnp.float32)
    out = core.apply_layer(x, w, b)
    assert out.shape == (1, 222, 222, 8)   # the paper's 222×222 output


def test_pallas_and_ref_backends_agree():
    x = jnp.asarray(RNG.normal(size=(1, 16, 16, 8)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(3, 3, 8, 4)), jnp.float32)
    a = ConvCore(ConvCoreConfig(backend="pallas")).apply_layer(x, w)
    b = ConvCore(ConvCoreConfig(backend="ref")).apply_layer(x, w)
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


def test_int8_datapath_end_to_end():
    x = jnp.asarray(RNG.integers(-128, 128, (1, 12, 12, 4)), jnp.int8)
    w = jnp.asarray(RNG.integers(-128, 128, (3, 3, 4, 4)), jnp.int8)
    core = ConvCore(ConvCoreConfig(int8=True))
    out = core.apply_layer(x, w)
    np.testing.assert_array_equal(out, ref.conv2d_ref_int8(x, w))


def test_quantized_float_path_accuracy():
    x = jnp.asarray(RNG.normal(size=(1, 12, 12, 8)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(3, 3, 8, 4)), jnp.float32) * 0.1
    core = ConvCore(ConvCoreConfig())
    got = core.apply_quantized_layer(x, w)
    want = ref.conv2d_ref(x, w)
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < 0.03, rel


def test_multi_layer_chaining():
    """'Output BRAMs are the next layer's input' (§4.1): chain two layers."""
    core = ConvCore(ConvCoreConfig(backend="pallas"))
    x = jnp.asarray(RNG.normal(size=(1, 14, 14, 4)), jnp.float32)
    w1 = jnp.asarray(RNG.normal(size=(3, 3, 4, 8)), jnp.float32)
    w2 = jnp.asarray(RNG.normal(size=(3, 3, 8, 4)), jnp.float32)
    h = core.apply_layer(x, w1)
    out = core.apply_layer(h.astype(jnp.float32), w2)
    want = ref.conv2d_ref(ref.conv2d_ref(x, w1), w2)
    np.testing.assert_allclose(out, want, rtol=1e-3, atol=1e-3)


def test_wrap8_epilogue_backend_parity():
    """wrap8 + fused epilogue: both backends apply ReLU/pool on the int32
    accumulator, then wrap — ref stays the correctness contract."""
    x = jnp.asarray(RNG.integers(-128, 128, (1, 12, 12, 4)), jnp.int8)
    w = jnp.asarray(RNG.integers(-128, 128, (3, 3, 4, 4)), jnp.int8)
    outs = [ConvCore(ConvCoreConfig(backend=b, int8=True, wrap8=True))
            .apply_layer(x, w, relu=True, pool=True)
            for b in ("pallas", "ref")]
    assert outs[0].dtype == jnp.int8
    np.testing.assert_array_equal(outs[0], outs[1])


def test_backends_agree_on_float_out_scale():
    """Backend contract regression: PallasBackend.conv(x_f32, out_scale=s)
    must requantize to int8 exactly like RefBackend — the scale used to be
    silently dropped on the float path."""
    from repro.core.convcore import get_backend
    x = jnp.asarray(RNG.integers(-6, 6, (1, 8, 8, 4)), jnp.float32)
    w = jnp.asarray(RNG.integers(-3, 3, (3, 3, 4, 4)), jnp.float32)
    s = jnp.float32(0.1)
    a = get_backend("pallas").conv(x, w, out_scale=s)
    r = get_backend("ref").conv(x, w, out_scale=s)
    assert a.dtype == jnp.int8 and r.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(a), np.asarray(r))


def test_vmem_plan_for_paper_layer():
    plan = plan_banks(224, 224, 8, 8, in_bytes=1)
    assert plan.fits_vmem
    assert plan.cin_banks == 4 and plan.kout_banks == 4   # paper defaults fit
