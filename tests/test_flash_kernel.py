"""Pallas flash-attention kernel vs the dense oracle (interpret mode):
shape/block/dtype sweeps, causal + full."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.layers.attention import chunked_attention, dense_attention

RNG = np.random.default_rng(23)


def _qkv(b, s, h, d, dtype=jnp.float32):
    def t():
        return jnp.asarray(RNG.normal(size=(b, s, h, d)), dtype)
    return t(), t(), t()


@pytest.mark.parametrize("s,blocks", [(64, (16, 16)), (128, (32, 64)),
                                      (128, (128, 128)), (96, (32, 32))])
@pytest.mark.parametrize("causal", [True, False])
def test_matches_dense(s, blocks, causal):
    q, k, v = _qkv(2, s, 2, 32)
    bq, bk = blocks
    got = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                          interpret=True)
    want = dense_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_matches_chunked_jnp_reference():
    """The kernel and the pure-JAX chunked implementation agree — the
    intra-framework consistency triangle (kernel ↔ chunked ↔ dense)."""
    q, k, v = _qkv(1, 128, 4, 16)
    a = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                        interpret=True)
    b = chunked_attention(q, k, v, causal=True, chunk=32)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


def test_bf16_inputs():
    q, k, v = _qkv(1, 64, 2, 32, jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                          interpret=True)
    want = dense_attention(q, k, v, causal=True)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_flash_through_the_model():
    """cfg.attn_impl='flash' reproduces the chunked path end to end."""
    import dataclasses
    from repro.configs.base import get_config, reduce_config
    from repro.layers.common import materialize
    from repro.models import lm
    cfg = reduce_config(get_config("llama3_8b"))
    params = materialize(lm.param_specs(cfg), jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray(
        RNG.integers(0, cfg.vocab_size, (2, 32)), jnp.int32)}
    l1, _ = lm.forward_train(params, batch, cfg)
    l2, _ = lm.forward_train(params, batch,
                             dataclasses.replace(cfg, attn_impl="flash"))
    np.testing.assert_allclose(l1, l2, rtol=2e-4, atol=2e-4)


def test_vmem_working_set_documented():
    """The default blocks' f32 working set stays well under v5e VMEM."""
    bq = bk = 512
    d = 128
    ws = (bq * d + 2 * bk * d + bq * bk + 2 * bq + bq * d) * 4  # bytes
    assert ws < 16 * 1024 * 1024   # ≪ 128 MiB VMEM, double-buffer friendly
