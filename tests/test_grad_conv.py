"""Gradients of the paper-dataflow conv: the custom VJP through the
weight-stationary backward kernels (kernels/conv2d_ws_bwd.py) against

1. finite differences of the kernel forward itself (directional probes —
   the ground truth no oracle can fake), swept over every
   stride × padding × epilogue config the fused kernel supports;
2. ``jax.grad`` of the differentiable ref oracle (tight float tolerance);
3. the standalone backward oracles (`conv2d_input_grad_ref` /
   `conv2d_weight_grad_ref` / `maxpool2x2_bwd_ref`) vs jax.vjp of the
   forward oracle.

Plus the matmul_ws gradient checks and the bias-gradient precision
regression (sum in f32, cast to the BIAS dtype)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.conv2d_ws_bwd import (conv2d_ws_input_grad,
                                         conv2d_ws_weight_grad)
from repro.kernels.conv2d_ws_trans import conv2d_ws_transpose

RNG = np.random.default_rng(11)


def _f32(*shape):
    return jnp.asarray(RNG.normal(size=shape), jnp.float32)


def _fd_directional(loss, args, grads, eps=1e-3, rtol=8e-2, atol=8e-2,
                    rng=RNG):
    """Central finite difference along one random direction per argument
    must match ⟨grad, direction⟩.  Loss evals run in f32; tolerances
    absorb the f32 eval noise and the measure-zero relu/pool kinks a
    random direction can graze."""
    for i, (a, g) in enumerate(zip(args, grads)):
        d = jnp.asarray(rng.normal(size=a.shape), jnp.float32)
        plus = [x if j != i else x + eps * d for j, x in enumerate(args)]
        minus = [x if j != i else x - eps * d for j, x in enumerate(args)]
        fd = (loss(*plus) - loss(*minus)) / (2 * eps)
        want = jnp.sum(g * d)
        np.testing.assert_allclose(
            float(want), float(fd), rtol=rtol, atol=atol,
            err_msg=f"finite difference mismatch on argument {i}")


# ---------------------------------------------------------------------------
# The acceptance sweep: every stride × padding × epilogue config
# ---------------------------------------------------------------------------


SWEEP = [(stride, padding, relu, pool)
         for stride in (1, 2)
         for padding in ("SAME", "VALID", ((1, 0), (0, 1)))
         for relu, pool in ((False, False), (True, False), (True, True))]


@pytest.mark.parametrize("seed,stride,padding,relu,pool",
                         [(i, *cfg) for i, cfg in enumerate(SWEEP)])
def test_conv_grads_fd_and_oracle_sweep(seed, stride, padding, relu, pool):
    """Finite-difference + oracle-grad check for conv input/weight/bias
    gradients in every swept stride/padding/epilogue config (the PR's
    acceptance matrix).  Data is seeded per config so the fd probes are
    deterministic regardless of test order."""
    rng = np.random.default_rng(100 + seed)
    x = jnp.asarray(rng.normal(size=(2, 8, 8, 4)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 4, 4)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(4,)), jnp.float32)
    kw = dict(stride=stride, padding=padding, relu=relu, pool=pool)
    out = ops.conv2d(x, w, b, **kw)
    probe = jnp.asarray(rng.normal(size=out.shape), jnp.float32)

    def loss(x, w, b):
        return jnp.sum(ops.conv2d(x, w, b, **kw) * probe)

    grads = jax.grad(loss, argnums=(0, 1, 2))(x, w, b)
    _fd_directional(loss, [x, w, b], grads, rng=rng)

    # tight tolerance vs jax.grad of the differentiable oracle
    def loss_ref(x, w, b):
        return jnp.sum(ref.conv2d_epilogue_ref(x, w, b, **kw) * probe)

    want = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    for g, wgt in zip(grads, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(wgt),
                                   rtol=1e-4, atol=1e-4)


GROUPED_SWEEP = [(groups, stride, relu, pool)
                 for groups in (2, 4, 8)
                 for stride in (1, 2)
                 for relu, pool in ((False, False), (True, True))]


@pytest.mark.parametrize("seed,groups,stride,relu,pool",
                         [(i, *cfg) for i, cfg in enumerate(GROUPED_SWEEP)])
def test_grouped_conv_grads_fd_and_oracle(seed, groups, stride, relu, pool):
    """Grouped/depthwise conv gradients (C=K=8, groups up to depthwise):
    finite differences + jax.grad of the grouped oracle.  The backward
    runs the grouped transposed conv and per-group weight-grad GEMMs."""
    rng = np.random.default_rng(300 + seed)
    c = k = 8
    x = jnp.asarray(rng.normal(size=(2, 8, 8, c)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, c // groups, k)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(k,)), jnp.float32)
    kw = dict(stride=stride, padding="SAME", groups=groups, relu=relu,
              pool=pool)
    out = ops.conv2d(x, w, b, **kw)
    probe = jnp.asarray(rng.normal(size=out.shape), jnp.float32)

    def loss(x, w, b):
        return jnp.sum(ops.conv2d(x, w, b, **kw) * probe)

    grads = jax.grad(loss, argnums=(0, 1, 2))(x, w, b)
    _fd_directional(loss, [x, w, b], grads, rng=rng)

    def loss_ref(x, w, b):
        return jnp.sum(ref.conv2d_epilogue_ref(x, w, b, **kw) * probe)

    want = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    for g, wgt in zip(grads, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(wgt),
                                   rtol=1e-4, atol=1e-4)


def test_grouped_conv_grad_tiled_path():
    """Grouped gradients through the spatially-tiled kernel: the grouped
    transposed conv streams through the same halo'd tiles."""
    x, w, b = _f32(1, 12, 14, 8), _f32(3, 3, 2, 8), _f32(8)
    kw = dict(stride=1, padding="SAME", groups=4, relu=True,
              h_tile=6, w_tile=6)
    probe = _f32(*ops.conv2d(x, w, b, **kw).shape)
    grads = jax.grad(lambda x, w, b: jnp.sum(
        ops.conv2d(x, w, b, **kw) * probe), (0, 1, 2))(x, w, b)
    want = jax.grad(lambda x, w, b: jnp.sum(ref.conv2d_epilogue_ref(
        x, w, b, stride=1, padding="SAME", groups=4, relu=True) * probe),
        (0, 1, 2))(x, w, b)
    for g, wgt in zip(grads, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(wgt),
                                   rtol=1e-4, atol=1e-4)


def test_conv_grad_odd_map_pool_floor():
    """Odd conv outputs: the fused 2×2 pool drops the trailing row/col
    (floor semantics) — their gradient must be exactly zero."""
    x, w = _f32(1, 11, 9, 4), _f32(3, 3, 4, 4)
    kw = dict(stride=1, padding="VALID", relu=True, pool=True)
    probe = _f32(*ops.conv2d(x, w, **kw).shape)

    def loss(x, w):
        return jnp.sum(ops.conv2d(x, w, **kw) * probe)

    grads = jax.grad(loss, argnums=(0, 1))(x, w)
    want = jax.grad(lambda x, w: jnp.sum(
        ref.conv2d_epilogue_ref(x, w, **kw) * probe), (0, 1))(x, w)
    for g, wgt in zip(grads, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(wgt),
                                   rtol=1e-4, atol=1e-4)


def test_conv_grad_tiled_path():
    """Gradients through the spatially-tiled kernel (h_tile/w_tile set):
    the backward input-grad conv reuses the same halo'd-tile machinery."""
    x, w, b = _f32(1, 16, 14, 4), _f32(3, 3, 4, 8), _f32(8)
    kw = dict(stride=1, padding="SAME", relu=True, pool=True,
              h_tile=8, w_tile=8)
    probe = _f32(*ops.conv2d(x, w, b, **kw).shape)

    def loss(x, w, b):
        return jnp.sum(ops.conv2d(x, w, b, **kw) * probe)

    grads = jax.grad(loss, argnums=(0, 1, 2))(x, w, b)
    want = jax.grad(lambda x, w, b: jnp.sum(ref.conv2d_epilogue_ref(
        x, w, b, stride=1, padding="SAME", relu=True, pool=True) * probe),
        (0, 1, 2))(x, w, b)
    for g, wgt in zip(grads, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(wgt),
                                   rtol=1e-4, atol=1e-4)


def test_conv_grad_sub2x2_pool_raises_like_primal():
    """Differentiation must reject a sub-2×2 pooled conv output exactly
    like the primal call does (the VJP fwd rule runs the kernel with the
    epilogue disabled, so it re-checks what the kernel would have)."""
    x, w = _f32(1, 3, 3, 4), _f32(3, 3, 4, 4)
    with pytest.raises(ValueError, match="2×2 pool"):
        ops.conv2d(x, w, relu=True, pool=True)
    with pytest.raises(ValueError, match="2×2 pool"):
        jax.grad(lambda x: jnp.sum(
            ops.conv2d(x, w, relu=True, pool=True)))(x)


def test_conv_grad_bias_none():
    x, w = _f32(1, 8, 8, 4), _f32(3, 3, 4, 4)
    dx = jax.grad(lambda x: jnp.sum(
        ops.conv2d(x, w, stride=1, padding="SAME", relu=True)))(x)
    assert dx.shape == x.shape and bool(jnp.all(jnp.isfinite(dx)))


# ---------------------------------------------------------------------------
# Backward kernels vs their ref oracles vs jax.vjp of the forward oracle
# ---------------------------------------------------------------------------


BWD_CASES = [
    (8, 8, 4, 4, 1, 3, 1, "VALID"),
    (9, 10, 4, 8, 1, 3, 2, "SAME"),
    (10, 7, 2, 4, 1, 5, 2, "VALID"),
    (6, 6, 4, 4, 1, 3, 1, ((2, 1), (0, 2))),
    (7, 7, 1, 4, 1, 1, 1, "VALID"),
    # forward padding beyond the kernel extent: the transposed conv's
    # "full" padding goes negative and must slice, not pad
    (8, 8, 4, 4, 1, 3, 3, ((4, 4), (4, 4))),
    # grouped: the transposed conv flips channels per group and the
    # weight grad contracts within groups
    (8, 8, 8, 8, 2, 3, 1, "SAME"),
    (9, 10, 8, 16, 4, 3, 2, "SAME"),
    (8, 8, 8, 8, 8, 3, 1, "VALID"),                 # depthwise
    (10, 7, 6, 12, 3, 5, 2, "VALID"),
    (8, 8, 4, 4, 4, 3, 3, ((4, 4), (4, 4))),        # depthwise + neg pad
]


@pytest.mark.parametrize("h,w,c,k,groups,kh,stride,padding", BWD_CASES)
def test_bwd_oracles_and_kernels_match_vjp(h, w, c, k, groups, kh, stride,
                                           padding):
    x = _f32(2, h, w, c)
    wgt = _f32(kh, kh, c // groups, k)
    y, vjp = jax.vjp(
        lambda x, w: ref.conv2d_ref(x, w, stride=stride, padding=padding,
                                    groups=groups),
        x, wgt)
    g = _f32(*y.shape)
    dx_t, dw_t = vjp(g)
    dx_o = ref.conv2d_input_grad_ref(g, wgt, x.shape, stride=stride,
                                     padding=padding, groups=groups)
    dw_o = ref.conv2d_weight_grad_ref(x, g, kh, kh, stride=stride,
                                      padding=padding, groups=groups)
    np.testing.assert_allclose(np.asarray(dx_o), np.asarray(dx_t),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(dw_o), np.asarray(dw_t),
                               rtol=1e-5, atol=1e-4)
    dx_k = conv2d_ws_input_grad(g, wgt, x.shape, stride=stride,
                                padding=padding, groups=groups,
                                interpret=True)
    dw_k = conv2d_ws_weight_grad(x, g, kh, kh, stride=stride,
                                 padding=padding, groups=groups,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(dx_k), np.asarray(dx_t),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(dw_k), np.asarray(dw_t),
                               rtol=1e-4, atol=1e-4)


TRANS_PARITY_CASES = [
    # h, w, c, k, groups, kh, stride, padding, dilation
    (8, 8, 4, 4, 1, 3, 1, "VALID", 1),
    (9, 10, 4, 8, 1, 3, 2, "SAME", 1),
    (8, 8, 8, 8, 2, 3, 2, "SAME", 1),
    (8, 8, 8, 8, 8, 3, 1, "VALID", 1),                 # depthwise
    (8, 8, 4, 4, 1, 3, 1, "SAME", 2),                  # dilated
    (10, 7, 6, 12, 3, 3, 2, "VALID", 2),               # grouped + dilated
    (8, 8, 4, 4, 1, 3, 3, ((4, 4), (4, 4)), 1),        # negative eq pads
]


@pytest.mark.parametrize("h,w,c,k,groups,kh,stride,padding,dilation",
                         TRANS_PARITY_CASES)
def test_input_grad_is_first_class_transpose(h, w, c, k, groups, kh,
                                             stride, padding, dilation):
    """The backward input-gradient kernel must be BIT-EXACTLY the
    first-class transposed conv of the cotangent with channel-swapped
    weights pinned to the forward input shape — the duality PR 8 promoted
    into kernels/conv2d_ws_trans.py.  Bit-exact, not allclose: both paths
    must lower to the identical eq-conv launch."""
    x_shape = (2, h, w, c)
    wgt = _f32(kh, kh, c // groups, k)
    oh, ow = ref.conv_out_shape(h, w, kh, kh, stride, padding, dilation)
    g = _f32(2, oh, ow, k)
    via_bwd = conv2d_ws_input_grad(g, wgt, x_shape, stride=stride,
                                   padding=padding, groups=groups,
                                   dilation=dilation, interpret=True)
    # same bank wants as conv2d_ws_input_grad's re-legalization, so both
    # paths resolve to the identical launch (same accumulation order)
    via_trans = conv2d_ws_transpose(
        g, ref.grouped_swap_weights(wgt, groups), stride=stride,
        padding=padding, groups=groups, dilation=dilation,
        out_spatial=(h, w),
        cin_banks=4, kout_banks=max(4, groups), interpret=True)
    assert via_bwd.shape == x_shape
    np.testing.assert_array_equal(np.asarray(via_bwd),
                                  np.asarray(via_trans))
    # and both match jax.vjp of the forward oracle
    want = jax.vjp(lambda x: ref.conv2d_ref(
        x, wgt, stride=stride, padding=padding, groups=groups,
        dilation=dilation), _f32(*x_shape))[1](g)[0]
    np.testing.assert_allclose(np.asarray(via_bwd), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


TRANS_GRAD_SWEEP = [
    # stride, padding, dilation, groups, relu, pool
    (2, "VALID", 1, 1, False, False),
    (2, "SAME", 1, 1, True, False),
    (2, "VALID", 1, 2, True, True),
    (1, "VALID", 2, 1, True, False),
    (3, "SAME", 1, 4, False, False),
]


@pytest.mark.parametrize("seed,stride,padding,dilation,groups,relu,pool",
                         [(i, *cfg) for i, cfg in
                          enumerate(TRANS_GRAD_SWEEP)])
def test_conv_transpose_grads_fd_and_oracle(seed, stride, padding, dilation,
                                            groups, relu, pool):
    """ops.conv2d_transpose's custom VJP (forward-conv duality: dX runs
    the WS forward kernel, dW the batched-correlation weight grad)
    against finite differences and jax.grad of the transpose oracle."""
    rng = np.random.default_rng(500 + seed)
    c = k = 4 if groups <= 2 else groups
    x = jnp.asarray(rng.normal(size=(2, 5, 6, c)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(2, 2, c // groups, k)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(k,)), jnp.float32)
    kw = dict(stride=stride, padding=padding, dilation=dilation,
              groups=groups, relu=relu, pool=pool)
    out = ops.conv2d_transpose(x, w, b, **kw)
    probe = jnp.asarray(rng.normal(size=out.shape), jnp.float32)

    def loss(x, w, b):
        return jnp.sum(ops.conv2d_transpose(x, w, b, **kw) * probe)

    grads = jax.grad(loss, argnums=(0, 1, 2))(x, w, b)
    _fd_directional(loss, [x, w, b], grads, rng=rng)

    def loss_ref(x, w, b):
        return jnp.sum(
            ref.conv2d_transpose_epilogue_ref(x, w, b, **kw) * probe)

    want = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    for g, wgt in zip(grads, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(wgt),
                                   rtol=1e-4, atol=1e-4)


def test_input_grad_kernel_tiled_matches_whole_map():
    x_shape = (1, 16, 14, 4)
    wgt = _f32(3, 3, 4, 8)
    g = _f32(1, 8, 7, 8)
    whole = conv2d_ws_input_grad(g, wgt, x_shape, stride=2,
                                 padding="SAME", interpret=True)
    tiled = conv2d_ws_input_grad(g, wgt, x_shape, stride=2, padding="SAME",
                                 h_tile=5, w_tile=4, interpret=True)
    np.testing.assert_allclose(np.asarray(tiled), np.asarray(whole),
                               rtol=1e-5, atol=1e-5)


def test_maxpool_argmax_bwd_oracle():
    """The argmax-mask pool backward routes each window's cotangent to
    the forward max — matching jax.grad of the pooling oracle wherever
    windows have a unique max (ties are measure-zero for random data)."""
    y = _f32(2, 6, 8, 4)
    g = _f32(2, 3, 4, 4)
    idx = ref.maxpool2x2_argmax_ref(y)
    got = ref.maxpool2x2_bwd_ref(idx, g, y.shape)
    want = jax.vjp(lambda y: ref.maxpool2d_ref(y), y)[1](g)[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_relu_mask_convention():
    """The epilogue mask passes gradient only where the accumulator was
    strictly positive; exactly-zero accumulators (measure-zero for real
    data; jnp.maximum splits the tie as 0.5) get none — the deployed
    kernel's hard-gate reading of the ReLU subgradient."""
    acc = jnp.asarray([-1.0, 0.0, 2.0], jnp.float32)
    np.testing.assert_array_equal(np.asarray(ref.relu_mask_ref(acc)),
                                  np.asarray([False, False, True]))


# ---------------------------------------------------------------------------
# int8 / requantized paths stay non-differentiable, primal unchanged
# ---------------------------------------------------------------------------


def test_float_requant_path_primal_unchanged():
    """out_scale on float inputs still runs the fused requantize (int8
    out) — the custom VJP only wraps the plain float accumulator path."""
    x, w = _f32(1, 8, 8, 4), _f32(3, 3, 4, 4)
    out = ops.conv2d(x, w, out_scale=jnp.float32(0.05), relu=True)
    want = ref.conv2d_epilogue_ref(x, w, relu=True,
                                   out_scale=jnp.float32(0.05))
    assert out.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


# ---------------------------------------------------------------------------
# matmul_ws gradient checks + the bias-grad precision regression
# ---------------------------------------------------------------------------


def test_matmul_grads_fd():
    x, w, b = _f32(16, 12), _f32(12, 8), _f32(8)
    probe = _f32(16, 8)

    def loss(x, w, b):
        return jnp.sum(ops.matmul_ws(x, w, b) * probe)

    grads = jax.grad(loss, argnums=(0, 1, 2))(x, w, b)
    _fd_directional(loss, [x, w, b], grads, rtol=2e-2, atol=2e-2)


def test_matmul_bias_grad_sums_in_f32_regression():
    """Regression (failing before): ``_matmul_bwd`` summed the RAW
    cotangent dtype, so an f32 master bias fed bf16 cotangents got a
    bf16-rounded, bf16-DTYPED gradient.  The sum must run in f32 and only
    the result cast — to the bias dtype."""
    x = jnp.asarray(RNG.normal(size=(64, 32)), jnp.bfloat16)
    w = jnp.asarray(RNG.normal(size=(32, 16)), jnp.bfloat16)
    b = jnp.asarray(RNG.normal(size=(16,)), jnp.float32)
    probe = _f32(64, 16)

    db = jax.grad(lambda b: jnp.sum(
        ops.matmul_ws(x, w, b).astype(jnp.float32) * probe))(b)
    # the incoming cotangent is bf16 (the kernel output dtype); its exact
    # f32 sum is NOT bf16-representable for this probe
    want = jnp.sum(probe.astype(jnp.bfloat16).astype(jnp.float32), axis=0)
    assert db.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(db), np.asarray(want))
    assert not bool(jnp.all(want.astype(jnp.bfloat16).astype(jnp.float32)
                            == want)), \
        "probe too benign: the bf16 round-trip should lose precision"


def test_conv_bias_grad_dtype_follows_bias():
    """conv2d's VJP applies the same contract: f32 bias + bf16 network →
    f32 bias gradient."""
    x = jnp.asarray(RNG.normal(size=(1, 8, 8, 4)), jnp.bfloat16)
    w = jnp.asarray(RNG.normal(size=(3, 3, 4, 4)), jnp.bfloat16)
    b = jnp.asarray(RNG.normal(size=(4,)), jnp.float32)
    db = jax.grad(lambda b: jnp.sum(
        ops.conv2d(x, w, b, relu=True).astype(jnp.float32)))(b)
    assert db.dtype == jnp.float32


# ---------------------------------------------------------------------------
# Hypothesis sweep (guarded import, like tests/test_property.py)
# ---------------------------------------------------------------------------


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @st.composite
    def grad_case(draw):
        h = draw(st.integers(6, 11))
        w = draw(st.integers(6, 11))
        kh = draw(st.sampled_from([1, 3]))
        stride = draw(st.sampled_from([1, 2]))
        padding = draw(st.sampled_from(
            ["SAME", "VALID", ((1, 0), (0, 1)), ((0, 2), (1, 1))]))
        relu = draw(st.booleans())
        pool = draw(st.booleans())
        groups = draw(st.sampled_from([1, 2, 4]))
        dilation = draw(st.sampled_from([1, 2, 3])) if kh > 1 else 1
        if ref.dilated_extent(kh, dilation) > min(h, w):
            dilation = 1                  # keep the dilated taps in-map
        oh, ow = ref.conv_out_shape(h, w, kh, kh, stride, padding, dilation)
        if oh < 1 or ow < 1:
            dilation = 1
            oh, ow = ref.conv_out_shape(h, w, kh, kh, stride, padding)
        if pool and (oh < 2 or ow < 2):
            pool = False
        seed = draw(st.integers(0, 2**31 - 1))
        return h, w, kh, stride, padding, relu, pool, groups, dilation, seed

    @given(grad_case())
    @settings(max_examples=12, deadline=None)
    def test_conv_grad_hypothesis_sweep(case):
        """Random stride/padding/dilation/epilogue/groups configs: kernel
        grads track the differentiable oracle's."""
        (h, w, kh, stride, padding, relu, pool, groups, dilation,
         seed) = case
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(1, h, w, 4)), jnp.float32)
        wgt = jnp.asarray(rng.normal(size=(kh, kh, 4 // groups, 4)),
                          jnp.float32)
        b = jnp.asarray(rng.normal(size=(4,)), jnp.float32)
        kw = dict(stride=stride, padding=padding, relu=relu, pool=pool,
                  groups=groups, dilation=dilation)
        probe = jnp.asarray(
            rng.normal(size=ops.conv2d(x, wgt, b, **kw).shape), jnp.float32)
        grads = jax.grad(lambda x, w, b: jnp.sum(
            ops.conv2d(x, w, b, **kw) * probe), (0, 1, 2))(x, wgt, b)
        want = jax.grad(lambda x, w, b: jnp.sum(
            ref.conv2d_epilogue_ref(x, w, b, **kw) * probe),
            (0, 1, 2))(x, wgt, b)
        for g, wnt in zip(grads, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(wnt),
                                       rtol=2e-4, atol=2e-4)
