"""RoPE invariants + LR schedule behaviour."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.layers.rope import apply_rope, sinusoidal_positions
from repro.optim.schedule import warmup_cosine


def test_rope_preserves_norm():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 8, 4, 16)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(8), (2, 8))
    y = apply_rope(x, pos, theta=10_000.0)
    np.testing.assert_allclose(jnp.linalg.norm(x, axis=-1),
                               jnp.linalg.norm(y, axis=-1),
                               rtol=1e-5, atol=1e-5)


def test_rope_relative_position_property():
    """⟨rope(q,p1), rope(k,p2)⟩ depends only on p1-p2 (the point of RoPE)."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 1, 1, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, 32)), jnp.float32)

    def dot_at(p1, p2):
        qr = apply_rope(q, jnp.full((1, 1), p1), 10_000.0)
        kr = apply_rope(k, jnp.full((1, 1), p2), 10_000.0)
        return float(jnp.sum(qr * kr))

    np.testing.assert_allclose(dot_at(5, 3), dot_at(12, 10), rtol=1e-4)
    np.testing.assert_allclose(dot_at(100, 80), dot_at(40, 20), rtol=1e-4)


def test_rope_position_zero_is_identity():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(1, 1, 2, 16)), jnp.float32)
    y = apply_rope(x, jnp.zeros((1, 1), jnp.int32), 10_000.0)
    np.testing.assert_allclose(x, y, rtol=1e-6, atol=1e-6)


def test_sinusoidal_shape_and_range():
    pos = jnp.broadcast_to(jnp.arange(16), (2, 16))
    table = sinusoidal_positions(pos, 64)
    assert table.shape == (2, 16, 64)
    assert float(jnp.max(jnp.abs(table))) <= 1.0 + 1e-6


def test_warmup_cosine_shape():
    lr0 = float(warmup_cosine(0, peak_lr=1e-3, warmup_steps=10,
                              total_steps=100))
    lr_peak = float(warmup_cosine(9, peak_lr=1e-3, warmup_steps=10,
                                  total_steps=100))
    lr_end = float(warmup_cosine(99, peak_lr=1e-3, warmup_steps=10,
                                 total_steps=100))
    assert 0 < lr0 < lr_peak          # first step non-zero (step+1 conv.)
    assert abs(lr_peak - 1e-3) < 1e-9
    assert lr_end < 0.2 * 1e-3        # decays toward final_frac
    # monotone decay after warmup
    lrs = [float(warmup_cosine(s, peak_lr=1e-3, warmup_steps=10,
                               total_steps=100)) for s in range(10, 100, 10)]
    assert all(a >= b for a, b in zip(lrs, lrs[1:]))
