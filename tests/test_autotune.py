"""Calibration layer + plan autotuner.

Calibration: CalibrationTable JSON round-trip, synthetic fit recovery
(known ground-truth factors come back out of fit_calibration), IQR noise
rejection, and the bit-exactness contract — with no table loaded every
perfmodel output (including the §5.2 paper anchors 0.224 / 4.48 GOPS) is
identical to the uncalibrated model.

Autotuner: hypothesis invariants over random layer shapes — the chosen
plan always fits VMEM, respects group-aligned banks, is never worse than
the greedy ``plan_tiles(kernel="auto")`` plan under the same model, and
is deterministic given a fixed CalibrationTable; plus the crossover
verdict flip a fitted overhead makes (the README worked example) and the
execution contract (tuned plans produce bit-identical network outputs —
they change WHERE tiles fall, never WHAT is computed).

``bench_util``'s Timing stats record (the even-iters median fix) is
covered here too — tier-1 runs with PYTHONPATH=src, so the benchmarks
package is added to sys.path explicitly.
"""

import os
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import banking, network, perfmodel, scheduler
from repro.core.autotune import (NetworkTunePlan, autotune_network,
                                 candidate_states, schedule_cycles)
from repro.core.calibration import (CalibrationSample, CalibrationTable,
                                    fit_calibration, load_table,
                                    sample_from_plan)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir))

RNG = np.random.default_rng(11)


# ---------------------------------------------------------------------------
# CalibrationTable: round-trip, defaults, prediction
# ---------------------------------------------------------------------------


def test_table_json_round_trip(tmp_path):
    t = CalibrationTable(compute_factor=3.89, dma_bytes_per_cycle=2.5,
                         pipeline_overhead_cycles=40.0,
                         fit={"n_fit": 12}, provenance={"mode": "test"})
    assert CalibrationTable.from_json(t.to_json()) == t
    p = tmp_path / "calib.json"
    t.save(str(p))
    assert CalibrationTable.load(str(p)) == t
    assert load_table(str(p)) == t
    assert load_table(str(tmp_path / "missing.json")) is None
    assert load_table(None) is None


def test_table_defaults_are_analytic():
    t = CalibrationTable()
    assert t.compute_factor == 1.0
    assert t.dma_bytes_per_cycle is None
    assert t.pipeline_overhead_cycles == perfmodel.PIPELINE_OVERHEAD_CYCLES


def test_pipeline_overhead_is_table_field_default_16():
    # the satellite contract: the module constant is the no-table value
    # and stays pinned; a table carries the fitted value
    assert perfmodel.PIPELINE_OVERHEAD_CYCLES == 16
    assert perfmodel.pipeline_overhead_cycles(None) == 16
    t = CalibrationTable(pipeline_overhead_cycles=64.0)
    assert perfmodel.pipeline_overhead_cycles(t) == 64.0


# ---------------------------------------------------------------------------
# No table loaded → bit-identical perfmodel (the CI-asserted anchor)
# ---------------------------------------------------------------------------


def test_no_table_is_bit_exact():
    ref_nums = perfmodel.paper_reference_numbers()
    assert ref_nums["gops_1core"] == 0.224
    assert round(ref_nums["gops_20cores"], 2) == 4.48
    assert ref_nums["psums"] == 3_154_176
    plan = banking.plan_tiles(28, 28, 8, 16, in_bytes=1)
    psums = perfmodel.psum_count(28, 28, 8, 16)
    est0 = perfmodel.pipeline_estimate(plan, psums)
    est1 = perfmodel.pipeline_estimate(plan, psums, calib=None)
    assert est0 == est1
    assert perfmodel.calibrated_cycles(psums) == perfmodel.cycles(psums)
    net = network.lenet()
    tps = net.tile_plans()
    assert tps == net.tile_plans(calib=None)
    assert net.perf_report(tile_plans=tps) == \
        net.perf_report(tile_plans=tps, calib=None)
    assert net.train_report(tile_plans=tps) == \
        net.train_report(tile_plans=tps, calib=None)


# ---------------------------------------------------------------------------
# Fit: synthetic recovery + noise rejection
# ---------------------------------------------------------------------------

_TRUTH = dict(cf=3.89, bpc=2.5, ov=40.0)


def _synthetic_samples(noise_sd=0.002):
    cfg = perfmodel.IPCoreConfig()
    rng = np.random.default_rng(0)
    cases = [  # (compute_cycles, dma_bytes, n_slabs, pipelined)
        (2_000_000, 1_000_000, 8, True), (1_500_000, 4_000_000, 16, True),
        (500_000, 8_000_000, 32, True), (3_000_000, 200_000, 4, True),
        (800_000, 2_500_000, 64, True), (50_000, 100_000, 128, True),
        (20_000, 50_000, 256, True), (1_000_000, 1_000_000, 1, False),
        (2_500_000, 500_000, 1, False), (100_000, 6_000_000, 1, False),
    ]
    out = []
    for i, (cc, db, ns, pl) in enumerate(cases):
        true_cycles = (_TRUTH["cf"] * cc + db / _TRUTH["bpc"]
                       + (_TRUTH["ov"] * ns if pl else 0))
        us = true_cycles / cfg.clock_hz * 1e6 \
            * (1.0 + rng.normal(0, noise_sd))
        out.append(CalibrationSample(
            name=f"s{i}", compute_cycles=cc, dma_bytes=db, n_slabs=ns,
            pipelined=pl, measured_us=us, iqr_us=us * 0.001))
    return out


def test_fit_recovers_ground_truth():
    table = fit_calibration(_synthetic_samples(),
                            provenance={"mode": "synthetic"})
    assert abs(table.compute_factor - _TRUTH["cf"]) < 0.05
    assert abs(table.dma_bytes_per_cycle - _TRUTH["bpc"]) < 0.1
    assert abs(table.pipeline_overhead_cycles - _TRUTH["ov"]) < 8
    assert table.fit["n_rejected_noisy"] == 0
    assert table.fit["mean_abs_error_pct"] < 2.0
    assert table.provenance["mode"] == "synthetic"


def test_fit_rejects_noisy_samples():
    samples = _synthetic_samples()
    wild = CalibrationSample(name="wild", compute_cycles=1_000_000,
                             dma_bytes=1_000_000, n_slabs=4, pipelined=True,
                             measured_us=1e6, iqr_us=9e5)   # IQR ≈ median
    assert wild.noisy
    table = fit_calibration(samples + [wild])
    assert table.fit["n_rejected_noisy"] == 1
    assert abs(table.compute_factor - _TRUTH["cf"]) < 0.05
    with pytest.raises(ValueError):
        fit_calibration([wild])          # nothing usable left


def test_fit_without_pipelined_samples_keeps_default_overhead():
    seq_only = [s for s in _synthetic_samples() if not s.pipelined]
    table = fit_calibration(seq_only)
    assert table.pipeline_overhead_cycles == \
        perfmodel.PIPELINE_OVERHEAD_CYCLES
    assert "pipeline_overhead_cycles" not in table.fit["terms_fit"]


def test_sample_from_plan_terms_match_perfmodel():
    plan = banking.plan_tiles(28, 28, 8, 16, in_bytes=1)
    psums = perfmodel.psum_count(28, 28, 8, 16)
    s = sample_from_plan("l0", plan, psums, measured_us=123.0, iqr_us=1.0)
    assert s.compute_cycles == perfmodel.cycles(psums)
    assert s.dma_bytes == perfmodel.tile_traffic(plan)["total_bytes"]
    assert s.n_slabs == perfmodel.pipeline_slabs(plan)
    assert s.pipelined == plan.pipelined


# ---------------------------------------------------------------------------
# Autotuner (deterministic; hypothesis invariants live in
# tests/test_autotune_property.py, skipped where hypothesis is absent)
# ---------------------------------------------------------------------------

_CALIB = CalibrationTable(compute_factor=2.0, dma_bytes_per_cycle=4.0,
                          pipeline_overhead_cycles=32.0)


def test_greedy_plan_in_candidate_space():
    # the "never worse" guarantee rests on the greedy tile/bank state
    # being enumerable: its tile extents come from the same halving
    # chain, its banks are divisors
    greedy = banking.plan_tiles(64, 64, 16, 16, in_bytes=1,
                                vmem_budget=96 * 1024)
    states = candidate_states(greedy.out_h, greedy.out_w, 16, 16, 1, False)
    assert (greedy.h_tile, greedy.w_tile, greedy.cin_banks,
            greedy.kout_banks) in states


def test_network_tune_plan_contract():
    plan = network.mobilenet_v2ish()
    tune = autotune_network(plan, calib=_CALIB)
    assert isinstance(tune, NetworkTunePlan)
    assert len(tune.tile_plans) == len(plan.layers)
    assert tune.cycles <= tune.greedy_cycles
    assert tune.calibrated
    # per-layer rows carry the plan_source contract
    rows = tune.layer_rows()
    assert all(r["plan_source"] in ("greedy", "autotuned") for r in rows)
    assert sum(r["plan_source"] == "autotuned" for r in rows) == \
        tune.layers_differ
    # the scheduler glue: mode/cores thread into SchedulerConfig
    cfg = tune.scheduler_config()
    assert cfg == scheduler.SchedulerConfig.for_tune(tune)
    assert scheduler.MultiCoreScheduler.from_tune(tune).config == cfg
    # the schedule point the tuner reports is reproducible
    assert tune.schedule_cycles_ == schedule_cycles(
        tune.layers, tune.scheduler_mode, tune.n_cores, calib=_CALIB)


def test_zoo_networks_tune_leq_greedy_and_one_differs():
    # the PR acceptance criterion, as a regression test: on every zoo
    # network tuned ≤ greedy, and at least one network actually moves
    zoo = [network.lenet(), network.vgg_small(), network.resnet_small(),
           network.mobilenet_small(), network.mobilenet_v2ish()]
    differ = 0
    for plan in zoo:
        tune = autotune_network(plan)
        assert tune.cycles <= tune.greedy_cycles, plan.name
        differ += tune.layers_differ
    assert differ > 0


def test_crossover_verdict_flips_with_fitted_overhead():
    # the README worked example: a tiny DMA-bound layer the analytic
    # 16-cycle overhead routes to the pipelined kernel flips back to
    # sequential once a fitted table says slabs cost 64 cycles each
    plan16 = banking.plan_tiles(6, 6, 8, 8, in_bytes=1, kernel="auto")
    assert plan16.pipelined
    plan64 = banking.plan_tiles(
        6, 6, 8, 8, in_bytes=1, kernel="auto",
        calib=CalibrationTable(pipeline_overhead_cycles=64.0))
    assert not plan64.pipelined


def test_tuned_plans_execute_bit_exact():
    # tile plans change WHERE tiles fall, never WHAT is computed: the
    # compiled int8 program under tuned plans must produce bit-identical
    # outputs to the greedy-planned program
    plan = network.lenet(input_shape=(12, 12, 1))
    rng = np.random.default_rng(5)
    params = plan.init_params(rng)
    x = jnp.asarray(rng.normal(size=(2, *plan.input_shape)), jnp.float32)
    qnet = network.quantize_network(plan, params, x)
    from repro.core.convcore import ConvCoreConfig
    cfg = ConvCoreConfig(backend="pallas", int8=True)
    greedy_prog = network.make_int8_program(
        qnet, cfg, tile_plans=network.program_tile_plans(plan, cfg))
    tune = autotune_network(plan, calib=_CALIB)
    tuned_prog = network.make_int8_program(
        qnet, cfg, tile_plans=tune.tile_plans)
    np.testing.assert_array_equal(np.asarray(greedy_prog(x)),
                                  np.asarray(tuned_prog(x)))


# ---------------------------------------------------------------------------
# ConvCoreConfig.calib threads into the compile-time planner
# ---------------------------------------------------------------------------


def test_convcore_config_threads_calib():
    from repro.core.convcore import ConvCoreConfig
    plan = network.lenet()
    cfg = ConvCoreConfig(int8=True, calib=_CALIB)
    tps = network.program_tile_plans(plan, cfg)
    assert tps == plan.tile_plans(calib=_CALIB)
    cfg0 = ConvCoreConfig(int8=True)
    assert network.program_tile_plans(plan, cfg0) == plan.tile_plans()


# ---------------------------------------------------------------------------
# bench_util.Timing (stats record + even-iters median fix)
# ---------------------------------------------------------------------------


def test_timing_even_median_and_stats():
    from benchmarks.bench_util import Timing
    t = Timing([4.0, 1.0, 3.0, 2.0])
    assert t == 2.5                       # was 3.0 (upper-middle) before
    assert isinstance(t, float)
    assert t.min_us == 1.0 and t.median_us == 2.5
    assert t.samples_us == (1.0, 2.0, 3.0, 4.0)
    assert t.iqr_us > 0
    assert Timing([5.0, 1.0, 3.0, 2.0, 4.0]) == 3.0
    s = t.stats()
    assert set(s) == {"median_us", "min_us", "iqr_us", "samples_us"}
    with pytest.raises(ValueError):
        Timing([])


def test_time_fn_returns_timing():
    from benchmarks.bench_util import Timing, time_fn
    r = time_fn(lambda: jnp.zeros(()), iters=4, warmup=1)
    assert isinstance(r, Timing)
    assert len(r.samples_us) == 4
    assert r.min_us <= r.median_us <= max(r.samples_us)
