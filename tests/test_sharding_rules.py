"""Unit tests for the logical-axis sharding machinery: conflict resolution,
divisibility fallbacks (the yi-34b 56-head case), and mode-dependent rules.
Runs on a fake 8-device mesh in a subprocess."""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str) -> str:
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, cwd=REPO, timeout=560)
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-4000:]
    return r.stdout


def test_rules_and_fallbacks():
    out = _run(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.distributed.sharding import (act_rules, axes_to_pspec,
                                                param_rules, spec_shardings)
        from repro.layers.common import ParamSpec

        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             devices=jax.devices()[:8])
        rules = param_rules(mesh, fsdp=True)

        # conflict resolution: experts wins, mlp dropped (same mesh axis)
        spec = axes_to_pspec(("experts", "embed", "mlp"), rules)
        assert spec == P("model", ("data",), None), spec

        # divisibility fallback: 6 heads don't divide model=4 → head_dim
        # (the yi-34b case scaled down)
        s = ParamSpec((16, 6, 8), ("embed", "heads", "head_dim"))
        sh = spec_shardings({"w": s}, mesh, rules)["w"]
        assert sh.spec == P(("data",), None, "model"), sh.spec

        # decode mode sequence-shards the cache; prefill does not
        dec = act_rules(mesh, "decode")
        pre = act_rules(mesh, "prefill")
        assert dec["cache_seq"] == "model" and pre["cache_seq"] is None

        # SP: seq_r maps to model only when requested
        assert act_rules(mesh, "train", seq_shard=True)["seq_r"] == "model"
        assert act_rules(mesh, "train")["seq_r"] is None
        print("RULES_OK")
    """))
    assert "RULES_OK" in out
