"""Strongest model-level correctness check: prefill + step-by-step decode
must reproduce the full-sequence forward logits for every family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_NAMES, get_config, reduce_config
from repro.layers.common import materialize
from repro.models import lm

# one representative per family (all 10 run in smoke tests; equivalence is
# the expensive check)
FAMILIES = ["llama3_8b", "gemma_7b", "recurrentgemma_9b", "rwkv6_1p6b",
            "deepseek_moe_16b", "seamless_m4t_medium", "internvl2_26b"]


def _batch(cfg, B, S):
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.kind == "vlm":
        P = 4
        batch["tokens"] = batch["tokens"][:, :S - P]
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, P, cfg.frontend_dim)), jnp.float32)
    if cfg.kind == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.frontend_dim)), jnp.float32)
    return batch


@pytest.mark.parametrize("name", FAMILIES)
def test_prefill_decode_matches_forward(name):
    cfg = reduce_config(get_config(name))
    if cfg.moe is not None:
        # capacity-routed MoE is decode-consistent only when nothing is
        # dropped: the full-sequence pass can drop tokens at imbalanced
        # experts while a 1-token decode step never does (inherent GShard
        # property, documented in layers/moe.py).  Ample capacity here.
        import dataclasses
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = materialize(lm.param_specs(cfg), jax.random.PRNGKey(1))
    B, S, n_new = 2, 16, 4

    full_batch = _batch(cfg, B, S + n_new)
    prompt_batch = jax.tree.map(
        lambda t: t[:, :t.shape[1] - n_new] if t.dtype == jnp.int32 else t,
        full_batch)
    # encdec cross-attends the full frame sequence in both runs
    if cfg.kind == "encdec":
        prompt_batch["frames"] = full_batch["frames"]

    # forward_train logits cover the TEXT positions only (VLM slices the
    # patch prefix); decode positions are global (patches included)
    logits_full, _ = lm.forward_train(params, full_batch, cfg)
    n_patches = (prompt_batch["patches"].shape[1]
                 if cfg.kind == "vlm" else 0)

    cache_len = (S + n_new)
    last, cache = lm.prefill(params, prompt_batch, cfg, cache_len=cache_len)
    prompt_len = prompt_batch["tokens"].shape[1] + n_patches

    np.testing.assert_allclose(
        last, logits_full[:, prompt_len - 1 - n_patches],
        rtol=2e-3, atol=2e-3)

    # step-by-step decode of the remaining tokens
    toks = full_batch["tokens"]
    for j in range(n_new - 1):
        token = toks[:, toks.shape[1] - n_new + j]
        pos = jnp.full((B,), prompt_len + j, jnp.int32)
        logits_j, cache = lm.decode_step(params, cfg, token=token, pos=pos,
                                         cache=cache)
        np.testing.assert_allclose(
            logits_j, logits_full[:, prompt_len + j - n_patches],
            rtol=2e-3, atol=2e-3,
            err_msg=f"{name}: decode step {j} diverges from forward")


def test_sliding_window_ring_decode():
    """recurrentgemma with a prompt longer than the attention window: the
    ring cache must reproduce the full forward exactly (window semantics)."""
    cfg = reduce_config(get_config("recurrentgemma_9b"))
    # reduced window is 64; make the prompt longer than the window
    assert cfg.attention_window == 64
    params = materialize(lm.param_specs(cfg), jax.random.PRNGKey(2))
    B, S, n_new = 1, 96, 3
    batch = _batch(cfg, B, S + n_new)
    logits_full, _ = lm.forward_train(params, batch, cfg)
    prompt = {"tokens": batch["tokens"][:, :S]}
    last, cache = lm.prefill(params, prompt, cfg, cache_len=S + n_new)
    np.testing.assert_allclose(last, logits_full[:, S - 1],
                               rtol=2e-3, atol=2e-3)
    for j in range(n_new - 1):
        token = batch["tokens"][:, S + j]
        pos = jnp.full((B,), S + j, jnp.int32)
        lg, cache = lm.decode_step(params, cfg, token=token, pos=pos,
                                   cache=cache)
        np.testing.assert_allclose(lg, logits_full[:, S + j],
                                   rtol=2e-3, atol=2e-3)
