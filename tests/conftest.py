"""Shared fixtures.

The Backend registry (core/convcore.BACKENDS) is process-global; tests
that register sharded backends (the scheduler differentials) used to leak
them into every later test.  Snapshot/restore it around each test so no
registration escapes its test, whatever the test itself does.
"""

import pytest

from repro.core import convcore


@pytest.fixture(autouse=True)
def _clean_backend_registry():
    snapshot = dict(convcore.BACKENDS)
    yield
    convcore.BACKENDS.clear()
    convcore.BACKENDS.update(snapshot)
