"""MoE dispatch/combine correctness vs a dense per-expert oracle."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduce_config
from repro.layers.common import materialize
from repro.layers.mlp import _act
from repro.layers.moe import _capacity, apply_moe, moe_specs

RNG = np.random.default_rng(9)


def _setup(name="deepseek_moe_16b", capacity_factor=8.0):
    cfg = reduce_config(get_config(name))
    # huge capacity → no drops → must equal the dense oracle exactly
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=capacity_factor))
    params = materialize(moe_specs(cfg), jax.random.PRNGKey(0))
    x = jnp.asarray(RNG.normal(size=(2, 16, cfg.d_model)), jnp.float32)
    return cfg, params, x


def _dense_oracle(params, x, cfg):
    """Route every token through its top-k experts by direct computation."""
    m = cfg.moe
    B, S, D = x.shape
    logits = jnp.einsum("bsd,de->bse", x, params["router"])
    probs = jax.nn.softmax(logits, -1)
    vals, idx = jax.lax.top_k(probs, m.top_k)
    vals = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)
    act = _act(cfg.mlp_act)
    out = jnp.zeros_like(x)
    for e in range(m.num_experts):
        h = act(x @ params["wi_gate"][e]) * (x @ params["wi_up"][e])
        y_e = h @ params["wo"][e]
        w_e = jnp.sum(jnp.where(idx == e, vals, 0.0), axis=-1)
        out = out + w_e[..., None] * y_e
    if m.num_shared:
        from repro.layers.mlp import apply_mlp
        out = out + apply_mlp(params["shared"], x, cfg)
    return out


def test_moe_matches_dense_oracle_no_drops():
    cfg, params, x = _setup()
    got, _ = apply_moe(params, x, cfg)
    want = _dense_oracle(params, x, cfg)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


def test_moe_qwen_config_matches_oracle():
    cfg, params, x = _setup("qwen3_moe_30b_a3b")
    got, _ = apply_moe(params, x, cfg)
    want = _dense_oracle(params, x, cfg)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


def test_capacity_drops_tokens_gracefully():
    """With capacity 0 < cf ≪ 1 some tokens are dropped (output zero-ish),
    but nothing NaNs and kept tokens still match."""
    cfg, params, x = _setup(capacity_factor=0.25)
    got, aux = apply_moe(params, x, cfg)
    assert bool(jnp.all(jnp.isfinite(got)))
    assert float(aux) >= 0.0


def test_aux_loss_prefers_balance():
    """A uniform router earns a smaller aux loss than a collapsed one."""
    cfg, params, x = _setup()
    balanced = params
    collapsed = dict(params)
    collapsed["router"] = params["router"] * 0.0
    collapsed["router"] = collapsed["router"].at[:, 0].set(50.0)
    _, aux_bal = apply_moe(balanced, x, cfg)
    _, aux_col = apply_moe(collapsed, x, cfg)
    assert float(aux_col) > float(aux_bal)


def test_capacity_rounding():
    cfg, _, _ = _setup()
    m = cfg.moe
    c = _capacity(1024, m)
    assert c % 8 == 0 and c >= 8


def test_grads_flow_through_dispatch():
    cfg, params, x = _setup()

    def loss(p):
        y, aux = apply_moe(p, x, cfg)
        return jnp.sum(jnp.square(y)) + aux

    g = jax.grad(loss)(params)
    gn = jax.tree.leaves(jax.tree.map(lambda t: float(jnp.sum(jnp.abs(t))), g))
    assert all(np.isfinite(v) for v in gn)
    # router must receive gradient (through gate weights and aux loss)
    assert float(jnp.sum(jnp.abs(g["router"]))) > 0
