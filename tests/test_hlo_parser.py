"""The roofline HLO parser against compiled programs with known costs."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline.hlo import analyze, parse_module


def test_single_dot_flops_exact():
    m, k, n = 64, 128, 32

    def f(a, b):
        return a @ b

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32)).compile()
    costs = analyze(compiled.as_text())
    assert costs.flops == 2 * m * k * n


def test_scan_trip_count_multiplier():
    """A scan of L matmuls must count L× the body flops — the while-body
    correction cost_analysis() misses."""
    L, d = 7, 32

    def f(w, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        c, _ = jax.lax.scan(body, x, w)
        return c

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((L, d, d), jnp.float32),
        jax.ShapeDtypeStruct((4, d), jnp.float32)).compile()
    costs = analyze(compiled.as_text())
    want = L * 2 * 4 * d * d
    assert costs.flops == want, (costs.flops, want)
    # XLA's own number counts the body once — our correction must exceed it
    ca = compiled.cost_analysis()
    if isinstance(ca, list):      # older jax returns one dict per partition
        ca = ca[0]
    xla = ca.get("flops", 0)
    assert costs.flops > xla


def test_nested_scan_multiplies():
    Lo, Li, d = 3, 5, 16

    def f(w, x):
        def outer(c, _):
            def inner(ci, _):
                return jnp.tanh(ci @ w), None
            ci, _ = jax.lax.scan(inner, c, None, length=Li)
            return ci, None
        c, _ = jax.lax.scan(outer, x, None, length=Lo)
        return c

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((d, d), jnp.float32),
        jax.ShapeDtypeStruct((2, d), jnp.float32)).compile()
    costs = analyze(compiled.as_text())
    assert costs.flops == Lo * Li * 2 * 2 * d * d


def test_parse_module_finds_entry():
    compiled = jax.jit(lambda x: x * 2).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)).compile()
    comps = parse_module(compiled.as_text())
    assert "__entry__" in comps
