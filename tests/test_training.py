"""The training subsystem: float shadow forward through the WS kernels,
the jitted AdamW train step over NetworkPlan DAGs, QAT fake quantization,
the §5.2 train-step cycle model, and the acceptance round trip —
train float+STE → quantize_network → make_int8_program with int8 accuracy
within 2% of the float shadow."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import network, perfmodel, training
from repro.core.convcore import ConvCoreConfig
from repro.core.quantize import (fake_quant_act, fake_quant_weight,
                                 fake_quantize, quantize_symmetric)

RNG = np.random.default_rng(5)


# ---------------------------------------------------------------------------
# fake quantization (the STE)
# ---------------------------------------------------------------------------


def test_fake_quantize_forward_is_int8_roundtrip():
    x = jnp.asarray(RNG.normal(size=(64,)) * 3, jnp.float32)
    scale = jnp.float32(0.05)
    got = fake_quantize(x, scale)
    want = jnp.clip(jnp.round(x / scale), -127, 127) * scale
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_fake_quantize_backward_is_identity():
    x = jnp.asarray(RNG.normal(size=(32,)), jnp.float32)
    g = jax.grad(lambda x: jnp.sum(fake_quantize(x, jnp.float32(0.1)) *
                                   jnp.arange(32, dtype=jnp.float32)))(x)
    np.testing.assert_allclose(np.asarray(g),
                               np.arange(32, dtype=np.float32), rtol=1e-6)


def test_fake_quant_weight_matches_deployment_grid():
    """QAT must see the grid quantize_network will emit: fake-quantized
    weights are exactly the dequantized int8 lowering (per tensor and per
    output channel)."""
    w = jnp.asarray(RNG.normal(size=(3, 3, 4, 8)), jnp.float32)
    for per_channel in (False, True):
        got = fake_quant_weight(w, per_channel)
        wq = quantize_symmetric(
            w, axis=tuple(range(w.ndim - 1)) if per_channel else None)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(wq.dequantize()),
                                   rtol=1e-5, atol=1e-6)


def test_fake_quant_act_scale_has_no_gradient():
    x = jnp.asarray(RNG.normal(size=(16,)), jnp.float32)
    g = jax.grad(lambda x: jnp.sum(fake_quant_act(x)))(x)
    assert bool(jnp.all(jnp.isfinite(g)))
    np.testing.assert_allclose(np.asarray(g), np.ones(16, np.float32),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# float shadow forward
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("make_plan", [
    lambda: network.lenet(input_shape=(12, 12, 1)),
    lambda: network.resnet_small(input_shape=(16, 16, 4)),
])
def test_float_forward_matches_ref_oracle(make_plan):
    """The kernel-substrate shadow forward equals the lax-based float
    oracle (straight-line and residual-DAG plans alike)."""
    plan = make_plan()
    params = plan.init_params(np.random.default_rng(0))
    x = jnp.asarray(RNG.normal(size=(2, *plan.input_shape)), jnp.float32)
    got = training.float_forward(plan, params, x)
    want = plan.apply_ref(params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-4, atol=5e-4)


def test_float_forward_qat_still_close_to_float():
    """Fake quantization perturbs activations by at most ~1 LSB per grid
    point — the QAT forward stays close to (but not equal to) the float
    one."""
    plan = network.lenet(input_shape=(12, 12, 1))
    params = plan.init_params(np.random.default_rng(0))
    x = jnp.asarray(RNG.normal(size=(4, *plan.input_shape)), jnp.float32)
    f = training.float_forward(plan, params, x)
    q = training.float_forward(plan, params, x, qat=True)
    assert not bool(jnp.all(f == q))
    rel = float(jnp.linalg.norm(f - q) / jnp.linalg.norm(f))
    assert rel < 0.2, rel


# ---------------------------------------------------------------------------
# train step / fit
# ---------------------------------------------------------------------------


def test_train_step_runs_and_learns_lenet():
    plan = network.lenet(input_shape=(12, 12, 1))
    rng = np.random.default_rng(1)
    x, y = training.synthetic_digits(rng, 256)
    state, hist = training.fit(plan, x, y, steps=25, batch=32, seed=2)
    assert int(state.step) == 25
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.5, (
        hist[0]["loss"], hist[-1]["loss"])
    assert all(np.isfinite(h["grad_norm"]) for h in hist)


def test_train_step_residual_dag():
    """One step through a residual graph: gradients flow through skip
    adds, projection shortcuts, and global pool, and stay finite."""
    plan = network.resnet_small(input_shape=(16, 16, 4), classes=4)
    rng = np.random.default_rng(3)
    x, y = training.synthetic_digits(rng, 32, input_shape=(16, 16, 4),
                                     classes=4)
    state = training.init_train_state(plan, rng)
    step = training.make_train_step(plan)
    state2, metrics = step(state, x[:8], y[:8])
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # every parametric node actually moved
    for p0, p1 in zip(state.params, state2.params):
        if p0 is not None:
            assert not bool(jnp.all(p0["w"] == p1["w"]))


def test_train_step_concat_merge():
    """Branch-concat graphs train too: gradients split across the
    concatenated branches."""
    plan = network.NetworkPlan(
        name="concat_net", input_shape=(8, 8, 4),
        layers=(
            network.conv(4, relu=True, name="a", input="input"),
            network.conv(4, relu=True, name="b", input="input"),
            network.concat("a", "b", name="m"),
            network.global_pool(),
            network.dense(4),
        ))
    rng = np.random.default_rng(4)
    x, y = training.synthetic_digits(rng, 16, input_shape=(8, 8, 4),
                                     classes=4)
    state = training.init_train_state(plan, rng)
    step = training.make_train_step(plan, training.TrainConfig(qat=True))
    state2, metrics = step(state, x, y)
    assert np.isfinite(float(metrics["loss"]))
    for i in (0, 1):                      # both branches got gradient
        assert not bool(jnp.all(state.params[i]["w"]
                                == state2.params[i]["w"]))


def test_synthetic_digits_share_templates_across_calls():
    rng = np.random.default_rng(0)
    x1, y1 = training.synthetic_digits(rng, 64)
    x2, y2 = training.synthetic_digits(rng, 64)
    # same task (templates), different samples
    assert not bool(jnp.all(x1 == x2))
    m1 = jnp.stack([jnp.mean(x1[y1 == c], 0) for c in range(10)])
    m2 = jnp.stack([jnp.mean(x2[y2 == c], 0) for c in range(10)])
    assert float(jnp.mean(jnp.abs(m1 - m2))) < 0.5


# ---------------------------------------------------------------------------
# the acceptance round trip: QAT → quantize_network → int8 program
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("per_channel", [False, True])
def test_lenet_qat_roundtrip_within_2pct(per_channel):
    """Train the LeNet float shadow with straight-through fake quant,
    lower the trained weights with quantize_network, compile with
    make_int8_program — deployed int8 accuracy must hold within 2% of
    the float shadow on the held-out synthetic eval set."""
    plan = network.lenet(input_shape=(12, 12, 1))
    rng = np.random.default_rng(7)
    x, y = training.synthetic_digits(rng, 384)
    xe, ye = training.synthetic_digits(rng, 192)
    cfg = training.TrainConfig(qat=True, per_channel=per_channel)
    state, _ = training.fit(plan, x, y, steps=50, batch=32, cfg=cfg,
                            seed=8)

    float_logits = training.float_forward(plan, state.params, xe)
    float_acc = float(training.accuracy(float_logits, ye))
    assert float_acc >= 0.9, f"shadow model failed to learn: {float_acc}"

    qnet = network.quantize_network(plan, state.params, x[:128],
                                    per_channel=per_channel)
    program = network.make_int8_program(
        qnet, ConvCoreConfig(backend="pallas", int8=True))
    int8_acc = float(training.accuracy(program(xe), ye))
    assert abs(float_acc - int8_acc) <= 0.02, (float_acc, int8_acc)


@pytest.mark.parametrize("make_plan", [network.mobilenet_small,
                                       network.mobilenet_v2ish])
def test_mobilenet_qat_roundtrip_within_2pct(make_plan):
    """Acceptance: the MobileNet zoo trains through the grouped WS
    backward kernels (depthwise transposed convs + per-group weight-grad
    GEMMs) with QAT, and the deployed int8 program holds accuracy within
    2% of the float shadow — the LeNet/ResNet contract extended to the
    grouped-conv workload family."""
    plan = make_plan(input_shape=(12, 12, 1))
    rng = np.random.default_rng(7)
    x, y = training.synthetic_digits(rng, 256)
    xe, ye = training.synthetic_digits(rng, 128)
    from repro.optim.adamw import AdamWConfig
    cfg = training.TrainConfig(qat=True, per_channel=True,
                               adamw=AdamWConfig(
                                   peak_lr=1e-2, warmup_steps=10,
                                   total_steps=80, weight_decay=1e-4,
                                   grad_clip_norm=1.0))
    state, _ = training.fit(plan, x, y, steps=80, batch=32, cfg=cfg,
                            seed=8)

    float_logits = training.float_forward(plan, state.params, xe)
    float_acc = float(training.accuracy(float_logits, ye))
    assert float_acc >= 0.9, f"shadow model failed to learn: {float_acc}"

    qnet = network.quantize_network(plan, state.params, x[:128],
                                    per_channel=True)
    program = network.make_int8_program(
        qnet, ConvCoreConfig(backend="pallas", int8=True))
    int8_acc = float(training.accuracy(program(xe), ye))
    assert abs(float_acc - int8_acc) <= 0.02, (float_acc, int8_acc)


# ---------------------------------------------------------------------------
# the §5.2 train-step cycle model
# ---------------------------------------------------------------------------


def test_train_report_backward_accounting():
    plan = network.lenet()
    fwd = plan.perf_report()
    rep = plan.train_report()
    # backward = input-grad + weight-grad ≈ 2× forward psums; step = 3×
    assert rep["forward"]["psums"] == fwd["psums"]
    assert rep["backward"]["psums"] == 2 * fwd["psums"]
    assert rep["psums"] == 3 * fwd["psums"]
    assert rep["cycles"] >= fwd["cycles"] * 3 - len(plan.layers) * \
        perfmodel.IPCoreConfig().cycles_per_batch
    # parametric nodes carry dW writeback traffic on the DMA interface
    dw_rows = [r for r in rep["backward"]["layers"] if "dw_bytes" in r]
    shapes = [s for s in plan.param_shapes() if s is not None]
    assert len(dw_rows) == len(shapes)
    for row, shp in zip(dw_rows, shapes):
        want = 4 * (int(np.prod(shp["w"])) + int(np.prod(shp["b"])))
        assert row["dw_bytes"] == want
        assert row["cycles"] >= row["dw_dma_cycles"] or \
            row["cycles"] >= perfmodel.cycles(row["psums_bwd"])
    # full board: replication helps compute, not the shared DMA interface
    assert rep["full_board"]["cycles"] <= rep["cycles"]


def test_train_report_paper_defaults_untouched():
    """Adding the training model must not move the §5.2 inference
    anchors."""
    nums = perfmodel.paper_reference_numbers()
    assert round(nums["gops_1core"], 3) == 0.224
    assert round(nums["gops_20cores"], 2) == 4.48


def test_dense_only_train_report():
    """train_report works for plans whose backward is DMA-bound (fat dense
    layers: dW traffic dominates the 2× psum compute)."""
    plan = network.NetworkPlan(
        name="dense_heavy", input_shape=(4, 4, 4),
        layers=(network.flatten(), network.dense(512, relu=True),
                network.dense(4)))
    rep = plan.train_report()
    rows = {r["name"]: r for r in rep["backward"]["layers"]}
    fat = rows["dense1"]
    assert fat["cycles"] == max(perfmodel.cycles(fat["psums_bwd"]),
                                fat["dw_dma_cycles"])
