"""Data pipeline: determinism, seekability, host sharding."""

import numpy as np

from repro.data.pipeline import DataConfig, SyntheticLM, fingerprint, make_pipeline


def _cfg(**kw):
    base = dict(vocab_size=128, seq_len=32, global_batch=8, seed=7)
    base.update(kw)
    return DataConfig(**base)


def test_batch_is_pure_function_of_step():
    p1 = SyntheticLM(_cfg())
    p2 = SyntheticLM(_cfg())
    for step in (0, 5, 1000):
        a, b = p1.batch_at(step), p2.batch_at(step)
        assert fingerprint(a) == fingerprint(b)


def test_steps_differ():
    p = SyntheticLM(_cfg())
    assert fingerprint(p.batch_at(1)) != fingerprint(p.batch_at(2))


def test_labels_are_next_tokens():
    p = SyntheticLM(_cfg())
    b = p.batch_at(0)
    # structure: labels[t] is mostly perm[tokens[t]] (90%), so a model can
    # learn it; verify the shift relationship holds exactly
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_host_sharding_partitions_batch():
    full = SyntheticLM(_cfg(), process_index=0, process_count=1)
    h0 = SyntheticLM(_cfg(), process_index=0, process_count=2)
    h1 = SyntheticLM(_cfg(), process_index=1, process_count=2)
    assert h0.local_batch == h1.local_batch == 4
    b0, b1 = h0.batch_at(3), h1.batch_at(3)
    # different hosts draw independent rows
    assert fingerprint(b0) != fingerprint(b1)


def test_textfile_pipeline(tmp_path):
    path = tmp_path / "corpus.txt"
    path.write_bytes(b"hello world, this is a tiny corpus for testing " * 40)
    p = make_pipeline(_cfg(kind="textfile", path=str(path), vocab_size=256))
    b = p.batch_at(0)
    assert b["tokens"].shape == (8, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    assert fingerprint(p.batch_at(0)) == fingerprint(p.batch_at(0))
