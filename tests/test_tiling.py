"""Spatial tiling: the halo'd H/W-streaming conv kernel vs the oracle, the
joint TilePlan planner's invariants, the working-set accounting fix, the
spatial-sharded scheduler mode, and the large-map acceptance path (a conv
layer whose whole-map working set exceeds the VMEM budget streaming
bit-exactly through halo'd tiles)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import banking, network, perfmodel, scheduler
from repro.core.banking import TilePlan, plan_banks, plan_tiles
from repro.core.convcore import ConvCore, ConvCoreConfig, get_backend
from repro.kernels import ref
from repro.kernels.conv2d_ws import conv2d_ws

RNG = np.random.default_rng(23)


def _i8(*shape):
    return jnp.asarray(RNG.integers(-128, 128, size=shape), jnp.int8)


def _f32(*shape):
    return jnp.asarray(RNG.normal(size=shape), jnp.float32)


# ---------------------------------------------------------------------------
# Tiled kernel vs oracle (deterministic grid of the hard cases)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("stride", [1, 2, 3])
@pytest.mark.parametrize("padding", ["VALID", "SAME"])
def test_tiled_int8_bit_exact_strides(stride, padding):
    """Tile sizes that do NOT divide the output, every stride, both
    canonical paddings — int8 is bit-exact, no tolerance."""
    x, w = _i8(2, 17, 13, 8), _i8(3, 3, 8, 8)
    b = jnp.asarray(RNG.integers(-500, 500, (8,)), jnp.int32)
    got = conv2d_ws(x, w, b, stride=stride, padding=padding,
                    h_tile=3, w_tile=5, interpret=True)
    want = ref.conv2d_ref_int8(x, w, b, stride=stride, padding=padding)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("kh,kw", [(1, 3), (5, 2), (2, 4)])
def test_tiled_nonsquare_kernels(kh, kw):
    x, w = _i8(1, 14, 15, 4), _i8(kh, kw, 4, 4)
    got = conv2d_ws(x, w, h_tile=4, w_tile=6, interpret=True)
    want = ref.conv2d_ref_int8(x, w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_tiled_explicit_padding():
    x, w = _i8(1, 11, 9, 4), _i8(3, 3, 4, 8)
    pad = ((2, 1), (0, 2))
    got = conv2d_ws(x, w, padding=pad, h_tile=5, w_tile=4, interpret=True)
    want = ref.conv2d_ref_int8(x, w, padding=pad)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_tiled_fused_epilogue_pool_aligned():
    """ReLU → 2×2 pool → requantize, tile-local: even tiles keep pool
    windows inside tiles and the result bit-matches the oracle chain."""
    x, w = _i8(2, 18, 14, 8), _i8(3, 3, 8, 8)
    b = jnp.asarray(RNG.integers(-500, 500, (8,)), jnp.int32)
    sc = jnp.asarray(RNG.uniform(5e-4, 2e-3, (8,)), jnp.float32)
    got = conv2d_ws(x, w, b, sc, padding="SAME", h_tile=4, w_tile=6,
                    relu=True, pool=True, interpret=True)
    want = ref.conv2d_epilogue_ref(x, w, b, padding="SAME", relu=True,
                                   pool=True, out_scale=sc)
    assert got.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pool_rejects_unaligned_tiles():
    x, w = _i8(1, 12, 12, 4), _i8(3, 3, 4, 4)
    with pytest.raises(AssertionError):
        conv2d_ws(x, w, padding="SAME", h_tile=3, w_tile=4, pool=True,
                  interpret=True)


def test_tiled_float_matches_oracle():
    x, w, b = _f32(1, 13, 17, 4), _f32(3, 3, 4, 8), _f32(8)
    got = conv2d_ws(x, w, b, stride=2, padding="SAME", h_tile=2, w_tile=4,
                    interpret=True)
    want = ref.conv2d_ref(x, w, b, stride=2, padding="SAME")
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# Hypothesis sweep (guarded import, like tests/test_property.py)
# ---------------------------------------------------------------------------


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @st.composite
    def tiled_case(draw):
        h = draw(st.integers(6, 16))
        w = draw(st.integers(6, 16))
        kh = draw(st.integers(1, 4))
        kw = draw(st.integers(1, 4))
        stride = draw(st.sampled_from([1, 2, 3]))
        padding = draw(st.sampled_from(
            ["VALID", "SAME", ((draw(st.integers(0, 2)),
                                draw(st.integers(0, 2))),
                               (draw(st.integers(0, 2)),
                                draw(st.integers(0, 2))))]))
        oh, ow = ref.conv_out_shape(h, w, kh, kw, stride, padding)
        if oh < 1 or ow < 1:
            h, w, padding = h + kh, w + kw, "SAME"
            oh, ow = ref.conv_out_shape(h, w, kh, kw, stride, padding)
        th = draw(st.integers(1, max(1, oh)))
        tw = draw(st.integers(1, max(1, ow)))
        seed = draw(st.integers(0, 2**31 - 1))
        return h, w, kh, kw, stride, padding, th, tw, seed

    @given(tiled_case())
    @settings(max_examples=25, deadline=None)
    def test_tiled_conv_bit_exact_property(case):
        """Tiled == untiled == oracle, bit-exact, for arbitrary strides,
        paddings, non-square kernels, and non-dividing tile sizes."""
        h, w, kh, kw, stride, padding, th, tw, seed = case
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.integers(-128, 128, (1, h, w, 4)), jnp.int8)
        wt = jnp.asarray(rng.integers(-128, 128, (kh, kw, 4, 4)), jnp.int8)
        got = conv2d_ws(x, wt, stride=stride, padding=padding,
                        h_tile=th, w_tile=tw, interpret=True)
        want = ref.conv2d_ref_int8(x, wt, stride=stride, padding=padding)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @given(st.integers(8, 320), st.integers(8, 320),
           st.sampled_from([4, 8, 16, 64]), st.sampled_from([4, 16, 64]),
           st.sampled_from([1, 2]), st.booleans())
    @settings(max_examples=30, deadline=None)
    def test_plan_tiles_invariants(h, w, c, k, stride, pool):
        """plan_tiles: working set fits the budget (or nothing can shrink
        further), tiles are pool-aligned, banks divide the channels, and
        tiles cover the output."""
        budget = 1 << 20                       # 1 MiB: forces real tiling
        oh, ow = ref.conv_out_shape(h, w, 3, 3, stride, "SAME")
        if pool and (oh < 2 or ow < 2):
            pool = False
        p = plan_tiles(h, w, c, k, stride=stride, padding="SAME",
                       pool=pool, in_bytes=1, out_bytes=1,
                       vmem_budget=budget)
        assert c % p.cin_banks == 0 and k % p.kout_banks == 0
        if pool:
            assert p.h_tile % 2 == 0 and p.w_tile % 2 == 0
        assert p.n_h_tiles * p.h_tile >= p.out_h
        assert p.n_w_tiles * p.w_tile >= p.out_w
        # recompute the working set from first principles
        cb, kb = c // p.cin_banks, k // p.kout_banks
        assert p.image_block_bytes == p.in_h_tile * p.in_w_tile * cb
        assert p.acc_block_bytes == p.h_tile * p.w_tile * kb * 4
        if not p.fits_vmem:
            # only legal when maximally split: minimal tiles AND banks
            min_tile = 2 if pool else 1
            assert p.h_tile <= min_tile and p.w_tile <= min_tile
            assert cb == 1 and kb == 1


# ---------------------------------------------------------------------------
# Working-set accounting (the BankPlan undercount fix)
# ---------------------------------------------------------------------------


def test_bankplan_counts_acc_and_output_separately():
    plan = plan_banks(64, 64, 8, 8, in_bytes=1, out_bytes=1)
    # epilogue output (int8) and accumulator scratch (int32) are distinct
    oh = ow = 62
    assert plan.output_block_bytes == oh * ow * 2 * 1
    assert plan.acc_block_bytes == oh * ow * 2 * 4
    assert plan.working_set_bytes == (
        2 * (plan.image_block_bytes + plan.weight_block_bytes
             + plan.output_block_bytes) + plan.acc_block_bytes)


def test_tileplan_working_set_separates_acc():
    p = plan_tiles(64, 64, 8, 8, in_bytes=1, out_bytes=1, pool=False,
                   vmem_budget=None)
    assert p.working_set_bytes == (
        2 * (p.image_block_bytes + p.weight_block_bytes
             + p.output_block_bytes) + p.acc_block_bytes)
    assert p.acc_block_bytes == p.h_tile * p.w_tile * (8 // p.kout_banks) * 4


def test_pooled_tiny_output_planner_and_kernel_agree():
    """Regression: plan_tiles(pool=True) used to clamp a 1×1 conv output
    to a phantom 2×2 pooled map — reporting nonzero tile traffic for a
    layer conv2d_ws rejects.  Planner and kernel now raise the same
    error."""
    with pytest.raises(ValueError, match="2×2 pool"):
        plan_tiles(3, 3, 4, 4, padding="VALID", pool=True, in_bytes=1)
    x, w = _i8(1, 3, 3, 4), _i8(3, 3, 4, 4)       # VALID → 1×1 conv output
    with pytest.raises(ValueError, match="2×2 pool"):
        conv2d_ws(x, w, pool=True, interpret=True)
    # 2×2 output is the smallest legal pooled map: both accept it
    p = plan_tiles(4, 4, 4, 4, padding="VALID", pool=True, in_bytes=1)
    assert (p.out_h, p.out_w) == (2, 2)


def test_resnet_tile_plans_compile():
    """Residual-graph plans route per-node input shapes into the planner:
    every conv (including 1×1 projection shortcuts) gets a fitting plan."""
    for plan in (network.resnet_small(), network.resnet_bottleneck()):
        tps = plan.tile_plans()
        convs = [tp for tp in tps if tp is not None]
        assert len(convs) == sum(
            1 for sp in plan.layers if sp.kind == "conv")
        assert all(tp.fits_vmem for tp in convs), plan.name


# ---------------------------------------------------------------------------
# ConvCore planning + spatial-sharded scheduler
# ---------------------------------------------------------------------------


def test_convcore_plans_tiles_for_large_maps():
    core = ConvCore(ConvCoreConfig(int8=True))
    plan = core.plan((1, 512, 512, 64), (3, 3, 64, 64), 1, "SAME")
    assert plan.tiled and plan.fits_vmem
    # small maps keep the whole-map single tile and paper 4×4 banking
    small = core.plan((1, 28, 28, 8), (3, 3, 8, 8), 1, "SAME")
    assert not small.tiled
    assert small.cin_banks == 4 and small.kout_banks == 4


@pytest.mark.parametrize("pool", [False, True])
def test_spatial_sharded_backend_exact(pool):
    """Halo'd row bands across virtual cores == the unsharded conv,
    bit-exact, including the fused pool epilogue (pool-aligned bands)."""
    inner = get_backend("ref")
    sb = scheduler.SpatialShardedBackend(inner, 3)
    x, w = _i8(2, 19, 11, 4), _i8(3, 3, 4, 8)
    b = jnp.asarray(RNG.integers(-300, 300, (8,)), jnp.int32)
    got = sb.conv(x, w, b, stride=1, padding="SAME", relu=True, pool=pool)
    want = inner.conv(x, w, b, stride=1, padding="SAME", relu=True,
                      pool=pool)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_spatial_mode_network_bit_identical():
    plan = network.lenet()
    params = plan.init_params(RNG)
    x = jnp.asarray(RNG.normal(size=(2, *plan.input_shape)), jnp.float32)
    qnet = network.quantize_network(plan, params, x)
    base = network.make_int8_program(
        qnet, ConvCoreConfig(backend="ref", int8=True))(x)
    sched = scheduler.MultiCoreScheduler(
        scheduler.SchedulerConfig(n_cores=4, mode="spatial"))
    sb = sched.shard_backend("ref")
    from repro.core.convcore import register_backend
    register_backend(sb)
    got = sched.run(network.make_int8_program(
        qnet, ConvCoreConfig(backend=sb.name, int8=True)), x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))


# ---------------------------------------------------------------------------
# Perfmodel: tile revisits + halo re-reads
# ---------------------------------------------------------------------------


def test_tile_traffic_prices_halo_rereads():
    p = plan_tiles(512, 512, 64, 64, stride=1, padding="SAME",
                   in_bytes=1, out_bytes=1)
    assert p.tiled
    t = perfmodel.tile_traffic(p)
    assert t["halo_read_factor"] > 1.0          # halos are re-read
    assert t["kout_revisits"] == p.kout_banks   # input re-read per kernel set
    assert t["total_bytes"] == (t["input_bytes"] + t["weight_bytes"]
                                + t["output_bytes"])


def test_network_report_tile_pricing_keeps_defaults():
    """Without tile plans the §5.2 numbers are untouched; with plans,
    layer cycles floor at the DMA time and the shared-DDR bound keeps the
    20-core estimate honest."""
    plan = network.large_map()
    base = plan.perf_report()
    priced = plan.perf_report(tile_plans=plan.tile_plans())
    assert priced["cycles"] >= base["cycles"]
    l0 = priced["layers"][0]
    assert l0["n_tiles"] > 1 and l0["halo_read_factor"] > 1.0
    assert l0["cycles"] >= l0["dma_cycles"]
    # the DMA floor does not shrink with 20 cores (shared interface)
    assert priced["full_board"]["cycles"] >= sum(
        r["dma_cycles"] for r in priced["layers"] if "dma_cycles" in r)
    # default-path regression: lenet keeps the paper's numbers exactly
    rep = network.lenet().perf_report()
    assert rep["gops_paper"] == pytest.approx(0.224, rel=1e-2)


# ---------------------------------------------------------------------------
# Acceptance: a conv layer larger than the VMEM budget streams through
# halo'd spatial tiles, bit-exact vs the oracle
# ---------------------------------------------------------------------------


def test_large_map_layer_exceeds_budget_and_runs_tiled():
    """512×512×64 → 64, batch 4, SAME: the whole-map working set exceeds
    the VMEM budget; the planned tiled kernel is bit-exact vs ref."""
    whole = plan_tiles(512, 512, 64, 64, stride=1, padding="SAME",
                       in_bytes=1, out_bytes=4, vmem_budget=None)
    assert whole.working_set_bytes > banking.VMEM_BYTES   # seed couldn't fit
    p = plan_tiles(512, 512, 64, 64, stride=1, padding="SAME",
                   in_bytes=1, out_bytes=4)
    assert p.tiled and p.fits_vmem
    x, w = _i8(4, 512, 512, 64), _i8(3, 3, 64, 64)
    got = conv2d_ws(x, w, stride=1, padding="SAME",
                    cin_banks=p.cin_banks, kout_banks=p.kout_banks,
                    h_tile=p.h_tile, w_tile=p.w_tile, interpret=True)
    want = ref.conv2d_ref_int8(x, w, stride=1, padding="SAME")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
