"""Pipeline parallelism: GPipe schedule == sequential execution (fwd + bwd),
on a 4-stage debug mesh in a subprocess (fake devices must not leak)."""

import os
import subprocess
import sys
import textwrap

from repro.distributed.pipeline import bubble_fraction

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_bubble_fraction():
    assert bubble_fraction(1, 8) == 0.0
    assert abs(bubble_fraction(4, 4) - 3 / 7) < 1e-9
    assert bubble_fraction(4, 28) < 0.1    # enough microbatches amortize


def test_pipeline_matches_sequential():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline_apply
        from repro.distributed.sharding import use_mesh

        mesh = jax.make_mesh((4,), ("stage",), devices=jax.devices()[:4])
        rng = np.random.default_rng(0)
        D = 16
        n_stages, n_micro, B = 4, 8, 32
        params = {"w": jnp.asarray(rng.normal(size=(n_stages, D, D)) * 0.3,
                                   jnp.float32),
                  "b": jnp.asarray(rng.normal(size=(n_stages, D)) * 0.1,
                                   jnp.float32)}
        x = jnp.asarray(rng.normal(size=(B, D)), jnp.float32)

        def stage_fn(p, h):
            return jnp.tanh(h @ p["w"] + p["b"])

        def sequential(params, x):
            h = x
            for s in range(n_stages):
                h = stage_fn(jax.tree.map(lambda t: t[s], params), h)
            return h

        with use_mesh(mesh):
            y_pipe = pipeline_apply(stage_fn, params, x, mesh=mesh,
                                    axis="stage", n_micro=n_micro)
        y_seq = sequential(params, x)
        np.testing.assert_allclose(np.asarray(y_pipe), np.asarray(y_seq),
                                   rtol=2e-5, atol=2e-5)

        # gradients flow through the schedule identically
        def loss_pipe(p):
            return jnp.sum(jnp.square(pipeline_apply(
                stage_fn, p, x, mesh=mesh, axis="stage",
                n_micro=n_micro)))

        def loss_seq(p):
            return jnp.sum(jnp.square(sequential(p, x)))

        with use_mesh(mesh):
            g1 = jax.grad(loss_pipe)(params)
        g2 = jax.grad(loss_seq)(params)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-4)
        print("PIPELINE_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, cwd=REPO, timeout=560)
    assert "PIPELINE_OK" in r.stdout, r.stdout[-1500:] + r.stderr[-4000:]
