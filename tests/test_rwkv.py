"""RWKV6: the chunk-parallel wkv6 must equal the sequential recurrence
(including carried state), and decode must continue prefill exactly."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.layers.rwkv import wkv6_chunked, wkv6_recurrent

RNG = np.random.default_rng(11)


def _inputs(b=2, s=64, h=2, n=8):
    r = jnp.asarray(RNG.normal(size=(b, s, h, n)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, h, n)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, h, n)), jnp.float32)
    # log-decay: negative, spanning mild to strong decay
    logw = -jnp.exp(jnp.asarray(RNG.normal(size=(b, s, h, n)), jnp.float32))
    u = jnp.asarray(RNG.normal(size=(h, n)), jnp.float32)
    return r, k, v, logw, u


@pytest.mark.parametrize("chunk", [4, 16, 32, 64])
def test_chunked_equals_recurrent(chunk):
    r, k, v, logw, u = _inputs()
    o1, s1 = wkv6_recurrent(r, k, v, logw, u)
    o2, s2 = wkv6_chunked(r, k, v, logw, u, chunk=chunk)
    np.testing.assert_allclose(o1, o2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s1, s2, rtol=1e-4, atol=1e-4)


def test_carried_state():
    r, k, v, logw, u = _inputs(s=32)
    S0 = jnp.asarray(RNG.normal(size=(2, 2, 8, 8)), jnp.float32)
    o1, s1 = wkv6_recurrent(r, k, v, logw, u, S0=S0)
    o2, s2 = wkv6_chunked(r, k, v, logw, u, S0=S0, chunk=8)
    np.testing.assert_allclose(o1, o2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s1, s2, rtol=1e-4, atol=1e-4)


def test_split_sequence_continuity():
    """Processing [0:32] then [32:64] with the carried state == full pass."""
    r, k, v, logw, u = _inputs(s=64)
    o_full, s_full = wkv6_chunked(r, k, v, logw, u, chunk=16)
    o_a, s_a = wkv6_chunked(r[:, :32], k[:, :32], v[:, :32], logw[:, :32],
                            u, chunk=16)
    o_b, s_b = wkv6_chunked(r[:, 32:], k[:, 32:], v[:, 32:], logw[:, 32:],
                            u, S0=s_a, chunk=16)
    np.testing.assert_allclose(o_full, jnp.concatenate([o_a, o_b], 1),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s_full, s_b, rtol=1e-4, atol=1e-4)


def test_strong_decay_is_stable():
    """Deep decays (logP very negative) must not produce inf/nan — the
    chunked form never exponentiates a positive number."""
    r, k, v, logw, u = _inputs(s=64)
    logw = logw * 50.0     # extreme decay
    o, s = wkv6_chunked(r, k, v, logw, u, chunk=32)
    assert bool(jnp.all(jnp.isfinite(o)))
    assert bool(jnp.all(jnp.isfinite(s)))


def test_decay_actually_decays():
    """With strong decay, early tokens must not influence late outputs."""
    r, k, v, logw, u = _inputs(s=32)
    strong = logw * 100.0
    o1, _ = wkv6_chunked(r, k, v, strong, u, chunk=8)
    k2 = k.at[:, :8].set(100.0)
    o2, _ = wkv6_chunked(r, k2, v, strong, u, chunk=8)
    np.testing.assert_allclose(o1[:, 16:], o2[:, 16:], rtol=1e-4, atol=1e-4)
