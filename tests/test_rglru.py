"""RG-LRU: associative scan vs sequential reference; conv1d state
continuity; decode continues prefill."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduce_config
from repro.layers.common import materialize
from repro.layers.rglru import (RGLRUState, apply_rglru, causal_conv1d,
                                rglru_scan, rglru_specs)

RNG = np.random.default_rng(5)


def _cfg():
    return reduce_config(get_config("recurrentgemma_9b"))


def _params(cfg):
    return materialize(rglru_specs(cfg), jax.random.PRNGKey(0))


def test_scan_matches_sequential():
    cfg = _cfg()
    params = _params(cfg)
    u = jnp.asarray(RNG.normal(size=(2, 16, cfg.rnn_width)), jnp.float32)
    h_par = rglru_scan(params, u)

    # sequential reference
    from repro.layers.rglru import _gates
    log_a, b = _gates(params, u)
    a = jnp.exp(log_a)
    hs = []
    h = jnp.zeros((2, cfg.rnn_width))
    for t in range(16):
        h = a[:, t] * h + b[:, t]
        hs.append(h)
    h_seq = jnp.stack(hs, axis=1)
    np.testing.assert_allclose(h_par, h_seq, rtol=1e-5, atol=1e-5)


def test_conv1d_prefix_continuity():
    cfg = _cfg()
    w = jnp.asarray(RNG.normal(size=(4, cfg.rnn_width)), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(cfg.rnn_width,)), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(2, 24, cfg.rnn_width)), jnp.float32)
    full = causal_conv1d(x, w, b)
    a = causal_conv1d(x[:, :16], w, b)
    bpart = causal_conv1d(x[:, 16:], w, b, prefix=x[:, 13:16])
    np.testing.assert_allclose(full, jnp.concatenate([a, bpart], 1),
                               rtol=1e-5, atol=1e-5)


def test_block_decode_continues_prefill():
    """apply_rglru over S tokens == apply over S-1 then decode 1 step."""
    cfg = _cfg()
    params = _params(cfg)
    x = jnp.asarray(RNG.normal(size=(2, 12, cfg.d_model)), jnp.float32)
    zero = RGLRUState(
        conv=jnp.zeros((2, cfg.conv1d_width - 1, cfg.rnn_width)),
        h=jnp.zeros((2, cfg.rnn_width)))
    y_full, st_full = apply_rglru(params, x, cfg, state=zero)
    y_a, st_a = apply_rglru(params, x[:, :11], cfg, state=zero)
    y_b, st_b = apply_rglru(params, x[:, 11:12], cfg, state=st_a)
    np.testing.assert_allclose(y_full[:, 11:], y_b, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(st_full.h, st_b.h, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(st_full.conv, st_b.conv, rtol=1e-5, atol=1e-5)


def test_stability_bound():
    """|a_t| < 1 ⇒ the recurrence cannot blow up; h stays bounded for
    bounded inputs."""
    cfg = _cfg()
    params = _params(cfg)
    u = jnp.asarray(10 * RNG.normal(size=(1, 256, cfg.rnn_width)), jnp.float32)
    h = rglru_scan(params, u)
    assert bool(jnp.all(jnp.isfinite(h)))
