"""conv2d_ws Pallas kernel vs the pure-jnp oracle: shape/dtype sweeps,
banking variants, int8/wrap8 datapaths, bias preload."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.conv2d_ws import conv2d_ws

RNG = np.random.default_rng(42)


def _f32(*shape):
    return jnp.asarray(RNG.normal(size=shape), jnp.float32)


def _i8(*shape):
    return jnp.asarray(RNG.integers(-128, 128, size=shape), jnp.int8)


@pytest.mark.parametrize("n,h,w,c,k,kh", [
    (1, 8, 8, 4, 4, 3),
    (2, 16, 12, 8, 8, 3),
    (1, 224, 224, 8, 8, 3),          # the paper's §5.2 workload
    (2, 10, 10, 16, 4, 1),           # 1×1 conv (≡ GEMM)
    (1, 9, 9, 4, 8, 5),              # 5×5 kernel
])
def test_float_matches_oracle(n, h, w, c, k, kh):
    x, wgt, b = _f32(n, h, w, c), _f32(kh, kh, c, k), _f32(k)
    got = ops.conv2d(x, wgt, b)
    want = ref.conv2d_ref(x, wgt, b)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("banks", [(1, 1), (2, 2), (4, 4), (4, 1), (1, 4),
                                   (8, 8)])
def test_banking_invariance(banks):
    """Any bank decomposition computes the same convolution (the paper's
    4-way split is a dataflow choice, not a semantic one)."""
    cb, kb = banks
    x, wgt, b = _f32(1, 12, 12, 8), _f32(3, 3, 8, 8), _f32(8)
    got = conv2d_ws(x, wgt, b, cin_banks=cb, kout_banks=kb, interpret=True)
    want = ref.conv2d_ref(x, wgt, b)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_divisibility_enforced():
    x, wgt = _f32(1, 8, 8, 6), _f32(3, 3, 6, 8)   # C=6 not divisible by 4
    with pytest.raises(AssertionError):
        conv2d_ws(x, wgt, interpret=True)


@pytest.mark.parametrize("c,k", [(4, 4), (8, 8), (16, 4)])
def test_int8_exact(c, k):
    x, wgt = _i8(1, 10, 10, c), _i8(3, 3, c, k)
    b = jnp.asarray(RNG.integers(-1000, 1000, size=(k,)), jnp.int32)
    got = ops.conv2d(x, wgt, b)
    want = ref.conv2d_ref_int8(x, wgt, b)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(got, want)


def test_wrap8_bit_faithful():
    """The Fig. 6 waveform mode: psums wrap in 8 bits."""
    x, wgt = _i8(1, 8, 8, 8), _i8(3, 3, 8, 4)
    got = ops.conv2d(x, wgt, wrap8=True)
    want = ref.conv2d_ref_wrap8(x, wgt)
    assert got.dtype == jnp.int8
    np.testing.assert_array_equal(got, want)


def test_bias_preload_equals_post_add():
    """M5: preloading bias into the accumulator == adding bias after."""
    x, wgt, b = _f32(1, 10, 10, 4), _f32(3, 3, 4, 4), _f32(4)
    with_bias = ops.conv2d(x, wgt, b)
    without = ops.conv2d(x, wgt, None)
    np.testing.assert_allclose(with_bias, without + b, rtol=1e-5, atol=1e-5)


def test_requantized_output():
    x, wgt = _i8(1, 8, 8, 4), _i8(3, 3, 4, 4)
    scale = jnp.float32(1e-3)
    got = ops.conv2d(x, wgt, out_scale=scale)
    assert got.dtype == jnp.int8
    acc = ref.conv2d_ref_int8(x, wgt)
    want = jnp.clip(jnp.round(acc.astype(jnp.float32) * scale),
                    -128, 127).astype(jnp.int8)
    np.testing.assert_array_equal(got, want)
