"""conv2d_ws Pallas kernel vs the pure-jnp oracle: shape/dtype sweeps,
banking variants, int8/wrap8 datapaths, bias preload, stride/padding
generality, and the fused ReLU → max-pool → requantize epilogue.

Every generalized case is checked against ``lax.conv_general_dilated``
(through kernels/ref.py) — the oracle itself is built on it."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.conv2d_ws import conv2d_ws

RNG = np.random.default_rng(42)


def _f32(*shape):
    return jnp.asarray(RNG.normal(size=shape), jnp.float32)


def _i8(*shape):
    return jnp.asarray(RNG.integers(-128, 128, size=shape), jnp.int8)


@pytest.mark.parametrize("n,h,w,c,k,kh", [
    (1, 8, 8, 4, 4, 3),
    (2, 16, 12, 8, 8, 3),
    (1, 224, 224, 8, 8, 3),          # the paper's §5.2 workload
    (2, 10, 10, 16, 4, 1),           # 1×1 conv (≡ GEMM)
    (1, 9, 9, 4, 8, 5),              # 5×5 kernel
])
def test_float_matches_oracle(n, h, w, c, k, kh):
    x, wgt, b = _f32(n, h, w, c), _f32(kh, kh, c, k), _f32(k)
    got = ops.conv2d(x, wgt, b)
    want = ref.conv2d_ref(x, wgt, b)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("banks", [(1, 1), (2, 2), (4, 4), (4, 1), (1, 4),
                                   (8, 8)])
def test_banking_invariance(banks):
    """Any bank decomposition computes the same convolution (the paper's
    4-way split is a dataflow choice, not a semantic one)."""
    cb, kb = banks
    x, wgt, b = _f32(1, 12, 12, 8), _f32(3, 3, 8, 8), _f32(8)
    got = conv2d_ws(x, wgt, b, cin_banks=cb, kout_banks=kb, interpret=True)
    want = ref.conv2d_ref(x, wgt, b)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_divisibility_enforced():
    x, wgt = _f32(1, 8, 8, 6), _f32(3, 3, 6, 8)   # C=6 not divisible by 4
    with pytest.raises(ValueError, match="banking invariant"):
        conv2d_ws(x, wgt, interpret=True)


@pytest.mark.parametrize("c,k", [(4, 4), (8, 8), (16, 4)])
def test_int8_exact(c, k):
    x, wgt = _i8(1, 10, 10, c), _i8(3, 3, c, k)
    b = jnp.asarray(RNG.integers(-1000, 1000, size=(k,)), jnp.int32)
    got = ops.conv2d(x, wgt, b)
    want = ref.conv2d_ref_int8(x, wgt, b)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(got, want)


def test_wrap8_bit_faithful():
    """The Fig. 6 waveform mode: psums wrap in 8 bits."""
    x, wgt = _i8(1, 8, 8, 8), _i8(3, 3, 8, 4)
    got = ops.conv2d(x, wgt, wrap8=True)
    want = ref.conv2d_ref_wrap8(x, wgt)
    assert got.dtype == jnp.int8
    np.testing.assert_array_equal(got, want)
    # the wrap path has no requantize stage: combining it with out_scale
    # is a loud contract violation, not a silent drop
    with pytest.raises(ValueError, match="mutually exclusive"):
        ops.conv2d(x, wgt, wrap8=True, out_scale=jnp.float32(1e-3))


def test_bias_preload_equals_post_add():
    """M5: preloading bias into the accumulator == adding bias after."""
    x, wgt, b = _f32(1, 10, 10, 4), _f32(3, 3, 4, 4), _f32(4)
    with_bias = ops.conv2d(x, wgt, b)
    without = ops.conv2d(x, wgt, None)
    np.testing.assert_allclose(with_bias, without + b, rtol=1e-5, atol=1e-5)


def test_requantized_output():
    x, wgt = _i8(1, 8, 8, 4), _i8(3, 3, 4, 4)
    scale = jnp.float32(1e-3)
    got = ops.conv2d(x, wgt, out_scale=scale)
    assert got.dtype == jnp.int8
    acc = ref.conv2d_ref_int8(x, wgt)
    want = jnp.clip(jnp.round(acc.astype(jnp.float32) * scale),
                    -128, 127).astype(jnp.int8)
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# Generalized conv: stride / padding / fused epilogue
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("padding", ["VALID", "SAME"])
def test_stride_padding_matches_lax(stride, padding):
    x, wgt, b = _f32(2, 13, 11, 8), _f32(3, 3, 8, 4), _f32(4)
    got = ops.conv2d(x, wgt, b, stride=stride, padding=padding)
    pad = ref.normalize_padding(padding, 3, 3, stride, 13, 11)
    want = jax.lax.conv_general_dilated(
        x, wgt, window_strides=(stride, stride), padding=pad,
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + b
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_explicit_padding():
    x, wgt = _f32(1, 9, 9, 4), _f32(3, 3, 4, 4)
    got = ops.conv2d(x, wgt, padding=((2, 1), (0, 2)))
    want = jax.lax.conv_general_dilated(
        x, wgt, window_strides=(1, 1), padding=((2, 1), (0, 2)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("stride,padding", [(1, "SAME"), (2, "SAME"),
                                            (1, "VALID")])
def test_fused_relu_pool_epilogue(stride, padding):
    """ReLU + 2×2 max-pool fused in the kernel == lax conv + post ops."""
    x, wgt, b = _f32(1, 12, 14, 4), _f32(3, 3, 4, 8), _f32(8)
    got = ops.conv2d(x, wgt, b, stride=stride, padding=padding,
                     relu=True, pool=True)
    conv = jax.lax.conv_general_dilated(
        x, wgt, window_strides=(stride, stride),
        padding=ref.normalize_padding(padding, 3, 3, stride, 12, 14),
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + b
    want = ref.maxpool2d_ref(jnp.maximum(conv, 0))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_pool_floor_semantics_odd_output():
    """Odd conv outputs drop the trailing row/col (floor), like the oracle."""
    x, wgt = _f32(1, 9, 9, 4), _f32(3, 3, 4, 4)     # VALID → 7×7 conv out
    got = ops.conv2d(x, wgt, pool=True)
    want = ref.maxpool2d_ref(ref.conv2d_ref(x, wgt))
    assert got.shape == (1, 3, 3, 4)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("per_channel", [False, True])
def test_int8_fused_epilogue_exact(per_channel):
    """The production path: int8 in, fused ReLU→pool→requantize, int8 out —
    bit-exact vs the int32 oracle chain."""
    x, wgt = _i8(1, 12, 12, 8), _i8(3, 3, 8, 8)
    b = jnp.asarray(RNG.integers(-500, 500, size=(8,)), jnp.int32)
    scale = (jnp.asarray(RNG.uniform(5e-4, 2e-3, size=(8,)), jnp.float32)
             if per_channel else jnp.float32(1e-3))
    got = ops.conv2d(x, wgt, b, stride=2, padding="SAME", relu=True,
                     pool=True, out_scale=scale)
    want = ref.conv2d_epilogue_ref(x, wgt, b, stride=2, padding="SAME",
                                   relu=True, pool=True, out_scale=scale)
    assert got.dtype == jnp.int8
    np.testing.assert_array_equal(got, want)


def test_float_out_scale_requantizes():
    """Regression: float inputs with out_scale used to silently drop the
    requantize (f32 out while the ref path returned int8).  The fused
    epilogue now covers the float accumulator path too — integer-valued
    float inputs make both accumulations exact, so the comparison is
    bit-strict."""
    x = jnp.asarray(RNG.integers(-8, 8, (1, 10, 10, 4)), jnp.float32)
    wgt = jnp.asarray(RNG.integers(-4, 4, (3, 3, 4, 4)), jnp.float32)
    b = jnp.asarray(RNG.integers(-10, 10, (4,)), jnp.float32)
    scale = jnp.float32(0.05)
    got = ops.conv2d(x, wgt, b, relu=True, out_scale=scale)
    want = ref.conv2d_epilogue_ref(x, wgt, b, relu=True, out_scale=scale)
    assert got.dtype == jnp.int8 and want.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_int8_stride2_same_exact():
    x, wgt = _i8(2, 11, 11, 4), _i8(3, 3, 4, 8)
    got = ops.conv2d(x, wgt, stride=2, padding="SAME")
    want = ref.conv2d_ref_int8(x, wgt, stride=2, padding="SAME")
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(got, want)
