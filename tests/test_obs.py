"""Tests for the obs telemetry subsystem (trace spans, metrics,
per-layer profiling, drift detection).

Every test that enables obs restores the disabled default and resets the
global sinks (the autouse fixture) — the tier-1 suite must never see
leaked spans or metric counts.
"""

import json

import numpy as np
import pytest

from repro import obs
from repro.obs.metrics import Histogram, MetricsRegistry, default_buckets
from repro.obs.profile import (DEFAULT_DRIFT_BAND, DriftDetector,
                               LayerProfile, profile_network)
from repro.obs.trace import NOOP_SPAN, Tracer


@pytest.fixture(autouse=True)
def _obs_clean():
    """Disabled-by-default in, disabled-and-empty out."""
    obs.disable()
    obs.reset()
    yield
    obs.disable()
    obs.reset()


# -- disabled-by-default no-op contract -------------------------------------

def test_disabled_by_default_span_is_shared_noop():
    assert not obs.enabled()
    s1 = obs.span("anything", key="val")
    s2 = obs.span("else")
    assert s1 is NOOP_SPAN and s2 is NOOP_SPAN   # no per-call allocation
    with s1:
        with s2:
            pass
    obs.instant("mark", x=1)
    assert len(obs.tracer) == 0                  # nothing recorded


def test_enable_disable_roundtrip():
    obs.enable()
    with obs.span("on"):
        pass
    assert len(obs.tracer) == 1
    obs.disable()
    with obs.span("off"):
        pass
    assert len(obs.tracer) == 1                  # disabled path records 0


# -- span nesting + exception safety ----------------------------------------

def test_span_nesting_records_parentage():
    obs.enable()
    with obs.span("outer"):
        with obs.span("inner"):
            pass
    evs = {e["name"]: e for e in obs.tracer.events()}
    assert set(evs) == {"outer", "inner"}
    assert evs["inner"]["args"]["parent"] == "outer"
    assert "args" not in evs["outer"] or "parent" not in evs["outer"]["args"]
    # inner is contained in outer on the timeline
    assert evs["inner"]["ts"] >= evs["outer"]["ts"]
    assert (evs["inner"]["ts"] + evs["inner"]["dur"]
            <= evs["outer"]["ts"] + evs["outer"]["dur"] + 1e-6)


def test_span_exception_recorded_and_propagated():
    obs.enable()
    with pytest.raises(ValueError):
        with obs.span("outer"):
            with obs.span("boom"):
                raise ValueError("expected")
    evs = {e["name"]: e for e in obs.tracer.events()}
    # every span the exception propagated through carries the error tag
    assert evs["boom"]["args"]["error"] == "ValueError"
    assert evs["outer"]["args"]["error"] == "ValueError"
    # the stack unwound fully: a new span nests at top level again
    with obs.span("after"):
        pass
    after = [e for e in obs.tracer.events() if e["name"] == "after"][0]
    assert "parent" not in after.get("args", {})


def test_chrome_trace_export_is_loadable(tmp_path):
    obs.enable()
    with obs.span("compile", network="lenet"):
        with obs.span("layer:conv1", psums=123):
            pass
    obs.instant("drift", layer="conv1")
    path = obs.tracer.export(str(tmp_path / "trace.json"))
    with open(path) as f:
        doc = json.load(f)
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    assert len(doc["traceEvents"]) == 3
    for ev in doc["traceEvents"]:
        assert ev["ph"] in ("X", "i")
        assert isinstance(ev["ts"], float) and ev["ts"] >= 0
        assert {"name", "pid", "tid"} <= set(ev)
        if ev["ph"] == "X":
            assert ev["dur"] >= 0


def test_tracer_threads_nest_independently():
    import threading
    tr = Tracer()

    def worker(tag):
        with tr.span(f"outer:{tag}"):
            with tr.span(f"inner:{tag}"):
                pass

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    evs = tr.events()
    assert len(evs) == 8
    for e in evs:
        if e["name"].startswith("inner:"):
            tag = e["name"].split(":")[1]
            assert e["args"]["parent"] == f"outer:{tag}"


# -- metrics ----------------------------------------------------------------

def test_counter_gauge_reset_contract():
    reg = MetricsRegistry()
    c = reg.counter("req")
    c.inc()
    c.inc(5)
    assert c.value == 6
    g = reg.gauge("fill")
    g.set(0.75)
    assert g.value == 0.75
    assert reg.counter("req") is c               # get-or-create idempotent
    with pytest.raises(TypeError):
        reg.gauge("req")                         # type-checked
    reg.reset()
    assert c.value == 0 and g.value is None
    assert reg.get("req") is c                   # reset keeps registration


def test_histogram_percentiles_vs_numpy():
    rng = np.random.default_rng(0)
    samples = rng.lognormal(mean=5.0, sigma=1.5, size=5000)
    h = Histogram("lat_us")
    h.observe_many(samples)
    assert h.count == len(samples)
    assert h.sum == pytest.approx(samples.sum(), rel=1e-9)
    for p in (50, 90, 99):
        exact = float(np.percentile(samples, p))
        est = h.percentile(p)
        # interpolated fixed-bucket estimate: error bounded by the bucket
        # ratio (~12% at 20 buckets/decade), tested with headroom
        assert abs(est - exact) / exact < 0.15, (p, est, exact)
    s = h.summary()
    assert s["min"] == pytest.approx(samples.min())
    assert s["max"] == pytest.approx(samples.max())


def test_histogram_edge_cases():
    h = Histogram("h")
    assert h.percentile(50) == 0.0               # empty
    h.observe(42.0)
    assert h.percentile(0) == pytest.approx(42.0)
    assert h.percentile(100) == pytest.approx(42.0)
    big = Histogram("big", bounds=[1.0, 2.0])
    big.observe(1e9)                             # overflow bucket
    assert big.percentile(99) == pytest.approx(1e9)  # clamped to max
    with pytest.raises(ValueError):
        Histogram("bad", bounds=[2.0, 1.0])
    with pytest.raises(ValueError):
        h.percentile(101)


def test_default_buckets_cover_and_ascend():
    b = default_buckets()
    assert b[0] == pytest.approx(1.0)
    assert b[-1] >= 1e8
    assert all(y > x for x, y in zip(b, b[1:]))


def test_registry_jsonl_export(tmp_path):
    reg = MetricsRegistry()
    reg.counter("a").inc(3)
    reg.histogram("b").observe(10.0)
    path = reg.export_jsonl(str(tmp_path / "m.jsonl"))
    lines = [json.loads(ln) for ln in open(path)]
    assert [d["name"] for d in lines] == ["a", "b"]
    assert lines[0]["value"] == 3
    assert lines[1]["type"] == "histogram" and lines[1]["count"] == 1
    assert all("exported_at" in d for d in lines)


# -- profiler + drift --------------------------------------------------------

def _lenet_qnet():
    from repro.core import network
    rng = np.random.default_rng(0)
    plan = network.lenet(input_shape=(12, 12, 1))
    params = plan.init_params(rng)
    x = np.asarray(rng.normal(size=(1, *plan.input_shape)), np.float32)
    return network.quantize_network(plan, params, x), x


def test_profile_layer_set_matches_plan_topology():
    qnet, x = _lenet_qnet()
    prof = profile_network(qnet, x, warmup=0)
    plan = qnet.plan
    assert len(prof.records) == len(plan.layers)
    assert prof.layer_names == list(plan.node_names())
    assert not prof.calibrated
    for i, r in enumerate(prof.records):
        assert r.index == i
        assert r.wall_us > 0
        assert r.kind == plan.layers[i].kind
    # conv layers carry a prediction and achieved GOPS
    convs = [r for r in prof.records if r.kind in ("conv", "conv_transpose")]
    assert convs and all(r.predicted_us and r.predicted_us > 0
                         and r.gops > 0 for r in convs)


def test_profile_emits_layer_spans_when_enabled():
    qnet, x = _lenet_qnet()
    obs.enable()
    prof = profile_network(qnet, x, warmup=0)
    names = {e["name"] for e in obs.tracer.events()}
    assert "profile" in names
    for ln in prof.layer_names:
        assert f"layer:{ln}" in names
    # per-layer wall times landed in the profile histogram too
    h = obs.metrics.get(f"profile.layer_us.{qnet.plan.name}")
    assert h is not None and h.count == len(prof.records)


def test_drift_detector_fires_on_miscalibrated_table():
    from repro.core.calibration import CalibrationTable
    qnet, x = _lenet_qnet()
    # an absurd table: claims every compute cycle costs 1e6 real cycles,
    # so predictions are ~6 orders too slow — every priced layer drifts
    # below the band (machine much faster than the "calibration")
    bad = CalibrationTable(compute_factor=1e6, clock_hz=112e6)
    det = DriftDetector()
    prof = profile_network(qnet, x, warmup=0, calib=bad, drift=det)
    assert prof.calibrated
    priced = [r for r in prof.records if r.predicted_us]
    assert priced
    assert len(prof.drift) == len(priced)
    for ev in prof.drift:
        assert ev.ratio < DEFAULT_DRIFT_BAND[0]
        assert ev.band == DEFAULT_DRIFT_BAND
    assert obs.metrics.counter("obs.drift.events").value == len(prof.drift)


def test_drift_detector_band_and_floor():
    rec = LayerProfile(index=0, name="c1", kind="conv", wall_us=100.0,
                       psums=1000, batch=1, gops=0.01, predicted_us=110.0,
                       pipelined=False, calibrated=True)
    assert DriftDetector().check([rec]) == []        # ratio ~0.9: in band
    fast = LayerProfile(index=1, name="c2", kind="conv", wall_us=10.0,
                        psums=1000, batch=1, gops=0.1, predicted_us=110.0,
                        pipelined=False, calibrated=True)
    assert len(DriftDetector().check([fast])) == 1   # ratio ~0.09: drift
    # the noise floor suppresses tiny layers
    assert DriftDetector(min_wall_us=50.0).check([fast]) == []
    free = LayerProfile(index=2, name="pool", kind="maxpool", wall_us=5.0,
                        psums=0, batch=1, gops=0.0, predicted_us=None,
                        pipelined=None, calibrated=True)
    assert DriftDetector().check([free]) == []       # unpriced: no signal
    with pytest.raises(ValueError):
        DriftDetector(band=(2.0, 0.5))


# -- engine integration ------------------------------------------------------

def test_engine_stats_and_percentiles():
    from repro.serving.engine import ConvNetEngine
    qnet, _ = _lenet_qnet()
    eng = ConvNetEngine(qnet, batch=2, backend="pallas")
    rng = np.random.default_rng(1)
    imgs = rng.normal(size=(3, *qnet.plan.input_shape)).astype(np.float32)
    eng.submit(imgs)
    assert eng.stats == {"requests": 3, "batches": 2, "padded": 1}
    pct = eng.latency_percentiles()
    assert pct["count"] == 3
    assert 0 < pct["p50"] <= pct["p90"] <= pct["p99"]
    # obs disabled: no spans recorded, no profile taken
    assert len(obs.tracer) == 0
    assert eng.layer_profile is None


def test_engine_obs_enabled_profiles_first_batch():
    from repro.serving.engine import ConvNetEngine
    qnet, _ = _lenet_qnet()
    obs.enable()
    eng = ConvNetEngine(qnet, batch=2, backend="pallas")
    rng = np.random.default_rng(1)
    imgs = rng.normal(size=(2, *qnet.plan.input_shape)).astype(np.float32)
    eng.submit(imgs)
    assert eng.layer_profile is not None
    assert eng.layer_profile.layer_names == list(qnet.plan.node_names())
    assert eng.drift_events == ()            # no calib → no drift check
    names = [e["name"] for e in obs.tracer.events()]
    assert "engine.compile" in names and "engine.batch" in names
    # obs off → same engine records nothing more
    obs.disable()
    n = len(obs.tracer)
    eng.submit(imgs)
    assert len(obs.tracer) == n


def test_obs_dump_writes_both_artifacts(tmp_path):
    assert obs.dump(str(tmp_path)) is None       # disabled → nothing
    obs.enable()
    with obs.span("s"):
        pass
    obs.metrics.counter("c").inc()
    paths = obs.dump(str(tmp_path), prefix="t")
    trace = json.load(open(paths["trace"]))
    assert trace["traceEvents"]
    lines = [json.loads(ln) for ln in open(paths["metrics"])]
    assert any(d["name"] == "c" for d in lines)
