"""Batched serving engine: outputs must match unbatched greedy decoding."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduce_config
from repro.layers.common import materialize
from repro.models import lm
from repro.serving.engine import Request, ServingEngine
from repro.serving.serve_step import greedy_sample


def _reference_generate(params, cfg, prompt, n_new, max_seq):
    """Unbatched greedy generation via prefill + decode."""
    batch = {"tokens": jnp.asarray(prompt, jnp.int32)[None]}
    logits, cache = lm.prefill(params, batch, cfg, cache_len=max_seq)
    toks = [int(greedy_sample(logits)[0])]
    pos = len(prompt)
    for _ in range(n_new - 1):
        lg, cache = lm.decode_step(
            params, cfg, token=jnp.asarray([toks[-1]], jnp.int32),
            pos=jnp.asarray([pos], jnp.int32), cache=cache)
        toks.append(int(greedy_sample(lg)[0]))
        pos += 1
    return toks


def test_engine_matches_unbatched_decode():
    cfg = reduce_config(get_config("llama3p2_3b"))
    params = materialize(lm.param_specs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    max_seq = 64

    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 9, 7)]
    n_new = 6
    engine = ServingEngine(cfg, params, slots=2, max_seq=max_seq)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=n_new)
            for i, p in enumerate(prompts)]
    done = engine.run(list(reqs))
    assert len(done) == 3

    for req in reqs:
        want = _reference_generate(params, cfg, req.prompt, n_new, max_seq)
        assert req.output == want, (req.uid, req.output, want)


def test_engine_slot_reuse():
    """More requests than slots: slots must be recycled."""
    cfg = reduce_config(get_config("llama3p2_3b"))
    params = materialize(lm.param_specs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    engine = ServingEngine(cfg, params, slots=2, max_seq=32)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, size=4)
                    .astype(np.int32), max_new_tokens=3) for i in range(5)]
    done = engine.run(list(reqs))
    assert len(done) == 5
    assert all(len(r.output) == 3 for r in reqs)


class _BookkeepingEngine(ServingEngine):
    """ServingEngine with the model swapped out for counters, so run()'s
    bookkeeping cost is measurable in isolation."""

    def __init__(self, slots: int):
        self.slots = slots
        self.max_seq = 1 << 30
        self.active = [None] * slots
        self.pos = np.zeros((slots,), np.int32)
        self.last_token = np.zeros((slots,), np.int32)

    def admit(self, req: Request) -> bool:
        free = self._free_slots()
        if not free:
            return False
        req.output.append(0)
        self.active[free[0]] = req
        return True

    def step(self):
        finished = []
        for i, req in enumerate(self.active):
            if req is None:
                continue
            req.output.append(1)
            if len(req.output) >= req.max_new_tokens:
                req.done = True
                self.active[i] = None
                finished.append(req)
        return finished


def test_run_bookkeeping_is_single_pass():
    """Regression: run() used ``list.pop(0)`` on pending plus a full
    rescan-and-rebuild of the request list every step — O(steps×requests)
    bookkeeping that took minutes at this size.  Finished requests must
    move out exactly once."""
    import time
    n = 20_000
    reqs = [Request(uid=i, prompt=np.zeros(1, np.int32), max_new_tokens=2)
            for i in range(n)]
    engine = _BookkeepingEngine(slots=4)
    t0 = time.perf_counter()
    done = engine.run(list(reqs))
    wall = time.perf_counter() - t0
    assert len(done) == n
    assert len({r.uid for r in done}) == n           # no dupes, no drops
    assert all(r.done and len(r.output) == 2 for r in reqs)
    # deque + single-pass handoff finishes in well under a second; the
    # quadratic rescan needed minutes — generous CI margin in between
    assert wall < 10.0, f"run() bookkeeping took {wall:.1f}s for {n} reqs"
