"""Checkpoint save/restore equality, atomicity, GC, async, and ELASTIC
resharding (restore onto a different device layout)."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import Checkpointer


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)),
                   "b": jnp.arange(16, dtype=jnp.float32)},
        "opt": {"m": {"w": jnp.zeros((8, 16)), "b": jnp.zeros(16)}},
        "step": jnp.asarray(3, jnp.int32),
    }


def test_roundtrip_bitwise(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = _state()
    ck.save(3, state, extra={"cursor": 3})
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state)
    restored, extra = ck.restore(like)
    assert extra == {"cursor": 3}
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _state(s))
    assert ck.latest_step() == 4
    assert ck.all_steps() == [3, 4]          # GC kept only 2


def test_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(7, _state(), blocking=False)
    ck.wait()
    assert ck.latest_step() == 7


def test_shape_mismatch_rejected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _state())
    bad = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                       _state())
    bad["params"]["w"] = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    with pytest.raises(ValueError):
        ck.restore(bad)


def test_elastic_restore_across_meshes(tmp_path):
    """Save under one sharding layout, restore under a different mesh shape
    — the elastic-restart path.  Runs in a subprocess so the fake device
    count never leaks into this test process (per the dry-run rules)."""
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.checkpoint.checkpoint import Checkpointer

        d = {str(tmp_path)!r}
        w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        mesh_a = jax.make_mesh((2, 4), ("data", "model"),
                               devices=jax.devices()[:8])
        wa = jax.device_put(w, NamedSharding(mesh_a, P("data", "model")))
        ck = Checkpointer(d)
        ck.save(5, {{"w": wa}})

        mesh_b = jax.make_mesh((4,), ("data",), devices=jax.devices()[:4])
        sh_b = {{"w": NamedSharding(mesh_b, P("data", None))}}
        like = {{"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}}
        restored, _ = ck.restore(like, shardings=sh_b)
        assert restored["w"].sharding.num_devices == 4
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
        print("ELASTIC_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))))
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr
