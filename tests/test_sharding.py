"""Distribution coherence on a small (2×4) debug mesh, in a subprocess so
the fake-device flag never leaks (smoke tests must see 1 device).

Checks: sharded train step == single-device train step (GSPMD is a
numerics-preserving transform up to reduction order), and the decode step
compiles + runs under the decode sharding rules (sequence-sharded cache).
"""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str) -> str:
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, cwd=REPO, timeout=560)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-4000:]
    return r.stdout


def test_sharded_train_step_matches_single_device():
    out = _run(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_config, reduce_config
        from repro.distributed.sharding import ShardingPlan, use_mesh
        from repro.launch.mesh import make_debug_mesh
        from repro.layers.common import materialize, shape_structs, ParamSpec
        from repro.models import lm
        from repro.optim.adamw import AdamWConfig, opt_state_specs
        from repro.train.train_step import make_train_step, init_state_specs

        cfg = reduce_config(get_config("llama3_8b"))
        sspecs = init_state_specs(cfg)
        state = {
            "params": materialize(sspecs["params"], jax.random.PRNGKey(0)),
            "opt": materialize(sspecs["opt"], jax.random.PRNGKey(1)),
            "step": jnp.zeros((), jnp.int32),
        }
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                    (4, 32)), jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                    (4, 32)), jnp.int32)}
        hp = AdamWConfig(warmup_steps=1, total_steps=10)

        # single device
        ref_step = jax.jit(make_train_step(cfg, hp))
        ref_state, ref_metrics = ref_step(state, batch)

        # sharded
        mesh = make_debug_mesh(2, 4)
        plan = ShardingPlan(mesh=mesh, fsdp=True, mode="train")
        full_specs = {"params": sspecs["params"], "opt": sspecs["opt"],
                      "step": ParamSpec((), (), dtype="int32", init="zeros")}
        st_sh = plan.param_shardings(full_specs)
        b_sh = plan.input_shardings(jax.tree.map(
            lambda t: jax.ShapeDtypeStruct(t.shape, t.dtype), batch))
        with use_mesh(mesh):
            sh_step = jax.jit(make_train_step(cfg, hp, act_rules=plan.acts),
                              in_shardings=(st_sh, b_sh),
                              out_shardings=(st_sh, None))
            state_d = jax.device_put(state, st_sh)
            batch_d = jax.device_put(batch, b_sh)
            new_state, metrics = sh_step(state_d, batch_d)
        np.testing.assert_allclose(float(metrics["loss"]),
                                   float(ref_metrics["loss"]),
                                   rtol=2e-4, atol=2e-4)
        d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))),
            ref_state["params"], jax.device_get(new_state["params"]))
        worst = max(jax.tree.leaves(d))
        assert worst < 5e-3, worst
        print("TRAIN_SHARDED_OK", worst)
    """))
    assert "TRAIN_SHARDED_OK" in out


def test_sharded_decode_step_runs():
    out = _run(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_config, reduce_config
        from repro.distributed.sharding import ShardingPlan, use_mesh
        from repro.launch.mesh import make_debug_mesh
        from repro.layers.common import materialize, shape_structs
        from repro.models import lm
        from repro.serving.serve_step import make_decode_step

        cfg = reduce_config(get_config("llama3_8b"))
        params = materialize(lm.param_specs(cfg), jax.random.PRNGKey(0))
        B, S = 4, 32
        cspecs = lm.cache_specs(cfg, B, S)
        mesh = make_debug_mesh(2, 4)
        plan = ShardingPlan(mesh=mesh, fsdp=False, mode="decode")
        p_sh = plan.param_shardings(lm.param_specs(cfg))
        c_sh = plan.cache_shardings(cspecs)
        with use_mesh(mesh):
            cache = jax.tree.map(
                lambda s, sh: jax.device_put(
                    jnp.zeros(s.shape, jnp.dtype(s.dtype)), sh),
                cspecs, c_sh, is_leaf=lambda x: hasattr(x, "axes"))
            params_d = jax.device_put(params, p_sh)
            step = jax.jit(make_decode_step(cfg, act_rules=plan.acts),
                           donate_argnums=(1,))
            tok = jnp.zeros((B,), jnp.int32)
            pos = jnp.zeros((B,), jnp.int32)
            logits, cache = step(params_d, cache, tok, pos)
            logits2, cache = step(params_d, cache, tok + 1, pos + 1)
        assert logits.shape == (B, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits2)))
        print("DECODE_SHARDED_OK")
    """))
    assert "DECODE_SHARDED_OK" in out
