"""Continuous-batching serving: queue semantics, LRU program cache,
batch routing, and end-to-end engine consistency.

Formation semantics (full / deadline / drain, priority lanes, aging)
are tested against :class:`RequestQueue` directly with an injected fake
clock — pure functions of (queue contents, time), no threads, no
sleeps.  The engine integration tests then exercise the real worker
thread: deadline launches without a drain waiter, LRU evict → recompile
→ bit-exact logits, and ≥4 concurrent submitters with zero dropped or
duplicated responses."""

import threading
from concurrent.futures import Future

import numpy as np
import pytest

from repro import obs
from repro.core import network
from repro.serving.batching import (ContinuousBatchingEngine, ProgramCache,
                                    RequestQueue, ServeRequest)

MS = 1_000_000                           # ns per ms


def _registry():
    return obs.MetricsRegistry()


def _queue(clock, **kw):
    kw.setdefault("deadline_ms", 5.0)
    kw.setdefault("bulk_aging_ms", 50.0)
    return RequestQueue(_registry(), clock=clock, **kw)


class FakeClock:
    def __init__(self):
        self.t = 0

    def __call__(self):
        return self.t


def _req(uid, model="m", priority="interactive", enq=0,
         deadline_ns=5 * MS):
    return ServeRequest(uid=uid, model=model,
                        image=np.zeros((2, 2, 1), np.float32),
                        priority=priority, enqueue_ns=enq,
                        deadline_ns=enq + deadline_ns, future=Future())


# -- formation: full / deadline / drain --------------------------------------

def test_deadline_fires_on_lone_request():
    clk = FakeClock()
    q = _queue(clk)
    q.push_many([_req(0)])
    assert q.form(8) is None                   # young: no launch
    clk.t = 5 * MS - 1
    assert q.form(8) is None                   # still inside deadline
    clk.t = 5 * MS
    fb = q.form(8)
    assert fb is not None and fb.reason == "deadline"
    assert [r.uid for r in fb.requests] == [0]
    assert len(q) == 0


def test_full_batch_fires_before_deadline():
    clk = FakeClock()
    q = _queue(clk)
    q.push_many([_req(i) for i in range(4)])
    fb = q.form(4)                             # t=0: way inside deadline
    assert fb.reason == "full"
    assert [r.uid for r in fb.requests] == [0, 1, 2, 3]


def test_drain_launches_partial_batch():
    clk = FakeClock()
    q = _queue(clk)
    q.push_many([_req(0), _req(1)])
    assert q.form(4) is None                   # not full, not due
    fb = q.form(4, drain=True)
    assert fb.reason == "drain"
    assert [r.uid for r in fb.requests] == [0, 1]


def test_full_model_wins_over_drain_and_takes_only_its_own():
    clk = FakeClock()
    q = _queue(clk)
    q.push_many([_req(0, model="a"), _req(1, model="b"),
                 _req(2, model="b")])
    fb = q.form(2, drain=True)
    assert fb.reason == "full" and fb.model == "b"
    assert [r.uid for r in fb.requests] == [1, 2]
    # model a's request stays queued, FIFO intact
    fb2 = q.form(2, drain=True)
    assert fb2.reason == "drain" and fb2.model == "a"
    assert [r.uid for r in fb2.requests] == [0]


def test_deadline_launches_oldest_requests_model():
    clk = FakeClock()
    q = _queue(clk)
    q.push_many([_req(0, model="a")])
    clk.t = 2 * MS
    q.push_many([_req(1, model="b", enq=clk.t)])
    clk.t = 5 * MS                             # a is due, b is not
    fb = q.form(8)
    assert fb.reason == "deadline" and fb.model == "a"


# -- priority lanes + aging --------------------------------------------------

def test_interactive_preempts_fresh_bulk():
    clk = FakeClock()
    q = _queue(clk)
    q.push_many([_req(0, priority="bulk"), _req(1, priority="bulk")])
    clk.t = 1 * MS
    q.push_many([_req(2, enq=clk.t), _req(3, enq=clk.t)])
    fb = q.form(2, drain=True)
    assert [r.uid for r in fb.requests] == [2, 3]   # interactive first
    fb2 = q.form(2, drain=True)
    assert [r.uid for r in fb2.requests] == [0, 1]  # bulk not dropped


def test_aged_bulk_outranks_newer_interactive():
    """Starvation-free: bulk older than the aging window merges into the
    interactive ordering by ORIGINAL enqueue time, so a steady
    interactive flood cannot hold it off forever."""
    clk = FakeClock()
    q = _queue(clk, bulk_aging_ms=50.0)
    q.push_many([_req(0, priority="bulk")])
    clk.t = 60 * MS                            # bulk is past aging
    q.push_many([_req(1, enq=clk.t), _req(2, enq=clk.t)])
    fb = q.form(2, drain=True)
    assert [r.uid for r in fb.requests] == [0, 1]   # aged bulk leads
    # under the window the same bulk request would have waited
    clk2 = FakeClock()
    q2 = _queue(clk2, bulk_aging_ms=50.0)
    q2.push_many([_req(0, priority="bulk")])
    clk2.t = 10 * MS
    q2.push_many([_req(1, enq=clk2.t), _req(2, enq=clk2.t)])
    fb2 = q2.form(2, drain=True)
    assert [r.uid for r in fb2.requests] == [1, 2]


def test_queue_depth_gauge_and_validation():
    clk = FakeClock()
    reg = _registry()
    q = RequestQueue(reg, deadline_ms=5.0, clock=clk)
    q.push_many([_req(i) for i in range(3)])
    assert reg.gauge("queue.depth").value == 3
    assert reg.gauge("queue.depth.peak").value == 3
    q.form(2, drain=True)
    assert reg.gauge("queue.depth").value == 1
    assert reg.gauge("queue.depth.peak").value == 3   # peak sticks
    with pytest.raises(ValueError):
        q.push_many([_req(9, priority="nope")])
    with pytest.raises(ValueError):
        RequestQueue(_registry(), deadline_ms=0.0, clock=clk)


# -- LRU program cache -------------------------------------------------------

def test_program_cache_lru_eviction_and_counters():
    reg = _registry()
    cache = ProgramCache(2, reg)
    built = []

    def mk(k):
        def build():
            built.append(k)
            return f"prog-{k}"
        return build

    assert cache.get("a", mk("a")) == "prog-a"
    assert cache.get("b", mk("b")) == "prog-b"
    assert cache.get("a", mk("a")) == "prog-a"       # hit refreshes a
    assert cache.get("c", mk("c")) == "prog-c"       # evicts b (LRU)
    assert cache.keys() == ["a", "c"]
    assert cache.get("b", mk("b")) == "prog-b"       # rebuild b
    assert built == ["a", "b", "c", "b"]
    assert reg.counter("cache.hits").value == 1
    assert reg.counter("cache.misses").value == 4
    assert reg.counter("cache.evictions").value == 2
    assert len(cache) == 2
    with pytest.raises(ValueError):
        ProgramCache(0, _registry())


# -- per-batch scheduler routing --------------------------------------------

def test_route_batch_flips_with_formed_size():
    from repro.core.autotune import route_batch
    tune = _TUNES["small"]
    mode1, cores1, cyc1 = route_batch(tune.layers, 1, 8)
    mode8, cores8, cyc8 = route_batch(tune.layers, 8, 8)
    # one image can't batch-shard: the cores must go inside the program
    assert mode1 in ("kout", "spatial")
    assert cores1 == 8
    # a full batch divides compute across every core with no halo tax
    assert mode8 == "batch" and cores8 == 8
    assert cyc8 >= cyc1                        # more images, more cycles
    # the verdict is never worse than forcing either extreme
    from repro.core.autotune import schedule_cycles
    assert cyc1 <= 1 * schedule_cycles(tune.layers, "batch", 1)
    assert cyc8 <= 8 * schedule_cycles(tune.layers, "batch", 8)
    with pytest.raises(ValueError):
        route_batch(tune.layers, 0, 8)
    with pytest.raises(ValueError):
        route_batch(tune.layers, 1, 0)


# -- engine integration ------------------------------------------------------

_QNETS = {}
_TUNES = {}


def _qnet(shape=(12, 12, 1)):
    if shape not in _QNETS:
        rng = np.random.default_rng(0)
        plan = network.lenet(input_shape=shape)
        params = plan.init_params(rng)
        x = np.asarray(rng.normal(size=(1, *shape)), np.float32)
        _QNETS[shape] = network.quantize_network(plan, params, x)
    return _QNETS[shape]


def setup_module(_m):
    from repro.core.autotune import autotune_network
    _TUNES["small"] = autotune_network(network.lenet(input_shape=(12, 12, 1)))


def test_deadline_launch_without_drain_waiter():
    """A lone async request must come back without anyone draining —
    the worker's deadline timeout is what launches it."""
    eng = ContinuousBatchingEngine(batch=8, backend="pallas",
                                   deadline_ms=25.0)
    try:
        eng.add_model(_qnet())
        fut = eng.submit_async(np.zeros((12, 12, 1), np.float32))
        logits = fut.result(timeout=300)
        assert logits.shape == (10,)
        counts = eng.formation_counts()
        assert counts["deadline"] == 1 and counts["full"] == 0
        assert eng.stats == {"requests": 1, "batches": 1, "padded": 7}
        assert eng.metrics.histogram("queue_wait_us").summary()["count"] == 1
    finally:
        eng.close()


def test_lru_evict_recompile_bit_exact():
    """capacity=1 multi-model serving: adding model b evicts a's
    program; the recompile on a's next batch must be observable
    (eviction/miss counters) and bit-exact with a fresh engine."""
    qa, qb = _qnet((12, 12, 1)), _qnet((10, 10, 1))
    rng = np.random.default_rng(7)
    imgs = rng.normal(size=(3, 12, 12, 1)).astype(np.float32)
    eng = ContinuousBatchingEngine(batch=2, backend="pallas",
                                   cache_capacity=1)
    try:
        eng.add_model(qa, name="a")
        eng.add_model(qb, name="b")            # evicts a's program
        assert eng.cache_stats()["evictions"] == 1
        got = eng.submit(imgs, model="a")      # recompile (miss)
        stats = eng.cache_stats()
        assert stats["misses"] == 3 and stats["evictions"] == 2
        assert stats["size"] == 1 and stats["capacity"] == 1
        # admission by unique input shape still finds model b
        out_b = eng.submit(rng.normal(size=(1, 10, 10, 1))
                           .astype(np.float32))
        assert out_b.shape == (1, 10)
    finally:
        eng.close()
    fresh = ContinuousBatchingEngine(batch=2, backend="pallas")
    try:
        fresh.add_model(qa, name="a")
        want = fresh.submit(imgs, model="a")
    finally:
        fresh.close()
    np.testing.assert_array_equal(got, want)


def test_concurrent_submitters_consistent():
    """≥4 threads share one engine; every thread must get exactly its
    own logits back (zero dropped, zero duplicated, zero cross-wired),
    bit-exact with the reference program run row-by-row."""
    import jax.numpy as jnp

    from repro.core.convcore import ConvCoreConfig
    from repro.core.network import make_int8_program
    qnet = _qnet()
    prog = make_int8_program(qnet, ConvCoreConfig(backend="pallas",
                                                  int8=True))
    eng = ContinuousBatchingEngine(batch=4, backend="pallas",
                                   deadline_ms=50.0)
    n_threads, per = 4, 6
    rng = np.random.default_rng(3)
    # distinct images per thread so a cross-wired response is detectable
    images = [rng.normal(size=(per, 12, 12, 1)).astype(np.float32)
              for _ in range(n_threads)]
    results = [None] * n_threads
    errors = []

    def work(t):
        try:
            results[t] = eng.submit(images[t])
        except BaseException as e:             # pragma: no cover
            errors.append((t, e))

    try:
        eng.add_model(qnet)
        threads = [threading.Thread(target=work, args=(t,))
                   for t in range(n_threads)]
        for th in threads:
            th.start()
        for th in threads:
            th.join(timeout=300)
        assert not errors, errors
        assert all(r is not None for r in results)
        for t in range(n_threads):
            assert results[t].shape == (per, 10)
            for i in range(per):
                want = np.asarray(prog(jnp.asarray(images[t][i][None])))[0]
                np.testing.assert_array_equal(results[t][i], want)
        s = eng.stats
        assert s["requests"] == n_threads * per
        # continuous batching mixes threads' requests into shared
        # batches: fewer launches than the per-thread sync floor
        assert s["batches"] <= n_threads * per
        assert eng.latency_percentiles()["count"] == n_threads * per
    finally:
        eng.close()


def test_engine_validation_and_admission_errors():
    eng = ContinuousBatchingEngine(batch=2, backend="pallas")
    try:
        with pytest.raises(ValueError, match="no models"):
            eng.submit_async(np.zeros((12, 12, 1), np.float32))
        eng.add_model(_qnet(), name="m")
        with pytest.raises(ValueError, match="already registered"):
            eng.add_model(_qnet(), name="m")
        with pytest.raises(ValueError, match="unknown model"):
            eng.submit_async(np.zeros((12, 12, 1), np.float32),
                             model="nope")
        with pytest.raises(ValueError, match="input shape"):
            eng.submit_async(np.zeros((9, 9, 1), np.float32), model="m")
        with pytest.raises(ValueError, match="unknown priority"):
            eng.submit_async(np.zeros((12, 12, 1), np.float32),
                             priority="urgent")
        assert eng.models() == ["m"]
    finally:
        eng.close()
    with pytest.raises(ValueError):
        ContinuousBatchingEngine(batch=0)
    with pytest.raises(ValueError):
        ContinuousBatchingEngine(max_inflight=0)


def test_close_drains_queued_work():
    eng = ContinuousBatchingEngine(batch=4, backend="pallas",
                                   deadline_ms=10_000.0)
    eng.add_model(_qnet())
    futs = eng.submit_async(np.zeros((2, 12, 12, 1), np.float32))
    eng.close()                                # must not strand the futures
    for f in futs:
        assert f.result(timeout=60).shape == (10,)
    with pytest.raises(RuntimeError):
        eng.submit_async(np.zeros((12, 12, 1), np.float32))
