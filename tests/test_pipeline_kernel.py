"""The explicit double-buffered DMA conv pipeline (kernels/conv2d_ws_pipe)
and its planner/cost-model contract:

* bit-exactness vs conv2d_ws across stride × padding × epilogue × groups ×
  tiling (deterministic hard cases + a hypothesis sweep), on the int8 AND
  float accumulator paths, whole networks under every scheduler mode;
* VMEM accounting: the ping-pong working set IS the working set
  ``plan_tiles`` already budgets (the ×2 double-buffer term), so the
  ``pipelined`` choice never changes whether a plan fits, and budget
  degradation still yields legal plans, dense and grouped;
* the crossover predictor: §5.2 anchors untouched, depthwise
  ``dma_bound_board`` layers marked profitable, tiny layers left
  sequential, and ``network_report`` pricing consistent both ways.

On a TPU host these tests compile natively (the CI smoke lane);
elsewhere they run in interpret mode.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import banking, network, perfmodel, scheduler
from repro.core.banking import plan_tiles
from repro.core.convcore import (ConvCoreConfig, get_backend,
                                 register_backend)
from repro.kernels import ops, ref
from repro.kernels.conv2d_ws import conv2d_ws
from repro.kernels.conv2d_ws_pipe import conv2d_ws_pipe

RNG = np.random.default_rng(47)

# native Mosaic on TPU (the CI smoke lane), interpret everywhere else —
# same tests, two execution modes
INTERPRET = jax.default_backend() != "tpu"


def _i8(*shape):
    return jnp.asarray(RNG.integers(-128, 128, size=shape), jnp.int8)


def _f32(*shape):
    return jnp.asarray(RNG.normal(size=shape), jnp.float32)


def _both(x, w, b=None, **kw):
    a = conv2d_ws(x, w, b, interpret=INTERPRET, **kw)
    p = conv2d_ws_pipe(x, w, b, interpret=INTERPRET, **kw)
    assert a.dtype == p.dtype and a.shape == p.shape
    np.testing.assert_array_equal(np.asarray(a), np.asarray(p))
    return a


# ---------------------------------------------------------------------------
# Bit-exactness vs the sequential kernel — deterministic hard cases
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("padding", ["VALID", "SAME", ((2, 1), (0, 2))])
def test_pipe_bit_exact_stride_padding(stride, padding):
    x, w = _i8(2, 11, 9, 8), _i8(3, 3, 8, 8)
    b = jnp.asarray(RNG.integers(-500, 500, (8,)), jnp.int32)
    _both(x, w, b, stride=stride, padding=padding,
          cin_banks=2, kout_banks=2)


@pytest.mark.parametrize("groups", [1, 2, 8])
def test_pipe_bit_exact_grouped(groups):
    """Dense, mid-grouped and depthwise (C=K=8, groups=8): the pipelined
    kernel's HBM slices must carry the same per-group channel offsets as
    the sequential BlockSpec index maps."""
    c = k = 8
    x, w = _i8(1, 12, 10, c), _i8(3, 3, c // groups, k)
    cb, kb = ref.grouped_banks(c, k, groups)
    got = _both(x, w, stride=1, padding="SAME", groups=groups,
                cin_banks=cb, kout_banks=kb)
    want = ref.conv2d_ref_int8(x, w, padding="SAME", groups=groups)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pipe_bit_exact_fused_epilogue_requant():
    """ReLU → 2×2 max-pool → requantize, tiled: the epilogue runs on the
    ping-pong output buffer and its store overlaps the next tile."""
    x, w = _i8(2, 16, 16, 8), _i8(3, 3, 8, 16)
    b = jnp.asarray(RNG.integers(-500, 500, (16,)), jnp.int32)
    out = _both(x, w, b, out_scale=0.015, stride=1, padding="SAME",
                relu=True, pool=True, cin_banks=2, kout_banks=4,
                h_tile=8, w_tile=8)
    assert out.dtype == jnp.int8


def test_pipe_bit_exact_float_accumulator():
    """The f32 accumulator path: bitwise equality requires the pipelined
    kernel to accumulate in exactly the sequential order (co-major, then
    the KH×KW taps) — allclose would hide a reordering."""
    x, w, b = _f32(1, 13, 11, 8), _f32(3, 3, 8, 8), _f32(8)
    _both(x, w, b, stride=1, padding="SAME", relu=True,
          cin_banks=2, kout_banks=2, h_tile=4, w_tile=8)


def test_pipe_bit_exact_1x1_pointwise():
    x, w = _i8(1, 9, 9, 16), _i8(1, 1, 16, 16)
    _both(x, w, cin_banks=4, kout_banks=4)


def test_pipe_single_slab_degenerate():
    """cin_banks = kout_banks = 1, one tile: a 1-slab pipeline is pure
    fill + drain — the warm-up/prefetch/drain protocol must not deadlock
    or read a buffer that was never filled."""
    x, w = _i8(1, 6, 6, 4), _i8(3, 3, 4, 4)
    _both(x, w, cin_banks=1, kout_banks=1)


def test_pipe_odd_cin_banks_slot_parity():
    """cin_banks odd (here 3): consecutive grid steps start on OPPOSITE
    ping-pong slots, so any slot math keyed to co alone (instead of the
    global slab index) would clobber the buffer in flight."""
    x, w = _i8(1, 10, 10, 12), _i8(3, 3, 12, 8)
    _both(x, w, cin_banks=3, kout_banks=2, h_tile=4, w_tile=4)


def test_pipe_through_ops_dispatch():
    """ops.conv2d(pipelined=True) routes to the pipe kernel on both the
    int8 and the differentiable float path, bit-equal to the default."""
    x, w = _i8(1, 10, 10, 8), _i8(3, 3, 8, 8)
    np.testing.assert_array_equal(
        np.asarray(ops.conv2d(x, w, pipelined=True)),
        np.asarray(ops.conv2d(x, w)))
    xf, wf = _f32(1, 10, 10, 8), _f32(3, 3, 8, 8)
    np.testing.assert_array_equal(
        np.asarray(ops.conv2d(xf, wf, relu=True, pipelined=True)),
        np.asarray(ops.conv2d(xf, wf, relu=True)))


def test_pipe_float_path_differentiable():
    """The pipelined float path carries the same custom VJP: gradients
    are bitwise those of the sequential path (the VJP rules recompute
    residuals sequentially — legal because the kernels are bit-exact)."""
    xf, wf, bf = _f32(1, 8, 8, 4), _f32(3, 3, 4, 4), _f32(4)

    def loss(pipelined):
        def f(x, w, b):
            y = ops.conv2d(x, w, b, relu=True, pool=True,
                           cin_banks=2, kout_banks=2, pipelined=pipelined)
            return jnp.sum(y * y)
        return jax.grad(f, argnums=(0, 1, 2))(xf, wf, bf)

    for g_pipe, g_seq in zip(loss(True), loss(False)):
        np.testing.assert_array_equal(np.asarray(g_pipe), np.asarray(g_seq))


# ---------------------------------------------------------------------------
# Hypothesis sweep (guarded import, same pattern as test_tiling.py)
# ---------------------------------------------------------------------------


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @st.composite
    def pipe_case(draw):
        stride = draw(st.sampled_from([1, 2]))
        padding = draw(st.sampled_from(
            ["VALID", "SAME", ((draw(st.integers(0, 2)),
                                draw(st.integers(0, 2))),
                               (draw(st.integers(0, 2)),
                                draw(st.integers(0, 2))))]))
        groups = draw(st.sampled_from([1, 2, 8]))     # dense / mid / depthwise
        epilogue = draw(st.sampled_from(["none", "relu", "relu_pool"]))
        requant = draw(st.booleans())
        tiled = draw(st.booleans())
        h = draw(st.integers(8, 14))
        w = draw(st.integers(8, 14))
        seed = draw(st.integers(0, 2**31 - 1))
        return stride, padding, groups, epilogue, requant, tiled, h, w, seed

    @given(pipe_case())
    @settings(max_examples=25, deadline=None)
    def test_pipe_bit_exact_property(case):
        """Pipelined == sequential, bit-exact, across the full
        stride × padding × epilogue × groups × tiling space."""
        stride, padding, groups, epi, requant, tiled, h, w, seed = case
        c = k = 8
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.integers(-128, 128, (1, h, w, c)), jnp.int8)
        wt = jnp.asarray(rng.integers(-128, 128, (3, 3, c // groups, k)),
                         jnp.int8)
        b = jnp.asarray(rng.integers(-500, 500, (k,)), jnp.int32)
        oh, ow = ref.conv_out_shape(h, w, 3, 3, stride, padding)
        if oh < 1 or ow < 1:
            padding = "SAME"
            oh, ow = ref.conv_out_shape(h, w, 3, 3, stride, padding)
        pool = epi == "relu_pool" and oh >= 2 and ow >= 2
        cb, kb = ref.grouped_banks(c, k, groups)
        kw = dict(stride=stride, padding=padding, groups=groups,
                  cin_banks=cb, kout_banks=kb, relu=epi != "none",
                  pool=pool, out_scale=0.02 if requant else None)
        if tiled:
            ph, pw = (oh // 2, ow // 2) if pool else (oh, ow)
            if ph >= 2 and pw >= 2:
                kw["h_tile"] = 2 if pool else max(1, ph // 2)
                kw["w_tile"] = 2 if pool else max(1, pw // 2)
        _both(x, wt, b, **kw)

    @given(st.integers(8, 320), st.integers(8, 320),
           st.sampled_from([8, 16, 64]), st.sampled_from([8, 16, 64]),
           st.sampled_from([1, 2, 8]), st.booleans(),
           st.sampled_from([1 << 18, 1 << 20, 1 << 22]))
    @settings(max_examples=40, deadline=None)
    def test_pipe_vmem_accounting_property(h, w, c, k, groups, pool,
                                           budget):
        """The ping-pong working set never exceeds the budget the planner
        promised: ``working_set_bytes`` (whose ×2 term IS the two
        ping-pong slots) fits whenever the plan claims to, the
        ``pipelined`` flag changes no byte counts, and budget degradation
        still yields legal plans — dense and grouped."""
        if k % groups:
            k = groups * max(1, k // groups)
        oh, ow = ref.conv_out_shape(h, w, 3, 3, 1, "SAME")
        if pool and (oh < 2 or ow < 2):
            pool = False
        cb, kb = banking.grouped_banks(c, k, groups)
        plans = {
            mode: plan_tiles(h, w, c, k, stride=1, padding="SAME",
                             pool=pool, groups=groups, in_bytes=1,
                             out_bytes=1, cin_banks=cb, kout_banks=kb,
                             vmem_budget=budget, kernel=mode)
            for mode in ("sequential", "pipelined", "auto")
        }
        seq, pipe = plans["sequential"], plans["pipelined"]
        # identical geometry and bytes — only the kernel choice differs
        assert seq.working_set_bytes == pipe.working_set_bytes
        assert (seq.h_tile, seq.w_tile, seq.cin_banks, seq.kout_banks) \
            == (pipe.h_tile, pipe.w_tile, pipe.cin_banks, pipe.kout_banks)
        assert not seq.pipelined and pipe.pipelined
        for p in plans.values():
            # explicit ping-pong buffers: 2 input + 2 weight + 2 output
            # slots + the single accumulator — first principles, must
            # equal the planner's promise
            pingpong = 2 * (p.image_block_bytes + p.weight_block_bytes
                            + p.output_block_bytes) + p.acc_block_bytes
            assert p.working_set_bytes == pingpong
            assert p.fits_vmem == (pingpong <= budget)
            # legality under degradation, dense and grouped
            assert (c // groups) % p.cin_banks == 0
            assert k % p.kout_banks == 0 and p.kout_banks % groups == 0
            assert p.n_h_tiles * p.h_tile >= p.out_h
            assert p.n_w_tiles * p.w_tile >= p.out_w
            if pool:
                assert p.h_tile % 2 == 0 and p.w_tile % 2 == 0


# ---------------------------------------------------------------------------
# Whole networks: every scheduler mode, planner-auto kernel choice
# ---------------------------------------------------------------------------


def _net_setup(make):
    plan = make()
    rng = np.random.default_rng(3)
    params = plan.init_params(rng)
    xf = jnp.asarray(rng.normal(size=(2,) + plan.input_shape), jnp.float32)
    qnet = network.quantize_network(plan, params, xf)
    x8 = jnp.clip(jnp.round(xf / qnet.in_scale), -128, 127).astype(jnp.int8)
    return qnet, x8


@pytest.mark.parametrize("mode", ["batch", "kout", "spatial"])
def test_pipelined_network_bit_exact_all_scheduler_modes(mode):
    """make_int8_program with kernel="pipelined" (every conv forced onto
    conv2d_ws_pipe) is bit-identical to the sequential compile under all
    three scheduler modes — the TilePlan.pipelined flag must survive the
    shard-plan rewrites (kout re-banking, spatial slicing)."""
    qnet, x8 = _net_setup(network.mobilenet_small)
    outs = []
    for kernel in ("sequential", "pipelined"):
        sched = scheduler.MultiCoreScheduler(
            scheduler.SchedulerConfig(n_cores=2, mode=mode))
        name = "pallas"
        if mode != "batch":
            sb = sched.shard_backend("pallas")
            register_backend(sb)
            name = sb.name
        program = network.make_int8_program(
            qnet, ConvCoreConfig(backend=name, int8=True, kernel=kernel))
        outs.append(sched.run(program, x8))
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(outs[1]))


def test_auto_kernel_network_matches_ref():
    """The default compile (kernel="auto" — the planner mixes variants
    per layer) stays bit-exact against the ref backend."""
    qnet, x8 = _net_setup(network.mobilenet_small)
    a = network.make_int8_program(
        qnet, ConvCoreConfig(backend="pallas", int8=True))(x8)
    b = network.make_int8_program(
        qnet, ConvCoreConfig(backend="ref", int8=True))(x8)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# The crossover predictor (no kernels: pure cost model — fast)
# ---------------------------------------------------------------------------


def test_paper_anchors_untouched():
    """The new pipeline layer must not drift §5.2: 3,154,176 psums,
    0.224 / 4.48 GOPS exact (also asserted standalone in CI)."""
    refnum = perfmodel.paper_reference_numbers()
    assert refnum["psums"] == 3_154_176
    assert refnum["gops_1core"] == pytest.approx(0.224, rel=1e-3)
    assert refnum["gops_20cores"] == pytest.approx(4.48, rel=1e-2)


def test_pipeline_estimate_model_identities():
    """fill + steady-state + drain from first principles: with D = n·d
    and C = n·c exactly, pipelined = d + (n−1)·max(d,c) + c + n·overhead,
    sequential = D + C, and a 1-slab pipe is pure fill+drain+overhead."""
    plan = plan_tiles(32, 32, 8, 8, in_bytes=1, out_bytes=1,
                      kernel="sequential")
    n = perfmodel.pipeline_slabs(plan)
    psums = perfmodel.psum_count(32, 32, 8, 8)
    est = perfmodel.pipeline_estimate(plan, psums)
    d = -(-est["dma_cycles"] // n)
    c = -(-est["compute_cycles"] // n)
    assert est["n_slabs"] == n
    assert est["sequential_cycles"] == est["dma_cycles"] + est["compute_cycles"]
    assert est["pipelined_cycles"] == (
        d + (n - 1) * max(d, c) + c
        + n * perfmodel.PIPELINE_OVERHEAD_CYCLES)
    assert est["profitable"] == (
        est["pipelined_cycles"] < est["sequential_cycles"])
    # perfect overlap bound: pipelining can never beat the slower phase
    assert est["pipelined_cycles"] >= max(est["dma_cycles"],
                                          est["compute_cycles"])


def test_predictor_marks_depthwise_dma_bound_profitable():
    """Acceptance: on every MobileNet zoo plan, each depthwise layer the
    perf model flags dma_bound_board is marked pipelined-profitable (the
    DMA-floor diagnosis converted into recovered throughput)."""
    for make in (network.mobilenet_small, network.mobilenet_v2ish):
        plan = make()
        tps = plan.tile_plans()           # kernel="auto"
        rep = perfmodel.network_report(plan.psum_table(), tile_plans=tps)
        geoms = dict(zip(plan.node_names(), plan.conv_geometries()))
        dw_rows = [r for r in rep["layers"]
                   if geoms.get(r["name"]) and geoms[r["name"]][1] > 1
                   and r.get("dma_bound_board")]
        assert dw_rows, "zoo plan must contain DMA-bound depthwise layers"
        for r in dw_rows:
            assert r["pipelined"], r
            assert r["pipeline_speedup"] > 1.0, r
        assert rep["pipelined_layers"] >= len(dw_rows)


def test_predictor_leaves_tiny_layers_sequential():
    """Per-slab protocol overhead keeps the pipeline off layers with
    almost nothing to overlap — auto must make a real choice, not a
    constant one."""
    tiny = plan_tiles(6, 6, 4, 4, kernel="auto")
    assert not tiny.pipelined
    big = plan_tiles(64, 64, 16, 16, kernel="auto")
    assert big.pipelined


def test_network_report_prices_chosen_variant():
    """Priced rows expose both variants and charge the chosen one; the
    sequential total can only go down when the planner pipelines."""
    plan = network.mobilenet_small()
    auto = perfmodel.network_report(plan.psum_table(),
                                    tile_plans=plan.tile_plans())
    seq = perfmodel.network_report(
        plan.psum_table(), tile_plans=plan.tile_plans(kernel="sequential"))
    assert auto["pipelined_layers"] > 0 and seq["pipelined_layers"] == 0
    assert auto["cycles"] < seq["cycles"]
    assert auto["full_board"]["cycles"] <= seq["full_board"]["cycles"]
    for r in auto["layers"]:
        if "pipelined" not in r:
            continue
        chosen = (r["cycles_pipelined"] if r["pipelined"]
                  else r["cycles_sequential"])
        if r["psums"]:
            assert r["cycles"] == chosen
        # both estimates are real costs: never below the DMA time
        assert r["cycles_sequential"] >= r["dma_cycles"]
        assert r["cycles_pipelined"] >= r["dma_cycles"]


def test_forced_kernel_modes():
    p_seq = plan_tiles(32, 32, 8, 8, kernel="sequential")
    p_pipe = plan_tiles(32, 32, 8, 8, kernel="pipelined")
    assert not p_seq.pipelined and p_pipe.pipelined
    with pytest.raises(ValueError):
        plan_tiles(32, 32, 8, 8, kernel="bogus")
