"""Dense-prediction (segmentation) acceptance: the PR-8 contract.

The dilated & transposed conv knobs threaded through the stack must hold
the repo's established guarantees on the new workload class:

* ``unet_small`` (encoder–decoder, conv_transpose upsampling + skip
  concats) and ``dilated_context`` (atrous context module) compile via
  ``make_int8_program`` and are BIT-EXACT ref↔pallas under all three
  scheduler modes, with both the sequential and the pipelined kernel;
* QAT round trip (train the float shadow → quantize_network →
  make_int8_program) holds per-pixel accuracy within the established 2%;
* the §5.2 paper anchors (0.224 / 4.48 GOPS, 3,154,176 psums) remain
  exact with ``calib=None`` — dense prediction is additive, not a drift;
* the transposed-conv psum pricing exposes both the naive (~stride²×)
  and the zero-skipping MAC counts;
* over-dilated layers fail loudly in ``plan_tiles`` (the satellite
  shaped-error contract) instead of emitting an out-of-range BlockSpec.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import banking, network, perfmodel, scheduler, training
from repro.core.convcore import ConvCoreConfig, register_backend
from repro.kernels import ref

RNG = np.random.default_rng(5)

ZOO = [network.unet_small, network.dilated_context]


def _setup(make, batch: int = 2):
    plan = make()
    rng = np.random.default_rng(3)
    params = plan.init_params(rng)
    xf = jnp.asarray(rng.normal(size=(batch,) + plan.input_shape),
                     jnp.float32)
    qnet = network.quantize_network(plan, params, xf)
    return plan, params, xf, qnet


# ---------------------------------------------------------------------------
# Compile + numeric parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("make", ZOO)
def test_zoo_compiles_and_tracks_float_oracle(make):
    """Both segmentation nets compile to full-resolution logit maps; the
    int8 program tracks the float oracle within quantization error and is
    bit-exact ref↔pallas."""
    plan, params, xf, qnet = _setup(make)
    a = network.make_int8_program(
        qnet, ConvCoreConfig(backend="pallas", int8=True))(xf)
    b = network.make_int8_program(
        qnet, ConvCoreConfig(backend="ref", int8=True))(xf)
    h, w, _ = plan.input_shape
    assert a.shape[1:3] == (h, w), "dense prediction keeps resolution"
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    want = plan.apply_ref(params, xf)
    rel = float(jnp.linalg.norm(a - want) / jnp.linalg.norm(want))
    assert rel < 0.15, rel


@pytest.mark.parametrize("mode", ["batch", "kout", "spatial"])
@pytest.mark.parametrize("make", ZOO)
def test_zoo_bit_exact_all_scheduler_modes(make, mode):
    """Acceptance: ref↔pallas bit-exact under every scheduler mode — the
    kout shards divide transposed kernels like forward ones, and spatial
    row bands widen their halos for dilation / lower the transpose onto
    the banded eq conv."""
    plan, params, xf, qnet = _setup(make)
    outs = []
    for backend in ("ref", "pallas"):
        sched = scheduler.MultiCoreScheduler(
            scheduler.SchedulerConfig(n_cores=2, mode=mode))
        name = backend
        if mode != "batch":
            sb = sched.shard_backend(backend)
            register_backend(sb)
            name = sb.name
        program = network.make_int8_program(
            qnet, ConvCoreConfig(backend=name, int8=True))
        outs.append(sched.run(program, xf))
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(outs[1]))


@pytest.mark.parametrize("mode", ["batch", "kout", "spatial"])
@pytest.mark.parametrize("make", ZOO)
def test_zoo_pipelined_kernel_bit_exact(make, mode):
    """The forced-pipelined compile (every conv, transposed ones via the
    eq-conv lowering included, on conv2d_ws_pipe) is bit-identical to the
    sequential compile under every scheduler mode."""
    plan, params, xf, qnet = _setup(make)
    outs = []
    for kernel in ("sequential", "pipelined"):
        sched = scheduler.MultiCoreScheduler(
            scheduler.SchedulerConfig(n_cores=2, mode=mode))
        name = "pallas"
        if mode != "batch":
            sb = sched.shard_backend("pallas")
            register_backend(sb)
            name = sb.name
        program = network.make_int8_program(
            qnet, ConvCoreConfig(backend=name, int8=True, kernel=kernel))
        outs.append(sched.run(program, xf))
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(outs[1]))


# ---------------------------------------------------------------------------
# QAT round trip on the segmentation task
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("make", ZOO)
def test_segmentation_qat_roundtrip_within_2pct(make):
    """Acceptance: train the float shadow (through the transposed/dilated
    WS-kernel VJPs) with QAT on the synthetic segmentation task, lower
    with quantize_network, deploy with make_int8_program — per-PIXEL
    accuracy of the int8 program within 2% of the float shadow."""
    plan = make(input_shape=(8, 8, 2), classes=3)
    rng = np.random.default_rng(7)
    x, y = training.synthetic_segmentation(rng, 256, (8, 8, 2), classes=3)
    xe, ye = training.synthetic_segmentation(rng, 128, (8, 8, 2), classes=3)
    cfg = training.TrainConfig(qat=True, per_channel=True)
    state, _ = training.fit(plan, x, y, steps=60, batch=32, cfg=cfg, seed=8)

    float_logits = training.float_forward(plan, state.params, xe)
    float_acc = float(training.accuracy(float_logits, ye))
    assert float_acc >= 0.9, f"shadow model failed to learn: {float_acc}"

    qnet = network.quantize_network(plan, state.params, x[:128],
                                    per_channel=True)
    program = network.make_int8_program(
        qnet, ConvCoreConfig(backend="pallas", int8=True))
    int8_acc = float(training.accuracy(program(xe), ye))
    assert abs(float_acc - int8_acc) <= 0.02, (float_acc, int8_acc)


# ---------------------------------------------------------------------------
# Perf model: anchors untouched, transpose psum pricing
# ---------------------------------------------------------------------------


def test_paper_anchors_exact_with_calib_none():
    """The dense-prediction layer is additive: §5.2 anchors stay exact."""
    refnum = perfmodel.paper_reference_numbers()
    assert refnum["psums"] == 3_154_176
    assert refnum["gops_1core"] == pytest.approx(0.224, rel=1e-3)
    assert refnum["gops_20cores"] == pytest.approx(4.48, rel=1e-2)


def test_transpose_psum_skip_vs_naive():
    """Zero-skipping prices one psum per INPUT pixel × tap; the naive
    sweep prices the upsampled output — ~stride²× more for stride-s
    upsampling (exactly stride² when the kernel tiles the stride)."""
    h = w = 8
    c, k, kh, s = 4, 8, 2, 2
    skip = perfmodel.conv_transpose_psum_count(h, w, c, k, kh, kh, stride=s)
    naive = perfmodel.conv_transpose_psum_count(h, w, c, k, kh, kh,
                                               stride=s, skip_zeros=False)
    assert skip == h * w * k * c
    oh, ow = ref.conv_transpose_out_shape(h, w, kh, kh, s)
    assert naive == oh * ow * k * c
    assert naive == s * s * skip
    # the network walk prices transposed rows on the skip count
    plan = network.unet_small()
    rows = dict(plan.psum_table())
    acts = plan.activation_shapes()
    ins = plan.resolved_inputs()
    for i, sp in enumerate(plan.layers):
        if sp.kind != "conv_transpose":
            continue
        hh, ww, cc = plan.input_shape if ins[i][0] < 0 else acts[ins[i][0]]
        assert rows[plan.node_names()[i]] == hh * ww * sp.features * cc


def test_plan_tiles_rejects_over_dilated_kernel():
    """Satellite: a dilated kernel extent wider than the padded input is
    a shaped ValueError from the planner, not an out-of-range BlockSpec
    from the kernel launch."""
    with pytest.raises(ValueError, match="dilated kernel extent"):
        banking.plan_tiles(12, 12, 4, 4, 3, 3, padding="VALID", dilation=50)


def test_tile_plans_transpose_planned_on_eq_geometry():
    """Transposed layers plan on the stride-1 eq conv: the plan's output
    extent is the transpose output and its input tile carries the eq
    stride-1 halo."""
    plan = network.unet_small()
    plans = plan.tile_plans()
    acts = plan.activation_shapes()
    for i, sp in enumerate(plan.layers):
        if sp.kind != "conv_transpose":
            continue
        tp = plans[i]
        assert (tp.out_h, tp.out_w) == acts[i][:2]
        assert tp.stride == 1
        kh = sp.kernel[0]
        assert tp.in_h_tile == (tp.h_tile - 1) + ref.dilated_extent(
            kh, sp.dilation)


def test_autotuned_engine_serves_segmentation():
    """Satellite: a NetworkTunePlan routes end-to-end through
    ConvNetEngine — tuned tile plans into the compiled program, the
    winning scheduler verdict into the serving loop — and stays
    bit-exact with the greedy engine."""
    from repro.core.autotune import autotune_network
    from repro.serving.engine import ConvNetEngine
    plan, params, xf, qnet = _setup(network.dilated_context, batch=3)
    tune = autotune_network(plan)
    base = ConvNetEngine(qnet, batch=2, backend="pallas")
    tuned = ConvNetEngine(qnet, batch=2, backend="pallas", tune=tune)
    a = base.submit(np.asarray(xf))
    b = tuned.submit(np.asarray(xf))
    np.testing.assert_array_equal(a, b)
    with pytest.raises(ValueError, match="tune plan is for network"):
        ConvNetEngine(_setup(network.unet_small)[3], tune=tune)
