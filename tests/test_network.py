"""Network executor + multi-core scheduler: LayerSpec/NetworkPlan shape
math, int8 scale chaining, backend parity, the LeNet acceptance path
(stride-2 / SAME / fused pool through Pallas vs the float lax reference
within quantization tolerance), replicated-IP-core scheduling, the
conv-net serving engine, the whole-network §5.2 cycle model, and the
residual-graph (DAG) path: add/concat merge nodes, the shared-grid int8
residual add, and resnet ref↔pallas bit-exactness under every scheduler
mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import network, perfmodel, scheduler
from repro.core.convcore import ConvCoreConfig, get_backend, register_backend
from repro.core.quantize import requant_scale
from repro.kernels import ref
from repro.serving.engine import ConvNetEngine

RNG = np.random.default_rng(11)


def _lenet_setup(batch=4):
    plan = network.lenet()
    params = plan.init_params(RNG)
    x = jnp.asarray(RNG.normal(size=(batch, *plan.input_shape)), jnp.float32)
    return plan, params, x


def test_activation_and_param_shapes():
    plan = network.lenet()
    assert plan.activation_shapes() == [
        (14, 14, 8), (7, 7, 16), (4, 4, 32), (512,), (64,), (10,)]
    shapes = plan.param_shapes()
    assert shapes[0] == {"w": (3, 3, 1, 8), "b": (8,)}
    assert shapes[2] == {"w": (3, 3, 16, 32), "b": (32,)}
    assert shapes[3] is None                       # flatten
    assert shapes[4] == {"w": (512, 64), "b": (64,)}


def test_float_reference_matches_lax_composition():
    """apply_ref == hand-composed lax ops (the oracle is itself audited)."""
    plan, params, x = _lenet_setup(batch=2)
    got = plan.apply_ref(params, x)
    h = x
    for sp, p in zip(plan.layers, params):
        if sp.kind == "conv":
            h = jax.lax.conv_general_dilated(
                h, p["w"], window_strides=(sp.stride, sp.stride),
                padding=ref.normalize_padding(
                    sp.padding, *sp.kernel, sp.stride, h.shape[1],
                    h.shape[2]),
                dimension_numbers=("NHWC", "HWIO", "NHWC")) + p["b"]
            if sp.relu:
                h = jnp.maximum(h, 0)
            if sp.pool:
                h = ref.maxpool2d_ref(h)
        elif sp.kind == "flatten":
            h = h.reshape(h.shape[0], -1)
        elif sp.kind == "dense":
            h = h @ p["w"] + p["b"]
            if sp.relu:
                h = jnp.maximum(h, 0)
    np.testing.assert_allclose(got, h, rtol=1e-5, atol=1e-5)


def test_lenet_int8_end_to_end_acceptance():
    """The PR acceptance gate: a LeNet-style int8 NetworkPlan (3 conv
    layers with stride-2 / SAME / fused pool among them) runs end-to-end
    through the Pallas backend and matches the float lax reference within
    quantization tolerance."""
    plan, params, x = _lenet_setup()
    want = plan.apply_ref(params, x)
    qnet = network.quantize_network(plan, params, x)
    program = network.make_int8_program(
        qnet, ConvCoreConfig(backend="pallas", int8=True))
    got = program(x)
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < 0.1, rel


def test_int8_backends_bit_identical():
    """Pallas and ref backends produce the SAME int8 network (every
    inter-layer tensor requantizes identically)."""
    plan, params, x = _lenet_setup(batch=2)
    qnet = network.quantize_network(plan, params, x)
    a = network.make_int8_program(
        qnet, ConvCoreConfig(backend="pallas", int8=True))(x)
    b = network.make_int8_program(
        qnet, ConvCoreConfig(backend="ref", int8=True))(x)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_scale_chaining_is_consistent():
    """requant_scale puts layer-i accumulators on layer-i+1's int8 grid:
    quantizing the float activation directly == requantizing the int32
    accumulator (up to the ±1 LSB of the two rounding paths)."""
    s_in, s_w = jnp.float32(0.02), jnp.float32(0.005)
    acc = jnp.asarray(RNG.integers(-20000, 20000, size=(64,)), jnp.int32)
    float_act = acc.astype(jnp.float32) * s_in * s_w
    s_out = jnp.max(jnp.abs(float_act)) / 127.0
    via_requant = ref.requantize_ref(acc, requant_scale(s_in, s_w, s_out))
    direct = jnp.clip(jnp.round(float_act / s_out), -128, 127)
    assert int(jnp.max(jnp.abs(
        via_requant.astype(jnp.int32) - direct.astype(jnp.int32)))) <= 1


def test_vgg_small_runs():
    plan = network.vgg_small()
    params = plan.init_params(RNG)
    x = jnp.asarray(RNG.normal(size=(2, *plan.input_shape)), jnp.float32)
    qnet = network.quantize_network(plan, params, x)
    program = network.make_int8_program(
        qnet, ConvCoreConfig(backend="ref", int8=True))
    want = plan.apply_ref(params, x)
    got = program(x)
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < 0.15, rel


def test_per_channel_scales_end_to_end():
    """Per-channel (kout-bank) weight scales ride the fused requantize
    epilogue end-to-end: [K] requant vectors, both backends bit-identical,
    and accuracy no worse than per-tensor (usually better — that is the
    point of per-channel calibration)."""
    plan, params, x = _lenet_setup()
    want = plan.apply_ref(params, x)
    qpc = network.quantize_network(plan, params, x, per_channel=True)
    assert qpc.per_channel
    # every non-final parametric layer carries a [K] requant vector
    for sp, rq, shp in zip(plan.layers, qpc.requants, plan.param_shapes()):
        if sp.kind in ("conv", "dense") and rq is not None:
            assert rq.shape == (shp["b"][0],), (sp.kind, rq.shape)
    a = network.make_int8_program(
        qpc, ConvCoreConfig(backend="pallas", int8=True))(x)
    b = network.make_int8_program(
        qpc, ConvCoreConfig(backend="ref", int8=True))(x)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    qpt = network.quantize_network(plan, params, x)
    pt = network.make_int8_program(
        qpt, ConvCoreConfig(backend="ref", int8=True))(x)
    rel_pc = float(jnp.linalg.norm(a - want) / jnp.linalg.norm(want))
    rel_pt = float(jnp.linalg.norm(pt - want) / jnp.linalg.norm(want))
    assert rel_pc < 0.1, rel_pc
    assert rel_pc <= rel_pt * 1.25, (rel_pc, rel_pt)   # no regression


def _head_plan():
    """Classifier head without flatten + giant dense: avg-pool then a
    global average pool straight into the logits layer."""
    return network.NetworkPlan(
        name="gap_head", input_shape=(16, 16, 4),
        layers=(
            network.conv(8, relu=True, pool=True),
            network.conv(16, relu=True),
            network.avgpool(2),
            network.global_pool(),
            network.dense(10),
        ))


def test_avg_and_global_pool_shapes_and_oracle():
    plan = _head_plan()
    assert plan.activation_shapes() == [
        (8, 8, 8), (8, 8, 16), (4, 4, 16), (16,), (10,)]
    # dense consumes the global-pooled channel vector — no flatten layer
    assert plan.param_shapes()[-1] == {"w": (16, 10), "b": (10,)}
    params = plan.init_params(RNG)
    x = jnp.asarray(RNG.normal(size=(2, *plan.input_shape)), jnp.float32)
    got = plan.apply_ref(params, x)
    h = x
    h = ref.conv2d_epilogue_ref(h, params[0]["w"], params[0]["b"],
                                padding="SAME", relu=True, pool=True)
    h = ref.conv2d_epilogue_ref(h, params[1]["w"], params[1]["b"],
                                padding="SAME", relu=True)
    h = ref.avgpool2d_ref(h, 2)
    h = jnp.mean(h, axis=(1, 2))
    h = h @ params[-1]["w"] + params[-1]["b"]
    np.testing.assert_allclose(got, h, rtol=1e-5, atol=1e-5)


def test_avg_global_pool_int8_program():
    plan = _head_plan()
    params = plan.init_params(RNG)
    x = jnp.asarray(RNG.normal(size=(4, *plan.input_shape)), jnp.float32)
    qnet = network.quantize_network(plan, params, x)
    a = network.make_int8_program(
        qnet, ConvCoreConfig(backend="pallas", int8=True))(x)
    b = network.make_int8_program(
        qnet, ConvCoreConfig(backend="ref", int8=True))(x)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    want = plan.apply_ref(params, x)
    rel = float(jnp.linalg.norm(a - want) / jnp.linalg.norm(want))
    assert rel < 0.15, rel
    # pooling layers are free in the paper's psum accounting
    rows = dict(plan.psum_table())
    assert rows["avgpool2"] == 0 and rows["globalpool3"] == 0


def test_vgg_small_64_and_imagenet_plans_compile():
    """Per-layer TilePlans let larger-input plans compile: every conv
    layer gets a plan that fits the VMEM budget."""
    for plan in (network.vgg_small((64, 64, 4)),
                 network.vgg_imagenet(), network.large_map()):
        tps = plan.tile_plans()
        convs = [tp for tp in tps if tp is not None]
        assert len(convs) == sum(
            1 for sp in plan.layers if sp.kind == "conv")
        assert all(tp.fits_vmem for tp in convs), plan.name
    # the large-map plan's first layer genuinely exceeds the whole-map
    # budget and compiles onto spatial tiles
    whole = network.large_map().tile_plans(vmem_budget=None)
    assert any(not tp.fits_vmem for tp in whole if tp is not None)
    assert any(tp.tiled for tp in network.large_map().tile_plans()
               if tp is not None)


# ---------------------------------------------------------------------------
# Scheduler: replicated IP cores
# ---------------------------------------------------------------------------


def test_batch_sharded_virtual_cores_exact():
    plan, params, x = _lenet_setup(batch=4)
    qnet = network.quantize_network(plan, params, x)
    program = network.make_int8_program(
        qnet, ConvCoreConfig(backend="pallas", int8=True))
    want = program(x)
    sched = scheduler.MultiCoreScheduler(
        scheduler.SchedulerConfig(n_cores=2))
    got = sched.run(program, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("inner", ["ref", "pallas"])
def test_kout_sharded_backend_exact(inner):
    """Kernel-set division across cores == the unsharded network (the
    pallas case also checks per-shard bank-plan rebanking)."""
    plan, params, x = _lenet_setup(batch=2)
    qnet = network.quantize_network(plan, params, x)
    base = network.make_int8_program(
        qnet, ConvCoreConfig(backend="ref", int8=True))(x)
    sched = scheduler.MultiCoreScheduler(
        scheduler.SchedulerConfig(n_cores=4, mode="kout"))
    kb = sched.shard_backend(inner)
    register_backend(kb)
    got = network.make_int8_program(
        qnet, ConvCoreConfig(backend=kb.name, int8=True))(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))


def test_kout_mode_run_passes_batch_through():
    """mode='kout' must not batch-split (cores divide kernels instead), so
    batch=1 single-image latency mode works."""
    plan, params, x = _lenet_setup(batch=1)
    qnet = network.quantize_network(plan, params, x)
    program = network.make_int8_program(
        qnet, ConvCoreConfig(backend="ref", int8=True))
    sched = scheduler.MultiCoreScheduler(
        scheduler.SchedulerConfig(n_cores=4, mode="kout"))
    got = sched.run(program, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(program(x)))


def test_kout_shards_degrade_for_awkward_channels():
    kb = scheduler.KoutShardedBackend(get_backend("ref"), 4)
    assert kb._shards(8) == 4
    assert kb._shards(10) == 2
    assert kb._shards(1) == 1


# ---------------------------------------------------------------------------
# Serving + perfmodel consumers
# ---------------------------------------------------------------------------


def test_convnet_serving_engine_pads_partial_batches():
    plan, params, x = _lenet_setup(batch=4)
    qnet = network.quantize_network(plan, params, x)
    engine = ConvNetEngine(qnet, batch=4, n_cores=2, backend="pallas")
    program = network.make_int8_program(
        qnet, ConvCoreConfig(backend="pallas", int8=True))
    imgs = np.asarray(RNG.normal(size=(6, 28, 28, 1)), np.float32)
    logits = engine.submit(imgs)
    assert logits.shape == (6, 10)
    want = program(jnp.asarray(imgs[:4]))
    np.testing.assert_array_equal(logits[:4], np.asarray(want))
    assert engine.stats == {"requests": 6, "batches": 2, "padded": 2}
    # empty request list keeps the [R, K] contract
    assert engine.submit(np.zeros((0, 28, 28, 1), np.float32)).shape \
        == (0, 10)


def test_network_perf_report():
    plan = network.lenet()
    rep = plan.perf_report()
    # layer-at-a-time: total == sum of per-layer cycle counts
    assert rep["cycles"] == sum(r["cycles"] for r in rep["layers"])
    assert rep["cycles"] > 0 and rep["seconds"] > 0
    # one IP core sustains the paper's 0.224 GOPS on psum-dense networks
    assert rep["gops_paper"] == pytest.approx(0.224, rel=1e-2)
    fb = rep["full_board"]
    assert fb["ip_cores"] == 20
    assert fb["seconds"] < rep["seconds"] / 10      # ≥10× from 20 cores
    assert fb["gops_paper"] == pytest.approx(4.48, rel=0.05)


def test_batch_mode_pads_ragged_batches():
    """batch mode used to assert n % cores == 0; ragged batches now pad to
    the next core multiple and slice the padding back off."""
    plan, params, x = _lenet_setup(batch=5)
    qnet = network.quantize_network(plan, params, x)
    program = network.make_int8_program(
        qnet, ConvCoreConfig(backend="ref", int8=True))
    sched = scheduler.MultiCoreScheduler(
        scheduler.SchedulerConfig(n_cores=2))
    got = sched.run(program, x)
    assert got.shape[0] == 5
    np.testing.assert_array_equal(np.asarray(got), np.asarray(program(x)))


def test_backend_registry_unregister_and_no_leak():
    """register_backend has an inverse, and the conftest fixture keeps the
    global registry clean — sharded backends registered by earlier tests
    in this module must not still be visible here."""
    from repro.core.convcore import BACKENDS, unregister_backend

    class Dummy:
        name = "dummy-backend"

    register_backend(Dummy())
    assert "dummy-backend" in BACKENDS
    unregister_backend("dummy-backend")
    assert "dummy-backend" not in BACKENDS
    unregister_backend("dummy-backend")            # idempotent
    assert all("@" not in name for name in BACKENDS), sorted(BACKENDS)


def test_psum_count_stride_padding():
    # SAME stride-1: output pixels == input pixels
    assert perfmodel.psum_count(14, 14, 8, 16, 3, 3, 1, "SAME") \
        == 14 * 14 * 16 * 8
    # stride-2 SAME: ceil(14/2)=7
    assert perfmodel.psum_count(14, 14, 8, 16, 3, 3, 2, "SAME") \
        == 7 * 7 * 16 * 8
    # VALID unchanged vs the seed accounting
    assert perfmodel.psum_count(224, 224, 8, 8) == 3_154_176


# ---------------------------------------------------------------------------
# Residual / branch-merge graphs (DAG NetworkPlan)
# ---------------------------------------------------------------------------


def _resnet_setup(batch=2, per_channel=False):
    plan = network.resnet_small()
    params = plan.init_params(RNG)
    x = jnp.asarray(RNG.normal(size=(batch, *plan.input_shape)), jnp.float32)
    qnet = network.quantize_network(plan, params, x,
                                    per_channel=per_channel)
    return plan, params, x, qnet


def test_resnet_graph_shapes_and_params():
    plan = network.resnet_small()
    shapes = plan.activation_shapes()
    assert shapes[0] == (32, 32, 16)                     # stem
    assert shapes[-2] == (64,) and shapes[-1] == (10,)
    names = plan.node_names()
    ins = plan.resolved_inputs()
    # the identity-skip merge consumes the block input and the conv branch
    b1 = names.index("b1")
    assert set(ins[b1]) == {names.index("stem"), names.index("b1c2")}
    # projection shortcut: a 1×1 stride-2 conv from the block input
    b2p = names.index("b2p")
    assert plan.param_shapes()[b2p] == {"w": (1, 1, 16, 32), "b": (32,)}
    assert ins[b2p] == (names.index("b1"),)
    # merge nodes are free in the psum accounting; the projection is not
    rows = dict(plan.psum_table())
    assert rows["b1"] == 0 and rows["b2"] == 0 and rows["b2p"] > 0


def test_residual_float_oracle_matches_hand_composition():
    """apply_ref over a residual graph == hand-composed lax ops."""
    plan = network.NetworkPlan(
        name="tiny_res", input_shape=(8, 8, 4),
        layers=(
            network.conv(8, relu=True, name="a"),
            network.conv(8, relu=False, name="b"),
            network.add("a", "b", relu=True),
            network.global_pool(),
            network.dense(3),
        ))
    params = plan.init_params(RNG)
    x = jnp.asarray(RNG.normal(size=(2, *plan.input_shape)), jnp.float32)
    got = plan.apply_ref(params, x)
    a = ref.conv2d_epilogue_ref(x, params[0]["w"], params[0]["b"],
                                padding="SAME", relu=True)
    b = ref.conv2d_epilogue_ref(a, params[1]["w"], params[1]["b"],
                                padding="SAME")
    h = jnp.maximum(a + b, 0)
    h = jnp.mean(h, axis=(1, 2))
    h = h @ params[-1]["w"] + params[-1]["b"]
    np.testing.assert_allclose(got, h, rtol=1e-5, atol=1e-5)


def test_skip_from_network_input():
    """The reserved name "input" lets a skip reach the network input."""
    plan = network.NetworkPlan(
        name="in_skip", input_shape=(6, 6, 4),
        layers=(
            network.conv(4, relu=False, name="c"),
            network.add(network.INPUT, "c", relu=True),
            network.global_pool(),
            network.dense(2),
        ))
    assert plan.resolved_inputs()[1] == (-1, 0)
    params = plan.init_params(RNG)
    x = jnp.asarray(RNG.normal(size=(2, *plan.input_shape)), jnp.float32)
    want = plan.apply_ref(params, x)
    c = ref.conv2d_epilogue_ref(x, params[0]["w"], params[0]["b"],
                                padding="SAME")
    h = jnp.mean(jnp.maximum(x + c, 0), axis=(1, 2))
    np.testing.assert_allclose(
        want, h @ params[-1]["w"] + params[-1]["b"], rtol=1e-5, atol=1e-5)
    qnet = network.quantize_network(plan, params, x)
    out = network.make_int8_program(
        qnet, ConvCoreConfig(backend="ref", int8=True))(x)
    assert out.shape == (2, 2)


def test_add_requant_ref_shared_grid_is_exact():
    """Both branches on the merge grid → the residual add is exact int8
    arithmetic; mismatched grids requantize per branch at ≤1 LSB vs the
    float-domain add (the two rounding orders)."""
    a = jnp.asarray(RNG.integers(-60, 60, (128,)), jnp.int8)
    b = jnp.asarray(RNG.integers(-60, 60, (128,)), jnp.int8)
    same = ref.add_requant_ref(a, b, 1.0, 1.0)
    np.testing.assert_array_equal(
        np.asarray(same, np.int32),
        np.clip(np.asarray(a, np.int32) + np.asarray(b, np.int32),
                -128, 127))
    sa, sb, so = 0.02, 0.013, 0.025
    got = ref.add_requant_ref(a, b, sa / so, sb / so)
    direct = np.clip(np.round(
        (np.asarray(a, np.float32) * sa + np.asarray(b, np.float32) * sb)
        / so), -128, 127)
    assert np.max(np.abs(np.asarray(got, np.float32) - direct)) <= 1


def test_quantize_network_merge_scales():
    """Every add node carries per-branch requant scales (s_branch/s_out);
    non-merge nodes carry none."""
    plan, params, x, qnet = _resnet_setup()
    for i, sp in enumerate(plan.layers):
        if sp.kind == "add":
            ms = qnet.merge_scales[i]
            assert ms is not None and len(ms) == 2
            assert all(jnp.ndim(m) == 0 and float(m) > 0 for m in ms)
        else:
            assert qnet.merge_scales[i] is None


@pytest.mark.parametrize("per_channel", [False, True])
def test_resnet_int8_backends_bit_identical(per_channel):
    """resnet_small end-to-end int8: pallas and ref produce the SAME
    network (per-tensor and per-channel scales), and stay within
    quantization tolerance of the float oracle."""
    plan, params, x, qnet = _resnet_setup(per_channel=per_channel)
    a = network.make_int8_program(
        qnet, ConvCoreConfig(backend="pallas", int8=True))(x)
    b = network.make_int8_program(
        qnet, ConvCoreConfig(backend="ref", int8=True))(x)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    want = plan.apply_ref(params, x)
    rel = float(jnp.linalg.norm(a - want) / jnp.linalg.norm(want))
    assert rel < 0.15, rel


@pytest.mark.parametrize("mode", ["batch", "kout", "spatial"])
def test_resnet_ref_pallas_bit_exact_all_scheduler_modes(mode):
    """Acceptance: resnet_small is bit-exact ref↔pallas in int8 (with
    per-channel scales) under every scheduler mode — merge operands stay
    consistent because each sharded conv concatenates its shards before
    the add node consumes them."""
    plan, params, x, qnet = _resnet_setup(per_channel=True)
    outs = []
    for backend in ("ref", "pallas"):
        sched = scheduler.MultiCoreScheduler(
            scheduler.SchedulerConfig(n_cores=2, mode=mode))
        name = backend
        if mode != "batch":
            sb = sched.shard_backend(backend)
            register_backend(sb)
            name = sb.name
        program = network.make_int8_program(
            qnet, ConvCoreConfig(backend=name, int8=True))
        outs.append(sched.run(program, x))
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(outs[1]))


def test_resnet_bottleneck_int8_parity():
    plan = network.resnet_bottleneck()
    params = plan.init_params(RNG)
    x = jnp.asarray(RNG.normal(size=(2, *plan.input_shape)), jnp.float32)
    qnet = network.quantize_network(plan, params, x)
    a = network.make_int8_program(
        qnet, ConvCoreConfig(backend="pallas", int8=True))(x)
    b = network.make_int8_program(
        qnet, ConvCoreConfig(backend="ref", int8=True))(x)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    want = plan.apply_ref(params, x)
    rel = float(jnp.linalg.norm(a - want) / jnp.linalg.norm(want))
    assert rel < 0.15, rel


def test_concat_merge_int8_parity():
    """Branch-merge (inception-style) concat: each branch requantizes onto
    the merge grid; both backends bit-identical."""
    plan = network.NetworkPlan(
        name="widenet", input_shape=(8, 8, 4),
        layers=(
            network.conv(8, relu=True, name="trunk"),
            network.conv(8, kernel=1, relu=True, name="left",
                         input="trunk"),
            network.conv(8, kernel=5, relu=True, name="right",
                         input="trunk"),
            network.concat("left", "right"),
            network.global_pool(),
            network.dense(5),
        ))
    assert plan.activation_shapes()[3] == (8, 8, 16)
    params = plan.init_params(RNG)
    x = jnp.asarray(RNG.normal(size=(2, *plan.input_shape)), jnp.float32)
    qnet = network.quantize_network(plan, params, x)
    a = network.make_int8_program(
        qnet, ConvCoreConfig(backend="pallas", int8=True))(x)
    b = network.make_int8_program(
        qnet, ConvCoreConfig(backend="ref", int8=True))(x)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    want = plan.apply_ref(params, x)
    rel = float(jnp.linalg.norm(a - want) / jnp.linalg.norm(want))
    assert rel < 0.2, rel


def test_graph_validation_errors():
    def mk(layers):
        return network.NetworkPlan("bad", (8, 8, 4), tuple(layers))

    with pytest.raises(ValueError, match="unknown input"):
        mk([network.conv(8, input="nope")]).resolved_inputs()
    with pytest.raises(ValueError, match="topologically"):
        mk([network.conv(8, input="later", name="first"),
            network.conv(8, name="later")]).resolved_inputs()
    with pytest.raises(ValueError, match="duplicate"):
        mk([network.conv(8, name="x"),
            network.conv(8, name="x")]).node_names()
    with pytest.raises(ValueError, match="disagree"):
        mk([network.conv(8, name="a"),
            network.conv(16, name="b"),
            network.add("a", "b")]).activation_shapes()
    with pytest.raises(ValueError, match="share H×W"):
        mk([network.conv(8, name="a"),
            network.conv(8, stride=2, name="b", input="a"),
            network.concat("a", "b")]).activation_shapes()
    # spatial ops after flatten get a named error, not an unpack traceback
    with pytest.raises(ValueError, match="needs an \\[H,W,C\\] input"):
        mk([network.conv(8), network.flatten(),
            network.maxpool()]).activation_shapes()
    # fused pool of a sub-2×2 conv output: the shape walk raises the same
    # error as plan_tiles / conv2d_ws instead of reporting a 0×0 map
    with pytest.raises(ValueError, match="2×2 pool"):
        network.NetworkPlan(
            "t", (3, 3, 4),
            (network.conv(8, padding="VALID", pool=True),)
        ).activation_shapes()


def test_auto_names_step_aside_for_explicit_names():
    """A user name matching a later unnamed node's default ("conv1") must
    not reject the plan: auto names disambiguate instead."""
    plan = network.NetworkPlan(
        "t", (8, 8, 4),
        (network.conv(8, name="conv1"), network.conv(8)))
    names = plan.node_names()
    assert names[0] == "conv1" and names[1] != "conv1"
    assert plan.resolved_inputs() == [(-1,), (0,)]
    assert plan.activation_shapes() == [(8, 8, 8), (8, 8, 8)]


def test_basic_block_projection_for_stride1_width_change():
    """A stride-1 block that changes width takes project=True and builds a
    valid graph (identity skips can't change channel count)."""
    layers = [network.conv(16, relu=True, name="stem")]
    layers += network._basic_block(1, "stem", 32, 1, project=True)
    plan = network.NetworkPlan("t", (8, 8, 4), tuple(layers))
    shapes = plan.activation_shapes()
    assert shapes[plan.node_names().index("b1")] == (8, 8, 32)


def test_forward_activations_release_dead_nodes():
    """The eager oracle walk frees each activation after its last
    consumer — a straight-line plan holds exactly ONE live activation at
    every step (calibrating large_map must not retain every layer's
    feature map simultaneously)."""
    plan, params, x = _lenet_setup(batch=1)
    gen = plan.forward_activations(params, x)
    out = None
    for i, sp, p, h in gen:
        acts = gen.gi_frame.f_locals["acts"]
        live = [j for j, a in enumerate(acts) if a is not None]
        assert live == [i], (i, live)
        out = h
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(plan.apply_ref(params, x)),
                               rtol=1e-6, atol=1e-6)


def test_make_int8_program_rejects_short_tile_plans():
    """A tile_plans override with one entry per CONV (instead of one per
    node) must fail loudly, not silently compile a truncated network."""
    plan, params, x = _lenet_setup(batch=1)
    qnet = network.quantize_network(plan, params, x)
    short = [tp for tp in plan.tile_plans() if tp is not None]
    with pytest.raises(ValueError, match="one entry per node"):
        network.make_int8_program(
            qnet, ConvCoreConfig(backend="ref", int8=True),
            tile_plans=short)


def test_float_tail_after_last_param_layer():
    """Feature-extractor plans (shape-only nodes after the final
    parametric layer) quantize and run: the dequantized float tail
    propagates a None scale through pool/globalpool instead of raising."""
    plan = network.NetworkPlan(
        name="fx", input_shape=(8, 8, 4),
        layers=(network.conv(8, relu=True), network.global_pool()))
    params = plan.init_params(RNG)
    x = jnp.asarray(RNG.normal(size=(2, *plan.input_shape)), jnp.float32)
    qnet = network.quantize_network(plan, params, x)
    out = network.make_int8_program(
        qnet, ConvCoreConfig(backend="ref", int8=True))(x)
    assert out.dtype == jnp.float32 and out.shape == (2, 8)
    want = plan.apply_ref(params, x)
    rel = float(jnp.linalg.norm(out - want) / jnp.linalg.norm(want))
    assert rel < 0.1, rel
