"""Network executor + multi-core scheduler: LayerSpec/NetworkPlan shape
math, int8 scale chaining, backend parity, the LeNet acceptance path
(stride-2 / SAME / fused pool through Pallas vs the float lax reference
within quantization tolerance), replicated-IP-core scheduling, the
conv-net serving engine, and the whole-network §5.2 cycle model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import network, perfmodel, scheduler
from repro.core.convcore import ConvCoreConfig, get_backend, register_backend
from repro.core.quantize import requant_scale
from repro.kernels import ref
from repro.serving.engine import ConvNetEngine

RNG = np.random.default_rng(11)


def _lenet_setup(batch=4):
    plan = network.lenet()
    params = plan.init_params(RNG)
    x = jnp.asarray(RNG.normal(size=(batch, *plan.input_shape)), jnp.float32)
    return plan, params, x


def test_activation_and_param_shapes():
    plan = network.lenet()
    assert plan.activation_shapes() == [
        (14, 14, 8), (7, 7, 16), (4, 4, 32), (512,), (64,), (10,)]
    shapes = plan.param_shapes()
    assert shapes[0] == {"w": (3, 3, 1, 8), "b": (8,)}
    assert shapes[2] == {"w": (3, 3, 16, 32), "b": (32,)}
    assert shapes[3] is None                       # flatten
    assert shapes[4] == {"w": (512, 64), "b": (64,)}


def test_float_reference_matches_lax_composition():
    """apply_ref == hand-composed lax ops (the oracle is itself audited)."""
    plan, params, x = _lenet_setup(batch=2)
    got = plan.apply_ref(params, x)
    h = x
    for sp, p in zip(plan.layers, params):
        if sp.kind == "conv":
            h = jax.lax.conv_general_dilated(
                h, p["w"], window_strides=(sp.stride, sp.stride),
                padding=ref.normalize_padding(
                    sp.padding, *sp.kernel, sp.stride, h.shape[1],
                    h.shape[2]),
                dimension_numbers=("NHWC", "HWIO", "NHWC")) + p["b"]
            if sp.relu:
                h = jnp.maximum(h, 0)
            if sp.pool:
                h = ref.maxpool2d_ref(h)
        elif sp.kind == "flatten":
            h = h.reshape(h.shape[0], -1)
        elif sp.kind == "dense":
            h = h @ p["w"] + p["b"]
            if sp.relu:
                h = jnp.maximum(h, 0)
    np.testing.assert_allclose(got, h, rtol=1e-5, atol=1e-5)


def test_lenet_int8_end_to_end_acceptance():
    """The PR acceptance gate: a LeNet-style int8 NetworkPlan (3 conv
    layers with stride-2 / SAME / fused pool among them) runs end-to-end
    through the Pallas backend and matches the float lax reference within
    quantization tolerance."""
    plan, params, x = _lenet_setup()
    want = plan.apply_ref(params, x)
    qnet = network.quantize_network(plan, params, x)
    program = network.make_int8_program(
        qnet, ConvCoreConfig(backend="pallas", int8=True))
    got = program(x)
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < 0.1, rel


def test_int8_backends_bit_identical():
    """Pallas and ref backends produce the SAME int8 network (every
    inter-layer tensor requantizes identically)."""
    plan, params, x = _lenet_setup(batch=2)
    qnet = network.quantize_network(plan, params, x)
    a = network.make_int8_program(
        qnet, ConvCoreConfig(backend="pallas", int8=True))(x)
    b = network.make_int8_program(
        qnet, ConvCoreConfig(backend="ref", int8=True))(x)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_scale_chaining_is_consistent():
    """requant_scale puts layer-i accumulators on layer-i+1's int8 grid:
    quantizing the float activation directly == requantizing the int32
    accumulator (up to the ±1 LSB of the two rounding paths)."""
    s_in, s_w = jnp.float32(0.02), jnp.float32(0.005)
    acc = jnp.asarray(RNG.integers(-20000, 20000, size=(64,)), jnp.int32)
    float_act = acc.astype(jnp.float32) * s_in * s_w
    s_out = jnp.max(jnp.abs(float_act)) / 127.0
    via_requant = ref.requantize_ref(acc, requant_scale(s_in, s_w, s_out))
    direct = jnp.clip(jnp.round(float_act / s_out), -128, 127)
    assert int(jnp.max(jnp.abs(
        via_requant.astype(jnp.int32) - direct.astype(jnp.int32)))) <= 1


def test_vgg_small_runs():
    plan = network.vgg_small()
    params = plan.init_params(RNG)
    x = jnp.asarray(RNG.normal(size=(2, *plan.input_shape)), jnp.float32)
    qnet = network.quantize_network(plan, params, x)
    program = network.make_int8_program(
        qnet, ConvCoreConfig(backend="ref", int8=True))
    want = plan.apply_ref(params, x)
    got = program(x)
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < 0.15, rel


def test_per_channel_scales_end_to_end():
    """Per-channel (kout-bank) weight scales ride the fused requantize
    epilogue end-to-end: [K] requant vectors, both backends bit-identical,
    and accuracy no worse than per-tensor (usually better — that is the
    point of per-channel calibration)."""
    plan, params, x = _lenet_setup()
    want = plan.apply_ref(params, x)
    qpc = network.quantize_network(plan, params, x, per_channel=True)
    assert qpc.per_channel
    # every non-final parametric layer carries a [K] requant vector
    for sp, rq, shp in zip(plan.layers, qpc.requants, plan.param_shapes()):
        if sp.kind in ("conv", "dense") and rq is not None:
            assert rq.shape == (shp["b"][0],), (sp.kind, rq.shape)
    a = network.make_int8_program(
        qpc, ConvCoreConfig(backend="pallas", int8=True))(x)
    b = network.make_int8_program(
        qpc, ConvCoreConfig(backend="ref", int8=True))(x)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    qpt = network.quantize_network(plan, params, x)
    pt = network.make_int8_program(
        qpt, ConvCoreConfig(backend="ref", int8=True))(x)
    rel_pc = float(jnp.linalg.norm(a - want) / jnp.linalg.norm(want))
    rel_pt = float(jnp.linalg.norm(pt - want) / jnp.linalg.norm(want))
    assert rel_pc < 0.1, rel_pc
    assert rel_pc <= rel_pt * 1.25, (rel_pc, rel_pt)   # no regression


def _head_plan():
    """Classifier head without flatten + giant dense: avg-pool then a
    global average pool straight into the logits layer."""
    return network.NetworkPlan(
        name="gap_head", input_shape=(16, 16, 4),
        layers=(
            network.conv(8, relu=True, pool=True),
            network.conv(16, relu=True),
            network.avgpool(2),
            network.global_pool(),
            network.dense(10),
        ))


def test_avg_and_global_pool_shapes_and_oracle():
    plan = _head_plan()
    assert plan.activation_shapes() == [
        (8, 8, 8), (8, 8, 16), (4, 4, 16), (16,), (10,)]
    # dense consumes the global-pooled channel vector — no flatten layer
    assert plan.param_shapes()[-1] == {"w": (16, 10), "b": (10,)}
    params = plan.init_params(RNG)
    x = jnp.asarray(RNG.normal(size=(2, *plan.input_shape)), jnp.float32)
    got = plan.apply_ref(params, x)
    h = x
    h = ref.conv2d_epilogue_ref(h, params[0]["w"], params[0]["b"],
                                padding="SAME", relu=True, pool=True)
    h = ref.conv2d_epilogue_ref(h, params[1]["w"], params[1]["b"],
                                padding="SAME", relu=True)
    h = ref.avgpool2d_ref(h, 2)
    h = jnp.mean(h, axis=(1, 2))
    h = h @ params[-1]["w"] + params[-1]["b"]
    np.testing.assert_allclose(got, h, rtol=1e-5, atol=1e-5)


def test_avg_global_pool_int8_program():
    plan = _head_plan()
    params = plan.init_params(RNG)
    x = jnp.asarray(RNG.normal(size=(4, *plan.input_shape)), jnp.float32)
    qnet = network.quantize_network(plan, params, x)
    a = network.make_int8_program(
        qnet, ConvCoreConfig(backend="pallas", int8=True))(x)
    b = network.make_int8_program(
        qnet, ConvCoreConfig(backend="ref", int8=True))(x)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    want = plan.apply_ref(params, x)
    rel = float(jnp.linalg.norm(a - want) / jnp.linalg.norm(want))
    assert rel < 0.15, rel
    # pooling layers are free in the paper's psum accounting
    rows = dict(plan.psum_table())
    assert rows["avgpool2"] == 0 and rows["globalpool3"] == 0


def test_vgg_small_64_and_imagenet_plans_compile():
    """Per-layer TilePlans let larger-input plans compile: every conv
    layer gets a plan that fits the VMEM budget."""
    for plan in (network.vgg_small((64, 64, 4)),
                 network.vgg_imagenet(), network.large_map()):
        tps = plan.tile_plans()
        convs = [tp for tp in tps if tp is not None]
        assert len(convs) == sum(
            1 for sp in plan.layers if sp.kind == "conv")
        assert all(tp.fits_vmem for tp in convs), plan.name
    # the large-map plan's first layer genuinely exceeds the whole-map
    # budget and compiles onto spatial tiles
    whole = network.large_map().tile_plans(vmem_budget=None)
    assert any(not tp.fits_vmem for tp in whole if tp is not None)
    assert any(tp.tiled for tp in network.large_map().tile_plans()
               if tp is not None)


# ---------------------------------------------------------------------------
# Scheduler: replicated IP cores
# ---------------------------------------------------------------------------


def test_batch_sharded_virtual_cores_exact():
    plan, params, x = _lenet_setup(batch=4)
    qnet = network.quantize_network(plan, params, x)
    program = network.make_int8_program(
        qnet, ConvCoreConfig(backend="pallas", int8=True))
    want = program(x)
    sched = scheduler.MultiCoreScheduler(
        scheduler.SchedulerConfig(n_cores=2))
    got = sched.run(program, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("inner", ["ref", "pallas"])
def test_kout_sharded_backend_exact(inner):
    """Kernel-set division across cores == the unsharded network (the
    pallas case also checks per-shard bank-plan rebanking)."""
    plan, params, x = _lenet_setup(batch=2)
    qnet = network.quantize_network(plan, params, x)
    base = network.make_int8_program(
        qnet, ConvCoreConfig(backend="ref", int8=True))(x)
    sched = scheduler.MultiCoreScheduler(
        scheduler.SchedulerConfig(n_cores=4, mode="kout"))
    kb = sched.shard_backend(inner)
    register_backend(kb)
    got = network.make_int8_program(
        qnet, ConvCoreConfig(backend=kb.name, int8=True))(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))


def test_kout_mode_run_passes_batch_through():
    """mode='kout' must not batch-split (cores divide kernels instead), so
    batch=1 single-image latency mode works."""
    plan, params, x = _lenet_setup(batch=1)
    qnet = network.quantize_network(plan, params, x)
    program = network.make_int8_program(
        qnet, ConvCoreConfig(backend="ref", int8=True))
    sched = scheduler.MultiCoreScheduler(
        scheduler.SchedulerConfig(n_cores=4, mode="kout"))
    got = sched.run(program, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(program(x)))


def test_kout_shards_degrade_for_awkward_channels():
    kb = scheduler.KoutShardedBackend(get_backend("ref"), 4)
    assert kb._shards(8) == 4
    assert kb._shards(10) == 2
    assert kb._shards(1) == 1


# ---------------------------------------------------------------------------
# Serving + perfmodel consumers
# ---------------------------------------------------------------------------


def test_convnet_serving_engine_pads_partial_batches():
    plan, params, x = _lenet_setup(batch=4)
    qnet = network.quantize_network(plan, params, x)
    engine = ConvNetEngine(qnet, batch=4, n_cores=2, backend="pallas")
    program = network.make_int8_program(
        qnet, ConvCoreConfig(backend="pallas", int8=True))
    imgs = np.asarray(RNG.normal(size=(6, 28, 28, 1)), np.float32)
    logits = engine.submit(imgs)
    assert logits.shape == (6, 10)
    want = program(jnp.asarray(imgs[:4]))
    np.testing.assert_array_equal(logits[:4], np.asarray(want))
    assert engine.stats == {"requests": 6, "batches": 2, "padded": 2}
    # empty request list keeps the [R, K] contract
    assert engine.submit(np.zeros((0, 28, 28, 1), np.float32)).shape \
        == (0, 10)


def test_network_perf_report():
    plan = network.lenet()
    rep = plan.perf_report()
    # layer-at-a-time: total == sum of per-layer cycle counts
    assert rep["cycles"] == sum(r["cycles"] for r in rep["layers"])
    assert rep["cycles"] > 0 and rep["seconds"] > 0
    # one IP core sustains the paper's 0.224 GOPS on psum-dense networks
    assert rep["gops_paper"] == pytest.approx(0.224, rel=1e-2)
    fb = rep["full_board"]
    assert fb["ip_cores"] == 20
    assert fb["seconds"] < rep["seconds"] / 10      # ≥10× from 20 cores
    assert fb["gops_paper"] == pytest.approx(4.48, rel=0.05)


def test_psum_count_stride_padding():
    # SAME stride-1: output pixels == input pixels
    assert perfmodel.psum_count(14, 14, 8, 16, 3, 3, 1, "SAME") \
        == 14 * 14 * 16 * 8
    # stride-2 SAME: ceil(14/2)=7
    assert perfmodel.psum_count(14, 14, 8, 16, 3, 3, 2, "SAME") \
        == 7 * 7 * 16 * 8
    # VALID unchanged vs the seed accounting
    assert perfmodel.psum_count(224, 224, 8, 8) == 3_154_176
