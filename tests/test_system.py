"""End-to-end system behaviour: the paper's workload through the full
ConvCore path, a small LM trained until the loss drops, and int8-compressed
training staying close to the uncompressed trajectory."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduce_config
from repro.core import ConvCore, ConvCoreConfig
from repro.core.perfmodel import gops_paper, psum_count, seconds
from repro.data.pipeline import DataConfig, make_pipeline
from repro.distributed.compression import compress_grads, init_ef_state
from repro.kernels import ref
from repro.layers.common import materialize
from repro.optim.adamw import AdamWConfig, adamw_update
from repro.train.train_step import (_loss_fn, init_state_specs,
                                    make_train_step)


def test_paper_pipeline_end_to_end():
    """The §5.2 scenario: quantize a float layer, run the banked int8 IP
    core, compare against the float oracle, and report the modeled speed."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, 224, 224, 8)), jnp.float32) * 0.5
    w = jnp.asarray(rng.normal(size=(3, 3, 8, 8)), jnp.float32) * 0.1
    b = jnp.asarray(rng.normal(size=(8,)), jnp.float32) * 0.1

    core = ConvCore(ConvCoreConfig(backend="pallas"))
    got = core.apply_quantized_layer(x, w, b)
    want = ref.conv2d_ref(x, w, b)
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < 0.03, rel

    n = psum_count(224, 224, 8, 8)
    assert abs(seconds(n) - 0.01408) < 1e-4
    assert abs(gops_paper(n) - 0.224) < 1e-3


def test_tiny_lm_trains():
    """~0.5M-param llama-family model on synthetic data: loss must drop
    substantially within 30 steps (the learnable Markov structure)."""
    cfg = reduce_config(get_config("llama3p2_3b"))
    sspecs = init_state_specs(cfg)
    state = {
        "params": materialize(sspecs["params"], jax.random.PRNGKey(0)),
        "opt": materialize(sspecs["opt"], jax.random.PRNGKey(1)),
        "step": jnp.zeros((), jnp.int32),
    }
    pipe = make_pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=8, seed=1))
    step_fn = jax.jit(make_train_step(
        cfg, AdamWConfig(peak_lr=1e-2, warmup_steps=5, total_steps=60)))
    losses = []
    for s in range(40):
        batch = jax.tree.map(jnp.asarray, pipe.batch_at(s))
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 1.0, losses[::8]


def test_grad_accumulation_equivalence():
    """accum_steps=4 must equal the monolithic step up to float tolerance
    (same global batch)."""
    cfg = reduce_config(get_config("llama3p2_3b"))
    sspecs = init_state_specs(cfg)
    state = {
        "params": materialize(sspecs["params"], jax.random.PRNGKey(0)),
        "opt": materialize(sspecs["opt"], jax.random.PRNGKey(1)),
        "step": jnp.zeros((), jnp.int32),
    }
    pipe = make_pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                    global_batch=8, seed=2))
    batch = jax.tree.map(jnp.asarray, pipe.batch_at(0))
    hp = AdamWConfig(warmup_steps=1, total_steps=10)
    s1, m1 = jax.jit(make_train_step(cfg, hp, accum_steps=1))(state, batch)
    s4, m4 = jax.jit(make_train_step(cfg, hp, accum_steps=4))(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5, atol=1e-5)
    worst = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))),
        s1["params"], s4["params"])))
    assert worst < 2e-4, worst


def test_compressed_training_tracks_uncompressed():
    """int8 error-feedback gradient compression: after N steps the weights
    stay close to the uncompressed trajectory (the distributed-optimization
    trick is usable, not just decorative)."""
    cfg = reduce_config(get_config("llama3p2_3b"))
    sspecs = init_state_specs(cfg)

    def init():
        return {
            "params": materialize(sspecs["params"], jax.random.PRNGKey(0)),
            "opt": materialize(sspecs["opt"], jax.random.PRNGKey(1)),
            "step": jnp.zeros((), jnp.int32),
        }

    pipe = make_pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                    global_batch=4, seed=3))
    hp = AdamWConfig(peak_lr=1e-3, warmup_steps=2, total_steps=10)

    @jax.jit
    def raw_step(state, batch):
        (_, _), grads = jax.value_and_grad(_loss_fn, has_aux=True)(
            state["params"], batch, cfg)
        p, o, _ = adamw_update(state["params"], grads, state["opt"],
                               state["step"], hp)
        return {"params": p, "opt": o, "step": state["step"] + 1}

    @jax.jit
    def comp_step(state, ef, batch):
        (_, _), grads = jax.value_and_grad(_loss_fn, has_aux=True)(
            state["params"], batch, cfg)
        grads, ef = compress_grads(grads, ef)
        p, o, _ = adamw_update(state["params"], grads, state["opt"],
                               state["step"], hp)
        return {"params": p, "opt": o, "step": state["step"] + 1}, ef

    s_raw, s_cmp = init(), init()
    ef = init_ef_state(s_cmp["params"])
    for s in range(8):
        batch = jax.tree.map(jnp.asarray, pipe.batch_at(s))
        s_raw = raw_step(s_raw, batch)
        s_cmp, ef = comp_step(s_cmp, ef, batch)
    deltas = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))),
        s_raw["params"], s_cmp["params"])
    num = max(jax.tree.leaves(deltas))
    # AdamW normalizes per-parameter, so int8 noise perturbs the path by
    # O(lr) per step at most; after 8 steps the trajectories must still be
    # within a few lr-units of each other (compression is usable, not free)
    assert num < 8 * 2 * hp.peak_lr, (num, deltas)
    assert all(np.isfinite(v) for v in jax.tree.leaves(deltas))
