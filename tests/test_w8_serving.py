"""w8a8 serving (the paper's 8-bit datapath on the LM): quantized decode
must stay close to the f32 path — top-1 agreement + bounded logit error."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduce_config
from repro.core.quantize import (quantize_weight_specs, quantize_weights,
                                 w8_einsum)
from repro.layers.common import materialize, shape_structs
from repro.models import lm


def test_w8_einsum_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    wq = quantize_weights({"m": {"w": w}})["m"]["w"]
    got = w8_einsum("md,dn->mn", x, wq["q"], wq["s"],
                    compute_dtype=jnp.float32)
    want = x @ w
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < 0.02, rel


def test_w8_specs_match_weights():
    cfg = reduce_config(get_config("llama3_8b"))
    pspecs = quantize_weight_specs(lm.param_specs(cfg))
    params = materialize(lm.param_specs(cfg), jax.random.PRNGKey(0))
    qparams = quantize_weights(params, lm.param_specs(cfg))
    spec_struct = jax.tree.structure(shape_structs(pspecs))
    q_struct = jax.tree.structure(qparams)
    assert spec_struct == q_struct
    # shapes line up leaf by leaf
    for s, q in zip(jax.tree.leaves(shape_structs(pspecs)),
                    jax.tree.leaves(qparams)):
        assert s.shape == q.shape, (s.shape, q.shape)
        assert s.dtype == q.dtype, (s.dtype, q.dtype)


def test_quantized_decode_close_to_f32():
    cfg = reduce_config(get_config("llama3_8b"))
    params = materialize(lm.param_specs(cfg), jax.random.PRNGKey(1))
    rng = np.random.default_rng(2)
    B, S = 2, 24
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}

    last_f32, cache = lm.prefill(params, batch, cfg, cache_len=S + 4)

    qparams = quantize_weights(params, lm.param_specs(cfg))
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8",
                               kv_cache_scale=0.25)
    last_q, cache_q = lm.prefill(qparams, batch, cfg8, cache_len=S + 4)
    # prefill caches produced by the f32 path are bf16/compute-typed; for
    # the int8-cache decode test quantize them the way a serving engine
    # would (same fixed scale)
    cache_q = jax.tree.map(
        lambda t: (jnp.clip(jnp.round(t.astype(jnp.float32)
                                      / cfg8.kv_cache_scale), -128, 127)
                   .astype(jnp.int8)
                   if t.dtype == jnp.dtype(cfg.compute_dtype) and t.ndim == 4
                   else t), cache)

    # quantized prefill logits track f32 (same argmax, small relative error)
    rel = float(jnp.linalg.norm(last_q - last_f32)
                / jnp.linalg.norm(last_f32))
    assert rel < 0.15, rel
    agree = float(jnp.mean(jnp.argmax(last_q, -1) == jnp.argmax(last_f32, -1)))
    assert agree >= 0.5, agree

    # quantized decode step runs and stays finite + close in distribution
    tok = jnp.argmax(last_f32, -1).astype(jnp.int32)
    pos = jnp.full((B,), S, jnp.int32)
    lg_f32, _ = lm.decode_step(params, cfg, token=tok, pos=pos, cache=cache)
    lg_q, _ = lm.decode_step(qparams, cfg8, token=tok, pos=pos, cache=cache_q)
    assert bool(jnp.all(jnp.isfinite(lg_q)))
    p = jax.nn.softmax(lg_f32, -1)
    q = jax.nn.softmax(lg_q, -1)
    tv = float(0.5 * jnp.mean(jnp.sum(jnp.abs(p - q), axis=-1)))
    assert tv < 0.5, tv
