"""Grouped/depthwise conv contract end-to-end: the grouped WS kernel vs
the oracle (bit-exact int8, every groups × stride × padding × epilogue ×
tiling combination), the grouped planner invariants, the group-aligned
kout-sharding contract (and its loud failure mode), the rerouted
conv1d_depthwise, the MobileNet zoo (depthwise-separable and
inverted-residual plans bit-exact ref↔pallas under every scheduler mode),
and the grouped §5.2 accounting — depthwise layers sit on the shared-DMA
floor, which the perfmodel rows must show."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import banking, network, perfmodel, scheduler
from repro.core.convcore import (ConvCoreConfig, get_backend,
                                 register_backend)
from repro.kernels import ops, ref
from repro.kernels.conv2d_ws import conv2d_ws

RNG = np.random.default_rng(31)


def _i8(*shape):
    return jnp.asarray(RNG.integers(-128, 128, size=shape), jnp.int8)


def _f32(*shape):
    return jnp.asarray(RNG.normal(size=shape), jnp.float32)


# ---------------------------------------------------------------------------
# Grouped kernel vs oracle (deterministic grid of the hard cases)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("groups", [1, 2, 4, 8])
@pytest.mark.parametrize("stride", [1, 2])
def test_grouped_int8_bit_exact(groups, stride):
    """Grouped channel contraction, dense through depthwise (C=K=8,
    groups=8), bit-exact vs the lax grouped oracle."""
    c = k = 8
    x, w = _i8(2, 11, 9, c), _i8(3, 3, c // groups, k)
    b = jnp.asarray(RNG.integers(-500, 500, (k,)), jnp.int32)
    cb, kb = ref.grouped_banks(c, k, groups)
    got = conv2d_ws(x, w, b, stride=stride, padding="SAME", groups=groups,
                    cin_banks=cb, kout_banks=kb, interpret=True)
    want = ref.conv2d_ref_int8(x, w, b, stride=stride, padding="SAME",
                               groups=groups)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_grouped_uneven_group_width():
    """groups that divide C and K but not each other's bank defaults
    (C=6, K=12, groups=3): the bank degrade keeps the kernel legal."""
    x, w = _i8(1, 9, 9, 6), _i8(3, 3, 2, 12)
    got = ops.conv2d(x, w, groups=3)
    want = ref.conv2d_ref_int8(x, w, groups=3)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_depthwise_tiled_fused_epilogue_bit_exact():
    """The full production stack on a depthwise layer: halo'd spatial
    tiles + fused ReLU → 2×2 pool → per-channel requantize, bit-exact."""
    c = 8
    x, w = _i8(2, 14, 18, c), _i8(3, 3, 1, c)
    b = jnp.asarray(RNG.integers(-500, 500, (c,)), jnp.int32)
    sc = jnp.asarray(RNG.uniform(5e-4, 2e-3, (c,)), jnp.float32)
    got = conv2d_ws(x, w, b, sc, padding="SAME", groups=c, cin_banks=1,
                    kout_banks=c, h_tile=4, w_tile=6, relu=True, pool=True,
                    interpret=True)
    want = ref.conv2d_epilogue_ref(x, w, b, padding="SAME", groups=c,
                                   relu=True, pool=True, out_scale=sc)
    assert got.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_grouped_contract_errors():
    """The grouped divisibility contract fails loudly and identically
    across oracle, kernel, and planner."""
    x, w = _i8(1, 8, 8, 6), _i8(3, 3, 2, 8)     # groups=3 divides C not K
    with pytest.raises(ValueError, match="groups=3"):
        ref.conv2d_ref_int8(x, w, groups=3)
    with pytest.raises(ValueError, match="groups=3"):
        conv2d_ws(x, w, groups=3, cin_banks=1, kout_banks=3,
                  interpret=True)
    with pytest.raises(ValueError, match="groups=3"):
        banking.plan_tiles(8, 8, 6, 8, groups=3, cin_banks=1, kout_banks=3)
    # kout banks straddling group boundaries are rejected, not misread
    x2, w2 = _i8(1, 8, 8, 8), _i8(3, 3, 2, 8)
    with pytest.raises(ValueError, match="group boundaries"):
        conv2d_ws(x2, w2, groups=4, cin_banks=1, kout_banks=2,
                  interpret=True)


def test_grouped_banks_invariants():
    """grouped_banks always returns kernel-legal banking: cin banks divide
    the per-group slice, kout banks are group-aligned with per-group
    counts dividing K/groups."""
    for c, k, g in [(8, 8, 1), (8, 8, 2), (8, 16, 4), (16, 16, 16),
                    (6, 12, 3), (12, 4, 2), (1, 4, 1)]:
        cb, kb = ref.grouped_banks(c, k, g)
        assert (c // g) % cb == 0
        assert k % kb == 0 and kb % g == 0, (c, k, g, cb, kb)


def test_plan_tiles_grouped_working_set():
    """Grouped TilePlans size the per-group working set: image and weight
    blocks carry C/groups-channel slices, and the plan records its group
    structure for traffic pricing."""
    p = banking.plan_tiles(16, 16, 32, 32, groups=32, cin_banks=1,
                           kout_banks=32, in_bytes=1, out_bytes=1)
    assert p.groups == 32
    assert p.image_block_bytes == p.in_h_tile * p.in_w_tile * 1
    assert p.weight_block_bytes == 9 * 1 * (32 // p.kout_banks)
    # a kout sweep (kout_banks × cin_banks group slices) covers the input
    # map exactly once per tile set — grouped reads don't multiply
    t = perfmodel.tile_traffic(p)
    whole_input = p.n_tiles * p.in_h_tile * p.in_w_tile * 32
    assert t["input_bytes"] == whole_input


# ---------------------------------------------------------------------------
# Hypothesis sweep (guarded import, like tests/test_property.py)
# ---------------------------------------------------------------------------


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @st.composite
    def grouped_case(draw):
        groups = draw(st.sampled_from([1, 2, 4, 8]))
        cg = draw(st.sampled_from([1, 2]))
        kg = draw(st.sampled_from([1, 2, 4]))
        c, k = groups * cg, groups * kg
        h = draw(st.integers(6, 12))
        w = draw(st.integers(6, 12))
        kh = draw(st.sampled_from([1, 3]))
        stride = draw(st.sampled_from([1, 2]))
        padding = draw(st.sampled_from(
            ["SAME", "VALID", ((1, 0), (0, 2))]))
        relu = draw(st.booleans())
        pool = draw(st.booleans())
        oh, ow = ref.conv_out_shape(h, w, kh, kh, stride, padding)
        if pool and (oh < 2 or ow < 2):
            pool = False
        tile = draw(st.sampled_from([0, 2, 4]))
        requant = draw(st.booleans())
        seed = draw(st.integers(0, 2**31 - 1))
        return (groups, c, k, h, w, kh, stride, padding, relu, pool,
                tile, requant, seed)

    @given(grouped_case())
    @settings(max_examples=20, deadline=None)
    def test_grouped_conv_bit_exact_property(case):
        """groups × stride × padding × epilogue × tiling: the grouped WS
        kernel is bit-exact vs the grouped oracle in int8."""
        (groups, c, k, h, w, kh, stride, padding, relu, pool, tile,
         requant, seed) = case
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.integers(-128, 128, (1, h, w, c)), jnp.int8)
        wt = jnp.asarray(rng.integers(-128, 128, (kh, kh, c // groups, k)),
                         jnp.int8)
        b = jnp.asarray(rng.integers(-500, 500, (k,)), jnp.int32)
        sc = (jnp.asarray(rng.uniform(5e-4, 2e-3, (k,)), jnp.float32)
              if requant else None)
        got = ops.conv2d(x, wt, b, stride=stride, padding=padding,
                         groups=groups, h_tile=tile, w_tile=tile,
                         relu=relu, pool=pool, out_scale=sc)
        want = ref.conv2d_epilogue_ref(x, wt, b, stride=stride,
                                       padding=padding, groups=groups,
                                       relu=relu, pool=pool, out_scale=sc)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# conv1d_depthwise: rerouted through the grouped WS kernel
# ---------------------------------------------------------------------------


def test_conv1d_depthwise_matches_ref_oracle():
    for dt in (jnp.float32, jnp.bfloat16):
        x = jnp.asarray(RNG.normal(size=(2, 12, 8)), dt)
        w = jnp.asarray(RNG.normal(size=(4, 8)), jnp.float32)
        b = jnp.asarray(RNG.normal(size=(8,)), jnp.float32)
        got = ops.conv1d_depthwise(x, w, b)
        want = ref.conv1d_depthwise_ref(x, w, b)
        assert got.dtype == x.dtype
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=2e-2, atol=2e-2)


def test_conv1d_depthwise_differentiable():
    """The reroute must keep the op differentiable (it goes through
    ops.conv2d's grouped custom VJP, not the raw kernel): gradients match
    jax.grad of the pure-jnp ref oracle."""
    import jax
    x = _f32(1, 6, 4)
    w = jnp.asarray(RNG.normal(size=(3, 4)), jnp.float32)
    probe = _f32(1, 6, 4)
    got = jax.grad(lambda x, w: jnp.sum(
        ops.conv1d_depthwise(x, w) * probe), (0, 1))(x, w)
    want = jax.grad(lambda x, w: jnp.sum(
        ref.conv1d_depthwise_ref(x, w) * probe), (0, 1))(x, w)
    for g, wn in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(wn),
                                   rtol=1e-4, atol=1e-4)


def test_conv1d_depthwise_is_causal():
    """Output at step t must not see inputs after t (the left-pad
    contract the WS rerouting has to preserve)."""
    x = _f32(1, 10, 4)
    w = jnp.asarray(RNG.normal(size=(4, 4)), jnp.float32)
    full = ops.conv1d_depthwise(x, w)
    x2 = x.at[:, 7:].set(0.0)
    np.testing.assert_allclose(np.asarray(ops.conv1d_depthwise(x2, w)[:, :7]),
                               np.asarray(full[:, :7]), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Kout sharding: group-aligned kernel-set division
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("inner", ["ref", "pallas"])
@pytest.mark.parametrize("groups,cores", [(4, 2), (8, 4), (2, 4), (8, 8)])
def test_kout_sharded_grouped_exact(inner, groups, cores):
    """Group-aligned kernel-set division == the unsharded grouped conv:
    whole-group shards (cores ≤ groups) and within-group shards
    (cores > groups) both stay bit-exact, each core reading only its
    groups' cin slice."""
    c = k = 8
    x, w = _i8(2, 9, 9, c), _i8(3, 3, c // groups, k)
    b = jnp.asarray(RNG.integers(-300, 300, (k,)), jnp.int32)
    base = get_backend("ref").conv(x, w, b, stride=1, padding="SAME",
                                   groups=groups, relu=True)
    kb = scheduler.KoutShardedBackend(get_backend(inner), cores)
    got = kb.conv(x, w, b, stride=1, padding="SAME", groups=groups,
                  relu=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))


def test_kout_sharded_grouped_raises_on_misaligned_split():
    """Cores that would cut through a group mid-slice raise with the
    offending shapes instead of silently degrading the core count."""
    kb = scheduler.KoutShardedBackend(get_backend("ref"), 4)
    x, w = _i8(1, 8, 8, 6), _i8(3, 3, 1, 6)
    with pytest.raises(ValueError, match="K=6.*groups=6.*4 cores"):
        kb.conv(x, w, groups=6)
    # a dense conv with the same K still degrades silently (paper mode)
    wd = _i8(3, 3, 6, 6)
    out = kb.conv(x, wd)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(get_backend("ref").conv(x, wd)))


# ---------------------------------------------------------------------------
# MobileNet zoo: the edge workload family end-to-end
# ---------------------------------------------------------------------------


def _net_setup(make, batch=2, per_channel=True):
    plan = make()
    params = plan.init_params(RNG)
    x = jnp.asarray(RNG.normal(size=(batch, *plan.input_shape)),
                    jnp.float32)
    qnet = network.quantize_network(plan, params, x,
                                    per_channel=per_channel)
    return plan, params, x, qnet


def test_mobilenet_shapes_params_and_geometry():
    plan = network.mobilenet_small()
    names = plan.node_names()
    shapes = plan.param_shapes()
    geoms = plan.conv_geometries()
    d1 = names.index("d1")
    # depthwise weights carry the per-group (1-channel) slice
    assert shapes[d1] == {"w": (3, 3, 1, 8), "b": (8,)}
    assert geoms[d1] == (8, 8)
    p1 = names.index("p1")
    assert shapes[p1] == {"w": (1, 1, 8, 16), "b": (16,)}
    assert geoms[p1] == (16, 1)
    # depthwise psums are a factor-C cheaper than the dense equivalent
    rows = dict(plan.psum_table())
    assert rows["d1"] == 16 * 16 * 8              # oh·ow·K·(C/groups)
    assert rows["p1"] == 16 * 16 * 16 * 8


def test_mobilenet_v2ish_reuses_residual_merge():
    plan = network.mobilenet_v2ish()
    names = plan.node_names()
    ins = plan.resolved_inputs()
    m1 = names.index("m1")
    assert plan.layers[m1].kind == "add"
    assert set(ins[m1]) == {names.index("stem"), names.index("m1p")}


@pytest.mark.parametrize("make", [network.mobilenet_small,
                                  network.mobilenet_v2ish])
@pytest.mark.parametrize("per_channel", [False, True])
def test_mobilenet_int8_backends_bit_identical(make, per_channel):
    """Acceptance: both MobileNets compile through make_int8_program
    bit-exact ref↔pallas (incl. per-channel scales within groups) and
    stay within quantization tolerance of the float oracle."""
    plan, params, x, qnet = _net_setup(make, per_channel=per_channel)
    a = network.make_int8_program(
        qnet, ConvCoreConfig(backend="pallas", int8=True))(x)
    b = network.make_int8_program(
        qnet, ConvCoreConfig(backend="ref", int8=True))(x)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    want = plan.apply_ref(params, x)
    rel = float(jnp.linalg.norm(a - want) / jnp.linalg.norm(want))
    assert rel < 0.15, rel


@pytest.mark.parametrize("make", [network.mobilenet_small,
                                  network.mobilenet_v2ish])
@pytest.mark.parametrize("mode", ["batch", "kout", "spatial"])
def test_mobilenet_bit_exact_all_scheduler_modes(make, mode):
    """Acceptance: grouped convs stay bit-exact ref↔pallas under every
    scheduler mode — kout shards split along group boundaries."""
    plan, params, x, qnet = _net_setup(make)
    outs = []
    for backend in ("ref", "pallas"):
        sched = scheduler.MultiCoreScheduler(
            scheduler.SchedulerConfig(n_cores=2, mode=mode))
        name = backend
        if mode != "batch":
            sb = sched.shard_backend(backend)
            register_backend(sb)
            name = sb.name
        program = network.make_int8_program(
            qnet, ConvCoreConfig(backend=name, int8=True))
        outs.append(sched.run(program, x))
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(outs[1]))


def test_mobilenet_tile_plans_fit_and_carry_groups():
    for make in (network.mobilenet_small, network.mobilenet_v2ish):
        plan = make()
        geoms = plan.conv_geometries()
        tps = plan.tile_plans()
        for tp, geom in zip(tps, geoms):
            assert (tp is None) == (geom is None)
            if tp is not None:
                assert tp.fits_vmem
                assert tp.groups == geom[1]
                assert tp.kout_banks % tp.groups == 0


def test_depthwise_layers_sit_on_dma_floor():
    """The grouped §5.2 accounting: a depthwise layer computes a
    factor-C fewer psums than its dense shape-twin while moving the same
    maps, so the SHARED DMA interface binds it on the full board — the
    perf report's dma_bound flags must show exactly that."""
    plan = network.mobilenet_small((32, 32, 8))
    rep = plan.perf_report(tile_plans=plan.tile_plans())
    rows = {r["name"]: r for r in rep["layers"] if "dma_bound" in r}
    geoms = dict(zip(plan.node_names(), plan.conv_geometries()))
    dw = [n for n, g in geoms.items() if g is not None and g[1] > 1]
    assert dw, "plan must contain depthwise layers"
    for name in dw:
        assert rows[name]["dma_bound_board"], (name, rows[name])
    assert rep["dma_bound_board_layers"] >= len(dw)
    # the arithmetic-intensity contrast: vs a dense shape-twin, the
    # depthwise layer's compute collapses by the group factor while its
    # map traffic stays put — so on the full board (compute ÷ 20 cores,
    # DMA shared) the depthwise layer is firmly DMA-bound
    d1 = plan.node_names().index("d1")
    h, w, c = plan.activation_shapes()[plan.node_names().index("stem")]
    dw_psums = perfmodel.psum_count(h, w, c, c, 3, 3, 1, "SAME", groups=c)
    dense_psums = perfmodel.psum_count(h, w, c, c, 3, 3, 1, "SAME")
    assert dense_psums == c * dw_psums
    tp_dw = plan.tile_plans()[d1]
    tp_dense = banking.plan_tiles(h, w, c, c, stride=1, padding="SAME",
                                  in_bytes=1, out_bytes=1)
    dma_dw = perfmodel.dma_cycles(
        perfmodel.tile_traffic(tp_dw)["total_bytes"])
    dma_dense = perfmodel.dma_cycles(
        perfmodel.tile_traffic(tp_dense)["total_bytes"])
    ai_dw = perfmodel.cycles(dw_psums) / dma_dw
    ai_dense = perfmodel.cycles(dense_psums) / dma_dense
    # (the dense twin pays kout-revisit re-reads too, so the observed gap
    # is the group factor divided by the revisit count — still a clear
    # separation)
    assert ai_dw * 2 < ai_dense, (ai_dw, ai_dense)
    board = perfmodel.IPCoreConfig(ip_cores=20)
    assert dma_dw > perfmodel.cycles(dw_psums, board)
