"""The paper's §5.2 numbers, reproduced exactly (the reproduction contract)."""

import pytest

from repro.core import perfmodel


def test_psum_count_matches_paper():
    # [224x224x8] ⊛ [8x3x3x8] → "the system needs to compute 3,154,176 psum
    # values" (= 222·222·8·8)
    assert perfmodel.psum_count(224, 224, 8, 8) == 3_154_176


def test_seconds_matches_paper():
    n = perfmodel.psum_count(224, 224, 8, 8)
    # "the theory time needed for computing this sample, which is 0.01408 s"
    assert perfmodel.seconds(n) == pytest.approx(0.01408, rel=1e-3)


def test_gops_single_ip_core():
    n = perfmodel.psum_count(224, 224, 8, 8)
    # "the throughput of a single core is 0.224 GOPS"
    assert perfmodel.gops_paper(n) == pytest.approx(0.224, rel=1e-3)


def test_gops_twenty_cores():
    n = perfmodel.psum_count(224, 224, 8, 8)
    cfg = perfmodel.IPCoreConfig(ip_cores=20)
    # "when 20 cores are deployed ... up to 4.48 GOPS"
    assert perfmodel.gops_paper(n, cfg) == pytest.approx(4.48, rel=1e-2)


def test_macs_accounting():
    n = perfmodel.psum_count(224, 224, 8, 8)
    # 1 psum = 9 MACs = 18 ops → 0.224 × 18 = 4.032 standard GOPS
    assert perfmodel.gops_macs(n) == pytest.approx(0.224 * 18, rel=1e-3)


def test_16_psums_per_8_cycles():
    cfg = perfmodel.IPCoreConfig()
    assert perfmodel.cycles(16, cfg) == 8
    assert perfmodel.cycles(17, cfg) == 16  # next batch


def test_tpu_roofline_sane():
    r = perfmodel.tpu_conv_roofline(224, 224, 8, 8)
    assert r["seconds"] > 0
    # the paper layer is tiny: a single v5e core is memory-bound on it
    assert r["t_memory"] > r["t_compute"]
    # and still orders of magnitude faster than the FPGA
    assert r["gops_paper"] > 0.224 * 10
