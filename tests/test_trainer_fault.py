"""Fault tolerance: an injected mid-run failure must recover from the last
checkpoint and produce a loss trajectory IDENTICAL to an uninterrupted run.
Plus straggler detection."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduce_config
from repro.data.pipeline import DataConfig, make_pipeline
from repro.layers.common import materialize
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import init_state_specs, make_train_step
from repro.train.trainer import (StragglerMonitor, Trainer, TrainerConfig)


def _setup(tmp_path, fail_at=(), total=12):
    cfg = reduce_config(get_config("llama3p2_3b"))
    sspecs = init_state_specs(cfg)
    state = {
        "params": materialize(sspecs["params"], jax.random.PRNGKey(0)),
        "opt": materialize(sspecs["opt"], jax.random.PRNGKey(1)),
        "step": jnp.zeros((), jnp.int32),
    }
    step_fn = jax.jit(make_train_step(
        cfg, AdamWConfig(peak_lr=1e-3, warmup_steps=2, total_steps=total)))
    pipe = make_pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                    global_batch=4, seed=0))
    tc = TrainerConfig(total_steps=total, checkpoint_every=4,
                       checkpoint_dir=str(tmp_path), log_every=0,
                       fail_at_steps=tuple(fail_at),
                       async_checkpoint=False)
    return Trainer(tc, step_fn, pipe, state)


def test_loss_decreases(tmp_path):
    tr = _setup(tmp_path / "a", total=12)
    hist = tr.run()
    losses = [h["loss"] for h in hist]
    assert losses[-1] < losses[0], losses


def test_resume_equals_uninterrupted(tmp_path):
    clean = _setup(tmp_path / "clean", total=12)
    clean_hist = clean.run()

    faulty = _setup(tmp_path / "faulty", fail_at=(6, 9), total=12)
    faulty_hist = faulty.run()
    assert faulty.restarts == 2

    clean_by_step = {h["step"]: h["loss"] for h in clean_hist}
    # after recovery some steps are REPLAYED; the final trajectory must
    # match the clean run exactly at every step (bitwise determinism)
    last = {h["step"]: h["loss"] for h in faulty_hist}
    for step, loss in last.items():
        np.testing.assert_allclose(loss, clean_by_step[step], rtol=0,
                                   atol=0.0, err_msg=f"step {step}")


def test_failure_before_first_checkpoint_is_fatal(tmp_path):
    tr = _setup(tmp_path / "x", fail_at=(0,), total=4)
    # step-0 checkpoint exists by design, so failure at 0 recovers; make the
    # checkpoint directory read-only instead is platform-dependent — assert
    # recovery works (the step-0 snapshot is the guarantee).
    hist = tr.run()
    assert tr.restarts == 1
    assert len(hist) >= 4


def test_straggler_monitor():
    m = StragglerMonitor(factor=2.0, warmup=2)
    for step in range(6):
        assert not m.observe(step, 0.10)
    assert m.observe(6, 0.5)        # 5× the EMA → straggler
    assert len(m.events) == 1
    assert m.events[0]["step"] == 6
    # EMA clipping: a single outlier must not poison the baseline
    assert m.ema < 0.2
