"""Hypothesis invariants for the plan autotuner (ISSUE 7 satellite):
over random legal layer shapes, the chosen plan always fits VMEM,
respects group-aligned banks, is never worse than the greedy
``plan_tiles(kernel="auto")`` plan under the same model, and is
deterministic given a fixed CalibrationTable."""

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import banking  # noqa: E402
from repro.core.autotune import autotune_layer, plan_cost  # noqa: E402
from repro.core.calibration import CalibrationTable  # noqa: E402

_CALIB = CalibrationTable(compute_factor=2.0, dma_bytes_per_cycle=4.0,
                          pipeline_overhead_cycles=32.0)


@st.composite
def _layer_shapes(draw):
    groups = draw(st.sampled_from([1, 1, 1, 2, 4]))
    cgrp = draw(st.sampled_from([1, 2, 4, 8]))
    kg = draw(st.sampled_from([1, 2, 4, 8]))
    h = draw(st.integers(6, 40))
    w = draw(st.integers(6, 40))
    kh = draw(st.sampled_from([1, 3]))
    pool = draw(st.booleans())
    stride = draw(st.sampled_from([1, 2]))
    return dict(h=h, w=w, c=cgrp * groups, k=kg * groups, kh=kh,
                stride=stride, padding="SAME", groups=groups,
                pool=pool and kh == 3 and stride == 1)


@settings(max_examples=30, deadline=None)
@given(shape=_layer_shapes(),
       budget=st.sampled_from([64 * 1024, 512 * 1024, banking.VMEM_BYTES]))
def test_autotuned_plan_fits_and_respects_groups(shape, budget):
    lt = autotune_layer(**shape, vmem_budget=budget, calib=_CALIB)
    tp = lt.plan
    assert tp.fits_vmem or not lt.greedy_plan.fits_vmem, (
        "tuned plan busts VMEM even though candidates were pruned")
    # group alignment: cin banks divide the per-group slice, kout banks
    # are group-aligned divisors of K
    g = shape["groups"]
    assert (shape["c"] // g) % tp.cin_banks == 0
    assert shape["k"] % tp.kout_banks == 0
    assert tp.kout_banks % g == 0 or tp.kout_banks <= g


@settings(max_examples=30, deadline=None)
@given(shape=_layer_shapes())
def test_autotuned_never_worse_than_greedy(shape):
    for calib in (None, _CALIB):
        lt = autotune_layer(**shape, calib=calib)
        assert lt.cycles <= lt.greedy_cycles
        # plan_cost agrees with the stored verdict
        assert plan_cost(lt.plan, lt.psums, calib=calib) == lt.cycles


@settings(max_examples=15, deadline=None)
@given(shape=_layer_shapes())
def test_autotune_deterministic_given_table(shape):
    a = autotune_layer(**shape, calib=_CALIB)
    b = autotune_layer(**shape, calib=_CALIB)
    assert a == b
