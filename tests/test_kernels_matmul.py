"""matmul_ws (generalized paper dataflow) vs oracle + custom-VJP checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.matmul_ws import matmul_ws

RNG = np.random.default_rng(7)


def _f32(*shape):
    return jnp.asarray(RNG.normal(size=shape), jnp.float32)


@pytest.mark.parametrize("m,k,n", [
    (8, 8, 8), (64, 96, 32), (256, 512, 256), (100, 60, 28),  # odd shapes
    (512, 2048, 256),
])
def test_matches_oracle(m, k, n):
    x, w, b = _f32(m, k), _f32(k, n), _f32(n)
    got = matmul_ws(x, w, b, interpret=True)
    want = ref.matmul_ref(x, w, b)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("blocks", [(64, 64, 64), (128, 256, 128),
                                    (32, 512, 256)])
def test_block_shape_invariance(blocks):
    bm, bk, bn = blocks
    x, w = _f32(256, 512), _f32(512, 128)
    got = matmul_ws(x, w, bm=bm, bk=bk, bn=bn, interpret=True)
    np.testing.assert_allclose(got, ref.matmul_ref(x, w), rtol=2e-4, atol=2e-4)


def test_int8_exact():
    x = jnp.asarray(RNG.integers(-128, 128, size=(64, 128)), jnp.int8)
    w = jnp.asarray(RNG.integers(-128, 128, size=(128, 32)), jnp.int8)
    got = matmul_ws(x, w, interpret=True)
    want = ref.matmul_ref_int8(x, w)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(got, want)


def test_custom_vjp_matches_reference_grads():
    x, w, b = _f32(32, 48), _f32(48, 16), _f32(16)

    def f_kernel(x, w, b):
        return jnp.sum(jnp.tanh(ops.matmul_ws(x, w, b)))

    def f_ref(x, w, b):
        return jnp.sum(jnp.tanh(ref.matmul_ref(x, w, b)))

    g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, e in zip(g1, g2):
        np.testing.assert_allclose(a, e, rtol=2e-4, atol=2e-4)


def test_vjp_rejects_int8_and_promotes_cotangent():
    """Regression: the VJP used to cast the cotangent with
    ``g.astype(x.dtype)`` — an int8 forward would silently truncate
    gradients to int8.  Integer operands now raise, and float operands run
    the backward GEMMs in f32, casting only the results back."""
    g = jnp.ones((4, 3), jnp.float32)
    xi = jnp.asarray(RNG.integers(-128, 128, (4, 5)), jnp.int8)
    wi = jnp.asarray(RNG.integers(-128, 128, (5, 3)), jnp.int8)
    with pytest.raises(TypeError, match="float"):
        ops._matmul_bwd((xi, wi, None), g)
    xb = _f32(4, 5).astype(jnp.bfloat16)
    wb = _f32(5, 3).astype(jnp.bfloat16)
    # residuals carry the bias itself (its dtype steers the bias-grad cast)
    dx, dw, db = ops._matmul_bwd((xb, wb, _f32(3)), g)
    assert dx.dtype == jnp.bfloat16 and dw.dtype == jnp.bfloat16
    assert db.shape == (3,) and db.dtype == jnp.float32


def test_bf16_inputs():
    x = _f32(64, 64).astype(jnp.bfloat16)
    w = _f32(64, 32).astype(jnp.bfloat16)
    got = ops.matmul_ws(x, w)
    want = ref.matmul_ref(x, w)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32), want,
                               rtol=2e-2, atol=2e-1)
