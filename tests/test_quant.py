"""int8 quantization (the paper's 8-bit datapath substrate) + error-feedback
compression properties."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import (EFState, Quantized, ef_compress,
                                 quantize_symmetric, quantized_matmul)

RNG = np.random.default_rng(21)


def test_quantize_roundtrip_error_bound():
    x = jnp.asarray(RNG.normal(size=(64, 64)), jnp.float32)
    q = quantize_symmetric(x)
    err = jnp.abs(q.dequantize() - x)
    # |err| ≤ scale/2 per element
    assert float(jnp.max(err)) <= float(q.scale) / 2 + 1e-7


def test_per_channel_beats_per_tensor():
    x = jnp.asarray(RNG.normal(size=(32, 8)) * np.logspace(-2, 2, 8),
                    jnp.float32)
    qt = quantize_symmetric(x)
    qc = quantize_symmetric(x, axis=0)
    e_t = float(jnp.mean(jnp.square(qt.dequantize() - x)))
    e_c = float(jnp.mean(jnp.square(qc.dequantize() - x)))
    assert e_c < e_t


def test_quantized_matmul_error():
    x = jnp.asarray(RNG.normal(size=(32, 64)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(64, 16)), jnp.float32)
    wq = quantize_symmetric(w, axis=0)
    got = quantized_matmul(x, wq)
    want = x @ w
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < 0.02, rel


def test_error_feedback_unbiased_over_time():
    """Accumulated EF-compressed gradients converge to the true sum — the
    property that makes int8 collective compression safe for training."""
    g = jnp.asarray(RNG.normal(size=(256,)), jnp.float32) * 1e-3
    state = None
    acc = jnp.zeros_like(g)
    for _ in range(50):
        q, state = ef_compress(g, state)
        acc = acc + q.dequantize()
    # after N steps the total equals N·g up to one quantization step
    err = jnp.abs(acc - 50 * g)
    assert float(jnp.max(err)) < 50 * 1e-5 + float(
        jnp.max(jnp.abs(g))) , float(jnp.max(err))


def test_wire_level_compression_math():
    """compressed value-level round trip ≈ identity for well-scaled grads."""
    g = jnp.asarray(RNG.normal(size=(128,)), jnp.float32)
    q, _ = ef_compress(g, None)
    rel = float(jnp.linalg.norm(q.dequantize() - g) / jnp.linalg.norm(g))
    assert rel < 0.01
