"""Hypothesis property tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.banking import plan_banks
from repro.core.quantize import quantize_symmetric
from repro.core.perfmodel import IPCoreConfig, cycles, psum_count
from repro.kernels import ref
from repro.kernels.conv2d_ws import conv2d_ws
from repro.kernels.matmul_ws import matmul_ws

SETTINGS = dict(max_examples=20, deadline=None)


@st.composite
def conv_case(draw):
    h = draw(st.integers(5, 12))
    w = draw(st.integers(5, 12))
    c = draw(st.sampled_from([4, 8]))
    k = draw(st.sampled_from([4, 8]))
    seed = draw(st.integers(0, 2**31 - 1))
    return h, w, c, k, seed


@given(conv_case())
@settings(**SETTINGS)
def test_conv_matches_oracle_property(case):
    h, w, c, k, seed = case
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(1, h, w, c)), jnp.float32)
    wt = jnp.asarray(rng.normal(size=(3, 3, c, k)), jnp.float32)
    got = conv2d_ws(x, wt, interpret=True)
    want = ref.conv2d_ref(x, wt)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


@given(st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_conv_linearity(seed):
    """conv(a·x + b·y) == a·conv(x) + b·conv(y) — Eq. (1) is linear."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(1, 8, 8, 4)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(1, 8, 8, 4)), jnp.float32)
    wt = jnp.asarray(rng.normal(size=(3, 3, 4, 4)), jnp.float32)
    a, b = 1.7, -0.3
    lhs = conv2d_ws(a * x + b * y, wt, interpret=True)
    rhs = a * conv2d_ws(x, wt, interpret=True) \
        + b * conv2d_ws(y, wt, interpret=True)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-3, atol=1e-3)


@given(st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_conv_translation_equivariance(seed):
    """Shifting the input shifts the output (valid-region comparison)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(1, 10, 10, 4)), jnp.float32)
    wt = jnp.asarray(rng.normal(size=(3, 3, 4, 4)), jnp.float32)
    full = conv2d_ws(x, wt, interpret=True)
    shifted = conv2d_ws(x[:, 1:, 1:], wt, interpret=True)
    np.testing.assert_allclose(full[:, 1:, 1:], shifted, rtol=1e-4, atol=1e-4)


@given(st.integers(1, 5000000), st.integers(1, 20))
@settings(**SETTINGS)
def test_perfmodel_cycle_monotonicity(n, ip_cores):
    cfg1 = IPCoreConfig(ip_cores=ip_cores)
    assert cycles(n, cfg1) >= cycles(max(n - 1, 1), cfg1)
    # more IP cores never increases latency
    assert cycles(n, IPCoreConfig(ip_cores=ip_cores + 1)) <= cycles(n, cfg1)


@given(st.integers(4, 64).filter(lambda v: v % 4 == 0),
       st.integers(4, 64).filter(lambda v: v % 4 == 0))
@settings(**SETTINGS)
def test_bank_plan_always_fits_or_maximally_split(c, k):
    plan = plan_banks(64, 64, c, k)
    assert plan.fits_vmem or (c // plan.cin_banks == 1
                              and k // plan.kout_banks == 1)
    assert c % plan.cin_banks == 0 and k % plan.kout_banks == 0


@given(st.integers(0, 2**31 - 1), st.floats(0.1, 100.0))
@settings(**SETTINGS)
def test_quantize_bounds_property(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(64,)) * scale, jnp.float32)
    q = quantize_symmetric(x)
    assert int(jnp.max(jnp.abs(q.values.astype(jnp.int32)))) <= 127
    assert float(jnp.max(jnp.abs(q.dequantize() - x))) <= float(q.scale) / 2 + 1e-6


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_matmul_ws_associative_banking(seed):
    """Splitting the contraction dimension into banks never changes the
    result beyond float tolerance (the paper's channel banking, M1)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
    a = matmul_ws(x, w, bk=64, interpret=True)   # single bank
    b = matmul_ws(x, w, bk=16, interpret=True)   # four banks
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)
