"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
config of its family and runs one forward + one full train step on CPU,
asserting output shapes and the absence of NaNs (assignment requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (ARCH_NAMES, SHAPES, get_config, param_count,
                                reduce_config, shape_applicable)
from repro.layers.common import materialize, shape_structs
from repro.models import lm
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import init_state_specs, make_train_step

RNG = np.random.default_rng(0)


def _batch(cfg, B=2, S=16):
    batch = {"tokens": jnp.asarray(
        RNG.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(
        RNG.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.kind == "vlm":
        P = 4
        batch["tokens"] = batch["tokens"][:, :S - P]
        batch["labels"] = batch["labels"][:, :S - P]
        batch["patches"] = jnp.asarray(
            RNG.normal(size=(B, P, cfg.frontend_dim)), jnp.float32)
    if cfg.kind == "encdec":
        batch["frames"] = jnp.asarray(
            RNG.normal(size=(B, S, cfg.frontend_dim)), jnp.float32)
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_forward_and_train_step(name):
    cfg = reduce_config(get_config(name))
    cfg.validate()
    batch = _batch(cfg)
    sspecs = init_state_specs(cfg)
    state = {
        "params": materialize(sspecs["params"], jax.random.PRNGKey(0)),
        "opt": materialize(sspecs["opt"], jax.random.PRNGKey(1)),
        "step": jnp.zeros((), jnp.int32),
    }
    # forward: shapes + no NaN (VLM logits cover the text suffix only)
    logits, aux = jax.jit(lambda p, b: lm.forward_train(p, b, cfg))(
        state["params"], batch)
    S_out = batch["labels"].shape[1]
    assert logits.shape == (2, S_out, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits))), f"{name}: NaN logits"
    assert bool(jnp.isfinite(aux))

    # one train step: params move, loss finite
    step_fn = jax.jit(make_train_step(cfg, AdamWConfig(warmup_steps=1,
                                                       total_steps=10)))
    new_state, metrics = step_fn(state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), f"{name}: non-finite loss"
    assert int(new_state["step"]) == 1
    moved = jax.tree.reduce(
        lambda acc, pair: acc, jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a - b))),
            state["params"], new_state["params"]))
    deltas = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        state["params"], new_state["params"]))
    assert max(deltas) > 0, f"{name}: parameters did not update"


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_full_config_validates(name):
    """The FULL config (exercised by the dry run, never allocated here)
    satisfies its own invariants and matches the assignment numbers."""
    cfg = get_config(name)
    cfg.validate()
    n = param_count(cfg)
    assert n > 1e8, f"{name}: param count {n} implausibly small"
    # dry-run applicability grid is well-defined for every shape
    for shape in SHAPES.values():
        ok, why = shape_applicable(cfg, shape)
        assert ok or why


def test_assigned_param_counts_plausible():
    """Sanity: headline sizes roughly match the assigned names."""
    expect = {
        "llama3_8b": (7e9, 9e9),
        "yi_34b": (32e9, 36e9),
        "llama3p2_3b": (2.5e9, 4e9),
        "qwen3_moe_30b_a3b": (28e9, 33e9),
        "deepseek_moe_16b": (14e9, 19e9),
        "rwkv6_1p6b": (1.3e9, 2.2e9),
    }
    for name, (lo, hi) in expect.items():
        n = param_count(get_config(name))
        assert lo < n < hi, f"{name}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"
