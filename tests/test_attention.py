"""Attention equivalences: chunked-flash vs dense oracle; sliding window;
decode ring-buffer vs dense over the realized history."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.layers.attention import (KVCache, chunked_attention,
                                    dense_attention)

RNG = np.random.default_rng(3)


def _qkv(b=2, s=64, h=4, d=16):
    q = jnp.asarray(RNG.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, h, d)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_chunked_equals_dense_causal(chunk):
    q, k, v = _qkv()
    got = chunked_attention(q, k, v, causal=True, chunk=chunk)
    want = dense_attention(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_chunked_equals_dense_noncausal():
    q, k, v = _qkv(s=48)
    got = chunked_attention(q, k, v, causal=False, chunk=16)
    want = dense_attention(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [8, 16, 33])
def test_chunked_sliding_window(window):
    q, k, v = _qkv(s=64)
    got = chunked_attention(q, k, v, causal=True, window=window, chunk=16)
    want = dense_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_chunked_gradients_match_dense():
    q, k, v = _qkv(b=1, s=32)

    def f(fn):
        return lambda q, k, v: jnp.sum(jnp.square(
            fn(q, k, v, causal=True)))

    g1 = jax.grad(lambda q, k, v: f(
        lambda *a, **kw: chunked_attention(*a, chunk=8, **kw))(q, k, v),
        argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f(dense_attention), argnums=(0, 1, 2))(q, k, v)
    for a, e in zip(g1, g2):
        np.testing.assert_allclose(a, e, rtol=1e-4, atol=1e-4)


def test_window_chunks_are_skipped():
    """Keys far outside the window must not influence the output (the
    cond-skip path): perturbing them changes nothing."""
    q, k, v = _qkv(s=64)
    out1 = chunked_attention(q, k, v, causal=True, window=8, chunk=8)
    k2 = k.at[:, :16].set(1e6)   # far-past keys, > window away for late qs
    v2 = v.at[:, :16].set(1e6)
    out2 = chunked_attention(q, k2, v2, causal=True, window=8, chunk=8)
    np.testing.assert_allclose(out1[:, 32:], out2[:, 32:],
                               rtol=1e-5, atol=1e-5)
