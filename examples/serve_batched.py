"""End-to-end serving driver (the paper is an inference accelerator, so the
serving path is this repo's headline example): a batched engine with slot
recycling serves a stream of requests against a small model, optionally
through the paper's int8 datapath (w8 weights + int8 KV cache).

    PYTHONPATH=src python examples/serve_batched.py [--w8] [--requests 8]
"""

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.base import get_config, reduce_config
from repro.core.quantize import quantize_weights
from repro.layers.common import materialize
from repro.models import lm
from repro.serving.engine import Request, ServingEngine


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="llama3.2-3b")
    p.add_argument("--requests", type=int, default=6)
    p.add_argument("--slots", type=int, default=3)
    p.add_argument("--max-new", type=int, default=12)
    p.add_argument("--w8", action="store_true",
                   help="serve through the paper's 8-bit datapath")
    args = p.parse_args()

    cfg = reduce_config(get_config(args.arch))
    params = materialize(lm.param_specs(cfg), jax.random.PRNGKey(0))
    if args.w8:
        params = quantize_weights(params, lm.param_specs(cfg))
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8",
                                  kv_cache_scale=0.25)
        print("serving via w8 weights + int8 KV cache")

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=int(rng.integers(4, 12)))
                    .astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]

    engine = ServingEngine(cfg, params, slots=args.slots, max_seq=128)
    t0 = time.time()
    done = engine.run(list(reqs))
    dt = time.time() - t0
    total_tokens = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests / {total_tokens} tokens "
          f"in {dt:.2f}s with {args.slots} slots")
    for r in sorted(done, key=lambda r: r.uid)[:4]:
        print(f"  req {r.uid}: prompt {len(r.prompt)} toks → {r.output}")


if __name__ == "__main__":
    main()
