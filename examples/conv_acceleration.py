"""The paper end-to-end: the §5.2 workload ([224×224×8] ⊛ [8×3×3×8])
through the ConvCore IP abstraction — float oracle, quantized int8
datapath, banked Pallas kernel, and the cycle-accurate performance model
reproducing the paper's 0.224 / 4.48 GOPS numbers — then the network
executor: a LeNet-style int8 ``NetworkPlan`` compiled into one jitted
multi-layer program and scheduled over replicated (virtual) IP cores,
a ResNet-style residual graph (skip connections as shared-grid int8
merge adds) served through ``ConvNetEngine``, and the training subsystem:
a tiny LeNet fit on synthetic digits with quantization-aware training
(backward pass through the weight-stationary transposed-conv /
weight-grad kernels), then dropped into the int8 deployment pipeline.

Paper → TPU mapping of the network path:
* one FPGA IP core processing "a convolutional layer at a time"  ↔  one
  jitted layer pass of the conv2d_ws kernel (fused ReLU/pool/requant
  epilogue = the FPGA post-processing before writeback);
* the host sequencing layer passes through the output BRAMs  ↔  the
  compiled NetworkPlan program chaining int8 feature maps in HBM;
* ~20 replicated IP cores on the full board  ↔  batch sharding across
  devices (or vmapped virtual cores) / kernel-set (kout) sharding —
  core/scheduler.py.

    PYTHONPATH=src python examples/conv_acceleration.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ConvCore, ConvCoreConfig, network, paper_workload,
                        scheduler, training)
from repro.core.banking import plan_banks
from repro.core.perfmodel import (IPCoreConfig, gops_macs, gops_paper,
                                  psum_count, seconds, tpu_conv_roofline)
from repro.kernels import ref
from repro.serving.engine import ConvNetEngine


def main():
    wl = paper_workload()
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=wl["x"]), jnp.float32) * 0.5
    w = jnp.asarray(rng.normal(size=wl["w"]), jnp.float32) * 0.1
    b = jnp.asarray(rng.normal(size=wl["bias"]), jnp.float32) * 0.1

    print("=== paper workload:", wl)

    # --- banking plan (the §4.1 BRAM organization on VMEM) ---------------
    plan = plan_banks(224, 224, 8, 8, in_bytes=1)
    print(f"bank plan: {plan.cin_banks} image banks × {plan.kout_banks} "
          f"kernel banks; VMEM working set "
          f"{plan.working_set_bytes/1024:.0f} KiB (fits: {plan.fits_vmem})")

    # --- float path through the banked kernel -----------------------------
    core = ConvCore(ConvCoreConfig(backend="pallas"))
    t0 = time.time()
    out = jax.block_until_ready(core.apply_layer(x, w, b))
    print(f"float conv: out {out.shape} in {time.time()-t0:.2f}s "
          f"(interpret mode on CPU)")

    # --- the 8-bit datapath (quantize → int8 MACs → int32 psums) ----------
    got = core.apply_quantized_layer(x, w, b)
    want = ref.conv2d_ref(x, w, b)
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    print(f"int8 datapath relative error vs float oracle: {rel:.4f}")

    # --- the paper's §5.2 performance model --------------------------------
    n = psum_count(224, 224, 8, 8)
    print(f"\n=== §5.2 performance model")
    print(f"psums: {n:,} (paper: 3,154,176)")
    print(f"1 IP core  @112MHz: {seconds(n)*1e3:.3f} ms  "
          f"{gops_paper(n):.3f} GOPS-paper  {gops_macs(n):.3f} GOPS-MACs")
    c20 = IPCoreConfig(ip_cores=20)
    print(f"20 IP cores        : {seconds(n, c20)*1e3:.3f} ms  "
          f"{gops_paper(n, c20):.2f} GOPS-paper")

    r = tpu_conv_roofline(224, 224, 8, 8)
    print(f"\n=== the same layer on one TPU v5e core (conv2d_ws roofline)")
    print(f"bound: {'memory' if r['t_memory'] > r['t_compute'] else 'compute'}"
          f"  time {r['seconds']*1e6:.2f} µs  {r['gops_paper']:.0f} GOPS-paper"
          f"  ({seconds(n)/r['seconds']:.0f}× the FPGA IP core)")

    # --- the network executor: LeNet-style int8 NetworkPlan ----------------
    rng = np.random.default_rng(7)
    plan_net = network.lenet()
    print(f"\n=== network executor: {plan_net.name} "
          f"{plan_net.input_shape} → {plan_net.activation_shapes()[-1]}")
    params = plan_net.init_params(rng)
    imgs = jnp.asarray(rng.normal(size=(8, 28, 28, 1)), jnp.float32)
    want = plan_net.apply_ref(params, imgs)

    qnet = network.quantize_network(plan_net, params, imgs)
    program = network.make_int8_program(
        qnet, ConvCoreConfig(backend="pallas", int8=True))
    t0 = time.time()
    logits = jax.block_until_ready(program(imgs))
    rel = float(jnp.linalg.norm(logits - want) / jnp.linalg.norm(want))
    print(f"int8 network ({len(plan_net.layers)} layers, all inter-layer "
          f"maps int8): {time.time()-t0:.2f}s, rel err vs float {rel:.4f}")

    # replicated IP cores: batch-sharded virtual cores (one per image pair)
    sched = scheduler.MultiCoreScheduler(scheduler.SchedulerConfig(n_cores=4))
    logits_mc = sched.run(program, imgs)
    print(f"4 virtual IP cores (batch-sharded): max|Δ| = "
          f"{float(jnp.max(jnp.abs(logits_mc - logits))):.1f} (exact)")

    # the §5.2 model summed over the whole network, incl. the full board
    rep = plan_net.perf_report()
    print(f"\nwhole-network cycle model ({plan_net.name}):")
    for row in rep["layers"]:
        if row["psums"]:
            print(f"  {row['name']:<10} {row['psums']:>10,} psums  "
                  f"{row['cycles']:>8,} cycles")
    print(f"  total      {rep['psums']:>10,} psums  {rep['cycles']:>8,} "
          f"cycles = {rep['seconds']*1e3:.3f} ms @112MHz "
          f"({rep['gops_paper']:.3f} GOPS-paper)")
    fb = rep["full_board"]
    print(f"  full board ({fb['ip_cores']} IP cores): "
          f"{fb['seconds']*1e3:.3f} ms ({fb['gops_paper']:.2f} GOPS-paper)")

    # --- residual graphs: ResNet-class skip connections through the DAG
    # compiler, served by ConvNetEngine over replicated IP cores ---------
    rn = network.resnet_small()
    print(f"\n=== residual graph: {rn.name} {rn.input_shape} "
          f"({sum(1 for sp in rn.layers if sp.kind == 'add')} skip adds, "
          f"{sum(1 for sp in rn.layers if sp.kind == 'conv')} convs)")
    params_rn = rn.init_params(rng)
    imgs_rn = np.asarray(rng.normal(size=(6, *rn.input_shape)), np.float32)
    want_rn = rn.apply_ref(params_rn, jnp.asarray(imgs_rn))
    # per-channel weight scales; every merge node carries per-branch
    # requant scales so the skip add is a pure int8 op on a shared grid
    qrn = network.quantize_network(rn, params_rn, jnp.asarray(imgs_rn),
                                   per_channel=True)
    engine = ConvNetEngine(qrn, batch=4, n_cores=2, backend="pallas")
    t0 = time.time()
    logits_rn = engine.submit(imgs_rn)       # ragged 6 over batch-4 pads
    rel = float(np.linalg.norm(logits_rn - np.asarray(want_rn))
                / np.linalg.norm(np.asarray(want_rn)))
    print(f"int8 resnet via ConvNetEngine (2 virtual cores, "
          f"{engine.stats['batches']} batches, {engine.stats['padded']} "
          f"padded): {time.time()-t0:.2f}s, rel err vs float {rel:.4f}")
    rep_rn = rn.perf_report()
    print(f"model: {rep_rn['seconds']*1e3:.3f} ms @112MHz "
          f"({rep_rn['gops_paper']:.3f} GOPS-paper; branches serialize "
          f"on the layer-at-a-time core)")
    # the engine above is a facade over the continuous-batching queue
    # (PR 10): async admission returns futures, a lone request launches
    # on the deadline instead of waiting for a full batch, and the
    # honest latency number includes its queue wait
    fut = engine.submit_async(imgs_rn[0])
    lone = fut.result(timeout=600)
    np.testing.assert_array_equal(lone, logits_rn[0])
    pct = engine.latency_percentiles()
    print(f"continuous batching: lone async request served "
          f"(formation {engine.engine.formation_counts()}), "
          f"p50 enqueue-to-result {pct['p50']/1e3:.1f} ms over "
          f"{pct['count']} requests")

    # --- grouped/depthwise convs: the MobileNet edge workload family.
    # Depthwise layers run the degenerate one-cin-bank sweep (one kernel
    # set per channel group) — a factor-C fewer psums than a dense conv
    # over the SAME maps, which parks them on the shared-DMA floor ------
    mb = network.mobilenet_small()
    print(f"\n=== grouped conv: {mb.name} {mb.input_shape} "
          f"({mb.grouped_layer_count()} depthwise layers)")
    params_mb = mb.init_params(rng)
    imgs_mb = jnp.asarray(rng.normal(size=(4, *mb.input_shape)), jnp.float32)
    want_mb = mb.apply_ref(params_mb, imgs_mb)
    qmb = network.quantize_network(mb, params_mb, imgs_mb,
                                   per_channel=True)
    prog_mb = network.make_int8_program(
        qmb, ConvCoreConfig(backend="pallas", int8=True))
    logits_mb = prog_mb(imgs_mb)
    rel = float(jnp.linalg.norm(logits_mb - want_mb)
                / jnp.linalg.norm(want_mb))
    print(f"int8 depthwise-separable network: rel err vs float {rel:.4f}")
    rep_mb = mb.perf_report(tile_plans=mb.tile_plans())
    priced = sum(1 for r in rep_mb["layers"] if "dma_bound" in r)
    print(f"model: {rep_mb['seconds']*1e3:.3f} ms @112MHz; on the full "
          f"board the SHARED DMA interface binds "
          f"{rep_mb['dma_bound_board_layers']}/{priced} priced layers — "
          "the depthwise arithmetic-intensity story")

    # --- spatial tiling: maps larger than VMEM stream through halo'd
    # H/W blocks (the paper's fixed-size image BRAMs, generalized) -------
    lm = network.large_map()
    print(f"\n=== spatially-tiled pipeline: {lm.name} {lm.input_shape}")
    for sp, tp in zip(lm.layers, lm.tile_plans()):
        if tp is None:
            continue
        print(f"  conv K={sp.features:<4} tile {tp.h_tile}×{tp.w_tile} "
              f"({tp.n_h_tiles}×{tp.n_w_tiles} tiles, halo re-read "
              f"×{tp.halo_read_factor:.3f})  working set "
              f"{tp.working_set_bytes/2**20:.2f} MiB "
              f"(fits VMEM: {tp.fits_vmem})")
    rep_t = lm.perf_report(tile_plans=lm.tile_plans())
    print(f"  model w/ tile+halo DMA pricing: {rep_t['seconds']*1e3:.3f} ms"
          f" @112MHz; full board {rep_t['full_board']['seconds']*1e3:.3f} ms"
          f" (shared-DDR floor keeps 20-core GOPS honest)")

    # --- training: QAT on the float shadow → the int8 deployment pipeline.
    # The backward pass runs the SAME weight-stationary dataflow: input
    # gradients as a zero-insertion-dilated transposed conv through
    # conv2d_ws, weight gradients as KH·KW batched-correlation WS GEMMs
    # (kernels/conv2d_ws_bwd.py, wired in by ops.conv2d's custom VJP) ----
    tiny = network.lenet(input_shape=(12, 12, 1))
    print(f"\n=== training: {tiny.name} {tiny.input_shape} on synthetic "
          "digits (QAT float shadow)")
    rng = np.random.default_rng(11)
    x_tr, y_tr = training.synthetic_digits(rng, 384)
    x_ev, y_ev = training.synthetic_digits(rng, 192)
    t0 = time.time()
    state, hist = training.fit(tiny, x_tr, y_tr, steps=50, batch=32,
                               cfg=training.TrainConfig(qat=True), seed=12)
    float_acc = float(training.accuracy(
        training.float_forward(tiny, state.params, x_ev), y_ev))
    print(f"50 QAT steps in {time.time()-t0:.1f}s: loss "
          f"{hist[0]['loss']:.2f} → {hist[-1]['loss']:.3f}; float shadow "
          f"eval acc {float_acc:.3f}")
    # trained weights drop straight into the int8 pipeline
    qtiny = network.quantize_network(tiny, state.params, x_tr[:128])
    prog_tiny = network.make_int8_program(
        qtiny, ConvCoreConfig(backend="pallas", int8=True))
    int8_acc = float(training.accuracy(prog_tiny(x_ev), y_ev))
    print(f"deployed int8 eval acc {int8_acc:.3f} "
          f"(Δ {float_acc - int8_acc:+.3f} vs the float shadow)")
    rep_tr = tiny.train_report()
    print(f"train-step model: {rep_tr['seconds']*1e3:.3f} ms @112MHz "
          f"({rep_tr['backward']['cycles']/rep_tr['cycles']:.0%} backward; "
          f"≈3× forward psums — perfmodel.train_report)")


if __name__ == "__main__":
    main()
