"""Quickstart: train a tiny llama-family model on synthetic data, then
greedily generate from it — the whole public API in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduce_config
from repro.data.pipeline import DataConfig, make_pipeline
from repro.layers.common import materialize
from repro.models import lm
from repro.optim.adamw import AdamWConfig
from repro.serving.serve_step import greedy_sample
from repro.train.train_step import init_state_specs, make_train_step


def main():
    # 1. architecture: any assigned config, reduced to laptop scale
    cfg = reduce_config(get_config("llama3-8b"))
    print(f"arch: {cfg.name} (reduced) — {cfg.num_layers}L d={cfg.d_model}")

    # 2. state: parameters + AdamW moments from one spec tree
    sspecs = init_state_specs(cfg)
    state = {
        "params": materialize(sspecs["params"], jax.random.PRNGKey(0)),
        "opt": materialize(sspecs["opt"], jax.random.PRNGKey(1)),
        "step": jnp.zeros((), jnp.int32),
    }

    # 3. data: deterministic, seekable synthetic stream
    pipe = make_pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                    global_batch=8, seed=0))

    # 4. train
    step_fn = jax.jit(make_train_step(
        cfg, AdamWConfig(peak_lr=1e-2, warmup_steps=5, total_steps=60)))
    for s in range(60):
        batch = jax.tree.map(jnp.asarray, pipe.batch_at(s))
        state, metrics = step_fn(state, batch)
        if s % 10 == 0:
            print(f"step {s:3d}  loss {float(metrics['loss']):.4f}  "
                  f"lr {float(metrics['lr']):.2e}")

    # 5. generate: prefill + decode with a KV cache
    prompt = jnp.asarray(pipe.batch_at(999)["tokens"][:1, :16])
    logits, cache = lm.prefill(state["params"], {"tokens": prompt}, cfg,
                               cache_len=32)
    toks = [int(greedy_sample(logits)[0])]
    for i in range(8):
        lg, cache = lm.decode_step(
            state["params"], cfg,
            token=jnp.asarray([toks[-1]], jnp.int32),
            pos=jnp.asarray([16 + i], jnp.int32), cache=cache)
        toks.append(int(greedy_sample(lg)[0]))
    print("generated:", toks)


if __name__ == "__main__":
    main()
