"""End-to-end training driver with the production loop: checkpointing,
fault-injection recovery, straggler monitoring, optional int8 gradient
compression — on a llama-family model of configurable size.

Default is laptop-scale; ``--preset 100m`` trains a ~100M-parameter model
(a few hundred steps is a multi-hour CPU run; on TPU it is minutes).

    PYTHONPATH=src python examples/train_llama_tiny.py --steps 60
    PYTHONPATH=src python examples/train_llama_tiny.py --preset 100m \
        --steps 300 --batch 32 --seq 512      # the full deliverable run
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduce_config
from repro.data.pipeline import DataConfig, make_pipeline
from repro.layers.common import materialize
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import init_state_specs, make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def build_config(preset: str):
    base = get_config("llama3.2-3b")
    if preset == "tiny":
        return reduce_config(base)
    if preset == "100m":
        # ~100M params: 8L, d=768, 12H/4KV, ff=2048, 32k vocab
        return dataclasses.replace(
            base, num_layers=8, d_model=768, num_heads=12, num_kv_heads=4,
            head_dim=64, d_ff=2048, vocab_size=32768, attn_chunk=256,
            remat_policy="none", compute_dtype="float32")
    raise ValueError(preset)


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--preset", choices=("tiny", "100m"), default="tiny")
    p.add_argument("--steps", type=int, default=60)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--lr", type=float, default=1e-2)
    p.add_argument("--ckpt-dir", default="/tmp/repro_train_example")
    p.add_argument("--fail-at", type=int, nargs="*", default=[],
                   help="inject failures at these steps (recovery demo)")
    args = p.parse_args()

    cfg = build_config(args.preset)
    from repro.configs.base import param_count
    print(f"model: {cfg.num_layers}L d={cfg.d_model} "
          f"params≈{param_count(cfg)/1e6:.1f}M")

    sspecs = init_state_specs(cfg)
    state = {
        "params": materialize(sspecs["params"], jax.random.PRNGKey(0)),
        "opt": materialize(sspecs["opt"], jax.random.PRNGKey(1)),
        "step": jnp.zeros((), jnp.int32),
    }
    pipe = make_pipeline(DataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=args.seq,
                                    global_batch=args.batch, seed=0))
    hp = AdamWConfig(peak_lr=args.lr, warmup_steps=max(args.steps // 20, 2),
                     total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, hp))

    trainer = Trainer(
        TrainerConfig(total_steps=args.steps,
                      checkpoint_every=max(args.steps // 5, 10),
                      checkpoint_dir=args.ckpt_dir, log_every=10,
                      fail_at_steps=tuple(args.fail_at)),
        step_fn, pipe, state)
    history = trainer.run()
    print(f"done: loss {history[0]['loss']:.4f} → {history[-1]['loss']:.4f} "
          f"({trainer.restarts} restarts, "
          f"{len(trainer.monitor.events)} straggler events)")


if __name__ == "__main__":
    main()
