"""Calibration sweep: time the REAL conv kernels over a factorial grid of
(tile shape × cin/kout banks × groups × dilation/transpose × epilogue ×
pipelined) and fit the per-term corrections of
``core/calibration.CalibrationTable`` onto the §5.2 analytic model — the
measured counterpart of the exemplar repo's ``overhead_factor = 3.89``.
The dense-prediction grid points (PR 8) cover a dilated 3×3 and a
stride-2 transposed conv (timed through the shared ``conv2d_ws_trans``
eq-conv lowering, with the zero-skipping psum count as the analytic
compute term).

Each grid point runs ``conv2d_ws`` (sequential) or ``conv2d_ws_pipe``
(explicit double-buffered DMA) with a concrete ``banking.TilePlan``; its
analytic terms (compute cycles, DMA bytes incl. tile revisits/halos,
pipeline slab count) come from the same perfmodel walk the planner uses,
so the fitted table corrects exactly the expression the planner descends
against.  ``bench_util.time_fn`` returns the full stats record; samples
whose IQR exceeds half their median are rejected before the fit.

On a TPU host the kernels compile natively and the table calibrates the
real datapath; on this CPU container they run in interpret mode and the
table calibrates the emulation — either way predictions and measurements
land on one scale, which is what turns ``measured_vs_predicted`` error
into a trackable number (BENCH_network.json).

Usage::

    python benchmarks/calibrate.py [--smoke] [--out CALIBRATION.json]

``--smoke`` runs a reduced grid with minimal iterations (the CI lane);
the fitted table is written to ``--out`` (default ``CALIBRATION.json``,
or the ``CALIBRATION_JSON`` env var) with provenance + fit diagnostics.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_util import emit, time_fn
from repro.core import perfmodel
from repro.core.banking import grouped_banks, plan_tiles
from repro.core.calibration import (NOISE_IQR_FRACTION, fit_calibration,
                                    sample_from_plan)
from repro.kernels.conv2d_ws import conv2d_ws
from repro.kernels.conv2d_ws_pipe import conv2d_ws_pipe
from repro.kernels.conv2d_ws_trans import (conv2d_ws_transpose,
                                           transpose_eq_conv_geometry)

OUT_PATH = os.environ.get("CALIBRATION_JSON", "CALIBRATION.json")


def _provenance(smoke: bool) -> dict:
    """Same toolchain pin as BENCH_network.json, plus the execution mode
    (interpret on CPU vs native Mosaic on TPU) — a table fitted on the
    emulation must never be mistaken for silicon numbers."""
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.abspath(__file__)),
            timeout=10).stdout.strip()
    except (OSError, subprocess.SubprocessError):
        sha = ""
    dev = jax.devices()[0]
    return {"jax_version": jax.__version__,
            "backend": jax.default_backend(),
            "device_kind": getattr(dev, "device_kind", str(dev)),
            "git_sha": sha or "unknown",
            "mode": "native" if jax.default_backend() == "tpu"
                    else "interpret",
            "smoke": smoke}


# factorial axes: (name, H, W, C, K, KH, groups, padding, dilation, op)
# × bank pairs × epilogues × {sequential, pipelined}.  The shapes span
# the zoo's workload classes: dense 3×3, pointwise 1×1, grouped,
# depthwise, a spatially-tiled map (many slabs — the axis that
# constrains the per-slab overhead term), and the dense-prediction pair
# (PR 8): a dilated (atrous) kernel with its widened halo, and a
# stride-2 transposed-conv upsampler through the shared
# ``conv2d_ws_trans`` eq-conv lowering.  ``op`` is "conv" (stride 1) or
# "transpose" (stride-2 upsampling, the unet_small deconv shape class).
_SHAPES = [
    ("dense3x3",    16, 16, 16, 16, 3, 1,  "SAME",  1, "conv"),
    ("dense3x3big", 32, 32, 16, 16, 3, 1,  "SAME",  1, "conv"),
    ("pointwise",   16, 16, 32, 32, 1, 1,  "VALID", 1, "conv"),
    ("grouped",     16, 16, 32, 32, 3, 4,  "SAME",  1, "conv"),
    ("depthwise",   16, 16, 32, 32, 3, 32, "SAME",  1, "conv"),
    ("tiledmap",    64, 64, 16, 16, 3, 1,  "SAME",  1, "conv"),
    ("dilated2",    16, 16, 16, 16, 3, 1,  "SAME",  2, "conv"),
    ("transpose2x",  8,  8, 16, 16, 2, 1,  "VALID", 1, "transpose"),
]
_BANKS = [(4, 4), (8, 8)]
# the stride the transposed shapes upsample by (the zoo's 2× deconv)
_TRANSPOSE_STRIDE = 2
# epilogue grid: bare, ReLU, ReLU+pool, fused requantize
_EPILOGUES = [
    ("bare",    dict()),
    ("relu",    dict(relu=True)),
    ("relupool", dict(relu=True, pool=True)),
    ("requant", dict(out_scale=0.03125)),
]

_SMOKE_SHAPES = [_SHAPES[0], _SHAPES[2], _SHAPES[4], _SHAPES[5],
                 _SHAPES[6], _SHAPES[7]]
_SMOKE_EPILOGUES = [_EPILOGUES[1], _EPILOGUES[3]]


def sweep(smoke: bool = False, iters: int = 0) -> list:
    """Run the factorial microbenchmark grid; one CalibrationSample per
    (shape × banks × epilogue × kernel variant) point."""
    interpret = jax.default_backend() != "tpu"
    shapes = _SMOKE_SHAPES if smoke else _SHAPES
    banks = _BANKS[:1] if smoke else _BANKS
    epilogues = _SMOKE_EPILOGUES if smoke else _EPILOGUES
    iters = iters or (2 if smoke else 5)
    rng = np.random.default_rng(7)
    samples = []
    for name, h, w, c, k, kh, groups, pad, dil, op in shapes:
        x = jnp.asarray(rng.integers(-128, 128, (1, h, w, c)), jnp.int8)
        wt = jnp.asarray(
            rng.integers(-128, 128, (kh, kh, c // groups, k)), jnp.int8)
        if op == "transpose":
            # zero-skipping MACs — the count the planner prices transposed
            # rows with; the plan geometry is the eq stride-1 conv the
            # lowering actually launches
            psums = perfmodel.conv_transpose_psum_count(
                h, w, c, k, kh, kh, stride=_TRANSPOSE_STRIDE, padding=pad,
                groups=groups, dilation=dil)
            ph, pw, ppad = transpose_eq_conv_geometry(
                h, w, kh, kh, _TRANSPOSE_STRIDE, pad, dil)
        else:
            psums = perfmodel.psum_count(h, w, c, k, kh, kh, padding=pad,
                                         groups=groups, dilation=dil)
            ph, pw, ppad = h, w, pad
        # spatial tiles only where the shape calls for them: the tiled
        # map's tight budget forces plan_tiles into halo'd H/W tiles —
        # the many-slab axis that constrains the per-slab overhead term
        budget = 96 * 1024 if name == "tiledmap" else None
        for cb, kb in banks:
            cb_n, kb_n = grouped_banks(c, k, groups, want_cin=cb,
                                       want_kout=kb)
            for ep_name, ep in epilogues:
                out_scale = ep.get("out_scale")
                for variant, fn, pipelined in (
                        ("seq", conv2d_ws, False),
                        ("pipe", conv2d_ws_pipe, True)):
                    plan = plan_tiles(
                        ph, pw, c, k, kh, kh, padding=ppad, groups=groups,
                        dilation=dil,
                        pool=ep.get("pool", False), in_bytes=1,
                        out_bytes=1 if out_scale is not None else 4,
                        cin_banks=cb_n, kout_banks=kb_n,
                        vmem_budget=budget,
                        kernel="pipelined" if pipelined else "sequential")
                    # the kernel runs the PLAN's geometry (banks + tiles),
                    # so the analytic terms describe exactly what was
                    # measured
                    kw = dict(stride=1, padding=pad, groups=groups,
                              dilation=dil,
                              cin_banks=plan.cin_banks,
                              kout_banks=plan.kout_banks,
                              h_tile=plan.h_tile if plan.tiled else 0,
                              w_tile=plan.w_tile if plan.tiled else 0,
                              relu=ep.get("relu", False),
                              pool=ep.get("pool", False))
                    scale = (jnp.float32(out_scale)
                             if out_scale is not None else None)
                    if op == "transpose":
                        # both variants go through the shared lowering —
                        # it dispatches the eq conv on ``pipelined``
                        fn = conv2d_ws_transpose
                        kw.update(stride=_TRANSPOSE_STRIDE,
                                  pipelined=pipelined)
                    t = time_fn(
                        lambda fn=fn, kw=kw, scale=scale: fn(
                            x, wt, None, scale, interpret=interpret, **kw),
                        iters=iters, warmup=1)
                    label = (f"{name}/b{plan.cin_banks}x{plan.kout_banks}"
                             f"/{ep_name}/{variant}")
                    s = sample_from_plan(
                        label, plan, psums, t.median_us, t.iqr_us,
                        pipelined=pipelined, shape=[h, w, c, k, kh],
                        groups=groups, epilogue=ep_name)
                    samples.append(s)
                    emit(f"calibrate/{label}", t,
                         f"compute_cycles={s.compute_cycles};"
                         f"dma_bytes={s.dma_bytes};n_slabs={s.n_slabs};"
                         f"noisy={int(s.noisy)}")
    return samples


def run(smoke: bool = False, out_path: str = OUT_PATH):
    samples = sweep(smoke=smoke)
    table = fit_calibration(samples, provenance=_provenance(smoke))
    table.save(out_path)
    fit = table.fit
    emit("calibrate/fit", 0.0,
         f"path={out_path};compute_factor={table.compute_factor:.3f};"
         f"dma_bpc={table.dma_bytes_per_cycle};"
         f"pipe_overhead={table.pipeline_overhead_cycles:.1f};"
         f"n_fit={fit['n_fit']}/{fit['n_samples']};"
         f"mean_err_pct={fit['mean_abs_error_pct']:.1f}")
    return table


if __name__ == "__main__":
    out = OUT_PATH
    if "--out" in sys.argv:
        out = sys.argv[sys.argv.index("--out") + 1]
    print("name,us_per_call,derived")
    run(smoke="--smoke" in sys.argv, out_path=out)
