"""Whole-network benchmark: LeNet / VGG-small int8 NetworkPlans through the
Pallas backend (interpret on CPU — functional timing reference), with the
§5.2 cycle model's whole-network prediction alongside the measurement.

Emits ``BENCH_network.json`` so the perf trajectory of the network executor
is tracked across PRs: per-network images/s, layers/s, measured µs/batch,
and the model-predicted FPGA times (1 IP core and the 20-core full board).
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_util import emit, time_fn
from repro.core import network
from repro.core.convcore import ConvCoreConfig

BATCH = 4
OUT_PATH = os.environ.get("BENCH_NETWORK_JSON", "BENCH_network.json")


def _bench_plan(plan: network.NetworkPlan, rng) -> dict:
    params = plan.init_params(rng)
    x = jnp.asarray(
        rng.normal(size=(BATCH, *plan.input_shape)), jnp.float32)
    qnet = network.quantize_network(plan, params, x)
    program = network.make_int8_program(
        qnet, ConvCoreConfig(backend="pallas", int8=True))
    us = time_fn(lambda: program(x), iters=3, warmup=1)

    n_layers = len(plan.layers)
    rep = plan.perf_report()
    fb = rep["full_board"]
    images_s = BATCH / (us * 1e-6)
    layers_s = BATCH * n_layers / (us * 1e-6)
    emit(f"network/{plan.name}", us,
         f"images_s={images_s:.1f};layers_s={layers_s:.1f};"
         f"model_ms={rep['seconds']*1e3:.3f};"
         f"model_ms_20core={fb['seconds']*1e3:.3f}")
    return {
        "name": plan.name,
        "batch": BATCH,
        "layers": n_layers,
        "measured_us_per_batch": us,
        "images_per_s": images_s,
        "layers_per_s": layers_s,
        "model_psums": rep["psums"],
        "model_seconds_1core": rep["seconds"],
        "model_gops_1core": rep["gops_paper"],
        "model_seconds_20core": fb["seconds"],
        "model_gops_20core": fb["gops_paper"],
    }


def run():
    rng = np.random.default_rng(3)
    results = [_bench_plan(network.lenet(), rng),
               _bench_plan(network.vgg_small(), rng)]
    payload = {"backend": jax.default_backend(),
               "interpret": jax.default_backend() != "tpu",
               "networks": results}
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    emit("network/json", 0.0, f"path={OUT_PATH}")
