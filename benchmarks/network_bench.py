"""Whole-network benchmark: LeNet / VGG-small / ResNet-small / MobileNet /
segmentation (unet_small, dilated_context) / large-map int8 NetworkPlans
through the Pallas backend (interpret on CPU — functional timing
reference), with the §5.2 cycle model's whole-network prediction alongside
the measurement.

The segmentation rows exercise the dense-prediction contract (PR 8):
``unet_small`` compiles transposed-conv upsampling through the shared
``conv2d_ws_trans`` eq-conv lowering (its model rows price psums with the
zero-skipping MAC count, not the naive upsampled sweep) and
``dilated_context`` runs dilated (atrous) kernels with their widened
halos; both also land in ``measured_vs_predicted`` when a calibration
table is loaded.

The resnet row exercises the residual-graph (DAG) compiler: skip
connections with shared-grid int8 merge adds and 1×1 projection
shortcuts.  The mobilenet rows exercise the grouped-conv contract
(depthwise-separable and inverted-residual blocks); their model rows
carry the grouped perfmodel pricing — ``grouped_layers`` and
``dma_bound_board_layers`` record how many layers the shared DMA
interface binds on the full board (depthwise layers compute a factor-C
fewer psums over the same maps, so DMA, not compute, is their floor).

The large-map network's first layer exceeds the whole-map VMEM budget —
it only runs because the spatially-tiled conv pipeline streams it through
halo'd H/W blocks; its model row also carries the tile-revisit / halo
DMA pricing (perfmodel.tile_traffic).

Emits ``BENCH_network.json`` so the perf trajectory of the network executor
is tracked across PRs: per-network images/s, layers/s, measured µs/batch,
the model-predicted FPGA times (1 IP core and the 20-core full board),
and per-plan tiling stats.  A ``provenance`` block (jax version, device
kind, git sha) pins each run to its toolchain, each network row carries
``pipelined_layers`` (how many convs the planner routed to the explicit
DMA pipeline, kernels/conv2d_ws_pipe), and a ``pipeline`` section prices
every network both ways (kernel="sequential" vs "auto") with per-layer
crossover rows — the model columns there are the cross-PR throughput
signal; interpret-mode measurements of the pipelined kernel time Python
DMA emulation, not overlap.

``--smoke`` (or run(smoke=True)) times LeNet, the resnet residual graph,
the mobilenet grouped-conv compiler, and the two segmentation nets with
minimal iterations — the CI fast path.  The large-map row is
measured with iters=1/warmup=0 (interpret mode is slow), so treat its
measured_us as indicative — the modelled FPGA times are the stable
cross-PR signal.

Calibration & autotuning rows: with a fitted CalibrationTable present
(``CALIBRATION.json`` or the ``CALIBRATION_JSON`` env var —
benchmarks/calibrate.py writes it), each network row's ``autotune`` block
prices the full (TilePlan × kernel × scheduler mode × core count) search
against the calibrated model (``cycles_autotuned ≤ cycles_greedy`` is
asserted — the greedy plan is in the search space), every row carries
``plan_source``, and a ``measured_vs_predicted`` section reports the
calibrated model's per-layer wall-time error (mean |error| % + worst
layer per network) — the model-accuracy regression signal.  Without a
table the autotune block prices on the analytic model and the
measured_vs_predicted section is omitted (no shared scale to predict on).

Train-step rows: one jitted ``training.make_train_step`` step (forward
through the WS kernels + backward through the transposed-conv /
weight-grad kernels + AdamW), measured per batch and priced by
``perfmodel.train_report`` (≈3× forward psums + dW traffic).  The full
run ALWAYS writes them into the ``train`` section of
``BENCH_network.json`` (so a flagless run can never silently drop the
tracked training trajectory); ``--train`` opts the fast ``--smoke`` path
into one train-step row as well.

Serving rows (``--serving``, benchmarks/serving_load.py): sustained
requests/s under open-loop load through the continuous-batching engine —
sync-baseline vs saturating throughput (the ≥1.5× acceptance gate, with
mean batch fill and zero-drop/zero-dup accounting asserted), an
offered-load sweep at λ ∈ {0.5, 1, 2}× capacity with p50/p90/p99
*including queue wait* and the deadline-launch fraction, and the
multi-model LRU cache segment.  Lands as the schema-additive ``serving``
section (smoke: lenet + multi-model; full: the zoo minus large_map).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_util import emit, time_fn
from repro import obs
from repro.core import autotune, network, training
from repro.core.calibration import load_table, sample_from_plan
from repro.core.convcore import ConvCoreConfig
from repro.kernels.conv2d_ws import conv2d_ws
from repro.kernels.conv2d_ws_pipe import conv2d_ws_pipe
from repro.kernels.conv2d_ws_trans import conv2d_ws_transpose

BATCH = 4
OUT_PATH = os.environ.get("BENCH_NETWORK_JSON", "BENCH_network.json")
# fitted CalibrationTable (benchmarks/calibrate.py output); None → the
# analytic model, autotune rows priced uncalibrated, no
# measured_vs_predicted section (there is no measured scale to predict on)
CALIB = load_table(os.environ.get("CALIBRATION_JSON", "CALIBRATION.json"))


def _provenance() -> dict:
    """Pin the run to its toolchain so rows are comparable across PRs
    (the existing top-level keys stay untouched; this is additive)."""
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.abspath(__file__)),
            timeout=10).stdout.strip()
    except (OSError, subprocess.SubprocessError):
        sha = ""
    dev = jax.devices()[0]
    return {"jax_version": jax.__version__,
            "backend": jax.default_backend(),
            "device_kind": getattr(dev, "device_kind", str(dev)),
            "git_sha": sha or "unknown"}


def _bench_plan(plan: network.NetworkPlan, rng, batch: int = BATCH,
                iters: int = 3, warmup: int = 1) -> dict:
    params = plan.init_params(rng)
    x = jnp.asarray(
        rng.normal(size=(batch, *plan.input_shape)), jnp.float32)
    qnet = network.quantize_network(plan, params, x)
    cfg = ConvCoreConfig(backend="pallas", int8=True)
    # the very plans the compiled program executes — reported stats can't
    # drift from the measured run
    tile_plans = network.program_tile_plans(plan, cfg)
    program = network.make_int8_program(qnet, cfg, tile_plans=tile_plans)
    with obs.span("bench.network", network=plan.name, batch=batch,
                  iters=iters):
        us = time_fn(lambda: program(x), iters=iters, warmup=warmup)
    if obs.enabled():
        us.to_histogram(f"bench.network_us.{plan.name}")

    n_layers = len(plan.layers)
    rep = plan.perf_report(tile_plans=tile_plans)
    fb = rep["full_board"]
    tiled_layers = sum(1 for tp in tile_plans if tp is not None and tp.tiled)
    halo_max = max((tp.halo_read_factor for tp in tile_plans
                    if tp is not None), default=1.0)
    # grouped-conv rows: how many layers are grouped/depthwise, and how
    # many priced layers the shared DMA interface binds on the full board
    # (the depthwise arithmetic-intensity signal the model must show)
    grouped_layers = plan.grouped_layer_count()
    dma_bound = rep["dma_bound_board_layers"]
    # kernel-variant split: how many conv layers the planner routed to
    # the explicit DMA pipeline (conv2d_ws_pipe) in the measured program
    pipelined_layers = rep["pipelined_layers"]
    images_s = batch / (us * 1e-6)
    layers_s = batch * n_layers / (us * 1e-6)
    # autotuner verdict under the loaded (or analytic) model: the tuned
    # plan may only ever match or beat greedy — assert the acceptance
    # contract right where the tracked numbers are produced
    tune = autotune.autotune_network(plan, calib=CALIB)
    assert tune.cycles <= tune.greedy_cycles, (
        f"{plan.name}: autotuned {tune.cycles} > greedy "
        f"{tune.greedy_cycles} cycles — the greedy plan is in the search "
        "space, this must be impossible")
    emit(f"network/{plan.name}", us,
         f"images_s={images_s:.1f};layers_s={layers_s:.1f};"
         f"model_ms={rep['seconds']*1e3:.3f};"
         f"model_ms_20core={fb['seconds']*1e3:.3f};"
         f"tiled_layers={tiled_layers};halo_factor={halo_max:.3f};"
         f"grouped_layers={grouped_layers};dma_bound_board={dma_bound};"
         f"pipelined_layers={pipelined_layers};"
         f"tune_speedup={tune.speedup:.4f};"
         f"tune_differ={tune.layers_differ};"
         f"tune_sched={tune.scheduler_mode}x{tune.n_cores}")
    return {
        "name": plan.name,
        "batch": batch,
        "layers": n_layers,
        # the measured program above ran the greedy program_tile_plans
        # (the serving default); the autotune block reports what the
        # tuner would run and how much the calibrated model says it saves
        "plan_source": "greedy",
        "autotune": {
            "calibrated": tune.calibrated,
            "cycles_autotuned": tune.cycles,
            "cycles_greedy": tune.greedy_cycles,
            "model_speedup": tune.speedup,
            "layers_differ": tune.layers_differ,
            "scheduler_mode": tune.scheduler_mode,
            "n_cores": tune.n_cores,
            "schedule_cycles": tune.schedule_cycles_,
            "layers": tune.layer_rows(),
        },
        "measured_us_per_batch": us,
        "images_per_s": images_s,
        "layers_per_s": layers_s,
        "model_psums": rep["psums"],
        "model_seconds_1core": rep["seconds"],
        "model_gops_1core": rep["gops_paper"],
        "model_seconds_20core": fb["seconds"],
        "model_gops_20core": fb["gops_paper"],
        "tiled_layers": tiled_layers,
        "max_halo_read_factor": halo_max,
        "grouped_layers": grouped_layers,
        "dma_bound_board_layers": dma_bound,
        "pipelined_layers": pipelined_layers,
        # exact percentiles over the raw timing samples (additive; the
        # top-level latency_percentiles section aggregates these per net)
        "latency_percentiles": us.percentiles(),
    }


def _bench_pipeline(plan: network.NetworkPlan, rng, batch: int = 2,
                    iters: int = 1, measure: bool = True) -> dict:
    """Sequential-vs-pipelined head-to-head for one network: the same
    quantized program compiled with kernel="sequential" (every conv on
    conv2d_ws) and kernel="auto" (the planner routes DMA-bound layers to
    conv2d_ws_pipe), with the §5.2 model pricing both ways and per-layer
    crossover rows for the layers the planner pipelined.  The model
    columns are the cross-PR throughput signal; interpret-mode
    measurements time Python DMA emulation, so on CPU they bound
    correctness cost, not overlap (the docstring caveat above)."""
    params = plan.init_params(rng)
    x = jnp.asarray(
        rng.normal(size=(batch, *plan.input_shape)), jnp.float32)
    qnet = network.quantize_network(plan, params, x)
    reports, measured = {}, {}
    for kernel in ("sequential", "auto"):
        cfg = ConvCoreConfig(backend="pallas", int8=True, kernel=kernel)
        tps = network.program_tile_plans(plan, cfg)
        reports[kernel] = plan.perf_report(tile_plans=tps)
        if measure:
            program = network.make_int8_program(qnet, cfg, tile_plans=tps)
            measured[kernel] = time_fn(lambda p=program: p(x),
                                       iters=iters, warmup=1)
    seq, auto = reports["sequential"], reports["auto"]
    speedup = seq["seconds"] / auto["seconds"] if auto["seconds"] else 1.0
    layer_rows = [
        {"name": r["name"], "pipelined": r["pipelined"],
         "cycles_sequential": r["cycles_sequential"],
         "cycles_pipelined": r["cycles_pipelined"],
         "speedup": r["pipeline_speedup"],
         "dma_bound_board": r["dma_bound_board"]}
        for r in auto["layers"] if r.get("pipelined") is not None]
    emit(f"pipeline/{plan.name}", measured.get("auto", 0.0),
         f"pipelined_layers={auto['pipelined_layers']};"
         f"model_speedup={speedup:.3f};"
         f"model_ms_seq={seq['seconds']*1e3:.3f};"
         f"model_ms_auto={auto['seconds']*1e3:.3f}")
    row = {
        "name": plan.name,
        "pipelined_layers": auto["pipelined_layers"],
        "model_seconds_sequential": seq["seconds"],
        "model_seconds_auto": auto["seconds"],
        "model_speedup": speedup,
        "model_gops_sequential": seq["gops_paper"],
        "model_gops_auto": auto["gops_paper"],
        "layers": layer_rows,
    }
    if measure:
        row["measured_us_sequential"] = measured["sequential"]
        row["measured_us_auto"] = measured["auto"]
    return row


def _measured_vs_predicted(plan: network.NetworkPlan, rng,
                           iters: int = 2) -> dict:
    """Per-layer model-accuracy row for one network: time every conv /
    conv_transpose layer's actual kernel call (the variant + plan
    geometry the compiled program runs — transposed layers go through the
    shared ``conv2d_ws_trans`` lowering, so what's timed is the eq
    stride-1 conv their TilePlan was planned on) and compare against the
    calibrated model's predicted wall time — mean |error| % across layers
    plus the worst layer, the regression-tested number that says how much
    to trust the planner's cost model.  Requires a loaded
    CalibrationTable: predictions and measurements only share a scale
    through the fitted ``clock_hz``."""
    assert CALIB is not None
    interpret = jax.default_backend() != "tpu"
    cfg = ConvCoreConfig(backend="pallas", int8=True, calib=CALIB)
    tile_plans = network.program_tile_plans(plan, cfg)
    names = plan.node_names()
    ins = plan.resolved_inputs()
    acts = plan.activation_shapes()
    psum_rows = dict(plan.psum_table())
    rows = []
    for i, sp in enumerate(plan.layers):
        tp = tile_plans[i]
        if sp.kind not in ("conv", "conv_transpose") or tp is None:
            continue
        h, w, c = plan.input_shape if ins[i][0] < 0 else acts[ins[i][0]]
        k, g_ = network.conv_geometry(sp, c)
        kh, kw_ = sp.kernel
        x = jnp.asarray(rng.integers(-128, 128, (1, h, w, c)), jnp.int8)
        wt = jnp.asarray(
            rng.integers(-128, 128, (kh, kw_, c // g_, k)), jnp.int8)
        scale = jnp.float32(0.03125)
        if sp.kind == "conv_transpose":
            # the lowering re-legalizes banks and dispatches the eq conv
            # (sequential or pipelined) off the plan verdict itself
            def call(fn=conv2d_ws_transpose, tp=tp, sp=sp, x=x, wt=wt,
                     g_=g_):
                return fn(
                    x, wt, None, scale, stride=sp.stride,
                    padding=sp.padding, groups=g_, cin_banks=tp.cin_banks,
                    kout_banks=tp.kout_banks,
                    h_tile=tp.h_tile if tp.tiled else 0,
                    w_tile=tp.w_tile if tp.tiled else 0,
                    relu=sp.relu, pool=sp.pool, dilation=sp.dilation,
                    pipelined=tp.pipelined, interpret=interpret)
        else:
            def call(fn=conv2d_ws_pipe if tp.pipelined else conv2d_ws,
                     tp=tp, sp=sp, x=x, wt=wt, g_=g_):
                return fn(
                    x, wt, None, scale, stride=sp.stride,
                    padding=sp.padding, groups=g_, cin_banks=tp.cin_banks,
                    kout_banks=tp.kout_banks,
                    h_tile=tp.h_tile if tp.tiled else 0,
                    w_tile=tp.w_tile if tp.tiled else 0,
                    relu=sp.relu, pool=sp.pool, dilation=sp.dilation,
                    interpret=interpret)
        t = time_fn(call, iters=iters, warmup=1)
        s = sample_from_plan(names[i], tp, psum_rows[names[i]],
                             t.median_us, t.iqr_us)
        pred = CALIB.predicted_us(s.compute_cycles, s.dma_bytes,
                                  s.n_slabs, s.pipelined)
        err = abs(pred - t.median_us) / max(t.median_us, 1e-9) * 100.0
        rows.append({"name": names[i], "measured_us": t.median_us,
                     "predicted_us": pred, "abs_error_pct": err,
                     "pipelined": tp.pipelined})
    if not rows:
        return {"name": plan.name, "layers": []}
    worst = max(rows, key=lambda r: r["abs_error_pct"])
    mean_err = sum(r["abs_error_pct"] for r in rows) / len(rows)
    emit(f"mvp/{plan.name}", 0.0,
         f"mean_abs_error_pct={mean_err:.1f};"
         f"worst_layer={worst['name']};"
         f"worst_abs_error_pct={worst['abs_error_pct']:.1f}")
    return {"name": plan.name,
            "mean_abs_error_pct": mean_err,
            "worst_layer": worst["name"],
            "worst_abs_error_pct": worst["abs_error_pct"],
            "layers": rows}


def _bench_train(plan: network.NetworkPlan, rng, batch: int = BATCH,
                 iters: int = 3, warmup: int = 1, qat: bool = True) -> dict:
    """Time one jitted QAT train step (fwd WS kernels + bwd WS kernels +
    AdamW) and put the §5.2 train-step model alongside it."""
    x, y = training.synthetic_digits(
        rng, max(batch * 2, 16), input_shape=plan.input_shape,
        classes=plan.activation_shapes()[-1][-1])
    cfg = training.TrainConfig(qat=qat)
    step = training.make_train_step(plan, cfg)
    state = training.init_train_state(plan, rng)

    def one_step():
        nonlocal state
        state, m = step(state, x[:batch], y[:batch])
        return m["loss"]

    us = time_fn(one_step, iters=iters, warmup=warmup)
    rep = plan.train_report()
    fb = rep["full_board"]
    steps_s = 1e6 / us
    emit(f"train/{plan.name}", us,
         f"steps_s={steps_s:.2f};qat={int(qat)};"
         f"model_ms={rep['seconds']*1e3:.3f};"
         f"model_ms_20core={fb['seconds']*1e3:.3f};"
         f"bwd_frac={rep['backward']['cycles']/max(rep['cycles'],1):.3f}")
    return {
        "name": plan.name,
        "batch": batch,
        "qat": qat,
        "measured_us_per_step": us,
        "steps_per_s": steps_s,
        "model_psums_step": rep["psums"],
        "model_seconds_1core": rep["seconds"],
        "model_gops_1core": rep["gops_paper"],
        "model_seconds_20core": fb["seconds"],
        "model_gops_20core": fb["gops_paper"],
        "backward_cycle_fraction":
            rep["backward"]["cycles"] / max(rep["cycles"], 1),
    }


def _latency_section(results) -> dict:
    """Top-level p50/p90/p99 per zoo network (schema-additive)."""
    return {r["name"]: r["latency_percentiles"] for r in results}


def _dump_obs():
    """With REPRO_OBS=1 (or obs.enable()), write the Chrome trace +
    metrics JSONL next to the bench output (REPRO_OBS_DIR overrides)."""
    if not obs.enabled():
        return
    paths = obs.dump(os.environ.get("REPRO_OBS_DIR", "."), prefix="bench")
    if paths:
        emit("obs/trace", 0.0, f"path={paths['trace']}")
        emit("obs/metrics", 0.0, f"path={paths['metrics']}")


def run(smoke: bool = False, train: bool = False, serving: bool = False):
    rng = np.random.default_rng(3)
    serving_rows = None
    if serving:
        from benchmarks.serving_load import serving_section
        serving_rows = serving_section(np.random.default_rng(11),
                                       smoke=smoke)
    if smoke:
        # CI fast path: LeNet + the residual-graph compiler (resnet) +
        # the grouped-conv compiler (mobilenet) with minimal iterations;
        # do NOT touch the tracked BENCH_network.json by default — that
        # file records the cross-PR trajectory of the full run.  With
        # BENCH_NETWORK_JSON pointed elsewhere (the CI calibration lane),
        # the smoke payload IS written there so the calibration +
        # measured_vs_predicted sections land in the uploaded artifact.
        results = [
            _bench_plan(network.lenet(), rng, batch=2, iters=1, warmup=1),
            _bench_plan(network.resnet_small(), rng, batch=2, iters=1,
                        warmup=1),
            _bench_plan(network.mobilenet_small(), rng, batch=2, iters=1,
                        warmup=1),
            # dense prediction: the transposed-conv (unet) and dilated
            # (atrous-context) compilers ride the CI fast path too
            _bench_plan(network.unet_small(), rng, batch=2, iters=1,
                        warmup=1),
            _bench_plan(network.dilated_context(), rng, batch=2, iters=1,
                        warmup=1)]
        # sequential-vs-pipelined compile path (model columns + one
        # measured pass each way)
        pipe_rows = [_bench_pipeline(network.mobilenet_small(), rng)]
        mvp = []
        if CALIB is not None:
            mvp = [_measured_vs_predicted(network.lenet(), rng, iters=1),
                   _measured_vs_predicted(network.mobilenet_small(), rng,
                                          iters=1),
                   # exercises the conv_transpose timing branch
                   _measured_vs_predicted(network.unet_small(), rng,
                                          iters=1)]
        if train:
            _bench_train(network.lenet(input_shape=(12, 12, 1)), rng,
                         batch=2, iters=1, warmup=1)
        if os.environ.get("BENCH_NETWORK_JSON"):
            payload = {"backend": jax.default_backend(),
                       "interpret": jax.default_backend() != "tpu",
                       "smoke": True,
                       "provenance": _provenance(),
                       "calibration": (CALIB.to_dict()
                                       if CALIB is not None else None),
                       "networks": results,
                       "latency_percentiles": _latency_section(results),
                       "pipeline": pipe_rows,
                       "measured_vs_predicted": mvp}
            if serving_rows is not None:
                payload["serving"] = serving_rows
            with open(OUT_PATH, "w") as f:
                json.dump(payload, f, indent=2)
            emit("network/json", 0.0, f"path={OUT_PATH}")
        _dump_obs()
        return
    results = [_bench_plan(network.lenet(), rng),
               _bench_plan(network.vgg_small(), rng),
               # residual graphs: skip adds + projection shortcuts
               _bench_plan(network.resnet_small(), rng),
               # grouped/depthwise convs: the MobileNet edge family, with
               # grouped perfmodel rows (DMA-bound depthwise layers)
               _bench_plan(network.mobilenet_small(), rng),
               _bench_plan(network.mobilenet_v2ish(), rng),
               # dense-prediction (segmentation) workloads: transposed-
               # conv upsampling with skip concats (unet) and dilated
               # context aggregation — the rows carry the zero-skipping
               # transpose psum pricing
               _bench_plan(network.unet_small(), rng),
               _bench_plan(network.dilated_context(), rng),
               # the tiled-pipeline workload: exceeds whole-map VMEM
               _bench_plan(network.large_map(), rng, batch=2,
                           iters=1, warmup=0)]
    payload = {"backend": jax.default_backend(),
               "interpret": jax.default_backend() != "tpu",
               "provenance": _provenance(),
               # the table the autotune rows were priced under — None
               # means the analytic model (run benchmarks/calibrate.py
               # first, or set CALIBRATION_JSON, for calibrated rows)
               "calibration": (CALIB.to_dict() if CALIB is not None
                               else None),
               "networks": results,
               "latency_percentiles": _latency_section(results)}
    # model-accuracy tracking: per-layer measured vs calibrated-predicted
    # wall time.  large_map is deliberately skipped — interpret-mode
    # timing of its tiled layers is minutes per row; its model columns in
    # the network section remain the tracked signal.
    if CALIB is not None:
        payload["measured_vs_predicted"] = [
            _measured_vs_predicted(network.lenet(), rng),
            _measured_vs_predicted(network.vgg_small(), rng),
            _measured_vs_predicted(network.resnet_small(), rng),
            _measured_vs_predicted(network.mobilenet_small(), rng),
            _measured_vs_predicted(network.mobilenet_v2ish(), rng),
            _measured_vs_predicted(network.unet_small(), rng),
            _measured_vs_predicted(network.dilated_context(), rng),
        ]
        payload["measured_vs_predicted_skipped"] = [
            {"name": "large_map",
             "reason": "interpret-mode per-layer timing is minutes per "
                       "row; model columns in 'networks' are the signal"}]
    # sequential-vs-pipelined head-to-head: measured on the DMA-bound
    # MobileNet family, model-only for the big tiled map (interpret-mode
    # timing of large_map is already minutes per run)
    payload["pipeline"] = [
        _bench_pipeline(network.mobilenet_small(), rng),
        _bench_pipeline(network.mobilenet_v2ish(), rng),
        _bench_pipeline(network.large_map(), rng, measure=False),
    ]
    # train-step rows: the QAT trainer through the backward WS kernels.
    # Always part of the full run — the tracked JSON must not lose its
    # training trajectory just because a flag was omitted.
    payload["train"] = [
        _bench_train(network.lenet(input_shape=(12, 12, 1)), rng),
        _bench_train(network.resnet_small(input_shape=(16, 16, 4)),
                     rng, batch=2, iters=2),
        # the grouped backward pass: depthwise transposed convs +
        # per-group weight-grad GEMMs through the QAT step
        _bench_train(network.mobilenet_small(input_shape=(12, 12, 1)),
                     rng, batch=2, iters=2),
    ]
    # serving trajectory: sustained requests/s through the continuous-
    # batching queue (only with --serving — the open-loop sweeps add
    # minutes of interpret-mode wall time to a flagless run)
    if serving_rows is not None:
        payload["serving"] = serving_rows
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    emit("network/json", 0.0, f"path={OUT_PATH}")
    _dump_obs()


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(smoke="--smoke" in sys.argv, train="--train" in sys.argv,
        serving="--serving" in sys.argv)
