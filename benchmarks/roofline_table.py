"""Formats the dry-run matrix (experiments/dryrun/*.json) into the
EXPERIMENTS.md roofline tables.  Usable as a bench (emits CSV) and as a
report generator (python -m benchmarks.roofline_table --markdown)."""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from benchmarks.bench_util import emit

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load_cells(d: str = DRYRUN_DIR) -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(d, "*.json"))):
        if path.endswith("skips.json"):
            continue
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def load_skips(d: str = DRYRUN_DIR) -> List[Dict]:
    p = os.path.join(d, "skips.json")
    if not os.path.exists(p):
        return []
    with open(p) as f:
        return json.load(f)


def run():
    for c in load_cells():
        r = c["roofline"]
        emit(f"dryrun/{c['arch']}/{c['shape']}/{c['mesh']}",
             max(r["t_compute"], r["t_memory"], r["t_collective"]) * 1e6,
             f"bottleneck={r['bottleneck']};mfu={r['mfu_at_roofline']:.3f};"
             f"compile_s={c['compile_s']}")


def markdown(d: str = DRYRUN_DIR) -> str:
    rows = []
    head = ("| arch | shape | mesh | chips | t_comp (s) | t_mem (s) | "
            "t_coll (s) | bound | HLO GF/dev | useful | MFU@roofline | "
            "HBM GB/dev |")
    sep = "|" + "---|" * 12
    rows.append(head)
    rows.append(sep)
    for c in load_cells(d):
        r = c["roofline"]
        ma = c.get("memory_analysis", {})
        hbm = ma.get("total_hbm_bytes", 0) / 1e9
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | {c['chips']} "
            f"| {r['t_compute']:.4f} | {r['t_memory']:.4f} "
            f"| {r['t_collective']:.4f} | {r['bottleneck']} "
            f"| {r['hlo_flops_dev']/1e9:.0f} | {r['useful_ratio']:.2f} "
            f"| {r['mfu_at_roofline']:.3f} | {hbm:.1f} |")
    for s in load_skips(d):
        rows.append(f"| {s['arch']} | {s['shape']} | {s['mesh']} | — | — | — "
                    f"| — | SKIP | — | — | — | — |")
    return "\n".join(rows)


if __name__ == "__main__":
    import sys
    if "--markdown" in sys.argv:
        print(markdown())
    else:
        run()
