"""Benchmark for the paper's §5.2 simulation + Table 1 context.

Reproduces the paper's own throughput model exactly and compares three
executions of the same [224×224×8] ⊛ [8×3×3×8] layer:

  a) the paper's FPGA IP core (analytic, 112 MHz Pynq Z2)   — 0.224 GOPS
  b) 20 replicated IP cores (the paper's full-board figure) — 4.48 GOPS
  c) one TPU v5e core running conv2d_ws (roofline model)    — the adapted
     architecture's headroom
  d) CPU-measured oracle + interpret-mode kernel (functional check only)
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.bench_util import emit, time_fn
from repro.core import ConvCore, ConvCoreConfig
from repro.core.perfmodel import (IPCoreConfig, gops_macs, gops_paper,
                                  psum_count, seconds, tpu_conv_roofline)
from repro.kernels import ref


def run():
    n = psum_count(224, 224, 8, 8)
    t1 = seconds(n)
    emit("paper/psums", 0.0, f"count={n}")
    emit("paper/ip_core_1x", t1 * 1e6, f"GOPS_paper={gops_paper(n):.3f}"
         f";GOPS_macs={gops_macs(n):.3f}")
    t20 = seconds(n, IPCoreConfig(ip_cores=20))
    emit("paper/ip_core_20x", t20 * 1e6,
         f"GOPS_paper={gops_paper(n, IPCoreConfig(ip_cores=20)):.2f}")

    r = tpu_conv_roofline(224, 224, 8, 8)
    emit("tpu_v5e/conv2d_ws_roofline", r["seconds"] * 1e6,
         f"GOPS_paper={r['gops_paper']:.1f};bound="
         f"{'memory' if r['t_memory'] > r['t_compute'] else 'compute'};"
         f"speedup_vs_paper={t1 / r['seconds']:.0f}x")

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, 224, 224, 8)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 8, 8)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(8,)), jnp.float32)
    core = ConvCore(ConvCoreConfig(backend="ref"))
    us = time_fn(lambda: core.apply_layer(x, w, b), iters=3)
    emit("cpu_host/conv_oracle", us, f"GOPS_paper={n / us / 1e3:.3f}")
