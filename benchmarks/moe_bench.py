"""MoE dispatch benchmark (paper-adjacent: the EP collective pattern the
§Perf hillclimb optimizes).  CPU functional timings + dispatch statistics."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_util import emit, time_fn
from repro.configs.base import get_config, reduce_config
from repro.layers.common import materialize
from repro.layers.moe import _capacity, apply_moe, moe_specs


def run():
    for name in ("deepseek_moe_16b", "qwen3_moe_30b_a3b"):
        cfg = reduce_config(get_config(name))
        params = materialize(moe_specs(cfg), jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(4, 64, cfg.d_model)), jnp.float32)
        fn = jax.jit(lambda p, x: apply_moe(p, x, cfg)[0])
        us = time_fn(fn, params, x, iters=3)
        m = cfg.moe
        cap = _capacity((4 * 64) // 4, m)
        emit(f"moe/{name}", us,
             f"experts={m.num_experts};topk={m.top_k};capacity={cap}")

        # drop-rate statistic at train capacity factor
        logits = jnp.einsum("bsd,de->bse", x, params["router"])
        _, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), m.top_k)
        flat = idx.reshape(4, -1)
        oh = jax.nn.one_hot(flat, m.num_experts, dtype=jnp.int32)
        pos = jnp.cumsum(oh, axis=1) - oh
        p = jnp.take_along_axis(pos, flat[..., None], -1)[..., 0]
        drop = float(jnp.mean(p >= cap))
        emit(f"moe/{name}/drop_rate", 0.0, f"dropped_frac={drop:.4f}")
