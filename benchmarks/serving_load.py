"""Open-loop serving load generator for the continuous-batching engine.

Converts the serving headline from per-call latency to sustained
requests/s under load, per zoo network:

* **Sync baseline** — the pre-queue serving idiom: every request
  submitted alone and waited on (a padded batch-of-one program call per
  request, strictly sequential).  This is what ``ConvNetEngine.submit``
  did for a single-image caller before the queue existed.
* **Saturating phase** — ≥4 submitter threads enqueue their whole share
  at once (open-loop at infinite arrival rate) through one shared
  engine.  This is the acceptance gate: continuous batching must
  sustain ≥ 1.5× the sync baseline's requests/s with mean batch fill
  ≥ 0.9 and zero dropped / duplicated / cross-wired responses (every
  response is checked bit-exact against the reference program row).
* **Offered-load sweep** — fixed inter-arrival submission at
  λ ∈ {0.5, 1.0, 2.0}× the measured capacity (capacity = the
  saturating phase's throughput).  Each point reports throughput,
  p50/p90/p99 *including queue wait* (the honest
  ``request_latency_us``), mean batch fill, and the deadline-launch
  fraction — below capacity the deadline launches partial batches
  (latency-bound), above it batches fill before the deadline
  (throughput-bound): the throughput-vs-deadline tradeoff the README
  table quotes.
* **Multi-model LRU segment** — two networks round-robin through a
  ``cache_capacity=1`` engine: evictions and recompiles must be counted
  and the post-evict logits bit-exact with a fresh single-model engine.

``large_map`` is skipped (interpret-mode batches are ~minutes; its
model columns in the ``networks`` section remain the tracked signal).

Emits the schema-additive ``serving`` section consumed by
``benchmarks/network_bench.py --serving`` and the serving-smoke CI lane;
with obs enabled the shared engine's metrics registry (queue-depth
gauges, formation counters, queue-wait histograms) is exported to
``serving_metrics.jsonl`` in ``REPRO_OBS_DIR``.
"""

from __future__ import annotations

import os
import threading
import time
from typing import Callable, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from benchmarks.bench_util import emit
from repro import obs
from repro.core import network
from repro.core.convcore import ConvCoreConfig
from repro.core.network import make_int8_program
from repro.serving.batching import ContinuousBatchingEngine

SWEEP_FACTORS = (0.5, 1.0, 2.0)
FUTURE_TIMEOUT_S = 600.0


def _qnet(plan, rng):
    params = plan.init_params(rng)
    x = np.asarray(rng.normal(size=(2, *plan.input_shape)), np.float32)
    return network.quantize_network(plan, params, x)


def _reference_rows(program, imgs: np.ndarray, batch: int) -> np.ndarray:
    """Ground-truth logits for every image, through the same padded
    fixed-batch program the engine runs."""
    rows = []
    for lo in range(0, imgs.shape[0], batch):
        chunk = imgs[lo:lo + batch]
        pad = batch - chunk.shape[0]
        if pad:
            chunk = np.concatenate(
                [chunk, np.zeros((pad, *imgs.shape[1:]), np.float32)])
        rows.append(np.asarray(program(jnp.asarray(chunk)))[:batch - pad])
    return np.concatenate(rows)


def _sync_baseline(program, imgs: np.ndarray, batch: int) -> float:
    """Requests/s of the pre-queue idiom: one padded batch-of-one
    program call per request, submitted sequentially and materialized
    before the next is sent."""
    pad = np.zeros((batch - 1, *imgs.shape[1:]), np.float32)
    np.asarray(program(jnp.asarray(                       # warm the shape
        np.concatenate([imgs[:1], pad]))))
    t0 = time.perf_counter()
    for i in range(imgs.shape[0]):
        np.asarray(program(jnp.asarray(
            np.concatenate([imgs[i:i + 1], pad]))))
    wall = time.perf_counter() - t0
    return imgs.shape[0] / wall


def _saturating(eng: ContinuousBatchingEngine, model: str,
                imgs: np.ndarray, want: np.ndarray,
                threads: int = 4) -> Dict:
    """Open-loop at infinite λ: every thread enqueues its whole share at
    once.  Returns throughput + zero-drop/zero-dup accounting."""
    shares = np.array_split(np.arange(imgs.shape[0]), threads)
    futures: List[List] = [None] * threads
    t_start = [0.0] * threads

    def submit(t):
        t_start[t] = time.perf_counter()
        futures[t] = eng.submit_async(imgs[shares[t]], model=model)

    ths = [threading.Thread(target=submit, args=(t,))
           for t in range(threads)]
    t0 = time.perf_counter()
    for th in ths:
        th.start()
    for th in ths:
        th.join(timeout=FUTURE_TIMEOUT_S)
    results: Dict[int, np.ndarray] = {}
    for t in range(threads):
        for j, f in enumerate(futures[t]):
            results[int(shares[t][j])] = f.result(
                timeout=FUTURE_TIMEOUT_S)
    wall = time.perf_counter() - t0
    # zero dropped: every request index resolved exactly once; zero
    # duplicated/cross-wired: each response bit-exact with its own row
    dropped = imgs.shape[0] - len(results)
    mismatched = sum(
        0 if np.array_equal(results[i], want[i]) else 1
        for i in results)
    return {"requests": imgs.shape[0], "threads": threads,
            "wall_s": wall, "rps": imgs.shape[0] / wall,
            "dropped": dropped, "mismatched": mismatched}


def _open_loop_point(eng: ContinuousBatchingEngine, model: str,
                     imgs: np.ndarray, offered_rps: float,
                     factor: float) -> Dict:
    """One sweep point: submit at fixed inter-arrival 1/λ, wait for
    everything, read the engine's own histograms for the answer."""
    eng.metrics.reset()
    interval = 1.0 / offered_rps
    futures = []
    t0 = time.perf_counter()
    for i in range(imgs.shape[0]):
        futures.append(eng.submit_async(imgs[i], model=model))
        target = t0 + (i + 1) * interval
        delay = target - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
    for f in futures:
        f.result(timeout=FUTURE_TIMEOUT_S)
    wall = time.perf_counter() - t0
    pct = eng.latency_percentiles()
    fill = eng.metrics.histogram("batch_fill").summary()
    formed = eng.formation_counts()
    batches = max(sum(formed.values()), 1)
    return {"lambda_x_capacity": factor,
            "offered_rps": offered_rps,
            "throughput_rps": imgs.shape[0] / wall,
            "p50_us": pct["p50"], "p90_us": pct["p90"],
            "p99_us": pct["p99"],
            "mean_batch_fill": fill["mean"],
            "deadline_fraction": formed["deadline"] / batches,
            "formation": formed,
            "queue_depth_peak":
                eng.metrics.gauge("queue.depth.peak").value}


def bench_serving_network(plan, rng, *, batch: int = 8,
                          deadline_ms: float = 20.0,
                          sat_per_thread: Optional[int] = None,
                          sync_requests: Optional[int] = None,
                          sweep_requests: Optional[int] = None,
                          threads: int = 4,
                          assert_acceptance: bool = False) -> Dict:
    """Full serving benchmark for one network.  With
    ``assert_acceptance`` the ISSUE-10 gate is enforced here: ≥1.5×
    sync requests/s, mean fill ≥ 0.9, zero dropped/duplicated."""
    sat_per_thread = sat_per_thread or 2 * batch
    sync_requests = sync_requests or batch
    sweep_requests = sweep_requests or 2 * batch
    qnet = _qnet(plan, rng)
    cfg = ConvCoreConfig(backend="pallas", int8=True)
    program = make_int8_program(qnet, cfg)

    n_sat = threads * sat_per_thread
    imgs = rng.normal(
        size=(max(n_sat, sweep_requests), *plan.input_shape)
    ).astype(np.float32)
    want = _reference_rows(program, imgs, batch)

    sync_rps = _sync_baseline(program, imgs[:sync_requests], batch)

    eng = ContinuousBatchingEngine(batch=batch, backend="pallas",
                                   deadline_ms=deadline_ms)
    try:
        eng.add_model(qnet)
        # warm the engine's own program (compile is eager, but the first
        # program CALL traces) so the measured phases time serving, not
        # jit tracing
        eng.submit(imgs[:1])
        eng.metrics.reset()
        sat = _saturating(eng, plan.name, imgs[:n_sat], want, threads)
        fill = eng.metrics.histogram("batch_fill").summary()
        speedup = sat["rps"] / sync_rps
        row = {"name": plan.name, "batch": batch,
               "deadline_ms": deadline_ms,
               "sync_rps": sync_rps,
               "continuous_rps": sat["rps"],
               "speedup_vs_sync": speedup,
               "mean_batch_fill": fill["mean"],
               "saturating": {**sat,
                              "formation": eng.formation_counts()}}
        if assert_acceptance:
            assert speedup >= 1.5, (
                f"{plan.name}: continuous batching {sat['rps']:.1f} rps "
                f"< 1.5x sync {sync_rps:.1f} rps")
            assert fill["mean"] >= 0.9, (
                f"{plan.name}: mean batch fill {fill['mean']:.3f} < 0.9 "
                "under saturating load")
        assert sat["dropped"] == 0, (
            f"{plan.name}: {sat['dropped']} requests dropped")
        assert sat["mismatched"] == 0, (
            f"{plan.name}: {sat['mismatched']} responses duplicated or "
            "cross-wired (not bit-exact with their reference rows)")
        # offered-load sweep around the measured capacity
        sweep = []
        for factor in SWEEP_FACTORS:
            sweep.append(_open_loop_point(
                eng, plan.name, imgs[:sweep_requests],
                offered_rps=max(sat["rps"] * factor, 1e-6),
                factor=factor))
        row["sweep"] = sweep
        emit(f"serving/{plan.name}", 0.0,
             f"sync_rps={sync_rps:.1f};cont_rps={sat['rps']:.1f};"
             f"speedup={speedup:.2f};fill={fill['mean']:.3f};"
             f"dropped={sat['dropped']};mismatched={sat['mismatched']};"
             f"deadline_frac_at_half_load={sweep[0]['deadline_fraction']:.2f}")
        _export_engine_metrics(eng, plan.name)
    finally:
        eng.close()
    return row


def bench_multi_model(rng, *, batch: int = 4) -> Dict:
    """LRU segment: two networks round-robin through a capacity-1
    program cache — evictions observable, recompiled logits bit-exact
    with a fresh single-model engine."""
    qa = _qnet(network.lenet(input_shape=(12, 12, 1)), rng)
    qb = _qnet(network.lenet(input_shape=(10, 10, 1)), rng)
    imgs_a = rng.normal(size=(3, 12, 12, 1)).astype(np.float32)
    imgs_b = rng.normal(size=(3, 10, 10, 1)).astype(np.float32)
    eng = ContinuousBatchingEngine(batch=batch, backend="pallas",
                                   cache_capacity=1)
    try:
        eng.add_model(qa, name="lenet12")
        eng.add_model(qb, name="lenet10")
        out_a = eng.submit(imgs_a, model="lenet12")   # recompile a
        out_b = eng.submit(imgs_b, model="lenet10")   # recompile b
        cache = eng.cache_stats()
    finally:
        eng.close()
    fresh = ContinuousBatchingEngine(batch=batch, backend="pallas")
    try:
        fresh.add_model(qa, name="lenet12")
        want_a = fresh.submit(imgs_a, model="lenet12")
    finally:
        fresh.close()
    bit_exact = bool(np.array_equal(out_a, want_a))
    assert cache["evictions"] >= 2, cache
    assert cache["size"] <= 1 and cache["capacity"] == 1, cache
    assert bit_exact, "post-eviction recompile changed the logits"
    assert out_b.shape == (3, 10)
    emit("serving/multi_model", 0.0,
         f"evictions={cache['evictions']};misses={cache['misses']};"
         f"hits={cache['hits']};bit_exact={int(bit_exact)}")
    return {"cache": cache, "bit_exact": bit_exact,
            "models": ["lenet12", "lenet10"]}


def _export_engine_metrics(eng: ContinuousBatchingEngine,
                           name: str) -> None:
    """With obs on, persist the engine's per-engine registry (queue
    gauges, formation counters, latency histograms) — the global
    obs.dump only covers the process registry."""
    if not obs.enabled():
        return
    out_dir = os.environ.get("REPRO_OBS_DIR", ".")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "serving_metrics.jsonl")
    eng.metrics.export_jsonl(path)
    emit(f"serving/metrics/{name}", 0.0, f"path={path}")


def serving_section(rng, smoke: bool = False) -> Dict:
    """The schema-additive ``serving`` section for BENCH_network.json.

    Smoke: lenet (with the acceptance gate asserted) + the multi-model
    LRU segment.  Full: the whole zoo except large_map."""
    if smoke:
        nets = [(network.lenet(), True)]
    else:
        nets = [(network.lenet(), True),
                (network.vgg_small(), False),
                (network.resnet_small(), False),
                (network.mobilenet_small(), False),
                (network.mobilenet_v2ish(), False),
                (network.unet_small(), False),
                (network.dilated_context(), False)]
    rows = [bench_serving_network(plan, rng, assert_acceptance=gate)
            for plan, gate in nets]
    return {
        "batch": 8,
        "threads": 4,
        "sweep_factors": list(SWEEP_FACTORS),
        "networks": rows,
        "multi_model": bench_multi_model(rng),
        "skipped": [
            {"name": "large_map",
             "reason": "interpret-mode batch is ~minutes; serving load "
                       "generation is meaningless at that scale on CPU — "
                       "model columns in 'networks' stay the signal"}],
    }
