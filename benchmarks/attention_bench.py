"""Attention implementations: chunked-flash vs dense oracle (CPU functional
timing + the memory-footprint argument that motivates chunking)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_util import emit, time_fn
from repro.layers.attention import chunked_attention, dense_attention


def run():
    rng = np.random.default_rng(0)
    B, S, H, D = 1, 512, 4, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)

    dense = jax.jit(lambda q, k, v: dense_attention(q, k, v, causal=True))
    emit("attention/dense_512", time_fn(dense, q, k, v, iters=3),
         f"scores_bytes={B*H*S*S*4}")

    for chunk in (128, 256):
        ck = jax.jit(lambda q, k, v, c=chunk: chunked_attention(
            q, k, v, causal=True, chunk=c))
        emit(f"attention/chunked_{chunk}", time_fn(ck, q, k, v, iters=3),
             f"flash_bytes={B*H*chunk*chunk*4}")

    win = jax.jit(lambda q, k, v: chunked_attention(
        q, k, v, causal=True, window=128, chunk=128))
    emit("attention/window_128", time_fn(win, q, k, v, iters=3),
         "subquadratic=True")
