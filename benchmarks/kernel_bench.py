"""Kernel microbenchmarks: banked conv + WS-GEMM variants (functional CPU
timings + analytic VMEM working sets from banking.py), plus the
sequential-vs-pipelined conv head-to-head over the DMA-bound shapes from
the zoo so the perfmodel crossover predictor can be eyeballed against
measurement.  Interpret-mode caveat for the head-to-head: the manual DMAs
execute eagerly in Python on CPU, so measured_us there reflects emulation
overhead, not overlap — the model columns (seq/pipe cycles, the predictor
verdict) are the cross-PR signal; on a TPU host the same rows time native
Mosaic."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

import os

from benchmarks.bench_util import emit, time_fn
from repro.core import perfmodel
from repro.core.banking import plan_banks, plan_tiles
from repro.core.calibration import load_table
from repro.kernels import ref
from repro.kernels.conv2d_ws import conv2d_ws
from repro.kernels.conv2d_ws_pipe import conv2d_ws_pipe
from repro.kernels.matmul_ws import matmul_ws


def run():
    rng = np.random.default_rng(1)

    # --- conv banking variants (paper M1/M2 sweep) -----------------------
    x = jnp.asarray(rng.normal(size=(1, 64, 64, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 16, 16)), jnp.float32)
    for cb, kb in [(1, 1), (4, 4), (8, 8)]:
        plan = plan_banks(64, 64, 16, 16, in_bytes=4,
                          cin_banks=cb, kout_banks=kb)
        us = time_fn(lambda cb=cb, kb=kb: conv2d_ws(
            x, w, cin_banks=cb, kout_banks=kb, interpret=True), iters=3)
        emit(f"conv2d_ws/banks_{cb}x{kb}", us,
             f"vmem_ws_bytes={plan.working_set_bytes}")

    # --- int8 vs f32 datapath --------------------------------------------
    xi = jnp.asarray(rng.integers(-128, 128, (1, 64, 64, 16)), jnp.int8)
    wi = jnp.asarray(rng.integers(-128, 128, (3, 3, 16, 16)), jnp.int8)
    us = time_fn(lambda: conv2d_ws(xi, wi, interpret=True), iters=3)
    emit("conv2d_ws/int8", us, "accum=int32")

    # --- WS-GEMM block sweep ----------------------------------------------
    a = jnp.asarray(rng.normal(size=(512, 1024)), jnp.float32)
    bmat = jnp.asarray(rng.normal(size=(1024, 512)), jnp.float32)
    for bm, bk, bn in [(128, 256, 128), (256, 512, 256)]:
        us = time_fn(lambda bm=bm, bk=bk, bn=bn: matmul_ws(
            a, bmat, bm=bm, bk=bk, bn=bn, interpret=True), iters=3)
        flops = 2 * 512 * 1024 * 512
        emit(f"matmul_ws/b{bm}x{bk}x{bn}", us, f"flops={flops}")

    # --- oracle baseline ---------------------------------------------------
    us = time_fn(lambda: ref.matmul_ref(a, bmat), iters=3)
    emit("matmul_ref/xla_cpu", us, "")

    # --- sequential vs pipelined head-to-head (DMA-bound zoo shapes) ------
    # depthwise 3×3 (the dma_bound_board family), 1×1 pointwise, and a
    # large-map tiled layer: one row per (shape, kernel variant) with the
    # crossover predictor's verdict alongside the measurement
    cases = [
        ("depthwise3x3", dict(h=16, w=16, c=32, k=32, kh=3, kw=3,
                              groups=32, pad="SAME", h_tile=0, w_tile=0)),
        ("pointwise1x1", dict(h=16, w=16, c=32, k=64, kh=1, kw=1,
                              groups=1, pad="VALID", h_tile=0, w_tile=0)),
        ("largemap_tiled", dict(h=64, w=64, c=16, k=16, kh=3, kw=3,
                                groups=1, pad="SAME", h_tile=16,
                                w_tile=16)),
    ]
    # fitted table (benchmarks/calibrate.py): the head-to-head rows then
    # carry the CALIBRATED verdict alongside the analytic one, so a
    # crossover flip after calibration is visible right in the kernel rows
    calib = load_table(os.environ.get("CALIBRATION_JSON",
                                      "CALIBRATION.json"))
    for name, c_ in cases:
        cb, kb = ref.grouped_banks(c_["c"], c_["k"], c_["groups"])
        xi8 = jnp.asarray(
            rng.integers(-128, 128,
                         (1, c_["h"], c_["w"], c_["c"])), jnp.int8)
        wi8 = jnp.asarray(
            rng.integers(-128, 128,
                         (c_["kh"], c_["kw"], c_["c"] // c_["groups"],
                          c_["k"])), jnp.int8)
        plan = plan_tiles(c_["h"], c_["w"], c_["c"], c_["k"], c_["kh"],
                          c_["kw"], padding=c_["pad"], groups=c_["groups"],
                          in_bytes=1, out_bytes=1, cin_banks=cb,
                          kout_banks=kb, kernel="auto")
        psums = perfmodel.psum_count(c_["h"], c_["w"], c_["c"], c_["k"],
                                     c_["kh"], c_["kw"], padding=c_["pad"],
                                     groups=c_["groups"])
        est = perfmodel.pipeline_estimate(plan, psums)
        model = (f"model_seq_cycles={est['sequential_cycles']};"
                 f"model_pipe_cycles={est['pipelined_cycles']};"
                 f"model_speedup={est['speedup']:.3f};"
                 f"predictor_pipelined={int(plan.pipelined)}")
        if calib is not None:
            cal = perfmodel.pipeline_estimate(plan, psums, calib=calib)
            model += (f";calib_seq_cycles={cal['sequential_cycles']};"
                      f"calib_pipe_cycles={cal['pipelined_cycles']};"
                      f"calib_pipelined="
                      f"{int(cal['pipelined_cycles'] < cal['sequential_cycles'])}")
        for variant, fn in (("seq", conv2d_ws), ("pipe", conv2d_ws_pipe)):
            us = time_fn(lambda fn=fn: fn(
                xi8, wi8, padding=c_["pad"], groups=c_["groups"],
                cin_banks=cb, kout_banks=kb, h_tile=c_["h_tile"],
                w_tile=c_["w_tile"], interpret=True), iters=2)
            emit(f"conv_pipe/{name}/{variant}", us, model)
