"""Kernel microbenchmarks: banked conv + WS-GEMM variants (functional CPU
timings + analytic VMEM working sets from banking.py)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.bench_util import emit, time_fn
from repro.core.banking import plan_banks
from repro.kernels import ref
from repro.kernels.conv2d_ws import conv2d_ws
from repro.kernels.matmul_ws import matmul_ws


def run():
    rng = np.random.default_rng(1)

    # --- conv banking variants (paper M1/M2 sweep) -----------------------
    x = jnp.asarray(rng.normal(size=(1, 64, 64, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 16, 16)), jnp.float32)
    for cb, kb in [(1, 1), (4, 4), (8, 8)]:
        plan = plan_banks(64, 64, 16, 16, in_bytes=4,
                          cin_banks=cb, kout_banks=kb)
        us = time_fn(lambda cb=cb, kb=kb: conv2d_ws(
            x, w, cin_banks=cb, kout_banks=kb, interpret=True), iters=3)
        emit(f"conv2d_ws/banks_{cb}x{kb}", us,
             f"vmem_ws_bytes={plan.working_set_bytes}")

    # --- int8 vs f32 datapath --------------------------------------------
    xi = jnp.asarray(rng.integers(-128, 128, (1, 64, 64, 16)), jnp.int8)
    wi = jnp.asarray(rng.integers(-128, 128, (3, 3, 16, 16)), jnp.int8)
    us = time_fn(lambda: conv2d_ws(xi, wi, interpret=True), iters=3)
    emit("conv2d_ws/int8", us, "accum=int32")

    # --- WS-GEMM block sweep ----------------------------------------------
    a = jnp.asarray(rng.normal(size=(512, 1024)), jnp.float32)
    bmat = jnp.asarray(rng.normal(size=(1024, 512)), jnp.float32)
    for bm, bk, bn in [(128, 256, 128), (256, 512, 256)]:
        us = time_fn(lambda bm=bm, bk=bk, bn=bn: matmul_ws(
            a, bmat, bm=bm, bk=bk, bn=bn, interpret=True), iters=3)
        flops = 2 * 512 * 1024 * 512
        emit(f"matmul_ws/b{bm}x{bk}x{bn}", us, f"flops={flops}")

    # --- oracle baseline ---------------------------------------------------
    us = time_fn(lambda: ref.matmul_ref(a, bmat), iters=3)
    emit("matmul_ref/xla_cpu", us, "")
