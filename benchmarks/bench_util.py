"""Timing helpers for the benchmark harness (CPU host; kernel numbers on
this container are functional references — the TPU numbers come from the
roofline analysis of the compiled dry-run)."""

from __future__ import annotations

import time

import jax


def time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in microseconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")
