"""Timing helpers for the benchmark harness (CPU host; kernel numbers on
this container are functional references — the TPU numbers come from the
roofline analysis of the compiled dry-run)."""

from __future__ import annotations

import time

import jax


class Timing(float):
    """Median wall-time per call in microseconds, carrying the full stats
    record the calibration fitter needs: ``min`` / ``median`` / ``iqr``
    and the raw sample list.  A float subclass, so every existing
    ``time_fn`` call site keeps working unchanged while calibration code
    reads ``.iqr_us`` to reject noisy samples."""

    def __new__(cls, samples_us):
        times = sorted(samples_us)
        n = len(times)
        if n == 0:
            raise ValueError("Timing needs at least one sample")
        # proper median: mean of the two middle elements when n is even
        # (the old harness took the upper-middle one)
        mid = n // 2
        median = times[mid] if n % 2 else (times[mid - 1] + times[mid]) / 2.0
        self = super().__new__(cls, median)
        self.samples_us = tuple(times)
        self.median_us = median
        self.min_us = times[0]
        q1 = times[max(0, (n - 1) // 4)]
        q3 = times[min(n - 1, (3 * (n - 1) + 2) // 4)]
        self.iqr_us = q3 - q1
        return self

    def stats(self) -> dict:
        return {"median_us": self.median_us, "min_us": self.min_us,
                "iqr_us": self.iqr_us, "samples_us": list(self.samples_us)}

    def to_histogram(self, name: str):
        """Feed the samples into an obs histogram (global registry) and
        return it — the bridge from one-shot bench timings to the
        percentile machinery serving uses."""
        from repro import obs
        h = obs.metrics.histogram(name)
        for s in self.samples_us:
            h.observe(s)
        return h

    def percentiles(self) -> dict:
        """Exact p50/p90/p99 over the raw samples (no bucketing — bench
        runs hold every sample, unlike the serving histograms)."""
        times = self.samples_us
        n = len(times)

        def pct(p):
            if n == 1:
                return times[0]
            # linear interpolation between closest ranks
            x = (p / 100.0) * (n - 1)
            lo = int(x)
            hi = min(lo + 1, n - 1)
            return times[lo] + (times[hi] - times[lo]) * (x - lo)

        return {"count": n, "p50": pct(50), "p90": pct(90),
                "p99": pct(99), "min": times[0], "max": times[-1]}


def time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> Timing:
    """Median wall-time per call in microseconds (blocks on results).
    Returns a :class:`Timing` — a float (the median) that also carries
    min / IQR / the sample list for calibration-grade noise rejection."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    return Timing(times)


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")
    from repro import obs
    if obs.enabled():
        h = obs.metrics.histogram(f"bench.{name}")
        if isinstance(us, Timing):
            for s in us.samples_us:
                h.observe(s)
        else:
            h.observe(float(us))
