"""Benchmark harness — one module per paper table/figure plus framework
microbenches.  Prints ``name,us_per_call,derived`` CSV.

  paper_table1     — §5.2 throughput reproduction (0.224 / 4.48 GOPS) +
                     Table 1 context + the TPU-adapted roofline comparison
  kernel_bench     — conv2d_ws banking sweep, int8 datapath, WS-GEMM blocks
  network_bench    — whole-network int8 executor (LeNet/VGG-small) vs the
                     §5.2 model's network prediction → BENCH_network.json
  attention_bench  — chunked-flash vs dense
  moe_bench        — EP dispatch statistics (drop rates, capacity)
  roofline_table   — the dry-run matrix (TPU numbers; see EXPERIMENTS.md)
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (attention_bench, kernel_bench, moe_bench,
                            network_bench, paper_table1, roofline_table)
    print("name,us_per_call,derived")
    suites = [
        ("paper_table1", paper_table1.run),
        ("kernel_bench", kernel_bench.run),
        ("network_bench", network_bench.run),
        ("attention_bench", attention_bench.run),
        ("moe_bench", moe_bench.run),
        ("roofline_table", roofline_table.run),
    ]
    only = [a for a in sys.argv[1:] if not a.startswith("-")]
    failed = []
    for name, fn in suites:
        if only and name not in only:
            continue
        try:
            fn()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
